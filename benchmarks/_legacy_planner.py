"""Frozen pre-optimization planner pipeline — benchmark baseline only.

This is the solve path as it existed before the structure-cached assembly,
exact presolve and batched round-down: every LP rebuilt by the row-loop
``milp.build_lp_reference`` and solved sequentially at full size. It exists
so ``solver_bench`` can measure the fast path's speedup against the real
pre-PR behaviour on the same machine, with identical plan costs asserted.
Do not import from production code.
"""

from __future__ import annotations

import numpy as np

from repro.core import milp
from repro.core.solver.bnb import MILPResult, _topup_connections
from repro.core.solver.ipm import IPMResult, _max_step, _ruiz_equilibrate

_INT_TOL = 1e-6
_EPS = 1e-11


# --------------------------------------------------------- pre-PR IPM, frozen
# (normal matrix rebuilt and re-factorized for the predictor AND corrector,
# dense slack columns carried through the A D A^T matmul)
def _solve_normal(AD, A, rhs, reg0: float):
    m = A.shape[0]
    M = AD @ A.T
    tr = max(np.trace(M) / max(m, 1), 1.0)
    reg = reg0
    for _ in range(6):
        try:
            L = np.linalg.cholesky(M + reg * tr * np.eye(m))
            return np.linalg.solve(L.T, np.linalg.solve(L, rhs))
        except np.linalg.LinAlgError:
            reg *= 100.0
    return np.linalg.lstsq(M + reg * tr * np.eye(m), rhs, rcond=None)[0]


def _solve_standard_form_legacy(A, b, c, *, tol=1e-9, max_iter=100):
    A = np.asarray(A, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    c = np.asarray(c, dtype=np.float64)
    m, n = A.shape
    if m == 0:
        return np.zeros(n), "optimal", 0, 0.0, 0.0, 0.0
    As, rsc, csc = _ruiz_equilibrate(A)
    bs = b / rsc
    cs = c / csc
    bnorm = 1.0 + np.linalg.norm(bs)
    cnorm = 1.0 + np.linalg.norm(cs)
    AAt = As @ As.T
    tr = max(np.trace(AAt) / m, 1.0)
    AAt_reg = AAt + 1e-10 * tr * np.eye(m)
    try:
        x0 = As.T @ np.linalg.solve(AAt_reg, bs)
        y = np.linalg.solve(AAt_reg, As @ cs)
    except np.linalg.LinAlgError:
        x0 = As.T @ np.linalg.lstsq(AAt_reg, bs, rcond=None)[0]
        y = np.linalg.lstsq(AAt_reg, As @ cs, rcond=None)[0]
    s0 = cs - As.T @ y
    dx = max(-1.5 * x0.min(initial=0.0), 0.0)
    ds = max(-1.5 * s0.min(initial=0.0), 0.0)
    x = x0 + dx
    s = s0 + ds
    xs = float(x @ s)
    if xs <= 0:
        x = np.ones(n)
        s = np.ones(n)
        xs = float(n)
    x = x + 0.5 * xs / max(s.sum(), _EPS)
    s = s + 0.5 * xs / max(x.sum(), _EPS)
    x = np.maximum(x, 1e-4)
    s = np.maximum(s, 1e-4)
    status = "max_iter"
    it = 0
    best_pres = np.inf
    stall = 0
    for it in range(1, max_iter + 1):
        rb = As @ x - bs
        rc = As.T @ y + s - cs
        mu = float(x @ s) / n
        pres = np.linalg.norm(rb) / bnorm
        dres = np.linalg.norm(rc) / cnorm
        gap = n * mu / (1.0 + abs(float(cs @ x)))
        if pres < tol and dres < tol and gap < tol:
            status = "optimal"
            break
        if pres < best_pres * 0.9:
            best_pres = pres
            stall = 0
        else:
            stall += 1
            if stall >= 12 and pres > 1e-6:
                status = "infeasible"
                break
        d = x / s
        AD = As * d[None, :]
        r_xs = x * s
        rhs = -rb - As @ (d * rc - r_xs / s)
        dy_aff = _solve_normal(AD, As, rhs, 1e-12)
        dx_aff = d * (As.T @ dy_aff + rc) - r_xs / s
        ds_aff = -(r_xs + s * dx_aff) / x
        a_pri = _max_step(x, dx_aff)
        a_dua = _max_step(s, ds_aff)
        mu_aff = float((x + a_pri * dx_aff) @ (s + a_dua * ds_aff)) / n
        sigma = float(np.clip((mu_aff / max(mu, _EPS)) ** 3, 0.0, 1.0))
        r_xs = x * s + dx_aff * ds_aff - sigma * mu
        rhs = -rb - As @ (d * rc - r_xs / s)
        dy = _solve_normal(AD, As, rhs, 1e-12)
        dx = d * (As.T @ dy + rc) - r_xs / s
        dsv = -(r_xs + s * dx) / x
        eta = min(0.999, 0.9 + 0.09 * it / max_iter)
        a_pri = eta * _max_step(x, dx)
        a_dua = eta * _max_step(s, dsv)
        x = x + a_pri * dx
        y = y + a_dua * dy
        s = s + a_dua * dsv
        x = np.maximum(x, _EPS)
        s = np.maximum(s, _EPS)
    rb = As @ x - bs
    rc = As.T @ y + s - cs
    mu = float(x @ s) / n
    pres = float(np.linalg.norm(rb) / bnorm)
    dres = float(np.linalg.norm(rc) / cnorm)
    gap = float(n * mu / (1.0 + abs(float(cs @ x))))
    if status != "optimal":
        if pres < 1e-7 and dres < 1e-7 and gap < 1e-7:
            status = "optimal"
        elif pres > 1e-4:
            status = "infeasible"
    return x / csc, status, it, gap, pres, dres


def solve_lp(c, A_ub, b_ub, A_eq, b_eq, *, tol=1e-9, max_iter=100) -> IPMResult:
    c = np.asarray(c, dtype=np.float64)
    n = c.shape[0]
    m_ub = A_ub.shape[0] if A_ub is not None and A_ub.size else 0
    m_eq = A_eq.shape[0] if A_eq is not None and A_eq.size else 0
    A = np.zeros((m_ub + m_eq, n + m_ub))
    b = np.zeros(m_ub + m_eq)
    if m_ub:
        A[:m_ub, :n] = A_ub
        A[:m_ub, n:] = np.eye(m_ub)
        b[:m_ub] = b_ub
    if m_eq:
        A[m_ub:, :n] = A_eq
        b[m_ub:] = b_eq
    c_std = np.concatenate([c, np.zeros(m_ub)])
    x, status, it, gap, pres, dres = _solve_standard_form_legacy(
        A, b, c_std, tol=tol, max_iter=max_iter
    )
    return IPMResult(
        x=x[:n], fun=float(c @ x[:n]), status=status, iterations=it,
        gap=gap, primal_residual=pres, dual_residual=dres,
    )


def _outflow_objective(lp: milp.LPData) -> np.ndarray:
    c = np.zeros_like(lp.c)
    for k, (u, w) in enumerate(lp.edges):
        if u == lp.src:
            c[k] = -1.0
    return c


def _max_flow(top, src, dst, *, fixed_n=None, fixed_m=None) -> float:
    lp = milp.build_lp_reference(top, src, dst, 0.0, fixed_n=fixed_n,
                                 fixed_m=fixed_m)
    if lp.trivially_infeasible:
        return 0.0
    res = solve_lp(_outflow_objective(lp), lp.A_ub, lp.b_ub, lp.A_eq, lp.b_eq)
    if not res.ok:
        return 0.0
    return max(float(-(_outflow_objective(lp) @ res.x)), 0.0)


def _integerize(top, src, dst, tput_goal, n_int):
    goal_n = min(tput_goal,
                 _max_flow(top, src, dst, fixed_n=n_int) * (1.0 - 1e-9))
    if goal_n <= 0:
        return None
    lp = milp.build_lp_reference(top, src, dst, goal_n, fixed_n=n_int)
    res = solve_lp(lp.c, lp.A_ub, lp.b_ub, lp.A_eq, lp.b_eq)
    if not res.ok:
        return None
    _, _, M_frac = lp.split(res.x)
    M_int = np.floor(M_frac + _INT_TOL)
    _topup_connections(top, M_frac, M_int, n_int)
    maxflow = _max_flow(top, src, dst, fixed_n=n_int, fixed_m=M_int)
    achieved = min(goal_n, maxflow * (1.0 - 1e-9))
    if achieved <= 0:
        return None
    lp2 = milp.build_lp_reference(top, src, dst, achieved, fixed_n=n_int,
                                  fixed_m=M_int)
    res2 = solve_lp(lp2.c, lp2.A_ub, lp2.b_ub, lp2.A_eq, lp2.b_eq)
    if not res2.ok:
        return None
    F, _, _ = lp2.split(res2.x)
    obj = float((F * top.price_egress).sum() / 8.0 + n_int @ top.price_vm)
    return F, M_int, achieved, obj


def _feasible_with_n(top, src, dst, tput_goal, n_int) -> bool:
    return _max_flow(top, src, dst, fixed_n=n_int) >= tput_goal * (1.0 - 1e-6)


def _feasibility_repair(top, src, dst, tput_goal, n_frac):
    n_floor = np.floor(n_frac + _INT_TOL)
    candidates = np.argsort(-(n_frac - n_floor))
    n_try = n_floor.copy()
    if _feasible_with_n(top, src, dst, tput_goal, n_try):
        return n_try
    for r in candidates:
        n_try = n_try.copy()
        n_try[r] = min(n_try[r] + 1, top.limit_vm)
        if _feasible_with_n(top, src, dst, tput_goal, n_try):
            return n_try
    n_ceil = np.minimum(np.ceil(n_frac - _INT_TOL), top.limit_vm)
    if _feasible_with_n(top, src, dst, tput_goal, n_ceil):
        return n_ceil
    return None


def solve_milp_legacy(top, src, dst, tput_goal) -> MILPResult | None:
    """Pre-PR relaxed round-down: full-size sequential solves throughout."""
    lp = milp.build_lp_reference(top, src, dst, tput_goal)
    root = solve_lp(lp.c, lp.A_ub, lp.b_ub, lp.A_eq, lp.b_eq)
    if not root.ok:
        return None
    _, n_frac, _ = lp.split(root.x)
    n_int = _feasibility_repair(top, src, dst, tput_goal, n_frac)
    if n_int is None:
        return None
    fit = _integerize(top, src, dst, tput_goal, n_int)
    if fit is None:
        return None
    F, M, achieved, obj = fit
    return MILPResult(
        F=F, N=n_int.astype(np.int64), M=M.astype(np.int64),
        objective=obj, status="optimal", lp_objective=root.fun,
        achieved_tput=achieved,
    )


def pareto_frontier_legacy(planner, src, dst, volume_gb, *, n_samples):
    """Pre-PR §5.2 sweep: one sequential round-down per goal."""
    from repro.core import PlanSpec

    sub, s, t, keep = planner._prune(src, dst)
    hi = planner.plan(PlanSpec(objective="max_throughput", src=src, dst=dst))
    goals = np.linspace(hi / n_samples, hi * 0.999, n_samples)
    out = []
    for g in goals:
        res = solve_milp_legacy(sub, s, t, float(g))
        if res is None:
            continue
        plan = planner._lift(sub, keep, src, dst, float(g), volume_gb, res)
        out.append((float(g), plan.cost_per_gb, plan))
    return out
