"""Calibration plane (ISSUE 4): stale-grid vs calibrated planning on a
drifted true topology.

The scenario the paper's offline-measured grid cannot survive: a long
transfer crosses a step-change interconnect incident on the stale plan's
primary edge. The stale service keeps executing its frozen plan at the
incident's rate; the calibrated service detects the drift through probes
and passive telemetry, re-plans the remaining volume around the collapsed
link on CACHED LP structures (zero re-assembly), and recovers.

Acceptance (pinned here and in tests/test_calibration.py): the calibrated
service achieves >= 1.5x the stale plan's delivered throughput, with zero
LP structure builds during robust re-plans, and the believed-vs-true grid
error over the candidate links shrinks monotonically across probe rounds.
"""

from __future__ import annotations

import time

import numpy as np

from .common import FAST, emit

SRC, DST = "aws:us-west-2", "aws:eu-central-1"
GOAL = 4.0


def run():
    from repro.calibrate import (
        BeliefGrid,
        CalibratedTransferService,
        Calibrator,
        DriftModel,
        Incident,
        ProbeBudget,
    )
    from repro.core import Planner, PlanSpec, default_topology
    from repro.transfer import TransferRequest

    top = default_topology()

    # the incident lands on the stale plan's widest edge (its primary path)
    stale_plan = Planner(top, max_relays=6).plan(PlanSpec(
        objective="cost_min", src=SRC, dst=DST,
        tput_goal_gbps=GOAL, volume_gb=4.0,
    ))
    a, b = np.unravel_index(int(np.argmax(stale_plan.F)), stale_plan.F.shape)
    drift = DriftModel(
        top, seed=0, drift_sigma=0.10, diurnal_amp=0.0,
        incidents=[Incident(src=int(a), dst=int(b), t_start_s=6.0,
                            duration_s=1e9, severity=0.08)],
    )

    volume = 4.0 if FAST else 8.0
    achieved = {}
    for calibrate in (True, False):
        svc = CalibratedTransferService(
            drift, backend="jax", max_relays=6, calibrate=calibrate,
            check_interval_s=4.0, max_segments=150,
        )
        svc.submit(TransferRequest("bench", SRC, DST, volume, GOAL))
        t0 = time.time()
        rep = svc.run()
        wall = time.time() - t0
        job = rep.jobs[0]
        ach = job.delivered_gb * 8.0 / max(rep.time_s, 1e-9)
        achieved[calibrate] = ach
        tag = "calibrated" if calibrate else "stale"
        emit(f"calibration/{tag}_achieved_gbps", wall * 1e6, round(ach, 4))
        if calibrate:
            assert rep.drift_events, "incident went undetected"
            builds = sum(r.structure_builds for r in rep.replans)
            assert builds == 0, "robust re-plan re-assembled an LP structure"
            emit("calibration/replan_struct_builds", wall * 1e6, builds)
            emit("calibration/replans", wall * 1e6, len(rep.replans))
            emit("calibration/probe_rounds", wall * 1e6,
                 len(rep.probe_rounds))
            emit("calibration/probe_cost_usd", wall * 1e6,
                 round(rep.probe_cost_usd, 4))
            emit("calibration/probe_seconds", wall * 1e6,
                 round(rep.probe_seconds, 2))

    ratio = achieved[True] / max(achieved[False], 1e-9)
    assert ratio >= 1.5, f"calibrated/stale ratio {ratio:.2f} < 1.5"
    emit("calibration/achieved_ratio_vs_stale", 0.0, round(ratio, 3))

    # ---- belief convergence: probe rounds against a frozen drifted truth
    dm = DriftModel(top, seed=11, drift_sigma=0.3, diurnal_amp=0.0)
    truth = dm.tput_at(500.0)
    bel = BeliefGrid(top)
    cal = Calibrator(bel, noise_sigma=0.0, budget=ProbeBudget(
        usd_per_round=2.0, seconds_per_round=60.0, max_probes_per_round=6,
    ))
    planner = Planner(top, max_relays=6)
    t0 = time.time()
    errs = []
    for k in range(5 if FAST else 10):
        rnd = cal.run_round(float(k), truth, planner=planner,
                            contexts=[(SRC, DST)])
        errs.append(rnd.belief_error)
    t_probe = time.time() - t0
    assert all(e1 <= e0 + 1e-12 for e0, e1 in zip(errs, errs[1:])), (
        f"belief error not monotone: {errs}"
    )
    emit("calibration/belief_err_round0", t_probe * 1e6, round(errs[0], 5))
    emit("calibration/belief_err_final", t_probe * 1e6, round(errs[-1], 5))
    emit("calibration/probes_total", t_probe * 1e6, cal.total_probes)
