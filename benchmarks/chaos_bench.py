"""Chaos plane: seeded correlated-fault suites through the TransferService,
with the circuit-breaker + retry-budget arm scored against a no-breaker
baseline. Hard gates: zero delivered-byte loss, chunk-for-chunk parity of
the vectorized sim against the reference oracle under chaos, zero LP
re-assembly across every quarantine/deadline re-plan, and the breaker arm
at-least-matching the baseline on SLO violations while staying inside the
p99 completion envelope (the baseline's flapping trap — re-planning back
onto the trunk at every restore — is what the breaker is for)."""

from __future__ import annotations

import time

from .common import FAST, emit

SRC, DST = "aws:us-west-2", "aws:eu-central-1"
SRC2 = "gcp:us-central1"


def _run_suite(top, seeds, *, with_breaker: bool, sim=None):
    """One arm: the same seeded chaos suites, with or without the breaker.
    Returns (reports, completion_times, replans)."""
    from repro.transfer import (
        BreakerConfig,
        ChaosScenario,
        DegradationLadder,
        LinkBreaker,
        TransferRequest,
        TransferService,
    )

    s, d, s2 = top.index(SRC), top.index(DST), top.index(SRC2)
    vol = 2.0 if FAST else 4.0
    reports, times, replans = [], [], []
    for seed in seeds:
        # archetype starts drawn inside the first 6s so the flap trains
        # (8-12 flaps, 2-3s period) overlap the whole transfer — persistent
        # flapping is the regime the breaker is built for; short trains
        # just reward the baseline's re-plan-onto-the-trunk reflex
        sc = ChaosScenario(top, seed=seed, horizon_s=6.0,
                           n_brownouts=1, n_gray=1, n_flapping=1,
                           flap_count=(8, 12), flap_period_s=(2.0, 3.0),
                           links=[(s, d), (s2, d)])
        br = (
            LinkBreaker(BreakerConfig(k=3, window_s=20.0, cooldown_s=8.0))
            if with_breaker else None
        )
        svc = TransferService(
            top, backend="jax", max_relays=6, breaker=br,
            degradation=DegradationLadder(pressure=0.25),
        )
        budget = None if not with_breaker else 10_000
        svc.submit(TransferRequest("a", SRC, DST, vol, 2.0,
                                   deadline_s=40.0, retry_budget=budget))
        svc.submit(TransferRequest("b", SRC2, DST, vol, 2.0, arrival_s=1.0,
                                   deadline_s=40.0, retry_budget=budget))
        kw = {} if sim is None else {"sim": sim}
        rep = svc.run(faults=sc.events(2), **kw)
        reports.append(rep)
        replans += rep.replans
        for j in rep.jobs:
            if j.status == "done":
                # realized tput is delivered gbit over arrival->finish
                times.append(
                    j.delivered_gb * 8.0 / max(j.realized_tput_gbps, 1e-9)
                )
            else:
                times.append(rep.time_s)  # censored at the run's end
    return reports, times, replans


def run():
    import numpy as np

    import functools

    from repro.core import default_topology
    from repro.transfer import simulate

    top = default_topology()
    seeds = list(range(3)) if FAST else list(range(8))

    # ---- breaker + budget arm vs the no-breaker baseline
    t0 = time.time()
    rep_b, times_b, replans_b = _run_suite(top, seeds, with_breaker=True)
    t_breaker = time.time() - t0
    t0 = time.time()
    rep_0, times_0, _ = _run_suite(top, seeds, with_breaker=False)
    t_base = time.time() - t0

    jobs_b = [j for r in rep_b for j in r.jobs]
    jobs_0 = [j for r in rep_0 for j in r.jobs]
    lost = sum(j.lost_chunks for j in jobs_b + jobs_0)
    viol_b = sum(j.deadline_met is False for j in jobs_b)
    viol_0 = sum(j.deadline_met is False for j in jobs_0)
    with_dl_b = sum(j.deadline_met is not None for j in jobs_b)
    with_dl_0 = sum(j.deadline_met is not None for j in jobs_0)
    rate_b = viol_b / max(with_dl_b, 1)
    rate_0 = viol_0 / max(with_dl_0, 1)
    p99_b = float(np.percentile(times_b, 99))
    p99_0 = float(np.percentile(times_0, 99))

    emit("chaos/lost_chunks", t_breaker * 1e6, lost)
    emit("chaos/slo_violation_rate_breaker", t_breaker * 1e6,
         round(rate_b, 3))
    emit("chaos/slo_violation_rate_baseline", t_base * 1e6,
         round(rate_0, 3))
    # gate value: violations AVOIDED per violation the baseline takes,
    # shifted so "no worse than the baseline" scores exactly 1.0
    if rate_0 > 0:
        gain = 1.0 + (rate_0 - rate_b) / rate_0
    else:
        gain = 1.0 - rate_b  # clean baseline: any breaker violation dips
    emit("chaos/slo_gain_vs_no_breaker", t_breaker * 1e6, round(gain, 3))
    emit("chaos/p99_completion_ratio", t_breaker * 1e6,
         round(p99_b / max(p99_0, 1e-9), 3))
    emit("chaos/quarantines", t_breaker * 1e6,
         sum(len(r.quarantines) for r in rep_b))
    emit("chaos/replan_struct_builds", t_breaker * 1e6,
         sum(r.structure_builds for r in replans_b))

    # ---- oracle parity under chaos: the same suite, reference simulator —
    # every delivered-chunk count must agree with the vectorized run
    t0 = time.time()
    rep_r, _, _ = _run_suite(top, seeds[:2], with_breaker=True,
                             sim=functools.partial(simulate, engine="ref"))
    t_ref = time.time() - t0
    rep_v = rep_b[: len(rep_r)]
    mismatches = sum(
        a.delivered_chunks != b.delivered_chunks or a.status != b.status
        for rv, rr in zip(rep_v, rep_r)
        for a, b in zip(rv.jobs, rr.jobs)
    )
    emit("chaos/parity_mismatches", t_ref * 1e6, mismatches)
