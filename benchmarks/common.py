"""Shared benchmark plumbing. Every benchmark prints CSV rows:
    name,us_per_call,derived
where ``derived`` is the figure-relevant metric (speedup, Gbps, $/GB, ...).
"""

from __future__ import annotations

import os
import sys
import time
from pathlib import Path

SRC = Path(__file__).resolve().parent.parent / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

FAST = os.environ.get("REPRO_BENCH_FAST", "0") == "1"

# every emit() is also recorded here so run.py --json can snapshot a run
RESULTS: list[dict] = []


def emit(name: str, us_per_call: float, derived) -> None:
    RESULTS.append(
        {"name": name, "us_per_call": round(us_per_call, 1), "derived": derived}
    )
    print(f"{name},{us_per_call:.1f},{derived}")


class timed:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *exc):
        self.us = (time.time() - self.t0) * 1e6
        return False
