"""Benchmark regression checks for CI.

    python -m benchmarks.compare NEW.json [BASELINE.json] [--hard]

Diffs a ``benchmarks.run --json`` snapshot against a recorded baseline
(default: BENCH_planner_hotpath.json at the repo root).

Two gates, layered:

  * **soft** (always): per-metric wall times are compared where both
    sides have them; large slowdowns print ``::warning::`` annotations
    (rendered inline by GitHub Actions) but never fail the job — shared
    CI runners are far too noisy for a hard *wall-time* gate, the signal
    is the warning trail across PRs.
  * **hard** (``--hard``): the headline throughput/cost metrics in
    ``HARD_METRICS`` are checked on their ``derived`` values, which are
    deterministic model outputs (ratios, savings, counters) rather than
    timings — runner noise does not move them, so a regression exits
    non-zero and fails the PR. Each metric carries a direction, a
    relative tolerance against the baseline, and an absolute bound that
    must hold in ANY mode; the relative leg only applies when both
    snapshots were recorded in the same ``fast_mode`` (the CI smoke runs
    REPRO_BENCH_FAST=1 while the checked-in baselines are full runs —
    comparing a fast derived value against a full one would gate on the
    mode difference, not on a regression).
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

# shared-runner noise floor: only flag slowdowns beyond this factor, and
# ignore sub-millisecond metrics entirely (pure timer jitter)
SLOWDOWN_FACTOR = 2.0
MIN_US = 1000.0

# Hard-gated headline metrics: name -> (direction, rel_tol, abs_bound).
# direction "higher" means bigger is better; the check fails when the new
# value falls below baseline * (1 - rel_tol) (same-mode snapshots only) or
# below abs_bound (always). "lower" is the mirror image.
HARD_METRICS: dict[str, tuple[str, float, float]] = {
    # calibration plane: the closed loop must keep beating the stale grid,
    # and robust re-plans must stay zero-reassembly
    "calibration/achieved_ratio_vs_stale": ("higher", 0.25, 1.5),
    "calibration/replan_struct_builds": ("lower", 0.0, 0.0),
    # multicast: the envelope must keep beating N unicasts
    "multicast/cost_ratio_vs_unicasts": ("lower", 0.10, 0.75),
    "multicast/egress_savings_pct": ("higher", 0.10, 25.0),
    "multicast/replan_struct_builds": ("lower", 0.0, 0.0),
    # chaos plane: delivered bytes are sacred (zero loss, exact oracle
    # parity), quarantine/deadline re-plans never re-assemble an LP, the
    # breaker arm never does worse than the no-breaker baseline on SLO
    # violations (1.0 = tie, >1 = violations avoided), and quarantining
    # must not blow up tail latency (p99 within 15% of the baseline)
    "chaos/lost_chunks": ("lower", 0.0, 0.0),
    "chaos/parity_mismatches": ("lower", 0.0, 0.0),
    "chaos/replan_struct_builds": ("lower", 0.0, 0.0),
    "chaos/slo_gain_vs_no_breaker": ("higher", 0.25, 1.0),
    "chaos/p99_completion_ratio": ("lower", 0.10, 1.15),
    # probe policies: EVOI must keep earning its LP solves (the combined
    # gate is >= 1 when it clears either acceptance leg; capped at 5, and
    # tolerant relatively — the interesting signal is the absolute floor),
    # rolls stay rare
    "probe_policies/evoi_gate": ("higher", 0.50, 1.0),
    "probe_policies/epoch_rolls": ("lower", 0.0, 2.0),
    "probe_policies/epoch_roll_struct_builds": ("lower", 0.0, 8.0),
    # fleet control plane: consolidating N tenants onto one shared belief
    # must not cost aggregate throughput, must amortize the probe budget
    # (per-tenant spend <= 0.7x the isolated arms'), and fleet re-plans
    # ride cached structures like everything else
    "fleet/agg_tput_ratio_vs_isolated": ("higher", 0.25, 1.0),
    "fleet/p99_job_latency_ratio": ("lower", 0.25, 1.1),
    "fleet/probe_cost_per_tenant_ratio": ("lower", 0.25, 0.7),
    "fleet/replan_struct_builds": ("lower", 0.0, 0.0),
    # observability plane: an enabled tracer stays within 5% of the
    # untraced simulator (best-of-N wall ratio — deterministic enough to
    # hard-gate, unlike raw wall times), and re-plans on cached LP
    # structures never move the registered struct-builds counter
    "obs/tracing_overhead_ratio": ("lower", 0.15, 1.05),
    "obs/struct_builds_delta": ("lower", 0.0, 0.0),
    # sim engines (ISSUE 10): the accelerator-resident jax engine must
    # stay chunk-for-chunk bitwise identical to the numpy SoA engine, and
    # at the 1e5-chunk scale (fixed-cost dispatch amortized) its event
    # loop must at least match SoA throughput (best-of-N events/s ratio)
    "flowsim_jax/parity_mismatches": ("lower", 0.0, 0.0),
    "flowsim_jax/speedup_vs_soa_at_1e5": ("higher", 0.5, 1.0),
}


def load(path: str) -> tuple[dict, dict]:
    with open(path) as fh:
        snap = json.load(fh)
    return {m["name"]: m for m in snap.get("metrics", [])}, snap


def soft_compare(new: dict, base: dict) -> int:
    shared = sorted(set(new) & set(base))
    print(f"comparing {len(shared)} shared metrics")
    regressions = 0
    for name in shared:
        b, n = base[name]["us_per_call"], new[name]["us_per_call"]
        if b < MIN_US or n <= 0:
            continue
        ratio = n / b
        flag = ""
        if ratio > SLOWDOWN_FACTOR:
            regressions += 1
            flag = " <-- REGRESSION?"
            print(f"::warning title=bench {name}::"
                  f"{b / 1e3:.1f}ms -> {n / 1e3:.1f}ms ({ratio:.1f}x)")
        print(f"{name}: {b / 1e3:.1f}ms -> {n / 1e3:.1f}ms "
              f"({ratio:.2f}x){flag}")
    only_new = sorted(set(new) - set(base))
    if only_new:
        print(f"{len(only_new)} new metrics (no baseline): "
              + ", ".join(only_new[:12])
              + ("..." if len(only_new) > 12 else ""))
    print(f"soft pass: {regressions} wall-time regression(s) flagged "
          "(warnings only)")
    return regressions


def hard_compare(new: dict, base: dict, same_mode: bool) -> int:
    """Gate the HARD_METRICS derived values; returns the violation count."""
    checked = violations = 0
    for name, (direction, rel_tol, abs_bound) in HARD_METRICS.items():
        metric = new.get(name)
        if metric is None or not isinstance(metric.get("derived"), (int, float)):
            continue
        val = float(metric["derived"])
        checked += 1
        fails: list[str] = []
        if direction == "higher":
            if val < abs_bound:
                fails.append(f"below absolute floor {abs_bound}")
            if same_mode and name in base:
                ref = float(base[name]["derived"])
                if val < ref * (1.0 - rel_tol):
                    fails.append(
                        f"regressed vs baseline {ref:.4g} (tol -{rel_tol:.0%})"
                    )
        else:
            if val > abs_bound:
                fails.append(f"above absolute ceiling {abs_bound}")
            if same_mode and name in base:
                ref = float(base[name]["derived"])
                if val > ref * (1.0 + rel_tol):
                    fails.append(
                        f"regressed vs baseline {ref:.4g} (tol +{rel_tol:.0%})"
                    )
        if fails:
            violations += 1
            print(f"::error title=bench {name}::derived={val:.4g} "
                  + "; ".join(fails))
        else:
            print(f"hard ok: {name} = {val:.4g}")
    print(f"hard gate: {checked} metric(s) checked, {violations} violation(s)")
    return violations


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("new", help="snapshot from benchmarks.run --json")
    ap.add_argument("baseline", nargs="?", default=str(
        Path(__file__).resolve().parent.parent / "BENCH_planner_hotpath.json"
    ))
    ap.add_argument("--hard", action="store_true",
                    help="fail (exit 1) on headline-metric regressions")
    args = ap.parse_args(argv)
    if not Path(args.new).exists() or not Path(args.baseline).exists():
        print(f"::warning::benchmark snapshot missing "
              f"({args.new} or {args.baseline}); skipping comparison")
        return 0
    new, new_snap = load(args.new)
    base, base_snap = load(args.baseline)
    print(f"{args.new} vs {args.baseline}")
    if not args.hard:
        soft_compare(new, base)
        return 0
    # --hard runs ONLY the hard gate: CI already printed the soft
    # wall-time diff in its own step, and the hard loop repeats per
    # baseline — duplicating the soft output five times buries the signal
    same_mode = bool(new_snap.get("fast_mode")) == bool(
        base_snap.get("fast_mode")
    )
    if not same_mode:
        print("(snapshots differ in fast_mode: relative hard checks "
              "skipped, absolute bounds still enforced)")
    return 1 if hard_compare(new, base, same_mode) else 0


if __name__ == "__main__":
    raise SystemExit(main())
