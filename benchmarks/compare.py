"""Soft benchmark regression check for CI.

    python -m benchmarks.compare NEW.json [BASELINE.json]

Diffs a ``benchmarks.run --json`` snapshot against a recorded baseline
(default: BENCH_planner_hotpath.json at the repo root). Per-metric wall
times are compared where both sides have them; large regressions print
``::warning::`` annotations (rendered inline by GitHub Actions) but the
exit code is always 0 — shared CI runners are far too noisy for a hard
perf gate, the signal is the warning trail across PRs.
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

# shared-runner noise floor: only flag slowdowns beyond this factor, and
# ignore sub-millisecond metrics entirely (pure timer jitter)
SLOWDOWN_FACTOR = 2.0
MIN_US = 1000.0


def load(path: str) -> dict:
    with open(path) as fh:
        snap = json.load(fh)
    return {m["name"]: m for m in snap.get("metrics", [])}


def main(argv: list[str]) -> int:
    if not argv:
        print("usage: python -m benchmarks.compare NEW.json [BASELINE.json]")
        return 0
    new_path = argv[0]
    base_path = argv[1] if len(argv) > 1 else str(
        Path(__file__).resolve().parent.parent / "BENCH_planner_hotpath.json"
    )
    if not Path(new_path).exists() or not Path(base_path).exists():
        print(f"::warning::benchmark snapshot missing "
              f"({new_path} or {base_path}); skipping comparison")
        return 0
    new, base = load(new_path), load(base_path)
    shared = sorted(set(new) & set(base))
    print(f"comparing {len(shared)} shared metrics "
          f"({new_path} vs {base_path})")
    regressions = 0
    for name in shared:
        b, n = base[name]["us_per_call"], new[name]["us_per_call"]
        if b < MIN_US or n <= 0:
            continue
        ratio = n / b
        flag = ""
        if ratio > SLOWDOWN_FACTOR:
            regressions += 1
            flag = " <-- REGRESSION?"
            print(f"::warning title=bench {name}::"
                  f"{b/1e3:.1f}ms -> {n/1e3:.1f}ms ({ratio:.1f}x)")
        print(f"{name}: {b/1e3:.1f}ms -> {n/1e3:.1f}ms ({ratio:.2f}x){flag}")
    only_new = sorted(set(new) - set(base))
    if only_new:
        print(f"{len(only_new)} new metrics (no baseline): "
              + ", ".join(only_new[:12]) + ("..." if len(only_new) > 12 else ""))
    print(f"done: {regressions} soft regression(s) flagged (exit 0 always)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
