"""Fig. 10: given a fixed VM budget, is it better to parallelize the direct
path or to form overlay paths? (Paper: ~2.08x geomean for inter-continental
routes, ~1.03x intra-continental.)"""

from __future__ import annotations

import numpy as np

from .common import FAST, emit, timed


def run():
    from repro.core import Planner, PlanSpec, default_topology, direct_plan

    top = default_topology()
    planner = Planner(top)
    cases = [
        ("inter_continental", "azure:canadacentral", "gcp:asia-northeast1"),
        ("intra_continental", "aws:us-east-1", "aws:us-west-2"),
    ]
    vm_counts = [2, 8] if FAST else [1, 2, 4, 8]
    for label, src, dst in cases:
        ratios = []
        for n_vm in vm_counts:
            import dataclasses

            top_n = dataclasses.replace(top, limit_vm=n_vm)
            p_n = Planner(top_n)
            with timed() as t:
                dp = direct_plan(top_n, src, dst, 50.0, num_vms=n_vm)
                op = p_n.plan(PlanSpec(
                    objective="tput_max", src=src, dst=dst,
                    cost_ceiling_per_gb=dp.cost_per_gb * 1.3,
                    volume_gb=50.0, n_samples=8,
                ))
            ratio = op.throughput / max(dp.throughput, 1e-9)
            ratios.append(ratio)
            emit(f"fig10/{label}/vms={n_vm}/overlay_over_direct", t.us,
                 round(ratio, 2))
        emit(f"fig10/{label}/geomean", 0.0,
             round(float(np.exp(np.mean(np.log(ratios)))), 2))
