"""Fig. 3: intra-cloud vs inter-cloud link throughput per source provider."""

from __future__ import annotations

import numpy as np

from .common import emit, timed


def run():
    from repro.core import default_topology

    with timed() as t:
        top = default_topology()
    providers = ["aws", "azure", "gcp"]
    prov = np.array([r.provider for r in top.regions])
    for p in providers:
        src = prov == p
        for q in providers:
            dst = prov == q
            block = top.tput[np.ix_(src, dst)]
            mask = block > 0
            med = float(np.median(block[mask]))
            p90 = float(np.quantile(block[mask], 0.9))
            kind = "intra" if p == q else "inter"
            emit(f"fig3/{p}->{q}/{kind}_median_gbps", t.us, round(med, 2))
            emit(f"fig3/{p}->{q}/{kind}_p90_gbps", t.us, round(p90, 2))
    # the paper's headline observation: inter-cloud consistently slower
    intra = [top.tput[np.ix_(prov == p, prov == p)] for p in providers]
    inter = [top.tput[np.ix_(prov == p, prov != p)] for p in providers]
    med_intra = np.median(np.concatenate([b[b > 0].ravel() for b in intra]))
    med_inter = np.median(np.concatenate([b[b > 0].ravel() for b in inter]))
    emit("fig3/intra_over_inter_median", t.us, round(float(med_intra / med_inter), 2))
    assert med_intra > med_inter
