"""Fig. 6 / §7.2: Skyplane vs managed cloud transfer services.

Six source->destination panels (three intra-cloud, three inter-cloud, each
ending at the cloud whose managed service is compared). Skyplane runs with
8 VMs under a cost ceiling; services use their measured-rate models. The
fluid simulator provides transfer times; the "storage I/O overhead" thatch
of the figure corresponds to the chunked object-store read/write the
gateway performs (folded into the achieved goodput here).
"""

from __future__ import annotations

from .common import FAST, emit, timed

ROUTES = [
    # (src, dst, service attr, label)
    ("aws:us-east-1", "aws:ap-southeast-2", "AWS_DATASYNC", "aws->aws"),
    ("gcp:us-central1", "gcp:asia-northeast1", "GCP_STORAGE_TRANSFER", "gcp->gcp"),
    ("azure:westus2", "azure:koreacentral", "AZURE_AZCOPY", "azure->azure"),
    ("azure:eastus", "aws:ap-northeast-1", "AWS_DATASYNC", "azure->aws"),
    ("aws:us-east-1", "gcp:europe-west4", "GCP_STORAGE_TRANSFER", "aws->gcp"),
    ("gcp:us-east1", "azure:southeastasia", "AZURE_AZCOPY", "gcp->azure"),
]


def run():
    import repro.core.baselines as B
    from repro.core import Planner, PlanSpec, default_topology, direct_plan
    from repro.transfer import execute_plan, execute_service_model

    top = default_topology()
    planner = Planner(top)
    volume = 8.0 if FAST else 32.0
    chunk = 32.0

    for src, dst, svc_name, label in ROUTES[: 2 if FAST else None]:
        svc = getattr(B, svc_name)
        with timed() as t:
            dp = direct_plan(top, src, dst, volume)
            plan = planner.plan(PlanSpec(
                objective="tput_max", src=src, dst=dst,
                cost_ceiling_per_gb=max(dp.cost_per_gb * 1.15,
                                        svc.cost(top, src, dst, 1.0)),
                volume_gb=volume, n_samples=8 if FAST else 16,
            ))
            rep = execute_plan(plan, chunk_mb=chunk, seed=0)
        svc_res = execute_service_model(svc, top, src, dst, volume)
        speedup = svc_res["time_s"] / rep.time_s
        emit(f"fig6/{label}/skyplane_gbps", t.us, round(rep.sim.tput_gbps, 2))
        emit(f"fig6/{label}/{svc.name}_gbps", t.us, round(svc_res["tput_gbps"], 2))
        emit(f"fig6/{label}/speedup_vs_service", t.us, round(speedup, 2))
        emit(f"fig6/{label}/skyplane_cost_per_gb", t.us,
             round(rep.sim.total_cost / volume, 4))
        emit(f"fig6/{label}/service_cost_per_gb", t.us,
             round(svc_res["cost"] / volume, 4))
