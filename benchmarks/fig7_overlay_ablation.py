"""Fig. 7 / §7.3: predicted throughput, direct vs overlay, across region
pairs grouped by (source cloud -> dest cloud). The paper evaluates all 5184
pairs with the planner (not live transfers); we sample pairs per cloud-pair
block and report the distribution of overlay speedups.
"""

from __future__ import annotations

import numpy as np

from .common import FAST, emit, timed


def run():
    from repro.core import Planner, PlanSpec, default_topology, direct_plan

    top = default_topology()
    planner = Planner(top, max_relays=8)
    rng = np.random.default_rng(7)
    prov = np.array([r.provider for r in top.regions])
    keys = top.keys()
    per_block = 2 if FAST else 5

    speedups_all = []
    for p in ("aws", "azure", "gcp"):
        for q in ("aws", "azure", "gcp"):
            src_ix = np.where(prov == p)[0]
            dst_ix = np.where(prov == q)[0]
            pairs = []
            while len(pairs) < per_block:
                s, d = rng.choice(src_ix), rng.choice(dst_ix)
                if s != d:
                    pairs.append((int(s), int(d)))
            sp = []
            with timed() as t:
                for s, d in pairs:
                    dp = direct_plan(top, keys[s], keys[d], 50.0)
                    plan = planner.plan(PlanSpec(
                        objective="tput_max", src=keys[s], dst=keys[d],
                        cost_ceiling_per_gb=dp.cost_per_gb * 1.25,
                        volume_gb=50.0, n_samples=8,
                    ))
                    sp.append(plan.throughput / max(dp.throughput, 1e-9))
            sp = np.array(sp)
            speedups_all.extend(sp.tolist())
            emit(f"fig7/{p}->{q}/median_speedup",
                 t.us / len(pairs), round(float(np.median(sp)), 2))
            emit(f"fig7/{p}->{q}/max_speedup",
                 t.us / len(pairs), round(float(sp.max()), 2))
    arr = np.array(speedups_all)
    emit("fig7/all/median_speedup", 0.0, round(float(np.median(arr)), 2))
    emit("fig7/all/frac_pairs_speedup_gt_1.5x", 0.0,
         round(float((arr > 1.5).mean()), 2))
    emit("fig7/all/max_speedup", 0.0, round(float(arr.max()), 2))
