"""Fig. 8 / §7.4: where are transfers bottlenecked (>=99% utilization),
with and without the overlay. Uses the fluid simulator's per-resource
utilization attribution."""

from __future__ import annotations

import numpy as np

from .common import FAST, emit, timed


def run():
    from repro.core import Planner, PlanSpec, default_topology, direct_plan
    from repro.transfer import simulate_transfer

    top = default_topology()
    planner = Planner(top, max_relays=8)
    rng = np.random.default_rng(8)
    keys = top.keys()
    n_pairs = 4 if FAST else 10
    volume = 4.0

    counts = {"direct": {}, "overlay": {}}
    totals = {"direct": 0, "overlay": 0}
    with timed() as t:
        done = 0
        while done < n_pairs:
            s, d = rng.integers(0, top.num_regions, 2)
            if s == d:
                continue
            done += 1
            dp = direct_plan(top, keys[s], keys[d], volume, num_vms=2)
            op = planner.plan(PlanSpec(
                objective="tput_max", src=keys[s], dst=keys[d],
                cost_ceiling_per_gb=dp.cost_per_gb * 1.3,
                volume_gb=volume, n_samples=6,
            ))
            for mode, plan in (("direct", dp), ("overlay", op)):
                res = simulate_transfer(plan, chunk_mb=16, seed=done,
                                        straggler_prob=0.0)
                totals[mode] += 1
                # paper counts locations at >99% utilization; a fluid sim
                # with ramp/tail phases never averages that high, so we
                # attribute the *most utilized* resource(s): everything
                # within 5% of the max (>=1 bottleneck per transfer).
                peak = max(res.utilization.values())
                for loc, u in res.utilization.items():
                    if u >= peak * 0.95:
                        counts[mode][loc] = counts[mode].get(loc, 0) + 1
    for mode in ("direct", "overlay"):
        for loc in ("source_vm", "source_link", "overlay_vm", "overlay_link",
                    "dest_vm"):
            frac = counts[mode].get(loc, 0) / max(totals[mode], 1)
            emit(f"fig8/{mode}/{loc}_bottleneck_frac",
                 t.us / max(totals["direct"] + totals["overlay"], 1),
                 round(frac, 2))
