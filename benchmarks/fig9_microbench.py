"""Fig. 9 microbenchmarks: (a) parallel-TCP scaling, (b) parallel-VM
scaling, (c) the cost-throughput Pareto frontier."""

from __future__ import annotations

import numpy as np

from .common import FAST, emit, timed


def _forced_conn_plan(top, src, dst, n_conn: int, volume: float):
    """Direct 1-VM-per-region plan with exactly n_conn connections."""
    from repro.core.plan import TransferPlan

    s, t = top.index(src), top.index(dst)
    v = top.num_regions
    F = np.zeros((v, v))
    M = np.zeros((v, v))
    N = np.zeros(v)
    from repro.transfer.flowsim import conn_efficiency

    tput = top.tput[s, t] * conn_efficiency(n_conn, top.limit_conn)
    F[s, t] = min(tput, top.limit_egress[s], top.limit_ingress[t])
    M[s, t] = n_conn
    N[s] = N[t] = 1
    return TransferPlan(top=top, src=s, dst=t, tput_goal=F[s, t],
                        volume_gb=volume, F=F, N=N, M=M)


def run():
    from repro.core import Planner, PlanSpec, default_topology, direct_plan
    from repro.transfer import simulate_transfer

    top = default_topology()
    volume = 2.0 if FAST else 8.0

    # ---- 9a: throughput vs parallel TCP connections (paper: ap-northeast-1
    # -> eu-central-1, 1 VM, plateau near but below the 5 Gbps AWS cap)
    src, dst = "aws:ap-northeast-1", "aws:eu-central-1"
    for n in ([8, 64] if FAST else [1, 4, 16, 32, 64]):
        plan = _forced_conn_plan(top, src, dst, n, volume)
        with timed() as t:
            res = simulate_transfer(plan, chunk_mb=16, seed=0,
                                    straggler_prob=0.0)
        emit(f"fig9a/conns={n}/gbps", t.us, round(res.tput_gbps, 3))
    assert _forced_conn_plan(top, src, dst, 64, 1.0).throughput <= 5.0

    # ---- 9b: throughput vs parallel VMs (direct path)
    for n_vm in ([2, 8] if FAST else [1, 2, 4, 8]):
        plan = direct_plan(top, src, dst, volume, num_vms=n_vm)
        with timed() as t:
            res = simulate_transfer(plan, chunk_mb=16, seed=0,
                                    straggler_prob=0.0)
        emit(f"fig9b/vms={n_vm}/gbps", t.us, round(res.tput_gbps, 3))

    # ---- 9c: cost-throughput trade-off (three routes of the paper)
    routes = [
        ("azure:westus", "aws:eu-west-1", "considerable"),
        ("gcp:asia-east1", "aws:sa-east-1", "good"),
        ("aws:af-south-1", "aws:ap-southeast-2", "minimal"),
    ]
    planner = Planner(top)
    for s, d, label in routes[: 1 if FAST else None]:
        with timed() as t:
            pts = planner.plan(PlanSpec(
                objective="pareto", src=s, dst=d, volume_gb=50.0,
                n_samples=6 if FAST else 14,
            ))
        base = pts[0].cost_per_gb
        for p in pts[:: max(len(pts) // 5, 1)]:
            emit(
                f"fig9c/{label}/budget={p.cost_per_gb/base:.2f}x",
                t.us / len(pts), round(p.plan.throughput, 2),
            )
        dmax = max(p.plan.throughput for p in pts)
        emit(f"fig9c/{label}/max_gbps", t.us / len(pts), round(dmax, 2))
