"""Fleet control plane (ISSUE 7): multi-tenant service vs N isolated ones.

The consolidation claim: running N tenants through ONE ``FleetController``
— one shared belief, one deduplicated probe budget, admission-controlled
work-conserving waves planned as one batched cohort — beats giving every
tenant its own ``CalibratedTransferService`` on the same drifting true
topology. The fleet's structural edges: unclaimed route capacity is
granted back to the wave (an isolated service must treat the request as a
cap — it cannot see the other tenants' demand on the shared links), and
the probe budget is spent once instead of N times.

Acceptance (hard-gated in benchmarks/compare.py):

  * aggregate delivered throughput >= 1.0x the isolated arms';
  * p99 job latency <= 1.1x the isolated arms';
  * probe cost per tenant <= 0.7x the isolated arms' mean;
  * zero LP structure builds across every fleet re-plan.
"""

from __future__ import annotations

import time

import numpy as np

from .common import FAST, emit

SRC, DST = "aws:us-west-2", "aws:eu-central-1"
SRC2 = "azure:canadacentral"


def _scenario():
    """(drift factory, tenant specs, per-tenant request lists) — the same
    seeded world for both arms: mixed job sizes and SLO classes over two
    routes, with a step-change incident on the busiest planned edge of
    the shared route."""
    from repro.calibrate import DriftModel, Incident
    from repro.core import Planner, PlanSpec, default_topology
    from repro.transfer import TenantSpec, TransferRequest

    top = default_topology()
    probe_plan = Planner(top, max_relays=6).plan(PlanSpec(
        objective="cost_min", src=SRC, dst=DST,
        tput_goal_gbps=4.0, volume_gb=4.0,
    ))
    a, b = np.unravel_index(int(np.argmax(probe_plan.F)),
                            probe_plan.F.shape)

    def make_drift():
        return DriftModel(
            top, seed=0, drift_sigma=0.10, diurnal_amp=0.0,
            incidents=[Incident(src=int(a), dst=int(b), t_start_s=6.0,
                                duration_s=1e9, severity=0.08)],
        )

    per_tenant = 2 if FAST else 8
    # every tenant's cloud subscription caps it at 4 VMs per plan: an
    # isolated service hits that wall on its post-incident detour (which
    # wants more, smaller VMs); the fleet borrows idle quota from tenants
    # that have drained
    tenants = [
        TenantSpec("analytics", weight=1.0, vm_quota=4),
        TenantSpec("backup", weight=1.0, vm_quota=4),
        TenantSpec("ml-sync", weight=2.0, slo_class="deadline", vm_quota=4),
    ]
    sizes = (2.0, 4.0, 3.0, 6.0)  # GB, cycled: mixed job sizes
    # deadline slack scales with the cohort: a tenant submitting 8
    # concurrent jobs cannot expect the 2-job wave's completion times
    slack_s = 30.0 + 15.0 * (per_tenant - 2)
    # full mode staggers each tenant's submissions (real tenants trickle
    # work in); the FAST wave keeps the all-at-once admission stress
    stagger_s = 0.0 if per_tenant <= 2 else 12.0
    jobs = {}
    for ti, spec in enumerate(tenants):
        src = SRC2 if spec.name == "backup" else SRC
        reqs = []
        for j in range(per_tenant):
            vol = sizes[(ti + j) % len(sizes)]
            reqs.append(TransferRequest(
                f"{spec.name}-{j}", src, DST, vol, 2.0,
                chunk_mb=1.0,
                arrival_s=j * stagger_s,
                deadline_s=(vol * 8.0 / 2.0 + slack_s
                            if spec.slo_class == "deadline" else None),
            ))
        jobs[spec.name] = reqs
    return make_drift, tenants, jobs


def _latencies(jobs) -> list[float]:
    return [j.delivered_gb * 8.0 / max(j.realized_tput_gbps, 1e-9)
            for j in jobs if j.delivered_gb > 0]


def run():
    from repro.core import milp
    from repro.calibrate import CalibratedTransferService
    from repro.transfer import FleetController, TransferRequest

    make_drift, tenants, jobs = _scenario()
    svc_kw = dict(backend="jax", max_relays=6, check_interval_s=4.0,
                  max_segments=150)

    # ---- isolated arms: one calibrated service (and probe budget) per
    # tenant, each discovering the same incident independently
    iso_delivered = iso_probe_cost = 0.0
    iso_makespan = 0.0
    iso_lat: list[float] = []
    t0 = time.time()
    for spec in tenants:
        # the tenant's own subscription quota caps every solo plan
        svc = CalibratedTransferService(make_drift(),
                                        vm_budget=spec.vm_quota, **svc_kw)
        for req in jobs[spec.name]:
            svc.submit(TransferRequest(**req.__dict__))
        rep = svc.run()
        iso_delivered += sum(j.delivered_gb for j in rep.jobs)
        iso_probe_cost += rep.probe_cost_usd
        iso_makespan = max(iso_makespan, rep.time_s)
        iso_lat += _latencies(rep.jobs)
    iso_wall = time.time() - t0
    iso_tput = iso_delivered * 8.0 / max(iso_makespan, 1e-9)

    # ---- the fleet: same world, same requests, one shared loop
    fleet = FleetController(make_drift(), tenants=tenants,
                            probe_dedup_window_s=3.0, **svc_kw)
    for spec in tenants:
        for req in jobs[spec.name]:
            fleet.submit(TransferRequest(**req.__dict__), tenant=spec.name)
    t0 = time.time()
    frep = fleet.run()
    fleet_wall = time.time() - t0
    fleet_delivered = sum(j.delivered_gb for j in frep.jobs)
    fleet_tput = fleet_delivered * 8.0 / max(frep.time_s, 1e-9)
    fleet_lat = _latencies(frep.jobs)

    assert fleet_delivered >= iso_delivered - 1e-6, (
        f"fleet delivered {fleet_delivered} < isolated {iso_delivered}"
    )
    replan_builds = sum(
        r.structure_builds for j in frep.jobs for r in j.replans
    )
    assert replan_builds == 0, "fleet re-plan re-assembled an LP structure"

    tput_ratio = fleet_tput / max(iso_tput, 1e-9)
    probe_ratio = (frep.probe_cost_usd / len(tenants)) / max(
        iso_probe_cost / len(tenants), 1e-9
    )
    p99 = lambda xs: float(np.percentile(xs, 99)) if xs else 0.0  # noqa: E731
    p99_ratio = p99(fleet_lat) / max(p99(iso_lat), 1e-9)

    emit("fleet/agg_tput_ratio_vs_isolated", fleet_wall * 1e6,
         round(tput_ratio, 3))
    emit("fleet/p99_job_latency_ratio", fleet_wall * 1e6,
         round(p99_ratio, 3))
    emit("fleet/probe_cost_per_tenant_ratio", iso_wall * 1e6,
         round(probe_ratio, 3))
    emit("fleet/replan_struct_builds", fleet_wall * 1e6, replan_builds)
    emit("fleet/fleet_agg_gbps", fleet_wall * 1e6, round(fleet_tput, 3))
    emit("fleet/isolated_agg_gbps", iso_wall * 1e6, round(iso_tput, 3))
    emit("fleet/probe_cost_usd", fleet_wall * 1e6,
         round(frep.probe_cost_usd, 4))
    emit("fleet/deferred_jobs", fleet_wall * 1e6, frep.deferred_jobs)
    emit("fleet/drift_events", fleet_wall * 1e6, len(frep.drift_events))
    emit("fleet/deadline_misses", fleet_wall * 1e6,
         sum(t.deadline_misses for t in frep.tenants))
    emit("fleet/quota_borrows", fleet_wall * 1e6,
         sum(t.quota_borrows for t in frep.tenants))

    # ---- batched cohort admission: wave planning must not re-assemble
    # beyond the first-touch structure builds of each distinct route
    fleet2 = FleetController(make_drift(), tenants=tenants, **svc_kw)
    for spec in tenants:
        for req in jobs[spec.name]:
            fleet2.submit(TransferRequest(**req.__dict__), tenant=spec.name)
    b0 = milp.N_STRUCT_BUILDS
    t0 = time.time()
    states = fleet2._admit_queue()
    admit_us = (time.time() - t0) * 1e6
    routes = {(r.src, r.dst) for t in tenants for r in jobs[t.name]}
    builds = milp.N_STRUCT_BUILDS - b0
    assert builds <= len(routes), (
        f"cohort admission built {builds} structures for "
        f"{len(routes)} routes"
    )
    assert all(s.status == "planned" for s in states)
    emit("fleet/cohort_admit_us", admit_us, len(states))
