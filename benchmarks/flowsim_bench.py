"""Data-plane simulator throughput: the vectorized event loop vs the
object-per-connection reference on the Fig. 6 workload, plus the
accelerator-resident jax engine vs the numpy SoA engine at scale.

The acceptance bar for the planner-hot-path PR: >=5x events/s at identical
delivered-chunk counts (fixed seed). The headroom is what lets Fig. 6/7/8
benchmarks run at 10x the chunk counts.

The jax-engine arm pins ISSUE 10: chunk-for-chunk bitwise parity with the
SoA engine (``flowsim_jax/parity_mismatches`` must be 0) and events/s at
least matching SoA at the 1e5-chunk scale where per-event python overhead
is amortized (``flowsim_jax/speedup_vs_soa_at_1e5`` >= 1.0 — a hard gate
in benchmarks/compare.py; the fused while_loop body keeps the O(chunks)
ring buffers out of every ``lax.cond`` so XLA inserts no per-event
copies)."""

from __future__ import annotations

import dataclasses
import time

from .common import FAST, emit


def run():
    from repro.core import Planner, PlanSpec, default_topology, direct_plan
    from repro.transfer import simulate_transfer, simulate_transfer_reference

    top = default_topology()
    planner = Planner(top)
    # Fig. 6 panel 1 route and planning setup
    src, dst = "aws:us-east-1", "aws:ap-southeast-2"
    volume = 8.0 if FAST else 32.0
    chunk = 32.0
    dp = direct_plan(top, src, dst, volume)
    plan = planner.plan(PlanSpec(
        objective="tput_max", src=src, dst=dst,
        cost_ceiling_per_gb=dp.cost_per_gb * 1.15,
        volume_gb=volume, n_samples=8, backend="jax",
    ))

    t0 = time.time()
    new = simulate_transfer(plan, chunk_mb=chunk, seed=0)
    t_new = time.time() - t0
    t0 = time.time()
    ref = simulate_transfer_reference(plan, chunk_mb=chunk, seed=0)
    t_ref = time.time() - t0

    ev_s_new = new.events / max(t_new, 1e-9)
    ev_s_ref = ref.events / max(t_ref, 1e-9)
    speedup = ev_s_new / ev_s_ref
    emit("flowsim/fig6_chunks", t_new * 1e6, new.chunks_delivered)
    emit("flowsim/fig6_events_per_s_vectorized", t_new * 1e6, round(ev_s_new))
    emit("flowsim/fig6_events_per_s_reference", t_ref * 1e6, round(ev_s_ref))
    emit("flowsim/fig6_events_per_s_speedup", t_new * 1e6, round(speedup, 1))
    assert new.chunks_delivered == ref.chunks_delivered, (
        new.chunks_delivered, ref.chunks_delivered)
    assert speedup >= 5.0, f"flowsim events/s speedup {speedup:.1f}x < 5x"

    # headroom demonstration: 10x the chunk count, vectorized path only
    t0 = time.time()
    big = simulate_transfer(plan, chunk_mb=chunk / 10.0, seed=0)
    t_big = time.time() - t0
    emit("flowsim/fig6_10x_chunks", t_big * 1e6, big.chunks_delivered)
    emit("flowsim/fig6_10x_chunks_wall_s", t_big * 1e6, round(t_big, 2))
    emit("flowsim/fig6_10x_events_per_s", t_big * 1e6,
         round(big.events / max(t_big, 1e-9)))

    _jax_engine_arm(top)


def _jax_engine_arm(top):
    """jax engine vs numpy SoA engine through transfer.sim.simulate."""
    from repro.core import direct_plan
    from repro.transfer import TransferJob, simulate

    def jobs_for(n_chunks):
        # 64 MB chunks, so volume_gb * 1024 / 64 == n_chunks exactly
        vol = n_chunks * 64 / 1024
        return [TransferJob(
            direct_plan(top, "aws:us-west-2", "aws:eu-central-1", vol,
                        num_vms=2),
            "bench",
        )]

    scales = ((1_000, 2), (20_000, 3)) if FAST else \
        ((1_000, 2), (10_000, 2), (100_000, 3))
    gate_scale = scales[-1][0]
    mismatches = 0
    speedup_at_gate = 0.0
    for n_chunks, reps in scales:
        rates = {}
        results = {}
        for eng in ("soa", "jax"):
            best = 0.0
            simulate(jobs_for(n_chunks), [], engine=eng, seed=0)  # warm
            for _ in range(reps):
                jobs = jobs_for(n_chunks)
                t0 = time.time()
                res = simulate(jobs, [], engine=eng, seed=0)
                best = max(best, res.events / max(time.time() - t0, 1e-9))
            rates[eng], results[eng] = best, res
        for a, b in zip(results["soa"].jobs, results["jax"].jobs):
            if dataclasses.asdict(a) != dataclasses.asdict(b):
                mismatches += 1
        speedup = rates["jax"] / rates["soa"]
        tag = f"{n_chunks // 1000}e3"
        emit(f"flowsim_jax/events_per_s_soa_{tag}", 0.0,
             round(rates["soa"]))
        emit(f"flowsim_jax/events_per_s_jax_{tag}", 0.0,
             round(rates["jax"]))
        emit(f"flowsim_jax/speedup_vs_soa_{tag}", 0.0, round(speedup, 2))
        if n_chunks == gate_scale:
            speedup_at_gate = speedup

    emit("flowsim_jax/parity_mismatches", 0.0, mismatches)
    emit("flowsim_jax/speedup_vs_soa_at_1e5", 0.0,
         round(speedup_at_gate, 2))
    assert mismatches == 0, (
        f"jax engine diverged bitwise from SoA on {mismatches} job(s)")
