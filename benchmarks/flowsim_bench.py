"""Data-plane simulator throughput: the vectorized event loop vs the
object-per-connection reference on the Fig. 6 workload.

The acceptance bar for the planner-hot-path PR: >=5x events/s at identical
delivered-chunk counts (fixed seed). The headroom is what lets Fig. 6/7/8
benchmarks run at 10x the chunk counts."""

from __future__ import annotations

import time

from .common import FAST, emit


def run():
    from repro.core import Planner, PlanSpec, default_topology, direct_plan
    from repro.transfer import simulate_transfer, simulate_transfer_reference

    top = default_topology()
    planner = Planner(top)
    # Fig. 6 panel 1 route and planning setup
    src, dst = "aws:us-east-1", "aws:ap-southeast-2"
    volume = 8.0 if FAST else 32.0
    chunk = 32.0
    dp = direct_plan(top, src, dst, volume)
    plan = planner.plan(PlanSpec(
        objective="tput_max", src=src, dst=dst,
        cost_ceiling_per_gb=dp.cost_per_gb * 1.15,
        volume_gb=volume, n_samples=8, backend="jax",
    ))

    t0 = time.time()
    new = simulate_transfer(plan, chunk_mb=chunk, seed=0)
    t_new = time.time() - t0
    t0 = time.time()
    ref = simulate_transfer_reference(plan, chunk_mb=chunk, seed=0)
    t_ref = time.time() - t0

    ev_s_new = new.events / max(t_new, 1e-9)
    ev_s_ref = ref.events / max(t_ref, 1e-9)
    speedup = ev_s_new / ev_s_ref
    emit("flowsim/fig6_chunks", t_new * 1e6, new.chunks_delivered)
    emit("flowsim/fig6_events_per_s_vectorized", t_new * 1e6, round(ev_s_new))
    emit("flowsim/fig6_events_per_s_reference", t_ref * 1e6, round(ev_s_ref))
    emit("flowsim/fig6_events_per_s_speedup", t_new * 1e6, round(speedup, 1))
    assert new.chunks_delivered == ref.chunks_delivered, (
        new.chunks_delivered, ref.chunks_delivered)
    assert speedup >= 5.0, f"flowsim events/s speedup {speedup:.1f}x < 5x"

    # headroom demonstration: 10x the chunk count, vectorized path only
    t0 = time.time()
    big = simulate_transfer(plan, chunk_mb=chunk / 10.0, seed=0)
    t_big = time.time() - t0
    emit("flowsim/fig6_10x_chunks", t_big * 1e6, big.chunks_delivered)
    emit("flowsim/fig6_10x_chunks_wall_s", t_big * 1e6, round(t_big, 2))
    emit("flowsim/fig6_10x_events_per_s", t_big * 1e6,
         round(big.events / max(t_big, 1e-9)))
