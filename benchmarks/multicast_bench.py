"""Multicast replication (ISSUE 3): one-to-many distribution trees.

Demonstrates the tentpole claim on the paper-scale topology: a multicast
plan to three same-continent destinations costs strictly less than the sum
of three per-destination unicast plans at the same throughput floor — the
shared cross-continent trunk is billed once. Also measures the multicast
simulator's events/s against the object-per-connection oracle on a
3-destination fan-out with a branch fault.
"""

from __future__ import annotations

import time

from .common import FAST, emit

SRC = "gcp:us-central1"
DSTS = ["gcp:europe-west1", "gcp:europe-west3", "gcp:europe-west4"]
FLOOR = 2.0


def run():
    from repro.core import PlanSpec, default_topology
    from repro.core.planner import Planner
    from repro.transfer import TransferJob, VMFailure, simulate

    top = default_topology()
    planner = Planner(top, max_relays=6)

    # ---- plan cost: multicast vs N unicasts at the same floor
    t0 = time.time()
    mc = planner.plan(PlanSpec(
        objective="cost_min", src=SRC, dsts=tuple(DSTS),
        tput_goal_gbps=FLOOR, volume_gb=16.0,
    ))
    t_mc = time.time() - t0
    assert mc.solver_status == "optimal" and mc.validate() == []
    t0 = time.time()
    unis = [
        planner.plan(PlanSpec(
            objective="cost_min", src=SRC, dst=d,
            tput_goal_gbps=FLOOR, volume_gb=16.0,
        ))
        for d in DSTS
    ]
    t_uni = time.time() - t0
    uni_cost = sum(u.total_cost for u in unis)
    uni_egress = sum(u.egress_cost for u in unis)
    assert mc.total_cost < uni_cost, "multicast must beat the unicast sum"
    ratio = mc.total_cost / uni_cost
    egress_saving = 1.0 - mc.egress_cost / uni_egress
    emit("multicast/plan_cost_per_gb", t_mc * 1e6,
         round(mc.cost_per_gb, 5))
    emit("multicast/unicast_sum_cost_per_gb", t_uni * 1e6,
         round(uni_cost / 16.0, 5))
    emit("multicast/cost_ratio_vs_unicasts", t_mc * 1e6, round(ratio, 4))
    emit("multicast/egress_savings_pct", t_mc * 1e6,
         round(100 * egress_saving, 1))

    # ---- warm re-plan of surviving branches: zero LP re-assembly
    from repro.core import milp

    s, d0 = top.index(SRC), top.index(DSTS[0])
    builds0 = milp.N_STRUCT_BUILDS
    t0 = time.time()
    replan = planner.plan(PlanSpec(
        objective="cost_min", src=SRC, dsts=tuple(DSTS),
        tput_goal_gbps=(0.0, FLOOR, FLOOR), volume_gb=8.0,
        degraded_links={(s, d0): 0.3},
    ))
    t_re = time.time() - t0
    assert replan.solver_status == "optimal"
    assert milp.N_STRUCT_BUILDS == builds0, "re-plan re-assembled structures"
    emit("multicast/replan_ms", t_re * 1e6, round(t_re * 1e3, 1))
    emit("multicast/replan_struct_builds", 0.0,
         milp.N_STRUCT_BUILDS - builds0)

    # ---- fan-out simulator vs oracle on a faulted 3-destination job
    volume = 4.0 if FAST else 12.0
    job = TransferJob(mc.with_volume(volume), "repl")
    kill_region = next(int(d) for d in mc.dsts if mc.N[d] >= 1)
    faults = [VMFailure(t_s=1.5, job=0, region=kill_region, count=1)]
    t0 = time.time()
    new = simulate([job], faults, seed=0)
    t_new = time.time() - t0
    t0 = time.time()
    ref = simulate([job], faults, seed=0, engine="ref")
    t_ref = time.time() - t0
    a, b = new.jobs[0], ref.jobs[0]
    assert a.per_dst_delivered == b.per_dst_delivered, (
        "multicast sim diverged from the reference"
    )
    ev_new = new.events / max(t_new, 1e-9)
    ev_ref = ref.events / max(t_ref, 1e-9)
    emit("multicast/sim_chunks_all_dests", t_new * 1e6,
         sum(a.per_dst_delivered.values()))
    emit("multicast/sim_retried", t_new * 1e6, a.retried_chunks)
    emit("multicast/sim_events_per_s_vectorized", t_new * 1e6, round(ev_new))
    emit("multicast/sim_events_per_s_reference", t_ref * 1e6, round(ev_ref))
    emit("multicast/sim_events_per_s_speedup", t_new * 1e6,
         round(ev_new / max(ev_ref, 1e-9), 1))
