"""Multi-job data plane under faults: simulator events/s (vectorized vs the
object-per-connection reference) on a 3-job contention scenario, and the
TransferService's mid-transfer re-plan latency on the warm LPStructure
cache (the PR-1 cache is what makes failure-driven re-planning cheap)."""

from __future__ import annotations

import time

from .common import FAST, emit


def run():
    from repro.core import default_topology, direct_plan
    from repro.transfer import (
        LinkDegrade,
        TransferJob,
        TransferRequest,
        TransferService,
        VMFailure,
        simulate,
    )

    top = default_topology()
    src, dst = "aws:us-east-1", "aws:ap-southeast-2"
    src2 = "gcp:us-central1"
    volume = 4.0 if FAST else 16.0
    jobs = [
        TransferJob(direct_plan(top, src, dst, volume, num_vms=2), "a"),
        TransferJob(direct_plan(top, src, dst, volume, num_vms=2), "b",
                    arrival_s=1.0),
        TransferJob(direct_plan(top, src2, dst, volume, num_vms=2), "c"),
    ]
    s, d = top.index(src), top.index(dst)
    faults = [
        LinkDegrade(t_s=2.0, src=s, dst=d, factor=0.5),
        VMFailure(t_s=4.0, job=0, region=s, count=1),
    ]

    t0 = time.time()
    new = simulate(jobs, faults, seed=0, link_capacity_scale=0.8)
    t_new = time.time() - t0
    t0 = time.time()
    ref = simulate(jobs, faults, seed=0, link_capacity_scale=0.8,
                   engine="ref")
    t_ref = time.time() - t0
    assert [j.chunks_delivered for j in new.jobs] == [
        j.chunks_delivered for j in ref.jobs
    ], "vectorized multi-job sim diverged from the reference"

    ev_new = new.events / max(t_new, 1e-9)
    ev_ref = ref.events / max(t_ref, 1e-9)
    emit("multijob/3job_chunks",
         t_new * 1e6, sum(j.chunks_delivered for j in new.jobs))
    emit("multijob/3job_retried", t_new * 1e6,
         sum(j.retried_chunks for j in new.jobs))
    emit("multijob/3job_events_per_s_vectorized", t_new * 1e6, round(ev_new))
    emit("multijob/3job_events_per_s_reference", t_ref * 1e6, round(ev_ref))
    emit("multijob/3job_events_per_s_speedup", t_new * 1e6,
         round(ev_new / max(ev_ref, 1e-9), 1))

    # ---- failure-driven re-plan latency on the warm structure cache
    svc = TransferService(top, backend="jax", max_relays=6)
    svc.submit(TransferRequest("a", src, dst, volume, 4.0))
    svc.submit(TransferRequest("b", src, dst, volume, 4.0, arrival_s=1.0))
    svc.submit(TransferRequest("c", src2, dst, volume, 4.0))
    rep = svc.run(faults=faults, link_capacity_scale=0.8)
    replans = rep.replans
    assert replans, "fault schedule produced no re-plans"
    assert all(r.structure_builds == 0 for r in replans), (
        "re-planning re-assembled an LPStructure"
    )
    lat = [r.latency_s for r in replans]
    emit("multijob/service_jobs_done", 0.0,
         sum(j.status == "done" for j in rep.jobs))
    emit("multijob/service_replans", 0.0, len(replans))
    emit("multijob/replan_latency_ms", sum(lat) / len(lat) * 1e6,
         round(sum(lat) / len(lat) * 1e3, 1))
    emit("multijob/replan_struct_builds", 0.0,
         sum(r.structure_builds for r in replans))
