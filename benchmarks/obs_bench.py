"""Skytrace overhead: flowsim with tracing on vs off.

The instrumentation contract is that a disabled tracer costs one
attribute read per guard site and an ENABLED tracer stays within 5% of
the untraced simulator — ``obs/tracing_overhead_ratio`` (wall time with
tracing on over off, best-of-N) is hard-gated at <= 1.05 in
``benchmarks/compare.py``. Also pins ``N_STRUCT_BUILDS`` parity: re-plans
over cached LP structures must leave the registered counter untouched.
"""

from __future__ import annotations

import time

from .common import FAST, emit

SRC, DST = "aws:us-west-2", "aws:eu-central-1"
SRC2 = "gcp:us-central1"


def _scenario():
    """Two planned bulk jobs plus a seeded chaos suite on their links."""
    from repro.core import Planner, PlanSpec, default_topology
    from repro.transfer import ChaosScenario, TransferJob

    top = default_topology()
    planner = Planner(top, max_relays=6)
    s, d, s2 = top.index(SRC), top.index(DST), top.index(SRC2)
    vol = 1.0 if FAST else 2.0
    specs = [
        PlanSpec(objective="cost_min", src=SRC, dst=DST,
                 tput_goal_gbps=2.0, volume_gb=vol),
        PlanSpec(objective="cost_min", src=SRC2, dst=DST,
                 tput_goal_gbps=2.0, volume_gb=vol),
    ]
    jobs = [
        TransferJob(plan=planner.plan(specs[0]), name="bulk-a",
                    chunk_mb=64.0),
        TransferJob(plan=planner.plan(specs[1]), name="bulk-b",
                    arrival_s=1.0, chunk_mb=64.0),
    ]
    sc = ChaosScenario(top, seed=0, horizon_s=6.0,
                       n_brownouts=1, n_gray=1, n_flapping=1,
                       links=[(s, d), (s2, d)])
    return planner, specs, jobs, sc


def run():
    from repro.obs.metrics import REGISTRY
    from repro.obs.trace import disable, enable
    from repro.transfer import simulate

    planner, specs, jobs, sc = _scenario()
    faults = sc.events(len(jobs))

    def once():
        return simulate(jobs, faults, seed=0, horizon_s=12.0, drain=True)

    once()  # warm the vectorized kernels before timing
    reps = 3 if FAST else 5

    disable()
    t_off = min(_timed(once) for _ in range(reps))

    tr = enable(capacity=1 << 20)
    n_events = 0
    t_on = float("inf")
    for _ in range(reps):
        tr.clear()
        t_on = min(t_on, _timed(once))
        n_events = len(tr)
    disable()

    ratio = t_on / max(t_off, 1e-9)
    emit("obs/sim_wall_off", t_off * 1e6, round(t_off * 1e3, 2))
    emit("obs/sim_wall_on", t_on * 1e6, round(t_on * 1e3, 2))
    emit("obs/tracing_overhead_ratio", t_on * 1e6, round(ratio, 3))
    emit("obs/trace_events_per_run", t_on * 1e6, n_events)

    # N_STRUCT_BUILDS parity: the same specs re-plan on cached structures,
    # so the registered counter must not move
    b0 = REGISTRY.counter("planner.struct_builds").value
    for _ in range(2):
        for spec in specs:
            planner.plan(spec)
    delta = REGISTRY.counter("planner.struct_builds").value - b0
    emit("obs/struct_builds_delta", 0.0, delta)


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0
