"""Probe-policy engine (ISSUE 5): race the four probe schedulers across an
incident-rich drift scenario, and exercise belief epoch rolls.

Three parts:

1. **Tracking race** — greedy VoI, round-robin, ε-greedy and Bayesian
   EVOI each spend an identical, deliberately tight probe budget
   (3 probes/round against ~130 candidate links from THREE concurrent
   transfer contexts) while staggered step-change incidents collapse the
   links the plans actually ride. Beliefs start pre-warmed (the paper's
   offline profiling pass measured every link once), so the race
   measures steady-state RE-probing — where policies genuinely differ.
   The scored metric is the plan-scoped believed-vs-true error (mean
   over rounds, over the links carrying plan flow): the error that costs
   plan quality. EVOI re-prices every link by the plan regret its
   uncertainty causes, so it watches the handful of links the three
   plans depend on and catches each collapse within a round; greedy's
   score spreads across the whole candidate pool and detects late;
   round-robin's sweep is slowest of all.
2. **Service race** — the same scenario end-to-end through
   ``CalibratedTransferService``: aggregate delivered throughput per
   policy (the loop's passive telemetry backstops every policy at
   segment boundaries, so this leg is closer than the tracking race —
   which is itself a result worth pinning).
3. **Epoch rolls** — a recovery scenario (the epoch grid pins the
   source's egress at 5% of reality — a past incident, now over). The
   round-robin sweep discovers the recovery, the service rolls the epoch
   onto the improved belief, and the transfer finishes faster. Rolls are
   counted and bounded (<= 2 per transfer), only fire at segment
   boundaries, and their deliberate LP re-assemblies are the ONLY ones
   in the run.

Acceptance (asserted here, hard-gated in CI via benchmarks.compare):
EVOI delivers >= 1.1x greedy's believed-vs-true error reduction OR
>= 1.05x greedy's delivered throughput (``probe_policies/evoi_gate`` >= 1).
"""

from __future__ import annotations

import time

import numpy as np

from .common import FAST, emit

# three concurrent transfer contexts: one per provider, so the per-provider
# drift priors and the pruned candidate subgraphs all differ
CONTEXTS = [
    ("aws:us-west-2", "aws:eu-central-1"),
    ("gcp:us-central1", "gcp:europe-west1"),
    ("azure:eastus", "azure:westeurope"),
]
GOAL = 4.0
POLICIES = ("greedy", "round_robin", "epsilon_greedy", "evoi")


def _scenario(top):
    """Plans for the three contexts plus staggered incidents on the link
    carrying each plan's largest flow — the scenario a static belief
    cannot track. Returns (planner, plans, drift, plan_mask)."""
    from repro.calibrate import DriftModel, Incident
    from repro.core import Planner, PlanSpec

    planner = Planner(top, max_relays=6)
    plans = [
        planner.plan(PlanSpec(
            objective="cost_min", src=s, dst=d,
            tput_goal_gbps=GOAL, volume_gb=8.0,
        ))
        for s, d in CONTEXTS
    ]
    mask = np.zeros_like(np.asarray(top.tput), dtype=bool)
    hit = []
    for p in plans:
        m = p.F > 1e-9
        mask |= m
        links = np.argwhere(m)
        order = np.argsort(-p.F[m])
        hit.append(tuple(map(int, links[order[0]])))
    incidents = [
        Incident(src=a, dst=b, t_start_s=5.0 + 6.0 * i, duration_s=1e9,
                 severity=0.10 + 0.05 * i)
        for i, (a, b) in enumerate(hit)
    ]
    drift = DriftModel(top, seed=3, drift_sigma=0.20, diurnal_amp=0.0,
                       incidents=incidents)
    return planner, plans, drift, mask


def _prewarm(top, drift, candidates):
    """A belief after the offline profiling pass: every candidate link
    measured once at t=0 (high weight, no noise)."""
    from repro.calibrate import BeliefGrid

    bel = BeliefGrid(top)
    truth0 = drift.tput_at(0.0)
    for a, b in candidates:
        bel.observe_adaptive(a, b, float(truth0[a, b]), weight=4.0, t_s=0.0)
    return bel


def _budget():
    from repro.calibrate import ProbeBudget

    return ProbeBudget(usd_per_round=0.9, seconds_per_round=20.0,
                       max_probes_per_round=3)


def _tracking_race(top) -> float:
    """Part 1: mean plan-scoped belief error per policy; returns the
    greedy/EVOI error ratio (EVOI's error-reduction factor)."""
    from repro.calibrate import Calibrator, make_policy

    planner, plans, drift, mask = _scenario(top)
    candidates = Calibrator(_prewarm(top, drift, [])).candidate_links(
        planner, CONTEXTS
    )
    rounds = 10 if FAST else 16
    tracking = {}
    for pol in POLICIES:
        bel = _prewarm(top, drift, candidates)
        cal = Calibrator(bel, policy=make_policy(pol, seed=7),
                         budget=_budget())
        t0 = time.time()
        errs = []
        for k in range(rounds):
            t = 2.0 + 2.0 * k
            cal.run_round(t, drift.tput_at(t), planner=planner,
                          contexts=CONTEXTS, plans=plans)
            errs.append(bel.error_vs(drift.tput_at(t), mask=mask))
        wall = time.time() - t0
        tracking[pol] = float(np.mean(errs))
        emit(f"probe_policies/{pol}_tracking_err", wall * 1e6,
             round(tracking[pol], 4))
        emit(f"probe_policies/{pol}_probes", wall * 1e6, cal.total_probes)
    err_ratio = tracking["greedy"] / max(tracking["evoi"], 1e-9)
    emit("probe_policies/evoi_vs_greedy_error_reduction", 0.0,
         round(err_ratio, 3))
    return err_ratio


def _service_race(top) -> float:
    """Part 2: aggregate delivered throughput through the closed loop per
    policy; returns the EVOI/greedy throughput ratio."""
    from repro.calibrate import (
        CalibratedTransferService,
        Calibrator,
        make_policy,
    )
    from repro.core import Planner
    from repro.transfer import TransferRequest

    planner, _plans, drift, _mask = _scenario(top)
    candidates = Calibrator(_prewarm(top, drift, [])).candidate_links(
        Planner(top, max_relays=6), CONTEXTS
    )
    volume = 2.0 if FAST else 4.0
    achieved = {}
    arms = ("greedy", "evoi") if FAST else POLICIES
    for pol in arms:
        bel = _prewarm(top, drift, candidates)
        svc = CalibratedTransferService(
            drift, belief=bel,
            calibrator=Calibrator(bel, policy=make_policy(pol, seed=7),
                                  budget=_budget()),
            backend="jax", max_relays=6, check_interval_s=4.0,
            max_segments=150,
        )
        for i, (s, d) in enumerate(CONTEXTS):
            svc.submit(TransferRequest(f"job{i}", s, d, volume, GOAL))
        t0 = time.time()
        rep = svc.run()
        wall = time.time() - t0
        assert all(j.status == "done" for j in rep.jobs), (
            pol,
            [j.status for j in rep.jobs],
        )
        for r in rep.replans:
            assert r.structure_builds == 0, (
                f"{pol}: drift re-plan re-assembled an LP"
            )
        total_gb = sum(j.delivered_gb for j in rep.jobs)
        achieved[pol] = total_gb * 8.0 / max(rep.time_s, 1e-9)
        emit(f"probe_policies/{pol}_achieved_gbps", wall * 1e6,
             round(achieved[pol], 3))
    tput_ratio = achieved["evoi"] / max(achieved["greedy"], 1e-9)
    emit("probe_policies/evoi_vs_greedy_tput", 0.0, round(tput_ratio, 3))
    return tput_ratio


def _epoch_roll_scenario(top):
    """Part 3: the epoch grid undersells the source's egress 20x; the
    round-robin sweep discovers it and the service rolls the epoch."""
    from repro.calibrate import (
        BeliefGrid,
        CalibratedTransferService,
        DriftModel,
    )
    from repro.transfer import TransferRequest

    src, dst = CONTEXTS[0]
    s = top.index(src)

    def degraded_belief():
        bel = BeliefGrid(top)
        for b in range(top.num_regions):
            if b != s and top.tput[s, b] > 0:
                bel.reset_link(s, b, 0.05 * top.tput[s, b])
        return bel

    drift = DriftModel(top, seed=0, drift_sigma=0.02, diurnal_amp=0.0)
    volume = 4.0 if FAST else 8.0
    achieved = {}
    rolls = builds = 0
    for max_rolls in (2, 0):
        svc = CalibratedTransferService(
            drift, belief=degraded_belief(), backend="jax", max_relays=6,
            check_interval_s=4.0, policy="round_robin",
            max_epoch_rolls=max_rolls, max_segments=150,
        )
        svc.submit(TransferRequest("roll", src, dst, volume, GOAL))
        t0 = time.time()
        rep = svc.run()
        wall = time.time() - t0
        job = rep.jobs[0]
        assert job.status == "done", job.status
        achieved[max_rolls] = job.delivered_gb * 8.0 / max(rep.time_s, 1e-9)
        if max_rolls:
            rolls = len(rep.epoch_rolls)
            builds = rep.epoch_roll_builds
            # rolls only ever fire at segment boundaries, bounded per run
            assert 1 <= rolls <= 2, f"expected 1-2 epoch rolls, got {rolls}"
            assert all(
                any(abs(r.t_s - b) < 1e-9 for b in rep.boundaries)
                for r in rep.epoch_rolls
            ), "epoch roll fired mid-segment"
            emit("probe_policies/epoch_roll_achieved_gbps", wall * 1e6,
                 round(achieved[max_rolls], 3))
        else:
            assert not rep.epoch_rolls
            emit("probe_policies/noroll_achieved_gbps", wall * 1e6,
                 round(achieved[max_rolls], 3))
    emit("probe_policies/epoch_rolls", 0.0, rolls)
    emit("probe_policies/epoch_roll_struct_builds", 0.0, builds)
    gain = achieved[2] / max(achieved[0], 1e-9)
    assert gain >= 1.02, f"epoch roll did not pay: {gain:.3f}x"
    emit("probe_policies/epoch_roll_gain_x", 0.0, round(gain, 3))


def run():
    from repro.core import default_topology

    top = default_topology()
    err_ratio = _tracking_race(top)
    tput_ratio = _service_race(top)
    # the acceptance gate: EVOI must clear either leg — >= 1.1x greedy's
    # error reduction or >= 1.05x greedy's delivered throughput. The gate
    # metric is capped at 5: when EVOI's tracking error approaches zero
    # the raw ratio explodes, and a CI baseline comparison on an
    # unbounded ratio would gate on the denominator's noise.
    gate = min(max(err_ratio / 1.1, tput_ratio / 1.05), 5.0)
    assert gate >= 1.0, (
        f"EVOI under-performed greedy: err x{err_ratio:.2f} (need 1.1) "
        f"and tput x{tput_ratio:.2f} (need 1.05)"
    )
    emit("probe_policies/evoi_gate", 0.0, round(gate, 3))
    _epoch_roll_scenario(top)
