"""§Roofline: aggregate the dry-run artifacts into the per-(arch x shape x
mesh) three-term roofline table. Reads artifacts/dryrun/*.json (produced by
`python -m repro.launch.dryrun --all`)."""

from __future__ import annotations

import json
from pathlib import Path

from .common import emit

ART = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"


def iter_artifacts(mesh: str = "single", variant: str | None = None):
    for f in sorted(ART.glob("*.json")):
        parts = f.stem.split("__")
        if len(parts) < 3 or parts[2] != mesh:
            continue
        if variant is None and len(parts) > 3:
            continue
        if variant is not None and (len(parts) < 4 or parts[3] != variant):
            continue
        yield json.loads(f.read_text())


def run():
    if not ART.exists():
        emit("roofline/missing_artifacts", 0.0, "run repro.launch.dryrun first")
        return
    worst = None
    most_coll = None
    for a in iter_artifacts("single"):
        name = f"{a['arch']}/{a['shape']}"
        if a["status"] == "skipped":
            emit(f"roofline/{name}/skipped", 0.0, a["skip_reason"][:40])
            continue
        r = a.get("roofline")
        if not r:
            continue
        total = r["compute_s"] + r["memory_s"] + r["collective_s"]
        frac = r["compute_s"] / max(total, 1e-12)
        emit(f"roofline/{name}/compute_s", 0.0, f"{r['compute_s']:.4f}")
        emit(f"roofline/{name}/memory_s", 0.0, f"{r['memory_s']:.4f}")
        emit(f"roofline/{name}/collective_s", 0.0, f"{r['collective_s']:.4f}")
        emit(f"roofline/{name}/dominant", 0.0, r["dominant"])
        emit(f"roofline/{name}/compute_fraction", 0.0, f"{frac:.3f}")
        emit(f"roofline/{name}/useful_flops_ratio", 0.0,
             f"{r['useful_flops_ratio']:.3f}")
        if worst is None or frac < worst[1]:
            worst = (name, frac)
        cfrac = r["collective_s"] / max(total, 1e-12)
        if most_coll is None or cfrac > most_coll[1]:
            most_coll = (name, cfrac)
    if worst:
        emit("roofline/worst_compute_fraction_cell", 0.0,
             f"{worst[0]}:{worst[1]:.3f}")
    if most_coll:
        emit("roofline/most_collective_bound_cell", 0.0,
             f"{most_coll[0]}:{most_coll[1]:.3f}")
