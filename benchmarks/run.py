"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig7,table2] [--json out.json]

Prints ``name,us_per_call,derived`` CSV rows (common.emit). Set
REPRO_BENCH_FAST=1 for the abbreviated suite used in CI. ``--json PATH``
additionally writes a perf snapshot (every emitted metric plus per-module
wall time) so future PRs have a trajectory to compare against — see
BENCH_planner_hotpath.json at the repo root for the recorded baselines.
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
import time
import traceback

from . import (  # noqa: F401
    calibration_bench,
    chaos_bench,
    common,
    fig3_grid,
    fig6_transfer_comparison,
    fig7_overlay_ablation,
    fig8_bottlenecks,
    fig9_microbench,
    fig10_overlay_vs_vms,
    fleet_bench,
    flowsim_bench,
    multicast_bench,
    multijob_bench,
    obs_bench,
    probe_policy_bench,
    roofline,
    solver_bench,
    table2_academic,
)

MODULES = {
    "fig3": fig3_grid,
    "fig6": fig6_transfer_comparison,
    "fig7": fig7_overlay_ablation,
    "fig8": fig8_bottlenecks,
    "fig9": fig9_microbench,
    "fig10": fig10_overlay_vs_vms,
    "table2": table2_academic,
    "solver": solver_bench,
    "flowsim": flowsim_bench,
    "multijob": multijob_bench,
    "multicast": multicast_bench,
    "calibration": calibration_bench,
    "chaos": chaos_bench,
    "fleet": fleet_bench,
    "probe_policies": probe_policy_bench,
    "obs": obs_bench,
    "roofline": roofline,
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module names (default: all)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write a BENCH_<name>.json perf snapshot of this run")
    args = ap.parse_args()
    names = list(MODULES) if not args.only else args.only.split(",")
    print("name,us_per_call,derived")
    failures = 0
    module_s = {}
    for name in names:
        mod = MODULES[name]
        t0 = time.time()
        try:
            mod.run()
            module_s[name] = round(time.time() - t0, 1)
            print(f"# {name} done in {module_s[name]}s", file=sys.stderr)
        except Exception:  # noqa: BLE001
            failures += 1
            module_s[name] = None
            print(f"# {name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr)
    if args.json:
        snapshot = {
            "schema": 1,
            "generated": time.strftime("%Y-%m-%d %H:%M:%S"),
            "fast_mode": common.FAST,
            "python": platform.python_version(),
            "machine": platform.machine(),
            "modules_run": names,
            "module_wall_s": module_s,
            "metrics": common.RESULTS,
        }
        with open(args.json, "w") as fh:
            json.dump(snapshot, fh, indent=1)
        print(f"# snapshot -> {args.json}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
