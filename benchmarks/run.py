"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig7,table2]

Prints ``name,us_per_call,derived`` CSV rows (common.emit). Set
REPRO_BENCH_FAST=1 for the abbreviated suite used in CI.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from . import (  # noqa: F401
    fig3_grid,
    fig6_transfer_comparison,
    fig7_overlay_ablation,
    fig8_bottlenecks,
    fig9_microbench,
    fig10_overlay_vs_vms,
    roofline,
    solver_bench,
    table2_academic,
)

MODULES = {
    "fig3": fig3_grid,
    "fig6": fig6_transfer_comparison,
    "fig7": fig7_overlay_ablation,
    "fig8": fig8_bottlenecks,
    "fig9": fig9_microbench,
    "fig10": fig10_overlay_vs_vms,
    "table2": table2_academic,
    "solver": solver_bench,
    "roofline": roofline,
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module names (default: all)")
    args = ap.parse_args()
    names = list(MODULES) if not args.only else args.only.split(",")
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        mod = MODULES[name]
        t0 = time.time()
        try:
            mod.run()
            print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# {name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
