"""§5 solve-time claims: the MILP 'can quickly be solved in under 5 seconds',
a Pareto sweep evaluates many samples quickly — and the structure-cached /
batched planner hot path beats the frozen pre-optimization pipeline
(_legacy_planner) by the required margins with identical plan costs."""

from __future__ import annotations

import time

from .common import FAST, emit, timed


def run():
    from repro.core import Planner, PlanSpec, default_topology
    from repro.core.solver.bnb import solve_milp

    top = default_topology()
    planner = Planner(top)
    src, dst = "azure:canadacentral", "gcp:asia-northeast1"

    with timed() as t:
        plan = planner.plan(PlanSpec(
            objective="cost_min", src=src, dst=dst,
            tput_goal_gbps=25.0, volume_gb=50.0,
        ))
    emit("solver/cost_min_relaxed_s", t.us, round(t.us / 1e6, 3))
    assert t.us / 1e6 < 5.0, "paper claims <5s solves"

    sub, s, t_, _ = planner._prune(src, dst)
    with timed() as tm:
        res = solve_milp(sub, s, t_, 25.0, mode="exact")
    emit("solver/exact_bnb_s", tm.us, round(tm.us / 1e6, 3))
    emit("solver/exact_bnb_nodes", tm.us, res.nodes_explored)
    assert tm.us / 1e6 < 5.0

    n = 4 if FAST else 20
    t0 = time.time()
    planner.plan(PlanSpec(
        objective="pareto", src=src, dst=dst, volume_gb=50.0, n_samples=n,
    ))
    per = (time.time() - t0) / n
    emit("solver/pareto_per_sample_s", per * 1e6, round(per, 3))
    emit("solver/pareto_100_samples_projected_s", per * 1e6, round(per * 100, 1))

    # beyond-paper: the whole sweep as ONE batched IPM call (§5.2's
    # "100 samples in under 20 s on a c5.9xlarge" workload, single CPU core)
    nb = 16 if FAST else 100
    t0 = time.time()
    pts = planner.plan(PlanSpec(
        objective="pareto_fast", src=src, dst=dst, volume_gb=50.0,
        n_samples=nb,
    ))
    dt = time.time() - t0
    emit("solver/pareto_batched_continuous_samples", dt * 1e6, nb)
    emit("solver/pareto_batched_continuous_total_s", dt * 1e6, round(dt, 2))
    assert len(pts) >= nb * 0.8

    _speedup_section(top, src, dst)


def _speedup_section(top, src, dst):
    """Fast path (LPStructure cache + presolve + batched round-down) vs the
    frozen pre-PR sequential pipeline, identical plan costs enforced."""
    from repro.core import Planner, PlanSpec
    from . import _legacy_planner as legacy

    n_samples = 8 if FAST else 40
    # routes without degenerate alternate-optimum frontier points, so the
    # fast-vs-legacy cost comparison is exact (on degenerate routes the two
    # solvers may pick different near-equal integer plans; the fast path is
    # equal or better there — see tests/test_solver_equivalence.py for the
    # fast==sequential pin that holds on every route)
    pairs = [
        ("azure:canadacentral", "gcp:asia-northeast1"),
        ("aws:us-west-2", "aws:eu-central-1"),
    ]
    for pair_i, (a, b) in enumerate(pairs[: 1 if FAST else None]):
        tag = f"solver/pair{pair_i}"
        planner = Planner(top)
        # warm both paths once: jit/struct caches are amortized across the
        # thousands of planner calls this hot path serves
        planner.plan(PlanSpec(
            objective="cost_min", src=a, dst=b, tput_goal_gbps=20.0,
            volume_gb=50.0, backend="jax",
        ))

        # ---- cost_min: >=3x required
        with timed() as t_new:
            plan_new = planner.plan(PlanSpec(
                objective="cost_min", src=a, dst=b, tput_goal_gbps=25.0,
                volume_gb=50.0, backend="jax",
            ))
        legacy_planner = Planner(top)
        sub, s, t_, keep = legacy_planner._prune(a, b)
        with timed() as t_old:
            res_old = legacy.solve_milp_legacy(sub, s, t_, 25.0)
        plan_old = legacy_planner._lift(sub, keep, a, b, 25.0, 50.0, res_old)
        cost_min_speedup = t_old.us / t_new.us
        dcost = abs(plan_new.cost_per_gb - plan_old.cost_per_gb)
        emit(f"{tag}/cost_min_legacy_s", t_old.us, round(t_old.us / 1e6, 3))
        emit(f"{tag}/cost_min_fast_s", t_new.us, round(t_new.us / 1e6, 3))
        emit(f"{tag}/cost_min_speedup", t_new.us, round(cost_min_speedup, 1))
        emit(f"{tag}/cost_min_abs_dcost_per_gb", t_new.us, f"{dcost:.2e}")
        assert dcost < 1e-6, f"plan cost drifted: {dcost}"
        assert cost_min_speedup >= 3.0, f"cost_min speedup {cost_min_speedup:.1f}x < 3x"

        # ---- integerized pareto_frontier: >=5x required
        t0 = time.time()
        pts_new = planner.plan(PlanSpec(
            objective="pareto", src=a, dst=b, volume_gb=50.0,
            n_samples=n_samples, backend="jax",
        ))
        t_fast = time.time() - t0
        t0 = time.time()
        pts_old = legacy.pareto_frontier_legacy(legacy_planner, a, b, 50.0,
                                                n_samples=n_samples)
        t_leg = time.time() - t0
        pareto_speedup = t_leg / t_fast
        emit(f"{tag}/pareto_n", t_fast * 1e6, n_samples)
        emit(f"{tag}/pareto_legacy_s", t_leg * 1e6, round(t_leg, 2))
        emit(f"{tag}/pareto_fast_s", t_fast * 1e6, round(t_fast, 2))
        emit(f"{tag}/pareto_speedup", t_fast * 1e6, round(pareto_speedup, 1))
        assert len(pts_new) == len(pts_old)
        max_d = max(
            abs(p.cost_per_gb - c_old)
            for p, (_, c_old, _) in zip(pts_new, pts_old)
        )
        emit(f"{tag}/pareto_max_abs_dcost_per_gb", t_fast * 1e6, f"{max_d:.2e}")
        assert max_d < 1e-6, f"frontier cost drifted: {max_d}"
        # the >=5x acceptance bar is for the full n_samples=40 sweep; the
        # abbreviated FAST sweep amortizes the batched root less
        bar = 3.0 if FAST else 5.0
        assert pareto_speedup >= bar, (
            f"pareto speedup {pareto_speedup:.1f}x < {bar}x")
