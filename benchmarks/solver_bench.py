"""§5 solve-time claims: the MILP 'can quickly be solved in under 5 seconds'
and a Pareto sweep evaluates many samples quickly."""

from __future__ import annotations

import time

from .common import FAST, emit, timed


def run():
    from repro.core import Planner, default_topology
    from repro.core.solver.bnb import solve_milp

    top = default_topology()
    planner = Planner(top)
    src, dst = "azure:canadacentral", "gcp:asia-northeast1"

    with timed() as t:
        plan = planner.plan_cost_min(src, dst, 25.0, 50.0)
    emit("solver/cost_min_relaxed_s", t.us, round(t.us / 1e6, 3))
    assert t.us / 1e6 < 5.0, "paper claims <5s solves"

    sub, s, t_, _ = planner._prune(src, dst)
    with timed() as tm:
        res = solve_milp(sub, s, t_, 25.0, mode="exact")
    emit("solver/exact_bnb_s", tm.us, round(tm.us / 1e6, 3))
    emit("solver/exact_bnb_nodes", tm.us, res.nodes_explored)
    assert tm.us / 1e6 < 5.0

    n = 4 if FAST else 20
    t0 = time.time()
    planner.pareto_frontier(src, dst, 50.0, n_samples=n)
    per = (time.time() - t0) / n
    emit("solver/pareto_per_sample_s", per * 1e6, round(per, 3))
    emit("solver/pareto_100_samples_projected_s", per * 1e6, round(per * 100, 1))

    # beyond-paper: the whole sweep as ONE batched JAX IPM call (§5.2's
    # "100 samples in under 20 s on a c5.9xlarge" workload, single CPU core)
    nb = 16 if FAST else 100
    t0 = time.time()
    pts = planner.pareto_frontier_fast(src, dst, 50.0, n_samples=nb)
    dt = time.time() - t0
    emit("solver/pareto_batched_jax_samples", dt * 1e6, nb)
    emit("solver/pareto_batched_jax_total_s", dt * 1e6, round(dt, 2))
    assert len(pts) >= nb * 0.8
