"""Table 2 / §7.6: academic baselines on the paper's exact route —
16 GB, Azure East US -> AWS ap-northeast-1, VM-to-VM (no object store).

  GCT GridFTP (1 VM, static round-robin chunks)
  Skyplane    (1 VM, direct)
  Skyplane w/ RON routes (4 VMs)
  Skyplane    (cost optimized, 4 VMs)
  Skyplane    (throughput optimized, 4 VMs)
"""

from __future__ import annotations

import dataclasses

from .common import FAST, emit, timed

SRC, DST = "azure:eastus", "aws:ap-northeast-1"
VOLUME = 16.0


def run():
    from repro.core import (
        Planner, PlanSpec, default_topology, direct_plan, gridftp_plan,
        ron_plan,
    )
    from repro.transfer import simulate_transfer

    top = dataclasses.replace(default_topology(), limit_vm=4)
    planner = Planner(top)
    dp1 = direct_plan(top, SRC, DST, VOLUME, num_vms=1)

    rows = []
    rows.append(("gridftp_1vm", gridftp_plan(top, SRC, DST, VOLUME), "static"))
    rows.append(("skyplane_direct_1vm", dp1, "dynamic"))
    rows.append(("skyplane_ron_4vm", ron_plan(top, SRC, DST, VOLUME, num_vms=4),
                 "dynamic"))
    cost_plan = planner.plan(PlanSpec(
        objective="cost_min", src=SRC, dst=DST,
        tput_goal_gbps=max(dp1.throughput * 2.2, 1.0), volume_gb=VOLUME,
    ))
    rows.append(("skyplane_costopt_4vm", cost_plan, "dynamic"))
    ron_cost = rows[2][1].total_cost
    # paper Table 2: tput-opt costs 0.70x RON while beating its throughput;
    # the achievable margin is grid-dependent, so give the planner a 0.85x
    # ceiling (still decisively cheaper than RON)
    tput_plan = planner.plan(PlanSpec(
        objective="tput_max", src=SRC, dst=DST,
        cost_ceiling_per_gb=ron_cost / VOLUME * 0.85, volume_gb=VOLUME,
        n_samples=8 if FAST else 16,
    ))
    rows.append(("skyplane_tputopt_4vm", tput_plan, "dynamic"))

    results = {}
    for name, plan, dispatch in rows:
        with timed() as t:
            res = simulate_transfer(plan, chunk_mb=16, dispatch=dispatch,
                                    seed=2)
        results[name] = res
        emit(f"table2/{name}/time_s", t.us, round(res.time_s, 1))
        emit(f"table2/{name}/gbps", t.us, round(res.tput_gbps, 2))
        emit(f"table2/{name}/cost_usd", t.us, round(res.total_cost, 2))

    # the paper's qualitative claims
    assert (
        results["skyplane_direct_1vm"].tput_gbps
        > results["gridftp_1vm"].tput_gbps
    )
    assert (
        results["skyplane_ron_4vm"].tput_gbps
        > results["skyplane_direct_1vm"].tput_gbps
    )
    assert (
        results["skyplane_costopt_4vm"].total_cost
        < results["skyplane_ron_4vm"].total_cost
    )
    # RON-comparable throughput at decisively lower cost (paper: faster AND
    # 30% cheaper; the tput margin is grid-dependent)
    assert (
        results["skyplane_tputopt_4vm"].tput_gbps
        >= results["skyplane_ron_4vm"].tput_gbps * 0.85
    )
    assert (
        results["skyplane_tputopt_4vm"].total_cost
        < results["skyplane_ron_4vm"].total_cost * 0.95
    )
    emit("table2/tputopt_speedup_vs_direct1vm", 0.0,
         round(results["skyplane_tputopt_4vm"].tput_gbps
               / results["skyplane_direct_1vm"].tput_gbps, 2))
