"""Adaptive transfer (ISSUE 4): a long transfer survives a step-change
interconnect incident because the calibration plane closes the
measure -> believe -> plan -> observe loop.

Two identical services run the same job on the same drifting TRUE
topology. One calibrates: it spends a probe budget on the links its
planner cares about, harvests per-link delivered rates from every data
plane segment, detects that its primary link collapsed (believed vs
observed beyond confidence bounds), and re-plans the REMAINING volume
around the incident — on cached LP structures, zero re-assembly. The
other trusts the stale offline grid and limps through the incident at a
fraction of its SLO.

    PYTHONPATH=src python examples/adaptive_transfer.py
    PYTHONPATH=src python examples/adaptive_transfer.py --policy evoi

``--policy`` picks the probe scheduler (greedy | round_robin |
epsilon_greedy | evoi — see repro.calibrate.policies for what each
optimizes). Set REPRO_BENCH_FAST=1 for the abbreviated smoke-test volume.
"""

import argparse
import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np  # noqa: E402

from repro.calibrate import (  # noqa: E402
    POLICY_NAMES,
    BeliefGrid,
    CalibratedTransferService,
    Calibrator,
    DriftModel,
    Incident,
)
from repro.core import PlanSpec, Planner, default_topology  # noqa: E402
from repro.transfer import TransferRequest  # noqa: E402

FAST = os.environ.get("REPRO_BENCH_FAST", "0") == "1"

SRC, DST = "aws:us-west-2", "aws:eu-central-1"
GOAL_GBPS = 4.0
VOLUME_GB = 4.0 if FAST else 12.0


def main():
    ap = argparse.ArgumentParser(description="adaptive transfer demo")
    ap.add_argument("--policy", default="greedy", choices=list(POLICY_NAMES),
                    help="probe scheduling policy for the calibrated run")
    args = ap.parse_args()
    top = default_topology()

    # Scenario: the TRUE topology drifts slowly everywhere, and the stale
    # plan's primary edge suffers a step-change incident mid-transfer.
    stale_primary = Planner(top, max_relays=6).plan(PlanSpec(
        objective="cost_min", src=SRC, dst=DST,
        tput_goal_gbps=GOAL_GBPS, volume_gb=VOLUME_GB,
    ))
    a, b = np.unravel_index(int(np.argmax(stale_primary.F)),
                            stale_primary.F.shape)
    keys = top.keys()
    print(f"transfer {SRC} -> {DST}: {VOLUME_GB} GB at {GOAL_GBPS} Gbps SLO")
    print(f"incident: {keys[a]} -> {keys[b]} collapses to 8% at t=6s\n")
    drift = DriftModel(
        top, seed=0, drift_sigma=0.10, diurnal_amp=0.0,
        incidents=[Incident(src=int(a), dst=int(b), t_start_s=6.0,
                            duration_s=1e9, severity=0.08)],
    )

    slo_s = VOLUME_GB * 8.0 / GOAL_GBPS
    achieved = {}
    for calibrate in (True, False):
        belief = BeliefGrid(top)
        svc = CalibratedTransferService(
            drift, belief=belief, backend="jax", max_relays=6,
            calibrate=calibrate, check_interval_s=4.0, max_segments=150,
            calibrator=Calibrator(belief, policy=args.policy)
            if calibrate else None,
        )
        svc.submit(TransferRequest("weights", SRC, DST, VOLUME_GB, GOAL_GBPS))
        rep = svc.run()
        job = rep.jobs[0]
        ach = job.delivered_gb * 8.0 / max(rep.time_s, 1e-9)
        achieved[calibrate] = ach
        tag = (f"calibrated ({args.policy})" if calibrate else "stale grid")
        print(f"=== {tag} ===")
        print(f"  {job.delivered_gb:.1f} GB in {rep.time_s:.1f}s "
              f"({ach:.2f} Gbps achieved; SLO time {slo_s:.0f}s)")
        if calibrate:
            print(f"  probes: {sum(r.n_probes for r in rep.probe_rounds)} "
                  f"across {len(rep.probe_rounds)} rounds, "
                  f"${rep.probe_cost_usd:.2f} spent")
            for ev in rep.drift_events[:3]:
                print(f"  drift @t={ev.t_s:.1f}s via {ev.source}: "
                      f"{keys[ev.src]} -> {keys[ev.dst]} observed "
                      f"{ev.observed_gbps:.2f} Gbps vs "
                      f"{ev.assumed_gbps:.2f} assumed")
            for r in rep.replans:
                print(f"  re-plan @t={r.at_s:.1f}s: {r.remaining_gb:.1f} GB "
                      f"re-routed, {r.structure_builds} LP re-assemblies")
                assert r.structure_builds == 0
            assert rep.drift_events and rep.replans
        else:
            assert not rep.replans  # the stale service never adapts
        assert job.status == "done"
        print()

    ratio = achieved[True] / max(achieved[False], 1e-9)
    print(f"calibration kept {ratio:.1f}x the stale plan's throughput "
          "through the incident")
    assert ratio >= 1.5


if __name__ == "__main__":
    main()
