"""Train a model for a few steps, checkpoint it, and replicate the
checkpoint to two disaster-recovery regions through Skyplane-planned
overlays — the framework's verbatim use of the paper's technique.

    PYTHONPATH=src python examples/checkpoint_replication.py
"""

import os
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

FAST = os.environ.get("REPRO_BENCH_FAST", "0") == "1"

from repro.configs import get_arch, reduced  # noqa: E402
from repro.core import default_topology  # noqa: E402
from repro.ckpt import replicate_checkpoint  # noqa: E402
from repro.train.optimizer import OptConfig  # noqa: E402
from repro.train.trainer import Trainer, TrainerConfig  # noqa: E402
from repro.transfer.gateway import BlobStore  # noqa: E402


def main():
    cfg = reduced(get_arch("smollm-135m"))
    steps = 3 if FAST else 10
    with tempfile.TemporaryDirectory() as d:
        trainer = Trainer(
            cfg,
            TrainerConfig(steps=steps, global_batch=2, seq_len=64,
                          ckpt_every=steps, ckpt_dir=d),
            opt_cfg=OptConfig(total_steps=steps),
        )
        result = trainer.run()
        print(f"trained {result['final_step']} steps, "
              f"loss {result['losses'][-1]:.3f}")
        ckpt = trainer.ckpt.latest()
        print(f"checkpoint: {ckpt.name}")

        top = default_topology()
        dr_regions = ["gcp:europe-west4", "azure:southeastasia"]
        stores = {r: BlobStore() for r in dr_regions}
        reports = replicate_checkpoint(
            ckpt, top, src_region="aws:us-west-2",
            dst_regions=dr_regions, dst_stores=stores,
            tput_floor_gbps=10.0,
        )
        for r in reports:
            relay = f" via {r.relay_regions}" if r.relay_regions else " (direct)"
            print(f"  -> {r.destination}: {r.plan_tput_gbps:.1f} Gbps planned"
                  f"{relay}, ${r.plan_cost_per_gb:.4f}/GB, "
                  f"{r.gateway.chunks} chunks, "
                  f"{r.gateway.checksum_failures} checksum failures")
            assert r.gateway.checksum_failures == 0
            assert stores[r.destination].exists("MANIFEST.json")
        print("replication verified on both DR regions")


if __name__ == "__main__":
    main()
