"""Elastic fleet rescale: price the state movement for a pod joining the
fleet (a Skyplane flow job), then re-mesh the training state and keep
training.

    PYTHONPATH=src python examples/elastic_rescale.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402

from repro.configs import get_arch, reduced  # noqa: E402
from repro.core import default_topology  # noqa: E402
from repro.launch.elastic import plan_reshard, reshard_state  # noqa: E402
from repro.models import init_params, loss_fn  # noqa: E402
from repro.sharding.specs import ShardingRules  # noqa: E402
from repro.train.optimizer import init_opt_state  # noqa: E402


def main():
    cfg = reduced(get_arch("qwen2-7b"))
    top = default_topology()

    pods_old = ["aws:us-west-2", "gcp:us-central1"]
    pods_new = pods_old + ["azure:westeurope"]
    plan = plan_reshard(cfg, top, pods_old, pods_new, tput_floor_gbps=5.0)
    print(f"pod join: {plan.old_pods} -> {plan.new_pods} pods")
    for src, dst, gb, tput, cost in plan.moves:
        print(f"  bootstrap {dst} from {src}: {gb:.3f} GB at "
              f"{tput:.1f} Gbps, ${cost:.4f} (est {plan.est_time_s:.1f}s)")

    params = init_params(cfg, jax.random.PRNGKey(0))
    state = {"params": params, "opt": init_opt_state(params)}
    mesh, state2 = reshard_state(cfg, state, new_pods=1, data=1, model=1)
    print(f"state re-meshed onto {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    # training continues on the new mesh
    rules = ShardingRules(batch=None, fsdp=None, tp=None)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0,
                                     cfg.vocab_size),
    }
    loss, _ = jax.jit(lambda p, b: loss_fn(cfg, rules, p, b))(
        state2["params"], batch
    )
    print(f"post-rescale loss: {float(loss):.3f} (finite => state intact)")


if __name__ == "__main__":
    main()
