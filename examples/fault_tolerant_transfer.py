"""Fault-tolerant multi-job transfers: three tenants share the data plane,
a gateway dies mid-transfer, and every byte still lands — on both layers:

  1. the fluid multi-job simulator + TransferService: a VM failure and a
     link brown-out trigger failure-driven re-planning of the remaining
     volume on the degraded topology (cached-structure refit, no LP
     re-assembly);
  2. the real-bytes gateway chain: a FaultInjector kills a hop worker (and
     corrupts a payload) mid-transfer; chunk-level checksummed retry
     finishes with zero data loss and never re-sends a verified byte.

    PYTHONPATH=src python examples/fault_tolerant_transfer.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np  # noqa: E402

from repro.core import PlanSpec, Planner, default_topology, toy_topology  # noqa: E402
from repro.transfer import (  # noqa: E402
    BlobStore,
    FaultInjector,
    LinkDegrade,
    TransferRequest,
    TransferService,
    VMFailure,
    transfer_objects,
)


def control_plane_demo():
    print("=== control plane: 3 jobs, link brown-out + gateway-VM kill ===")
    top = default_topology()
    src, dst, src2 = "aws:us-west-2", "aws:eu-central-1", "gcp:us-central1"
    svc = TransferService(top, backend="jax", max_relays=6)
    svc.submit(TransferRequest("alpha", src, dst, 4.0, 4.0))
    svc.submit(TransferRequest("bravo", src, dst, 4.0, 4.0, arrival_s=1.0))
    svc.submit(TransferRequest("charlie", src2, dst, 4.0, 4.0))

    s, d = top.index(src), top.index(dst)
    report = svc.run(faults=[
        LinkDegrade(t_s=3.0, src=s, dst=d, factor=0.3),  # brown-out
        VMFailure(t_s=5.0, job=0, region=s, count=1),    # gateway dies
    ])
    for j in report.jobs:
        print(f"  {j.request.name:8s} {j.status:7s} "
              f"{j.delivered_gb:5.2f} GB delivered, "
              f"{j.realized_tput_gbps:5.2f} Gbps realized "
              f"(planned {j.planned_tput_gbps:5.2f}), "
              f"${j.realized_cost:.3f} vs ${j.planned_cost:.3f} planned, "
              f"{len(j.replans)} re-plan(s)")
    for r in report.replans:
        print(f"    re-plan {r.job} @t={r.at_s:.1f}s: "
              f"{r.remaining_gb:.2f} GB remaining re-routed in "
              f"{r.latency_s * 1e3:.0f} ms "
              f"({r.structure_builds} LP re-assemblies)")
    assert report.all_done, "a job did not survive the fault schedule"
    assert report.replans and all(r.reused_structure for r in report.replans)
    # the report protocol is the source of truth: summary() renders the
    # headline keys, to_dict() carries the registry's metrics section
    print("  " + report.summary())
    metrics = report.to_dict()["metrics"]
    assert metrics["service.replans"] >= len(report.replans)
    assert metrics["planner.struct_builds"] >= 1
    print("  metrics: "
          + " ".join(f"{k}={v}" for k, v in metrics.items()) + "\n")


def data_plane_demo():
    print("=== data plane: real bytes through a killed gateway worker ===")
    top = toy_topology(n=5, seed=2)
    plan = Planner(top, max_relays=3).plan(PlanSpec(
        objective="cost_min", src="toy:r0", dst="toy:r1",
        tput_goal_gbps=2.0, volume_gb=0.02,
    ))
    rng = np.random.default_rng(7)
    src_store, dst_store = BlobStore(), BlobStore()
    keys = []
    for i in range(3):
        key = f"ckpt/shard_{i:02d}.bin"
        src_store.put(key, rng.bytes(2_000_000 + 131 * i))
        keys.append(key)

    injector = FaultInjector(
        kill_worker_after={(0, 0): 3},  # first-hop worker dies on chunk #4
        corrupt_chunks={f"{keys[1]}#2"},  # one payload corrupted in flight
    )
    rep = transfer_objects(
        plan, src_store, dst_store, keys,
        chunk_bytes=1 << 18, workers_per_hop=3, fault_injector=injector,
    )
    print("  " + rep.summary())
    metrics = rep.to_dict()["metrics"]
    assert metrics["gateway.retries"] >= rep.retried_chunks
    print("  metrics: " + " ".join(f"{k}={v}" for k, v in metrics.items()))
    assert rep.checksum_failures == 0 and rep.chunks_missing == 0
    for key in keys:
        assert dst_store.get(key) == src_store.get(key)
    print("  every object byte-identical at the destination: zero data loss")


def main():
    control_plane_demo()
    data_plane_demo()


if __name__ == "__main__":
    main()
