"""Fleet control plane (ISSUE 7): three tenants, one shared belief.

One ``FleetController`` runs an analytics tenant, a backup tenant, and a
deadline-SLO ml-sync tenant through a single admission-controlled loop:

  * the wave is admitted as ONE batched cohort (``plan_cohort``) with
    weighted max-min fair goals on contended routes — deadline tenants
    are carved out first, bulk shares the remainder;
  * every tenant reads and writes the SAME belief grid, so one tenant's
    probe (or telemetry harvest) re-plans every plan riding the drifted
    link, and the probe budget is spent once, not once per tenant;
  * each tenant's cloud subscription caps its VM count — but a tenant
    whose recovery plan needs more than its own quota may borrow the
    idle quota of tenants that already drained (an isolated service
    treats the subscription limit as a wall).

    PYTHONPATH=src python examples/fleet_transfer.py

Set REPRO_BENCH_FAST=1 for the abbreviated smoke-test volumes.
"""

import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np  # noqa: E402

from repro.calibrate import DriftModel, Incident  # noqa: E402
from repro.core import Planner, PlanSpec, default_topology  # noqa: E402
from repro.transfer import (  # noqa: E402
    FleetController,
    TenantSpec,
    TransferRequest,
)

FAST = os.environ.get("REPRO_BENCH_FAST", "0") == "1"

SRC, DST = "aws:us-west-2", "aws:eu-central-1"
SRC2 = "azure:canadacentral"


def main():
    top = default_topology()

    # The incident lands on the busiest planned edge of the shared route
    # — the same edge every SRC->DST tenant rides.
    probe_plan = Planner(top, max_relays=6).plan(PlanSpec(
        objective="cost_min", src=SRC, dst=DST,
        tput_goal_gbps=4.0, volume_gb=4.0,
    ))
    a, b = np.unravel_index(int(np.argmax(probe_plan.F)),
                            probe_plan.F.shape)
    drift = DriftModel(
        top, seed=0, drift_sigma=0.10, diurnal_amp=0.0,
        incidents=[Incident(src=int(a), dst=int(b), t_start_s=6.0,
                            duration_s=1e9, severity=0.08)],
    )

    tenants = [
        TenantSpec("analytics", weight=1.0, vm_quota=4),
        TenantSpec("backup", weight=1.0, vm_quota=4),
        TenantSpec("ml-sync", weight=2.0, slo_class="deadline", vm_quota=4),
    ]
    fleet = FleetController(
        drift, tenants=tenants, backend="jax", max_relays=6,
        check_interval_s=4.0, max_segments=60 if FAST else 150,
        probe_dedup_window_s=3.0,
    )

    per_tenant = 2 if FAST else 4
    sizes = (2.0, 4.0, 3.0, 6.0)
    for ti, spec in enumerate(tenants):
        src = SRC2 if spec.name == "backup" else SRC
        for j in range(per_tenant):
            vol = sizes[(ti + j) % len(sizes)]
            fleet.submit(TransferRequest(
                f"{spec.name}-{j}", src, DST, vol, 2.0, chunk_mb=1.0,
                deadline_s=(vol * 8.0 / 2.0 + 30.0 * max(per_tenant // 2, 1)
                            if spec.slo_class == "deadline" else None),
            ), tenant=spec.name)

    rep = fleet.run()

    # summary() renders the fleet report's headline keys; to_dict()
    # carries the registry metrics section for the planes the fleet spans
    print(rep.summary())
    print(f"probe cost (shared)   : {rep.probe_cost_usd:8.4f} $")
    print(f"drift events          : {len(rep.drift_events):8d}")
    print(f"deferred jobs         : {rep.deferred_jobs:8d}")
    for t in rep.tenants:
        print(f"  tenant {t.name:<10} jobs={t.jobs} "
              f"delivered={t.delivered_gb:6.2f} GB "
              f"deadline_misses={t.deadline_misses} "
              f"quota_borrows={t.quota_borrows}")
        print("   ", t.summary())

    delivered = sum(j.delivered_gb for j in rep.jobs)
    submitted = sum(
        j.request.volume_gb for j in rep.jobs
    )
    assert delivered >= submitted - 1e-6, (
        f"fleet left {submitted - delivered:.2f} GB undelivered"
    )
    replan_builds = sum(
        r.structure_builds for j in rep.jobs for r in j.replans
    )
    assert replan_builds == 0, "a fleet re-plan re-assembled an LP structure"
    metrics = rep.to_dict()["metrics"]
    assert metrics["planner.struct_builds"] >= 1
    assert metrics["calibrate.probes"] >= 1
    print("metrics: " + " ".join(f"{k}={v}" for k, v in metrics.items()))
    print("OK: all volume delivered, zero structure builds across re-plans")


if __name__ == "__main__":
    main()
