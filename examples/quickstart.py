"""Quickstart: plan and execute a cloud bulk transfer with Skyplane's
planner (paper Fig. 1 route), then run it on the simulated data plane.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import dataclasses  # noqa: E402

from repro.core import PlanSpec, Planner, default_topology, direct_plan  # noqa: E402
from repro.transfer import execute_plan  # noqa: E402


def main():
    # 4-VM service limit keeps this quick; drop the replace() for the full
    # 8-VM plans used in the benchmarks.
    top = dataclasses.replace(default_topology(), limit_vm=4)
    planner = Planner(top)
    src, dst = "azure:canadacentral", "gcp:asia-northeast1"
    # the volume stays put even under REPRO_BENCH_FAST: the fidelity assert
    # below needs a transfer long enough to amortize pipeline ramp-up
    volume_gb = 16.0

    # ----- the naive baseline: direct path, max VMs
    direct = direct_plan(top, src, dst, volume_gb, num_vms=4)
    print(f"direct path:  {direct.throughput:6.2f} Gbps "
          f"at ${direct.cost_per_gb:.4f}/GB")

    # ----- Skyplane mode 2: maximize throughput under a 1.25x price ceiling
    plan = planner.plan(PlanSpec(
        objective="tput_max", src=src, dst=dst,
        cost_ceiling_per_gb=direct.cost_per_gb * 1.25,
        volume_gb=volume_gb,
    ))
    print(plan.describe())
    print(f"-> {plan.throughput / direct.throughput:.2f}x faster for "
          f"{plan.cost_per_gb / direct.cost_per_gb:.2f}x the price")

    # ----- Skyplane mode 1: cheapest plan that sustains 20 Gbps
    cheap = planner.plan(PlanSpec(
        objective="cost_min", src=src, dst=dst,
        tput_goal_gbps=20.0, volume_gb=volume_gb,
    ))
    print(f"cost-min @20Gbps: ${cheap.cost_per_gb:.4f}/GB "
          f"({cheap.throughput:.1f} Gbps planned)")

    # ----- execute on the fluid data plane (chunks, stragglers, flow ctrl)
    rep = execute_plan(plan, chunk_mb=16, seed=0)
    print(f"simulated: {rep.sim.tput_gbps:.2f} Gbps achieved "
          f"({rep.tput_ratio:.0%} of plan), realized cost "
          f"${rep.sim.total_cost:.2f} vs planned ${plan.total_cost:.2f}")
    assert rep.tput_ratio > 0.6


if __name__ == "__main__":
    main()
