"""End-to-end training driver (thin wrapper over repro.launch.train).

Default: a 0.25-scale smollm derivative for ~50 steps on CPU. The full
~135M-parameter run of the brief:

    PYTHONPATH=src python examples/train_e2e.py --scale 1.0 --steps 200 \
        --batch 8 --seq 256
"""

import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.launch.train import main  # noqa: E402

if __name__ == "__main__":
    if os.environ.get("REPRO_BENCH_FAST", "0") == "1" and len(sys.argv) == 1:
        # smoke-test abbreviation: enough steps to prove the loop runs
        sys.argv += ["--steps", "3", "--scale", "0.1", "--batch", "1",
                     "--seq", "32", "--ckpt-every", "3"]
    raise SystemExit(main())
