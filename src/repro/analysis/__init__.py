"""skylint: AST-based static analysis for the repo's load-bearing invariants.

Run it over the tree::

    python -m repro.analysis check src tests benchmarks examples
    python -m repro.analysis check src --format=json

The engine (``engine.py``) is rule-agnostic: it loads files, indexes
``# skylint: disable=RULE`` pragmas (standalone comment = whole file,
trailing comment = that line; every pragma is audited, unknown ids are
findings), and hands each parsed module to every registered rule. The
repo-specific rules live in ``rules.py``; importing this package registers
them. Stdlib-only by design — CI runs it before installing anything.
"""

from . import rules as _rules  # noqa: F401  (importing registers the rules)
from .engine import (
    CheckReport,
    Context,
    Finding,
    Pragma,
    Rule,
    active_rule_ids,
    active_rules,
    check,
    register,
)

__all__ = [
    "CheckReport",
    "Context",
    "Finding",
    "Pragma",
    "Rule",
    "active_rule_ids",
    "active_rules",
    "check",
    "register",
]
