"""CLI entry point: ``python -m repro.analysis check [paths] [--format=...]``.

Exit codes: 0 clean, 1 findings, 2 usage error. ``--format=text`` (default)
prints one line per finding plus a summary; ``--format=json`` emits the full
report — findings, active rules, and the pragma allowlist audit — for the CI
artifact. ``--output FILE`` additionally writes the JSON report to a file
regardless of the chosen display format.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from . import check


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="skylint: repo-invariant static analysis",
    )
    sub = parser.add_subparsers(dest="command")
    p_check = sub.add_parser("check", help="lint the given paths")
    p_check.add_argument(
        "paths", nargs="*", default=["src", "tests", "benchmarks", "examples"],
        help="files or directories, relative to --root (default: "
        "src tests benchmarks examples)",
    )
    p_check.add_argument(
        "--format", choices=("text", "json"), default="text",
        dest="fmt", help="report format on stdout",
    )
    p_check.add_argument(
        "--root", default=".",
        help="repo root the rule path-scopes are resolved against",
    )
    p_check.add_argument(
        "--output", default=None, metavar="FILE",
        help="also write the JSON report to FILE",
    )
    args = parser.parse_args(argv)
    if args.command != "check":
        parser.print_help()
        return 2

    root = Path(args.root).resolve()
    paths = [p for p in args.paths if (root / p).exists()]
    if not paths:
        print(f"skylint: no such paths under {root}: {args.paths}",
              file=sys.stderr)
        return 2

    report = check(root, paths)
    print(report.to_json() if args.fmt == "json" else report.to_text())
    if args.output:
        Path(args.output).write_text(report.to_json() + "\n",
                                     encoding="utf-8")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
