"""skylint engine: file loading, pragma handling, rule registry, reporting.

The engine is deliberately stdlib-only (``ast`` + ``tokenize``): CI runs it
before any heavyweight dependency is installed, and the self-tests run it
against synthetic trees under ``tmp_path``.

Vocabulary:

  * :class:`Finding`    — one violation: file:line, rule id, severity,
    message and a fix hint.
  * :class:`SourceFile` — one parsed file plus its pragma index.
  * :class:`Context`    — the whole scanned tree. Rules receive it on every
    ``visit`` call so cross-file rules (sim parity, report protocol) can
    read their sibling files; ``ctx.current`` is the file under visit.
  * :class:`Rule`       — the plugin protocol: ``visit(tree, ctx) ->
    list[Finding]`` plus ``id`` / ``severity`` / ``description`` class
    attributes. Register implementations with :func:`register`.

Pragmas: ``# skylint: disable=SKY001,SKY003``. A standalone comment line
disables the listed rules for the WHOLE file; a trailing comment disables
them for that line only. Every pragma is recorded (file, line, scope,
rules) so the JSON report doubles as the allowlist audit, and a pragma
naming an unknown rule id is itself a finding (``SKY000``) — a typo must
not silently disable nothing.
"""

from __future__ import annotations

import ast
import dataclasses
import io
import json
import re
import tokenize
from pathlib import Path

SEVERITIES = ("error", "warning")

# Engine-level rule id: parse failures and bad pragmas.
ENGINE_RULE_ID = "SKY000"

_PRAGMA_RE = re.compile(r"#\s*skylint:\s*disable=([A-Za-z0-9_,\s]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    path: str  # root-relative, posix separators
    line: int
    rule: str
    severity: str
    message: str
    hint: str = ""

    def format(self) -> str:
        s = f"{self.path}:{self.line}: {self.rule} [{self.severity}] {self.message}"
        if self.hint:
            s += f"  (hint: {self.hint})"
        return s

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Pragma:
    """One ``# skylint: disable=...`` occurrence (for the allowlist audit)."""

    path: str
    line: int
    scope: str  # "file" | "line"
    rules: tuple[str, ...]

    def to_dict(self) -> dict:
        return {"path": self.path, "line": self.line, "scope": self.scope,
                "rules": list(self.rules)}


@dataclasses.dataclass
class SourceFile:
    """One parsed source file plus its pragma index."""

    relpath: str
    source: str
    tree: ast.Module | None  # None when the file failed to parse
    file_pragmas: set = dataclasses.field(default_factory=set)
    line_pragmas: dict = dataclasses.field(default_factory=dict)  # line -> set
    pragmas: list = dataclasses.field(default_factory=list)  # [Pragma]
    parse_error: str | None = None

    @property
    def lines(self) -> list[str]:
        return self.source.splitlines()

    def suppressed(self, rule: str, line: int) -> bool:
        if rule in self.file_pragmas:
            return True
        return rule in self.line_pragmas.get(line, ())


def _scan_pragmas(sf: SourceFile) -> None:
    """Tokenize-based pragma extraction: comments only, so pragma-looking
    text inside string literals (fixture snippets in the self-tests) is
    never mistaken for a real pragma."""
    try:
        toks = list(tokenize.generate_tokens(io.StringIO(sf.source).readline))
    except (tokenize.TokenError, IndentationError, SyntaxError):
        return
    for tok in toks:
        if tok.type != tokenize.COMMENT:
            continue
        m = _PRAGMA_RE.search(tok.string)
        if m is None:
            continue
        rules = tuple(
            r.strip() for r in m.group(1).split(",") if r.strip()
        )
        line_no = tok.start[0]
        standalone = sf.lines[line_no - 1].lstrip().startswith("#")
        scope = "file" if standalone else "line"
        sf.pragmas.append(Pragma(sf.relpath, line_no, scope, rules))
        if standalone:
            sf.file_pragmas.update(rules)
        else:
            sf.line_pragmas.setdefault(line_no, set()).update(rules)


@dataclasses.dataclass
class ClassInfo:
    """Repo-wide class-table entry used by cross-file inheritance rules."""

    name: str
    relpath: str
    line: int
    bases: tuple[str, ...]  # simple names (Attribute bases keep the attr)
    own_names: frozenset  # methods + class-level assignments


class Context:
    """The scanned tree. ``current`` rotates as the engine visits files."""

    def __init__(self, root: Path, files: dict):
        self.root = Path(root)
        self.files: dict[str, SourceFile] = files
        self.current: SourceFile | None = None
        self._class_index: dict[str, ClassInfo] | None = None

    # ------------------------------------------------------------- utilities
    def file(self, relpath: str) -> SourceFile | None:
        return self.files.get(relpath)

    def under(self, *prefixes: str) -> bool:
        """Is the current file under any of the given root-relative dirs?"""
        rp = self.current.relpath
        return any(rp == p or rp.startswith(p.rstrip("/") + "/")
                   for p in prefixes)

    def finding(self, rule, node_or_line, message: str, hint: str = "") -> Finding:
        line = getattr(node_or_line, "lineno", node_or_line)
        return Finding(
            path=self.current.relpath, line=int(line), rule=rule.id,
            severity=rule.severity, message=message, hint=hint or rule.hint,
        )

    @property
    def class_index(self) -> dict[str, ClassInfo]:
        """name -> ClassInfo over every scanned file (last definition wins —
        class names are unique in this repo; good enough for lint)."""
        if self._class_index is None:
            index: dict[str, ClassInfo] = {}
            for sf in self.files.values():
                if sf.tree is None:
                    continue
                for node in ast.walk(sf.tree):
                    if not isinstance(node, ast.ClassDef):
                        continue
                    bases = []
                    for b in node.bases:
                        if isinstance(b, ast.Name):
                            bases.append(b.id)
                        elif isinstance(b, ast.Attribute):
                            bases.append(b.attr)
                    own = set()
                    for st in node.body:
                        if isinstance(st, (ast.FunctionDef,
                                           ast.AsyncFunctionDef)):
                            own.add(st.name)
                        elif isinstance(st, ast.Assign):
                            for t in st.targets:
                                if isinstance(t, ast.Name):
                                    own.add(t.id)
                        elif isinstance(st, ast.AnnAssign) and isinstance(
                            st.target, ast.Name
                        ):
                            own.add(st.target.id)
                    index[node.name] = ClassInfo(
                        node.name, sf.relpath, node.lineno, tuple(bases),
                        frozenset(own),
                    )
            self._class_index = index
        return self._class_index

    def mro_names(self, cls: str, *, include: tuple[str, ...] = (),
                  exclude: tuple[str, ...] = ()) -> set:
        """Union of ``own_names`` along the (simple-name) inheritance chain.

        ``exclude`` drops the listed class names' contributions (used to ask
        "does the chain define ``kind`` anywhere OTHER than the root
        mixin"). Unknown bases contribute nothing."""
        seen: set[str] = set()
        names: set[str] = set()
        stack = [cls]
        while stack:
            c = stack.pop()
            if c in seen:
                continue
            seen.add(c)
            info = self.class_index.get(c)
            if info is None:
                continue
            if c not in exclude or c in include:
                names |= info.own_names
            stack.extend(info.bases)
        return names


# ------------------------------------------------------------- rule registry
class Rule:
    """Base class / protocol for skylint rules.

    Subclasses set ``id`` (``SKY###``), ``severity``, ``description`` and a
    default fix ``hint``, and implement ``visit(tree, ctx)`` returning the
    findings for ``ctx.current``. ``visit`` is called once per parsed file;
    rules that need a whole-repo view anchor themselves on one file and
    read siblings through ``ctx.files``."""

    id: str = "SKY999"
    severity: str = "error"
    description: str = ""
    hint: str = ""

    def visit(self, tree: ast.Module, ctx: Context) -> list:
        raise NotImplementedError


_REGISTRY: dict[str, Rule] = {}


def register(cls):
    """Class decorator adding a rule to the active set."""
    if cls.severity not in SEVERITIES:
        raise ValueError(f"{cls.id}: bad severity {cls.severity!r}")
    if cls.id in _REGISTRY:
        raise ValueError(f"duplicate rule id {cls.id}")
    _REGISTRY[cls.id] = cls()
    return cls


def active_rules() -> list:
    return [_REGISTRY[k] for k in sorted(_REGISTRY)]


def active_rule_ids() -> tuple:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------- the check
def _collect_py(root: Path, paths) -> list:
    out = []
    for p in paths:
        ap = (root / p) if not Path(p).is_absolute() else Path(p)
        if ap.is_file() and ap.suffix == ".py":
            out.append(ap)
        elif ap.is_dir():
            out.extend(
                f for f in sorted(ap.rglob("*.py"))
                if "__pycache__" not in f.parts
                and not any(part.startswith(".") for part in f.parts)
            )
    return out


def load_tree(root, paths) -> Context:
    root = Path(root).resolve()
    files: dict[str, SourceFile] = {}
    for f in _collect_py(root, paths):
        rel = f.resolve().relative_to(root).as_posix()
        source = f.read_text(encoding="utf-8")
        try:
            tree = ast.parse(source, filename=rel)
            err = None
        except SyntaxError as e:
            tree, err = None, f"line {e.lineno}: {e.msg}"
        sf = SourceFile(relpath=rel, source=source, tree=tree,
                        parse_error=err)
        _scan_pragmas(sf)
        files[rel] = sf
    return Context(root, files)


@dataclasses.dataclass
class CheckReport:
    """Everything one ``check`` run produced."""

    findings: list
    pragmas: list
    files_scanned: int
    rules: list  # active Rule instances

    @property
    def ok(self) -> bool:
        return not self.findings

    def to_dict(self) -> dict:
        return {
            "version": 1,
            "ok": self.ok,
            "files_scanned": self.files_scanned,
            "rules": [
                {"id": r.id, "severity": r.severity,
                 "description": r.description}
                for r in self.rules
            ],
            "findings": [f.to_dict() for f in self.findings],
            "pragmas": [p.to_dict() for p in self.pragmas],
        }

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2)

    def to_text(self) -> str:
        lines = [f.format() for f in self.findings]
        n_err = sum(1 for f in self.findings if f.severity == "error")
        n_warn = len(self.findings) - n_err
        lines.append(
            f"skylint: {self.files_scanned} files, "
            f"{len(self.rules)} rules, {n_err} errors, {n_warn} warnings"
        )
        return "\n".join(lines)


def check(root, paths, rules=None) -> CheckReport:
    """Run every active rule over the tree under ``paths`` (relative to
    ``root``). Returns the full report; callers gate on ``report.ok``."""
    ctx = load_tree(root, paths)
    rules = list(rules) if rules is not None else active_rules()
    known_ids = {r.id for r in rules} | {ENGINE_RULE_ID}
    findings: list[Finding] = []
    pragmas: list[Pragma] = []

    for sf in ctx.files.values():
        pragmas.extend(sf.pragmas)
        # pragma allowlist audit: unknown ids are findings, not no-ops
        for pr in sf.pragmas:
            for rid in pr.rules:
                if rid not in known_ids:
                    findings.append(Finding(
                        path=sf.relpath, line=pr.line, rule=ENGINE_RULE_ID,
                        severity="error",
                        message=f"pragma disables unknown rule {rid!r}",
                        hint="fix the rule id or drop the pragma",
                    ))
        if sf.parse_error is not None:
            findings.append(Finding(
                path=sf.relpath, line=1, rule=ENGINE_RULE_ID,
                severity="error",
                message=f"syntax error: {sf.parse_error}",
            ))

    for sf in ctx.files.values():
        if sf.tree is None:
            continue
        ctx.current = sf
        for rule in rules:
            for f in rule.visit(sf.tree, ctx):
                if not sf.suppressed(f.rule, f.line):
                    findings.append(f)
    ctx.current = None

    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return CheckReport(
        findings=findings, pragmas=pragmas,
        files_scanned=len(ctx.files), rules=rules,
    )
