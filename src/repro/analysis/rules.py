"""skylint rules: the repo's load-bearing invariants, machine-checked.

Each rule is a :class:`~repro.analysis.engine.Rule` registered with
``@register``. Rules key off root-relative paths (``ctx.under(...)``), so
the self-tests exercise them against synthetic mini-trees under
``tmp_path`` that mirror the real layout.

| id     | invariant                                                     |
|--------|---------------------------------------------------------------|
| SKY001 | determinism: seeded RNG only, no wall-clock in sim/planner    |
| SKY002 | cache safety: LP structures built only by milp.py factories   |
| SKY003 | frozen grids: Topology arrays mutate via with_tput only       |
| SKY004 | sim parity: the three engine entry points stay signature-     |
|        | pinned behind sim.simulate and dispatch every event class     |
| SKY005 | report protocol: *Report classes expose kind/to_dict/summary  |
| SKY006 | deprecated API: first-party code uses Planner.plan(PlanSpec)  |
| SKY007 | shared state: registered counters + lock-guarded workers only |
| SKY008 | format drift: 88-col lines, double quotes, no tabs            |
| SKY009 | counter discipline: obs.metrics instruments, no `global`      |
| SKY010 | deprecated sim API: first-party code uses sim.simulate        |
"""

from __future__ import annotations

import ast

from .engine import Context, Finding, Rule, register


def _dotted(node: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _tail(node: ast.AST) -> str | None:
    """The final attribute/name of a call target (``c`` for ``a.b.c``)."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


# --------------------------------------------------------------------- SKY001
# Everything the planner, simulators and calibration plane compute must be a
# pure function of (topology, spec, seed): seeds flow in as parameters and
# wall-clock never leaks into simulated time. time.monotonic()/perf_counter()
# stay legal — they measure the measurement, not the simulation.
_WALL_CLOCK = {
    "time.time",
    "datetime.now", "datetime.utcnow",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "date.today", "datetime.date.today",
}
# Seeded construction stays legal on both RNG front-ends.
_RANDOM_OK = {"Random", "SystemRandom"}
_NP_RANDOM_OK = {"default_rng", "Generator", "PCG64", "SeedSequence"}
_DETERMINISTIC_DIRS = (
    "src/repro/transfer", "src/repro/core", "src/repro/calibrate",
    "src/repro/ckpt",
)


@register
class DeterminismRule(Rule):
    id = "SKY001"
    severity = "error"
    description = (
        "seeded randomness only: no unseeded default_rng(), no bare "
        "random.*/np.random.* module calls; no wall-clock reads inside "
        "sim/planner/calibrate code"
    )
    hint = "take a seed parameter and draw from np.random.default_rng(seed)"

    def visit(self, tree: ast.Module, ctx: Context) -> list[Finding]:
        out = []
        in_sim_code = ctx.under(*_DETERMINISTIC_DIRS)
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = _dotted(node.func)
            tail = _tail(node.func)
            if tail == "default_rng" and not node.args and not node.keywords:
                out.append(ctx.finding(
                    self, node,
                    "unseeded default_rng() — entropy from the OS breaks "
                    "replayability",
                ))
            elif dotted is not None and dotted.startswith("random."):
                fn = dotted.split(".", 1)[1]
                if "." not in fn and fn not in _RANDOM_OK:
                    out.append(ctx.finding(
                        self, node,
                        f"bare {dotted}() draws from the global random "
                        "module state",
                        hint="use random.Random(seed) or a passed-in rng",
                    ))
            elif dotted is not None and (
                dotted.startswith("np.random.")
                or dotted.startswith("numpy.random.")
            ):
                fn = dotted.split("random.", 1)[1]
                if "." not in fn and fn not in _NP_RANDOM_OK:
                    out.append(ctx.finding(
                        self, node,
                        f"{dotted}() uses numpy's legacy global RNG state",
                    ))
            elif in_sim_code and dotted in _WALL_CLOCK:
                out.append(ctx.finding(
                    self, node,
                    f"wall-clock read {dotted}() inside deterministic "
                    "sim/planner code",
                    hint="pass timestamps in as parameters; "
                    "time.monotonic()/perf_counter() are fine for "
                    "measuring real elapsed time",
                ))
        return out


# --------------------------------------------------------------------- SKY002
@register
class CacheSafetyRule(Rule):
    id = "SKY002"
    severity = "error"
    description = (
        "LPStructure/MulticastLPStructure are built only by core/milp.py's "
        "factories — re-plans must ride cached structures via scale cuts"
    )
    hint = "call milp.structure(...) / milp.multicast_structure(...)"

    FACTORY_HOME = "src/repro/core/milp.py"
    CLASSES = {"LPStructure", "MulticastLPStructure"}

    def visit(self, tree: ast.Module, ctx: Context) -> list[Finding]:
        if ctx.current.relpath == self.FACTORY_HOME:
            return []
        out = []
        for node in ast.walk(tree):
            if isinstance(node, ast.Call) and _tail(node.func) in self.CLASSES:
                out.append(ctx.finding(
                    self, node,
                    f"direct {_tail(node.func)}(...) construction bypasses "
                    "the structure cache (N_STRUCT_BUILDS)",
                ))
        return out


# --------------------------------------------------------------------- SKY003
@register
class FrozenGridRule(Rule):
    id = "SKY003"
    severity = "error"
    description = (
        "no subscript assignment into Topology grid arrays — the grids "
        "are frozen; mutation routes through Topology.with_tput"
    )
    hint = "build a modified copy with top.with_tput(...)"

    GRIDS = {
        "tput", "price_egress", "price_vm", "limit_ingress",
        "limit_egress", "rtt_ms",
    }

    def _grid_store(self, target: ast.AST) -> ast.AST | None:
        if (
            isinstance(target, ast.Subscript)
            and isinstance(target.value, ast.Attribute)
            and target.value.attr in self.GRIDS
        ):
            return target
        return None

    def visit(self, tree: ast.Module, ctx: Context) -> list[Finding]:
        out = []
        for node in ast.walk(tree):
            targets = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                targets = [node.target]
            for t in targets:
                hit = self._grid_store(t)
                if hit is not None:
                    out.append(ctx.finding(
                        self, node,
                        f"in-place write to frozen grid "
                        f".{t.value.attr}[...]",
                    ))
        return out


# --------------------------------------------------------------------- SKY004
def _func(tree: ast.Module, name: str) -> ast.FunctionDef | None:
    for node in tree.body:
        if isinstance(node, ast.FunctionDef) and node.name == name:
            return node
    return None


def _signature(fn: ast.FunctionDef) -> list[tuple[str, str | None]]:
    """(name, default-source) pairs across every parameter kind."""
    a = fn.args
    sig: list[tuple[str, str | None]] = []
    pos = list(a.posonlyargs) + list(a.args)
    pos_defaults = [None] * (len(pos) - len(a.defaults)) + list(a.defaults)
    for arg, d in zip(pos, pos_defaults):
        sig.append((arg.arg, None if d is None else ast.unparse(d)))
    if a.vararg:
        sig.append(("*" + a.vararg.arg, None))
    elif a.kwonlyargs:
        sig.append(("*", None))
    for arg, d in zip(a.kwonlyargs, a.kw_defaults):
        sig.append((arg.arg, None if d is None else ast.unparse(d)))
    if a.kwarg:
        sig.append(("**" + a.kwarg.arg, None))
    return sig


def _dispatch_names(root: ast.AST) -> set[str]:
    """Names a sim dispatches on: the second argument of every
    ``isinstance(ev, ...)`` call under ``root`` (tuples contribute each
    member). ``root`` may be a whole module — since the jax engine splits
    event application out of its entry point into a host helper, parity is
    checked module-wide, not per-function."""
    names: set[str] = set()
    for node in ast.walk(root):
        if not (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name)
            and node.func.id == "isinstance"
            and len(node.args) == 2
        ):
            continue
        spec = node.args[1]
        members = spec.elts if isinstance(spec, ast.Tuple) else [spec]
        for m in members:
            t = _tail(m)
            if t is not None:
                names.add(t)
    return names


@register
class SimParityRule(Rule):
    id = "SKY004"
    severity = "error"
    description = (
        "the three sim engines (flowsim / flowsim_ref / flowsim_jax) keep "
        "signature-pinned entry points behind transfer.sim.simulate, and "
        "every event class in events.py is dispatched by all three"
    )
    hint = "mirror the change in the sibling engines and the dispatcher"

    ANCHOR = "src/repro/transfer/flowsim.py"
    REF = "src/repro/transfer/flowsim_ref.py"
    JAX = "src/repro/transfer/flowsim_jax.py"
    DISPATCHER = "src/repro/transfer/sim.py"
    EVENTS = "src/repro/transfer/events.py"

    def visit(self, tree: ast.Module, ctx: Context) -> list[Finding]:
        if ctx.current.relpath != self.ANCHOR:
            return []
        trees: dict[str, ast.Module] = {self.ANCHOR: tree}
        absent = []
        for rel in (self.REF, self.JAX, self.DISPATCHER):
            sf = ctx.file(rel)
            if sf is None or sf.tree is None:
                absent.append(rel)
            else:
                trees[rel] = sf.tree
        if absent:
            return [ctx.finding(
                self, 1, "cannot check sim parity: "
                f"{', '.join(absent)} not in the scanned tree",
                hint="scan src/ as a whole",
            )]
        ev_sf = ctx.file(self.EVENTS)
        out = []
        fast = _func(tree, "simulate_multi")
        ref = _func(trees[self.REF], "simulate_multi_reference")
        jx = _func(trees[self.JAX], "simulate_multi_jax")
        disp_fn = _func(trees[self.DISPATCHER], "simulate")
        lost = [name for name, fn in (
            ("simulate_multi", fast),
            ("simulate_multi_reference", ref),
            ("simulate_multi_jax", jx),
            ("sim.simulate", disp_fn),
        ) if fn is None]
        if lost:
            return [ctx.finding(self, 1, f"{', '.join(lost)} not found")]

        sig_fast, sig_ref = _signature(fast), _signature(ref)
        if sig_fast != sig_ref:
            out.append(ctx.finding(
                self, fast,
                "simulate_multi and simulate_multi_reference signatures "
                f"differ: {sig_fast} vs {sig_ref}",
            ))
        # The jax entry extends the pinned surface with private knobs only
        # (e.g. _rate_solver) — anything public belongs on SimConfig.
        sig_jax = _signature(jx)
        extras = sig_jax[len(sig_fast):]
        if sig_jax[:len(sig_fast)] != sig_fast or not all(
            name.lstrip("*").startswith("_") for name, _ in extras
        ):
            out.append(ctx.finding(
                self, fast,
                "simulate_multi_jax must extend the pinned legacy "
                f"signature with private knobs only: {sig_jax} vs "
                f"{sig_fast}",
            ))
        # The dispatcher is the legacy surface plus a trailing engine knob.
        sig_disp = _signature(disp_fn)
        if sig_disp[:-1] != sig_fast or sig_disp[-1] != (
            "engine", "'soa'",
        ):
            out.append(ctx.finding(
                self, fast,
                "sim.simulate must take the pinned legacy signature plus "
                f"a trailing engine=\"soa\": {sig_disp} vs {sig_fast}",
            ))

        # Expand RATE_EVENTS through events.py so dispatching on the tuple
        # covers its members.
        groups: dict[str, set[str]] = {}
        universe: set[str] = set()
        ev_classes: set[str] = set()
        if ev_sf is not None and ev_sf.tree is not None:
            for node in ev_sf.tree.body:
                if isinstance(node, ast.Assign) and isinstance(
                    node.value, ast.Tuple
                ):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            groups[t.id] = {
                                _tail(e) for e in node.value.elts
                                if _tail(e) is not None
                            }
                if isinstance(node, ast.ClassDef):
                    ev_classes.add(node.name)
                    fields = {
                        s.target.id for s in node.body
                        if isinstance(s, ast.AnnAssign)
                        and isinstance(s.target, ast.Name)
                    }
                    # event classes are the frozen dataclasses stamped with
                    # an event time; result/job records carry no t_s
                    if "t_s" in fields:
                        universe.add(node.name)

        def expand(names: set[str]) -> set[str]:
            flat = set()
            for n in names:
                flat |= groups.get(n, {n})
            return flat

        engines = (
            ("flowsim", self.ANCHOR),
            ("flowsim_ref", self.REF),
            ("flowsim_jax", self.JAX),
        )
        disp = {
            side: expand(_dispatch_names(trees[rel]))
            for side, rel in engines
        }
        for side, _ in engines:
            if "int" not in disp[side]:
                out.append(ctx.finding(
                    self, fast,
                    f"{side} event loop has no job-arrival (int) dispatch "
                    "branch",
                ))
        for ev in sorted(universe):
            for side, _ in engines:
                if ev not in disp[side]:
                    out.append(ctx.finding(
                        self, fast,
                        f"event class {ev} from events.py has no dispatch "
                        f"branch in {side}",
                    ))
        # An events.py class outside the t_s universe dispatched by one
        # engine must be dispatched by all (isinstance checks on foreign
        # classes like MulticastPlan are not parity-relevant).
        union = set().union(*disp.values())
        for ev in sorted((union & ev_classes) - universe):
            behind = [s for s, _ in engines if ev not in disp[s]]
            if behind:
                out.append(ctx.finding(
                    self, fast,
                    f"{ev} is dispatched by some engines but not by "
                    f"{', '.join(behind)}",
                ))
        return out


# --------------------------------------------------------------------- SKY005
@register
class ReportProtocolRule(Rule):
    id = "SKY005"
    severity = "error"
    description = (
        "every *Report class in the transfer plane exposes the report "
        "protocol: kind, to_dict, summary"
    )
    hint = (
        "subclass transfer.reports.Report, set kind and implement "
        "_payload()/_summary_keys"
    )

    SCOPE = (
        "src/repro/transfer", "src/repro/core", "src/repro/calibrate",
        "src/repro/ckpt",
    )
    ROOT = "Report"  # the mixin itself is exempt

    def visit(self, tree: ast.Module, ctx: Context) -> list[Finding]:
        if not ctx.under(*self.SCOPE):
            return []
        out = []
        for node in ast.walk(tree):
            if not isinstance(node, ast.ClassDef):
                continue
            if not node.name.endswith("Report") or node.name == self.ROOT:
                continue
            full = ctx.mro_names(node.name)
            own = ctx.mro_names(node.name, exclude=(self.ROOT,))
            missing = [m for m in ("to_dict", "summary") if m not in full]
            # the mixin's to_dict/summary only produce real output when the
            # subclass chain supplies kind and _payload itself
            if "kind" not in own:
                missing.append("kind")
            if "to_dict" not in own and "_payload" not in own:
                missing.append("_payload")
            if missing:
                out.append(ctx.finding(
                    self, node,
                    f"{node.name} does not satisfy the report protocol "
                    f"(missing: {', '.join(sorted(set(missing)))})",
                ))
        return out


# --------------------------------------------------------------------- SKY006
@register
class DeprecatedApiRule(Rule):
    id = "SKY006"
    severity = "error"
    description = (
        "first-party code calls Planner.plan(PlanSpec(...)), not the "
        "deprecated plan_* shims (tests exempt: they pin shim equality)"
    )
    hint = "planner.plan(PlanSpec(objective=..., src=..., dst=...))"

    SHIMS = {
        "max_throughput", "max_multicast_throughput",
        "plan_cost_min", "plan_tput_max",
        "plan_multicast_cost_min", "plan_multicast_tput_max",
        "pareto_frontier", "pareto_frontier_fast",
    }
    SCOPE = ("src", "benchmarks", "examples")
    SHIM_HOME = "src/repro/core/planner.py"  # the shims' own definitions

    def visit(self, tree: ast.Module, ctx: Context) -> list[Finding]:
        if not ctx.under(*self.SCOPE):
            return []
        if ctx.current.relpath == self.SHIM_HOME:
            return []
        out = []
        for node in ast.walk(tree):
            if (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in self.SHIMS
            ):
                out.append(ctx.finding(
                    self, node,
                    f".{node.func.attr}(...) is a deprecated shim",
                ))
        return out


# --------------------------------------------------------------------- SKY007
def _bound_names(fn: ast.FunctionDef) -> set[str]:
    """Names the function binds locally (params + any store target)."""
    a = fn.args
    bound = {p.arg for p in (
        list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)
    )}
    if a.vararg:
        bound.add(a.vararg.arg)
    if a.kwarg:
        bound.add(a.kwarg.arg)
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            bound.add(node.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)) and node is not fn:
            bound.add(node.name)
        elif isinstance(node, (ast.Global, ast.Nonlocal)):
            bound -= set(node.names)
    return bound


class _LockWalk(ast.NodeVisitor):
    """Find subscript stores on free names outside with-lock blocks."""

    def __init__(self, free: set[str]):
        self.free = free
        self.in_lock = 0
        self.hits: list[ast.AST] = []

    def visit_With(self, node: ast.With):
        locked = any(
            "lock" in ast.unparse(item.context_expr).lower()
            for item in node.items
        )
        if locked:
            self.in_lock += 1
        self.generic_visit(node)
        if locked:
            self.in_lock -= 1

    def _check(self, target: ast.AST, node: ast.AST):
        if self.in_lock:
            return
        if isinstance(target, ast.Subscript):
            base = target.value
            while isinstance(base, ast.Subscript):
                base = base.value
            if isinstance(base, ast.Name) and base.id in self.free:
                self.hits.append(node)

    def visit_Assign(self, node: ast.Assign):
        for t in node.targets:
            self._check(t, node)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign):
        self._check(node.target, node)
        self.generic_visit(node)


@register
class SharedStateRule(Rule):
    id = "SKY007"
    severity = "error"
    description = (
        "module-level mutable state in transfer//calibrate/ must live in "
        "the obs.metrics registry; gateway thread workers write shared "
        "containers only under the lock"
    )
    hint = "register an obs.metrics instrument, or move the write under "\
           "`with lock:`"

    MODULE_SCOPE = ("src/repro/transfer", "src/repro/calibrate")
    # The one sanctioned module-level mutable: the API surface. Counters
    # moved into the obs.metrics registry (SKY009 polices the rest).
    REGISTERED = {"__all__"}
    MUTABLE_CALLS = {
        "dict", "list", "set", "defaultdict", "deque", "Counter",
        "OrderedDict",
    }

    def visit(self, tree: ast.Module, ctx: Context) -> list[Finding]:
        out = []
        if ctx.under(*self.MODULE_SCOPE):
            out += self._module_state(tree, ctx)
        if ctx.current.relpath.startswith("src/repro/transfer/gateway"):
            out += self._worker_closures(tree, ctx)
        return out

    def _is_mutable(self, value: ast.AST) -> bool:
        if isinstance(value, (ast.Dict, ast.List, ast.Set, ast.ListComp,
                              ast.SetComp, ast.DictComp)):
            return True
        return (
            isinstance(value, ast.Call)
            and _tail(value.func) in self.MUTABLE_CALLS
        )

    def _module_state(self, tree: ast.Module, ctx: Context) -> list[Finding]:
        out = []
        for node in tree.body:
            if isinstance(node, ast.Assign):
                targets, value = node.targets, node.value
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                targets, value = [node.target], node.value
            else:
                continue
            if not self._is_mutable(value):
                continue
            for t in targets:
                if isinstance(t, ast.Name) and t.id not in self.REGISTERED:
                    out.append(ctx.finding(
                        self, node,
                        f"module-level mutable {t.id!r} is unregistered "
                        "shared state",
                    ))
        return out

    def _worker_closures(self, tree: ast.Module, ctx: Context) -> list:
        out = []
        for top in tree.body:
            if not isinstance(top, ast.FunctionDef):
                continue
            # which nested functions run on threads?
            targets: set[str] = set()
            for node in ast.walk(top):
                if not (isinstance(node, ast.Call)
                        and _tail(node.func) == "Thread"):
                    continue
                for kw in node.keywords:
                    if kw.arg == "target" and isinstance(kw.value, ast.Name):
                        targets.add(kw.value.id)
            if not targets:
                continue
            for node in ast.walk(top):
                if not (isinstance(node, ast.FunctionDef)
                        and node.name in targets and node is not top):
                    continue
                free = _bound_names(top) - _bound_names(node)
                walk = _LockWalk(free)
                for st in node.body:
                    walk.visit(st)
                for hit in walk.hits:
                    out.append(ctx.finding(
                        self, hit,
                        f"thread worker {node.name!r} writes a shared "
                        "container outside the lock",
                    ))
        return out


# --------------------------------------------------------------------- SKY008
@register
class FormatDriftRule(Rule):
    id = "SKY008"
    severity = "warning"
    description = (
        "format drift: lines stay within 88 columns, strings are "
        "double-quoted, indentation is spaces (stand-in for the absent "
        "ruff-format binary)"
    )
    hint = "wrap the line / flip the quotes, matching `ruff format` output"

    MAX_COLS = 88

    def visit(self, tree: ast.Module, ctx: Context) -> list[Finding]:
        import io
        import tokenize

        out = []
        sf = ctx.current
        for i, line in enumerate(sf.lines, start=1):
            if len(line) > self.MAX_COLS:
                out.append(ctx.finding(
                    self, i, f"line is {len(line)} columns (max "
                    f"{self.MAX_COLS})",
                ))
            body = line[:len(line) - len(line.lstrip())]
            if "\t" in body:
                out.append(ctx.finding(self, i, "tab indentation"))
        try:
            toks = tokenize.generate_tokens(io.StringIO(sf.source).readline)
            for tok in toks:
                if tok.type != tokenize.STRING:
                    continue
                text = tok.string
                prefix_len = len(text) - len(text.lstrip("rbufRBUF"))
                prefix = text[:prefix_len].lower()
                body = text[prefix_len:]
                if "r" in prefix and '"' in text:
                    continue  # raw strings keep their author's quoting
                if body.startswith("'") and '"' not in body:
                    out.append(ctx.finding(
                        self, tok.start[0],
                        "single-quoted string (double quotes are the "
                        "repo style)",
                    ))
        except (tokenize.TokenError, IndentationError, SyntaxError):
            pass
        return out


# --------------------------------------------------------------------- SKY009
@register
class CounterDisciplineRule(Rule):
    id = "SKY009"
    severity = "error"
    description = (
        "counters and gauges in transfer//calibrate//core/ go through "
        "the obs.metrics registry: no `global` rebinding of module "
        "state, no ALL-CAPS zero-seeded module counters"
    )
    hint = "hold a REGISTRY.counter(...)/gauge(...) from repro.obs.metrics"

    SCOPE = ("src/repro/transfer", "src/repro/calibrate", "src/repro/core")

    def visit(self, tree: ast.Module, ctx: Context) -> list[Finding]:
        out: list[Finding] = []
        if not ctx.under(*self.SCOPE):
            return out
        for node in ast.walk(tree):
            if isinstance(node, ast.Global):
                out.append(ctx.finding(
                    self, node,
                    "global statement rebinds module state "
                    f"({', '.join(node.names)}) — ad-hoc process "
                    "counters belong in the obs.metrics registry",
                ))
        for node in tree.body:
            if isinstance(node, ast.Assign):
                targets, value = node.targets, node.value
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                targets, value = [node.target], node.value
            else:
                continue
            # an ALL-CAPS name seeded with a literal zero is the ad-hoc
            # counter idiom (`N_FOO = 0` bumped from function bodies) —
            # nonzero literals are genuine constants and stay legal
            if not (
                isinstance(value, ast.Constant)
                and type(value.value) in (int, float)
                and value.value == 0
            ):
                continue
            for t in targets:
                if (
                    isinstance(t, ast.Name)
                    and len(t.id) > 1
                    and t.id.isupper()
                ):
                    out.append(ctx.finding(
                        self, node,
                        f"zero-seeded module counter {t.id!r} — register "
                        "it as an obs.metrics instrument",
                    ))
        return out


# --------------------------------------------------------------------- SKY010
@register
class DeprecatedSimEntryRule(Rule):
    id = "SKY010"
    severity = "error"
    description = (
        "first-party code simulates through transfer.sim.simulate with an "
        "engine selector, not the per-engine entry points (tests exempt: "
        "they pin shim equality)"
    )
    hint = 'transfer.sim.simulate(jobs, faults, engine="soa"|"ref"|"jax")'

    ENTRIES = {
        "simulate_multi", "simulate_multi_reference", "simulate_multi_jax",
        "_simulate_multi_impl", "_simulate_multi_reference_impl",
    }
    SCOPE = ("src", "benchmarks", "examples")
    # the engines' own homes and the dispatcher that fronts them
    HOMES = {
        "src/repro/transfer/flowsim.py",
        "src/repro/transfer/flowsim_ref.py",
        "src/repro/transfer/flowsim_jax.py",
        "src/repro/transfer/sim.py",
    }

    def visit(self, tree: ast.Module, ctx: Context) -> list[Finding]:
        if not ctx.under(*self.SCOPE):
            return []
        if ctx.current.relpath in self.HOMES:
            return []
        out = []
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            t = _tail(node.func)
            if t in self.ENTRIES:
                out.append(ctx.finding(
                    self, node,
                    f"{t}(...) bypasses the sim-engine dispatcher",
                ))
        return out
