"""Calibration plane (ISSUE 4): online bandwidth probing, belief topology,
and uncertainty-aware re-planning.

The subsystem separates the TRUE topology (what the data plane delivers —
``drift.DriftModel``) from the BELIEVED topology (what the planner sees —
``belief.BeliefGrid``), spends an explicit probe budget according to a
pluggable scheduling policy (``policies``: greedy VoI, round-robin,
ε-greedy, Bayesian EVOI; executed by ``calibrator.Calibrator``), and
closes the measure→believe→plan→observe loop around the transfer service
(``service.CalibratedTransferService`` — including epoch rolls that
re-pin the planner's grid when the belief rises past it)."""

from .belief import (  # noqa: F401
    BeliefGrid,
    BeliefSnapshot,
    capacity_sample_from_rates,
)
from .calibrator import (  # noqa: F401
    Calibrator,
    ProbeBudget,
    ProbeRecord,
    ProbeRound,
)
from .drift import DriftModel, Incident  # noqa: F401
from .policies import (  # noqa: F401
    POLICY_NAMES,
    BayesianEVOIPolicy,
    EpsilonGreedyPolicy,
    GreedyVoIPolicy,
    PolicyContext,
    ProbePolicy,
    RoundRobinPolicy,
    make_policy,
)
from .service import (  # noqa: F401
    CalibratedServiceReport,
    CalibratedTransferService,
    DriftEvent,
    EpochRoll,
)

__all__ = [
    "POLICY_NAMES",
    "BayesianEVOIPolicy",
    "BeliefGrid",
    "BeliefSnapshot",
    "CalibratedServiceReport",
    "CalibratedTransferService",
    "Calibrator",
    "DriftEvent",
    "DriftModel",
    "EpochRoll",
    "EpsilonGreedyPolicy",
    "GreedyVoIPolicy",
    "Incident",
    "PolicyContext",
    "ProbeBudget",
    "ProbePolicy",
    "ProbeRecord",
    "ProbeRound",
    "RoundRobinPolicy",
    "capacity_sample_from_rates",
    "make_policy",
]
