"""BeliefGrid: per-link throughput estimates with confidence.

The planner should never see the raw profile grid again — it sees a
*belief*: per ordered region pair, a weighted-mean throughput estimate
plus an effective observation count and variance. The belief starts at
the embedded profile grid with a weak prior (the stale measurement IS
evidence, just old evidence) and tightens as evidence arrives:

  * **active probes** (calibrate.Calibrator) — iperf-style measurements of
    a link's current capacity; high weight;
  * **passive telemetry** (flowsim / gateway per-link delivered rates) —
    free but allocation-shaped; low weight, fed through
    ``capacity_sample_from_rates`` which rescales an observed/expected
    ratio back into grid space.

Updates are weighted Welford: numerically stable streaming mean/variance
where a weight-w observation counts as w unit observations. The belief
exposes the two grids the planner consumes — the mean (``believed_
topology``) and the z-lower-confidence-bound scale vector (``scale_
grid``) that uncertainty-aware plans ride as cuts on cached LP structures.

The prior spread is per-link: by default it comes from the per-provider
drift table (``core.profiles.prior_rel_sigma_grid`` — AWS routes hold
steady, GCP routes jitter, inter-cloud peering drifts hardest), so an
intra-AWS link starts with a tighter confidence band than a GCP→Azure
link at the same grid value. Pass a scalar to restore one global knob,
or a [V, V] array for full control.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.profiles import prior_rel_sigma_grid
from repro.core.topology import Topology

_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class BeliefSnapshot:
    """An immutable, epoch-versioned read view of a ``BeliefGrid``.

    The fleet control plane shares ONE live belief across many tenant
    services; a tenant planning a cohort must not see the grid move under
    it mid-decision (another tenant's probe landing between its scale-cut
    computation and its admission would make the two inconsistent).
    ``BeliefGrid.snapshot()`` copies the sufficient statistics and stamps
    them with ``version`` (bumped on every fold/reset) and ``epoch`` —
    readers check ``grid.version != snap.version`` to know their view is
    stale, writers never block."""

    base: Topology
    mean: np.ndarray
    count: np.ndarray
    m2: np.ndarray
    min_tput: float
    version: int
    epoch: int
    taken_t: float | None = None

    def stderr(self) -> np.ndarray:
        with np.errstate(divide="ignore", invalid="ignore"):
            var = np.where(self.count > 0,
                           self.m2 / np.maximum(self.count, _EPS), 0.0)
        return np.sqrt(np.maximum(var, 0.0)) / np.sqrt(
            np.maximum(self.count, 1.0)
        )

    def lower_bound(self, z: float = 1.5) -> np.ndarray:
        lb = self.mean - float(z) * self.stderr()
        return np.where(self.mean > 0, np.maximum(lb, self.min_tput), 0.0)

    def scale_grid(
        self, epoch_top: Topology, z: float = 1.5, floor: float = 0.02
    ) -> np.ndarray:
        ref = np.asarray(epoch_top.tput, dtype=float)
        lb = self.lower_bound(z)
        with np.errstate(divide="ignore", invalid="ignore"):
            phi = np.where(ref > 0, lb / np.maximum(ref, _EPS), 1.0)
        return np.clip(phi, float(floor), 1.0)

    def believed_topology(self) -> Topology:
        return self.base.with_tput(self.mean)


class BeliefGrid:
    def __init__(
        self,
        base: Topology,
        *,
        prior_count: float = 4.0,
        prior_rel_sigma: float | np.ndarray | None = None,
        min_tput: float = 1e-3,
    ):
        self.base = base
        v = base.num_regions
        self.mean = np.array(base.tput, dtype=float, copy=True)
        mask = self.mean > 0
        self.count = np.where(mask, float(prior_count), 0.0)
        # per-link prior spread: provider-pair table by default, scalar or
        # explicit [V, V] override accepted
        if prior_rel_sigma is None:
            sig = prior_rel_sigma_grid(base)
        else:
            sig = np.asarray(prior_rel_sigma, dtype=float)
            if sig.ndim == 0:
                sig = np.full((v, v), float(sig))
            elif sig.shape != (v, v):
                raise ValueError(
                    f"prior_rel_sigma must be scalar or ({v}, {v}), "
                    f"got shape {sig.shape}"
                )
        self.prior_rel_sigma = sig
        # m2 = sum of weighted squared deviations: prior variance encodes
        # "the stale grid is probably within ~prior_rel_sigma of reality"
        self.m2 = np.where(
            mask, (sig * self.mean) ** 2 * prior_count, 0.0
        )
        self.min_tput = float(min_tput)
        self.observations = 0
        # concurrency story for shared (fleet) beliefs: version bumps on
        # every mutation, epoch on every planner re-anchoring (the
        # calibrated service's epoch roll) — snapshot() readers compare
        # both to detect staleness without ever blocking a writer
        self.version = 0
        self.epoch = 0
        # when each link was last measured: the stale profile counts as one
        # very old measurement, so probe targeting (staleness-aware scores)
        # sweeps every candidate before re-visiting
        self.last_obs_t = np.full((v, v), -np.inf)
        assert self.mean.shape == (v, v)

    # ---------------------------------------------------------------- updates
    def observe(
        self, src: int, dst: int, gbps: float, weight: float = 1.0,
        t_s: float | None = None,
    ):
        """Fold one throughput observation of link (src, dst) into the
        belief (weighted Welford; ``weight`` = equivalent unit samples)."""
        if src == dst:
            raise ValueError("no self-links")
        g = max(float(gbps), self.min_tput)
        w = float(weight)
        c1 = self.count[src, dst] + w
        delta = g - self.mean[src, dst]
        self.mean[src, dst] += w * delta / c1
        self.m2[src, dst] += w * delta * (g - self.mean[src, dst])
        self.count[src, dst] = c1
        if t_s is not None:
            self.last_obs_t[src, dst] = float(t_s)
        self.observations += 1
        self.version += 1

    def reset_link(
        self,
        src: int,
        dst: int,
        gbps: float,
        count: float = 4.0,
        rel_sigma: float | None = None,
        t_s: float | None = None,
    ):
        """Regime change on one link: discard its history and re-seed the
        belief at ``gbps``. A step-change incident draws from a NEW
        distribution — Welford-averaging it against the old regime would
        let the stale prior drag the mean for many rounds while the plan
        keeps trusting a collapsed link. The re-seeded spread defaults to
        the link's per-provider drift prior."""
        if src == dst:
            raise ValueError("no self-links")
        g = max(float(gbps), self.min_tput)
        rs = (
            float(self.prior_rel_sigma[src, dst])
            if rel_sigma is None
            else float(rel_sigma)
        )
        self.mean[src, dst] = g
        self.count[src, dst] = float(count)
        self.m2[src, dst] = (rs * g) ** 2 * float(count)
        if t_s is not None:
            self.last_obs_t[src, dst] = float(t_s)
        self.observations += 1
        self.version += 1

    def observe_adaptive(
        self,
        src: int,
        dst: int,
        gbps: float,
        weight: float = 1.0,
        z_reset: float = 3.0,
        t_s: float | None = None,
    ) -> bool:
        """Observe with change-point handling: a sample outside the
        z-confidence band (either direction) resets the link's belief to
        the new regime; an in-band sample folds in normally. Returns
        whether a reset happened."""
        g = max(float(gbps), self.min_tput)
        band = float(z_reset) * max(
            self.stderr()[src, dst], 0.02 * self.mean[src, dst]
        )
        if abs(g - self.mean[src, dst]) > band:
            self.reset_link(src, dst, g, count=max(float(weight), 1.0),
                            t_s=t_s)
            return True
        self.observe(src, dst, g, weight, t_s=t_s)
        return False

    def observe_link_rates(
        self,
        rates: dict,
        weight: float = 1.0,
        t_s: float | None = None,
        one_sided: bool = True,
    ) -> int:
        """Fold a {(src, dst): Gbps} mapping into the belief — the
        gateway-side passive feed (``GatewayReport.link_gbps()``), with
        the same change-point handling as simulator telemetry.

        Gateway windows span first-pickup to last-completion on each hop,
        so a hop throttled by an UPSTREAM bottleneck reads far below its
        own capacity. The default ``one_sided=True`` therefore treats a
        rate as a lower-bound observation: samples below the current mean
        are dropped (capacity >= observed is the only safe inference from
        a possibly-idle window); callers with saturation evidence (e.g. a
        single-hop path, or the sim feed's expectation-checked samples)
        pass ``one_sided=False``. Returns how many samples were folded."""
        n = 0
        for (a, b), g in rates.items():
            if a == b:
                continue
            if one_sided and float(g) < self.mean[a, b]:
                continue
            self.observe_adaptive(int(a), int(b), float(g),
                                  weight=weight, t_s=t_s)
            n += 1
        return n

    # ------------------------------------------------------------ uncertainty
    def sigma(self) -> np.ndarray:
        """Per-link sample standard deviation."""
        with np.errstate(divide="ignore", invalid="ignore"):
            var = np.where(self.count > 0, self.m2 / np.maximum(
                self.count, _EPS), 0.0)
        return np.sqrt(np.maximum(var, 0.0))

    def stderr(self) -> np.ndarray:
        """Standard error of the mean — shrinks with evidence."""
        return self.sigma() / np.sqrt(np.maximum(self.count, 1.0))

    def rel_uncertainty(self) -> np.ndarray:
        """stderr / mean — the probe-targeting signal (0 on dead links)."""
        with np.errstate(divide="ignore", invalid="ignore"):
            r = np.where(self.mean > 0, self.stderr() /
                         np.maximum(self.mean, _EPS), 0.0)
        return r

    def lower_bound(self, z: float = 1.5) -> np.ndarray:
        """mean - z * stderr, floored at ``min_tput`` on live links."""
        lb = self.mean - float(z) * self.stderr()
        return np.where(self.mean > 0, np.maximum(lb, self.min_tput), 0.0)

    def out_of_bounds(
        self, src: int, dst: int, observed_gbps: float, z: float = 3.0
    ) -> bool:
        """Drift detector primitive: is this capacity sample below the
        belief's z-confidence band on the link?"""
        band = float(z) * max(self.stderr()[src, dst],
                              0.02 * self.mean[src, dst])
        return float(observed_gbps) < self.mean[src, dst] - band

    # ------------------------------------------------------- planner-facing
    def snapshot(self, t_s: float | None = None) -> BeliefSnapshot:
        """Epoch-versioned immutable read view — what a fleet tenant plans
        against while other tenants keep folding probes into the live
        grid. Copies the sufficient statistics (O(V^2), cheap next to one
        LP solve); see ``BeliefSnapshot``."""
        return BeliefSnapshot(
            base=self.base,
            mean=self.mean.copy(),
            count=self.count.copy(),
            m2=self.m2.copy(),
            min_tput=self.min_tput,
            version=self.version,
            epoch=self.epoch,
            taken_t=t_s,
        )

    def roll_epoch(self) -> int:
        """Mark a planner re-anchoring (the calibrated service's epoch
        roll): bumps ``epoch`` so shared-belief readers can tell a mere
        mean drift from a re-based planning grid."""
        self.epoch += 1
        self.version += 1
        return self.epoch

    def believed_topology(self) -> Topology:
        """A fresh Topology carrying the belief mean — the planner's epoch
        grid (copy-on-write; caches start clean on the new instance)."""
        return self.base.with_tput(self.mean)

    def scale_grid(
        self, epoch_top: Topology, z: float = 1.5, floor: float = 0.02
    ) -> np.ndarray:
        """[V,V] per-link scale phi = lower_bound(z) / epoch grid, clipped
        to [floor, 1]. The planner turns phi < 1 entries into tightened 4b
        rows on its CACHED structures (milp.*.scale_cuts) — uncertainty-
        aware planning with zero re-assembly. phi is clipped at 1 because
        a loosening row never binds; a belief that *improved* past the
        epoch grid is exploited at the next epoch roll, not mid-epoch."""
        ref = np.asarray(epoch_top.tput, dtype=float)
        lb = self.lower_bound(z)
        with np.errstate(divide="ignore", invalid="ignore"):
            phi = np.where(ref > 0, lb / np.maximum(ref, _EPS), 1.0)
        return np.clip(phi, float(floor), 1.0)

    # ------------------------------------------------------------- diagnostics
    def error_vs(
        self, true_tput: np.ndarray, mask: np.ndarray | None = None
    ) -> float:
        """Mean relative belief error vs a true grid, over ``mask`` (default:
        every live link). The calibration loop's convergence metric."""
        true_tput = np.asarray(true_tput, dtype=float)
        m = (self.mean > 0) & (true_tput > 0)
        if mask is not None:
            m &= np.asarray(mask, dtype=bool)
        if not m.any():
            return 0.0
        rel = np.abs(self.mean[m] - true_tput[m]) / true_tput[m]
        return float(rel.mean())


def capacity_sample_from_rates(
    observed_gbps: float,
    expected_gbps: float,
    *,
    n_vms: float = 1.0,
    link_capacity_scale: float | None = 2.0,
    saturation_ratio: float = 0.9,
) -> float | None:
    """Convert a passive (observed, expected) link-rate pair into a grid-
    space capacity sample — or None when the telemetry carries no
    capacity information.

    Passive evidence is ONE-SIDED: a link that delivered what the plan
    asked (``observed >= saturation_ratio * expected``) only proves
    capacity >= observed — inferring "the grid entry is fine" from it
    would reset a freshly-learned degradation back to the stale prior.
    Only an UNDER-delivering link was capacity-bound, and then the grid
    entry (single-VM-pair rate) is the observed aggregate divided by the
    effective parallelism: ``min(n_vms, link_capacity_scale)`` — the VM
    fan-out the data plane multiplies the grid rate by, ceilinged by the
    shared-interconnect capacity factor."""
    if expected_gbps <= 1e-9:
        return None
    if observed_gbps >= saturation_ratio * expected_gbps:
        return None  # link kept up with the plan: no capacity info
    par = max(float(n_vms), 1.0)
    if link_capacity_scale is not None:
        par = min(par, float(link_capacity_scale))
    return float(max(observed_gbps, 1e-6) / par)
