"""Calibrator: spend a probe budget where planner value-of-information is
highest.

An active probe is an iperf-style transfer of ``probe_gb`` over one
directed region pair — it costs real money (egress on the probed link
plus VM-seconds at both ends) and real time, so the paper's
"$4000 of iperf3" cannot simply be re-run every hour. The Calibrator
rations an explicit per-round budget (dollars AND seconds) across the
links the planner actually cares about:

  * candidate links are the edges of the planner's pruned candidate
    subgraphs for the active (src, dst[s]) contexts — the only links a
    plan could ever use;
  * each candidate is scored ``relative belief uncertainty x plan
    relevance``: links carrying flow in a current plan (on or near the
    Pareto frontier the planner picked from) outrank idle alternates,
    scaled by how much capacity the link could contribute;
  * probes are batched per round (they run concurrently, like the paper's
    parallel iperf grid): the round's wall time is the slowest probe, the
    round's cost is the sum.

Measurements sample the TRUE grid (a ``DriftModel`` lookup at the round's
time) with optional seeded measurement noise, and fold into the belief at
``probe_weight`` — several equivalent unit observations, since an active
probe saturates the link rather than inferring from allocation-shaped
telemetry.

WHICH candidates bid for the budget first is a pluggable
:mod:`~repro.calibrate.policies` decision (greedy VoI, round-robin,
ε-greedy, Bayesian EVOI); the Calibrator owns budget enforcement and
measurement execution, identical across policies.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.topology import GBIT_PER_GB
from repro.obs.metrics import REGISTRY
from repro.obs.trace import get_tracer

from .belief import BeliefGrid
from .policies import (
    GreedyVoIPolicy,
    PolicyContext,
    ProbeBudget,
    ProbePolicy,
    make_policy,
)

__all__ = ["Calibrator", "ProbeBudget", "ProbeRecord", "ProbeRound"]


@dataclasses.dataclass(frozen=True)
class ProbeRecord:
    t_s: float
    src: int
    dst: int
    measured_gbps: float
    cost_usd: float
    duration_s: float


@dataclasses.dataclass
class ProbeRound:
    t_s: float
    records: list[ProbeRecord]
    cost_usd: float
    duration_s: float  # probes run concurrently: the slowest one
    belief_error: float | None = None  # vs-true error AFTER the round
    policy: str = ""  # scheduling policy that ranked this round
    deduped: int = 0  # candidates skipped as freshly measured (fleet
    # cross-tenant amortization: another tenant's probe already landed
    # inside the dedup window)

    @property
    def n_probes(self) -> int:
        return len(self.records)


class Calibrator:
    def __init__(
        self,
        belief: BeliefGrid,
        *,
        budget: ProbeBudget | None = None,
        probe_gb: float = 0.5,
        probe_weight: float = 4.0,
        noise_sigma: float = 0.0,
        on_plan_bonus: float = 2.0,
        staleness_halflife_s: float = 30.0,
        seed: int = 0,
        policy: ProbePolicy | str | None = None,
        dedup_window_s: float = 0.0,
    ):
        self.belief = belief
        self.budget = budget or ProbeBudget()
        self.probe_gb = float(probe_gb)
        self.probe_weight = float(probe_weight)
        self.noise_sigma = float(noise_sigma)
        self.on_plan_bonus = float(on_plan_bonus)
        self.staleness_halflife_s = float(staleness_halflife_s)
        # cross-tenant probe dedup (the fleet's shared profiler): a
        # candidate whose belief entry was measured within the window —
        # by ANY tenant sharing this calibrator — is skipped this round,
        # amortizing probe $ across the fleet. 0 disables (per-service
        # calibrators keep the historical behavior, including same-
        # timestamp targeted rounds).
        self.dedup_window_s = float(dedup_window_s)
        # when each link was last ACTIVELY probed (passive telemetry does
        # not count: a throttled link looks freshly-observed every segment,
        # and deduping — or staleness-ranking — against that would skip
        # exactly the saturating probe that could expose the drift). Kept
        # both as a dict (dedup lookups) and as a grid handed to policies
        # so their staleness terms age links by probe time, not by the
        # last allocation-shaped telemetry sample.
        self.last_probe_t: dict[tuple[int, int], float] = {}
        self._probe_t_grid = np.full_like(
            np.asarray(belief.mean, dtype=float), -np.inf
        )
        self._rng = np.random.default_rng(seed)
        # the greedy scorer stays available (score_links) even when another
        # policy schedules the rounds — diagnostics and ε-greedy reuse it
        self._greedy = GreedyVoIPolicy(
            on_plan_bonus=self.on_plan_bonus,
            staleness_halflife_s=self.staleness_halflife_s,
        )
        if policy is None:
            self.policy: ProbePolicy = self._greedy
        elif isinstance(policy, str):
            # string specs inherit this Calibrator's scoring knobs, so
            # policy="greedy" is the default policy, not a differently
            # tuned one
            self.policy = make_policy(
                policy, seed=seed,
                on_plan_bonus=self.on_plan_bonus,
                staleness_halflife_s=self.staleness_halflife_s,
            )
        else:
            self.policy = policy
        self.rounds: list[ProbeRound] = []

    # ------------------------------------------------------------- selection
    def candidate_links(self, planner, contexts) -> list[tuple[int, int]]:
        """Edges of the planner's pruned candidate subgraphs for the given
        contexts (``(src, dst)`` or ``(src, [dsts])`` key tuples), mapped to
        full-topology indices, deduplicated in first-seen order."""
        seen: set[tuple[int, int]] = set()
        out: list[tuple[int, int]] = []
        for ctx in contexts:
            src, dst = ctx
            if isinstance(dst, (list, tuple)):
                sub, s, ds, keep = planner._prune_mc(src, list(dst))
                edges = sub.edge_list(s, None)
            else:
                sub, s, t, keep = planner._prune(src, dst)
                edges = sub.edge_list(s, t)
            for a, b in edges:
                e = (keep[a], keep[b])
                if e not in seen:
                    seen.add(e)
                    out.append(e)
        return out

    def score_links(self, links, plans=(), t_s: float = 0.0) -> np.ndarray:
        """Greedy value-of-information score per candidate link — the
        default policy's scorer (see ``policies.greedy_voi_scores``),
        kept as a method for diagnostics regardless of which policy is
        scheduling the rounds."""
        ctx = PolicyContext(
            belief=self.belief, t_s=float(t_s), budget=self.budget,
            plans=tuple(plans), last_probe_t=self._probe_t_grid,
        )
        return self._greedy.score(list(links), ctx)

    # -------------------------------------------------------------- execution
    def run_round(
        self,
        t_s: float,
        true_tput: np.ndarray,
        *,
        planner=None,
        contexts=(),
        plans=(),
        links: list[tuple[int, int]] | None = None,
    ) -> ProbeRound:
        """One batched probe round at time ``t_s`` against the true grid.

        Candidates come from ``links`` if given, else from the planner's
        pruned subgraphs for ``contexts``. The round's policy ranks the
        candidates; the Calibrator takes them in rank order while the
        round's dollar / second / count budget holds, then folds every
        measurement into the belief."""
        # dedup applies to the broad VoI sweeps only: an explicitly
        # targeted round (breaker half-open, drift confirmation) exists to
        # get a FRESH saturating measurement and always runs
        targeted = links is not None
        if links is None:
            if planner is None:
                raise ValueError("need either links= or planner+contexts")
            links = self.candidate_links(planner, contexts)
        true_tput = np.asarray(true_tput, dtype=float)
        ctx = PolicyContext(
            belief=self.belief, t_s=float(t_s), budget=self.budget,
            planner=planner, contexts=tuple(contexts), plans=tuple(plans),
            last_probe_t=self._probe_t_grid,
        )
        order = np.asarray(self.policy.rank(list(links), ctx), dtype=np.int64)

        base = self.belief.base
        records: list[ProbeRecord] = []
        spent_usd = 0.0
        longest = 0.0
        deduped = 0
        for i in order:
            if len(records) >= self.budget.max_probes_per_round:
                break
            a, b = links[int(i)]
            truth = float(true_tput[a, b])
            if truth <= 0:
                continue
            if (not targeted and self.dedup_window_s > 0.0
                    and self.last_probe_t.get((int(a), int(b)), -np.inf)
                    >= float(t_s) - self.dedup_window_s):
                deduped += 1
                continue
            measured = truth
            if self.noise_sigma > 0:
                measured *= float(np.exp(
                    self._rng.normal(0.0, self.noise_sigma)
                ))
            # a probe runs for min(full volume, round window): a collapsed
            # link — the highest-VoI candidate there is — still gets
            # measured, it just moves fewer bytes in the capped window
            # (iperf reports the observed rate either way)
            duration = min(
                self.probe_gb * GBIT_PER_GB / max(measured, 1e-6),
                self.budget.seconds_per_round,
            )
            gb_moved = measured * duration / GBIT_PER_GB
            cost = (
                gb_moved * float(base.price_egress[a, b])
                + duration * float(base.price_vm[a] + base.price_vm[b])
            )
            if spent_usd + cost > self.budget.usd_per_round:
                continue
            spent_usd += cost
            longest = max(longest, duration)
            records.append(ProbeRecord(
                t_s=float(t_s), src=int(a), dst=int(b),
                measured_gbps=measured, cost_usd=cost, duration_s=duration,
            ))
        for r in records:
            # probes saturate the link, so a measurement far outside the
            # belief's band is a regime change, not noise: change-point
            # handling resets the link instead of averaging against stale
            # history (observe_adaptive)
            self.belief.observe_adaptive(r.src, r.dst, r.measured_gbps,
                                         weight=self.probe_weight,
                                         t_s=float(t_s))
            self.last_probe_t[(r.src, r.dst)] = float(t_s)
            self._probe_t_grid[r.src, r.dst] = float(t_s)
        # convergence metric scoped to the links the calibrator can act on
        # (the candidate set): global grid error is dominated by links no
        # plan could ever use and no budget could ever probe
        mask = np.zeros_like(true_tput, dtype=bool)
        for a, b in links:
            mask[a, b] = True
        rnd = ProbeRound(
            t_s=float(t_s), records=records,
            cost_usd=spent_usd, duration_s=longest,
            belief_error=self.belief.error_vs(true_tput, mask=mask),
            policy=getattr(self.policy, "name", type(self.policy).__name__),
            deduped=deduped,
        )
        self.rounds.append(rnd)
        REGISTRY.counter("calibrate.probes").inc(len(records))
        REGISTRY.counter("calibrate.probe_usd").inc(spent_usd)
        REGISTRY.counter("calibrate.probe_s").inc(longest)
        if deduped:
            REGISTRY.counter("calibrate.dedup_hits").inc(deduped)
        tr = get_tracer()
        if tr.enabled:
            tr.instant("calibrate.probe_round", float(t_s),
                       track="calibrate", probes=len(records),
                       deduped=deduped, usd=round(spent_usd, 6),
                       targeted=targeted)
        return rnd

    # ------------------------------------------------------------ accounting
    @property
    def total_cost_usd(self) -> float:
        return sum(r.cost_usd for r in self.rounds)

    @property
    def total_probe_seconds(self) -> float:
        return sum(r.duration_s for r in self.rounds)

    @property
    def total_probes(self) -> int:
        return sum(r.n_probes for r in self.rounds)
