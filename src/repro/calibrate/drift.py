"""Deterministic drift model: the TRUE topology as a function of time.

The paper measures its throughput grid once, offline (§3.2), and the
planner treats it as ground truth. Real inter-region goodput drifts away
from any static profile within hours (cross-cloud interconnect studies),
so the calibration plane splits the world in two:

  * the **believed** topology — what the planner sees (calibrate.BeliefGrid);
  * the **true** topology — what the data plane actually delivers, produced
    here by layering three deterministic processes on a base grid:

      1. slow multiplicative drift  — per-link log-factor, a sum of two
         seeded sinusoids with incommensurate periods (smooth, bounded,
         zero-mean in log space);
      2. diurnal waves              — a shared-period, per-link-phase
         utilization cycle (links sag at their local peak hours);
      3. step-change incidents      — rare interconnect events that slam a
         link to ``severity`` of its drifted value for a bounded window
         (the failure mode that stalls a static plan mid-transfer).

Everything is a pure function of (seed, t): ``tput_at(t)`` is bitwise
reproducible at arbitrary query times and across processes — no hidden RNG
state advances between calls, so simulators, probes and tests can sample
the same instant independently and agree.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.topology import Topology


@dataclasses.dataclass(frozen=True)
class Incident:
    """A step-change interconnect event on one directed link: from
    ``t_start_s`` for ``duration_s``, the link runs at ``severity`` of its
    drifted capacity (0 < severity < 1; e.g. 0.08 = a brown-out to 8%)."""

    src: int  # region index
    dst: int
    t_start_s: float
    duration_s: float
    severity: float

    def active_at(self, t_s: float) -> bool:
        return self.t_start_s <= t_s < self.t_start_s + self.duration_s


class DriftModel:
    """Time-indexed true grid over a base :class:`Topology`.

    Static per-link parameters (sinusoid amplitudes/periods/phases, the
    incident schedule) are drawn ONCE from ``numpy.random.default_rng(seed)``
    at construction; after that every query is a pure function of time.

    ``drift_sigma`` bounds the slow drift (each sinusoid's log-amplitude is
    uniform in [sigma/4, sigma]); ``diurnal_amp`` the day-cycle sag;
    ``day_s`` the cycle period (set it to seconds-scale values in tests to
    make the wave observable inside a short transfer). ``n_incidents``
    random incidents are scheduled over ``incident_horizon_s`` on links
    with positive base throughput, or pass an explicit ``incidents`` list
    to script a scenario (e.g. "kill the stale plan's trunk at t=5s").
    """

    def __init__(
        self,
        base: Topology,
        *,
        seed: int = 0,
        drift_sigma: float = 0.12,
        drift_period_s: tuple[float, float] = (1800.0, 7200.0),
        diurnal_amp: float = 0.06,
        day_s: float = 86400.0,
        incidents: list[Incident] | None = None,
        n_incidents: int = 0,
        incident_horizon_s: float = 3600.0,
        incident_duration_s: tuple[float, float] = (60.0, 600.0),
        incident_severity: tuple[float, float] = (0.05, 0.35),
        clip: tuple[float, float] = (0.02, 2.0),
    ):
        self.base = base
        self.seed = int(seed)
        v = base.num_regions
        self._mask = np.asarray(base.tput) > 0
        self._clip = (float(clip[0]), float(clip[1]))
        rng = np.random.default_rng(self.seed)

        # slow drift: log-factor a1*sin(2pi t/p1 + f1) + a2*sin(2pi t/p2 + f2)
        lo, hi = drift_period_s
        self._amp1 = rng.uniform(drift_sigma / 4.0, drift_sigma, (v, v))
        self._amp2 = rng.uniform(drift_sigma / 4.0, drift_sigma, (v, v))
        self._per1 = rng.uniform(lo, hi, (v, v))
        # sqrt(2)-detuned so the two waves never phase-lock (quasi-periodic)
        self._per2 = rng.uniform(lo, hi, (v, v)) * np.sqrt(2.0)
        self._ph1 = rng.uniform(0.0, 2.0 * np.pi, (v, v))
        self._ph2 = rng.uniform(0.0, 2.0 * np.pi, (v, v))

        # diurnal: shared period, per-link phase and per-link depth
        self._day_s = float(day_s)
        self._damp = diurnal_amp * rng.uniform(0.5, 1.0, (v, v))
        self._dph = rng.uniform(0.0, 2.0 * np.pi, (v, v))

        if incidents is not None:
            self.incidents = list(incidents)
        else:
            self.incidents = []
            links = np.argwhere(self._mask)
            for _ in range(int(n_incidents)):
                a, b = links[int(rng.integers(len(links)))]
                self.incidents.append(Incident(
                    src=int(a), dst=int(b),
                    t_start_s=float(rng.uniform(0.0, incident_horizon_s)),
                    duration_s=float(rng.uniform(*incident_duration_s)),
                    severity=float(rng.uniform(*incident_severity)),
                ))

    # ------------------------------------------------------------------ query
    def factor_at(self, t_s: float) -> np.ndarray:
        """[V,V] multiplicative factor true/base at time ``t_s`` — pure in t."""
        t = float(t_s)
        two_pi = 2.0 * np.pi
        log_f = (
            self._amp1 * np.sin(two_pi * t / self._per1 + self._ph1)
            + self._amp2 * np.sin(two_pi * t / self._per2 + self._ph2)
        )
        f = np.exp(log_f) * (
            1.0 - self._damp * (0.5 + 0.5 * np.sin(
                two_pi * t / self._day_s + self._dph
            ))
        )
        for inc in self.incidents:
            if inc.active_at(t):
                f[inc.src, inc.dst] *= inc.severity
        f = np.clip(f, self._clip[0], self._clip[1])
        return np.where(self._mask, f, 0.0)

    def tput_at(self, t_s: float) -> np.ndarray:
        """The true [V,V] throughput grid (Gbps) at time ``t_s``."""
        return np.asarray(self.base.tput) * self.factor_at(t_s)

    def link_gbps(self, src: int, dst: int, t_s: float) -> float:
        return float(self.tput_at(t_s)[src, dst])

    def topology_at(self, t_s: float) -> Topology:
        """A fresh Topology carrying the true grid at ``t_s`` (copy-on-write
        — prices, caps and region identities are the base's)."""
        return self.base.with_tput(self.tput_at(t_s))

    def incidents_active(self, t_s: float) -> list[Incident]:
        return [i for i in self.incidents if i.active_at(t_s)]
