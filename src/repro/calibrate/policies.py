"""Probe-scheduling policies: *what to measure next* as a first-class
decision.

The Calibrator rations an explicit per-round budget ($, seconds, probe
count) across candidate links; a :class:`ProbePolicy` decides the ORDER
in which candidates bid for that budget. Four schedulers ship:

  * ``greedy``          — :class:`GreedyVoIPolicy`, the original heuristic
    (relative uncertainty + staleness, plan-flow bonus, sqrt-capacity
    weight). Cheap, myopic, the default.
  * ``round_robin``     — :class:`RoundRobinPolicy`, a least-recently-
    measured sweep. Ignores value entirely but *guarantees* staleness
    coverage: every candidate is eventually probed, so no link's belief
    can silently rot — the baseline any smarter policy must beat.
  * ``epsilon_greedy``  — :class:`EpsilonGreedyPolicy`, greedy with
    seed-deterministic random exploration: each rank slot defects to a
    uniformly random candidate with probability ``epsilon``.
  * ``evoi``            — :class:`BayesianEVOIPolicy`, Bayesian expected
    value of information: each candidate is priced by the *plan regret*
    its measurement could remove. The policy resolves the belief's
    z-lower-confidence-bound grid against its mean grid on the planner's
    CACHED LP structures (``Planner.max_throughput(tput_scale=...)`` —
    scale cuts ride the memoized ``milp.LPStructure``/
    ``MulticastLPStructure``, so ranking a round assembles NOTHING and
    ``milp.N_STRUCT_BUILDS`` stays pinned): the difference between the
    robust plan value with link *e* confirmed at its believed mean and
    the all-LCB robust plan value is the throughput the planner is
    leaving on the table *because* link *e* is uncertain. Probes go where
    that number is largest; when no probe can recover any plan value the
    policy degrades to greedy exploration.

Policies are stateless between processes but may carry state across
rounds (the ε-greedy RNG advances per call) — construct one per
experiment arm and reuse it for the arm's lifetime.
"""

from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import numpy as np

from repro.core.spec import PlanSpec

from .belief import BeliefGrid


@dataclasses.dataclass(frozen=True)
class ProbeBudget:
    """Per-round spending caps: dollars, wall-clock, and probe count."""

    usd_per_round: float = 2.0
    seconds_per_round: float = 30.0
    max_probes_per_round: int = 8


@dataclasses.dataclass(frozen=True)
class PolicyContext:
    """Everything a policy may consult when ranking candidate links.

    ``planner``/``contexts``/``plans`` are optional — a policy must
    degrade gracefully when the round was launched from a bare link list
    (``Calibrator.run_round(links=...)``) with no planner attached."""

    belief: BeliefGrid
    t_s: float = 0.0
    budget: ProbeBudget | None = None
    planner: object | None = None
    contexts: tuple = ()  # (src, dst) or (src, [dsts]) planner keys
    plans: tuple = ()  # current TransferPlan / MulticastPlan objects
    # when each link was last ACTIVELY probed (grid, -inf = never). The
    # belief's own last_obs_t is refreshed by passive telemetry every
    # segment, so ranking staleness on it starves exactly the plan's
    # load-bearing links: allocation-shaped telemetry keeps them looking
    # fresh while proving nothing about capacity (it is one-sided — see
    # ``capacity_sample_from_rates``). Only a saturating probe re-earns
    # capacity confidence, so policies age links against this stamp when
    # the round's Calibrator provides it.
    last_probe_t: np.ndarray | None = None

    @property
    def probe_age_t(self) -> np.ndarray:
        """Per-link active-probe age stamps: ``last_probe_t`` when the
        Calibrator supplied them, else the belief's passive stamps."""
        if self.last_probe_t is not None:
            return self.last_probe_t
        return self.belief.last_obs_t


@runtime_checkable
class ProbePolicy(Protocol):
    """Ranks candidate links for one probe round.

    ``rank`` returns indices into ``links`` in descending priority; the
    Calibrator walks the ranking while the round's budget holds. The
    policy never spends the budget itself — separating *what is worth
    measuring* from *what we can afford* keeps budget enforcement in one
    place and identical across policies."""

    name: str

    def rank(
        self, links: list[tuple[int, int]], ctx: PolicyContext
    ) -> np.ndarray: ...


# --------------------------------------------------------------- greedy VoI
def greedy_voi_scores(
    links: list[tuple[int, int]],
    ctx: PolicyContext,
    *,
    on_plan_bonus: float = 2.0,
    staleness_halflife_s: float = 30.0,
) -> np.ndarray:
    """Value-of-information score per candidate link.

    score = (rel_uncertainty + staleness) * (1 + bonus * flow_share)
            * sqrt(mean):
    uncertain links first, a measurement's value decaying with its age
    (a link probed once is NOT trusted forever — links drift within
    hours, so confidence must be re-earned), plan-carrying links
    boosted by their share of the plan's flow, and everything weighted
    toward links with real capacity (a 0.1 Gbps alternate is worth
    less than a 5 Gbps trunk at equal uncertainty).

    The staleness term SATURATES at one halflife: past that the stamp is
    simply old, and what still separates candidates is uncertainty, plan
    relevance, and capacity — not how much older than stale each stamp
    is. Unbounded aging turns the score into a pure never-probed sweep
    (every unprobed zero-flow alternate outranks every probed link by
    orders of magnitude), which starves re-confirmation of the drifting
    flow-carrying trunks the plans actually depend on until the full
    candidate set has been swept once — tens of rounds on a real
    subgraph, far longer than links stay trustworthy."""
    belief = ctx.belief
    unc = belief.rel_uncertainty()
    mean = belief.mean
    flow = np.zeros_like(mean)
    for plan in ctx.plans:
        grid = getattr(plan, "G", None)
        if grid is None:
            grid = plan.F
        peak = float(np.max(grid, initial=0.0))
        if peak > 0:
            flow = np.maximum(flow, np.asarray(grid) / peak)
    age = np.clip(
        float(ctx.t_s) - ctx.probe_age_t, 0.0, None
    )  # inf for never-probed links (the stale prior is ancient)
    stale = np.where(np.isfinite(age), age / staleness_halflife_s, 1e9)
    out = np.empty(len(links))
    for i, (a, b) in enumerate(links):
        out[i] = (
            (unc[a, b] + 0.05 * min(stale[a, b], 1.0))
            * (1.0 + on_plan_bonus * flow[a, b])
            * np.sqrt(max(mean[a, b], 0.0))
        )
    return out


class GreedyVoIPolicy:
    """The original Calibrator heuristic, extracted: rank candidates by
    ``greedy_voi_scores`` and take them best-first. Myopic — it never
    asks whether a measurement would change any plan — but cheap and a
    strong default when uncertainty tracks plan relevance."""

    name = "greedy"

    def __init__(
        self,
        *,
        on_plan_bonus: float = 2.0,
        staleness_halflife_s: float = 30.0,
    ):
        self.on_plan_bonus = float(on_plan_bonus)
        self.staleness_halflife_s = float(staleness_halflife_s)

    def score(
        self, links: list[tuple[int, int]], ctx: PolicyContext
    ) -> np.ndarray:
        return greedy_voi_scores(
            links,
            ctx,
            on_plan_bonus=self.on_plan_bonus,
            staleness_halflife_s=self.staleness_halflife_s,
        )

    def rank(
        self, links: list[tuple[int, int]], ctx: PolicyContext
    ) -> np.ndarray:
        return np.argsort(-self.score(links, ctx), kind="stable")


# -------------------------------------------------------------- round robin
class RoundRobinPolicy:
    """Least-recently-measured sweep.

    Ranking is by the last-active-probe stamp (never-probed links,
    stamped ``-inf``, lead), ties broken by stable candidate order.
    Probing a link moves its stamp to *now* and sends it to the back of
    the queue, so successive rounds cycle through the full candidate
    set — a round-robin over a stable set, and a guarantee no
    score-driven policy gives: every candidate's staleness is bounded by
    (candidate count / probes per round) rounds."""

    name = "round_robin"

    def rank(
        self, links: list[tuple[int, int]], ctx: PolicyContext
    ) -> np.ndarray:
        last = ctx.probe_age_t
        stamps = np.array([last[a, b] for a, b in links])
        return np.lexsort((np.arange(len(links)), stamps))


# ------------------------------------------------------------ epsilon-greedy
class EpsilonGreedyPolicy:
    """Greedy VoI with seed-deterministic random exploration.

    Each rank slot defects to a uniformly random remaining candidate
    with probability ``epsilon`` (otherwise it takes the best remaining
    by greedy score). The RNG is owned by the policy and advances one
    draw per slot, so two policies built with the same seed and fed the
    same rounds produce bitwise-identical probe schedules."""

    name = "epsilon_greedy"

    def __init__(
        self,
        *,
        epsilon: float = 0.2,
        seed: int = 0,
        on_plan_bonus: float = 2.0,
        staleness_halflife_s: float = 30.0,
    ):
        if not 0.0 <= epsilon <= 1.0:
            raise ValueError(f"epsilon must be in [0, 1], got {epsilon}")
        self.epsilon = float(epsilon)
        self._rng = np.random.default_rng(seed)
        self._greedy = GreedyVoIPolicy(
            on_plan_bonus=on_plan_bonus,
            staleness_halflife_s=staleness_halflife_s,
        )

    def rank(
        self, links: list[tuple[int, int]], ctx: PolicyContext
    ) -> np.ndarray:
        base = list(np.argsort(-self._greedy.score(links, ctx), kind="stable"))
        order = []
        while base:
            if len(base) > 1 and self._rng.random() < self.epsilon:
                j = int(self._rng.integers(len(base)))
            else:
                j = 0
            order.append(base.pop(j))
        return np.asarray(order, dtype=np.int64)


# ------------------------------------------------------------- Bayesian EVOI
class BayesianEVOIPolicy:
    """Expected value of information, priced in plan throughput regret.

    The robust planner plans against the belief's z-lower-confidence-
    bound grid, so every uncertain link taxes the plan by the gap
    between its LCB and its mean. A probe that confirms link *e* at its
    believed mean removes exactly that link's tax; its expected value is

        EVOI(e) = V(phi_lcb with e at phi_mean) - V(phi_lcb)

    where V(phi) is the robust plan value (max achievable throughput,
    summed over the active transfer contexts) under full-grid scale
    ``phi``. V is evaluated AT THE PLAN'S PROVISIONED VM ALLOCATION
    (``vm_caps`` from each context's plan N vector, when plans are
    supplied): at full fleet scale the paper-grid max-flow is VM-bound
    and no link's uncertainty moves it, but the VMs a plan actually
    bought are where a drifted link genuinely costs throughput — regret
    is priced against the deployment we have, not a hypothetical
    re-provisioned one. Both V evaluations ride the planner's CACHED LP
    structures (``max_throughput`` / ``max_multicast_throughput`` with
    ``tput_scale=`` — scale cuts as extra rows, zero re-assembly,
    ``milp.N_STRUCT_BUILDS`` pinned after warm-up).

    The belief tracks a DRIFTING quantity, so the policy's uncertainty is
    not the belief's raw standard error: a link measured 30 seconds ago
    is less certain than the sample count suggests. The effective sigma
    grows with measurement age (``stale_sigma_rate`` of the mean per
    ``staleness_halflife_s``, capped at ``stale_sigma_cap`` — a random-
    walk drift prior on top of the Welford estimate), which re-opens the
    LCB/mean gap on confirmed links over time. That is what sends EVOI
    *back* to the plan's bottleneck links between incidents — without it
    a confirmed link would never be re-probed and a later collapse would
    go unseen.

    Only links whose LCB/mean gap exceeds ``gap_tol`` can have positive
    EVOI; at most ``eval_top_k`` of those are evaluated exactly (one LP
    each, plus one base solve) — plan-flow links first, then the largest
    gap-weighted greedy pre-scores — and everything else inherits EVOI 0.
    Ranking is EVOI-first with the greedy score as tiebreak, so once no
    probe can recover plan value (all regret resolved) the policy
    degrades to plain uncertainty-driven exploration instead of going
    blind."""

    name = "evoi"

    def __init__(
        self,
        *,
        z: float = 1.5,
        eval_top_k: int = 8,
        gap_tol: float = 1e-3,
        stale_sigma_rate: float = 0.08,
        stale_sigma_cap: float = 0.5,
        on_plan_bonus: float = 2.0,
        staleness_halflife_s: float = 30.0,
    ):
        self.z = float(z)
        self.eval_top_k = int(eval_top_k)
        self.gap_tol = float(gap_tol)
        self.stale_sigma_rate = float(stale_sigma_rate)
        self.stale_sigma_cap = float(stale_sigma_cap)
        self.staleness_halflife_s = float(staleness_halflife_s)
        self._greedy = GreedyVoIPolicy(
            on_plan_bonus=on_plan_bonus,
            staleness_halflife_s=staleness_halflife_s,
        )

    def _phi_eff(
        self, belief: BeliefGrid, top, t_s: float,
        probe_age_t: np.ndarray | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """(phi_lcb_eff, phi_mean): the scale grids the EVOI resolves.

        phi_lcb_eff is the belief's z-LCB scale with the drift prior
        folded in — sigma inflated by time since the last ACTIVE probe
        (passive telemetry cannot re-earn capacity confidence) — so the
        regret a stale link causes grows until a probe re-confirms it."""
        phi_mean = belief.scale_grid(top, z=0.0)
        stamps = probe_age_t if probe_age_t is not None else belief.last_obs_t
        age = np.clip(float(t_s) - stamps, 0.0, None)
        with np.errstate(invalid="ignore"):
            growth = np.where(
                np.isfinite(age),
                age / self.staleness_halflife_s * self.stale_sigma_rate,
                self.stale_sigma_cap,
            )
        sigma_eff = belief.stderr() + np.minimum(
            growth, self.stale_sigma_cap
        ) * belief.mean
        lb = np.where(
            belief.mean > 0,
            np.maximum(belief.mean - self.z * sigma_eff, belief.min_tput),
            0.0,
        )
        ref = np.asarray(top.tput, dtype=float)
        with np.errstate(divide="ignore", invalid="ignore"):
            phi = np.where(ref > 0, lb / np.maximum(ref, 1e-12), 1.0)
        return np.clip(phi, 0.02, 1.0), phi_mean

    @staticmethod
    def _vm_caps(plan) -> dict[int, float] | None:
        """The plan's provisioned VM allocation as a vm_caps dict (full-
        topology indices; regions the plan did not provision are capped
        at 0 — re-routing through them would need VMs nobody bought)."""
        n = getattr(plan, "N", None)
        if n is None:
            return None
        return {
            int(r): float(np.ceil(v)) for r, v in enumerate(np.asarray(n))
        }

    def _value(self, planner, contexts, plans, phi: np.ndarray) -> float:
        """Robust plan value under full-grid scale ``phi``: achievable
        throughput summed over contexts at their plans' VM allocations
        (plans pair with contexts positionally when the counts match),
        on cached structures."""
        paired = (
            plans if len(plans) == len(contexts)
            else (None,) * len(contexts)
        )
        total = 0.0
        for (src, dst), plan in zip(contexts, paired):
            caps = self._vm_caps(plan) if plan is not None else None
            if isinstance(dst, (list, tuple)):
                total += planner.plan(PlanSpec(
                    objective="max_throughput", src=src, dsts=tuple(dst),
                    vm_caps=caps, tput_scale=phi,
                ))
            else:
                total += planner.plan(PlanSpec(
                    objective="max_throughput", src=src, dst=dst,
                    vm_caps=caps, tput_scale=phi,
                ))
        return total

    def rank(
        self, links: list[tuple[int, int]], ctx: PolicyContext
    ) -> np.ndarray:
        pre = self._greedy.score(links, ctx)
        planner = ctx.planner
        if planner is None or not ctx.contexts:
            return np.argsort(-pre, kind="stable")
        belief = ctx.belief
        top = planner.top
        phi_lcb, phi_mean = self._phi_eff(
            belief, top, ctx.t_s, probe_age_t=ctx.last_probe_t
        )
        gaps = np.array([phi_mean[a, b] - phi_lcb[a, b] for a, b in links])
        # links carrying plan flow take the FRONT of the eval budget (they
        # are where regret lives, even right after a confirming probe
        # shrank their gap — gap-weighted selection alone would drop them
        # and degenerate to greedy between staleness cycles); whatever
        # budget remains goes to the largest gap-weighted pre-scores.
        # Total exact evaluations stay <= eval_top_k (+1 base solve).
        on_plan: set[int] = set()
        for plan in ctx.plans:
            grid = getattr(plan, "G", None)
            if grid is None:
                grid = plan.F
            g = np.asarray(grid)
            for i, (a, b) in enumerate(links):
                if g[a, b] > 1e-9:
                    on_plan.add(i)
        k = max(self.eval_top_k, 0)
        ordered = [
            int(i)
            for i in np.argsort(-(gaps * pre), kind="stable")
            if gaps[i] > self.gap_tol
        ]
        cand = (
            [i for i in ordered if i in on_plan]
            + [i for i in ordered if i not in on_plan]
        )[:k]
        evoi = np.zeros(len(links))
        if cand:
            base = self._value(planner, ctx.contexts, ctx.plans, phi_lcb)
            # IPM solves carry O(1e-9) numerical noise; a "gain" below the
            # tolerance is not signal and must not outrank the greedy
            # tiebreak
            tol = max(1e-6, 1e-7 * abs(base))
            for i in cand:
                a, b = links[i]
                phi = phi_lcb.copy()
                phi[a, b] = phi_mean[a, b]
                gain = self._value(
                    planner, ctx.contexts, ctx.plans, phi
                ) - base
                evoi[i] = gain if gain > tol else 0.0
        # EVOI is primary; the greedy pre-score orders the zero-regret tail
        # (and breaks exact EVOI ties deterministically)
        return np.lexsort((-pre, -evoi))


# ------------------------------------------------------------------ factory
POLICY_NAMES = ("greedy", "round_robin", "epsilon_greedy", "evoi")


def make_policy(spec: str, *, seed: int = 0, **kw) -> ProbePolicy:
    """Build a policy from its CLI name (``--policy`` flag, bench arms).

    ``seed`` only matters for stochastic policies (ε-greedy); extra
    keyword arguments go to the policy constructor. The shared scoring
    knobs (``on_plan_bonus``, ``staleness_halflife_s``) are accepted for
    every policy and dropped for the ones that do not score (so a
    Calibrator can thread its knobs through any spec)."""
    name = str(spec).replace("-", "_").lower()
    if name in ("round_robin", "rr"):
        for knob in ("on_plan_bonus", "staleness_halflife_s"):
            kw.pop(knob, None)
        return RoundRobinPolicy(**kw)
    if name in ("greedy", "voi"):
        return GreedyVoIPolicy(**kw)
    if name in ("epsilon_greedy", "eps_greedy"):
        return EpsilonGreedyPolicy(seed=seed, **kw)
    if name in ("evoi", "bayes", "bayesian", "bayesian_evoi"):
        return BayesianEVOIPolicy(**kw)
    raise ValueError(
        f"unknown probe policy {spec!r} (expected one of {POLICY_NAMES})"
    )
