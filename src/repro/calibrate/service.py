"""CalibratedTransferService: the closed measure→believe→plan→observe loop.

Extends :class:`repro.transfer.TransferService` with the calibration
plane's split view of the world:

  * plans are made on the BELIEVED topology (``BeliefGrid`` mean at
    service start — the epoch grid) with the ``robustness`` knob applied:
    every admission and re-plan rides the belief's lower-confidence-bound
    scale as tightened 4b rows on the CACHED LP structures
    (``TransferService._plan_scale`` override; zero re-assembly);
  * the data plane executes on the TRUE topology (``DriftModel`` snapshot
    frozen at each segment start, via ``simulate_multi(exec_top=...)``);
  * the run is segmented every ``check_interval_s``: at each boundary a
    ``Calibrator`` probe round spends its budget on the highest
    value-of-information links, and passive telemetry (per-link delivered
    GB over active seconds) folds into the belief;
  * a drift detector compares what a plan assumed of each link it uses
    against what probes and telemetry observed: a sample below
    ``drift_ratio`` of the assumption AND outside the belief's
    z-confidence band (``BeliefGrid.out_of_bounds``) flags the link, the
    belief is updated at ``drift_weight``, and the job's REMAINING volume
    is re-planned (``TransferService._replan`` — cached structures, goal
    backoff ladder, ``ReplanRecord`` provenance all inherited).

A long transfer that crosses a step-change incident therefore finishes
near its SLO — the loop routes the remainder around the collapsed link —
where the same service with ``calibrate=False`` (the stale-grid baseline:
same segmentation, same true topology, no probes / no belief updates / no
re-planning) limps through at the incident's rate.

Beliefs can also IMPROVE past the epoch grid (a link recovers, or the
stale profile undersold it). Mid-epoch the planner cannot exploit that:
scale cuts only tighten (phi clips at 1.0 — a loosening row never binds).
The service therefore watches the flow-weighted believed/epoch ratio over
the links its plans ride and, past a hysteresis threshold, performs an
**epoch roll**: re-pin the epoch grid at the belief mean, rebuild the LP
structures on it (the one sanctioned, counted re-assembly), and re-plan
every active job's remaining volume at its full requested goal. Rolls are
rare by construction — the threshold gates them, each roll resets the
ratio to ~1, and ``max_epoch_rolls`` bounds them per run.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import milp
from repro.core.plan import MulticastPlan
from repro.core.planner import Planner
from repro.core.topology import GBIT_PER_GB
from repro.obs.metrics import REGISTRY
from repro.obs.trace import get_tracer
from repro.transfer.events import TransferJob
from repro.transfer.executor import (
    ReplanRecord,
    ServiceReport,
    TransferService,
    _drop_trickle_paths,
)

from .belief import BeliefGrid, capacity_sample_from_rates
from .calibrator import Calibrator, ProbeRound
from .drift import DriftModel
from .policies import ProbePolicy

_FLOW_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class DriftEvent:
    """One detected believed-vs-observed divergence on a plan link."""

    t_s: float
    job: str
    src: int  # region indices of the drifted link
    dst: int
    assumed_gbps: float  # what the job's plan assumed of the link
    observed_gbps: float  # the capacity sample that broke the bounds
    source: str  # "probe" | "telemetry"


@dataclasses.dataclass(frozen=True)
class EpochRoll:
    """One epoch roll: the belief mean re-pinned as the planner's grid.

    The roll is the sanctioned exception to the zero-re-assembly rule —
    it deliberately rebuilds LP structures on the improved grid, and
    ``structure_builds`` counts exactly how many assemblies it bought
    (bounded by the roll cap; drift re-plans still assemble nothing)."""

    t_s: float  # segment boundary the roll fired at
    ratio: float  # flow-weighted believed/epoch ratio that triggered it
    structure_builds: int
    replans: list[ReplanRecord]  # the roll's re-plans (kept out of
    # JobReport.replans so the zero-build invariant there stays meaningful)


@dataclasses.dataclass
class CalibratedServiceReport(ServiceReport):
    probe_rounds: list[ProbeRound] = dataclasses.field(default_factory=list)
    drift_events: list[DriftEvent] = dataclasses.field(default_factory=list)
    # (t_s, mean relative believed-vs-true grid error) per probe round
    belief_error_trajectory: list[tuple[float, float]] = dataclasses.field(
        default_factory=list
    )
    epoch_rolls: list[EpochRoll] = dataclasses.field(default_factory=list)
    boundaries: list[float] = dataclasses.field(default_factory=list)
    # segment end times — epoch rolls may only fire on these

    @property
    def probe_cost_usd(self) -> float:
        return sum(r.cost_usd for r in self.probe_rounds)

    @property
    def probe_seconds(self) -> float:
        return sum(r.duration_s for r in self.probe_rounds)

    @property
    def epoch_roll_builds(self) -> int:
        return sum(r.structure_builds for r in self.epoch_rolls)

    kind = "calibrated_service"
    _summary_keys = ("jobs", "time_s", "delivered_gb", "probe_cost_usd",
                     "drift_events", "epoch_rolls")
    _metrics_prefixes = ("planner.", "service.", "breaker.", "calibrate.")

    def _payload(self) -> dict:
        d = super()._payload()
        d.update({
            "probe_rounds": len(self.probe_rounds),
            "probe_cost_usd": self.probe_cost_usd,
            "probe_seconds": self.probe_seconds,
            "probes_deduped": sum(
                getattr(r, "deduped", 0) for r in self.probe_rounds
            ),
            "drift_events": len(self.drift_events),
            "epoch_rolls": len(self.epoch_rolls),
            "epoch_roll_builds": self.epoch_roll_builds,
            "belief_error_final": (
                self.belief_error_trajectory[-1][1]
                if self.belief_error_trajectory else None
            ),
        })
        return d


class CalibratedTransferService(TransferService):
    """TransferService planning on a belief, executing on a drift model.

    Usage::

        drift = DriftModel(default_topology(), seed=3, incidents=[...])
        svc = CalibratedTransferService(drift)
        svc.submit(TransferRequest("big", src, dst, 64.0, 4.0))
        report = svc.run()

    ``calibrate=False`` turns every feedback path off (no probes, no
    telemetry, no drift detection, no re-planning) while keeping the
    identical segmented execution on the true topology — the stale-grid
    baseline the calibration benchmark compares against.
    """

    def __init__(
        self,
        drift: DriftModel,
        *,
        belief: BeliefGrid | None = None,
        calibrator: Calibrator | None = None,
        calibrate: bool = True,
        robustness: float = 1.5,
        check_interval_s: float = 4.0,
        drift_ratio: float = 0.6,
        drift_z: float = 2.0,
        passive_weight: float = 1.0,
        drift_weight: float = 8.0,
        max_segments: int = 400,
        link_capacity_scale: float | None = 2.0,
        policy: ProbePolicy | str | None = None,
        epoch_roll_threshold: float = 1.15,
        max_epoch_rolls: int = 2,
        **kw,
    ):
        self.drift = drift
        self.belief = belief or BeliefGrid(drift.base)
        self.calibrate = bool(calibrate)
        self.robustness = float(robustness)
        self.check_interval_s = float(check_interval_s)
        self.drift_ratio = float(drift_ratio)
        self.drift_z = float(drift_z)
        self.passive_weight = float(passive_weight)
        self.drift_weight = float(drift_weight)
        self.max_segments = int(max_segments)
        self.link_capacity_scale = link_capacity_scale
        self.epoch_roll_threshold = float(epoch_roll_threshold)
        self.max_epoch_rolls = int(max_epoch_rolls)
        # the epoch grid: plans are priced and constrained against the
        # belief mean frozen at service construction; within the epoch the
        # belief moves only through scale cuts (zero re-assembly)
        super().__init__(self.belief.believed_topology(), **kw)
        self.planner.belief = self.belief
        # robust cuts also cap aggregate flow on drifted links at the data
        # plane's shared-link capacity — an incident cannot be bought back
        # with more VMs/connections (matches simulate_multi's water-filling)
        self.planner.link_capacity_scale = link_capacity_scale
        self.calibrator = calibrator if calibrator is not None else (
            Calibrator(self.belief, policy=policy) if self.calibrate else None
        )
        # contention-masked links _harvest flagged for a targeted
        # confirmation probe at the next boundary: oversubscription scales
        # the telemetry expectation down, so a capacity collapse hiding
        # under the mask is invisible to passive sampling — only a
        # saturating probe can tell contention from drift there
        self._confirm_links: set[tuple[int, int]] = set()

    # --------------------------------------------------------------- planning
    def _plan_scale(self) -> np.ndarray | None:
        """The belief's lower-confidence-bound scale vs the epoch grid —
        what every admission/re-plan solve rides as cached-structure cuts.
        None while the belief still matches the epoch (no cuts needed) or
        when calibration is off (the stale baseline trusts its grid)."""
        if not self.calibrate:
            return None
        # deadline shedding may strip the robustness margin for headroom:
        # z=0 plans on the belief mean instead of its lower bound
        z = self.robustness if self._replan_z is None else float(self._replan_z)
        phi = self.belief.scale_grid(self.top, z=max(z, 0.0))
        if (phi >= 1.0 - 1e-9).all():
            return None
        return phi

    # kept as a staticmethod alias — the implementation moved next to the
    # deadline-shedding machinery that also needs it
    _drop_trickle_paths = staticmethod(_drop_trickle_paths)

    def _plan_for(self, req, goal, volume_gb, *, vm_caps=None, constrained):
        plan = super()._plan_for(req, goal, volume_gb,
                                 vm_caps=vm_caps, constrained=constrained)
        if self.calibrate and plan.solver_status == "optimal":
            plan = self._drop_trickle_paths(plan)
        return plan

    def _post_replan(self, st) -> None:
        """Re-plans issued by the shared deadline/quarantine machinery must
        refresh the drift detector's reference grid like the run loop's
        own re-plan sites do."""
        if st.status != "failed":
            st._assumed = self._assumed_grid(st.plan)

    def _assumed_grid(self, plan) -> np.ndarray:
        """Per-link throughput the plan effectively assumed: the epoch grid
        under the scale active when the plan was made, masked to the links
        the plan uses. The drift detector's reference point."""
        grid = plan.G if isinstance(plan, MulticastPlan) else plan.F
        scale = self._plan_scale()
        eff = np.asarray(self.top.tput, dtype=float)
        if scale is not None:
            eff = eff * scale
        return np.where(np.asarray(grid) > _FLOW_EPS, eff, 0.0)

    # ------------------------------------------------------------ epoch rolls
    def _epoch_headroom(self, states_active) -> float:
        """Flow-weighted believed/epoch throughput ratio over the links the
        active plans actually ride. > 1 means the belief has risen past
        the epoch-pinned grid there — capacity the planner cannot exploit
        mid-epoch because scale cuts clip at 1.0."""
        epoch = np.asarray(self.top.tput, dtype=float)
        num = den = 0.0
        for st in states_active:
            g = np.asarray(
                st.plan.G if isinstance(st.plan, MulticastPlan) else st.plan.F
            )
            m = (g > _FLOW_EPS) & (epoch > 0)
            if not m.any():
                continue
            w = g[m]
            num += float((w * (self.belief.mean[m] / epoch[m])).sum())
            den += float(w.sum())
        return num / den if den > 0 else 1.0

    def _roll_epoch(self, states, act, t_s: float, ratio: float) -> EpochRoll:
        """Re-pin the epoch grid at the improved belief mean.

        This is the one place the calibration plane is ALLOWED to rebuild
        LP structures: the new epoch topology gets fresh caches, every
        active job's remaining volume is re-planned on them at its full
        requested goal, and the assemblies that bought are counted on the
        roll record (drift re-plans before and after stay zero-build).
        The roll's re-plans live on the roll, not in ``JobReport.replans``."""
        builds0 = milp.N_STRUCT_BUILDS
        self.belief.roll_epoch()
        self.top = self.belief.believed_topology()
        planner = Planner(self.top, max_relays=self.planner.max_relays)
        planner.belief = self.belief
        planner.link_capacity_scale = self.link_capacity_scale
        self.planner = planner
        recs: list[ReplanRecord] = []
        for i in act:
            st = states[i]
            n0 = len(st.replans)
            self._replan(st, i, at_s=t_s)
            if len(st.replans) > n0:
                recs.append(st.replans.pop())
            if st.status != "failed":
                st._assumed = self._assumed_grid(st.plan)
        roll = EpochRoll(
            t_s=float(t_s), ratio=float(ratio),
            structure_builds=milp.N_STRUCT_BUILDS - builds0,
            replans=recs,
        )
        REGISTRY.counter("calibrate.epoch_rolls").inc()
        tr = get_tracer()
        if tr.enabled:
            tr.instant("calibrate.epoch_roll", float(t_s), track="calibrate",
                       ratio=round(float(ratio), 4),
                       struct_builds=roll.structure_builds)
        return roll

    # ----------------------------------------------------------------- checks
    def _probe_focus(self, states, act):
        """(contexts, plans) the boundary's VoI sweep should rank over.

        The base service sweeps every active job's candidate subgraph.
        The fleet controller overrides this with a rotating per-tenant
        focus so one default-sized round concentrates on one tenant's
        links instead of diluting across the union."""
        ctxs = [
            (states[i].req.src, states[i].req.dsts)
            if states[i].req.multicast
            else (states[i].req.src, states[i].req.dst)
            for i in act
        ]
        return ctxs, [states[i].plan for i in act]

    def _probe_drifted_links(
        self, st, samples: dict[tuple[int, int], float]
    ) -> list[tuple[int, int, float, float]]:
        """(a, b, assumed, measured) for every plan link an active probe
        measured far below what the plan assumed of it (grid space). A
        probe saturates the link, so its measurement needs no confidence
        band to be trusted — the ratio alone convicts."""
        out = []
        for (a, b), obs in samples.items():
            assumed = float(st._assumed[a, b])
            if assumed <= _FLOW_EPS:
                continue
            if obs < self.drift_ratio * assumed:
                out.append((a, b, assumed, obs))
        return out

    def _harvest(
        self, st, jr, t_s: float = 0.0,
        agg_grid: np.ndarray | None = None,
    ) -> tuple[dict[tuple[int, int], float],
               list[tuple[int, int, float, float]]]:
        """Passive telemetry: per-link capacity samples for the links this
        job's segment actually exercised, folded into the belief with
        change-point handling (``observe_adaptive`` — a step change is a
        new regime, not one more noisy draw of the old one).

        Returns (samples, drifted links). A link drifts when it delivered
        below ``drift_ratio`` of the flow the plan allocated on it AND its
        capacity sample falls outside the belief's confidence band — the
        band is evaluated BEFORE the sample is folded in, because a
        change-point reset moves the band onto the sample.

        ``agg_grid`` is the AGGREGATE allocation across every job in the
        segment: when co-tenants over-subscribe a shared link beyond the
        believed interconnect capacity, this job's fair share — not its
        solo allocation — is what the data plane owes it, and reading the
        shortfall as capacity drift would reset healthy links low."""
        plan = st.plan
        grid = plan.G if isinstance(plan, MulticastPlan) else plan.F
        samples: dict[tuple[int, int], float] = {}
        hits: list[tuple[int, int, float, float]] = []
        busy_map = jr.per_edge_active_s or {}
        obs_map = jr.per_edge_obs_gb
        default_busy = 0.0
        if obs_map is None:
            # simulator without the obs window (e.g. the flowsim_ref
            # oracle via sim=): fall back to whole-run bytes over the
            # job's whole duration — a cruder, dilution-prone window,
            # but it keeps passive telemetry live on every backend
            obs_map = jr.per_edge_gb or {}
            default_busy = float(jr.time_s)
        for key, gb in obs_map.items():
            a_s, b_s = key.split("->")
            a, b = int(a_s), int(b_s)
            busy = float(busy_map.get(key, default_busy))
            if busy <= 1e-6:
                continue
            observed = gb * GBIT_PER_GB / busy
            expected = float(grid[a, b])
            if agg_grid is not None and self.link_capacity_scale is not None:
                cap_now = self.link_capacity_scale * float(
                    self.belief.mean[a, b]
                )
                agg = float(agg_grid[a, b])
                if agg > cap_now > 0.0:
                    # known contention, not drift — but a link that ALSO
                    # underdelivers against its unmasked expectation may be
                    # collapsing underneath the oversubscription. Passive
                    # telemetry cannot tell (the mask absorbs the shortfall);
                    # flag it for a targeted saturating probe next boundary.
                    if observed < self.drift_ratio * expected:
                        self._confirm_links.add((a, b))
                    expected *= cap_now / agg
            sample = capacity_sample_from_rates(
                observed, expected,
                n_vms=max(float(np.round(plan.N[a])), 1.0),
                link_capacity_scale=self.link_capacity_scale,
            )
            if sample is None:
                continue  # link kept up with the plan: no capacity info
            samples[(a, b)] = sample
            if (
                observed < self.drift_ratio * expected
                and st._assumed[a, b] > _FLOW_EPS
                and self.belief.out_of_bounds(a, b, sample, z=self.drift_z)
            ):
                hits.append((a, b, expected, observed))
        for (a, b), sample in samples.items():
            self.belief.observe_adaptive(
                a, b, sample,
                weight=self.passive_weight, z_reset=self.drift_z,
                t_s=t_s,
            )
        return samples, hits

    # -------------------------------------------------------------------- run
    def run(
        self,
        faults=(),
        *,
        seed: int = 0,
        link_capacity_scale: float | None = None,
        sim=None,
        **sim_kwargs,
    ) -> CalibratedServiceReport:
        """Segmented execution on the drifting true topology.

        Scripted ``faults`` are not supported here — incidents belong to
        the DriftModel (the service must *discover* them through probes
        and telemetry, which is the whole point)."""
        from repro.transfer.sim import simulate

        if faults:
            raise ValueError(
                "CalibratedTransferService takes no scripted faults; "
                "script incidents on the DriftModel instead"
            )
        sim = sim or simulate
        if link_capacity_scale is None:
            link_capacity_scale = self.link_capacity_scale
        states = self._admit_queue()
        for st in states:
            st._assumed = self._assumed_grid(st.plan)

        probe_rounds: list[ProbeRound] = []
        drift_events: list[DriftEvent] = []
        trajectory: list[tuple[float, float]] = []
        epoch_rolls: list[EpochRoll] = []
        boundaries: list[float] = []
        now = 0.0
        segments = 0
        sim_events = 0

        def active_indices() -> list[int]:
            return [
                i for i, st in enumerate(states)
                if st.status in ("planned", "running") and st.remaining_chunks
            ]

        def note_drift(st, hits, t, source):
            tr = get_tracer()
            for a, b, assumed, obs in hits:
                drift_events.append(DriftEvent(
                    t_s=t, job=st.req.name, src=a, dst=b,
                    assumed_gbps=assumed, observed_gbps=obs, source=source,
                ))
                REGISTRY.counter("calibrate.drift_events").inc()
                if tr.enabled:
                    tr.instant("calibrate.drift", float(t),
                               track="calibrate", job=st.req.name,
                               link=f"{a}->{b}", source=source)

        def breaker_feed(hits, t) -> list[tuple[int, int]]:
            """Drift detections are the breaker's failure signal here.
            A link that trips open is quarantined on the planner view and
            reseeded in the belief at the observed collapsed rate — the
            regime changed, the old posterior is evidence about nothing."""
            opened: list[tuple[int, int]] = []
            if self.breaker is None:
                return opened
            tr = get_tracer()
            for a, b, _assumed, obs in hits:
                if self.breaker.record_failure((a, b), t):
                    self._quarantine((a, b))
                    if tr.enabled:
                        tr.instant("service.quarantine", float(t),
                                   track="service", link=f"{a}->{b}")
                    self.belief.reset_link(a, b, max(obs, 1e-6), t_s=t)
                    opened.append((a, b))
            return opened

        def replan_quarantined_users(opened, t) -> None:
            """Every still-active job riding a just-quarantined link gets
            its remainder re-planned off it (cached structures — the
            quarantine is an extra_ub=0 scale cut, not a rebuild)."""
            for a, b in opened:
                for i in active_indices():
                    st = states[i]
                    g = np.asarray(
                        st.plan.G if isinstance(st.plan, MulticastPlan)
                        else st.plan.F
                    )
                    if g[a, b] > _FLOW_EPS:
                        self._replan(st, i, at_s=t, reason="quarantine")
                        self._post_replan(st)

        while segments < self.max_segments:
            act = active_indices()
            if not act:
                break
            true_now = self.drift.tput_at(now)

            # ---- breaker: quarantined links past their cooldown get a
            # targeted half-open probe through the calibrator; the
            # measurement reseeds the belief either way (regime change),
            # and a healthy link rejoins the plannable topology
            if (
                self.calibrate
                and self.breaker is not None
                and self.calibrator is not None
            ):
                for key in self.breaker.due_half_open(now):
                    a, b = key
                    rnd = self.calibrator.run_round(now, true_now, links=[key])
                    probe_rounds.append(rnd)
                    trajectory.append((now, rnd.belief_error))
                    measured = (
                        rnd.records[0].measured_gbps if rnd.records else 0.0
                    )
                    healthy = (
                        measured
                        >= self.breaker.config.heal_ratio
                        * float(np.asarray(self.top.tput)[a, b])
                    )
                    self.belief.reset_link(a, b, max(measured, 1e-6), t_s=now)
                    self.breaker.half_open_result(key, now, healthy)
                    if healthy:
                        self._unquarantine(key)
                        for i in active_indices():
                            self._replan(states[i], i, at_s=now,
                                         reason="quarantine")
                            self._post_replan(states[i])

            # ---- probe round: spend the budget where VoI is highest
            if self.calibrate and self.calibrator is not None:
                samples: dict[tuple[int, int], float] = {}
                if self._confirm_links:
                    # targeted confirmation of contention-masked links (one
                    # or two links, not a sweep): the mask scaled their
                    # telemetry expectation down, so a collapse hiding under
                    # oversubscription never trips the passive detector —
                    # a saturating probe settles contention-vs-drift.
                    # Targeted rounds bypass the dedup window by design.
                    crnd = self.calibrator.run_round(
                        now, true_now, links=sorted(self._confirm_links),
                    )
                    self._confirm_links.clear()
                    probe_rounds.append(crnd)
                    trajectory.append((now, crnd.belief_error))
                    samples.update({
                        (r.src, r.dst): r.measured_gbps for r in crnd.records
                    })
                ctxs, cplans = self._probe_focus(states, act)
                rnd = self.calibrator.run_round(
                    now, true_now,
                    planner=self.planner,
                    contexts=ctxs,
                    plans=cplans,
                )
                probe_rounds.append(rnd)
                trajectory.append((now, rnd.belief_error))
                # probe-driven drift: a probed plan link measured far below
                # what the plan assumed re-plans BEFORE the segment runs
                samples.update({
                    (r.src, r.dst): r.measured_gbps for r in rnd.records
                })
                opened: list[tuple[int, int]] = []
                for i in act:
                    st = states[i]
                    hits = self._probe_drifted_links(st, samples)
                    if hits:
                        note_drift(st, hits, now, "probe")
                        opened += breaker_feed(hits, now)
                        self._replan(st, i, at_s=now)
                        if st.status != "failed":
                            st._assumed = self._assumed_grid(st.plan)
                replan_quarantined_users(opened, now)

            # ---- one segment on the true topology frozen at `now`
            act = active_indices()
            if not act:
                break
            exec_top = self.top.with_tput(true_now)
            active = [states[i] for i in act]
            sim_jobs = [
                TransferJob(
                    plan=st.plan.with_volume(st.remaining_gb),
                    name=st.req.name,
                    arrival_s=max(st.req.arrival_s - now, 0.0),
                    chunk_mb=st.req.chunk_mb,
                )
                for st in active
            ]
            res = sim(
                sim_jobs, (),
                horizon_s=self.check_interval_s,
                seed=seed + 101 * segments,
                link_capacity_scale=link_capacity_scale,
                exec_top=exec_top,
                drain=True,
                **sim_kwargs,
            )
            segments += 1
            sim_events += res.events
            self._fold_segment(active, res, now)
            seg_end = now + res.time_s
            if res.time_s <= 1e-9:
                # every admitted job is still ahead of its arrival: jump
                # the clock to the next arrival instead of spinning the
                # segment counter at a frozen `now`
                pending = [st.req.arrival_s for st in active
                           if st.req.arrival_s > now + 1e-9]
                if pending:
                    seg_end = min(pending)
            boundaries.append(seg_end)
            tr = get_tracer()
            if tr.enabled:
                tr.span("service.segment", now, res.time_s,
                        track="service", seg=segments - 1,
                        jobs=len(active), sim_events=res.events)
                tr.instant("service.boundary", seg_end, track="service",
                           seg=segments - 1)

            # ---- feedback: telemetry -> belief -> drift -> re-plan
            if self.calibrate:
                agg = np.zeros_like(np.asarray(self.top.tput))
                for st in active:
                    g = (st.plan.G if isinstance(st.plan, MulticastPlan)
                         else st.plan.F)
                    agg = agg + np.asarray(g)
                opened = []
                drifted_links: set[tuple[int, int]] = set()
                replanned: set[int] = set()
                for i, jr in zip(act, res.jobs):
                    st = states[i]
                    _, hits = self._harvest(st, jr, t_s=seg_end,
                                            agg_grid=agg)
                    if hits:
                        drifted_links.update(
                            (a, b) for a, b, _, _ in hits
                        )
                    if (
                        hits
                        and st.status in ("planned", "running")
                        and st.remaining_chunks
                    ):
                        note_drift(st, hits, seg_end, "telemetry")
                        opened += breaker_feed(hits, seg_end)
                        self._replan(st, i, at_s=seg_end)
                        replanned.add(i)
                        if st.status != "failed":
                            st._assumed = self._assumed_grid(st.plan)
                # a convicted link re-routes EVERY plan riding it — a
                # co-tenant's telemetry may have been masked by known
                # contention, or its harvest ran after the first job's
                # change-point reset moved the belief onto the collapse
                for a, b in drifted_links:
                    for i in active_indices():
                        if i in replanned:
                            continue
                        st = states[i]
                        g = np.asarray(
                            st.plan.G
                            if isinstance(st.plan, MulticastPlan)
                            else st.plan.F
                        )
                        if g[a, b] > _FLOW_EPS:
                            note_drift(
                                st,
                                [(a, b, float(st._assumed[a, b]),
                                  float(self.belief.mean[a, b]))],
                                seg_end, "telemetry-shared",
                            )
                            self._replan(st, i, at_s=seg_end)
                            replanned.add(i)
                            self._post_replan(st)
                replan_quarantined_users(opened, seg_end)

            # ---- deadline SLOs: escalate pressured jobs down the ladder
            self._deadline_checks(states, seg_end)

            # ---- epoch roll: exploit a belief that rose past the epoch
            # grid. Only ever AT a segment boundary (never mid-segment),
            # only past the hysteresis threshold, and only up to the cap.
            if self.calibrate and len(epoch_rolls) < self.max_epoch_rolls:
                act = active_indices()
                if act:
                    ratio = self._epoch_headroom([states[i] for i in act])
                    if ratio >= self.epoch_roll_threshold:
                        epoch_rolls.append(
                            self._roll_epoch(states, act, seg_end, ratio)
                        )
            now = seg_end

        return CalibratedServiceReport(
            jobs=self._job_reports(states, now),
            time_s=now,
            segments=segments,
            sim_events=sim_events,
            quarantines=(
                list(self.breaker.transitions)
                if self.breaker is not None else []
            ),
            probe_rounds=probe_rounds,
            drift_events=drift_events,
            belief_error_trajectory=trajectory,
            epoch_rolls=epoch_rolls,
            boundaries=boundaries,
        )
