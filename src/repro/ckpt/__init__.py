from .checkpoint import (  # noqa: F401
    CheckpointManager,
    load_checkpoint,
    save_checkpoint,
)
from .replicate import replicate_checkpoint  # noqa: F401
