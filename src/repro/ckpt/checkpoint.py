"""Sharded, atomic, async checkpointing with exact-resume metadata.

Layout (one directory per step):
    step_000042/
      MANIFEST.json          tree structure, shapes/dtypes, step, extra state
      leaf_00000.npy ...     one file per pytree leaf (content-checksummed)
      COMMITTED              written last -> crash-safe atomic commit

Restore reshards: leaves are device_put against the *target* shardings, so a
checkpoint taken on one mesh restores onto another (elastic rescale path).
A background thread makes saves async (training continues); ``wait()``
drains it. ``CheckpointManager`` keeps the newest k checkpoints and finds
the latest committed one at restart (fault-tolerance restore point).
"""

from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path

import jax
import numpy as np

from repro.transfer.chunk import checksum


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(
    directory: str | Path,
    step: int,
    tree,
    *,
    extra: dict | None = None,
) -> Path:
    """Synchronous atomic save. Returns the committed checkpoint path."""
    directory = Path(directory)
    final = directory / f"step_{step:08d}"
    tmp = directory / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _flatten(tree)
    manifest = {
        "step": int(step),
        "treedef": str(treedef),
        "extra": extra or {},
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"].append(
            {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc": checksum(arr.tobytes()),
            }
        )
    (tmp / "MANIFEST.json").write_text(json.dumps(manifest))
    (tmp / "COMMITTED").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def load_checkpoint(
    path: str | Path,
    like,
    *,
    shardings=None,
    verify: bool = True,
):
    """Load into the structure of ``like``; reshard onto ``shardings`` if
    given. Returns (tree, step, extra)."""
    path = Path(path)
    if not (path / "COMMITTED").exists():
        raise FileNotFoundError(f"checkpoint {path} not committed")
    manifest = json.loads((path / "MANIFEST.json").read_text())
    leaves_like, treedef = _flatten(like)
    shard_leaves = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None
        else [None] * len(leaves_like)
    )
    assert len(manifest["leaves"]) == len(leaves_like), (
        f"leaf count mismatch: ckpt {len(manifest['leaves'])} vs "
        f"model {len(leaves_like)}"
    )
    out = []
    for meta, like_leaf, shd in zip(manifest["leaves"], leaves_like, shard_leaves):
        arr = np.load(path / meta["file"])
        if verify and checksum(arr.tobytes()) != meta["crc"]:
            raise IOError(f"checksum mismatch in {meta['file']}")
        want_shape = tuple(getattr(like_leaf, "shape", arr.shape))
        assert tuple(arr.shape) == want_shape, (meta["file"], arr.shape, want_shape)
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.numpy.asarray(arr))
    tree = jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(like), out)
    return tree, manifest["step"], manifest["extra"]


def latest_checkpoint(directory: str | Path) -> Path | None:
    directory = Path(directory)
    if not directory.exists():
        return None
    cands = sorted(
        p for p in directory.iterdir()
        if p.name.startswith("step_") and (p / "COMMITTED").exists()
    )
    return cands[-1] if cands else None


class CheckpointManager:
    """Async saves + retention. One in-flight save at a time (a newer save
    waits for the previous to commit, preserving monotone restore points)."""

    def __init__(self, directory: str | Path, *, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save_async(self, step: int, tree, *, extra: dict | None = None):
        self.wait()
        # device_get on the caller thread (consistent snapshot), IO async
        leaves, treedef = _flatten(tree)
        snapshot = [np.asarray(jax.device_get(l)) for l in leaves]
        tree_host = jax.tree_util.tree_unflatten(treedef, snapshot)

        def run():
            try:
                save_checkpoint(self.directory, step, tree_host, extra=extra)
                self._gc()
            except Exception as ex:  # noqa: BLE001
                self._error = ex

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def latest(self) -> Path | None:
        return latest_checkpoint(self.directory)

    def restore(self, like, *, shardings=None):
        """(tree, step, extra) from the newest committed checkpoint, or
        (None, 0, {}) when none exists."""
        path = self.latest()
        if path is None:
            return None, 0, {}
        return load_checkpoint(path, like, shardings=shardings)

    def _gc(self):
        cands = sorted(
            p for p in self.directory.iterdir() if p.name.startswith("step_")
        )
        for p in cands[: -self.keep]:
            shutil.rmtree(p, ignore_errors=True)
