"""Cross-region checkpoint replication — the framework's verbatim Skyplane
job. After a checkpoint commits, its files are bulk-transferred from the
training region's object store to disaster-recovery regions through the
cost/throughput-optimal overlay, and executed on the real-bytes gateway
chain (transfer.gateway) with checksum verification.
"""

from __future__ import annotations

import dataclasses
from pathlib import Path

from repro.core.planner import Planner
from repro.core.topology import Topology
from repro.transfer.gateway import (
    DirStore,
    GatewayReport,
    ObjectStore,
    transfer_objects,
)


@dataclasses.dataclass
class ReplicationReport:
    destination: str
    plan_tput_gbps: float
    plan_cost: float
    plan_cost_per_gb: float
    relay_regions: list
    gateway: GatewayReport


def replicate_checkpoint(
    ckpt_path: str | Path,
    top: Topology,
    src_region: str,
    dst_regions: list[str],
    dst_stores: dict[str, ObjectStore],
    *,
    cost_ceiling_per_gb: float | None = None,
    tput_floor_gbps: float | None = None,
    max_relays: int = 8,
    volume_gb: float | None = None,
) -> list[ReplicationReport]:
    """Replicate all files of a committed checkpoint to each DR region.

    Exactly one of cost_ceiling_per_gb / tput_floor_gbps selects the
    planner mode (paper §4: tput-max under cost ceiling, or cost-min under
    tput floor). Defaults to cost-min at half the max achievable rate."""
    ckpt_path = Path(ckpt_path)
    src_store = DirStore(ckpt_path)
    keys = src_store.keys()
    if volume_gb is None:
        volume_gb = sum(src_store.size(k) for k in keys) / 1e9
    planner = Planner(top, max_relays=max_relays)

    reports = []
    for dst in dst_regions:
        if cost_ceiling_per_gb is not None:
            plan = planner.plan_tput_max(
                src_region, dst, cost_ceiling_per_gb, volume_gb
            )
        else:
            goal = tput_floor_gbps or planner.max_throughput(src_region, dst) * 0.5
            plan = planner.plan_cost_min(src_region, dst, goal, volume_gb)
        gw = transfer_objects(plan, src_store, dst_stores[dst], keys)
        relays = sorted(
            {r for path, _ in plan.paths() for r in path[1:-1]}
        )
        reports.append(
            ReplicationReport(
                destination=dst,
                plan_tput_gbps=plan.throughput,
                plan_cost=plan.total_cost,
                plan_cost_per_gb=plan.cost_per_gb,
                relay_regions=[top.keys()[r] for r in relays],
                gateway=gw,
            )
        )
    return reports
