"""Cross-region checkpoint replication — the framework's verbatim Skyplane
job. After a checkpoint commits, its files are bulk-transferred from the
training region's object store to the disaster-recovery regions through ONE
multicast overlay (ISSUE 3): the planner builds distribution trees whose
shared hops are billed once, instead of paying source egress per DR region,
and the real-bytes gateway fans chunks out at the relays with per-
destination checksum verification.
"""

from __future__ import annotations

import dataclasses
from pathlib import Path

from repro.core.plan import MulticastPlan
from repro.core.planner import Planner
from repro.core.spec import PlanSpec
from repro.core.topology import Topology
from repro.transfer.gateway import (
    DirStore,
    GatewayReport,
    MulticastGatewayReport,
    ObjectStore,
    transfer_objects_multicast,
)
from repro.transfer.reports import Report


@dataclasses.dataclass
class ReplicationReport(Report):
    """Per-destination view of one multicast replication.

    ``plan_cost`` / ``plan_cost_per_gb`` are the cost of the WHOLE
    one-to-many transfer (shared hops are billed once, so per-destination
    cost is not separable); ``plan_tput_gbps`` is this destination's
    planned delivery rate."""

    destination: str
    plan_tput_gbps: float
    plan_cost: float
    plan_cost_per_gb: float
    relay_regions: list
    gateway: GatewayReport

    kind = "replication"
    _summary_keys = ("destination", "plan_tput_gbps", "plan_cost_per_gb",
                     "relays")

    def _payload(self) -> dict:
        return {
            "destination": self.destination,
            "plan_tput_gbps": self.plan_tput_gbps,
            "plan_cost": self.plan_cost,
            "plan_cost_per_gb": self.plan_cost_per_gb,
            "relays": len(self.relay_regions),
            "relay_regions": list(self.relay_regions),
            "gateway": self.gateway.to_dict(),
        }


def replicate_checkpoint(
    ckpt_path: str | Path,
    top: Topology,
    src_region: str,
    dst_regions: list[str],
    dst_stores: dict[str, ObjectStore],
    *,
    cost_ceiling_per_gb: float | None = None,
    tput_floor_gbps: float | None = None,
    max_relays: int = 8,
    volume_gb: float | None = None,
) -> list[ReplicationReport]:
    """Replicate all files of a committed checkpoint to every DR region
    through one multicast transfer.

    At most one of ``cost_ceiling_per_gb`` / ``tput_floor_gbps`` selects the
    planner mode (paper §4: tput-max under a cost ceiling, or cost-min under
    a per-destination tput floor); passing both raises — silently ignoring
    the floor would hand back a plan violating the caller's SLO. With
    neither, cost-min at half the max achievable uniform rate. Every entry
    of ``dst_regions`` must have a store in ``dst_stores`` — checked before
    any planning or byte movement."""
    if cost_ceiling_per_gb is not None and tput_floor_gbps is not None:
        raise ValueError(
            "pass at most one of cost_ceiling_per_gb / tput_floor_gbps "
            "(they select mutually exclusive planner modes)"
        )
    missing = [d for d in dst_regions if d not in dst_stores]
    if missing:
        raise ValueError(f"dst_regions missing from dst_stores: {missing}")
    if not dst_regions:
        raise ValueError("no destination regions")

    ckpt_path = Path(ckpt_path)
    src_store = DirStore(ckpt_path)
    keys = src_store.keys()
    if volume_gb is None:
        volume_gb = sum(src_store.size(k) for k in keys) / 1e9
    planner = Planner(top, max_relays=max_relays)

    if cost_ceiling_per_gb is not None:
        plan = planner.plan(PlanSpec(
            objective="tput_max", src=src_region, dsts=tuple(dst_regions),
            cost_ceiling_per_gb=cost_ceiling_per_gb, volume_gb=volume_gb,
        ))
    else:
        goal = tput_floor_gbps or planner.plan(PlanSpec(
            objective="max_throughput", src=src_region,
            dsts=tuple(dst_regions),
        )) * 0.5
        plan = planner.plan(PlanSpec(
            objective="cost_min", src=src_region, dsts=tuple(dst_regions),
            tput_goal_gbps=goal, volume_gb=volume_gb,
        ))

    gw = transfer_objects_multicast(
        plan, src_store, dst_stores, keys
    )
    return reports_from(plan, gw, dst_regions, top)


def reports_from(
    plan: MulticastPlan,
    gw: MulticastGatewayReport,
    dst_regions: list[str],
    top: Topology,
) -> list[ReplicationReport]:
    """Per-destination ReplicationReports for a finished multicast run."""
    reports = []
    for dst in dst_regions:
        d = top.index(dst)
        relays = sorted(
            {r for path, _ in plan.paths_to(d) for r in path[1:-1]}
        )
        reports.append(
            ReplicationReport(
                destination=dst,
                plan_tput_gbps=plan.delivered_gbps(d),
                plan_cost=plan.total_cost,
                plan_cost_per_gb=plan.cost_per_gb,
                relay_regions=[top.keys()[r] for r in relays],
                gateway=gw.per_dest[dst],
            )
        )
    return reports
