from .base import ModelConfig, MoEConfig, SSMConfig, reduced  # noqa: F401
from .archs import ARCHS, get_arch  # noqa: F401
from .shapes import SHAPES, ShapeSpec, applicable, cells  # noqa: F401
