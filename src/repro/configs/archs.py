"""The 10 assigned architectures, exact dims from the brief.

Each also has its own module (``repro/configs/<id>.py``) exporting CONFIG,
so ``--arch <id>`` resolves either via this registry or the module path.
"""

from __future__ import annotations

from .base import ModelConfig, MoEConfig, SSMConfig

# [hf:HuggingFaceTB/SmolLM-135M] llama-arch small; GQA 9H/kv3
SMOLLM_135M = ModelConfig(
    name="smollm-135m",
    num_layers=30, d_model=576, num_heads=9, num_kv_heads=3,
    d_ff=1536, vocab_size=49152, head_dim=64,
    activation="silu", rope_theta=1e4, tie_embeddings=True,
)

# [arXiv:2402.16819] GQA, squared-ReLU MLP
NEMOTRON_4_340B = ModelConfig(
    name="nemotron-4-340b",
    num_layers=96, d_model=18432, num_heads=96, num_kv_heads=8,
    d_ff=73728, vocab_size=256000, head_dim=192,
    activation="relu2", rope_theta=1e4,
)

# [hf:mistralai/Mistral-Large-Instruct-2407]
MISTRAL_LARGE_123B = ModelConfig(
    name="mistral-large-123b",
    num_layers=88, d_model=12288, num_heads=96, num_kv_heads=8,
    d_ff=28672, vocab_size=32768, head_dim=128,
    activation="silu", rope_theta=1e6,
)

# [arXiv:2407.10671] GQA with QKV bias
QWEN2_7B = ModelConfig(
    name="qwen2-7b",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152064, head_dim=128,
    activation="silu", qkv_bias=True, rope_theta=1e6,
)

# [hf:meta-llama/Llama-3.2-11B-Vision] cross-attn image layers every 5th
LLAMA_32_VISION_11B = ModelConfig(
    name="llama-3.2-vision-11b",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256, head_dim=128,
    activation="silu", rope_theta=5e5,
    cross_attn_every=5, num_vision_tokens=1601,
)

# [arXiv:2411.15242] Mamba2 backbone + shared attention block
ZAMBA2_7B = ModelConfig(
    name="zamba2-7b",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000, head_dim=112,
    activation="gelu", rope_theta=1e4,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=256),
    hybrid_attn_every=3,  # 27 scan groups x (3 mamba blocks + shared attn)
)

# [arXiv:2401.04088] 8 experts top-2, sliding-window attention
MIXTRAL_8X22B = ModelConfig(
    name="mixtral-8x22b",
    num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=32768, head_dim=128,
    activation="silu", rope_theta=1e6, sliding_window=4096,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=16384),
)

# [hf:Qwen/Qwen3-30B-A3B] 128 experts top-8
QWEN3_MOE_30B_A3B = ModelConfig(
    name="qwen3-moe-30b-a3b",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
    d_ff=768, vocab_size=151936, head_dim=128,
    activation="silu", rope_theta=1e6,
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=768),
)

# [arXiv:2405.21060] pure SSD (state-space duality), attention-free
MAMBA2_1_3B = ModelConfig(
    name="mamba2-1.3b",
    num_layers=48, d_model=2048, num_heads=1, num_kv_heads=1,
    d_ff=0, vocab_size=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    tie_embeddings=True,
)

# [arXiv:2308.11596] encoder-decoder over audio frames (frontend stubbed)
SEAMLESS_M4T_MEDIUM = ModelConfig(
    name="seamless-m4t-medium",
    num_layers=12, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=256206, head_dim=64,
    activation="gelu", rope_theta=1e4,
    encoder_layers=12, num_frames=960,
)

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        SMOLLM_135M, NEMOTRON_4_340B, MISTRAL_LARGE_123B, QWEN2_7B,
        LLAMA_32_VISION_11B, ZAMBA2_7B, MIXTRAL_8X22B, QWEN3_MOE_30B_A3B,
        MAMBA2_1_3B, SEAMLESS_M4T_MEDIUM,
    ]
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]
