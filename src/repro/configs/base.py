"""Model/architecture configuration for the assigned-architecture pool.

Every architecture in the pool is expressible as a ``ModelConfig``:
dense decoder, GQA/MHA, sliding-window attention, MoE FFN, Mamba2 SSD
blocks (pure or hybrid-with-shared-attention), cross-attention (VLM),
and encoder-decoder (audio). Modality frontends are stubs per the brief:
``input_specs`` provides precomputed patch/frame embeddings.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256  # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default: d_model // num_heads
    activation: str = "silu"  # "silu" | "gelu" | "relu2"
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None  # SWA width (tokens)
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # mixture of experts (FFN replaced in every layer when set)
    moe: Optional[MoEConfig] = None

    # state-space blocks. ssm set + hybrid_attn_every=None => pure SSM stack.
    # hybrid_attn_every=k  => one *shared* attention+MLP block applied after
    # every k SSM blocks (Zamba2-style parameter sharing).
    ssm: Optional[SSMConfig] = None
    hybrid_attn_every: Optional[int] = None

    # VLM: a cross-attention layer after every (cross_attn_every-1) self-attn
    # layers; vision tokens come precomputed from the (stubbed) frontend.
    cross_attn_every: Optional[int] = None
    num_vision_tokens: int = 0

    # encoder-decoder (audio): encoder over precomputed frame embeddings.
    encoder_layers: int = 0
    num_frames: int = 0

    # numerics / execution
    dtype: str = "bfloat16"  # activation/compute dtype
    param_dtype: str = "float32"
    remat: bool = True  # per-block activation checkpointing in training
    loss_chunk: int = 512  # sequence-chunked cross-entropy (memory bound)
    use_pallas: bool = False  # TPU kernels (ref paths on CPU)
    # Fully unroll layer/loss scans when lowering. Scanned loops compile
    # faster, but XLA's cost analysis counts a while body ONCE — unrolled
    # lowering gives trip-count-faithful FLOP/byte/collective numbers for the
    # roofline (launch/dryrun uses unroll for the single-pod roofline cells).
    scan_unroll: bool = False
    # Unroll the *inner* fixed-trip scans (chunked loss, SSD state recurrence)
    # whose trip counts don't vary with layer count — the probe-delta method
    # can't extrapolate those, so the dry-run unrolls them instead.
    inner_unroll: bool = False

    # ---- §Perf hillclimb variants (False == paper-faithful baseline) ----
    # Shard the embedding table on d_model instead of vocab: the gather then
    # has its indexed dim unsharded -> no involuntary replication of the
    # [B,S,D] lookup (XLA SPMD warning), no all-gather of the table.
    embed_dmodel_shard: bool = False
    # Shard-local MoE dispatch: route/sort/position per data shard (batched
    # ops, no cross-shard argsort), capacity-sharded dispatch buffers, and
    # expert weights with a TP fallback on d_ff when the expert count doesn't
    # divide the model axis (mixtral: 8 experts vs 16-wide TP).
    moe_shard_dispatch: bool = False
    # Attention score/weight buffers in bf16 (max-subtracted, f32 row sums):
    # halves the dominant O(S^2) bytes of the ref attention path.
    attn_scores_bf16: bool = False
    # Activation-checkpoint policy: "full" (recompute everything, paper-era
    # default), "dots" (save matmul outputs, recompute elementwise only),
    # "none" (no remat).
    remat_policy: str = "full"
    # MoE combine as scatter-from-experts + psum instead of gathering the
    # expert-sharded dispatch buffer (cuts combine collective bytes ~E/TP x).
    moe_psum_combine: bool = False
    # Cast params to the compute dtype ONCE per step (before FSDP gathers)
    # instead of per-use: the all-gather then moves bf16, not f32 — half the
    # parameter collective bytes.
    cast_params_once: bool = False

    # ---------------------------------------------------------------- helpers
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_per_kv(self) -> int:
        assert self.num_heads % self.num_kv_heads == 0
        return self.num_heads // self.num_kv_heads

    @property
    def is_ssm(self) -> bool:
        return self.ssm is not None and self.hybrid_attn_every is None

    @property
    def is_hybrid(self) -> bool:
        return self.ssm is not None and self.hybrid_attn_every is not None

    @property
    def is_enc_dec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_vlm(self) -> bool:
        return self.cross_attn_every is not None

    @property
    def sub_quadratic(self) -> bool:
        """Supports 500k-token decode without a full-context KV-cost blowup
        growing quadratically at prefill (SSM / hybrid / sliding-window)."""
        return self.ssm is not None or self.sliding_window is not None

    @property
    def supports_decode(self) -> bool:
        return True  # all pool members are (or contain) decoders

    def scan_groups(self) -> tuple[int, int]:
        """(num_scan_steps, layers_per_step) for the decoder stack."""
        if self.is_hybrid:
            k = self.hybrid_attn_every
            assert self.num_layers % k == 0, (self.num_layers, k)
            return self.num_layers // k, k
        if self.is_vlm:
            k = self.cross_attn_every
            assert self.num_layers % k == 0
            return self.num_layers // k, k
        return self.num_layers, 1

    def param_count(self) -> int:
        """Total parameters (for 6*N*D roofline accounting)."""
        from repro.models.model import count_params  # lazy, avoids cycle

        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params

        return count_params(self, active_only=True)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    groups, per = cfg.scan_groups()
    small_layers = per * min(2, groups)
    heads = min(cfg.num_heads, 4)
    q_per_kv = max(1, cfg.num_heads // cfg.num_kv_heads)
    kv = max(1, heads // min(q_per_kv, heads))
    base = dict(
        num_layers=small_layers,
        d_model=64,
        num_heads=heads,
        num_kv_heads=kv,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
        num_vision_tokens=16 if cfg.is_vlm else 0,
        encoder_layers=2 if cfg.is_enc_dec else 0,
        num_frames=24 if cfg.is_enc_dec else 0,
        sliding_window=16 if cfg.sliding_window else None,
        loss_chunk=32,
        remat=False,
    )
    if cfg.moe:
        base["moe"] = MoEConfig(
            num_experts=min(cfg.moe.num_experts, 8),
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=64,
            capacity_factor=cfg.moe.capacity_factor,
        )
    if cfg.ssm:
        base["ssm"] = SSMConfig(
            d_state=16, d_conv=cfg.ssm.d_conv, expand=2, head_dim=16, chunk=16
        )
    base.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **base)
