"""--arch config module (exact dims in archs.py)."""
from .archs import LLAMA_32_VISION_11B as CONFIG  # noqa: F401
from .base import reduced

SMOKE = reduced(CONFIG)
