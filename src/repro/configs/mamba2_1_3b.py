"""--arch config module (exact dims in archs.py)."""
from .archs import MAMBA2_1_3B as CONFIG  # noqa: F401
from .base import reduced

SMOKE = reduced(CONFIG)
