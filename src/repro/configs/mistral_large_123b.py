"""--arch config module (exact dims in archs.py)."""
from .archs import MISTRAL_LARGE_123B as CONFIG  # noqa: F401
from .base import reduced

SMOKE = reduced(CONFIG)
