"""--arch config module (exact dims in archs.py)."""
from .archs import MIXTRAL_8X22B as CONFIG  # noqa: F401
from .base import reduced

SMOKE = reduced(CONFIG)
