"""--arch config module (exact dims in archs.py)."""
from .archs import NEMOTRON_4_340B as CONFIG  # noqa: F401
from .base import reduced

SMOKE = reduced(CONFIG)
