"""--arch config module (exact dims in archs.py)."""
from .archs import QWEN2_7B as CONFIG  # noqa: F401
from .base import reduced

SMOKE = reduced(CONFIG)
