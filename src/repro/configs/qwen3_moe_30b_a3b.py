"""--arch config module (exact dims in archs.py)."""
from .archs import QWEN3_MOE_30B_A3B as CONFIG  # noqa: F401
from .base import reduced

SMOKE = reduced(CONFIG)
