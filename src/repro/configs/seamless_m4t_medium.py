"""--arch config module (exact dims in archs.py)."""
from .archs import SEAMLESS_M4T_MEDIUM as CONFIG  # noqa: F401
from .base import reduced

SMOKE = reduced(CONFIG)
