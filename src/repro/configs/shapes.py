"""Assigned input shapes (the brief's 4 LM shapes) and per-cell applicability.

  train_4k      seq 4,096  x global_batch 256   -> train_step
  prefill_32k   seq 32,768 x global_batch 32    -> serve prefill
  decode_32k    seq 32,768 x global_batch 128   -> serve_step (1 new token,
                                                   KV/SSM state of seq_len)
  long_500k     seq 524,288 x global_batch 1    -> serve_step; requires a
                sub-quadratic context mechanism (SSM / hybrid / SWA). Pure
                full-attention archs skip it (recorded as N/A per DESIGN.md).
"""

from __future__ import annotations

import dataclasses

from .base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in [TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K]}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) for an (arch x shape) cell."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "pure full-attention arch: 500k decode requires sub-quadratic "
            "context (DESIGN.md §4)"
        )
    return True, ""


def cells(archs: dict[str, ModelConfig]):
    """All 40 (arch, shape) cells with applicability annotations."""
    out = []
    for aname, cfg in archs.items():
        for sname, shape in SHAPES.items():
            runs, why = applicable(cfg, shape)
            out.append((aname, sname, runs, why))
    return out
