"""--arch config module (exact dims in archs.py)."""
from .archs import SMOLLM_135M as CONFIG  # noqa: F401
from .base import reduced

SMOKE = reduced(CONFIG)
