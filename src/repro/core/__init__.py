"""Skyplane's contribution: cost/throughput-optimal overlay planning (paper §4-§5)."""

from .topology import Region, Topology, GBIT_PER_GB  # noqa: F401
from .profiles import default_topology, grid_fingerprint, toy_topology  # noqa: F401
from .plan import McTree, MulticastPlan, TransferPlan  # noqa: F401
from .spec import PlanSpec  # noqa: F401
from .planner import Planner, ParetoPoint  # noqa: F401
from .ron import ron_plan  # noqa: F401
from .baselines import (  # noqa: F401
    AWS_DATASYNC,
    AZURE_AZCOPY,
    GCP_STORAGE_TRANSFER,
    CloudServiceModel,
    direct_plan,
    gridftp_plan,
)

__all__ = [
    "AWS_DATASYNC",
    "AZURE_AZCOPY",
    "GBIT_PER_GB",
    "GCP_STORAGE_TRANSFER",
    "CloudServiceModel",
    "McTree",
    "MulticastPlan",
    "ParetoPoint",
    "PlanSpec",
    "Planner",
    "Region",
    "Topology",
    "TransferPlan",
    "default_topology",
    "direct_plan",
    "grid_fingerprint",
    "gridftp_plan",
    "ron_plan",
    "toy_topology",
]
