"""Non-overlay baselines used throughout the paper's evaluation.

  * ``direct_plan``       — Skyplane with overlay routing disabled (the
    ablation baseline of §7.3/Fig. 7): N VMs at each endpoint, direct path.
  * ``gridftp_plan``      — GridFTP-style (§7.6/Table 2): single VM pair,
    direct path, parallel TCP with *static round-robin* chunk assignment
    (the data plane honors the static assignment, exposing stragglers).
  * ``cloud_service_model`` — throughput/price models for the managed
    transfer services Skyplane is compared against in Fig. 6. The services
    are closed-source; we model them as direct-path transfers at a measured
    service rate plus the provider's per-GB service fee.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .plan import TransferPlan
from .topology import Topology


def direct_plan(
    top: Topology, src: str, dst: str, volume_gb: float, *, num_vms: int = 8
) -> TransferPlan:
    s, t = top.index(src), top.index(dst)
    v = top.num_regions
    n = min(num_vms, top.limit_vm)
    tput = float(
        n * min(top.tput[s, t], top.limit_egress[s], top.limit_ingress[t])
    )
    F = np.zeros((v, v))
    M = np.zeros((v, v))
    N = np.zeros(v)
    F[s, t] = tput
    M[s, t] = top.limit_conn * n
    N[s] = N[t] = n
    return TransferPlan(
        top=top, src=s, dst=t, tput_goal=tput, volume_gb=volume_gb,
        F=F, N=N, M=M, solver_status="direct",
    )


def gridftp_plan(
    top: Topology, src: str, dst: str, volume_gb: float
) -> TransferPlan:
    """Single VM per region, direct path (GCT GridFTP per §7.6)."""
    plan = direct_plan(top, src, dst, volume_gb, num_vms=1)
    plan.solver_status = "gridftp"
    return plan


@dataclasses.dataclass
class CloudServiceModel:
    """A managed transfer service (Fig. 6 comparison)."""

    name: str
    provider: str  # destination cloud that offers the service
    # Effective service throughput as a fraction of the direct-path grid tput
    # (these services use provider-internal resources; the paper measures
    # Skyplane at 4.6x DataSync and 5.0x GCP ST on its slowest routes).
    rate_fraction: float
    service_fee_per_gb: float

    def transfer_time_s(
        self, top: Topology, src: str, dst: str, volume_gb: float
    ) -> float:
        s, t = top.index(src), top.index(dst)
        # managed services run a fixed small worker pool on the direct path
        gbps = max(top.tput[s, t] * self.rate_fraction, 0.05)
        return volume_gb * 8.0 / gbps

    def cost(self, top: Topology, src: str, dst: str, volume_gb: float) -> float:
        s, t = top.index(src), top.index(dst)
        return volume_gb * (top.price_egress[s, t] + self.service_fee_per_gb)


# Fig. 6 comparison set. rate_fraction calibrated so that the slowest routes
# reproduce the paper's headline speedups (4.6x vs DataSync intra-AWS, 5.0x
# vs GCP Storage Transfer inter-cloud) when Skyplane runs with 8 VMs.
AWS_DATASYNC = CloudServiceModel("aws-datasync", "aws", 1.60, 0.0125)
GCP_STORAGE_TRANSFER = CloudServiceModel("gcp-storage-transfer", "gcp", 1.45, 0.0)
AZURE_AZCOPY = CloudServiceModel("azure-azcopy", "azure", 6.0, 0.0)
