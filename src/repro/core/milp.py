"""Skyplane's MILP formulation (paper §5.1.4, Eq. 4a-4j) as LP matrices.

Decision vector layout:  x = [ F (E), N (V), M (E) ]
  F_e  >= 0  flow on directed edge e (Gbit/s)
  N_v  >= 0  VMs provisioned in region v         (integer in the MILP)
  M_e  >= 0  TCP connections on edge e (pooled across the region pair;
             integer in the MILP)

Objective (Eq. 4a): minimize  (VOLUME / TPUT_GOAL) * (<F, Cost_egress> + <N, Cost_vm>)
The leading factor is a positive constant after the paper's linear
reformulation (transfer time == VOLUME / TPUT_GOAL), so the LP minimizes the
unscaled "cost per second" and the caller scales afterwards.

Constraints (paper numbering):
  4b  F_e <= (Limit_link_e / Limit_conn) * M_e      per-connection throughput
  4c  sum_v F_{s,v} >= TPUT_GOAL                    source egress meets goal
  4d  sum_u F_{u,t} >= TPUT_GOAL                    dest ingress meets goal
  4e  flow conservation at every v not in {s, t}
  4f  sum_u F_{u,v} <= Limit_ingress_v * N_v        per-VM ingress scaled by VMs
  4g  sum_w F_{u,w} <= Limit_egress_u * N_u         per-VM egress scaled by VMs
  4h  sum_w M_{u,w} <= Limit_conn * N_u             outgoing conns per region
  4i  sum_u M_{u,v} <= Limit_conn * N_v             incoming conns per region
  4j  N_v <= Limit_vm

ERRATUM NOTE: the paper's printed 4h/4i bound region u's outgoing connections
by N_v and incoming by N_u — a typesetting slip (the text of §5.1.2 says "the
maximum number of egress TCP connections per region [scales] by the number of
VMs provisioned in each region"). We implement the semantically consistent
version above.

Assembly is split in two layers so the planner's hot path (thousands of
solves per (src, dst) pair — round-down refits, B&B nodes, Pareto sweeps)
never re-runs the O(rows * cols) construction:

  * ``LPStructure`` — built once per (topology, src, dst) by vectorized
    scatter-index assembly, cached on the Topology instance.  Holds the full
    A_ub/A_eq/c plus precomputed "pin patterns" (column partitions + reduced
    matrices) for the fixed-N and fixed-N+M refits of §5.1.3.
  * ``LPStructure.lp(...)`` — O(rows) derivation of a concrete ``LPData``
    for a given (tput_goal, fixed_n, fixed_m, extra_ub): copies b, shifts the
    RHS by the pinned values, and reuses the cached reduced matrices.

``build_lp`` keeps the original one-shot signature on top of the cache, and
``build_lp_reference`` keeps the original pure-Python row-loop assembly as
the oracle for equivalence tests and as the pre-optimization benchmark
baseline.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.obs.metrics import REGISTRY
from repro.obs.trace import get_tracer

from .topology import GBIT_PER_GB, Topology

_ZERO_ROW_TOL = 1e-12
_RHS_TOL = 1e-9

# Running count of LPStructure assemblies (the O(rows*cols) construction).
# Re-planning on a degraded topology must be a pure cache hit: tests snapshot
# this counter around a re-plan and assert it did not move. The count lives
# in the observability plane's registry; the module attribute
# ``N_STRUCT_BUILDS`` survives as a bitwise-compatible read alias below.
_struct_builds = REGISTRY.counter("planner.struct_builds")
_lp_cache_hits = REGISTRY.counter("planner.lp_cache_hits")
_lp_cache_misses = REGISTRY.counter("planner.lp_cache_misses")


def __getattr__(name: str):
    # PEP 562 read alias: ``milp.N_STRUCT_BUILDS`` (and ``from ... import``)
    # keeps returning the plain int every zero-re-assembly pin snapshots.
    if name == "N_STRUCT_BUILDS":
        return int(_struct_builds.value)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclasses.dataclass
class LPData:
    """min c@x  s.t.  A_ub@x <= b_ub,  A_eq@x = b_eq,  x >= 0."""

    c: np.ndarray
    A_ub: np.ndarray
    b_ub: np.ndarray
    A_eq: np.ndarray
    b_eq: np.ndarray
    integer_mask: np.ndarray  # True where x must be integral in the MILP
    # bookkeeping for unpacking solutions
    edges: list[tuple[int, int]]
    num_regions: int
    src: int
    dst: int
    tput_goal: float
    row_4c: int  # row index of the source-egress constraint in A_ub
    row_4d: int
    # fixed-variable elimination (round-down refits): full-space values for
    # pinned variables; solver variables are the free columns only. F columns
    # come first and are never pinned, so F indices are stable.
    fixed_values: np.ndarray | None = None  # [nx_full] nan where free
    trivially_infeasible: bool = False

    @property
    def n_edges(self) -> int:
        return len(self.edges)

    def _full_x(self, x: np.ndarray) -> np.ndarray:
        if self.fixed_values is None:
            return x
        full = self.fixed_values.copy()
        full[np.isnan(self.fixed_values)] = x
        return full

    def split(self, x: np.ndarray) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """solver x -> (F [V,V], N [V], M [V,V])."""
        x = self._full_x(np.asarray(x, dtype=float))
        e, v = self.n_edges, self.num_regions
        eu, ew = _edge_arrays(self.edges)
        F = np.zeros((v, v))
        M = np.zeros((v, v))
        F[eu, ew] = x[:e]
        M[eu, ew] = x[e + v :]
        N = np.asarray(x[e : e + v], dtype=float).copy()
        return F, N, M


def _edge_arrays(edges: list[tuple[int, int]]) -> tuple[np.ndarray, np.ndarray]:
    arr = np.asarray(edges, dtype=np.int64).reshape(len(edges), 2)
    return arr[:, 0], arr[:, 1]


def _scale_cut_rows(
    nx: int,
    m_col: int,
    tput_e: np.ndarray,
    limit_conn: float,
    edge_scale: np.ndarray,
    agg_cap: float | np.ndarray | None,
    tol: float,
) -> list[tuple[np.ndarray, float]]:
    """Shared body of the unicast/multicast ``scale_cuts``: per edge with
    phi < 1, a tightened 4b row (flow column k vs M column m_col + k) and,
    with ``agg_cap``, an aggregate interconnect row.

    ``agg_cap`` is a scalar (data-plane capacity factor: aggregate rows
    only where phi < 1, since an uncapped healthy link never binds) or a
    per-edge array (per-tenant fair-share caps: an aggregate row for EVERY
    edge with a finite entry, even healthy ones — a tenant's share of a
    contended link binds regardless of drift). Non-finite array entries
    mean "this edge is not share-capped"."""
    cuts: list[tuple[np.ndarray, float]] = []
    coef = tput_e / limit_conn
    agg_arr = None
    if agg_cap is not None and np.ndim(agg_cap) > 0:
        agg_arr = np.asarray(agg_cap, dtype=float)
        if agg_arr.shape != tput_e.shape:
            raise ValueError(
                f"per-edge agg_cap must have shape {tput_e.shape}, "
                f"got {agg_arr.shape}"
            )
    for k in np.flatnonzero(edge_scale < 1.0 - tol):
        phi = float(edge_scale[k])
        row = np.zeros(nx)
        row[k] = 1.0
        row[m_col + k] = -phi * coef[k]
        cuts.append((row, 0.0))
        if agg_cap is not None and agg_arr is None:
            agg = np.zeros(nx)
            agg[k] = 1.0
            cuts.append((agg, phi * float(tput_e[k]) * float(agg_cap)))
    if agg_arr is not None:
        for k in np.flatnonzero(np.isfinite(agg_arr)):
            phi = min(float(edge_scale[k]), 1.0)
            agg = np.zeros(nx)
            agg[k] = 1.0
            cuts.append((agg, phi * float(tput_e[k]) * float(agg_arr[k])))
    return cuts


@dataclasses.dataclass
class PinPattern:
    """Column partition + reduced matrices for one (pin_n, pin_m) choice.

    Rows of A_ub whose free part is structurally zero are dropped from
    ``A_ub_free``; their RHS (after the pinned shift) is only checked for
    trivial infeasibility. Which rows those are depends solely on the edge
    structure, so the masks are precomputed here.
    """

    pinned: np.ndarray  # [nx] bool
    A_ub_free: np.ndarray  # [m_keep, n_free]
    A_ub_pin: np.ndarray  # [m_ub, n_pin] (all rows, for RHS shifts)
    keep_ub: np.ndarray  # [m_ub] bool
    drop_ub: np.ndarray  # [m_ub] bool
    A_eq_free: np.ndarray  # [m_eq_keep, n_free]
    keep_eq: np.ndarray
    drop_eq: np.ndarray
    c_free: np.ndarray
    integer_mask_free: np.ndarray
    row_4c: int  # goal rows remapped into kept-row space (-1 if dropped)
    row_4d: int

    @property
    def n_free(self) -> int:
        return self.A_ub_free.shape[1]


class LPStructure:
    """Vectorized, cached assembly of Eq. 4a-4j for one (top, src, dst)."""

    def __init__(self, top: Topology, src: int, dst: int):
        _struct_builds.inc()
        self.top = top
        self.src = src
        self.dst = dst
        self.edges = top.edge_list(src, dst)
        self.eu, self.ew = _edge_arrays(self.edges)
        e, v = len(self.edges), top.num_regions
        self.n_edges = e
        self.num_regions = v
        nx = 2 * e + v
        self.nx = nx
        self.row_4c = e
        self.row_4d = e + 1
        ar = np.arange(e)

        # ---- objective (Eq. 4a without the constant factor)
        c = np.zeros(nx)
        c[:e] = top.price_egress[self.eu, self.ew] / GBIT_PER_GB
        c[e : e + v] = top.price_vm
        self.c = c

        # ---- A_ub, rows in the fixed order 4b | 4c | 4d | 4f | 4g | 4h | 4i | 4j
        m_ub = e + 2 + 5 * v
        A = np.zeros((m_ub, nx))
        b0 = np.zeros(m_ub)
        # 4b
        A[ar, ar] = 1.0
        A[ar, e + v + ar] = -top.tput[self.eu, self.ew] / top.limit_conn
        # 4c / 4d (b filled per-goal in lp())
        A[e, ar[self.eu == src]] = -1.0
        A[e + 1, ar[self.ew == dst]] = -1.0
        # 4f / 4g
        A[e + 2 + self.ew, ar] = 1.0
        A[e + 2 + np.arange(v), e + np.arange(v)] = -top.limit_ingress
        A[e + 2 + v + self.eu, ar] = 1.0
        A[e + 2 + v + np.arange(v), e + np.arange(v)] = -top.limit_egress
        # 4h / 4i
        A[e + 2 + 2 * v + self.eu, e + v + ar] = 1.0
        A[e + 2 + 2 * v + np.arange(v), e + np.arange(v)] = -float(top.limit_conn)
        A[e + 2 + 3 * v + self.ew, e + v + ar] = 1.0
        A[e + 2 + 3 * v + np.arange(v), e + np.arange(v)] = -float(top.limit_conn)
        # 4j
        A[e + 2 + 4 * v + np.arange(v), e + np.arange(v)] = 1.0
        b0[e + 2 + 4 * v :] = float(top.limit_vm)
        self.A_ub = A
        self.b_ub0 = b0

        # ---- A_eq: flow conservation at touched relays (ascending region id)
        full = np.zeros((v, nx))
        np.add.at(full, (self.ew, ar), 1.0)
        np.add.at(full, (self.eu, ar), -1.0)
        touched = np.zeros(v, dtype=bool)
        touched[self.eu] = True
        touched[self.ew] = True
        relay = touched.copy()
        relay[[src, dst]] = False
        self.A_eq = full[relay] if relay.any() else np.zeros((0, nx))
        self.b_eq = np.zeros(self.A_eq.shape[0])

        self.integer_mask = np.zeros(nx, dtype=bool)
        self.integer_mask[e:] = True  # N and M

        self._pin_patterns: dict[tuple[bool, bool], PinPattern] = {}
        self._reduced_cache: dict = {}

    # ------------------------------------------------------------ pin patterns
    def pin_pattern(self, pin_n: bool, pin_m: bool) -> PinPattern:
        key = (pin_n, pin_m)
        pat = self._pin_patterns.get(key)
        if pat is not None:
            return pat
        e, v = self.n_edges, self.num_regions
        pinned = np.zeros(self.nx, dtype=bool)
        if pin_n:
            pinned[e : e + v] = True
        if pin_m:
            pinned[e + v :] = True
        free = ~pinned
        A_ub_free = self.A_ub[:, free]
        A_eq_free = self.A_eq[:, free]
        drop_ub = (
            np.abs(A_ub_free).max(axis=1, initial=0.0) < _ZERO_ROW_TOL
            if pinned.any()
            else np.zeros(self.A_ub.shape[0], dtype=bool)
        )
        drop_eq = (
            np.abs(A_eq_free).max(axis=1, initial=0.0) < _ZERO_ROW_TOL
            if (pinned.any() and self.A_eq.size)
            else np.zeros(self.A_eq.shape[0], dtype=bool)
        )
        keep_ub = ~drop_ub
        keep_eq = ~drop_eq
        newpos = np.cumsum(keep_ub) - 1
        pat = PinPattern(
            pinned=pinned,
            A_ub_free=np.ascontiguousarray(A_ub_free[keep_ub]),
            A_ub_pin=np.ascontiguousarray(self.A_ub[:, pinned]),
            keep_ub=keep_ub,
            drop_ub=drop_ub,
            A_eq_free=np.ascontiguousarray(A_eq_free[keep_eq]),
            keep_eq=keep_eq,
            drop_eq=drop_eq,
            c_free=self.c[free],
            integer_mask_free=self.integer_mask[free],
            row_4c=int(newpos[self.row_4c]) if keep_ub[self.row_4c] else -1,
            row_4d=int(newpos[self.row_4d]) if keep_ub[self.row_4d] else -1,
        )
        self._pin_patterns[key] = pat
        return pat

    def pin_values(
        self, fixed_n: np.ndarray | None, fixed_m: np.ndarray | None
    ) -> np.ndarray:
        """Full-space fixed-value vector (nan where free)."""
        e, v = self.n_edges, self.num_regions
        fv = np.full(self.nx, np.nan)
        if fixed_n is not None:
            fv[e : e + v] = np.asarray(fixed_n, dtype=float)
        if fixed_m is not None:
            fm = np.asarray(fixed_m, dtype=float)
            fv[e + v :] = fm[self.eu, self.ew]
        return fv

    def outflow_c(self, pat: PinPattern | None = None) -> np.ndarray:
        """c with min c@x == max source outflow (F columns lead and are never
        pinned, so the same vector works for any pin pattern)."""
        n = pat.n_free if pat is not None else self.nx
        c = np.zeros(n)
        c[np.flatnonzero(self.eu == self.src)] = -1.0
        return c

    # ------------------------------------------------------------- scale cuts
    def scale_cuts(
        self,
        edge_scale: np.ndarray,
        agg_cap: float | np.ndarray | None = None,
        tol: float = 1e-9,
    ) -> list[tuple[np.ndarray, float]]:
        """Tightened rows for a per-edge throughput scale vector.

        ``edge_scale[k]`` (aligned with ``self.edges``) rescales edge k's
        grid throughput. For every edge with phi < 1 (phi >= 1 never
        binds next to the base 4b row and is skipped) this emits:

          * a tightened 4b row  ``F_k <= phi * tput_k / limit_conn * M_k``
            — the per-connection rate on a drifted link is down by phi;
          * with ``agg_cap`` (the data plane's shared-link capacity factor,
            ``link_capacity_scale``): an AGGREGATE row
            ``F_k <= phi * tput_k * agg_cap`` — an interconnect incident
            caps the wide-area link itself, so the solver cannot buy the
            loss back with more VMs and connections.

        ``agg_cap`` may also be a per-edge array (non-finite = uncapped):
        then an aggregate row ``F_k <= min(phi,1) * tput_k * agg_cap[k]``
        is emitted for every finite entry, drifted or not — the fleet
        controller's per-tenant fair-share caps on shared structures.

        This is how the calibration plane plans against a lower-confidence-
        bound grid: the scale vector rides the CACHED structure as
        ``extra_ub`` rows — exactly the degraded-link discipline — so a
        robust (re-)plan assembles nothing (``N_STRUCT_BUILDS`` does not
        move)."""
        edge_scale = np.asarray(edge_scale, dtype=float)
        if edge_scale.shape != (self.n_edges,):
            raise ValueError(
                f"edge_scale must have shape ({self.n_edges},), "
                f"got {edge_scale.shape}"
            )
        return _scale_cut_rows(
            self.nx, self.n_edges + self.num_regions,
            self.top.tput[self.eu, self.ew], self.top.limit_conn,
            edge_scale, agg_cap, tol,
        )

    # ----------------------------------------------------------- exact presolve
    def reduced(
        self,
        region_support: np.ndarray,
        edge_mask: np.ndarray | None = None,
    ) -> tuple["LPStructure", np.ndarray] | None:
        """Exact presolve for pinned solves: the sub-structure over supported
        regions (N_v > 0) and, optionally, supported edges (M_e > 0).

        With N_v = 0 pinned, 4f/4g force all flow through v to zero and 4h/4i
        force its connections to zero; with M_e = 0 pinned, 4b forces F_e = 0.
        Dropping those variables (and the rows that become empty) is lossless:
        the reduced LP's optimum extends by zeros to the full LP's optimum.
        Round-down refits typically keep 2-4 of 12 regions, shrinking the LP
        ~100x. Returns (sub-structure, kept region indices) — cached per
        (support, edge-mask) — or None when src/dst lost support or no edge
        survived (max-flow 0 / infeasible at any positive goal).
        """
        region_support = np.asarray(region_support, dtype=bool)
        if not (region_support[self.src] and region_support[self.dst]):
            return None
        key = (
            region_support.tobytes(),
            None if edge_mask is None else np.asarray(edge_mask, bool).tobytes(),
        )
        hit = self._reduced_cache.get(key)
        if hit is not None:
            return hit if hit != "empty" else None
        keep = np.flatnonzero(region_support)
        rtop = self.top.subgraph([int(i) for i in keep])
        if edge_mask is not None:
            rtop = rtop.with_tput(
                scale=np.asarray(edge_mask, bool)[np.ix_(keep, keep)]
            )
        rs = int(np.searchsorted(keep, self.src))
        rt = int(np.searchsorted(keep, self.dst))
        rstruct = LPStructure(rtop, rs, rt)
        if rstruct.n_edges == 0:
            self._reduced_cache[key] = "empty"
            return None
        out = (rstruct, keep)
        self._reduced_cache[key] = out
        return out

    # --------------------------------------------------------------- batch RHS
    def batch_b_ub(
        self,
        pat: PinPattern,
        goals: np.ndarray,
        pin_values: np.ndarray,
    ) -> tuple[np.ndarray, np.ndarray]:
        """RHS vectors for a batch of (tput_goal, pinned-value) variants.

        pin_values: [B, n_pin] values of the pinned variables per sample.
        Returns (b_keep [B, m_keep], trivially_infeasible [B]).
        """
        goals = np.asarray(goals, dtype=float)
        b = np.tile(self.b_ub0[None, :], (len(goals), 1))
        b[:, self.row_4c] = -goals
        b[:, self.row_4d] = -goals
        if pat.pinned.any():
            b -= np.asarray(pin_values, dtype=float) @ pat.A_ub_pin.T
        trivial = (
            (b[:, pat.drop_ub] < -_RHS_TOL).any(axis=1)
            if pat.drop_ub.any()
            else np.zeros(len(goals), dtype=bool)
        )
        return b[:, pat.keep_ub], trivial

    # ---------------------------------------------------------------- LP build
    def lp(
        self,
        tput_goal: float,
        *,
        fixed_n: np.ndarray | None = None,
        fixed_m: np.ndarray | None = None,
        extra_ub: list[tuple[np.ndarray, float]] | None = None,
    ) -> LPData:
        e, v = self.n_edges, self.num_regions
        b_ub = self.b_ub0.copy()
        b_ub[self.row_4c] = -tput_goal
        b_ub[self.row_4d] = -tput_goal

        if fixed_n is None and fixed_m is None:
            A_ub, A_eq, b_eq = self.A_ub, self.A_eq, self.b_eq
            if extra_ub:
                A_ub = np.vstack([A_ub] + [np.asarray(r, dtype=float)[None, :]
                                           for r, _ in extra_ub])
                b_ub = np.concatenate([b_ub, [float(b) for _, b in extra_ub]])
            return LPData(
                c=self.c, A_ub=A_ub, b_ub=b_ub, A_eq=A_eq, b_eq=b_eq.copy(),
                integer_mask=self.integer_mask, edges=self.edges,
                num_regions=v, src=self.src, dst=self.dst,
                tput_goal=tput_goal, row_4c=self.row_4c, row_4d=self.row_4d,
            )

        pat = self.pin_pattern(fixed_n is not None, fixed_m is not None)
        fv = self.pin_values(fixed_n, fixed_m)
        xpin = fv[pat.pinned]
        b_full = b_ub - pat.A_ub_pin @ xpin
        trivial = bool((b_full[pat.drop_ub] < -_RHS_TOL).any())
        A_ub_out = pat.A_ub_free
        b_ub_out = b_full[pat.keep_ub]
        if extra_ub:
            # extra rows (B&B cuts) go through the same elimination
            ex_rows = np.stack([np.asarray(r, dtype=float) for r, _ in extra_ub])
            ex_b = np.array([float(b) for _, b in extra_ub])
            ex_b = ex_b - ex_rows[:, pat.pinned] @ xpin
            ex_free = ex_rows[:, ~pat.pinned]
            ex_zero = np.abs(ex_free).max(axis=1, initial=0.0) < _ZERO_ROW_TOL
            if (ex_b[ex_zero] < -_RHS_TOL).any():
                trivial = True
            A_ub_out = np.vstack([A_ub_out, ex_free[~ex_zero]])
            b_ub_out = np.concatenate([b_ub_out, ex_b[~ex_zero]])
        # eq rows only touch F (never pinned): RHS shift is structurally zero
        return LPData(
            c=pat.c_free, A_ub=A_ub_out, b_ub=b_ub_out,
            A_eq=pat.A_eq_free, b_eq=self.b_eq[pat.keep_eq].copy(),
            integer_mask=pat.integer_mask_free, edges=self.edges,
            num_regions=v, src=self.src, dst=self.dst, tput_goal=tput_goal,
            row_4c=self.row_4c, row_4d=self.row_4d,
            fixed_values=fv, trivially_infeasible=trivial,
        )


def structure(top: Topology, src: int, dst: int) -> LPStructure:
    """The cached LPStructure for (top, src, dst). The cache lives on the
    Topology instance and is dropped whenever a new Topology is built."""
    cache = top._lp_struct_cache
    key = (src, dst)
    s = cache.get(key)
    tr = get_tracer()
    if s is None:
        _lp_cache_misses.inc()
        if tr.enabled:
            tr.instant("planner.lp_cache_miss", tr.now_wall(),
                       track="planner", key=f"{src}->{dst}")
        s = LPStructure(top, src, dst)
        cache[key] = s
    else:
        _lp_cache_hits.inc()
        if tr.enabled:
            tr.instant("planner.lp_cache_hit", tr.now_wall(),
                       track="planner", key=f"{src}->{dst}")
    return s


# ---------------------------------------------------------------- multicast
@dataclasses.dataclass
class McPinPattern:
    """Column partition + reduced matrices for one (pin_n, pin_m) choice of
    the multicast structure. Mirrors ``PinPattern`` except the goal rows are
    arrays (one 4c and one 4d row per destination commodity)."""

    pinned: np.ndarray  # [nx] bool
    A_ub_free: np.ndarray
    A_ub_pin: np.ndarray
    keep_ub: np.ndarray
    drop_ub: np.ndarray
    A_eq_free: np.ndarray
    keep_eq: np.ndarray
    drop_eq: np.ndarray
    c_free: np.ndarray
    integer_mask_free: np.ndarray
    rows_4c: np.ndarray  # [D] goal rows remapped into kept-row space
    rows_4d: np.ndarray

    @property
    def n_free(self) -> int:
        return self.A_ub_free.shape[1]


@dataclasses.dataclass
class MulticastLPData:
    """Concrete multicast LP (same contract as LPData, D commodities)."""

    c: np.ndarray
    A_ub: np.ndarray
    b_ub: np.ndarray
    A_eq: np.ndarray
    b_eq: np.ndarray
    integer_mask: np.ndarray
    edges: list[tuple[int, int]]
    num_regions: int
    src: int
    dsts: tuple[int, ...]
    goals: np.ndarray  # [D] per-destination throughput floors (Gbit/s)
    fixed_values: np.ndarray | None = None
    trivially_infeasible: bool = False

    @property
    def n_edges(self) -> int:
        return len(self.edges)

    def _full_x(self, x: np.ndarray) -> np.ndarray:
        if self.fixed_values is None:
            return x
        full = self.fixed_values.copy()
        full[np.isnan(self.fixed_values)] = x
        return full

    def split(
        self, x: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """solver x -> (G [V,V], F [D,V,V], N [V], M [V,V])."""
        x = self._full_x(np.asarray(x, dtype=float))
        e, v, d = self.n_edges, self.num_regions, len(self.dsts)
        eu, ew = _edge_arrays(self.edges)
        G = np.zeros((v, v))
        F = np.zeros((d, v, v))
        M = np.zeros((v, v))
        G[eu, ew] = x[:e]
        for k in range(d):
            F[k][eu, ew] = x[(1 + k) * e : (2 + k) * e]
        off = (1 + d) * e
        N = np.asarray(x[off : off + v], dtype=float).copy()
        M[eu, ew] = x[off + v :]
        return G, F, N, M


class MulticastLPStructure:
    """Cached multicast LP assembly for one (top, src, dsts) — the one-to-many
    extension of Eq. 4a-4j (paper §5.1.4) used by checkpoint replication.

    Decision vector:  x = [ G (E), F^0..F^{D-1} (D*E), N (V), M (E) ]

      G_e    envelope flow on edge e — the rate at which *bytes actually
             traverse* the link. A chunk forwarded over a hop serves every
             downstream destination, so egress is billed on G exactly once
             no matter how many commodities ride the link.
      F^d_e  commodity flow toward destination d (F^d_e <= G_e).
      N, M   shared VM / connection allocations, as in the unicast MILP.

    Objective: <G, Cost_egress> + <N, Cost_vm> — the "bill each link once"
    cost lever that makes one-to-many trees cheaper than N unicasts.

    Inequality rows, fixed order (D = len(dsts)):
      4b   G_e <= (tput_e / limit_conn) * M_e                     [E]
      dom  F^d_e <= G_e                                           [D*E]
      4c   sum_{e out of src} F^d_e >= goal_d                     [D]
      4d   sum_{e into d} F^d_e >= goal_d                         [D]
      4f/4g  VM ingress/egress caps on G                          [2V]
      4h/4i  connection caps                                      [2V]
      4j   N_v <= Limit_vm                                        [V]
    Equalities: per-commodity flow conservation at every touched region
    except {src, d} (a destination may relay to other destinations).

    Like ``LPStructure``, assembly is O(rows*cols) exactly once per
    (topology, src, dsts) — counted in ``N_STRUCT_BUILDS`` — and every
    variant (per-goal RHS, pinned N/M refits, degraded-link cuts via
    ``extra_ub``) derives in O(rows) from the cached matrices, so
    failure-driven re-planning is a pure cache hit.
    """

    def __init__(self, top: Topology, src: int, dsts: tuple[int, ...]):
        _struct_builds.inc()
        self.top = top
        self.src = src
        self.dsts = tuple(int(d) for d in dsts)
        if src in self.dsts:
            raise ValueError("source cannot be a multicast destination")
        if len(set(self.dsts)) != len(self.dsts):
            raise ValueError("duplicate multicast destinations")
        # edges into the source are never useful; edges out of a destination
        # stay (a destination can relay on toward another destination)
        self.edges = top.edge_list(src, None)
        self.eu, self.ew = _edge_arrays(self.edges)
        e, v, D = len(self.edges), top.num_regions, len(self.dsts)
        self.n_edges = e
        self.num_regions = v
        self.n_dsts = D
        nx = (1 + D) * e + v + e
        self.nx = nx
        self.iN = (1 + D) * e  # first N column
        self.iM = (1 + D) * e + v  # first M column
        ar = np.arange(e)

        # ---- objective: egress billed once on the envelope, VMs as usual
        c = np.zeros(nx)
        c[:e] = top.price_egress[self.eu, self.ew] / GBIT_PER_GB
        c[self.iN : self.iN + v] = top.price_vm
        self.c = c

        # ---- A_ub in the fixed row order documented above
        m_ub = e + D * e + 2 * D + 5 * v
        self.rows_4c = e + D * e + np.arange(D)
        self.rows_4d = e + D * e + D + np.arange(D)
        r_4f = e + D * e + 2 * D
        A = np.zeros((m_ub, nx))
        b0 = np.zeros(m_ub)
        # 4b on the envelope
        A[ar, ar] = 1.0
        A[ar, self.iM + ar] = -top.tput[self.eu, self.ew] / top.limit_conn
        # dominance F^d <= G
        for k in range(D):
            A[e + k * e + ar, (1 + k) * e + ar] = 1.0
            A[e + k * e + ar, ar] = -1.0
        # 4c / 4d per commodity (b filled per-goal in lp())
        for k, d in enumerate(self.dsts):
            A[self.rows_4c[k], (1 + k) * e + ar[self.eu == src]] = -1.0
            A[self.rows_4d[k], (1 + k) * e + ar[self.ew == d]] = -1.0
        # 4f / 4g on the envelope
        A[r_4f + self.ew, ar] = 1.0
        A[r_4f + np.arange(v), self.iN + np.arange(v)] = -top.limit_ingress
        A[r_4f + v + self.eu, ar] = 1.0
        A[r_4f + v + np.arange(v), self.iN + np.arange(v)] = -top.limit_egress
        # 4h / 4i
        A[r_4f + 2 * v + self.eu, self.iM + ar] = 1.0
        A[r_4f + 2 * v + np.arange(v), self.iN + np.arange(v)] = -float(top.limit_conn)
        A[r_4f + 3 * v + self.ew, self.iM + ar] = 1.0
        A[r_4f + 3 * v + np.arange(v), self.iN + np.arange(v)] = -float(top.limit_conn)
        # 4j
        A[r_4f + 4 * v + np.arange(v), self.iN + np.arange(v)] = 1.0
        b0[r_4f + 4 * v :] = float(top.limit_vm)
        self.A_ub = A
        self.b_ub0 = b0

        # ---- per-commodity flow conservation
        inc = np.zeros((v, e))
        np.add.at(inc, (self.ew, ar), 1.0)
        np.add.at(inc, (self.eu, ar), -1.0)
        touched = np.zeros(v, dtype=bool)
        touched[self.eu] = True
        touched[self.ew] = True
        eq_rows = []
        for k, d in enumerate(self.dsts):
            relay = touched.copy()
            relay[[src, d]] = False
            if not relay.any():
                continue
            block = np.zeros((int(relay.sum()), nx))
            block[:, (1 + k) * e : (2 + k) * e] = inc[relay]
            eq_rows.append(block)
        self.A_eq = np.vstack(eq_rows) if eq_rows else np.zeros((0, nx))
        self.b_eq = np.zeros(self.A_eq.shape[0])

        self.integer_mask = np.zeros(nx, dtype=bool)
        self.integer_mask[self.iN :] = True  # N and M

        self._pin_patterns: dict[tuple[bool, bool], McPinPattern] = {}
        self._reduced_cache: dict = {}

    # ----------------------------------------------------------- exact presolve
    def reduced(
        self, region_support: np.ndarray
    ) -> tuple["MulticastLPStructure", np.ndarray] | None:
        """Exact presolve for pinned solves: the sub-structure over supported
        regions. The source and every destination are force-kept even with
        N = 0 pinned — their 4f/4g rows then force zero delivery, which the
        scale probe reports faithfully — so only dead relays are dropped
        (lossless, as in ``LPStructure.reduced``). Cached per support;
        returns None when no edge survives."""
        region_support = np.asarray(region_support, dtype=bool).copy()
        region_support[[self.src, *self.dsts]] = True
        key = region_support.tobytes()
        hit = self._reduced_cache.get(key)
        if hit is not None:
            return hit if hit != "empty" else None
        keep = np.flatnonzero(region_support)
        rtop = self.top.subgraph([int(i) for i in keep])
        rs = int(np.searchsorted(keep, self.src))
        rds = tuple(int(np.searchsorted(keep, d)) for d in self.dsts)
        rstruct = MulticastLPStructure(rtop, rs, rds)
        if rstruct.n_edges == 0:
            self._reduced_cache[key] = "empty"
            return None
        out = (rstruct, keep)
        self._reduced_cache[key] = out
        return out

    def reduced_cached(self, region_support: np.ndarray):
        """Like ``reduced`` but NEVER assembles: returns the cached
        reduction, None for a cached-empty support, or "miss". Constrained
        re-plans use this so a cold support falls back to the full-size
        solve instead of building a structure mid-replan (the
        N_STRUCT_BUILDS == 0 contract of failure-driven re-planning)."""
        region_support = np.asarray(region_support, dtype=bool).copy()
        region_support[[self.src, *self.dsts]] = True
        hit = self._reduced_cache.get(region_support.tobytes())
        if hit is None:
            return "miss"
        return None if hit == "empty" else hit

    # ------------------------------------------------------------ pin patterns
    def pin_pattern(self, pin_n: bool, pin_m: bool) -> McPinPattern:
        key = (pin_n, pin_m)
        pat = self._pin_patterns.get(key)
        if pat is not None:
            return pat
        v = self.num_regions
        pinned = np.zeros(self.nx, dtype=bool)
        if pin_n:
            pinned[self.iN : self.iN + v] = True
        if pin_m:
            pinned[self.iM :] = True
        free = ~pinned
        A_ub_free = self.A_ub[:, free]
        A_eq_free = self.A_eq[:, free]
        drop_ub = (
            np.abs(A_ub_free).max(axis=1, initial=0.0) < _ZERO_ROW_TOL
            if pinned.any()
            else np.zeros(self.A_ub.shape[0], dtype=bool)
        )
        # eq rows only touch F columns, which are never pinned
        drop_eq = np.zeros(self.A_eq.shape[0], dtype=bool)
        keep_ub = ~drop_ub
        newpos = np.cumsum(keep_ub) - 1
        # goal rows touch F columns only: never dropped by pinning
        pat = McPinPattern(
            pinned=pinned,
            A_ub_free=np.ascontiguousarray(A_ub_free[keep_ub]),
            A_ub_pin=np.ascontiguousarray(self.A_ub[:, pinned]),
            keep_ub=keep_ub,
            drop_ub=drop_ub,
            A_eq_free=np.ascontiguousarray(A_eq_free),
            keep_eq=~drop_eq,
            drop_eq=drop_eq,
            c_free=self.c[free],
            integer_mask_free=self.integer_mask[free],
            rows_4c=newpos[self.rows_4c].astype(np.int64),
            rows_4d=newpos[self.rows_4d].astype(np.int64),
        )
        self._pin_patterns[key] = pat
        return pat

    def pin_values(
        self, fixed_n: np.ndarray | None, fixed_m: np.ndarray | None
    ) -> np.ndarray:
        fv = np.full(self.nx, np.nan)
        if fixed_n is not None:
            fv[self.iN : self.iN + self.num_regions] = np.asarray(
                fixed_n, dtype=float
            )
        if fixed_m is not None:
            fm = np.asarray(fixed_m, dtype=float)
            fv[self.iM :] = fm[self.eu, self.ew]
        return fv

    # ------------------------------------------------------------- scale cuts
    def scale_cuts(
        self,
        edge_scale: np.ndarray,
        agg_cap: float | np.ndarray | None = None,
        tol: float = 1e-9,
    ) -> list[tuple[np.ndarray, float]]:
        """Tightened rows on the ENVELOPE for a per-edge scale vector —
        the multicast analogue of ``LPStructure.scale_cuts`` (what crosses
        the wire is G, so the lower-confidence-bound grid binds G; the
        ``agg_cap`` aggregate row likewise). Rows ride the cached
        structure as ``extra_ub``; nothing re-assembles."""
        edge_scale = np.asarray(edge_scale, dtype=float)
        if edge_scale.shape != (self.n_edges,):
            raise ValueError(
                f"edge_scale must have shape ({self.n_edges},), "
                f"got {edge_scale.shape}"
            )
        return _scale_cut_rows(
            self.nx, self.iM,
            self.top.tput[self.eu, self.ew], self.top.limit_conn,
            edge_scale, agg_cap, tol,
        )

    # ---------------------------------------------------------------- LP build
    def _b_and_trivial(
        self,
        goals: np.ndarray,
        pat: McPinPattern,
        fv: np.ndarray,
        extra_ub,
    ):
        """(b_ub_kept, A_extra_free, b_extra, trivially_infeasible)."""
        b_ub = self.b_ub0.copy()
        b_ub[self.rows_4c] = -goals
        b_ub[self.rows_4d] = -goals
        trivial = False
        if pat.pinned.any():
            xpin = fv[pat.pinned]
            b_ub = b_ub - pat.A_ub_pin @ xpin
            trivial = bool((b_ub[pat.drop_ub] < -_RHS_TOL).any())
        A_ex, b_ex = None, None
        if extra_ub:
            ex_rows = np.stack([np.asarray(r, dtype=float) for r, _ in extra_ub])
            ex_b = np.array([float(b) for _, b in extra_ub])
            if pat.pinned.any():
                ex_b = ex_b - ex_rows[:, pat.pinned] @ fv[pat.pinned]
            ex_free = ex_rows[:, ~pat.pinned]
            ex_zero = np.abs(ex_free).max(axis=1, initial=0.0) < _ZERO_ROW_TOL
            if (ex_b[ex_zero] < -_RHS_TOL).any():
                trivial = True
            A_ex, b_ex = ex_free[~ex_zero], ex_b[~ex_zero]
        return b_ub[pat.keep_ub], A_ex, b_ex, trivial

    def lp(
        self,
        goals: np.ndarray,
        *,
        fixed_n: np.ndarray | None = None,
        fixed_m: np.ndarray | None = None,
        extra_ub: list[tuple[np.ndarray, float]] | None = None,
    ) -> MulticastLPData:
        """O(rows) multicast LP for per-destination goals (Gbit/s)."""
        goals = np.asarray(goals, dtype=float)
        pat = self.pin_pattern(fixed_n is not None, fixed_m is not None)
        fv = self.pin_values(fixed_n, fixed_m)
        b_keep, A_ex, b_ex, trivial = self._b_and_trivial(
            goals, pat, fv, extra_ub
        )
        A_ub = pat.A_ub_free
        if A_ex is not None and A_ex.size:
            A_ub = np.vstack([A_ub, A_ex])
            b_keep = np.concatenate([b_keep, b_ex])
        return MulticastLPData(
            c=pat.c_free, A_ub=A_ub, b_ub=b_keep,
            A_eq=pat.A_eq_free, b_eq=self.b_eq.copy(),
            integer_mask=pat.integer_mask_free, edges=self.edges,
            num_regions=self.num_regions, src=self.src, dsts=self.dsts,
            goals=goals,
            fixed_values=fv if pat.pinned.any() else None,
            trivially_infeasible=trivial,
        )

    def probe_lp(
        self,
        goals: np.ndarray,
        *,
        fixed_n: np.ndarray | None = None,
        fixed_m: np.ndarray | None = None,
        extra_ub: list[tuple[np.ndarray, float]] | None = None,
        cap: float | None = 1.0,
    ):
        """Uniform-scale feasibility probe: max t s.t. every commodity
        delivers >= t * goal_d. Always feasible (x=0, t=0), so the round-down
        pipeline never hands the IPM an infeasible instance — the multicast
        analogue of the unicast max-flow probe.

        Returns (c, A_ub, b_ub, A_eq, b_eq) over [free columns | t], or None
        when the pinned RHS is trivially infeasible. ``cap`` bounds t (1.0
        for feasibility checks — only "can we hit the goals" matters; None
        for max-rate probes with unit goals).
        """
        goals = np.asarray(goals, dtype=float)
        pat = self.pin_pattern(fixed_n is not None, fixed_m is not None)
        fv = self.pin_values(fixed_n, fixed_m)
        # goal rows move into the t column: RHS uses goals=0
        b_keep, A_ex, b_ex, trivial = self._b_and_trivial(
            np.zeros_like(goals), pat, fv, extra_ub
        )
        if trivial:
            return None
        tcol = np.zeros(self.A_ub.shape[0])
        tcol[self.rows_4c] = goals
        tcol[self.rows_4d] = goals
        A_ub = np.hstack([pat.A_ub_free, tcol[pat.keep_ub][:, None]])
        if A_ex is not None and A_ex.size:
            A_ub = np.vstack(
                [A_ub, np.hstack([A_ex, np.zeros((A_ex.shape[0], 1))])]
            )
            b_keep = np.concatenate([b_keep, b_ex])
        if cap is not None:
            cap_row = np.zeros(A_ub.shape[1])
            cap_row[-1] = 1.0
            A_ub = np.vstack([A_ub, cap_row[None, :]])
            b_keep = np.concatenate([b_keep, [float(cap)]])
        A_eq = np.hstack(
            [pat.A_eq_free, np.zeros((pat.A_eq_free.shape[0], 1))]
        )
        c = np.zeros(A_ub.shape[1])
        c[-1] = -1.0
        return c, A_ub, b_keep, A_eq, self.b_eq.copy()


def multicast_structure(
    top: Topology, src: int, dsts: Sequence[int]
) -> MulticastLPStructure:
    """The cached MulticastLPStructure for (top, src, dsts). Shares the
    Topology-instance cache with the unicast structures (distinct key space),
    so re-planning a degraded multicast job is a pure cache hit."""
    cache = top._lp_struct_cache
    key = ("mc", src, tuple(int(d) for d in dsts))
    s = cache.get(key)
    tr = get_tracer()
    if s is None:
        _lp_cache_misses.inc()
        if tr.enabled:
            tr.instant("planner.lp_cache_miss", tr.now_wall(),
                       track="planner", key=f"{src}->mc{list(key[2])}")
        s = MulticastLPStructure(top, src, tuple(int(d) for d in dsts))
        cache[key] = s
    else:
        _lp_cache_hits.inc()
        if tr.enabled:
            tr.instant("planner.lp_cache_hit", tr.now_wall(),
                       track="planner", key=f"{src}->mc{list(key[2])}")
    return s


def build_lp(
    top: Topology,
    src: int,
    dst: int,
    tput_goal: float,
    *,
    fixed_n: np.ndarray | None = None,
    fixed_m: np.ndarray | None = None,
    extra_ub: list[tuple[np.ndarray, float]] | None = None,
) -> LPData:
    """Build Eq. 4a-4j for a single s->t job on ``top``.

    fixed_n: if given, adds N_v == fixed_n[v] equality rows (used when
      re-fitting F, M after integer rounding of N).
    fixed_m: if given, adds M_e == fixed_m[u,w] equality rows (round-down
      refit of F with both integer allocations pinned, §5.1.3).
    extra_ub: extra inequality rows (used by branch & bound for bound cuts).
    """
    return structure(top, src, dst).lp(
        tput_goal, fixed_n=fixed_n, fixed_m=fixed_m, extra_ub=extra_ub
    )


def build_lp_reference(
    top: Topology,
    src: int,
    dst: int,
    tput_goal: float,
    *,
    fixed_n: np.ndarray | None = None,
    fixed_m: np.ndarray | None = None,
    extra_ub: list[tuple[np.ndarray, float]] | None = None,
) -> LPData:
    """Original pure-Python row-loop assembly; oracle for LPStructure."""
    v = top.num_regions
    edges = top.edge_list(src, dst)
    e = len(edges)
    nx = 2 * e + v
    def iF(k):
        return k

    def iN(r):
        return e + r

    def iM(k):
        return e + v + k

    # ---- objective: $/s of the running transfer (Eq. 4a without the constant)
    c = np.zeros(nx)
    for k, (u, w) in enumerate(edges):
        c[iF(k)] = top.price_egress[u, w] / GBIT_PER_GB  # $/Gbit * Gbit/s = $/s
    for r in range(v):
        c[iN(r)] = top.price_vm[r]

    rows_ub: list[np.ndarray] = []
    b_ub: list[float] = []

    def add_ub(row: np.ndarray, b: float) -> int:
        rows_ub.append(row)
        b_ub.append(b)
        return len(b_ub) - 1

    # ---- 4b: per-connection throughput cap
    for k, (u, w) in enumerate(edges):
        row = np.zeros(nx)
        row[iF(k)] = 1.0
        row[iM(k)] = -top.tput[u, w] / top.limit_conn
        add_ub(row, 0.0)

    # ---- 4c / 4d: goal throughput at the endpoints (>=, negated into <=)
    row = np.zeros(nx)
    for k, (u, w) in enumerate(edges):
        if u == src:
            row[iF(k)] = -1.0
    row_4c = add_ub(row, -tput_goal)

    row = np.zeros(nx)
    for k, (u, w) in enumerate(edges):
        if w == dst:
            row[iF(k)] = -1.0
    row_4d = add_ub(row, -tput_goal)

    # ---- 4f / 4g: per-region ingress/egress scaled by VM count
    for r in range(v):
        row = np.zeros(nx)
        for k, (u, w) in enumerate(edges):
            if w == r:
                row[iF(k)] = 1.0
        row[iN(r)] = -top.limit_ingress[r]
        add_ub(row, 0.0)
    for r in range(v):
        row = np.zeros(nx)
        for k, (u, w) in enumerate(edges):
            if u == r:
                row[iF(k)] = 1.0
        row[iN(r)] = -top.limit_egress[r]
        add_ub(row, 0.0)

    # ---- 4h / 4i: connection count scaled by VM count (erratum-corrected)
    for r in range(v):
        row = np.zeros(nx)
        for k, (u, w) in enumerate(edges):
            if u == r:
                row[iM(k)] = 1.0
        row[iN(r)] = -float(top.limit_conn)
        add_ub(row, 0.0)
    for r in range(v):
        row = np.zeros(nx)
        for k, (u, w) in enumerate(edges):
            if w == r:
                row[iM(k)] = 1.0
        row[iN(r)] = -float(top.limit_conn)
        add_ub(row, 0.0)

    # ---- 4j: per-region VM limit
    for r in range(v):
        row = np.zeros(nx)
        row[iN(r)] = 1.0
        add_ub(row, float(top.limit_vm))

    if extra_ub:
        for row, b in extra_ub:
            add_ub(np.asarray(row, dtype=float), float(b))

    # ---- 4e: flow conservation at relays
    rows_eq: list[np.ndarray] = []
    b_eq: list[float] = []
    for r in range(v):
        if r in (src, dst):
            continue
        row = np.zeros(nx)
        touched = False
        for k, (u, w) in enumerate(edges):
            if w == r:
                row[iF(k)] += 1.0
                touched = True
            if u == r:
                row[iF(k)] -= 1.0
                touched = True
        if touched:
            rows_eq.append(row)
            b_eq.append(0.0)

    integer_mask = np.zeros(nx, dtype=bool)
    integer_mask[e : e + v] = True  # N
    integer_mask[e + v :] = True  # M

    A_ub = np.array(rows_ub) if rows_ub else np.zeros((0, nx))
    b_ub_arr = np.array(b_ub)
    A_eq = np.array(rows_eq) if rows_eq else np.zeros((0, nx))
    b_eq_arr = np.array(b_eq)

    # ---- eliminate pinned variables (numerically cleaner than eq rows)
    fixed_values = None
    trivially_infeasible = False
    if fixed_n is not None or fixed_m is not None:
        fixed_values = np.full(nx, np.nan)
        if fixed_n is not None:
            fixed_values[e : e + v] = np.asarray(fixed_n, dtype=float)
        if fixed_m is not None:
            for k, (u, w) in enumerate(edges):
                fixed_values[iM(k)] = float(fixed_m[u, w])
        pinned = ~np.isnan(fixed_values)
        xb = np.where(pinned, fixed_values, 0.0)
        if A_ub.size:
            b_ub_arr = b_ub_arr - A_ub @ xb
            A_ub = A_ub[:, ~pinned]
        if A_eq.size:
            b_eq_arr = b_eq_arr - A_eq @ xb
            A_eq = A_eq[:, ~pinned]
        c = c[~pinned]
        integer_mask = integer_mask[~pinned]
        # drop rows that became vacuous; detect trivial infeasibility
        if A_ub.size:
            zero = np.abs(A_ub).max(axis=1) < _ZERO_ROW_TOL
            if (b_ub_arr[zero] < -_RHS_TOL).any():
                trivially_infeasible = True
            A_ub = A_ub[~zero]
            b_ub_arr = b_ub_arr[~zero]
        if A_eq.size:
            zero = np.abs(A_eq).max(axis=1) < _ZERO_ROW_TOL
            if (np.abs(b_eq_arr[zero]) > _RHS_TOL).any():
                trivially_infeasible = True
            A_eq = A_eq[~zero]
            b_eq_arr = b_eq_arr[~zero]

    return LPData(
        c=c,
        A_ub=A_ub,
        b_ub=b_ub_arr,
        A_eq=A_eq,
        b_eq=b_eq_arr,
        integer_mask=integer_mask,
        edges=edges,
        num_regions=v,
        src=src,
        dst=dst,
        tput_goal=tput_goal,
        row_4c=row_4c,
        row_4d=row_4d,
        fixed_values=fixed_values,
        trivially_infeasible=trivially_infeasible,
    )
