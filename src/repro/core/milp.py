"""Skyplane's MILP formulation (paper §5.1.4, Eq. 4a-4j) as LP matrices.

Decision vector layout:  x = [ F (E), N (V), M (E) ]
  F_e  >= 0  flow on directed edge e (Gbit/s)
  N_v  >= 0  VMs provisioned in region v         (integer in the MILP)
  M_e  >= 0  TCP connections on edge e (pooled across the region pair;
             integer in the MILP)

Objective (Eq. 4a): minimize  (VOLUME / TPUT_GOAL) * (<F, Cost_egress> + <N, Cost_vm>)
The leading factor is a positive constant after the paper's linear
reformulation (transfer time == VOLUME / TPUT_GOAL), so the LP minimizes the
unscaled "cost per second" and the caller scales afterwards.

Constraints (paper numbering):
  4b  F_e <= (Limit_link_e / Limit_conn) * M_e      per-connection throughput
  4c  sum_v F_{s,v} >= TPUT_GOAL                    source egress meets goal
  4d  sum_u F_{u,t} >= TPUT_GOAL                    dest ingress meets goal
  4e  flow conservation at every v not in {s, t}
  4f  sum_u F_{u,v} <= Limit_ingress_v * N_v        per-VM ingress scaled by VMs
  4g  sum_w F_{u,w} <= Limit_egress_u * N_u         per-VM egress scaled by VMs
  4h  sum_w M_{u,w} <= Limit_conn * N_u             outgoing conns per region
  4i  sum_u M_{u,v} <= Limit_conn * N_v             incoming conns per region
  4j  N_v <= Limit_vm

ERRATUM NOTE: the paper's printed 4h/4i bound region u's outgoing connections
by N_v and incoming by N_u — a typesetting slip (the text of §5.1.2 says "the
maximum number of egress TCP connections per region [scales] by the number of
VMs provisioned in each region"). We implement the semantically consistent
version above.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .topology import GBIT_PER_GB, Topology


@dataclasses.dataclass
class LPData:
    """min c@x  s.t.  A_ub@x <= b_ub,  A_eq@x = b_eq,  x >= 0."""

    c: np.ndarray
    A_ub: np.ndarray
    b_ub: np.ndarray
    A_eq: np.ndarray
    b_eq: np.ndarray
    integer_mask: np.ndarray  # True where x must be integral in the MILP
    # bookkeeping for unpacking solutions
    edges: list[tuple[int, int]]
    num_regions: int
    src: int
    dst: int
    tput_goal: float
    row_4c: int  # row index of the source-egress constraint in A_ub
    row_4d: int
    # fixed-variable elimination (round-down refits): full-space values for
    # pinned variables; solver variables are the free columns only. F columns
    # come first and are never pinned, so F indices are stable.
    fixed_values: np.ndarray | None = None  # [nx_full] nan where free
    trivially_infeasible: bool = False

    @property
    def n_edges(self) -> int:
        return len(self.edges)

    def _full_x(self, x: np.ndarray) -> np.ndarray:
        if self.fixed_values is None:
            return x
        full = self.fixed_values.copy()
        full[np.isnan(self.fixed_values)] = x
        return full

    def split(self, x: np.ndarray) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """solver x -> (F [V,V], N [V], M [V,V])."""
        x = self._full_x(np.asarray(x, dtype=float))
        e, v = self.n_edges, self.num_regions
        F = np.zeros((v, v))
        M = np.zeros((v, v))
        for k, (u, w) in enumerate(self.edges):
            F[u, w] = x[k]
            M[u, w] = x[e + v + k]
        N = np.asarray(x[e : e + v], dtype=float).copy()
        return F, N, M


def build_lp(
    top: Topology,
    src: int,
    dst: int,
    tput_goal: float,
    *,
    fixed_n: np.ndarray | None = None,
    fixed_m: np.ndarray | None = None,
    extra_ub: list[tuple[np.ndarray, float]] | None = None,
) -> LPData:
    """Build Eq. 4a-4j for a single s->t job on ``top``.

    fixed_n: if given, adds N_v == fixed_n[v] equality rows (used when
      re-fitting F, M after integer rounding of N).
    fixed_m: if given, adds M_e == fixed_m[u,w] equality rows (round-down
      refit of F with both integer allocations pinned, §5.1.3).
    extra_ub: extra inequality rows (used by branch & bound for bound cuts).
    """
    v = top.num_regions
    edges = top.edge_list(src, dst)
    e = len(edges)
    nx = 2 * e + v
    iF = lambda k: k
    iN = lambda r: e + r
    iM = lambda k: e + v + k

    # ---- objective: $/s of the running transfer (Eq. 4a without the constant)
    c = np.zeros(nx)
    for k, (u, w) in enumerate(edges):
        c[iF(k)] = top.price_egress[u, w] / GBIT_PER_GB  # $/Gbit * Gbit/s = $/s
    for r in range(v):
        c[iN(r)] = top.price_vm[r]

    rows_ub: list[np.ndarray] = []
    b_ub: list[float] = []

    def add_ub(row: np.ndarray, b: float) -> int:
        rows_ub.append(row)
        b_ub.append(b)
        return len(b_ub) - 1

    # ---- 4b: per-connection throughput cap
    for k, (u, w) in enumerate(edges):
        row = np.zeros(nx)
        row[iF(k)] = 1.0
        row[iM(k)] = -top.tput[u, w] / top.limit_conn
        add_ub(row, 0.0)

    # ---- 4c / 4d: goal throughput at the endpoints (>=, negated into <=)
    row = np.zeros(nx)
    for k, (u, w) in enumerate(edges):
        if u == src:
            row[iF(k)] = -1.0
    row_4c = add_ub(row, -tput_goal)

    row = np.zeros(nx)
    for k, (u, w) in enumerate(edges):
        if w == dst:
            row[iF(k)] = -1.0
    row_4d = add_ub(row, -tput_goal)

    # ---- 4f / 4g: per-region ingress/egress scaled by VM count
    for r in range(v):
        row = np.zeros(nx)
        for k, (u, w) in enumerate(edges):
            if w == r:
                row[iF(k)] = 1.0
        row[iN(r)] = -top.limit_ingress[r]
        add_ub(row, 0.0)
    for r in range(v):
        row = np.zeros(nx)
        for k, (u, w) in enumerate(edges):
            if u == r:
                row[iF(k)] = 1.0
        row[iN(r)] = -top.limit_egress[r]
        add_ub(row, 0.0)

    # ---- 4h / 4i: connection count scaled by VM count (erratum-corrected)
    for r in range(v):
        row = np.zeros(nx)
        for k, (u, w) in enumerate(edges):
            if u == r:
                row[iM(k)] = 1.0
        row[iN(r)] = -float(top.limit_conn)
        add_ub(row, 0.0)
    for r in range(v):
        row = np.zeros(nx)
        for k, (u, w) in enumerate(edges):
            if w == r:
                row[iM(k)] = 1.0
        row[iN(r)] = -float(top.limit_conn)
        add_ub(row, 0.0)

    # ---- 4j: per-region VM limit
    for r in range(v):
        row = np.zeros(nx)
        row[iN(r)] = 1.0
        add_ub(row, float(top.limit_vm))

    if extra_ub:
        for row, b in extra_ub:
            add_ub(np.asarray(row, dtype=float), float(b))

    # ---- 4e: flow conservation at relays
    rows_eq: list[np.ndarray] = []
    b_eq: list[float] = []
    for r in range(v):
        if r in (src, dst):
            continue
        row = np.zeros(nx)
        touched = False
        for k, (u, w) in enumerate(edges):
            if w == r:
                row[iF(k)] += 1.0
                touched = True
            if u == r:
                row[iF(k)] -= 1.0
                touched = True
        if touched:
            rows_eq.append(row)
            b_eq.append(0.0)

    integer_mask = np.zeros(nx, dtype=bool)
    integer_mask[e : e + v] = True  # N
    integer_mask[e + v :] = True  # M

    A_ub = np.array(rows_ub) if rows_ub else np.zeros((0, nx))
    b_ub_arr = np.array(b_ub)
    A_eq = np.array(rows_eq) if rows_eq else np.zeros((0, nx))
    b_eq_arr = np.array(b_eq)

    # ---- eliminate pinned variables (numerically cleaner than eq rows)
    fixed_values = None
    trivially_infeasible = False
    if fixed_n is not None or fixed_m is not None:
        fixed_values = np.full(nx, np.nan)
        if fixed_n is not None:
            fixed_values[e : e + v] = np.asarray(fixed_n, dtype=float)
        if fixed_m is not None:
            for k, (u, w) in enumerate(edges):
                fixed_values[iM(k)] = float(fixed_m[u, w])
        pinned = ~np.isnan(fixed_values)
        xb = np.where(pinned, fixed_values, 0.0)
        if A_ub.size:
            b_ub_arr = b_ub_arr - A_ub @ xb
            A_ub = A_ub[:, ~pinned]
        if A_eq.size:
            b_eq_arr = b_eq_arr - A_eq @ xb
            A_eq = A_eq[:, ~pinned]
        c = c[~pinned]
        integer_mask = integer_mask[~pinned]
        # drop rows that became vacuous; detect trivial infeasibility
        if A_ub.size:
            zero = np.abs(A_ub).max(axis=1) < 1e-12
            if (b_ub_arr[zero] < -1e-9).any():
                trivially_infeasible = True
            A_ub = A_ub[~zero]
            b_ub_arr = b_ub_arr[~zero]
        if A_eq.size:
            zero = np.abs(A_eq).max(axis=1) < 1e-12
            if (np.abs(b_eq_arr[zero]) > 1e-9).any():
                trivially_infeasible = True
            A_eq = A_eq[~zero]
            b_eq_arr = b_eq_arr[~zero]

    return LPData(
        c=c,
        A_ub=A_ub,
        b_ub=b_ub_arr,
        A_eq=A_eq,
        b_eq=b_eq_arr,
        integer_mask=integer_mask,
        edges=edges,
        num_regions=v,
        src=src,
        dst=dst,
        tput_goal=tput_goal,
        row_4c=row_4c,
        row_4d=row_4d,
        fixed_values=fixed_values,
        trivially_infeasible=trivially_infeasible,
    )
