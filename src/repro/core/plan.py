"""TransferPlan: the output of Skyplane's planner (paper Fig. 5).

A plan pins down the overlay topology (F), resource allocation (N VMs per
region, M connections per region pair) and exposes the paper's cost model:

  egress cost = sum_e  (bytes through e) * price_e          [volume-billed, §2]
  vm cost     = sum_v  N_v * price_vm_v * transfer_time
  transfer_time = VOLUME / TPUT_GOAL                        [linear reformulation]

``validate`` re-checks every constraint 4b-4j so tests (and hypothesis
properties) can assert that any plan the solver emits is feasible.
``paths`` decomposes F into weighted s->t paths for the data plane.
"""

from __future__ import annotations

import dataclasses
import warnings

import numpy as np

from .topology import GBIT_PER_GB, Topology

_TOL = 1e-5
_FLOW_EPS = 1e-9


def _widest_path(
    F: np.ndarray, src: int, dst: int
) -> tuple[list[int], float] | None:
    """Widest src->dst path in the flow grid F (Dijkstra-like relaxation on
    bottleneck capacity). Returns (path, width) or None when no flow path
    with width > _FLOW_EPS exists."""
    v = F.shape[0]
    width = np.full(v, 0.0)
    prev = np.full(v, -1, dtype=np.int64)
    width[src] = np.inf
    visited = np.zeros(v, dtype=bool)
    for _ in range(v):
        u = -1
        best = 0.0
        for i in range(v):
            if not visited[i] and width[i] > best:
                best = width[i]
                u = i
        if u < 0:
            break
        visited[u] = True
        if u == dst:
            break
        for w in range(v):
            cand = min(width[u], F[u, w])
            if cand > width[w] + 1e-12:
                width[w] = cand
                prev[w] = u
    if width[dst] <= _FLOW_EPS:
        return None
    path = [dst]
    while path[-1] != src:
        path.append(int(prev[path[-1]]))
    path.reverse()
    return path, float(width[dst])


def _peel_paths(
    F: np.ndarray,
    src: int,
    dst: int,
    max_paths: int | None,
    stop_below: float = 0.0,
) -> list[tuple[list[int], float]]:
    """Greedy widest-path flow decomposition of F (mutated in place). Each
    peel zeroes at least one edge, so at most #positive-edges paths exist;
    ``max_paths`` only caps that (None = all of them). ``stop_below`` ends
    the peel once the residual source outflow is negligible (solver noise
    would otherwise decompose into useless micro-paths)."""
    cap = max_paths if max_paths is not None else int((F > _FLOW_EPS).sum()) + 4
    out: list[tuple[list[int], float]] = []
    for _ in range(cap):
        hit = _widest_path(F, src, dst)
        if hit is None:
            break
        path, flow = hit
        for a, b in zip(path[:-1], path[1:]):
            F[a, b] -= flow
        out.append((path, flow))
        if stop_below > 0.0 and float(F[src, :].sum()) <= stop_below:
            break
    return out


@dataclasses.dataclass
class TransferPlan:
    top: Topology
    src: int
    dst: int
    tput_goal: float  # Gbit/s
    volume_gb: float  # GB to move
    F: np.ndarray  # [V,V] Gbit/s
    N: np.ndarray  # [V] VMs (int)
    M: np.ndarray  # [V,V] TCP connections (int)
    solver_status: str = "optimal"

    # ------------------------------------------------------------------ costs
    @property
    def throughput(self) -> float:
        """Planned end-to-end throughput (Gbit/s)."""
        return float(self.F[self.src, :].sum())

    @property
    def transfer_time_s(self) -> float:
        return self.volume_gb * GBIT_PER_GB / max(self.throughput, 1e-9)

    @property
    def egress_cost(self) -> float:
        t = self.transfer_time_s
        gb_per_edge = self.F * t / GBIT_PER_GB
        return float((gb_per_edge * self.top.price_egress).sum())

    @property
    def vm_cost(self) -> float:
        return float(self.N @ self.top.price_vm) * self.transfer_time_s

    @property
    def total_cost(self) -> float:
        return self.egress_cost + self.vm_cost

    @property
    def cost_per_gb(self) -> float:
        return self.total_cost / max(self.volume_gb, 1e-9)

    @property
    def num_vms(self) -> int:
        return int(self.N.sum())

    def with_volume(self, volume_gb: float) -> "TransferPlan":
        """The same allocation, re-scoped to a different volume — how the
        transfer service carries a plan over to the *remaining* bytes of a
        partially completed job (costs and transfer time rescale; F/N/M and
        feasibility are untouched)."""
        return dataclasses.replace(self, volume_gb=float(volume_gb))

    # ------------------------------------------------------------- valididity
    def validate(self, tol: float = _TOL) -> list[str]:
        """Returns a list of violated-constraint descriptions (empty = valid)."""
        top, F, N, M = self.top, self.F, self.N, self.M
        v = top.num_regions
        errs = []
        scale = max(self.tput_goal, 1.0)
        if (F < -tol).any():
            errs.append("F has negative entries")
        if (N < -tol).any() or (M < -tol).any():
            errs.append("N or M has negative entries")
        # 4b
        cap = top.tput * M / top.limit_conn
        if (F - cap > tol * scale).any():
            errs.append("4b: flow exceeds per-connection capacity")
        # 4c / 4d
        if F[self.src, :].sum() < self.tput_goal - tol * scale:
            errs.append("4c: source egress below goal")
        if F[:, self.dst].sum() < self.tput_goal - tol * scale:
            errs.append("4d: dest ingress below goal")
        # 4e
        for r in range(v):
            if r in (self.src, self.dst):
                continue
            if abs(F[:, r].sum() - F[r, :].sum()) > tol * scale:
                errs.append(f"4e: flow not conserved at region {r}")
        # 4f / 4g
        for r in range(v):
            if F[:, r].sum() - top.limit_ingress[r] * N[r] > tol * scale:
                errs.append(f"4f: ingress over VM limit at region {r}")
            if F[r, :].sum() - top.limit_egress[r] * N[r] > tol * scale:
                errs.append(f"4g: egress over VM limit at region {r}")
        # 4h / 4i
        for r in range(v):
            if M[r, :].sum() - top.limit_conn * N[r] > tol:
                errs.append(f"4h: outgoing connections over limit at region {r}")
            if M[:, r].sum() - top.limit_conn * N[r] > tol:
                errs.append(f"4i: incoming connections over limit at region {r}")
        # 4j
        if (N > top.limit_vm + tol).any():
            errs.append("4j: VM count over service limit")
        return errs

    # ------------------------------------------------------------------ paths
    def paths(
        self, max_paths: int | None = None, *, rel_eps: float = 1e-6
    ) -> list[tuple[list[int], float]]:
        """Greedy flow decomposition of F into (region path, Gbit/s) pairs.

        Repeatedly peels the widest remaining s->t path until the residual
        source outflow is below ``rel_eps`` of the plan throughput. Each peel
        zeroes at least one edge, so at most #positive-edges paths exist;
        ``max_paths`` is only a safety cap (default: all of them). Dropping
        residual flow silently would under-provision the gateway chains the
        data plane maps chunk streams onto, so any leftover beyond the
        tolerance warns.
        """
        F = self.F.copy()
        tol = rel_eps * max(self.throughput, 1e-9)
        out = _peel_paths(F, self.src, self.dst, max_paths, stop_below=tol)
        leftover = float(F[self.src, :].sum())
        if leftover > tol and _widest_path(F, self.src, self.dst) is not None:
            warnings.warn(
                f"paths(): {leftover:.3g} Gbit/s of source outflow left "
                f"undecomposed after {len(out)} paths; the gateway chains "
                "will under-provision",
                stacklevel=2,
            )
        return out

    def describe(self) -> str:
        keys = self.top.keys()
        lines = [
            f"plan {keys[self.src]} -> {keys[self.dst]}: "
            f"{self.throughput:.2f} Gbps, ${self.cost_per_gb:.4f}/GB "
            f"({self.num_vms} VMs, {int(self.M.sum())} conns)"
        ]
        for path, flow in self.paths():
            hops = " -> ".join(keys[i] for i in path)
            lines.append(f"  {flow:6.2f} Gbps via {hops}")
        return "\n".join(lines)


# ------------------------------------------------------------------ multicast
@dataclasses.dataclass
class McTree:
    """One distribution tree of a multicast plan: a rate and, per
    destination region, the path that serves it. Paths may share edges —
    a chunk traverses each shared edge once and fans out where the paths
    diverge (that sharing is exactly what the envelope bills once)."""

    rate: float  # Gbit/s carried by this tree
    paths: dict[int, list[int]]  # dest region -> [src, ..., dest]

    def edges(self) -> list[tuple[int, int]]:
        """Distinct edges in first-appearance order (dest order, then path
        order) — the deterministic stage order of the data plane."""
        seen: list[tuple[int, int]] = []
        have = set()
        for d in sorted(self.paths):
            p = self.paths[d]
            for e in zip(p[:-1], p[1:]):
                if e not in have:
                    have.add(e)
                    seen.append(e)
        return seen

    def dests_of_edge(self) -> dict[tuple[int, int], set[int]]:
        """edge -> destinations whose path traverses it."""
        out: dict[tuple[int, int], set[int]] = {}
        for d, p in self.paths.items():
            for e in zip(p[:-1], p[1:]):
                out.setdefault(e, set()).add(d)
        return out

    def children(self) -> dict[tuple[int, int], list[tuple[int, int]]]:
        """edge -> downstream edges some destination path continues on."""
        out: dict[tuple[int, int], set] = {e: set() for e in self.edges()}
        for p in self.paths.values():
            for i in range(len(p) - 2):
                out[(p[i], p[i + 1])].add((p[i + 1], p[i + 2]))
        order = {e: i for i, e in enumerate(self.edges())}
        return {e: sorted(cs, key=order.__getitem__)
                for e, cs in out.items()}

    def roots(self) -> list[tuple[int, int]]:
        """Distinct first edges (out of the source), in edge order."""
        firsts = {(p[0], p[1]) for p in self.paths.values()}
        return [e for e in self.edges() if e in firsts]

    def delivers(self) -> dict[tuple[int, int], int]:
        """edge -> destination region it terminates at (last hop only)."""
        return {(p[-2], p[-1]): d for d, p in self.paths.items()}


@dataclasses.dataclass
class MulticastPlan:
    """Output of the multicast planner: one source, a commodity per
    destination, egress billed once on the shared envelope ``G``.

    ``F[k]`` is the flow grid of the commodity serving ``dsts[k]``; the
    envelope satisfies ``F[k] <= G`` edge-wise, and ``G`` is what bytes
    actually traverse — the cost model and the data plane both run on it.
    """

    top: Topology
    src: int
    dsts: list[int]
    tput_goals: np.ndarray  # [D] Gbit/s floors the plan was asked for
    volume_gb: float  # GB delivered to EACH destination
    G: np.ndarray  # [V,V] envelope Gbit/s
    F: np.ndarray  # [D,V,V] per-commodity Gbit/s
    N: np.ndarray  # [V] VMs (int)
    M: np.ndarray  # [V,V] TCP connections (int)
    solver_status: str = "optimal"

    # ------------------------------------------------------------------ costs
    def delivered_gbps(self, dst: int) -> float:
        """Planned delivery rate into destination region ``dst``."""
        k = self.dsts.index(dst)
        return float(self.F[k][:, dst].sum())

    @property
    def active_dsts(self) -> list[int]:
        """Destinations with a positive goal or positive planned delivery."""
        out = []
        for k, d in enumerate(self.dsts):
            if self.tput_goals[k] > _FLOW_EPS or self.F[k][:, d].sum() > _FLOW_EPS:
                out.append(d)
        return out

    @property
    def throughput(self) -> float:
        """Sustained one-to-many rate: the slowest active branch (a chunk
        is retired once every destination holds it)."""
        rates = [self.delivered_gbps(d) for d in self.active_dsts]
        return float(min(rates)) if rates else 0.0

    @property
    def transfer_time_s(self) -> float:
        return self.volume_gb * GBIT_PER_GB / max(self.throughput, 1e-9)

    @property
    def egress_cost(self) -> float:
        """Envelope egress: every link billed once for the bytes it carries,
        no matter how many destinations ride it."""
        t = self.transfer_time_s
        gb_per_edge = self.G * t / GBIT_PER_GB
        return float((gb_per_edge * self.top.price_egress).sum())

    @property
    def vm_cost(self) -> float:
        return float(self.N @ self.top.price_vm) * self.transfer_time_s

    @property
    def total_cost(self) -> float:
        return self.egress_cost + self.vm_cost

    @property
    def cost_per_gb(self) -> float:
        """Cost per GB of source data replicated (not per GB delivered)."""
        return self.total_cost / max(self.volume_gb, 1e-9)

    @property
    def num_vms(self) -> int:
        return int(self.N.sum())

    def with_volume(self, volume_gb: float) -> "MulticastPlan":
        return dataclasses.replace(self, volume_gb=float(volume_gb))

    # ------------------------------------------------------------- valididity
    def validate(self, tol: float = _TOL) -> list[str]:
        """Violated-constraint descriptions (empty = valid). Flow
        conservation is checked per commodity."""
        top, G, N, M = self.top, self.G, self.N, self.M
        v = top.num_regions
        errs = []
        scale = max(float(self.tput_goals.max(initial=0.0)), 1.0)
        if (G < -tol).any() or (self.F < -tol).any():
            errs.append("G or F has negative entries")
        if (N < -tol).any() or (M < -tol).any():
            errs.append("N or M has negative entries")
        # envelope dominance
        if (self.F - G[None, :, :] > tol * scale).any():
            errs.append("commodity flow exceeds the envelope")
        # 4b on the envelope
        cap = top.tput * M / top.limit_conn
        if (G - cap > tol * scale).any():
            errs.append("4b: envelope exceeds per-connection capacity")
        for k, d in enumerate(self.dsts):
            Fk = self.F[k]
            goal = float(self.tput_goals[k])
            if Fk[self.src, :].sum() < goal - tol * scale:
                errs.append(f"4c: source egress below goal for dest {d}")
            if Fk[:, d].sum() < goal - tol * scale:
                errs.append(f"4d: ingress below goal at dest {d}")
            for r in range(v):
                if r in (self.src, d):
                    continue
                if abs(Fk[:, r].sum() - Fk[r, :].sum()) > tol * scale:
                    errs.append(
                        f"4e: commodity {d} flow not conserved at region {r}"
                    )
        for r in range(v):
            if G[:, r].sum() - top.limit_ingress[r] * N[r] > tol * scale:
                errs.append(f"4f: ingress over VM limit at region {r}")
            if G[r, :].sum() - top.limit_egress[r] * N[r] > tol * scale:
                errs.append(f"4g: egress over VM limit at region {r}")
            if M[r, :].sum() - top.limit_conn * N[r] > tol:
                errs.append(f"4h: outgoing connections over limit at region {r}")
            if M[:, r].sum() - top.limit_conn * N[r] > tol:
                errs.append(f"4i: incoming connections over limit at region {r}")
        if (N > top.limit_vm + tol).any():
            errs.append("4j: VM count over service limit")
        return errs

    # ------------------------------------------------------------------ trees
    def paths_to(
        self, dst: int, max_paths: int | None = None
    ) -> list[tuple[list[int], float]]:
        """Decomposition of the commodity flow serving ``dst`` into
        (path, Gbit/s) pairs — the per-destination tree decomposition."""
        k = self.dsts.index(dst)
        return _peel_paths(self.F[k].copy(), self.src, dst, max_paths)

    def trees(self, rel_eps: float = 1e-3) -> list[McTree]:
        """Peel the commodity flows into distribution trees.

        Each round takes the widest remaining path per active destination
        and carves the common rate (the min width) out of all of them: the
        result is a forwarding structure in which shared path segments carry
        a chunk once and fan out where destinations diverge.

        Every chunk must reach EVERY active destination, so every tree
        spans all of them: the commodity flows are first normalized to the
        slowest branch's delivery rate (a replication is governed by its
        slowest branch — ``throughput`` — and a faster branch's surplus
        capacity cannot retire chunks the slow branch still needs). Without
        this, unequal per-destination floors would peel trees serving only
        a subset, and chunks binned to those trees would never complete.

        Peeling stops when the residual is below ``rel_eps`` of the common
        rate (chunk streams are assigned to trees by rate share, so a
        sub-0.1% residual tree would only add idle stages to the data
        plane); a leftover beyond that warns."""
        act = self.active_dsts
        if not act:
            return []
        rate_of = {d: self.delivered_gbps(d) for d in act}
        r_min = min(rate_of.values())
        # scale each commodity down to the common rate; conservation is
        # preserved, so the widest-path peel still decomposes exactly
        res = {
            d: self.F[self.dsts.index(d)] * (r_min / rate_of[d])
            for d in act
        }
        remaining = {d: r_min for d in act}
        tol = rel_eps * max(r_min, 1e-9)
        cap = int((self.F > _FLOW_EPS).sum()) + 4 * len(act) + 4
        out: list[McTree] = []
        for _ in range(cap):
            live = [d for d in act if remaining[d] > tol]
            if not live:
                break
            paths: dict[int, list[int]] = {}
            widths = []
            for d in live:
                hit = _widest_path(res[d], self.src, d)
                if hit is None:
                    break
                paths[d], w = hit
                widths.append(min(w, remaining[d]))
            if len(paths) < len(live):
                break  # a destination ran dry mid-round: leftover warns below
            rate = float(min(widths))
            if rate <= _FLOW_EPS:
                break
            for d in live:
                for a, b in zip(paths[d][:-1], paths[d][1:]):
                    res[d][a, b] -= rate
                remaining[d] -= rate
            out.append(McTree(rate=rate, paths=paths))
        leftover = {d: remaining[d] for d in act if remaining[d] > tol}
        if leftover:
            warnings.warn(
                f"trees(): undecomposed delivery remains for {leftover} "
                f"after {len(out)} trees",
                stacklevel=2,
            )
        return out

    def describe(self) -> str:
        keys = self.top.keys()
        names = ", ".join(keys[d] for d in self.dsts)
        lines = [
            f"multicast plan {keys[self.src]} -> {{{names}}}: "
            f"{self.throughput:.2f} Gbps/dest, ${self.cost_per_gb:.4f}/GB "
            f"({self.num_vms} VMs, {int(self.M.sum())} conns)"
        ]
        for t in self.trees():
            lines.append(f"  tree @ {t.rate:.2f} Gbps:")
            for d in sorted(t.paths):
                hops = " -> ".join(keys[i] for i in t.paths[d])
                lines.append(f"    {hops}")
        return "\n".join(lines)
