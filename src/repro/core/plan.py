"""TransferPlan: the output of Skyplane's planner (paper Fig. 5).

A plan pins down the overlay topology (F), resource allocation (N VMs per
region, M connections per region pair) and exposes the paper's cost model:

  egress cost = sum_e  (bytes through e) * price_e          [volume-billed, §2]
  vm cost     = sum_v  N_v * price_vm_v * transfer_time
  transfer_time = VOLUME / TPUT_GOAL                        [linear reformulation]

``validate`` re-checks every constraint 4b-4j so tests (and hypothesis
properties) can assert that any plan the solver emits is feasible.
``paths`` decomposes F into weighted s->t paths for the data plane.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .topology import GBIT_PER_GB, Topology

_TOL = 1e-5


@dataclasses.dataclass
class TransferPlan:
    top: Topology
    src: int
    dst: int
    tput_goal: float  # Gbit/s
    volume_gb: float  # GB to move
    F: np.ndarray  # [V,V] Gbit/s
    N: np.ndarray  # [V] VMs (int)
    M: np.ndarray  # [V,V] TCP connections (int)
    solver_status: str = "optimal"

    # ------------------------------------------------------------------ costs
    @property
    def throughput(self) -> float:
        """Planned end-to-end throughput (Gbit/s)."""
        return float(self.F[self.src, :].sum())

    @property
    def transfer_time_s(self) -> float:
        return self.volume_gb * GBIT_PER_GB / max(self.throughput, 1e-9)

    @property
    def egress_cost(self) -> float:
        t = self.transfer_time_s
        gb_per_edge = self.F * t / GBIT_PER_GB
        return float((gb_per_edge * self.top.price_egress).sum())

    @property
    def vm_cost(self) -> float:
        return float(self.N @ self.top.price_vm) * self.transfer_time_s

    @property
    def total_cost(self) -> float:
        return self.egress_cost + self.vm_cost

    @property
    def cost_per_gb(self) -> float:
        return self.total_cost / max(self.volume_gb, 1e-9)

    @property
    def num_vms(self) -> int:
        return int(self.N.sum())

    def with_volume(self, volume_gb: float) -> "TransferPlan":
        """The same allocation, re-scoped to a different volume — how the
        transfer service carries a plan over to the *remaining* bytes of a
        partially completed job (costs and transfer time rescale; F/N/M and
        feasibility are untouched)."""
        return dataclasses.replace(self, volume_gb=float(volume_gb))

    # ------------------------------------------------------------- valididity
    def validate(self, tol: float = _TOL) -> list[str]:
        """Returns a list of violated-constraint descriptions (empty = valid)."""
        top, F, N, M = self.top, self.F, self.N, self.M
        v = top.num_regions
        errs = []
        scale = max(self.tput_goal, 1.0)
        if (F < -tol).any():
            errs.append("F has negative entries")
        if (N < -tol).any() or (M < -tol).any():
            errs.append("N or M has negative entries")
        # 4b
        cap = top.tput * M / top.limit_conn
        if (F - cap > tol * scale).any():
            errs.append("4b: flow exceeds per-connection capacity")
        # 4c / 4d
        if F[self.src, :].sum() < self.tput_goal - tol * scale:
            errs.append("4c: source egress below goal")
        if F[:, self.dst].sum() < self.tput_goal - tol * scale:
            errs.append("4d: dest ingress below goal")
        # 4e
        for r in range(v):
            if r in (self.src, self.dst):
                continue
            if abs(F[:, r].sum() - F[r, :].sum()) > tol * scale:
                errs.append(f"4e: flow not conserved at region {r}")
        # 4f / 4g
        for r in range(v):
            if F[:, r].sum() - top.limit_ingress[r] * N[r] > tol * scale:
                errs.append(f"4f: ingress over VM limit at region {r}")
            if F[r, :].sum() - top.limit_egress[r] * N[r] > tol * scale:
                errs.append(f"4g: egress over VM limit at region {r}")
        # 4h / 4i
        for r in range(v):
            if M[r, :].sum() - top.limit_conn * N[r] > tol:
                errs.append(f"4h: outgoing connections over limit at region {r}")
            if M[:, r].sum() - top.limit_conn * N[r] > tol:
                errs.append(f"4i: incoming connections over limit at region {r}")
        # 4j
        if (N > top.limit_vm + tol).any():
            errs.append("4j: VM count over service limit")
        return errs

    # ------------------------------------------------------------------ paths
    def paths(self, max_paths: int = 32) -> list[tuple[list[int], float]]:
        """Greedy flow decomposition of F into (region path, Gbit/s) pairs.

        Repeatedly peels the widest remaining s->t path. Used by the data
        plane to map chunk streams onto gateway chains.
        """
        F = self.F.copy()
        v = self.top.num_regions
        out: list[tuple[list[int], float]] = []
        for _ in range(max_paths):
            # widest path via Dijkstra-like relaxation on bottleneck capacity
            width = np.full(v, 0.0)
            prev = np.full(v, -1, dtype=np.int64)
            width[self.src] = np.inf
            visited = np.zeros(v, dtype=bool)
            for _ in range(v):
                u = -1
                best = 0.0
                for i in range(v):
                    if not visited[i] and width[i] > best:
                        best = width[i]
                        u = i
                if u < 0:
                    break
                visited[u] = True
                if u == self.dst:
                    break
                for w in range(v):
                    cand = min(width[u], F[u, w])
                    if cand > width[w] + 1e-12:
                        width[w] = cand
                        prev[w] = u
            if width[self.dst] <= 1e-9:
                break
            path = [self.dst]
            while path[-1] != self.src:
                path.append(int(prev[path[-1]]))
            path.reverse()
            flow = float(width[self.dst])
            for a, b in zip(path[:-1], path[1:]):
                F[a, b] -= flow
            out.append((path, flow))
        return out

    def describe(self) -> str:
        keys = self.top.keys()
        lines = [
            f"plan {keys[self.src]} -> {keys[self.dst]}: "
            f"{self.throughput:.2f} Gbps, ${self.cost_per_gb:.4f}/GB "
            f"({self.num_vms} VMs, {int(self.M.sum())} conns)"
        ]
        for path, flow in self.paths():
            hops = " -> ".join(keys[i] for i in path)
            lines.append(f"  {flow:6.2f} Gbps via {hops}")
        return "\n".join(lines)
