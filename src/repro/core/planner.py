"""Skyplane's planner (paper §4-§5): cost-min and throughput-max modes.

  * ``plan_cost_min``  — minimize $ subject to a throughput floor (Eq. 4a-4j).
  * ``plan_tput_max``  — maximize throughput subject to a price ceiling, via
    the paper's §5.2 procedure: sweep cost-min solves over a range of
    throughput goals, form the Pareto frontier, pick the fastest plan whose
    cost fits the ceiling.

Planning runs on a pruned candidate subgraph (src, dst + top-K relays) —
mirroring how the open-source Skyplane keeps MILPs "solvable in under 5
seconds" — and maps the solution back onto the full topology.

Solver backends (the planner hot path):

  * ``backend="numpy"`` (default) — the sequential reference pipeline; each
    LP re-derives from the cached ``milp.LPStructure`` and solves on the
    dense numpy IPM.
  * ``backend="jax"``   — the same round-down pipeline, but every stage of
    the sweep (root relaxations, feasibility-repair probes, fixed-N and
    fixed-N+M refits) runs as one batched JAX IPM call across all samples,
    with per-sample numpy fallback on KKT failure. This is the *integerized*
    fast path; ``pareto_frontier_fast`` remains the continuous-relaxation
    shortcut for frontier exploration.

Pruned subgraphs (and the LP structures cached on them) are memoized per
(src, dst), so repeated planner calls — the "thousands of solves" workload
of systems built on this planner — never re-assemble constraint matrices.
"""

from __future__ import annotations

import dataclasses
import warnings

import numpy as np

from repro.obs.trace import get_tracer

from . import milp
from .plan import MulticastPlan, TransferPlan
from .solver.bnb import (
    _mc_scale_probe,
    solve_milp,
    solve_milp_batched,
    solve_multicast,
)
from .solver.ipm import solve_lp
from .spec import PlanSpec
from .topology import Topology


def _warn_deprecated(name: str) -> None:
    warnings.warn(
        f"Planner.{name}() is deprecated; build a core.PlanSpec and call "
        "Planner.plan(spec) (see README 'Planning API')",
        DeprecationWarning,
        stacklevel=3,
    )


@dataclasses.dataclass
class ParetoPoint:
    tput_goal: float
    cost_per_gb: float
    plan: TransferPlan


class Planner:
    def __init__(
        self,
        top: Topology,
        *,
        max_relays: int = 10,
        mode: str = "relaxed",  # "relaxed" (round-down, §5.1.3) or "exact"
        belief=None,  # calibrate.BeliefGrid powering the robustness knob
        link_capacity_scale: float | None = None,  # data-plane shared-link
        # capacity factor: robust scale cuts then also cap each drifted
        # link's AGGREGATE flow (incidents hit the interconnect, which more
        # VMs/connections cannot buy back)
    ):
        self.top = top
        self.max_relays = max_relays
        self.mode = mode
        self.belief = belief
        self.link_capacity_scale = link_capacity_scale
        self._prune_cache: dict[tuple[str, str], tuple] = {}

    # ------------------------------------------------------------- robustness
    def _resolve_scale(
        self, robustness: float, tput_scale: np.ndarray | None
    ) -> np.ndarray | None:
        """The full-grid [V,V] throughput scale a solve should plan under.

        ``robustness`` > 0 asks the attached belief for its z-lower-
        confidence-bound grid relative to this planner's (epoch) grid;
        an explicit ``tput_scale`` composes with it elementwise (min —
        both pessimisms must hold). Returns None when nothing applies."""
        scale = None
        if robustness and robustness > 0.0:
            if self.belief is None:
                raise ValueError(
                    "robustness > 0 needs a belief attached to the Planner"
                )
            scale = self.belief.scale_grid(self.top, z=float(robustness))
        if tput_scale is not None:
            ts = np.asarray(tput_scale, dtype=float)
            scale = ts if scale is None else np.minimum(scale, ts)
        return scale

    def _scale_cuts(self, struct, keep, tput_scale, agg_scale=None) -> list:
        """Map a full-grid scale vector into ``struct``'s edge space and
        emit the tightened rows (``milp.*.scale_cuts``) — shared by the
        unicast and multicast paths, zero re-assembly either way.

        ``agg_scale`` (full-grid [V,V], non-finite = uncapped) adds
        per-link aggregate share caps — the fleet controller's weighted
        fair shares — composed with the data plane's scalar
        ``link_capacity_scale`` where both apply."""
        if tput_scale is None and agg_scale is None:
            return []
        ix = np.asarray(keep, dtype=np.int64)
        if tput_scale is not None:
            sub_scale = np.asarray(tput_scale, dtype=float)[np.ix_(ix, ix)]
            edge_scale = sub_scale[struct.eu, struct.ew]
        else:
            edge_scale = np.ones(struct.n_edges)
        agg = self.link_capacity_scale
        if agg_scale is not None:
            share = np.asarray(agg_scale, dtype=float)[np.ix_(ix, ix)]
            share_e = share[struct.eu, struct.ew]
            capped = np.isfinite(share_e)
            per_edge = np.where(capped, share_e, np.inf)
            if agg is not None:
                # a tenant's share of the data-plane capacity factor; on
                # drifted edges the plain incident cap must still hold
                per_edge = np.where(capped, share_e * float(agg), np.inf)
                drifted = edge_scale < 1.0 - 1e-9
                per_edge[drifted] = np.minimum(per_edge[drifted], float(agg))
            agg = per_edge
        return struct.scale_cuts(edge_scale, agg_cap=agg)

    # ----------------------------------------------------------------- bounds
    def _max_throughput(
        self,
        src: str,
        dst: str,
        *,
        degraded_links: dict[tuple[int, int], float] | None = None,
        vm_caps: dict[int, float] | None = None,
        robustness: float = 0.0,
        tput_scale: np.ndarray | None = None,
        agg_scale: np.ndarray | None = None,
    ) -> float:
        """Max achievable tput (Gbit/s): LP max-flow with N at the VM limit.

        degraded_links / vm_caps (full-topology region indices) constrain
        the same cached LPStructure — see the cost_min objective.
        robustness / tput_scale bound the flow by the scaled (lower-
        confidence) grid; agg_scale adds per-link share caps."""
        sub, s, t, keep = self._prune(src, dst)
        struct = milp.structure(sub, s, t)
        cuts = self._degrade_cuts(struct, keep, degraded_links, vm_caps)
        cuts = cuts + self._scale_cuts(
            struct, keep, self._resolve_scale(robustness, tput_scale),
            agg_scale,
        )
        fixed_n = np.full(sub.num_regions, float(sub.limit_vm))
        if vm_caps:
            inv = {full: i for i, full in enumerate(keep)}
            for r, cap in vm_caps.items():
                if r in inv:
                    fixed_n[inv[r]] = min(fixed_n[inv[r]], float(cap))
        lp = struct.lp(0.0, fixed_n=fixed_n, extra_ub=cuts or None)
        if lp.trivially_infeasible:
            return 0.0
        # maximize source egress == minimize -sum F_{s,*}
        c = struct.outflow_c(struct.pin_pattern(True, False))
        res = solve_lp(c, lp.A_ub, lp.b_ub, lp.A_eq, lp.b_eq)
        if not res.ok:
            return 0.0
        return float(-res.fun)

    def direct_throughput(
        self, src: str, dst: str, num_vms: int | None = None
    ) -> float:
        """Throughput of the direct path with ``num_vms`` VMs at each end."""
        n = float(num_vms if num_vms is not None else self.top.limit_vm)
        s, t = self.top.index(src), self.top.index(dst)
        return float(
            n * min(
                self.top.tput[s, t],
                self.top.limit_egress[s],
                self.top.limit_ingress[t],
            )
        )

    # --------------------------------------------------------------- unicast
    def _cost_min(
        self,
        src: str,
        dst: str,
        tput_goal_gbps: float,
        volume_gb: float,
        *,
        mode: str | None = None,
        backend: str = "numpy",
        degraded_links: dict[tuple[int, int], float] | None = None,
        vm_caps: dict[int, float] | None = None,
        robustness: float = 0.0,
        tput_scale: np.ndarray | None = None,
        agg_scale: np.ndarray | None = None,
    ) -> TransferPlan:
        """Paper mode 1: minimize cost subject to a throughput floor.

        degraded_links maps a full-topology (src_region, dst_region) index
        pair to the fraction of grid capacity the link still has; each
        becomes a tightened 4b row (F_e <= phi * tput_e / limit_conn * M_e)
        on the *cached* LPStructure. vm_caps maps a region index to a VM
        ceiling below the service limit (an unhealthy region; 0 excludes
        it). This is the degraded-topology re-planning hook of the
        fault-tolerant TransferService: nothing is re-assembled, the cuts
        ride on the memoized structure as extra rows.

        robustness > 0 plans against the attached belief's z-lower-
        confidence-bound grid (uncertainty-aware planning); tput_scale
        applies an explicit full-grid scale. Both ride the cached
        structure as scale cuts — the same zero-reassembly discipline.
        """
        sub, s, t, keep = self._prune(src, dst)
        scale = self._resolve_scale(robustness, tput_scale)
        cuts = None
        if degraded_links or vm_caps or scale is not None or agg_scale is not None:
            struct = milp.structure(sub, s, t)
            cuts = self._degrade_cuts(struct, keep, degraded_links, vm_caps)
            cuts = cuts + self._scale_cuts(struct, keep, scale, agg_scale)
        res = solve_milp(sub, s, t, tput_goal_gbps, mode=mode or self.mode,
                         backend=backend, extra_ub=cuts or None)
        return self._lift(sub, keep, src, dst, tput_goal_gbps, volume_gb, res)

    def _tput_max(
        self,
        src: str,
        dst: str,
        cost_ceiling_per_gb: float,
        volume_gb: float,
        *,
        n_samples: int = 40,
        mode: str | None = None,
        backend: str = "numpy",
        robustness: float = 0.0,
        tput_scale: np.ndarray | None = None,
    ) -> TransferPlan:
        """Paper mode 2 (§5.2): Pareto sweep, pick fastest plan under ceiling."""
        frontier = self._pareto(
            src, dst, volume_gb, n_samples=n_samples, mode=mode,
            backend=backend, robustness=robustness, tput_scale=tput_scale,
        )
        feasible = [p for p in frontier if p.cost_per_gb <= cost_ceiling_per_gb + 1e-9]
        if not feasible:
            # ceiling below even the cheapest plan: return cheapest as "best effort"
            cheapest = min(frontier, key=lambda p: p.cost_per_gb)
            plan = cheapest.plan
            plan.solver_status = "cost_ceiling_infeasible"
            return plan
        best = max(feasible, key=lambda p: p.tput_goal)
        return best.plan

    # -------------------------------------------------------------- multicast
    def _mc_cost_min(
        self,
        src: str,
        dsts: list[str],
        tput_floor_gbps,
        volume_gb: float,
        *,
        degraded_links: dict[tuple[int, int], float] | None = None,
        vm_caps: dict[int, float] | None = None,
        robustness: float = 0.0,
        tput_scale: np.ndarray | None = None,
        agg_scale: np.ndarray | None = None,
    ) -> MulticastPlan:
        """One-to-many cost-min: minimize $ with every destination receiving
        at least its throughput floor, billing each overlay link's egress
        once for the shared chunk stream (core/milp.MulticastLPStructure).

        ``tput_floor_gbps`` is a scalar floor applied to every destination
        or a per-destination sequence (zeros drop a destination from the
        trees — how the service re-plans only the surviving branches of a
        partially completed replication). degraded_links / vm_caps take
        full-topology indices and become extra rows on the cached structure,
        exactly as in ``plan_cost_min`` — re-planning re-assembles nothing.

        A single destination delegates to the unicast round-down, so the
        plan is bit-for-bit the one ``plan_cost_min`` returns.
        """
        goals = np.asarray(tput_floor_gbps, dtype=float)
        if goals.ndim == 0:
            goals = np.full(len(dsts), float(goals))
        if goals.shape != (len(dsts),):
            raise ValueError("need one throughput floor per destination")
        if len(dsts) == 1:
            uni = self._cost_min(
                src, dsts[0], float(goals[0]), volume_gb,
                degraded_links=degraded_links, vm_caps=vm_caps,
                robustness=robustness, tput_scale=tput_scale,
                agg_scale=agg_scale,
            )
            return MulticastPlan(
                top=self.top, src=uni.src, dsts=[uni.dst],
                tput_goals=goals, volume_gb=volume_gb,
                G=uni.F.copy(), F=uni.F[None, :, :].copy(),
                N=uni.N, M=uni.M, solver_status=uni.solver_status,
            )
        sub, s, ds, keep = self._prune_mc(src, dsts)
        scale = self._resolve_scale(robustness, tput_scale)
        cuts = None
        if degraded_links or vm_caps or scale is not None or agg_scale is not None:
            struct = milp.multicast_structure(sub, s, ds)
            cuts = self._mc_degrade_cuts(struct, keep, degraded_links, vm_caps)
            cuts = cuts + self._scale_cuts(struct, keep, scale, agg_scale)
        res = solve_multicast(sub, s, ds, goals, extra_ub=cuts or None)
        return self._lift_mc(sub, keep, src, dsts, goals, volume_gb, res)

    def _mc_tput_max(
        self,
        src: str,
        dsts: list[str],
        cost_ceiling_per_gb: float,
        volume_gb: float,
        *,
        n_samples: int = 12,
        robustness: float = 0.0,
        tput_scale: np.ndarray | None = None,
    ) -> MulticastPlan:
        """One-to-many throughput-max under a cost ceiling (§5.2 applied to
        the multicast MILP): sweep uniform per-destination floors, estimate
        the cost frontier from ONE batched relaxation solve (the sweep LPs
        share every matrix of the cached structure and differ only in the
        goal rows of b), then integerize candidates fastest-first until one
        fits the ceiling. robustness / tput_scale constrain the candidate
        range and every integerized solve by the scaled grid (the batched
        relaxation filter itself stays cut-free; over-optimistic candidates
        are rejected by the exact robust re-check)."""
        if len(dsts) == 1:
            uni = self._tput_max(src, dsts[0], cost_ceiling_per_gb,
                                 volume_gb, robustness=robustness,
                                 tput_scale=tput_scale)
            return MulticastPlan(
                top=self.top, src=uni.src, dsts=[uni.dst],
                tput_goals=np.array([uni.tput_goal]), volume_gb=volume_gb,
                G=uni.F.copy(), F=uni.F[None, :, :].copy(),
                N=uni.N, M=uni.M, solver_status=uni.solver_status,
            )
        from .solver.ipm_batch import solve_lp_batched_auto

        sub, s, ds, keep = self._prune_mc(src, dsts)
        hi = self._mc_max_throughput(
            src, dsts, robustness=robustness, tput_scale=tput_scale
        )
        if hi <= 0:
            raise ValueError(f"no multicast path from {src} to {dsts}")
        rates = np.linspace(hi / n_samples, hi * 0.999, n_samples)
        struct = milp.multicast_structure(sub, s, ds)
        lp = struct.lp(np.full(len(ds), float(rates[0])))
        b_batch = np.tile(lp.b_ub[None, :], (n_samples, 1))
        for i, g in enumerate(rates):
            b_batch[i, struct.rows_4c] = -g
            b_batch[i, struct.rows_4d] = -g
        _, _funs, ok = solve_lp_batched_auto(
            lp.c, lp.A_ub, b_batch, lp.A_eq, lp.b_eq
        )
        # the batched relaxation sweep prunes infeasible rates; exact
        # integerized costs are re-checked below, fastest-first
        cand = sorted(
            (float(g) for i, g in enumerate(rates) if ok[i]),
            reverse=True,
        )
        best: MulticastPlan | None = None
        for g in cand:
            plan = self._mc_cost_min(
                src, dsts, g, volume_gb,
                robustness=robustness, tput_scale=tput_scale,
            )
            if plan.solver_status != "optimal":
                continue
            if best is None or plan.cost_per_gb < best.cost_per_gb:
                best = plan
            if plan.cost_per_gb <= cost_ceiling_per_gb + 1e-9:
                return plan
        if best is None:
            raise RuntimeError(f"no feasible multicast plan {src}->{dsts}")
        best.solver_status = "cost_ceiling_infeasible"
        return best

    def _mc_max_throughput(
        self,
        src: str,
        dsts: list[str],
        *,
        degraded_links: dict[tuple[int, int], float] | None = None,
        vm_caps: dict[int, float] | None = None,
        robustness: float = 0.0,
        tput_scale: np.ndarray | None = None,
        agg_scale: np.ndarray | None = None,
    ) -> float:
        """Max uniform per-destination rate (Gbit/s) with N at the VM limit
        — the multicast scale probe with unit goals and no cap."""
        sub, s, ds, keep = self._prune_mc(src, dsts)
        struct = milp.multicast_structure(sub, s, ds)
        cuts = self._mc_degrade_cuts(struct, keep, degraded_links, vm_caps)
        cuts = cuts + self._scale_cuts(
            struct, keep, self._resolve_scale(robustness, tput_scale),
            agg_scale,
        )
        fixed_n = np.full(sub.num_regions, float(sub.limit_vm))
        if vm_caps:
            inv = {full: i for i, full in enumerate(keep)}
            for r, cap in vm_caps.items():
                if r in inv:
                    fixed_n[inv[r]] = min(fixed_n[inv[r]], float(cap))
        return _mc_scale_probe(
            struct, np.ones(len(ds)), fixed_n=fixed_n,
            extra_ub=cuts or None, cap=None,
        )

    def _pareto_fast(
        self,
        src: str,
        dst: str,
        volume_gb: float,
        *,
        n_samples: int = 64,
    ) -> list[ParetoPoint]:
        """§5.2 sweep as ONE batched JAX IPM solve (solver/ipm_jax).

        The N cost-min LPs differ only in the two goal rows of b, so the
        relaxation solves as a single vmapped call; plans returned here are
        the *continuous* relaxations (≤1% from integral per §5.1.3 — used
        for frontier exploration). ``pareto_frontier(backend="jax")`` is the
        batched *integerized* sweep; ``plan_tput_max`` integerizes winners."""
        from .solver.ipm_batch import solve_lp_batched_auto as solve_lp_batched

        sub, s, t, keep = self._prune(src, dst)
        hi = self._max_throughput(src, dst)
        if hi <= 0:
            raise ValueError(f"no path from {src} to {dst}")
        goals = np.linspace(hi / n_samples, hi * 0.999, n_samples)
        lp = milp.structure(sub, s, t).lp(float(goals[0]))
        b_batch = np.tile(lp.b_ub[None, :], (n_samples, 1))
        b_batch[:, lp.row_4c] = -goals
        b_batch[:, lp.row_4d] = -goals
        xs, funs, ok = solve_lp_batched(lp.c, lp.A_ub, b_batch, lp.A_eq, lp.b_eq)
        out = []
        for i, g in enumerate(goals):
            if not ok[i]:
                continue
            F, N, M = lp.split(xs[i])
            res = type("R", (), {})()
            res.F, res.N, res.M = F, N, M
            res.status = "optimal"
            res.achieved_tput = float(g)
            plan = self._lift(sub, keep, src, dst, float(g), volume_gb, res)
            out.append(ParetoPoint(float(g), plan.cost_per_gb, plan))
        if not out:
            # numerical fallback: the exact sequential path
            return self._pareto(src, dst, volume_gb,
                                n_samples=min(n_samples, 20))
        return out

    def _pareto(
        self,
        src: str,
        dst: str,
        volume_gb: float,
        *,
        n_samples: int = 40,
        mode: str | None = None,
        backend: str = "numpy",
        robustness: float = 0.0,
        tput_scale: np.ndarray | None = None,
    ) -> list[ParetoPoint]:
        """Cost-min solves across a range of throughput goals (paper §5.2).

        backend="jax" runs the whole integerized sweep stage-by-stage through
        the batched JAX IPM (solve_milp_batched) instead of n_samples
        sequential round-downs; results match the numpy path (per-sample
        fallback covers KKT failures). The exact B&B mode is sequential-only,
        as are robust sweeps (scale cuts are per-instance extra rows the
        shared-matrix batched pipeline does not take).
        """
        if backend not in ("numpy", "jax"):
            raise ValueError(f"unknown backend {backend!r} (use 'numpy' or 'jax')")
        sub, s, t, keep = self._prune(src, dst)
        scale = self._resolve_scale(robustness, tput_scale)
        cuts = None
        if scale is not None:
            struct = milp.structure(sub, s, t)
            cuts = self._scale_cuts(struct, keep, scale) or None
        hi = self._max_throughput(src, dst, tput_scale=scale)
        if hi <= 0:
            raise ValueError(f"no path from {src} to {dst}")
        goals = np.linspace(hi / n_samples, hi * 0.999, n_samples)
        out = []
        if backend == "jax" and (mode or self.mode) == "relaxed" and not cuts:
            batch = solve_milp_batched(sub, s, t, goals)
            for g, res in zip(goals, batch):
                if not res.ok:
                    continue
                plan = self._lift(sub, keep, src, dst, float(g), volume_gb, res)
                out.append(ParetoPoint(float(g), plan.cost_per_gb, plan))
        else:
            for g in goals:
                res = solve_milp(sub, s, t, float(g), mode=mode or self.mode,
                                 extra_ub=cuts)
                if not res.ok:
                    continue
                plan = self._lift(sub, keep, src, dst, float(g), volume_gb, res)
                out.append(ParetoPoint(float(g), plan.cost_per_gb, plan))
        if not out:
            raise RuntimeError(f"planner found no feasible plan {src}->{dst}")
        return out

    # ------------------------------------------------------------- public API
    def plan(self, spec: PlanSpec):
        """THE planning entry point: one ``PlanSpec`` in, one result out.

        Dispatches on ``spec.objective`` (and ``dst`` vs ``dsts`` for the
        unicast/multicast formulation). Returns a ``TransferPlan`` /
        ``MulticastPlan`` for ``cost_min`` and ``tput_max``, a float for
        ``max_throughput``, and a list of ``ParetoPoint`` for the sweeps.
        The eight legacy ``plan_*`` / ``max_*`` / ``pareto_*`` methods are
        deprecated shims over this method."""
        tr = get_tracer()
        if not tr.enabled:
            return self._plan_impl(spec)
        w0 = tr.now_wall()
        b0 = milp._struct_builds.value
        result = self._plan_impl(spec)
        tr.span(
            "planner.plan", w0, tr.now_wall() - w0, track="planner",
            objective=spec.objective, src=spec.src,
            dst=spec.dst if not spec.multicast else ",".join(spec.dsts),
            struct_builds=int(milp._struct_builds.value - b0),
        )
        return result

    def _plan_impl(self, spec: PlanSpec):
        obj = spec.objective
        ns = {} if spec.n_samples is None else {"n_samples": spec.n_samples}
        if obj == "cost_min":
            if spec.multicast:
                return self._mc_cost_min(
                    spec.src, list(spec.dsts), spec.goals(), spec.volume_gb,
                    degraded_links=spec.degraded_links_map,
                    vm_caps=spec.vm_caps_map, robustness=spec.robustness,
                    tput_scale=spec.tput_scale, agg_scale=spec.agg_scale,
                )
            return self._cost_min(
                spec.src, spec.dst, spec.goals(), spec.volume_gb,
                mode=spec.mode, backend=spec.backend,
                degraded_links=spec.degraded_links_map,
                vm_caps=spec.vm_caps_map, robustness=spec.robustness,
                tput_scale=spec.tput_scale, agg_scale=spec.agg_scale,
            )
        if obj == "tput_max":
            if spec.multicast:
                return self._mc_tput_max(
                    spec.src, list(spec.dsts), spec.cost_ceiling_per_gb,
                    spec.volume_gb, robustness=spec.robustness,
                    tput_scale=spec.tput_scale, **ns,
                )
            return self._tput_max(
                spec.src, spec.dst, spec.cost_ceiling_per_gb, spec.volume_gb,
                mode=spec.mode, backend=spec.backend,
                robustness=spec.robustness, tput_scale=spec.tput_scale, **ns,
            )
        if obj == "max_throughput":
            if spec.multicast:
                return self._mc_max_throughput(
                    spec.src, list(spec.dsts),
                    degraded_links=spec.degraded_links_map,
                    vm_caps=spec.vm_caps_map, robustness=spec.robustness,
                    tput_scale=spec.tput_scale, agg_scale=spec.agg_scale,
                )
            return self._max_throughput(
                spec.src, spec.dst,
                degraded_links=spec.degraded_links_map,
                vm_caps=spec.vm_caps_map, robustness=spec.robustness,
                tput_scale=spec.tput_scale, agg_scale=spec.agg_scale,
            )
        if obj == "pareto":
            return self._pareto(
                spec.src, spec.dst, spec.volume_gb, mode=spec.mode,
                backend=spec.backend, robustness=spec.robustness,
                tput_scale=spec.tput_scale, **ns,
            )
        return self._pareto_fast(spec.src, spec.dst, spec.volume_gb, **ns)

    def plan_cohort(self, specs: list[PlanSpec]) -> list:
        """Plan a whole admitted cohort in one sweep.

        Unicast ``cost_min`` specs in relaxed mode carrying no per-spec
        cuts are grouped by (src, dst) route and each group solves as ONE
        batched round-down sweep (``solve_milp_batched``) over the route's
        cached LPStructure — the fleet controller's admission path, a
        single stacked solve instead of a Python loop of per-job planner
        calls. Everything else (multicast, robust, degraded, exact-mode)
        falls back to the sequential ``plan()`` path, which still rides
        cached structures. Results come back in spec order."""
        tr = get_tracer()
        w0 = tr.now_wall() if tr.enabled else 0.0
        out: list = [None] * len(specs)
        groups: dict[tuple[str, str], list[int]] = {}
        for i, sp in enumerate(specs):
            batchable = (
                sp.objective == "cost_min"
                and not sp.multicast
                and (sp.mode or self.mode) == "relaxed"
                and not sp.degraded_links
                and not sp.vm_caps
                and not sp.robustness
                and sp.tput_scale is None
                and sp.agg_scale is None
            )
            if batchable:
                groups.setdefault((sp.src, sp.dst), []).append(i)
            else:
                out[i] = self.plan(sp)
        for (src, dst), ix in groups.items():
            sub, s, t, keep = self._prune(src, dst)
            goals = np.array([specs[i].goals() for i in ix], dtype=float)
            batch = solve_milp_batched(sub, s, t, goals)
            for i, g, res in zip(ix, goals, batch):
                if not res.ok:
                    # infeasible-goal corner: re-solve sequentially so the
                    # caller sees the same degraded status plan() returns
                    out[i] = self.plan(specs[i])
                    continue
                out[i] = self._lift(
                    sub, keep, src, dst, float(g), specs[i].volume_gb, res
                )
        if tr.enabled:
            tr.span(
                "planner.plan_cohort", w0, tr.now_wall() - w0,
                track="planner", n_specs=len(specs),
                n_batched_routes=len(groups),
            )
        return out

    # ------------------------------------------------- deprecated shims
    # The pre-PlanSpec surface: each method warns, builds the equivalent
    # spec, and delegates to plan() — bitwise-identical results (pinned
    # by tests/test_api_surface.py).
    def max_throughput(self, src, dst, *, degraded_links=None, vm_caps=None,
                       robustness=0.0, tput_scale=None):
        _warn_deprecated("max_throughput")
        return self.plan(PlanSpec(
            objective="max_throughput", src=src, dst=dst,
            degraded_links=degraded_links, vm_caps=vm_caps,
            robustness=robustness, tput_scale=tput_scale,
        ))

    def max_multicast_throughput(self, src, dsts, *, degraded_links=None,
                                 vm_caps=None, robustness=0.0,
                                 tput_scale=None):
        _warn_deprecated("max_multicast_throughput")
        return self.plan(PlanSpec(
            objective="max_throughput", src=src, dsts=tuple(dsts),
            degraded_links=degraded_links, vm_caps=vm_caps,
            robustness=robustness, tput_scale=tput_scale,
        ))

    def plan_cost_min(self, src, dst, tput_goal_gbps, volume_gb, *,
                      mode=None, backend="numpy", degraded_links=None,
                      vm_caps=None, robustness=0.0, tput_scale=None):
        _warn_deprecated("plan_cost_min")
        return self.plan(PlanSpec(
            objective="cost_min", src=src, dst=dst,
            tput_goal_gbps=tput_goal_gbps, volume_gb=volume_gb, mode=mode,
            backend=backend, degraded_links=degraded_links, vm_caps=vm_caps,
            robustness=robustness, tput_scale=tput_scale,
        ))

    def plan_tput_max(self, src, dst, cost_ceiling_per_gb, volume_gb, *,
                      n_samples=40, mode=None, backend="numpy",
                      robustness=0.0, tput_scale=None):
        _warn_deprecated("plan_tput_max")
        return self.plan(PlanSpec(
            objective="tput_max", src=src, dst=dst,
            cost_ceiling_per_gb=cost_ceiling_per_gb, volume_gb=volume_gb,
            n_samples=n_samples, mode=mode, backend=backend,
            robustness=robustness, tput_scale=tput_scale,
        ))

    def plan_multicast_cost_min(self, src, dsts, tput_floor_gbps, volume_gb,
                                *, degraded_links=None, vm_caps=None,
                                robustness=0.0, tput_scale=None):
        _warn_deprecated("plan_multicast_cost_min")
        return self.plan(PlanSpec(
            objective="cost_min", src=src, dsts=tuple(dsts),
            tput_goal_gbps=tput_floor_gbps, volume_gb=volume_gb,
            degraded_links=degraded_links, vm_caps=vm_caps,
            robustness=robustness, tput_scale=tput_scale,
        ))

    def plan_multicast_tput_max(self, src, dsts, cost_ceiling_per_gb,
                                volume_gb, *, n_samples=12, robustness=0.0,
                                tput_scale=None):
        _warn_deprecated("plan_multicast_tput_max")
        return self.plan(PlanSpec(
            objective="tput_max", src=src, dsts=tuple(dsts),
            cost_ceiling_per_gb=cost_ceiling_per_gb, volume_gb=volume_gb,
            n_samples=n_samples, robustness=robustness,
            tput_scale=tput_scale,
        ))

    def pareto_frontier(self, src, dst, volume_gb, *, n_samples=40,
                        mode=None, backend="numpy", robustness=0.0,
                        tput_scale=None):
        _warn_deprecated("pareto_frontier")
        return self.plan(PlanSpec(
            objective="pareto", src=src, dst=dst, volume_gb=volume_gb,
            n_samples=n_samples, mode=mode, backend=backend,
            robustness=robustness, tput_scale=tput_scale,
        ))

    def pareto_frontier_fast(self, src, dst, volume_gb, *, n_samples=64):
        _warn_deprecated("pareto_frontier_fast")
        return self.plan(PlanSpec(
            objective="pareto_fast", src=src, dst=dst, volume_gb=volume_gb,
            n_samples=n_samples,
        ))

    # -------------------------------------------------------------- internals
    @staticmethod
    def _degrade_cuts(
        struct,
        keep: list[int],
        degraded_links: dict[tuple[int, int], float] | None,
        vm_caps: dict[int, float] | None,
    ) -> list[tuple[np.ndarray, float]]:
        """Degraded-topology constraints as extra_ub rows of ``struct``.

        Indices in the input dicts are full-topology; they are mapped into
        the pruned structure's space (entries whose regions were pruned away
        are irrelevant and dropped). Returns [] when nothing applies."""
        inv = {full: i for i, full in enumerate(keep)}
        e, v = struct.n_edges, struct.num_regions
        edge_ix = {edge: k for k, edge in enumerate(struct.edges)}
        cuts: list[tuple[np.ndarray, float]] = []
        for (a, b), phi in (degraded_links or {}).items():
            sa, sb = inv.get(a), inv.get(b)
            if sa is None or sb is None or (sa, sb) not in edge_ix:
                continue
            k = edge_ix[(sa, sb)]
            row = np.zeros(struct.nx)
            row[k] = 1.0  # F_e <= phi * tput_e / limit_conn * M_e
            row[e + v + k] = (
                -float(phi) * struct.top.tput[sa, sb] / struct.top.limit_conn
            )
            cuts.append((row, 0.0))
        for r, cap in (vm_caps or {}).items():
            sr = inv.get(r)
            if sr is None or float(cap) >= struct.top.limit_vm:
                continue
            row = np.zeros(struct.nx)
            row[e + sr] = 1.0  # N_r <= cap (unhealthy region)
            cuts.append((row, float(cap)))
        return cuts

    @staticmethod
    def _mc_degrade_cuts(
        struct,
        keep: list[int],
        degraded_links: dict[tuple[int, int], float] | None,
        vm_caps: dict[int, float] | None,
    ) -> list[tuple[np.ndarray, float]]:
        """Degraded-topology rows in the multicast variable space: the
        tightened 4b row binds the *envelope* (what actually crosses the
        link), and VM caps bind N — all as extra_ub on the cached
        structure, nothing re-assembled."""
        inv = {full: i for i, full in enumerate(keep)}
        edge_ix = {edge: k for k, edge in enumerate(struct.edges)}
        cuts: list[tuple[np.ndarray, float]] = []
        for (a, b), phi in (degraded_links or {}).items():
            sa, sb = inv.get(a), inv.get(b)
            if sa is None or sb is None or (sa, sb) not in edge_ix:
                continue
            k = edge_ix[(sa, sb)]
            row = np.zeros(struct.nx)
            row[k] = 1.0  # G_e <= phi * tput_e / limit_conn * M_e
            row[struct.iM + k] = (
                -float(phi) * struct.top.tput[sa, sb] / struct.top.limit_conn
            )
            cuts.append((row, 0.0))
        for r, cap in (vm_caps or {}).items():
            sr = inv.get(r)
            if sr is None or float(cap) >= struct.top.limit_vm:
                continue
            row = np.zeros(struct.nx)
            row[struct.iN + sr] = 1.0
            cuts.append((row, float(cap)))
        return cuts

    def _prune_mc(self, src: str, dsts: list[str]):
        """Pruned candidate subgraph for one-to-many planning: source, all
        destinations, and the ``max_relays`` regions with the best two-hop
        bottleneck score toward ANY destination. Memoized per (src, dsts)
        so the multicast LP structure cached on it survives re-planning."""
        key = (src, tuple(dsts))
        hit = self._prune_cache.get(key)
        if hit is not None:
            return hit
        s_full = self.top.index(src)
        d_full = [self.top.index(d) for d in dsts]
        v = self.top.num_regions
        if v <= self.max_relays + 1 + len(dsts):
            keep = list(range(v))
        else:
            score = np.full(v, -np.inf)
            for d in d_full:
                score = np.maximum(
                    score, np.minimum(self.top.tput[s_full, :],
                                      self.top.tput[:, d])
                )
            score[[s_full, *d_full]] = -np.inf
            order = np.argsort(-score)
            relays = [int(i) for i in order[: self.max_relays]
                      if np.isfinite(score[i])]
            keep = sorted({s_full, *d_full, *relays})
        sub = self.top.subgraph(keep)
        s = keep.index(s_full)
        ds = tuple(keep.index(d) for d in d_full)
        out = (sub, s, ds, keep)
        self._prune_cache[key] = out
        return out

    def _lift_mc(
        self, sub, keep, src, dsts, goals, volume_gb, res
    ) -> MulticastPlan:
        v = self.top.num_regions
        D = len(dsts)
        ix = np.asarray(keep)
        G = np.zeros((v, v))
        F = np.zeros((D, v, v))
        M = np.zeros((v, v))
        N = np.zeros(v)
        G[np.ix_(ix, ix)] = res.G
        F[np.ix_(np.arange(D), ix, ix)] = res.F
        M[np.ix_(ix, ix)] = res.M
        N[ix] = res.N
        achieved = getattr(res, "achieved_goals", None)
        tgt = (np.minimum(goals, achieved) if achieved is not None
               else np.asarray(goals, dtype=float))
        return MulticastPlan(
            top=self.top,
            src=self.top.index(src),
            dsts=[self.top.index(d) for d in dsts],
            tput_goals=tgt,
            volume_gb=volume_gb,
            G=G,
            F=F,
            N=N,
            M=M,
            solver_status=res.status,
        )

    def _prune(self, src: str, dst: str):
        """Pruned candidate subgraph for (src, dst), memoized so the LP
        structures cached on the subgraph survive across planner calls."""
        key = (src, dst)
        hit = self._prune_cache.get(key)
        if hit is not None:
            return hit
        s_full, t_full = self.top.index(src), self.top.index(dst)
        v = self.top.num_regions
        if v <= self.max_relays + 2:
            keep = list(range(v))
            out = (self.top, s_full, t_full, keep)
        else:
            sub, s, t = self.top.candidate_subgraph(src, dst, self.max_relays)
            # recover kept indices in full-topology space
            keep = [self.top.index(r.key) for r in sub.regions]
            out = (sub, s, t, keep)
        self._prune_cache[key] = out
        return out

    def _lift(
        self, sub, keep, src, dst, tput_goal, volume_gb, res
    ) -> TransferPlan:
        v = self.top.num_regions
        F = np.zeros((v, v))
        M = np.zeros((v, v))
        N = np.zeros(v)
        ix = np.asarray(keep)
        F[np.ix_(ix, ix)] = res.F
        M[np.ix_(ix, ix)] = res.M
        N[ix] = res.N
        achieved = getattr(res, "achieved_tput", 0.0) or tput_goal
        return TransferPlan(
            top=self.top,
            src=self.top.index(src),
            dst=self.top.index(dst),
            tput_goal=min(tput_goal, achieved),
            volume_gb=volume_gb,
            F=F,
            N=N,
            M=M,
            solver_status=res.status,
        )
