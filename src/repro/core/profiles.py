"""Embedded throughput + price grids for AWS / Azure / GCP (paper §3.2).

The paper measures its throughput grid with iperf3 at 64 parallel connections
(~$4000 of egress). That measurement cannot be redone here, so we *embed* a
deterministic grid generated from the paper's own published facts:

  * per-VM egress throttles: AWS caps **all** egress at 5 Gbps for <=32-core
    instances; GCP caps public-IP egress at 7 Gbps; Azure has no cap beyond
    the NIC (16 Gbps for Standard_D32_v5).                      [paper §2, Fig 3]
  * inter-cloud links are consistently slower than intra-cloud links, and some
    inter-cloud pairs have much worse peering than others.       [paper Fig 3]
  * throughput decays with geographic distance (RTT), and intra-cloud GCP
    routes are noisier than AWS routes.                          [paper Figs 3-4]
  * egress is billed per GB per hop; intra-cloud intra-continental is cheap
    (~$0.02/GB), internet egress expensive (~$0.09-0.19/GB), ingress free.
                                                                 [paper §2, §4.1.1]

Region lists match the paper's evaluation scale (20 AWS / 24 Azure / 27 GCP).
Prices approximate 2022 public on-demand pricing for the instance types the
paper uses (m5.8xlarge / Standard_D32_v5 / n2-standard-32).

Everything is deterministic (fixed seed) so tests and benchmarks are stable.
"""

from __future__ import annotations

import functools
import hashlib

import numpy as np

from .topology import Region, Topology, haversine_km

# --------------------------------------------------------------------- regions
# (provider, name, continent, lat, lon)
_AWS = [
    ("us-east-1", "na", 38.9, -77.4), ("us-east-2", "na", 40.0, -83.0),
    ("us-west-1", "na", 37.4, -122.0), ("us-west-2", "na", 45.8, -119.7),
    ("ca-central-1", "na", 45.5, -73.6), ("sa-east-1", "sa", -23.5, -46.6),
    ("eu-west-1", "eu", 53.3, -6.3), ("eu-west-2", "eu", 51.5, -0.1),
    ("eu-west-3", "eu", 48.9, 2.3), ("eu-central-1", "eu", 50.1, 8.7),
    ("eu-north-1", "eu", 59.3, 18.1), ("eu-south-1", "eu", 45.5, 9.2),
    ("ap-northeast-1", "ap", 35.7, 139.7), ("ap-northeast-2", "ap", 37.6, 127.0),
    ("ap-northeast-3", "ap", 34.7, 135.5), ("ap-southeast-1", "ap", 1.3, 103.8),
    ("ap-southeast-2", "oc", -33.9, 151.2), ("ap-south-1", "ap", 19.1, 72.9),
    ("af-south-1", "af", -33.9, 18.4), ("me-south-1", "me", 26.2, 50.6),
]
_AZURE = [
    ("eastus", "na", 37.4, -79.8), ("eastus2", "na", 36.6, -78.4),
    ("centralus", "na", 41.6, -93.6), ("northcentralus", "na", 41.9, -87.6),
    ("southcentralus", "na", 29.4, -98.5), ("westus", "na", 37.4, -122.0),
    ("westus2", "na", 47.2, -119.9), ("westus3", "na", 33.4, -112.0),
    ("canadacentral", "na", 43.7, -79.4), ("canadaeast", "na", 46.8, -71.2),
    ("brazilsouth", "sa", -23.5, -46.6), ("northeurope", "eu", 53.3, -6.3),
    ("westeurope", "eu", 52.4, 4.9), ("uksouth", "eu", 51.5, -0.1),
    ("ukwest", "eu", 51.5, -3.2), ("francecentral", "eu", 48.9, 2.3),
    ("germanywestcentral", "eu", 50.1, 8.7), ("norwayeast", "eu", 59.9, 10.7),
    ("switzerlandnorth", "eu", 47.4, 8.5), ("japaneast", "ap", 35.7, 139.7),
    ("japanwest", "ap", 34.7, 135.5), ("koreacentral", "ap", 37.6, 127.0),
    ("southeastasia", "ap", 1.3, 103.8), ("australiaeast", "oc", -33.9, 151.2),
]
_GCP = [
    ("us-central1", "na", 41.3, -95.9), ("us-east1", "na", 33.2, -80.0),
    ("us-east4", "na", 38.9, -77.4), ("us-west1", "na", 45.6, -121.2),
    ("us-west2", "na", 34.1, -118.2), ("us-west3", "na", 40.8, -111.9),
    ("us-west4", "na", 36.1, -115.2),
    ("northamerica-northeast1", "na", 45.5, -73.6),
    ("northamerica-northeast2", "na", 43.7, -79.4),
    ("southamerica-east1", "sa", -23.5, -46.6),
    ("europe-west1", "eu", 50.4, 3.8), ("europe-west2", "eu", 51.5, -0.1),
    ("europe-west3", "eu", 50.1, 8.7), ("europe-west4", "eu", 53.4, 6.8),
    ("europe-west6", "eu", 47.4, 8.5), ("europe-north1", "eu", 60.6, 27.1),
    ("europe-central2", "eu", 52.2, 21.0), ("asia-east1", "ap", 24.0, 121.0),
    ("asia-east2", "ap", 22.3, 114.2), ("asia-northeast1", "ap", 35.7, 139.7),
    ("asia-northeast2", "ap", 34.7, 135.5), ("asia-northeast3", "ap", 37.6, 127.0),
    ("asia-south1", "ap", 19.1, 72.9), ("asia-south2", "ap", 28.6, 77.2),
    ("asia-southeast1", "ap", 1.3, 103.8), ("asia-southeast2", "ap", -6.2, 106.8),
    ("australia-southeast1", "oc", -33.9, 151.2),
]

# ------------------------------------------------------------------- constants
# Per-VM NIC bandwidth (Gbps) for the paper's instance types (§6).
_NIC = {"aws": 10.0, "azure": 16.0, "gcp": 16.0}
# Per-VM egress throttles (paper §2): AWS 5 Gbps all egress; GCP 7 Gbps to
# public IPs; Azure NIC-limited only.
_EGRESS_CAP = {"aws": 5.0, "azure": 16.0, "gcp": 7.0}
# On-demand $/hr: m5.8xlarge / Standard_D32_v5 / n2-standard-32 (2022 pricing).
_VM_HOURLY = {"aws": 1.536, "azure": 1.520, "gcp": 1.553}

# Internet (inter-cloud) egress $/GB by source provider x source continent.
_INTERNET_EGRESS = {
    "aws": {"na": 0.09, "eu": 0.09, "ap": 0.114, "oc": 0.114, "sa": 0.150,
            "af": 0.154, "me": 0.117},
    "azure": {"na": 0.0875, "eu": 0.0875, "ap": 0.12, "oc": 0.12, "sa": 0.181,
              "af": 0.181, "me": 0.12},
    "gcp": {"na": 0.12, "eu": 0.12, "ap": 0.12, "oc": 0.19, "sa": 0.12,
            "af": 0.12, "me": 0.12},
}
# Intra-cloud inter-region $/GB: (same-continent, cross-continent).
_INTRA_CLOUD_EGRESS = {
    "aws": (0.02, 0.02),   # AWS charges a flat inter-region rate
    "azure": (0.02, 0.05),
    "gcp": (0.02, 0.08),
}

_SEED = 20220415  # deterministic grid

# ------------------------------------------------------- belief drift priors
# Per-(source provider, dest provider) relative drift sigma for the
# calibration plane's BeliefGrid prior: how far the stale embedded grid is
# presumed to sit from current reality, before any probe lands. Cross-cloud
# measurement studies (and the paper's own Fig. 4) show this is NOT one
# number: intra-AWS routes hold steady, intra-GCP routes jitter, and
# inter-cloud peering drifts hardest of all. The table replaces the single
# global ``prior_rel_sigma`` knob; pairs not listed (e.g. the toy test
# provider) fall back to ``DEFAULT_DRIFT_PRIOR`` — the old global value.
PROVIDER_DRIFT_PRIOR: dict[tuple[str, str], float] = {
    ("aws", "aws"): 0.18,
    ("azure", "azure"): 0.20,
    ("gcp", "gcp"): 0.30,  # Fig. 4: GCP route jitter
    ("aws", "azure"): 0.32,
    ("azure", "aws"): 0.32,
    ("aws", "gcp"): 0.35,
    ("gcp", "aws"): 0.35,
    ("azure", "gcp"): 0.35,
    ("gcp", "azure"): 0.35,
}
DEFAULT_DRIFT_PRIOR = 0.25


def prior_rel_sigma_grid(top: Topology) -> np.ndarray:
    """[V, V] per-link prior relative drift sigma from the provider-pair
    table — the BeliefGrid's default prior spread (ordered pairs: egress
    provider rows, ingress provider columns)."""
    providers = [r.provider for r in top.regions]
    v = len(providers)
    out = np.full((v, v), DEFAULT_DRIFT_PRIOR)
    for i, p in enumerate(providers):
        for j, q in enumerate(providers):
            out[i, j] = PROVIDER_DRIFT_PRIOR.get((p, q), DEFAULT_DRIFT_PRIOR)
    return out


def region_list() -> list[Region]:
    out = []
    for provider, entries in (("aws", _AWS), ("azure", _AZURE), ("gcp", _GCP)):
        for name, cont, lat, lon in entries:
            out.append(Region(provider, name, cont, lat, lon))
    return out


def _rtt_ms(a: Region, b: Region) -> float:
    """RTT model: ~1ms/100km of fiber (x1.6 route inflation) + 2ms base."""
    d = haversine_km(a.lat, a.lon, b.lat, b.lon)
    return 2.0 + 0.016 * d


def _egress_price(a: Region, b: Region) -> float:
    if a.provider == b.provider:
        same, cross = _INTRA_CLOUD_EGRESS[a.provider]
        return same if a.continent == b.continent else cross
    return _INTERNET_EGRESS[a.provider][a.continent]


@functools.lru_cache(maxsize=1)
def default_topology() -> Topology:
    """The 71-region AWS+Azure+GCP topology with the embedded grids."""
    regions = region_list()
    v = len(regions)
    rng = np.random.default_rng(_SEED)

    rtt = np.zeros((v, v))
    tput = np.zeros((v, v))
    price = np.zeros((v, v))
    for i, a in enumerate(regions):
        for j, b in enumerate(regions):
            if i == j:
                continue
            rtt[i, j] = _rtt_ms(a, b)
            price[i, j] = _egress_price(a, b)

    # Throughput: start from the source VM's egress ceiling, decay with RTT,
    # apply inter-cloud peering penalties (paper Fig 3), add stable noise.
    # Peering quality is symmetric per unordered pair; intra-GCP routes get
    # extra jitter (paper Fig 4).
    peering = np.ones((v, v))
    for i in range(v):
        for j in range(i + 1, v):
            a, b = regions[i], regions[j]
            if a.provider != b.provider:
                q = rng.uniform(0.35, 0.95)  # some inter-cloud pairs peer badly
            else:
                q = rng.uniform(0.80, 1.00)
            peering[i, j] = peering[j, i] = q

    for i, a in enumerate(regions):
        for j, b in enumerate(regions):
            if i == j:
                continue
            inter_cloud = a.provider != b.provider
            ceiling = min(
                _EGRESS_CAP[a.provider] if inter_cloud else _NIC[a.provider],
                _NIC[b.provider],
            )
            # RTT decay: nearby pairs run at the ceiling; antipodal pairs at
            # roughly a third of it (BDP-limited even with 64 connections).
            geo = 1.0 / (1.0 + (rtt[i, j] / 140.0) ** 1.4)
            noise = float(rng.lognormal(0.0, 0.06))
            if a.provider == "gcp" and b.provider == "gcp":
                noise *= float(rng.lognormal(0.0, 0.08))  # Fig 4: GCP jitter
            val = ceiling * geo * peering[i, j] * noise
            # Inter-cloud flows still hit the hard egress throttle.
            cap = _EGRESS_CAP[a.provider] if inter_cloud else _NIC[a.provider]
            tput[i, j] = float(np.clip(val, 0.05, cap))

    price_vm = np.array([_VM_HOURLY[r.provider] / 3600.0 for r in regions])
    limit_ingress = np.array([_NIC[r.provider] for r in regions])
    limit_egress = np.array(
        [min(_NIC[r.provider], _EGRESS_CAP[r.provider]) for r in regions]
    )
    # NOTE: limit_egress is the *inter-cloud* throttle; intra-cloud flows may
    # exceed it (e.g. Azure 16 Gbps NIC). The MILP uses the conservative
    # per-VM cap; the tput grid itself encodes the per-link reality.
    return Topology(
        regions=regions,
        tput=tput,
        price_egress=price,
        price_vm=price_vm,
        limit_ingress=limit_ingress,
        limit_egress=limit_egress,
        rtt_ms=rtt,
        limit_conn=64,
        limit_vm=8,
    )


def grid_fingerprint(top: Topology) -> str:
    """SHA-256 over the topology's embedded grids, bit-for-bit.

    The whole stack treats the profile grids as a deterministic fixture:
    the same seed must produce bitwise-identical grids in every process
    (tests compare this fingerprint across subprocesses), and the
    calibration plane's drift model keys its true-topology snapshots off
    the same determinism."""
    h = hashlib.sha256()
    for arr in (top.tput, top.price_egress, top.price_vm,
                top.limit_ingress, top.limit_egress):
        h.update(np.ascontiguousarray(arr, dtype=np.float64).tobytes())
    if top.rtt_ms is not None:
        h.update(np.ascontiguousarray(top.rtt_ms, dtype=np.float64).tobytes())
    h.update(",".join(r.key for r in top.regions).encode())
    return h.hexdigest()


def toy_topology(
    n: int = 5, seed: int = 0, limit_vm: int = 4, limit_conn: int = 8
) -> Topology:
    """Small random topology for unit/property tests."""
    rng = np.random.default_rng(seed)
    regions = [
        Region("toy", f"r{i}", "na", float(rng.uniform(-60, 60)),
               float(rng.uniform(-180, 180)))
        for i in range(n)
    ]
    tput = rng.uniform(0.5, 10.0, size=(n, n))
    np.fill_diagonal(tput, 0.0)
    price = rng.uniform(0.01, 0.15, size=(n, n))
    np.fill_diagonal(price, 0.0)
    rtt = rng.uniform(5.0, 250.0, size=(n, n))
    np.fill_diagonal(rtt, 0.0)
    return Topology(
        regions=regions,
        tput=tput,
        price_egress=price,
        price_vm=rng.uniform(2e-4, 6e-4, size=n),
        limit_ingress=rng.uniform(8.0, 16.0, size=n),
        limit_egress=rng.uniform(4.0, 10.0, size=n),
        rtt_ms=rtt,
        limit_conn=limit_conn,
        limit_vm=limit_vm,
    )
