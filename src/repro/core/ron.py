"""RON baseline (Andersen et al., SOSP'01) — paper §2, §7.6, Table 2.

RON probes the network and routes via a single intermediate relay chosen for
low latency/loss (optionally a TCP throughput model); it is *price-blind* and
*elasticity-blind*. Following the paper's §7.6 methodology we implement RON's
path-selection heuristic inside our data plane: pick the single relay that
maximizes the bottleneck throughput of src->relay->dst (falling back to the
latency metric when no throughput model is available), allocate the full VM
budget along that path, and use the maximum connection count everywhere.
"""

from __future__ import annotations

import numpy as np

from .plan import TransferPlan
from .topology import Topology


def ron_plan(
    top: Topology,
    src: str,
    dst: str,
    volume_gb: float,
    *,
    num_vms: int = 4,
    metric: str = "throughput",  # "throughput" (TCP-model RON) | "latency"
) -> TransferPlan:
    s, t = top.index(src), top.index(dst)
    v = top.num_regions
    n_vm = min(num_vms, top.limit_vm)

    def path_tput(path: list[int]) -> float:
        """Achievable Gbit/s along a relay chain with n_vm VMs per region."""
        caps = []
        for a, b in zip(path[:-1], path[1:]):
            caps.append(top.tput[a, b] * n_vm)  # link, scaled by VM pairs
            caps.append(top.limit_egress[a] * n_vm)
            caps.append(top.limit_ingress[b] * n_vm)
        return min(caps)

    best_path = [s, t]
    if metric == "throughput":
        best_score = path_tput(best_path)
        for r in range(v):
            if r in (s, t):
                continue
            cand = [s, r, t]
            score = path_tput(cand)
            if score > best_score + 1e-9:
                best_score = score
                best_path = cand
    else:  # latency-minimizing RON
        assert top.rtt_ms is not None
        best_score = top.rtt_ms[s, t]
        for r in range(v):
            if r in (s, t):
                continue
            lat = top.rtt_ms[s, r] + top.rtt_ms[r, t]
            if lat < best_score - 1e-9:
                best_score = lat
                best_path = [s, r, t]

    tput = path_tput(best_path)
    F = np.zeros((v, v))
    M = np.zeros((v, v))
    N = np.zeros(v)
    for a, b in zip(best_path[:-1], best_path[1:]):
        F[a, b] = tput
        M[a, b] = top.limit_conn * n_vm
    for r in best_path:
        N[r] = n_vm
    return TransferPlan(
        top=top, src=s, dst=t, tput_goal=tput, volume_gb=volume_gb,
        F=F, N=N, M=M, solver_status="ron",
    )
