from .ipm import IPMResult, solve_lp  # noqa: F401
from .bnb import MILPResult, solve_milp  # noqa: F401
