"""MILP solving for Skyplane plans: exact branch & bound and the paper's
continuous relaxation + round-down (§5.1.3).

The paper's observation: relaxing N (VMs) and M (TCP connections) to reals and
rounding *down* performs within ~1% of the exact MILP. Procedure implemented
here (``mode="relaxed"``):

  1. solve the LP relaxation;
  2. floor N; if the throughput goal became unreachable, bump the regions with
     the largest fractional parts back up (feasibility repair);
  3. with N fixed, re-solve for (F, M); floor M, then greedily hand leftover
     per-region connection budget back to the highest-capacity active edges
     (restores most of the capacity the floor gave up);
  4. with N and M fixed, re-fit F: max-flow probe, then a min-cost solve at
     ``min(goal, maxflow)``. The achieved throughput (>= ~99% of the goal,
     matching the paper's <=1% optimality gap) is reported alongside the plan.

``mode="exact"`` wraps the same integerization in a best-first branch & bound
on N (the only integer variables with objective weight; M is integerized per
node as above).
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math

import numpy as np

from .. import milp
from .ipm import solve_lp

_INT_TOL = 1e-6


@dataclasses.dataclass
class MILPResult:
    F: np.ndarray  # [V,V] Gbit/s
    N: np.ndarray  # [V] ints
    M: np.ndarray  # [V,V] ints
    objective: float  # $/s while the transfer runs (unscaled Eq. 4a)
    status: str
    lp_objective: float  # relaxation bound
    achieved_tput: float = 0.0  # Gbit/s the integral plan actually provides
    nodes_explored: int = 1

    @property
    def ok(self) -> bool:
        return self.status == "optimal"


def _empty(top, status: str, lp_obj: float = math.inf, nodes: int = 1) -> MILPResult:
    v = top.num_regions
    z = np.zeros((v, v))
    return MILPResult(
        F=z, N=np.zeros(v), M=z.copy(), objective=math.inf, status=status,
        lp_objective=lp_obj, nodes_explored=nodes,
    )


def _outflow_objective(lp: milp.LPData) -> np.ndarray:
    """c such that min c@x == max source outflow."""
    c = np.zeros_like(lp.c)
    for k, (u, w) in enumerate(lp.edges):
        if u == lp.src:
            c[k] = -1.0
    return c


def _topup_connections(top, M_frac: np.ndarray, M_int: np.ndarray, n_int: np.ndarray):
    """Greedily spend leftover per-region connection budget on the edges the
    floor hurt most (largest per-connection capacity first). In place."""
    out_budget = top.limit_conn * n_int - M_int.sum(axis=1)
    in_budget = top.limit_conn * n_int - M_int.sum(axis=0)
    frac = M_frac - np.floor(M_frac + _INT_TOL)
    cand = [
        (u, w)
        for u, w in zip(*np.where(frac > 1e-4))
    ]
    # highest capacity-per-connection edges first
    cand.sort(key=lambda e: -top.tput[e[0], e[1]])
    for u, w in cand:
        if out_budget[u] >= 1 and in_budget[w] >= 1:
            M_int[u, w] += 1
            out_budget[u] -= 1
            in_budget[w] -= 1


def _max_flow(top, src, dst, *, fixed_n=None, fixed_m=None, extra_ub=None) -> float:
    """Max source outflow with the given allocations pinned. This LP is always
    feasible (F=0 works), so the IPM never grinds on an infeasible instance —
    the round-down pipeline is built exclusively from max-flow probes followed
    by min-cost solves at a known-achievable goal."""
    lp = milp.build_lp(
        top, src, dst, 0.0, fixed_n=fixed_n, fixed_m=fixed_m, extra_ub=extra_ub
    )
    if lp.trivially_infeasible:
        return 0.0
    res = solve_lp(_outflow_objective(lp), lp.A_ub, lp.b_ub, lp.A_eq, lp.b_eq)
    if not res.ok:
        return 0.0
    return max(float(-(_outflow_objective(lp) @ res.x)), 0.0)


def _integerize(
    top, src: int, dst: int, tput_goal: float, n_int: np.ndarray, extra_ub=None
):
    """Steps 3-4 above. Returns (F, M_int, achieved, obj) or None."""
    goal_n = min(tput_goal, _max_flow(top, src, dst, fixed_n=n_int, extra_ub=extra_ub)
                 * (1.0 - 1e-9))
    if goal_n <= 0:
        return None
    lp = milp.build_lp(top, src, dst, goal_n, fixed_n=n_int, extra_ub=extra_ub)
    res = solve_lp(lp.c, lp.A_ub, lp.b_ub, lp.A_eq, lp.b_eq)
    if not res.ok:
        return None
    _, _, M_frac = lp.split(res.x)
    M_int = np.floor(M_frac + _INT_TOL)
    _topup_connections(top, M_frac, M_int, n_int)

    # re-fit F with both integer allocations pinned at what they can carry
    maxflow = _max_flow(top, src, dst, fixed_n=n_int, fixed_m=M_int, extra_ub=extra_ub)
    achieved = min(goal_n, maxflow * (1.0 - 1e-9))
    if achieved <= 0:
        return None
    lp2 = milp.build_lp(
        top, src, dst, achieved, fixed_n=n_int, fixed_m=M_int, extra_ub=extra_ub
    )
    res2 = solve_lp(lp2.c, lp2.A_ub, lp2.b_ub, lp2.A_eq, lp2.b_eq)
    if not res2.ok:
        return None
    F, _, _ = lp2.split(res2.x)
    obj = float((F * top.price_egress).sum() / 8.0 + n_int @ top.price_vm)
    return F, M_int, achieved, obj


def _feasible_with_n(top, src, dst, tput_goal, n_int, extra_ub=None) -> bool:
    return _max_flow(top, src, dst, fixed_n=n_int, extra_ub=extra_ub) >= tput_goal * (
        1.0 - 1e-6
    )


def _feasibility_repair(
    top, src, dst, tput_goal, n_frac: np.ndarray, extra_ub=None
) -> np.ndarray | None:
    """Floor N, then bump regions (largest fractional part first) until the
    goal throughput is reachable again."""
    n_floor = np.floor(n_frac + _INT_TOL)
    candidates = np.argsort(-(n_frac - n_floor))
    n_try = n_floor.copy()
    if _feasible_with_n(top, src, dst, tput_goal, n_try, extra_ub):
        return n_try
    for r in candidates:
        n_try = n_try.copy()
        n_try[r] = min(n_try[r] + 1, top.limit_vm)
        if _feasible_with_n(top, src, dst, tput_goal, n_try, extra_ub):
            return n_try
    n_ceil = np.minimum(np.ceil(n_frac - _INT_TOL), top.limit_vm)
    if _feasible_with_n(top, src, dst, tput_goal, n_ceil, extra_ub):
        return n_ceil
    return None


def solve_milp(
    top,
    src: int,
    dst: int,
    tput_goal: float,
    *,
    mode: str = "relaxed",
    max_nodes: int = 60,
) -> MILPResult:
    lp = milp.build_lp(top, src, dst, tput_goal)
    root = solve_lp(lp.c, lp.A_ub, lp.b_ub, lp.A_eq, lp.b_eq)
    if not root.ok:
        return _empty(top, root.status)
    _, n_frac, _ = lp.split(root.x)

    def round_down(n_source: np.ndarray, extra_ub=None) -> MILPResult | None:
        n_int = _feasibility_repair(top, src, dst, tput_goal, n_source, extra_ub)
        if n_int is None:
            return None
        fit = _integerize(top, src, dst, tput_goal, n_int, extra_ub)
        if fit is None:
            return None
        F, M, achieved, obj = fit
        return MILPResult(
            F=F, N=n_int.astype(np.int64), M=M.astype(np.int64),
            objective=obj, status="optimal", lp_objective=root.fun,
            achieved_tput=achieved,
        )

    if mode == "relaxed":
        out = round_down(n_frac)
        return out if out is not None else _empty(top, "infeasible", root.fun)

    if mode != "exact":
        raise ValueError(f"unknown mode {mode!r}")

    # ---------------- best-first branch & bound over N ----------------
    v = top.num_regions
    e = lp.n_edges

    def n_col(r: int) -> np.ndarray:
        row = np.zeros(2 * e + v)
        row[e + r] = 1.0
        return row

    best: MILPResult | None = round_down(n_frac)  # incumbent
    best_obj = best.objective if best is not None else math.inf

    counter = itertools.count()
    heap: list[tuple[float, int, list]] = [(root.fun, next(counter), [])]
    nodes = 0
    while heap and nodes < max_nodes:
        bound, _, cuts = heapq.heappop(heap)
        if bound >= best_obj - 1e-9:
            continue
        nodes += 1
        extra = []
        for r, sense, val in cuts:
            col = n_col(r)
            if sense == "<=":
                extra.append((col, float(val)))
            else:  # N_r >= val
                extra.append((-col, -float(val)))
        node_lp = milp.build_lp(top, src, dst, tput_goal, extra_ub=extra)
        res = solve_lp(node_lp.c, node_lp.A_ub, node_lp.b_ub, node_lp.A_eq, node_lp.b_eq)
        if not res.ok or res.fun >= best_obj - 1e-9:
            continue
        _, n_node, _ = node_lp.split(res.x)
        frac = n_node - np.floor(n_node + _INT_TOL)
        frac_ix = np.where(frac > 1e-4)[0]
        if frac_ix.size == 0:
            n_int = np.round(n_node).astype(float)
            fit = _integerize(top, src, dst, tput_goal, n_int, extra)
            if fit is not None and fit[3] < best_obj:
                F, M, achieved, obj = fit
                best_obj = obj
                best = MILPResult(
                    F=F, N=n_int.astype(np.int64), M=M.astype(np.int64),
                    objective=obj, status="optimal", lp_objective=root.fun,
                    achieved_tput=achieved, nodes_explored=nodes,
                )
            continue
        r = int(frac_ix[np.argmax(frac[frac_ix])])
        lo = math.floor(n_node[r] + _INT_TOL)
        heapq.heappush(heap, (res.fun, next(counter), cuts + [(r, "<=", lo)]))
        heapq.heappush(heap, (res.fun, next(counter), cuts + [(r, ">=", lo + 1)]))

    if best is None:
        return _empty(top, "infeasible", root.fun, nodes)
    best.nodes_explored = nodes
    return best
