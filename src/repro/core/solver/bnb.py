"""MILP solving for Skyplane plans: exact branch & bound and the paper's
continuous relaxation + round-down (§5.1.3).

The paper's observation: relaxing N (VMs) and M (TCP connections) to reals and
rounding *down* performs within ~1% of the exact MILP. Procedure implemented
here (``mode="relaxed"``):

  1. solve the LP relaxation;
  2. floor N; if the throughput goal became unreachable, bump the regions with
     the largest fractional parts back up (feasibility repair);
  3. with N fixed, re-solve for (F, M); floor M, then greedily hand leftover
     per-region connection budget back to the highest-capacity active edges
     (restores most of the capacity the floor gave up);
  4. with N and M fixed, re-fit F: max-flow probe, then a min-cost solve at
     ``min(goal, maxflow)``. The achieved throughput (>= ~99% of the goal,
     matching the paper's <=1% optimality gap) is reported alongside the plan.

``mode="exact"`` wraps the same integerization in a best-first branch & bound
on N (the only integer variables with objective weight; M is integerized per
node as above).

Every step derives its LP from the cached ``milp.LPStructure`` — one
vectorized assembly per (topology, src, dst), O(rows) per variant — and
``solve_milp_batched`` runs the whole round-down pipeline for a *batch* of
throughput goals through the batched JAX IPM (stage-by-stage: root
relaxations, feasibility-repair candidate probes, fixed-N refits, fixed-N+M
refits — each one vmapped call over RHS variants, with per-sample numpy
fallback on KKT failure). ``planner.pareto_frontier(backend="jax")`` and
``plan_cost_min(..., backend="jax")`` are built on it.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math

import numpy as np

from .. import milp
from ..topology import GBIT_PER_GB
from .ipm import solve_lp

_INT_TOL = 1e-6


@dataclasses.dataclass
class MILPResult:
    F: np.ndarray  # [V,V] Gbit/s
    N: np.ndarray  # [V] ints
    M: np.ndarray  # [V,V] ints
    objective: float  # $/s while the transfer runs (unscaled Eq. 4a)
    status: str
    lp_objective: float  # relaxation bound
    achieved_tput: float = 0.0  # Gbit/s the integral plan actually provides
    nodes_explored: int = 1

    @property
    def ok(self) -> bool:
        return self.status == "optimal"


def _empty(top, status: str, lp_obj: float = math.inf, nodes: int = 1) -> MILPResult:
    v = top.num_regions
    z = np.zeros((v, v))
    return MILPResult(
        F=z, N=np.zeros(v), M=z.copy(), objective=math.inf, status=status,
        lp_objective=lp_obj, nodes_explored=nodes,
    )


def _topup_connections(top, M_frac: np.ndarray, M_int: np.ndarray, n_int: np.ndarray):
    """Greedily spend leftover per-region connection budget on the edges the
    floor hurt most (largest per-connection capacity first). In place."""
    out_budget = top.limit_conn * n_int - M_int.sum(axis=1)
    in_budget = top.limit_conn * n_int - M_int.sum(axis=0)
    frac = M_frac - np.floor(M_frac + _INT_TOL)
    cand = [
        (u, w)
        for u, w in zip(*np.where(frac > 1e-4))
    ]
    # highest capacity-per-connection edges first
    cand.sort(key=lambda e: -top.tput[e[0], e[1]])
    for u, w in cand:
        if out_budget[u] >= 1 and in_budget[w] >= 1:
            M_int[u, w] += 1
            out_budget[u] -= 1
            in_budget[w] -= 1


def _cuts_resolved_by_n(struct: milp.LPStructure, extra_ub, n_int):
    """B&B cuts only touch N columns; once N is pinned they are constants.

    Returns True (all satisfied: rows droppable), False (violated:
    infeasible), or None (a cut touches free variables: keep the rows)."""
    e, v = struct.n_edges, struct.num_regions
    n_int = np.asarray(n_int, dtype=float)
    for row, b in extra_ub:
        row = np.asarray(row, dtype=float)
        outside = np.abs(np.delete(row, np.s_[e : e + v])).max(initial=0.0)
        if outside > 1e-12:
            return None
        if row[e : e + v] @ n_int > b + 1e-9:
            return False
    return True


def _resolve_cuts(struct, fixed_n, extra_ub):
    """(extra_ub', infeasible) after evaluating N-only cuts against fixed_n."""
    if fixed_n is None or not extra_ub:
        return extra_ub, False
    res = _cuts_resolved_by_n(struct, extra_ub, fixed_n)
    if res is None:
        return extra_ub, False
    return None, not res


def _reduction(struct: milp.LPStructure, fixed_n, fixed_m=None):
    """Route a pinned solve to its exact presolve (milp.LPStructure.reduced).

    Returns "identity" when nothing shrinks, None when the reduction proves
    the instance carries no flow, else (rstruct, keep, reduced_n, reduced_m).
    """
    support = np.asarray(fixed_n) > 0
    edge_mask = None if fixed_m is None else np.asarray(fixed_m) > 0
    if support.all() and (
        edge_mask is None or edge_mask[struct.eu, struct.ew].all()
    ):
        return "identity"
    red = struct.reduced(support, edge_mask)
    if red is None:
        return None
    rstruct, keep = red
    rn = np.asarray(fixed_n, dtype=float)[keep]
    rM = (
        None if fixed_m is None
        else np.asarray(fixed_m, dtype=float)[np.ix_(keep, keep)]
    )
    return rstruct, keep, rn, rM


def _max_flow(struct: milp.LPStructure, *, fixed_n=None, fixed_m=None,
              extra_ub=None) -> float:
    """Max source outflow with the given allocations pinned. This LP is always
    feasible (F=0 works), so the IPM never grinds on an infeasible instance —
    the round-down pipeline is built exclusively from max-flow probes followed
    by min-cost solves at a known-achievable goal."""
    extra_ub, infeasible = _resolve_cuts(struct, fixed_n, extra_ub)
    if infeasible:
        return 0.0
    if fixed_n is not None and extra_ub is None:
        red = _reduction(struct, fixed_n, fixed_m)
        if red is None:
            return 0.0
        if red != "identity":
            rstruct, _, rn, rM = red
            return _max_flow(rstruct, fixed_n=rn, fixed_m=rM)
    return _max_flow_raw(struct, fixed_n=fixed_n, fixed_m=fixed_m,
                         extra_ub=extra_ub)


def _max_flow_raw(struct: milp.LPStructure, *, fixed_n=None, fixed_m=None,
                  extra_ub=None) -> float:
    lp = struct.lp(0.0, fixed_n=fixed_n, fixed_m=fixed_m, extra_ub=extra_ub)
    if lp.trivially_infeasible:
        return 0.0
    c_out = struct.outflow_c(
        struct.pin_pattern(fixed_n is not None, fixed_m is not None)
    )
    res = solve_lp(c_out, lp.A_ub, lp.b_ub, lp.A_eq, lp.b_eq)
    out = max(float(-(c_out @ res.x)), 0.0)
    if res.ok:
        return out
    # near-converged probe on an always-feasible LP (degenerate refit
    # instances can stall the IPM just above its acceptance threshold with
    # a tiny duality gap): the outflow is still a valid bound once shaded
    # down by the remaining primal infeasibility.
    if (res.status == "max_iter" and res.primal_residual < 1e-5
            and res.gap < 1e-6):
        return out * (1.0 - 10.0 * res.primal_residual)
    return 0.0


def _min_cost_fit(struct: milp.LPStructure, goal: float, n_int: np.ndarray,
                  M_int: np.ndarray, extra_ub=None) -> np.ndarray | None:
    """Min-cost F with N and M pinned (the final §5.1.3 refit)."""
    extra_ub, infeasible = _resolve_cuts(struct, n_int, extra_ub)
    if infeasible:
        return None
    if extra_ub is None:
        red = _reduction(struct, n_int, M_int)
        if red is None:
            return None
        if red != "identity":
            rstruct, keep, rn, rM = red
            rF = _min_cost_fit(rstruct, goal, rn, rM)
            if rF is None:
                return None
            F = np.zeros((struct.num_regions,) * 2)
            F[np.ix_(keep, keep)] = rF
            return F
    lp = struct.lp(goal, fixed_n=n_int, fixed_m=M_int, extra_ub=extra_ub)
    if lp.trivially_infeasible:
        return None
    res = solve_lp(lp.c, lp.A_ub, lp.b_ub, lp.A_eq, lp.b_eq)
    if not _near_ok(res):
        return None
    F, _, _ = lp.split(res.x)
    return F


def _near_ok(res) -> bool:
    """Refits run at achieved == maxflow*(1-1e-9): essentially on the
    feasibility boundary, where degenerate instances can stall the IPM a
    hair above its acceptance threshold. A near-converged solution (tiny
    gap/dual residual, primal violation ~1e-6 relative) is still a valid
    plan within TransferPlan.validate()'s tolerance."""
    return res.ok or (
        res.status == "max_iter" and res.primal_residual < 1e-5
        and res.dual_residual < 1e-6 and res.gap < 1e-6
    )


def _integerize(struct: milp.LPStructure, tput_goal: float, n_int: np.ndarray,
                extra_ub=None):
    """Steps 3-4 above. Returns (F, M_int, achieved, obj) or None."""
    extra_ub, infeasible = _resolve_cuts(struct, n_int, extra_ub)
    if infeasible:
        return None
    if extra_ub is None:
        red = _reduction(struct, n_int)
        if red is None:
            return None
        if red != "identity":
            rstruct, keep, rn, _ = red
            fit = _integerize(rstruct, tput_goal, rn)
            if fit is None:
                return None
            rF, rM, achieved, obj = fit
            v = struct.num_regions
            F = np.zeros((v, v))
            M = np.zeros((v, v))
            F[np.ix_(keep, keep)] = rF
            M[np.ix_(keep, keep)] = rM
            return F, M, achieved, obj
    top = struct.top
    goal_n = min(tput_goal, _max_flow(struct, fixed_n=n_int, extra_ub=extra_ub)
                 * (1.0 - 1e-9))
    if goal_n <= 0:
        return None
    lp = struct.lp(goal_n, fixed_n=n_int, extra_ub=extra_ub)
    if lp.trivially_infeasible:
        return None
    res = solve_lp(lp.c, lp.A_ub, lp.b_ub, lp.A_eq, lp.b_eq)
    if not _near_ok(res):
        return None
    _, _, M_frac = lp.split(res.x)
    M_int = np.floor(M_frac + _INT_TOL)
    _topup_connections(top, M_frac, M_int, n_int)

    # re-fit F with both integer allocations pinned at what they can carry
    maxflow = _max_flow(struct, fixed_n=n_int, fixed_m=M_int, extra_ub=extra_ub)
    achieved = min(goal_n, maxflow * (1.0 - 1e-9))
    if achieved <= 0:
        return None
    F = _min_cost_fit(struct, achieved, n_int, M_int, extra_ub)
    if F is None:
        return None
    obj = float((F * top.price_egress).sum() / GBIT_PER_GB + n_int @ top.price_vm)
    return F, M_int, achieved, obj


def _repair_candidates(n_frac: np.ndarray, limit_vm: float) -> np.ndarray:
    """The round-down repair ladder: floor, then cumulative +1 bumps in
    descending-fractional-part order, then ceil. [V+2, V]."""
    n_floor = np.floor(n_frac + _INT_TOL)
    order = np.argsort(-(n_frac - n_floor))
    cands = [n_floor]
    cur = n_floor
    for r in order:
        cur = cur.copy()
        cur[r] = min(cur[r] + 1, limit_vm)
        cands.append(cur)
    cands.append(np.minimum(np.ceil(n_frac - _INT_TOL), limit_vm))
    return np.stack(cands)


def _feasible_with_n(struct, tput_goal, n_int, extra_ub=None) -> bool:
    return _max_flow(struct, fixed_n=n_int, extra_ub=extra_ub) >= tput_goal * (
        1.0 - 1e-6
    )


def _feasibility_repair(
    struct, tput_goal, n_frac: np.ndarray, extra_ub=None
) -> np.ndarray | None:
    """Floor N, then bump regions (largest fractional part first) until the
    goal throughput is reachable again."""
    for n_try in _repair_candidates(n_frac, struct.top.limit_vm):
        if _feasible_with_n(struct, tput_goal, n_try, extra_ub):
            return n_try
    return None


def solve_milp(
    top,
    src: int,
    dst: int,
    tput_goal: float,
    *,
    mode: str = "relaxed",
    max_nodes: int = 60,
    backend: str = "numpy",
    extra_ub=None,
) -> MILPResult:
    """Solve one (src, dst, tput_goal) instance.

    backend="jax" routes the relaxed round-down through the batched JAX IPM
    (one-sample batches; amortized across calls by the jit cache). The exact
    branch & bound always runs on the numpy reference solver.

    extra_ub: extra inequality rows in the full [F, N, M] variable space,
    threaded through every stage of the round-down (and merged with the
    B&B's own bound cuts in exact mode). This is how degraded-topology
    re-planning constrains the cached LPStructure — tightened 4b rows for
    degraded links, N caps for unhealthy regions — without re-assembling
    anything. Constrained solves run on the sequential numpy path (the
    batched pipeline shares matrices across samples and does not take
    per-instance rows).
    """
    if backend not in ("numpy", "jax"):
        raise ValueError(f"unknown backend {backend!r} (use 'numpy' or 'jax')")
    if backend == "jax" and mode == "relaxed" and not extra_ub:
        return solve_milp_batched(top, src, dst, np.array([tput_goal]))[0]
    base_cuts = list(extra_ub) if extra_ub else None
    struct = milp.structure(top, src, dst)
    lp = struct.lp(tput_goal, extra_ub=base_cuts)
    root = solve_lp(lp.c, lp.A_ub, lp.b_ub, lp.A_eq, lp.b_eq)
    if not root.ok:
        return _empty(top, root.status)
    _, n_frac, _ = lp.split(root.x)

    def round_down(n_source: np.ndarray, extra_ub=None) -> MILPResult | None:
        n_int = _feasibility_repair(struct, tput_goal, n_source, extra_ub)
        if n_int is None:
            return None
        fit = _integerize(struct, tput_goal, n_int, extra_ub)
        if fit is None:
            return None
        F, M, achieved, obj = fit
        return MILPResult(
            F=F, N=n_int.astype(np.int64), M=M.astype(np.int64),
            objective=obj, status="optimal", lp_objective=root.fun,
            achieved_tput=achieved,
        )

    if mode == "relaxed":
        out = round_down(n_frac, base_cuts)
        return out if out is not None else _empty(top, "infeasible", root.fun)

    if mode != "exact":
        raise ValueError(f"unknown mode {mode!r}")

    # ---------------- best-first branch & bound over N ----------------
    v = top.num_regions
    e = lp.n_edges

    def n_col(r: int) -> np.ndarray:
        row = np.zeros(2 * e + v)
        row[e + r] = 1.0
        return row

    best: MILPResult | None = round_down(n_frac, base_cuts)  # incumbent
    best_obj = best.objective if best is not None else math.inf

    counter = itertools.count()
    heap: list[tuple[float, int, list]] = [(root.fun, next(counter), [])]
    nodes = 0
    while heap and nodes < max_nodes:
        bound, _, cuts = heapq.heappop(heap)
        if bound >= best_obj - 1e-9:
            continue
        nodes += 1
        extra = list(base_cuts) if base_cuts else []
        for r, sense, val in cuts:
            col = n_col(r)
            if sense == "<=":
                extra.append((col, float(val)))
            else:  # N_r >= val
                extra.append((-col, -float(val)))
        if cuts:
            node_lp = struct.lp(tput_goal, extra_ub=extra)
            res = solve_lp(node_lp.c, node_lp.A_ub, node_lp.b_ub,
                           node_lp.A_eq, node_lp.b_eq)
        else:  # the cut-free node IS the root relaxation: reuse it
            node_lp, res = lp, root
        if not res.ok or res.fun >= best_obj - 1e-9:
            continue
        _, n_node, _ = node_lp.split(res.x)
        frac = n_node - np.floor(n_node + _INT_TOL)
        frac_ix = np.where(frac > 1e-4)[0]
        if frac_ix.size == 0:
            n_int = np.round(n_node).astype(float)
            fit = _integerize(struct, tput_goal, n_int, extra)
            if fit is not None and fit[3] < best_obj:
                F, M, achieved, obj = fit
                best_obj = obj
                best = MILPResult(
                    F=F, N=n_int.astype(np.int64), M=M.astype(np.int64),
                    objective=obj, status="optimal", lp_objective=root.fun,
                    achieved_tput=achieved, nodes_explored=nodes,
                )
            continue
        r = int(frac_ix[np.argmax(frac[frac_ix])])
        lo = math.floor(n_node[r] + _INT_TOL)
        heapq.heappush(heap, (res.fun, next(counter), cuts + [(r, "<=", lo)]))
        heapq.heappush(heap, (res.fun, next(counter), cuts + [(r, ">=", lo + 1)]))

    if best is None:
        return _empty(top, "infeasible", root.fun, nodes)
    best.nodes_explored = nodes
    return best


# ------------------------------------------------------------------ multicast
@dataclasses.dataclass
class MulticastMILPResult:
    """Round-down result of the multicast MILP (one source, D commodities)."""

    G: np.ndarray  # [V,V] envelope Gbit/s — what egress is billed on
    F: np.ndarray  # [D,V,V] per-commodity Gbit/s
    N: np.ndarray  # [V] ints
    M: np.ndarray  # [V,V] ints
    objective: float  # $/s while the transfer runs
    status: str
    lp_objective: float
    achieved_goals: np.ndarray  # [D] Gbit/s the integral plan provides
    scale: float = 0.0  # uniform fraction of the requested goals achieved

    @property
    def ok(self) -> bool:
        return self.status == "optimal"


def _mc_empty(top, n_dsts: int, status: str,
              lp_obj: float = math.inf) -> MulticastMILPResult:
    v = top.num_regions
    return MulticastMILPResult(
        G=np.zeros((v, v)), F=np.zeros((n_dsts, v, v)), N=np.zeros(v),
        M=np.zeros((v, v)), objective=math.inf, status=status,
        lp_objective=lp_obj, achieved_goals=np.zeros(n_dsts),
    )


def _mc_reduction(struct, fixed_n, allow_build: bool = True):
    """Exact presolve routing for pinned multicast solves.

    Returns "identity" when every region is live (or the solve must run
    full-size), else (rstruct, keep, rn) — src and all destinations are
    force-kept by ``reduced`` — or None when the reduction has no edges
    left. ``allow_build=False`` (constrained re-plans) only ever REUSES a
    cached reduction: a cold support solves full-size rather than
    assembling anything mid-replan."""
    support = np.asarray(fixed_n) > 0
    support = support.copy()
    support[[struct.src, *struct.dsts]] = True
    if support.all():
        return "identity"
    if allow_build:
        red = struct.reduced(support)
    else:
        red = struct.reduced_cached(support)
        if red == "miss":
            return "identity"
    if red is None:
        return None
    rstruct, keep = red
    return rstruct, keep, np.asarray(fixed_n, dtype=float)[keep]


def _mc_map_cuts(struct, rstruct, keep, extra_ub):
    """Map extra_ub rows from ``struct``'s variable space into a reduced
    structure's. Exact: a dropped region has N pinned to 0, which forces
    every G/F/M variable on its edges to 0 (4f-4i), so dropped columns
    contribute nothing — kept columns are re-indexed, dropped ones vanish.
    Rows that become all-zero are handled by the RHS-shift machinery."""
    if not extra_ub:
        return extra_ub
    inv = {int(r): i for i, r in enumerate(keep)}
    redge_ix = {e: i for i, e in enumerate(rstruct.edges)}
    e_full, e_red = struct.n_edges, rstruct.n_edges
    D = struct.n_dsts
    kept_k, red_k = [], []
    for k, (u, w) in enumerate(struct.edges):
        ru, rw = inv.get(u), inv.get(w)
        if ru is not None and rw is not None and (ru, rw) in redge_ix:
            kept_k.append(k)
            red_k.append(redge_ix[(ru, rw)])
    kept_k = np.asarray(kept_k, dtype=np.int64)
    red_k = np.asarray(red_k, dtype=np.int64)
    kept_r = np.asarray(sorted(inv), dtype=np.int64)
    red_r = np.asarray([inv[int(r)] for r in kept_r], dtype=np.int64)
    out = []
    for row, b in extra_ub:
        row = np.asarray(row, dtype=float)
        nrow = np.zeros(rstruct.nx)
        for blk in range(1 + D):  # G then each commodity
            nrow[blk * e_red + red_k] = row[blk * e_full + kept_k]
        nrow[rstruct.iN + red_r] = row[struct.iN + kept_r]
        nrow[rstruct.iM + red_k] = row[struct.iM + kept_k]
        out.append((nrow, float(b)))
    return out


def _mc_scale_probe(struct, goals, *, fixed_n=None, fixed_m=None,
                    extra_ub=None, cap: float | None = 1.0) -> float:
    """Max uniform scale t with deliveries >= t * goal_d (see
    MulticastLPStructure.probe_lp). Returns 0.0 on failure."""
    if float(np.max(goals, initial=0.0)) <= 0.0:
        return cap if cap is not None else math.inf
    if fixed_n is not None:
        red = _mc_reduction(struct, fixed_n, allow_build=not extra_ub)
        if red is None:
            return 0.0
        if red != "identity":
            rstruct, keep, rn = red
            rM = (None if fixed_m is None
                  else np.asarray(fixed_m)[np.ix_(keep, keep)])
            return _mc_scale_probe(
                rstruct, goals, fixed_n=rn, fixed_m=rM,
                extra_ub=_mc_map_cuts(struct, rstruct, keep, extra_ub),
                cap=cap,
            )
    probe = struct.probe_lp(goals, fixed_n=fixed_n, fixed_m=fixed_m,
                            extra_ub=extra_ub, cap=cap)
    if probe is None:
        return 0.0
    c, A_ub, b_ub, A_eq, b_eq = probe
    res = solve_lp(c, A_ub, b_ub, A_eq, b_eq)
    t = max(float(-(c @ res.x)), 0.0)
    if res.ok:
        return t
    if (res.status == "max_iter" and res.primal_residual < 1e-5
            and res.gap < 1e-6):
        return t * (1.0 - 10.0 * res.primal_residual)
    return 0.0


def _mc_min_cost(struct, goals, *, fixed_n=None, fixed_m=None, extra_ub=None):
    """Min-cost multicast solve at known-achievable goals; None on failure.

    Returns ((G, F, N, M) in ``struct``'s full region space, objective)."""
    if fixed_n is not None:
        red = _mc_reduction(struct, fixed_n, allow_build=not extra_ub)
        if red is None:
            return None
        if red != "identity":
            rstruct, keep, rn = red
            rM = (None if fixed_m is None
                  else np.asarray(fixed_m)[np.ix_(keep, keep)])
            fit = _mc_min_cost(
                rstruct, goals, fixed_n=rn, fixed_m=rM,
                extra_ub=_mc_map_cuts(struct, rstruct, keep, extra_ub),
            )
            if fit is None:
                return None
            (rG, rF, rN, rMM), fun = fit
            v = struct.num_regions
            G = np.zeros((v, v))
            F = np.zeros((len(struct.dsts), v, v))
            N = np.zeros(v)
            M = np.zeros((v, v))
            G[np.ix_(keep, keep)] = rG
            F[np.ix_(np.arange(len(struct.dsts)), keep, keep)] = rF
            N[keep] = rN
            M[np.ix_(keep, keep)] = rMM
            return (G, F, N, M), fun
    lp = struct.lp(goals, fixed_n=fixed_n, fixed_m=fixed_m, extra_ub=extra_ub)
    if lp.trivially_infeasible:
        return None
    res = solve_lp(lp.c, lp.A_ub, lp.b_ub, lp.A_eq, lp.b_eq)
    if not _near_ok(res):
        return None
    return lp.split(res.x), float(res.fun)


def solve_multicast(
    top,
    src: int,
    dsts,
    goals,
    *,
    extra_ub=None,
) -> MulticastMILPResult:
    """§5.1.3 round-down for the multicast MILP: one source, a commodity per
    destination, egress billed once on the shared envelope.

    Same pipeline shape as the unicast ``solve_milp``: root relaxation ->
    floor N + feasibility-repair ladder -> fixed-N refit + connection
    floor/top-up -> fixed-N+M refit — except the max-flow probes become
    uniform-scale probes (max t with every commodity delivering t * goal_d),
    which are always-feasible LPs. Every solve derives O(rows) from the
    cached ``milp.MulticastLPStructure``; ``extra_ub`` rows (degraded links,
    VM caps) ride on it without any re-assembly.
    """
    dsts = tuple(int(d) for d in dsts)
    goals = np.asarray(goals, dtype=float)
    if goals.ndim == 0:
        goals = np.full(len(dsts), float(goals))
    if goals.shape != (len(dsts),):
        raise ValueError(f"need one goal per destination, got {goals.shape}")
    struct = milp.multicast_structure(top, src, dsts)
    v = struct.num_regions

    if float(goals.max(initial=0.0)) <= 0.0:
        out = _mc_empty(top, len(dsts), "optimal", 0.0)
        out.objective = 0.0
        out.scale = 1.0
        return out

    # ---- root relaxation
    lp = struct.lp(goals, extra_ub=extra_ub)
    if lp.trivially_infeasible:
        return _mc_empty(top, len(dsts), "infeasible")
    root = solve_lp(lp.c, lp.A_ub, lp.b_ub, lp.A_eq, lp.b_eq)
    if not _near_ok(root):
        return _mc_empty(top, len(dsts), root.status)
    _, _, n_frac, _ = lp.split(root.x)

    # ---- feasibility repair: floor N, bump until the goals are reachable
    n_int, t1 = None, 0.0
    for n_try in _repair_candidates(n_frac, top.limit_vm):
        t = _mc_scale_probe(struct, goals, fixed_n=n_try, extra_ub=extra_ub)
        if t >= 1.0 - 1e-6:
            n_int, t1 = n_try, t
            break
    if n_int is None:
        return _mc_empty(top, len(dsts), "infeasible", root.fun)

    # ---- fixed-N refit: fractional M at the probed-achievable goals
    fit = _mc_min_cost(struct, goals * min(1.0, t1) * (1.0 - 1e-9),
                       fixed_n=n_int, extra_ub=extra_ub)
    if fit is None:
        return _mc_empty(top, len(dsts), "infeasible", root.fun)
    (_, _, _, M_frac), _ = fit
    M_int = np.floor(M_frac + _INT_TOL)
    _topup_connections(top, M_frac, M_int, n_int)

    # ---- fixed-N+M: probe the residual scale, refit G and F at it
    t2 = _mc_scale_probe(struct, goals, fixed_n=n_int, fixed_m=M_int,
                         extra_ub=extra_ub)
    scale = min(1.0, t2) * (1.0 - 1e-9)
    if scale <= 0.0:
        return _mc_empty(top, len(dsts), "infeasible", root.fun)
    achieved = goals * scale
    fit = _mc_min_cost(struct, achieved, fixed_n=n_int, fixed_m=M_int,
                       extra_ub=extra_ub)
    if fit is None:
        return _mc_empty(top, len(dsts), "infeasible", root.fun)
    (G, F, _, _), _ = fit
    # commodity flows are free in the objective (only the envelope is
    # billed), so a zero-goal commodity can come back carrying junk flow —
    # scrub it, or a finished destination would re-enter the trees
    F[achieved <= 0.0] = 0.0
    obj = float((G * top.price_egress).sum() / GBIT_PER_GB
                + n_int @ top.price_vm)
    return MulticastMILPResult(
        G=G, F=F, N=n_int.astype(np.int64), M=M_int.astype(np.int64),
        objective=obj, status="optimal", lp_objective=float(root.fun),
        achieved_goals=achieved, scale=float(scale),
    )


# --------------------------------------------------------------------- batched
def solve_milp_batched(
    top,
    src: int,
    dst: int,
    goals: np.ndarray,
    *,
    iters: int = 40,
) -> list[MILPResult]:
    """The §5.1.3 round-down pipeline for a batch of throughput goals.

    Replays the exact sequential procedure (root relaxation -> feasibility
    repair -> fixed-N refit + connection top-up -> fixed-N+M refit) but runs
    each stage as ONE batched JAX IPM call across all still-live goals: the
    LPs of a stage share their matrices (cached pin patterns of the
    LPStructure) and differ only in RHS shifts. Samples whose batched solve
    fails its KKT check are transparently re-solved by the numpy IPM, so the
    result list matches the sequential path's answers. The batched engine is
    picked per host (ipm_batch: stacked-LAPACK numpy on CPU-only hosts, the
    vmapped JAX IPM when an accelerator is available).
    """
    from .ipm_batch import solve_lp_batched_with_fallback

    struct = milp.structure(top, src, dst)
    goals = np.asarray(goals, dtype=float)
    B = len(goals)
    v, e = struct.num_regions, struct.n_edges
    eu, ew = struct.eu, struct.ew
    results: list[MILPResult | None] = [None] * B

    def finish():
        return [
            results[i] if results[i] is not None
            else _empty(top, "infeasible",
                        root_fun[i] if root_ok[i] else math.inf)
            for i in range(B)
        ]

    # ---- stage 0: root relaxations (batch over the two goal rows of b)
    b0 = np.tile(struct.b_ub0[None, :], (B, 1))
    b0[:, struct.row_4c] = -goals
    b0[:, struct.row_4d] = -goals
    x0, root_fun, root_ok, _ = solve_lp_batched_with_fallback(
        struct.c, struct.A_ub, b0, struct.A_eq, struct.b_eq, iters=iters
    )
    alive = root_ok.copy()
    n_frac = x0[:, e : e + v]
    if not alive.any():
        return finish()

    # Stages 1-4 pin N (and later M), so every solve routes through the exact
    # presolve: rows sharing a (support, edge-mask) reduction solve as one
    # batched call on the reduced structure.
    def grouped_pinned(goals_k, n_mat, M_mat, objective):
        """Batched pinned solves grouped by identical reduction.

        objective "outflow": returns (maxflow [K]).
        objective "cost":    returns (x_full [K, nx-ish as (F, M) grids], ok):
        F [K,v,v] always; M [K,v,v] only meaningful when M_mat is None.
        """
        K = n_mat.shape[0]
        maxflow = np.zeros(K)
        F_out = np.zeros((K, v, v))
        M_out = np.zeros((K, v, v))
        okv = np.zeros(K, dtype=bool)
        groups: dict[bytes, list[int]] = {}
        for k in range(K):
            key = (n_mat[k] > 0).tobytes()
            if M_mat is not None:
                key += (M_mat[k] > 0).tobytes()
            groups.setdefault(key, []).append(k)
        for rows in groups.values():
            r0 = rows[0]
            support = n_mat[r0] > 0
            edge_mask = None if M_mat is None else M_mat[r0] > 0
            if support.all() and (
                edge_mask is None or edge_mask[eu, ew].all()
            ):
                rstruct, keep = struct, np.arange(v)
            else:
                red = struct.reduced(support, edge_mask)
                if red is None:
                    continue  # provably zero flow: maxflow 0 / not ok
                rstruct, keep = red
            rn = n_mat[rows][:, keep]
            if M_mat is not None:
                rM = M_mat[np.ix_(rows, keep, keep)]
                pins = np.concatenate(
                    [rn, rM[:, rstruct.eu, rstruct.ew]], axis=1
                )
            else:
                pins = rn
            pat = rstruct.pin_pattern(True, M_mat is not None)
            stage_goals = (
                np.zeros(len(rows)) if objective == "outflow"
                else goals_k[rows]
            )
            b, triv = rstruct.batch_b_ub(pat, stage_goals, pins)
            c_stage = (
                rstruct.outflow_c(pat) if objective == "outflow"
                else pat.c_free
            )
            x, fun, ok, _ = solve_lp_batched_with_fallback(
                c_stage, pat.A_ub_free, b, pat.A_eq_free,
                rstruct.b_eq[pat.keep_eq], iters=iters,
            )
            good = ok & ~triv
            re = rstruct.n_edges
            for row_local, k in enumerate(rows):
                if not good[row_local]:
                    if triv[row_local]:
                        continue
                    # uncertified sample: retry on the tolerant sequential
                    # path (degenerate boundary refits; see _max_flow_raw)
                    rn_k = n_mat[k][keep]
                    rM_k = (None if M_mat is None
                            else M_mat[k][np.ix_(keep, keep)])
                    if objective == "outflow":
                        maxflow[k] = _max_flow_raw(
                            rstruct, fixed_n=rn_k, fixed_m=rM_k
                        )
                        okv[k] = True
                    elif M_mat is not None:
                        Fk = _min_cost_fit(rstruct, float(goals_k[k]),
                                           rn_k, rM_k)
                        if Fk is not None:
                            F_out[np.ix_([k], keep, keep)] = Fk[None]
                            okv[k] = True
                    else:
                        lp_k = rstruct.lp(float(goals_k[k]), fixed_n=rn_k)
                        if not lp_k.trivially_infeasible:
                            res_k = solve_lp(lp_k.c, lp_k.A_ub, lp_k.b_ub,
                                             lp_k.A_eq, lp_k.b_eq)
                            if _near_ok(res_k):
                                Fk, _, Mk = lp_k.split(res_k.x)
                                F_out[np.ix_([k], keep, keep)] = Fk[None]
                                M_out[np.ix_([k], keep, keep)] = Mk[None]
                                okv[k] = True
                    continue
                okv[k] = True
                if objective == "outflow":
                    maxflow[k] = max(-float(fun[row_local]), 0.0)
                else:
                    Fk = np.zeros((rstruct.num_regions,) * 2)
                    Fk[rstruct.eu, rstruct.ew] = x[row_local, :re]
                    F_out[np.ix_([k], keep, keep)] = Fk[None]
                    if M_mat is None:  # fixed-N solve: free cols are [F, M]
                        Mk = np.zeros((rstruct.num_regions,) * 2)
                        Mk[rstruct.eu, rstruct.ew] = x[row_local, re:]
                        M_out[np.ix_([k], keep, keep)] = Mk[None]
        if objective == "outflow":
            return maxflow
        return F_out, M_out, okv

    # ---- stage 1: feasibility repair — batched max-flow probes, two-phase:
    # floors first (usually enough), then the full bump ladder only for the
    # goals whose floor fell short. Matches the sequential first-feasible pick.
    live_ix = np.flatnonzero(alive)
    floors = np.floor(n_frac[live_ix] + _INT_TOL)
    mf_floor = grouped_pinned(None, floors, None, "outflow")
    n_int = np.zeros((B, v))
    flow_cap = np.zeros(B)
    need_ladder = []
    for row, i in enumerate(live_ix):
        if mf_floor[row] >= goals[i] * (1.0 - 1e-6):
            n_int[i] = floors[row]
            flow_cap[i] = mf_floor[row]
        else:
            need_ladder.append(i)
    if need_ladder:
        K = v + 1  # bump ladder + ceil (floor already probed)
        ladders = np.stack(
            [_repair_candidates(n_frac[i], top.limit_vm)[1:] for i in need_ladder]
        )
        mf = grouped_pinned(
            None, ladders.reshape(-1, v), None, "outflow"
        ).reshape(len(need_ladder), K)
        for row, i in enumerate(need_ladder):
            feas = np.flatnonzero(mf[row] >= goals[i] * (1.0 - 1e-6))
            if feas.size == 0:
                alive[i] = False
                continue
            k = int(feas[0])
            n_int[i] = ladders[row, k]
            flow_cap[i] = mf[row, k]
    if not alive.any():
        return finish()

    # ---- stage 2: fixed-N min-cost refit at min(goal, maxflow)
    goal_n = np.minimum(goals, flow_cap * (1.0 - 1e-9))
    alive &= goal_n > 0
    live_ix = np.flatnonzero(alive)
    if live_ix.size == 0:
        return finish()
    _, M_frac_all, ok2 = grouped_pinned(
        goal_n[live_ix], n_int[live_ix], None, "cost"
    )
    M_int = np.zeros((B, v, v))
    for row, i in enumerate(live_ix):
        if not ok2[row]:
            alive[i] = False
            continue
        M_frac = M_frac_all[row]
        Mi = np.floor(M_frac + _INT_TOL)
        _topup_connections(top, M_frac, Mi, n_int[i])
        M_int[i] = Mi
    live_ix = np.flatnonzero(alive)
    if live_ix.size == 0:
        return finish()

    # ---- stage 3: fixed-N+M max-flow probe
    maxflow3 = grouped_pinned(
        None, n_int[live_ix], M_int[live_ix], "outflow"
    )
    achieved = np.zeros(B)
    achieved[live_ix] = np.minimum(goal_n[live_ix], maxflow3 * (1.0 - 1e-9))
    alive &= achieved > 0
    live_ix = np.flatnonzero(alive)
    if live_ix.size == 0:
        return finish()

    # ---- stage 4: fixed-N+M min-cost re-fit of F at the achieved goal
    F_all, _, ok4 = grouped_pinned(
        achieved[live_ix], n_int[live_ix], M_int[live_ix], "cost"
    )
    for row, i in enumerate(live_ix):
        if not ok4[row]:
            alive[i] = False
            continue
        F = F_all[row]
        obj = float(
            (F * top.price_egress).sum() / GBIT_PER_GB
            + n_int[i] @ top.price_vm
        )
        results[i] = MILPResult(
            F=F,
            N=n_int[i].astype(np.int64),
            M=M_int[i].astype(np.int64),
            objective=obj,
            status="optimal",
            lp_objective=float(root_fun[i]),
            achieved_tput=float(achieved[i]),
        )
    return finish()
