"""Dense primal-dual interior-point LP solver (Mehrotra predictor-corrector).

The paper solves its MILP with Gurobi (or Coin-OR). Neither is available
offline, so the framework ships its own solver. Problems produced by
``repro.core.milp`` are small and dense (a pruned candidate graph has ~12
regions -> ~300 variables), so a dense normal-equations IPM is both simple
and fast (<10 ms per solve), and — unlike simplex — trivially portable to a
batched JAX implementation (see ``ipm_jax.py``) for Pareto-frontier sweeps.

Standard form solved here:   min c@x   s.t.  A@x = b,  x >= 0
``solve_lp`` converts an inequality/equality description by appending slacks.

Reference: S. Wright, *Primal-Dual Interior-Point Methods*, SIAM 1997, ch. 10.
"""

from __future__ import annotations

import dataclasses

import numpy as np

_EPS = 1e-11


@dataclasses.dataclass
class IPMResult:
    x: np.ndarray  # primal solution (original variables, slacks stripped)
    fun: float
    status: str  # "optimal" | "max_iter" | "infeasible"
    iterations: int
    gap: float
    primal_residual: float
    dual_residual: float

    @property
    def ok(self) -> bool:
        return self.status == "optimal"


def _ruiz_equilibrate(A: np.ndarray, iters: int = 6):
    """Ruiz row/col equilibration; returns (A_scaled, row_scale, col_scale)."""
    m, n = A.shape
    r = np.ones(m)
    c = np.ones(n)
    As = A.copy()
    for _ in range(iters):
        row_norm = np.sqrt(np.maximum(np.abs(As).max(axis=1), _EPS))
        col_norm = np.sqrt(np.maximum(np.abs(As).max(axis=0), _EPS))
        As = As / row_norm[:, None] / col_norm[None, :]
        r *= row_norm
        c *= col_norm
    return As, r, c


class _NormalFactor:
    """Cholesky of (A D A^T + reg I) with escalating reg, reusable across the
    predictor and corrector solves of one IPM iteration (same matrix)."""

    def __init__(self, M: np.ndarray, reg0: float):
        m = M.shape[0]
        tr = max(np.trace(M) / max(m, 1), 1.0)
        reg = reg0
        self.L = None
        self.M_reg = M
        for _ in range(6):
            M_reg = M + reg * tr * np.eye(m)
            try:
                self.L = np.linalg.cholesky(M_reg)
                return
            except np.linalg.LinAlgError:
                reg *= 100.0
        self.M_reg = M + reg * tr * np.eye(m)  # lstsq fallback operand

    def solve(self, rhs: np.ndarray) -> np.ndarray:
        if self.L is not None:
            return np.linalg.solve(self.L.T, np.linalg.solve(self.L, rhs))
        return np.linalg.lstsq(self.M_reg, rhs, rcond=None)[0]


def _normal_matrix(As: np.ndarray, d: np.ndarray, n_slack: int,
                   slack_diag: np.ndarray | None) -> np.ndarray:
    """A D A^T, exploiting the slack identity block when present.

    With columns [A_core | slack] where slack column i has its single nonzero
    at row i, the product splits into a core matmul (m^2 * n_core flops
    instead of m^2 * n_std) plus a diagonal update on the slack rows.
    """
    if n_slack == 0:
        AD = As * d[None, :]
        return AD @ As.T
    nc = As.shape[1] - n_slack
    core = As[:, :nc]
    M = (core * d[None, :nc]) @ core.T
    sl = np.arange(n_slack)
    M[sl, sl] += slack_diag * slack_diag * d[nc:]
    return M


def solve_standard_form(
    A: np.ndarray,
    b: np.ndarray,
    c: np.ndarray,
    *,
    tol: float = 1e-9,
    max_iter: int = 100,
    n_slack: int = 0,
) -> tuple[np.ndarray, str, int, float, float, float]:
    """Mehrotra predictor-corrector on  min c@x s.t. A@x=b, x>=0.

    n_slack: the trailing ``n_slack`` columns of A form an identity slack
    block attached to rows 0..n_slack (as produced by ``solve_lp``); the
    normal-equation assembly then skips the m^2 * n_slack flops those columns
    would otherwise cost.
    """
    A = np.asarray(A, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    c = np.asarray(c, dtype=np.float64)
    m, n = A.shape
    if m == 0:
        # unconstrained positive orthant: optimum at 0 if c >= 0
        return np.zeros(n), "optimal", 0, 0.0, 0.0, 0.0

    # Dependent equality rows (pruned graphs / fixed-N refits produce them)
    # are tolerated by the regularized normal-equation solves below, so no
    # explicit rank filtering is needed on the hot path.

    # Scaling: As = A / (rsc ⊗ csc), x_scaled = csc * x, so b_s = b / rsc and
    # the objective keeps its value with c_s = c / csc.
    As, rsc, csc = _ruiz_equilibrate(A)
    bs = b / rsc
    cs = c / csc
    # diagonal scaling keeps the slack block diagonal: entry (i, n-n_slack+i)
    slack_diag = (
        As[np.arange(n_slack), n - n_slack + np.arange(n_slack)]
        if n_slack
        else None
    )

    bnorm = 1.0 + np.linalg.norm(bs)
    cnorm = 1.0 + np.linalg.norm(cs)

    # ---- Mehrotra starting point
    AAt = _normal_matrix(As, np.ones(n), n_slack, slack_diag)
    tr = max(np.trace(AAt) / m, 1.0)
    AAt_reg = AAt + 1e-10 * tr * np.eye(m)
    try:
        x0 = As.T @ np.linalg.solve(AAt_reg, bs)
        y = np.linalg.solve(AAt_reg, As @ cs)
    except np.linalg.LinAlgError:
        x0 = As.T @ np.linalg.lstsq(AAt_reg, bs, rcond=None)[0]
        y = np.linalg.lstsq(AAt_reg, As @ cs, rcond=None)[0]
    s0 = cs - As.T @ y
    dx = max(-1.5 * x0.min(initial=0.0), 0.0)
    ds = max(-1.5 * s0.min(initial=0.0), 0.0)
    x = x0 + dx
    s = s0 + ds
    xs = float(x @ s)
    if xs <= 0:
        x = np.ones(n)
        s = np.ones(n)
        xs = float(n)
    x = x + 0.5 * xs / max(s.sum(), _EPS)
    s = s + 0.5 * xs / max(x.sum(), _EPS)
    x = np.maximum(x, 1e-4)
    s = np.maximum(s, 1e-4)

    status = "max_iter"
    it = 0
    best_pres = np.inf
    stall = 0
    best_gap = np.inf
    floor_stall = 0
    for it in range(1, max_iter + 1):
        rb = As @ x - bs
        rc = As.T @ y + s - cs
        mu = float(x @ s) / n
        pres = np.linalg.norm(rb) / bnorm
        dres = np.linalg.norm(rc) / cnorm
        gap = n * mu / (1.0 + abs(float(cs @ x)))
        if pres < tol and dres < tol and gap < tol:
            status = "optimal"
            break
        # floor acceptance: once all residuals sit below the relaxed 1e-7
        # threshold (which the post-loop check would accept anyway) and the
        # gap has stopped halving, further iterations only burn flops — the
        # solve has hit its numerical floor for this scaling.
        if gap < best_gap * 0.5:
            best_gap = gap
            floor_stall = 0
        else:
            floor_stall += 1
        if (pres < 1e-7 and dres < 1e-7 and gap < 1e-7 and floor_stall >= 5):
            status = "optimal"
            break
        # stall detection: primal residual stopped improving while still far
        # from feasible => (numerically) infeasible instance, bail early.
        # Stalls in the (1e-6, 1e-5) band are near-degenerate boundary
        # instances, not proofs of infeasibility: report max_iter and let
        # the caller's acceptance logic judge the returned point.
        if pres < best_pres * 0.9:
            best_pres = pres
            stall = 0
        else:
            stall += 1
            if stall >= 12 and pres > 1e-6:
                status = "infeasible" if pres > 1e-5 else "max_iter"
                break

        d = x / s
        # one factorization serves both the predictor and corrector solves
        factor = _NormalFactor(_normal_matrix(As, d, n_slack, slack_diag), 1e-12)

        # predictor (affine) step
        r_xs = x * s
        rhs = -rb - As @ (d * rc - r_xs / s)
        dy_aff = factor.solve(rhs)
        dx_aff = d * (As.T @ dy_aff + rc) - r_xs / s
        ds_aff = -(r_xs + s * dx_aff) / x

        a_pri = _max_step(x, dx_aff)
        a_dua = _max_step(s, ds_aff)
        mu_aff = float((x + a_pri * dx_aff) @ (s + a_dua * ds_aff)) / n
        sigma = float(np.clip((mu_aff / max(mu, _EPS)) ** 3, 0.0, 1.0))

        # corrector step
        r_xs = x * s + dx_aff * ds_aff - sigma * mu
        rhs = -rb - As @ (d * rc - r_xs / s)
        dy = factor.solve(rhs)
        dx = d * (As.T @ dy + rc) - r_xs / s
        dsv = -(r_xs + s * dx) / x

        eta = min(0.999, 0.9 + 0.09 * it / max_iter)
        a_pri = eta * _max_step(x, dx)
        a_dua = eta * _max_step(s, dsv)
        x = x + a_pri * dx
        y = y + a_dua * dy
        s = s + a_dua * dsv
        x = np.maximum(x, _EPS)
        s = np.maximum(s, _EPS)

    rb = As @ x - bs
    rc = As.T @ y + s - cs
    mu = float(x @ s) / n
    pres = float(np.linalg.norm(rb) / bnorm)
    dres = float(np.linalg.norm(rc) / cnorm)
    gap = float(n * mu / (1.0 + abs(float(cs @ x))))
    if status != "optimal":
        if pres < 1e-7 and dres < 1e-7 and gap < 1e-7:
            status = "optimal"
        elif pres > 1e-4:
            status = "infeasible"
    x_orig = x / csc
    return x_orig, status, it, gap, pres, dres


def _max_step(v: np.ndarray, dv: np.ndarray) -> float:
    neg = dv < 0
    if not neg.any():
        return 1.0
    return float(min(1.0, np.min(-v[neg] / dv[neg])))


def solve_lp(
    c: np.ndarray,
    A_ub: np.ndarray,
    b_ub: np.ndarray,
    A_eq: np.ndarray,
    b_eq: np.ndarray,
    *,
    tol: float = 1e-9,
    max_iter: int = 100,
) -> IPMResult:
    """Solve min c@x s.t. A_ub@x<=b_ub, A_eq@x=b_eq, x>=0 by adding slacks."""
    c = np.asarray(c, dtype=np.float64)
    n = c.shape[0]
    m_ub = A_ub.shape[0] if A_ub is not None and A_ub.size else 0
    m_eq = A_eq.shape[0] if A_eq is not None and A_eq.size else 0
    n_std = n + m_ub
    A = np.zeros((m_ub + m_eq, n_std))
    b = np.zeros(m_ub + m_eq)
    if m_ub:
        A[:m_ub, :n] = A_ub
        A[:m_ub, n:] = np.eye(m_ub)
        b[:m_ub] = b_ub
    if m_eq:
        A[m_ub:, :n] = A_eq
        b[m_ub:] = b_eq
    c_std = np.concatenate([c, np.zeros(m_ub)])
    x, status, it, gap, pres, dres = solve_standard_form(
        A, b, c_std, tol=tol, max_iter=max_iter, n_slack=m_ub
    )
    return IPMResult(
        x=x[:n],
        fun=float(c @ x[:n]),
        status=status,
        iterations=it,
        gap=gap,
        primal_residual=pres,
        dual_residual=dres,
    )
