"""Batched LP solving across RHS variants — engine dispatch + numpy engine.

The planner's hot path (Pareto sweeps, feasibility-repair probes, round-down
refits) produces *batches* of LPs that share (c, A_ub, A_eq) and differ only
in b. Two engines solve such a batch:

  * ``engine="jax"``   — the vmapped fixed-iteration IPM in ``ipm_jax.py``.
    The right choice when an accelerator backs jax: one compiled scan, all
    samples in flight.
  * ``engine="numpy"`` — this module's batched Mehrotra predictor-corrector.
    All per-iteration linear algebra runs through numpy's *stacked* LAPACK
    gufuncs (``np.linalg.solve`` on [B, m, m]), which on CPU-only hosts beat
    XLA's triangular/LU solve lowering by 20-30x (measured on the 12-region
    planner LPs). Samples converge adaptively and are compacted out of the
    batch, so a sweep pays ~25-45 iterations per sample instead of a fixed
    worst-case count.

``engine="auto"`` picks numpy when jax only has CPU devices, jax otherwise
(override with REPRO_BATCH_ENGINE=numpy|jax). ``solve_lp_batched_with_fallback``
adds the per-sample KKT fallback: any sample the batched engine fails to
certify is re-solved by the sequential reference IPM, so callers always get
numpy-reference-grade answers.
"""

from __future__ import annotations

import os

import numpy as np

from .ipm import _normal_matrix, _ruiz_equilibrate, solve_lp

_EPS = 1e-11


def _max_step_batched(v: np.ndarray, dv: np.ndarray) -> np.ndarray:
    """Per-sample max alpha with v + alpha*dv >= 0. [B, n] -> [B]."""
    with np.errstate(divide="ignore", invalid="ignore"):
        ratio = np.where(dv < 0, -v / dv, np.inf)
    return np.minimum(1.0, ratio.min(axis=1))


def _solve_normal_batched(M: np.ndarray, rhs: np.ndarray, reg0: float) -> np.ndarray:
    """Solve (M_b + reg*tr_b*I) y_b = rhs_b for a stack of normal matrices.

    Batched LU via np.linalg.solve; regularization escalates for the whole
    batch on (rare) exact singularity, mirroring the sequential solver.
    """
    m = M.shape[-1]
    tr = np.maximum(np.trace(M, axis1=1, axis2=2) / max(m, 1), 1.0)
    eye = np.eye(m)
    reg = reg0
    for _ in range(6):
        try:
            return np.linalg.solve(
                M + (reg * tr)[:, None, None] * eye, rhs[..., None]
            )[..., 0]
        except np.linalg.LinAlgError:
            reg *= 100.0
    out = np.empty_like(rhs)
    for i in range(M.shape[0]):
        out[i] = np.linalg.lstsq(
            M[i] + reg * tr[i] * eye, rhs[i], rcond=None
        )[0]
    return out


def solve_standard_form_batched(
    A: np.ndarray,
    bs: np.ndarray,
    c: np.ndarray,
    *,
    tol: float = 1e-9,
    max_iter: int = 100,
    n_slack: int = 0,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Batched Mehrotra on  min c@x s.t. A@x=b_i, x>=0  for shared (A, c).

    Returns (x [B, n], fun [B], ok [B]). Per-sample iterates follow the
    sequential ``solve_standard_form`` (shared equilibration, same starting
    point, same stopping rules); converged/stalled samples drop out of the
    batch so the remaining ones keep full LAPACK batch width.
    """
    A = np.asarray(A, dtype=np.float64)
    bs = np.asarray(bs, dtype=np.float64)
    c = np.asarray(c, dtype=np.float64)
    B = bs.shape[0]
    m, n = A.shape
    if m == 0:
        return np.zeros((B, n)), np.zeros(B), np.ones(B, dtype=bool)

    As, rsc, csc = _ruiz_equilibrate(A)
    bs_s = bs / rsc[None, :]
    cs = c / csc
    nc = n - n_slack
    slack_diag = (
        As[np.arange(n_slack), nc + np.arange(n_slack)] if n_slack else None
    )
    core = As[:, :nc]

    def normal_matrices(D: np.ndarray) -> np.ndarray:
        # M_b = A diag(D_b) A^T, slack identity block folded into the diagonal.
        # Broadcasted matmul (batched BLAS dgemm) — einsum would bypass BLAS.
        M = (core[None, :, :] * D[:, None, :nc]) @ core.T
        if n_slack:
            sl = np.arange(n_slack)
            M[:, sl, sl] += slack_diag * slack_diag * D[:, nc:]
        return M

    bnorm = 1.0 + np.linalg.norm(bs_s, axis=1)
    cnorm = 1.0 + np.linalg.norm(cs)

    # ---- Mehrotra starting point (shared factor, per-sample b)
    AAt = _normal_matrix(As, np.ones(n), n_slack, slack_diag)
    tr = max(np.trace(AAt) / m, 1.0)
    AAt_reg = AAt + 1e-10 * tr * np.eye(m)
    try:
        X = (As.T @ np.linalg.solve(AAt_reg, bs_s.T)).T
        y0 = np.linalg.solve(AAt_reg, As @ cs)
    except np.linalg.LinAlgError:
        X = (As.T @ np.linalg.lstsq(AAt_reg, bs_s.T, rcond=None)[0]).T
        y0 = np.linalg.lstsq(AAt_reg, As @ cs, rcond=None)[0]
    s0 = cs - As.T @ y0
    S = np.tile(s0[None, :], (B, 1))
    Y = np.tile(y0[None, :], (B, 1))
    dx = np.maximum(-1.5 * X.min(axis=1, initial=0.0), 0.0)
    ds = np.maximum(-1.5 * S.min(axis=1, initial=0.0), 0.0)
    X = X + dx[:, None]
    S = S + ds[:, None]
    xs = np.einsum("bi,bi->b", X, S)
    bad = xs <= 0
    X[bad] = 1.0
    S[bad] = 1.0
    xs[bad] = float(n)
    X = X + 0.5 * (xs / np.maximum(S.sum(axis=1), _EPS))[:, None]
    S = S + 0.5 * (xs / np.maximum(X.sum(axis=1), _EPS))[:, None]
    X = np.maximum(X, 1e-4)
    S = np.maximum(S, 1e-4)

    # active-sample bookkeeping (batch compaction)
    idx = np.arange(B)
    best_pres = np.full(B, np.inf)
    stall = np.zeros(B, dtype=np.int64)
    best_gap = np.full(B, np.inf)
    floor_stall = np.zeros(B, dtype=np.int64)
    out_x = np.zeros((B, n))
    out_ok = np.zeros(B, dtype=bool)

    def finalize(sel_local, optimal: np.ndarray):
        """Record finished samples (local indices into the active batch)."""
        gi = idx[sel_local]
        out_x[gi] = X[sel_local]
        out_ok[gi] = optimal

    for it in range(1, max_iter + 1):
        rb = X @ As.T - bs_s
        rc = Y @ As + S - cs
        mu = np.einsum("bi,bi->b", X, S) / n
        pres = np.linalg.norm(rb, axis=1) / bnorm[idx]
        dres = np.linalg.norm(rc, axis=1) / cnorm
        gap = n * mu / (1.0 + np.abs(np.einsum("i,bi->b", cs, X)))

        converged = (pres < tol) & (dres < tol) & (gap < tol)
        # floor acceptance (mirrors the sequential solver): residuals below
        # the relaxed 1e-7 threshold with a gap that stopped halving
        gap_improving = gap < best_gap * 0.5
        best_gap = np.where(gap_improving, gap, best_gap)
        floor_stall = np.where(gap_improving, 0, floor_stall + 1)
        converged |= (
            (pres < 1e-7) & (dres < 1e-7) & (gap < 1e-7) & (floor_stall >= 5)
        )
        improving = pres < best_pres * 0.9
        best_pres = np.where(improving, pres, best_pres)
        stall = np.where(improving, 0, stall + 1)
        stalled = (stall >= 12) & (pres > 1e-6) & ~converged
        # out of iterations: apply the sequential solver's relaxed acceptance
        if it == max_iter:
            converged = converged | ((pres < 1e-7) & (dres < 1e-7) & (gap < 1e-7))
            stalled = ~converged
        finished = converged | stalled
        if finished.any():
            finalize(np.flatnonzero(finished), converged[finished])
            keep = ~finished
            if not keep.any():
                break
            X, Y, S = X[keep], Y[keep], S[keep]
            rb, rc, mu = rb[keep], rc[keep], mu[keep]
            bs_s = bs_s[keep]
            idx = idx[keep]
            best_pres, stall = best_pres[keep], stall[keep]
            best_gap, floor_stall = best_gap[keep], floor_stall[keep]

        D = X / S
        M = normal_matrices(D)

        # predictor (affine) step
        r_xs = X * S
        rhs = -rb - (D * rc - r_xs / S) @ As.T
        dY_a = _solve_normal_batched(M, rhs, 1e-12)
        dX_a = D * (dY_a @ As + rc) - r_xs / S
        dS_a = -(r_xs + S * dX_a) / X

        a_pri = _max_step_batched(X, dX_a)
        a_dua = _max_step_batched(S, dS_a)
        mu_aff = (
            np.einsum("bi,bi->b", X + a_pri[:, None] * dX_a,
                      S + a_dua[:, None] * dS_a) / n
        )
        sigma = np.clip((mu_aff / np.maximum(mu, _EPS)) ** 3, 0.0, 1.0)

        # corrector step (same normal matrices, second batched factorization)
        r_xs = X * S + dX_a * dS_a - (sigma * mu)[:, None]
        rhs = -rb - (D * rc - r_xs / S) @ As.T
        dY = _solve_normal_batched(M, rhs, 1e-12)
        dX = D * (dY @ As + rc) - r_xs / S
        dS = -(r_xs + S * dX) / X

        eta = min(0.999, 0.9 + 0.09 * it / max_iter)
        a_pri = eta * _max_step_batched(X, dX)
        a_dua = eta * _max_step_batched(S, dS)
        X = np.maximum(X + a_pri[:, None] * dX, _EPS)
        Y = Y + a_dua[:, None] * dY
        S = np.maximum(S + a_dua[:, None] * dS, _EPS)

    x_orig = out_x / csc[None, :]
    return x_orig, x_orig @ c, out_ok


def solve_lp_batched(
    c, A_ub, b_ub_batch, A_eq, b_eq, *, tol: float = 1e-9, max_iter: int = 100
):
    """numpy-engine batch solve of min c@x, A_ub@x <= b_i, A_eq@x = b_eq_i.

    Same contract as ``ipm_jax.solve_lp_batched``: b_eq may be [m_eq] or
    [B, m_eq]; returns (x [B, n], fun [B], ok [B])."""
    c = np.asarray(c, dtype=np.float64)
    A_ub = np.asarray(A_ub, dtype=np.float64)
    b_ub_batch = np.asarray(b_ub_batch, dtype=np.float64)
    n = c.shape[0]
    m_ub = A_ub.shape[0] if A_ub.size else 0
    m_eq = A_eq.shape[0] if A_eq is not None and A_eq.size else 0
    B = b_ub_batch.shape[0]
    A = np.zeros((m_ub + m_eq, n + m_ub))
    if m_ub:
        A[:m_ub, :n] = A_ub
        A[:m_ub, n:] = np.eye(m_ub)
    if m_eq:
        A[m_ub:, :n] = A_eq
    bs = np.zeros((B, m_ub + m_eq))
    bs[:, :m_ub] = b_ub_batch
    if m_eq:
        bs[:, m_ub:] = np.asarray(b_eq, np.float64)
    c_std = np.concatenate([c, np.zeros(m_ub)])
    x, _, ok = solve_standard_form_batched(
        A, bs, c_std, tol=tol, max_iter=max_iter, n_slack=m_ub
    )
    x = x[:, :n]
    return x, x @ c, ok


def _pick_engine(engine: str) -> str:
    if engine != "auto":
        return engine
    env = os.environ.get("REPRO_BATCH_ENGINE")
    if env in ("numpy", "jax"):
        return env
    try:
        import jax

        return "numpy" if jax.default_backend() == "cpu" else "jax"
    except Exception:  # pragma: no cover - jax is a hard dep elsewhere
        return "numpy"


def solve_lp_batched_auto(c, A_ub, b_ub_batch, A_eq, b_eq, *,
                          engine: str = "auto", iters: int = 40):
    """Engine-dispatched batch solve without the sequential fallback pass.

    Same (x, fun, ok) contract as both engines; ``ok`` is the engine's own
    KKT certificate."""
    if _pick_engine(engine) == "jax":
        from .ipm_jax import solve_lp_batched as jax_batched

        return jax_batched(c, A_ub, b_ub_batch, A_eq, b_eq, iters=iters)
    return solve_lp_batched(c, A_ub, b_ub_batch, A_eq, b_eq)


def solve_lp_batched_with_fallback(
    c, A_ub, b_ub_batch, A_eq, b_eq, *, engine: str = "auto", iters: int = 40
):
    """Batch solve + per-sample sequential re-solve of uncertified samples.

    Returns (x, fun, ok, n_fallback); ``ok`` afterwards means "solved to the
    sequential numpy reference's standard" — samples still not-ok are
    genuinely infeasible/unbounded there too.
    """
    x, fun, ok = solve_lp_batched_auto(
        c, A_ub, b_ub_batch, A_eq, b_eq, engine=engine, iters=iters
    )
    bad = np.flatnonzero(~ok)
    if bad.size:
        # jax-backed buffers are read-only
        x, fun, ok = np.array(x), np.array(fun), np.array(ok)
    b_eq_arr = np.asarray(b_eq, np.float64) if b_eq is not None else np.zeros(0)
    for i in bad:
        b_eq_i = b_eq_arr[i] if b_eq_arr.ndim == 2 else b_eq_arr
        res = solve_lp(c, A_ub, b_ub_batch[i], A_eq, b_eq_i)
        x[i] = res.x
        fun[i] = res.fun
        ok[i] = res.ok
    return x, fun, ok, len(bad)
