"""Batched JAX interior-point LP solver for the planner's solve pipelines.

The paper's §5.2 throughput-max mode solves ~100 cost-min LPs at different
throughput goals, and the §5.1.3 round-down pipeline adds feasibility-repair
probes and fixed-N / fixed-N+M refits. All of those LPs share their matrices
and differ only in the RHS — either the two goal rows of b or the
pinned-variable shifts produced by ``milp.LPStructure.batch_b_ub`` — a
textbook vmap: one fixed-iteration Mehrotra predictor-corrector, jitted
under scoped float64 (`jax.enable_x64` context — no global state), vmapped
over b. On the 12-region pruned graph a whole frontier stage solves in one
batched call.

Fixed iteration count (no data-dependent control flow) keeps the solve
jit/vmap-friendly; 40 iterations is ~3x the typical convergence point of
the numpy solver on these problems. Each LP iteration LU-factorizes the
normal matrix once and reuses the factor for the predictor and corrector
solves. Batch sizes are padded up to power-of-two buckets so the jit cache
holds a handful of entries instead of one per sample count.

The numpy solver (ipm.py) remains the reference. ``solve_lp_batched``
reports a per-sample KKT check; ``ipm_batch.solve_lp_batched_with_fallback``
re-solves the failing samples with the numpy IPM. ``planner.pareto_frontier(
backend="jax")`` / ``planner.plan_cost_min(..., backend="jax")`` reach this
engine through ``ipm_batch``'s dispatch: it is selected when jax has an
accelerator backend, while CPU-only hosts use the stacked-LAPACK numpy
engine instead (XLA's CPU triangular/LU solve lowering is 20-30x slower
than LAPACK on these problem sizes — measured, see ipm_batch.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

_EPS = 1e-11
_KKT_TOL = 1e-7


def _build_standard(c, A_ub, A_eq):
    """Standard-form matrix [A_ub I; A_eq 0] and extended objective."""
    n = c.shape[0]
    m_ub = A_ub.shape[0] if A_ub is not None and A_ub.size else 0
    m_eq = A_eq.shape[0] if A_eq is not None and A_eq.size else 0
    A = np.zeros((m_ub + m_eq, n + m_ub))
    if m_ub:
        A[:m_ub, :n] = A_ub
        A[:m_ub, n:] = np.eye(m_ub)
    if m_eq:
        A[m_ub:, :n] = A_eq
    cs = np.concatenate([c, np.zeros(m_ub)])
    return A, cs, m_ub, m_eq


@functools.partial(jax.jit, static_argnames=("iters", "n_slack"))
def _solve_batched(A, bs, c, iters: int = 40, n_slack: int = 0):
    """min c@x s.t. A@x=b_i, x>=0 for a batch of b vectors. f64 inside."""
    m, n = A.shape
    eye = jnp.eye(m)
    nc = n - n_slack
    core = A[:, :nc]
    sl = jnp.arange(n_slack)
    slack_diag = A[sl, nc + sl] if n_slack else None

    def normal_matrix(d):
        # A D A^T; the slack identity block only contributes to the diagonal
        M = (core * d[None, :nc]) @ core.T
        if n_slack:
            M = M.at[sl, sl].add(slack_diag * slack_diag * d[nc:])
        return M

    def reg_lu(M):
        tr = jnp.trace(M) / m
        return jax.scipy.linalg.lu_factor(M + 1e-11 * tr * eye)

    # the starting-point factor depends only on A: hoisted out of the vmap
    lu0 = reg_lu(normal_matrix(jnp.ones(n)))
    y0 = jax.scipy.linalg.lu_solve(lu0, A @ c)
    s0 = c - A.T @ y0

    def one(b):
        x = A.T @ jax.scipy.linalg.lu_solve(lu0, b)
        y = y0
        s = s0
        dx = jnp.maximum(-1.5 * jnp.min(x), 0.0)
        ds = jnp.maximum(-1.5 * jnp.min(s), 0.0)
        x = x + dx
        s = s + ds
        xs = jnp.maximum(x @ s, 1e-2)
        x = jnp.maximum(x + 0.5 * xs / jnp.maximum(s.sum(), _EPS), 1e-4)
        s = jnp.maximum(s + 0.5 * xs / jnp.maximum(x.sum(), _EPS), 1e-4)

        def step(carry, _):
            x, y, s = carry
            rb = A @ x - b
            rc = A.T @ y + s - c
            mu = (x @ s) / n
            d = x / s
            # one factorization serves the predictor and corrector solves
            lu = reg_lu(normal_matrix(d))

            r_xs = x * s
            rhs = -rb - A @ (d * rc - r_xs / s)
            dy_a = jax.scipy.linalg.lu_solve(lu, rhs)
            dx_a = d * (A.T @ dy_a + rc) - r_xs / s
            ds_a = -(r_xs + s * dx_a) / x

            def maxstep(v, dv):
                r = jnp.where(dv < 0, -v / jnp.where(dv < 0, dv, -1.0), jnp.inf)
                return jnp.minimum(1.0, jnp.min(r))

            ap = maxstep(x, dx_a)
            ad = maxstep(s, ds_a)
            mu_a = ((x + ap * dx_a) @ (s + ad * ds_a)) / n
            sigma = jnp.clip((mu_a / jnp.maximum(mu, _EPS)) ** 3, 0.0, 1.0)

            r_xs2 = x * s + dx_a * ds_a - sigma * mu
            rhs2 = -rb - A @ (d * rc - r_xs2 / s)
            dy = jax.scipy.linalg.lu_solve(lu, rhs2)
            dx = d * (A.T @ dy + rc) - r_xs2 / s
            dsv = -(r_xs2 + s * dx) / x

            ap = 0.99 * maxstep(x, dx)
            ad = 0.99 * maxstep(s, dsv)
            x2 = jnp.maximum(x + ap * dx, _EPS)
            y2 = y + ad * dy
            s2 = jnp.maximum(s + ad * dsv, _EPS)
            return (x2, y2, s2), None

        (x, y, s), _ = jax.lax.scan(step, (x, y, s), None, length=iters)
        pres = jnp.linalg.norm(A @ x - b) / (1.0 + jnp.linalg.norm(b))
        dres = jnp.linalg.norm(A.T @ y + s - c) / (1.0 + jnp.linalg.norm(c))
        gap = (x @ s) / (1.0 + jnp.abs(c @ x))
        return x, c @ x, pres, gap, dres

    return jax.vmap(one)(bs)


def _bucket(n: int) -> int:
    """Next power of two >= n: keeps the jit cache to a few batch shapes."""
    b = 1
    while b < n:
        b *= 2
    return b


def solve_lp_batched(c, A_ub, b_ub_batch, A_eq, b_eq, *, iters: int = 40):
    """Solve a batch of LPs sharing (c, A_ub, A_eq) but differing in RHS.

    b_ub_batch: [B, m_ub]; b_eq may be [m_eq] (shared) or [B, m_eq] (e.g.
    per-sample pinned-variable shifts). Returns (x [B, n], fun [B], ok [B])
    where ok is a per-sample KKT check (primal/dual residuals + gap).
    """
    with enable_x64():
        c = np.asarray(c, np.float64)
        A, cs, m_ub, m_eq = _build_standard(
            c,
            np.asarray(A_ub, np.float64),
            np.asarray(A_eq, np.float64) if A_eq is not None else None,
        )
        b_ub_batch = np.asarray(b_ub_batch, np.float64)
        B = b_ub_batch.shape[0]
        bs = np.zeros((B, m_ub + m_eq))
        bs[:, :m_ub] = b_ub_batch
        if m_eq:
            bs[:, m_ub:] = np.asarray(b_eq, np.float64)  # broadcasts [m_eq]/[B,m_eq]
        pad = _bucket(B) - B
        if pad:
            bs = np.concatenate([bs, np.tile(bs[:1], (pad, 1))], axis=0)
        x, fun, pres, gap, dres = _solve_batched(
            jnp.asarray(A), jnp.asarray(bs), jnp.asarray(cs),
            iters=iters, n_slack=m_ub,
        )
        x = np.asarray(x)[:B, : c.shape[0]]
        pres, gap, dres = (np.asarray(a)[:B] for a in (pres, gap, dres))
        ok = (
            (pres < _KKT_TOL) & (gap < _KKT_TOL) & (dres < _KKT_TOL)
            & np.isfinite(pres) & np.isfinite(gap) & np.isfinite(dres)
        )
        return x, np.asarray(fun)[:B], ok


