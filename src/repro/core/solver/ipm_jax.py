"""Batched JAX interior-point LP solver for Pareto-frontier sweeps.

The paper's §5.2 throughput-max mode solves ~100 cost-min LPs at different
throughput goals. Those LPs share every matrix except the two goal rows of
b — a textbook vmap: one fixed-iteration Mehrotra predictor-corrector,
jitted under scoped float64 (`jax.enable_x64` context — no global state),
vmapped over b. On the 12-region pruned graph the whole frontier solves in
one batched call.

Fixed iteration count (no data-dependent control flow) keeps the solve
jit/vmap-friendly; 40 iterations is ~3x the typical convergence point of
the numpy solver on these problems. The numpy solver (ipm.py) remains the
reference; `planner.pareto_frontier(backend="jax")` uses this one and
falls back per-sample when a batched solve fails its KKT check.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

_EPS = 1e-11


def _build_standard(c, A_ub, b_ub, A_eq, b_eq):
    n = c.shape[0]
    m_ub = A_ub.shape[0] if A_ub is not None and A_ub.size else 0
    m_eq = A_eq.shape[0] if A_eq is not None and A_eq.size else 0
    A = np.zeros((m_ub + m_eq, n + m_ub))
    b = np.zeros(m_ub + m_eq)
    if m_ub:
        A[:m_ub, :n] = A_ub
        A[:m_ub, n:] = np.eye(m_ub)
        b[:m_ub] = b_ub
    if m_eq:
        A[m_ub:, :n] = A_eq
        b[m_ub:] = b_eq
    cs = np.concatenate([c, np.zeros(m_ub)])
    return A, b, cs


@functools.partial(jax.jit, static_argnames=("iters",))
def _solve_batched(A, bs, c, iters: int = 40):
    """min c@x s.t. A@x=b_i, x>=0 for a batch of b vectors. f64 inside."""
    m, n = A.shape

    def reg_solve(M, rhs):
        tr = jnp.trace(M) / m
        return jnp.linalg.solve(M + 1e-11 * tr * jnp.eye(m), rhs)

    def one(b):
        AAt = A @ A.T
        x = A.T @ reg_solve(AAt, b)
        y = reg_solve(AAt, A @ c)
        s = c - A.T @ y
        dx = jnp.maximum(-1.5 * jnp.min(x), 0.0)
        ds = jnp.maximum(-1.5 * jnp.min(s), 0.0)
        x = x + dx
        s = s + ds
        xs = jnp.maximum(x @ s, 1e-2)
        x = jnp.maximum(x + 0.5 * xs / jnp.maximum(s.sum(), _EPS), 1e-4)
        s = jnp.maximum(s + 0.5 * xs / jnp.maximum(x.sum(), _EPS), 1e-4)

        def step(carry, _):
            x, y, s = carry
            rb = A @ x - b
            rc = A.T @ y + s - c
            mu = (x @ s) / n
            d = x / s
            AD = A * d[None, :]
            M = AD @ A.T

            r_xs = x * s
            rhs = -rb - A @ (d * rc - r_xs / s)
            dy_a = reg_solve(M, rhs)
            dx_a = d * (A.T @ dy_a + rc) - r_xs / s
            ds_a = -(r_xs + s * dx_a) / x

            def maxstep(v, dv):
                r = jnp.where(dv < 0, -v / jnp.where(dv < 0, dv, -1.0), jnp.inf)
                return jnp.minimum(1.0, jnp.min(r))

            ap = maxstep(x, dx_a)
            ad = maxstep(s, ds_a)
            mu_a = ((x + ap * dx_a) @ (s + ad * ds_a)) / n
            sigma = jnp.clip((mu_a / jnp.maximum(mu, _EPS)) ** 3, 0.0, 1.0)

            r_xs2 = x * s + dx_a * ds_a - sigma * mu
            rhs2 = -rb - A @ (d * rc - r_xs2 / s)
            dy = reg_solve(M, rhs2)
            dx = d * (A.T @ dy + rc) - r_xs2 / s
            dsv = -(r_xs2 + s * dx) / x

            ap = 0.99 * maxstep(x, dx)
            ad = 0.99 * maxstep(s, dsv)
            x2 = jnp.maximum(x + ap * dx, _EPS)
            y2 = y + ad * dy
            s2 = jnp.maximum(s + ad * dsv, _EPS)
            return (x2, y2, s2), None

        (x, y, s), _ = jax.lax.scan(step, (x, y, s), None, length=iters)
        pres = jnp.linalg.norm(A @ x - b) / (1.0 + jnp.linalg.norm(b))
        gap = (x @ s) / (1.0 + jnp.abs(c @ x))
        return x, c @ x, pres, gap

    return jax.vmap(one)(bs)


def solve_lp_batched(c, A_ub, b_ub_batch, A_eq, b_eq, *, iters: int = 40):
    """Solve a batch of LPs differing only in b_ub. Returns
    (x [B, n], fun [B], ok [B] bool)."""
    with jax.enable_x64(True):
        A, b0, cs = _build_standard(
            np.asarray(c, np.float64),
            np.asarray(A_ub, np.float64), np.zeros(A_ub.shape[0]),
            np.asarray(A_eq, np.float64) if A_eq is not None else None,
            np.asarray(b_eq, np.float64) if b_eq is not None else None,
        )
        m_ub = A_ub.shape[0]
        bs = np.tile(b0[None, :], (len(b_ub_batch), 1))
        bs[:, :m_ub] = np.asarray(b_ub_batch, np.float64)
        x, fun, pres, gap = _solve_batched(
            jnp.asarray(A), jnp.asarray(bs), jnp.asarray(cs), iters=iters
        )
        x = np.asarray(x)[:, : c.shape[0]]
        ok = (np.asarray(pres) < 1e-7) & (np.asarray(gap) < 1e-7)
        return x, np.asarray(fun), ok
