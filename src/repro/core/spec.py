"""PlanSpec — the single planning-request shape behind ``Planner.plan``.

Eight historically-grown entry points (``plan_cost_min``, ``plan_tput_max``,
their multicast twins, the two throughput bounds and the two Pareto sweeps)
accreted inconsistent kwargs. A ``PlanSpec`` names the request once:

  * ``objective`` — what to optimize: ``"cost_min"`` (minimize $ subject to
    a throughput floor), ``"tput_max"`` (maximize throughput under a cost
    ceiling), ``"max_throughput"`` (LP capacity bound, returns a float),
    ``"pareto"`` / ``"pareto_fast"`` (frontier sweeps, return ParetoPoints).
  * ``dst`` vs ``dsts`` — exactly one is set; ``dsts`` selects the
    multicast (one-to-many envelope) formulation.
  * the shared constraint vocabulary — ``robustness`` (belief LCB z),
    ``degraded_links`` / ``vm_caps`` (fault cuts), ``tput_scale`` (explicit
    per-link grid scale), ``agg_scale`` (per-link aggregate share caps, the
    fleet controller's fair-share rows) — all of which ride CACHED
    LPStructures as extra rows; no spec field ever re-assembles an LP.

The spec is frozen: mapping arguments are normalized to sorted item tuples
at construction (so two specs built from equal dicts compare equal), and
array fields are kept as-is (specs carrying grids are not hashable, which
is fine — they are request objects, not cache keys).
"""

from __future__ import annotations

import dataclasses
from collections.abc import Mapping, Sequence

import numpy as np

OBJECTIVES = ("cost_min", "tput_max", "max_throughput", "pareto", "pareto_fast")


def _freeze_items(m) -> tuple | None:
    """dict -> sorted item tuple; tuples pass through; None stays None."""
    if m is None:
        return None
    if isinstance(m, Mapping):
        return tuple(sorted(m.items()))
    return tuple(m)


@dataclasses.dataclass(frozen=True)
class PlanSpec:
    """One planning request. See module docstring for the vocabulary."""

    objective: str
    src: str
    dst: str | None = None
    dsts: tuple[str, ...] | None = None
    volume_gb: float = 0.0
    # cost_min: the throughput floor (scalar, or per-destination sequence
    # for multicast — zeros drop a destination from the trees)
    tput_goal_gbps: float | tuple[float, ...] = 0.0
    # tput_max: the price ceiling the fastest plan must fit under
    cost_ceiling_per_gb: float | None = None
    # sweep resolution for tput_max / pareto objectives (None = per-
    # objective default: 40 unicast, 12 multicast, 64 pareto_fast)
    n_samples: int | None = None
    mode: str | None = None  # None = planner default ("relaxed" or "exact")
    backend: str = "numpy"  # "numpy" | "jax" (batched round-down sweep)
    robustness: float = 0.0  # belief LCB z (needs a belief on the Planner)
    # fault cuts, full-topology indices: {(src_region, dst_region): phi}
    # and {region: vm_ceiling} — normalized to sorted item tuples
    degraded_links: tuple[tuple[tuple[int, int], float], ...] | None = None
    vm_caps: tuple[tuple[int, float], ...] | None = None
    tput_scale: np.ndarray | None = None  # explicit full-grid [V,V] scale
    # per-link aggregate share caps, full-grid [V,V] (non-finite =
    # uncapped): the fleet's weighted fair shares as scale-cut rows
    agg_scale: np.ndarray | None = None

    def __post_init__(self):
        if self.objective not in OBJECTIVES:
            raise ValueError(
                f"unknown objective {self.objective!r} (one of {OBJECTIVES})"
            )
        if (self.dst is None) == (self.dsts is None):
            raise ValueError("exactly one of dst / dsts must be set")
        if self.dsts is not None:
            if self.objective in ("pareto", "pareto_fast"):
                raise ValueError(f"{self.objective} is unicast-only (use dst)")
            object.__setattr__(self, "dsts", tuple(self.dsts))
            if not self.dsts:
                raise ValueError("dsts must be non-empty")
        tg = self.tput_goal_gbps
        if isinstance(tg, np.ndarray):
            tg = float(tg) if tg.ndim == 0 else tuple(float(g) for g in tg)
        elif isinstance(tg, Sequence):
            tg = tuple(float(g) for g in tg)
        else:
            tg = float(tg)
        object.__setattr__(self, "tput_goal_gbps", tg)
        if self.objective == "tput_max" and self.cost_ceiling_per_gb is None:
            raise ValueError("tput_max needs cost_ceiling_per_gb")
        object.__setattr__(
            self, "degraded_links", _freeze_items(self.degraded_links)
        )
        object.__setattr__(self, "vm_caps", _freeze_items(self.vm_caps))

    # ------------------------------------------------------------- accessors
    @property
    def multicast(self) -> bool:
        return self.dsts is not None

    @property
    def degraded_links_map(self) -> dict[tuple[int, int], float] | None:
        return dict(self.degraded_links) if self.degraded_links else None

    @property
    def vm_caps_map(self) -> dict[int, float] | None:
        return dict(self.vm_caps) if self.vm_caps else None

    def goals(self) -> np.ndarray | float:
        """Multicast floors as an array; the scalar unicast floor as-is."""
        if self.multicast:
            g = np.asarray(self.tput_goal_gbps, dtype=float)
            if g.ndim == 0:
                g = np.full(len(self.dsts), float(g))
            return g
        return float(self.tput_goal_gbps)
