"""Region topology: the flow-network graph over which Skyplane plans (paper §3.1).

Nodes are cloud regions; the two grids attached to the graph are exactly the
paper's inputs:
  * throughput grid  — achievable TCP goodput (Gbps) between each ordered region
    pair, measured at ``limit_conn`` parallel connections (paper §3.2, Fig. 3).
  * price grid       — egress $/GB between each ordered region pair (paper §2).

Per-region constants mirror Table 1: per-VM ingress/egress limits (Gbps), VM
price ($/s) and the per-region VM service limit.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

GBIT_PER_GB = 8.0  # egress prices are $/GB; flows are Gbit/s


@dataclasses.dataclass(frozen=True)
class Region:
    """A cloud region (one node of the overlay graph)."""

    provider: str  # "aws" | "azure" | "gcp"
    name: str  # provider-native region name, e.g. "us-west-2"
    continent: str  # "na" | "sa" | "eu" | "ap" | "af" | "oc" | "me"
    lat: float
    lon: float

    @property
    def key(self) -> str:
        return f"{self.provider}:{self.name}"

    def __str__(self) -> str:  # pragma: no cover - repr sugar
        return self.key


@dataclasses.dataclass
class Topology:
    """The overlay flow network. All arrays are ordered like ``regions``."""

    regions: list[Region]
    tput: np.ndarray  # [V,V] Gbps at limit_conn connections; 0 on diagonal
    price_egress: np.ndarray  # [V,V] $/GB for traffic u->v; 0 on diagonal
    price_vm: np.ndarray  # [V] $/s per VM
    limit_ingress: np.ndarray  # [V] Gbps per VM
    limit_egress: np.ndarray  # [V] Gbps per VM
    rtt_ms: np.ndarray | None = None  # [V,V] used by the RON baseline
    limit_conn: int = 64  # max TCP connections per VM (paper §4.2)
    limit_vm: int = 8  # per-region VM service limit (paper §7.2 uses 8)

    def __post_init__(self) -> None:
        v = len(self.regions)
        assert self.tput.shape == (v, v), self.tput.shape
        assert self.price_egress.shape == (v, v)
        assert self.price_vm.shape == (v,)
        assert self.limit_ingress.shape == (v,)
        assert self.limit_egress.shape == (v,)
        self._index = {r.key: i for i, r in enumerate(self.regions)}
        # derived-data caches (edge lists, LP structures). Keyed per instance:
        # mutate the grids only by building a new Topology (dataclasses.replace
        # re-runs __post_init__ and starts these fresh). The grids themselves
        # are frozen COPIES — an in-place write to ``tput`` after an
        # LPStructure was cached would silently desynchronize every cached
        # constraint matrix, so mutation raises and ``with_tput`` is the
        # sanctioned path. Copying first keeps the freeze from leaking into
        # arrays the caller still owns (already-frozen inputs, e.g. from
        # dataclasses.replace, are shared as-is).
        for name in ("tput", "price_egress", "price_vm",
                     "limit_ingress", "limit_egress", "rtt_ms"):
            arr = getattr(self, name)
            if arr is not None and arr.flags.writeable:
                arr = arr.copy()
                arr.setflags(write=False)
                setattr(self, name, arr)
        self._edge_cache: dict = {}
        self._lp_struct_cache: dict = {}

    def with_tput(
        self,
        tput: np.ndarray | None = None,
        *,
        scale: np.ndarray | float | None = None,
    ) -> "Topology":
        """Copy-on-write grid swap: a NEW Topology with ``tput`` (or the
        current grid times ``scale``) and fresh derived-data caches.

        This is the only sanctioned way to change a topology's throughput
        grid — the arrays are frozen in ``__post_init__`` because planner
        caches (edge lists, LP structures) key off topology *identity* and
        an in-place write would poison them. The calibration plane uses
        this for both sides of its split view: the drift model's
        time-indexed true grids and the belief's estimated grid."""
        if (tput is None) == (scale is None):
            raise ValueError("pass exactly one of tput= or scale=")
        if tput is None:
            new = self.tput * scale
        else:
            new = np.array(tput, dtype=float, copy=True)
        new.setflags(write=False)  # already a private copy: freeze directly
        return dataclasses.replace(self, tput=new)

    # ------------------------------------------------------------------ utils
    @property
    def num_regions(self) -> int:
        return len(self.regions)

    def index(self, region: str | Region) -> int:
        key = region.key if isinstance(region, Region) else region
        return self._index[key]

    def keys(self) -> list[str]:
        return [r.key for r in self.regions]

    def subgraph(self, keep: Sequence[int]) -> "Topology":
        """Topology restricted to region indices ``keep`` (order preserved)."""
        keep = list(keep)
        ix = np.asarray(keep, dtype=np.int64)
        return Topology(
            regions=[self.regions[i] for i in keep],
            tput=self.tput[np.ix_(ix, ix)].copy(),
            price_egress=self.price_egress[np.ix_(ix, ix)].copy(),
            price_vm=self.price_vm[ix].copy(),
            limit_ingress=self.limit_ingress[ix].copy(),
            limit_egress=self.limit_egress[ix].copy(),
            rtt_ms=None if self.rtt_ms is None else self.rtt_ms[np.ix_(ix, ix)].copy(),
            limit_conn=self.limit_conn,
            limit_vm=self.limit_vm,
        )

    def candidate_subgraph(
        self, src: str, dst: str, max_relays: int = 10
    ) -> tuple["Topology", int, int]:
        """Prune to {src, dst} + the ``max_relays`` most promising relays.

        Relays are ranked by the bottleneck throughput of the two-hop path
        src->r->dst (the quantity RON's throughput heuristic optimizes), which
        upper-bounds the usefulness of a region as a relay. Keeps the MILP tiny
        (paper §5: "solved in under 5 seconds") without excluding any relay the
        optimum could plausibly use.
        """
        s, t = self.index(src), self.index(dst)
        v = self.num_regions
        scores = np.minimum(self.tput[s, :], self.tput[:, t])
        scores[[s, t]] = -np.inf
        order = np.argsort(-scores)
        relays = [int(i) for i in order[:max_relays] if np.isfinite(scores[i])]
        keep = [s, t] + relays
        sub = self.subgraph(keep)
        return sub, 0, 1

    def edge_list(
        self, src_idx: int | None = None, dst_idx: int | None = None
    ) -> list[tuple[int, int]]:
        """Directed edges with nonzero capacity. Drops edges into the source
        and out of the destination (never useful for a single s->t job).

        Cached per (src_idx, dst_idx); callers must treat the result as
        read-only.
        """
        key = (src_idx, dst_idx)
        cached = self._edge_cache.get(key)
        if cached is not None:
            return cached
        mask = self.tput > 0
        np.fill_diagonal(mask, False)
        if src_idx is not None:
            mask[:, src_idx] = False
        if dst_idx is not None:
            mask[dst_idx, :] = False
        edges = [(int(u), int(w)) for u, w in np.argwhere(mask)]
        self._edge_cache[key] = edges
        return edges


def haversine_km(lat1, lon1, lat2, lon2) -> float:
    """Great-circle distance, used to synthesize RTTs for the embedded grid."""
    r = 6371.0
    p1, p2 = np.radians(lat1), np.radians(lat2)
    dp = p2 - p1
    dl = np.radians(lon2 - lon1)
    a = np.sin(dp / 2) ** 2 + np.cos(p1) * np.cos(p2) * np.sin(dl / 2) ** 2
    return float(2 * r * np.arcsin(np.sqrt(a)))
