from .pipeline import PipelineState, ShardedTokenPipeline  # noqa: F401
from .placement import plan_shard_sources  # noqa: F401
