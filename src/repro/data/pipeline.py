"""Deterministic sharded data pipeline with prefetch and exact resume.

Shards are synthetic (seeded by (seed, shard_index)) — the pool brief stubs
modality frontends, and training examples need reproducible token streams.
The pipeline state (next shard index) is part of the checkpoint, so restart
resumes the stream exactly. A background prefetch thread hides generation
latency (the straggler-mitigation analog at the input layer).
"""

from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class PipelineState:
    next_shard: int = 0
    epoch: int = 0


class ShardedTokenPipeline:
    def __init__(
        self,
        cfg: ModelConfig,
        *,
        global_batch: int,
        seq_len: int,
        num_shards: int = 1024,
        seed: int = 0,
        prefetch: int = 2,
        state: PipelineState | None = None,
    ):
        self.cfg = cfg
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.num_shards = num_shards
        self.seed = seed
        self.state = state or PipelineState()
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()

    # ------------------------------------------------------------- generation
    def _gen(self, shard: int, epoch: int) -> dict:
        """Synthetic but *learnable* stream: with prob 0.8 the next token
        follows a fixed affine bigram rule, else it's uniform noise. A model
        that learns the rule reaches ~0.2*log V + H(0.8) nats, well below the
        uniform-entropy floor — so training-loss assertions mean something."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, epoch, shard])
        )
        b, s = self.global_batch, self.seq_len
        v = self.cfg.vocab_size
        toks = np.empty((b, s + 1), dtype=np.int32)
        toks[:, 0] = rng.integers(0, v, size=b)
        noise = rng.random((b, s)) < 0.2
        randoms = rng.integers(0, v, size=(b, s), dtype=np.int32)
        for t in range(1, s + 1):
            rule = (toks[:, t - 1] * 7 + 13) % v
            toks[:, t] = np.where(noise[:, t - 1], randoms[:, t - 1], rule)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.cfg.is_vlm:
            batch["vision"] = rng.standard_normal(
                (b, self.cfg.num_vision_tokens, self.cfg.d_model), dtype=np.float32
            )
        if self.cfg.is_enc_dec:
            batch["frames"] = rng.standard_normal(
                (b, self.cfg.num_frames, self.cfg.d_model), dtype=np.float32
            )
        return batch

    # --------------------------------------------------------------- prefetch
    def _worker(self):
        st = PipelineState(self.state.next_shard, self.state.epoch)
        while not self._stop.is_set():
            batch = self._gen(st.next_shard, st.epoch)
            meta = PipelineState(st.next_shard, st.epoch)
            st.next_shard += 1
            if st.next_shard >= self.num_shards:
                st.next_shard = 0
                st.epoch += 1
            while not self._stop.is_set():
                try:
                    self._q.put((meta, batch), timeout=0.2)
                    break
                except queue.Full:
                    continue

    def start(self):
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None
        while not self._q.empty():
            self._q.get_nowait()

    def __next__(self) -> dict:
        if self._thread is None:
            batch = self._gen(self.state.next_shard, self.state.epoch)
            self._advance()
            return batch
        meta, batch = self._q.get()
        # consumed shard `meta`; the resume point is the one after it
        self.state = PipelineState(meta.next_shard, meta.epoch)
        self._advance()
        return batch

    def _advance(self):
        ns = self.state.next_shard + 1
        ep = self.state.epoch
        if ns >= self.num_shards:
            ns, ep = 0, ep + 1
        self.state = PipelineState(ns, ep)

    def __iter__(self):
        return self

    # ----------------------------------------------------------------- resume
    def state_dict(self) -> dict:
        return {"next_shard": self.state.next_shard, "epoch": self.state.epoch}

    def load_state_dict(self, d: dict):
        self.stop()
        self.state = PipelineState(int(d["next_shard"]), int(d["epoch"]))
