"""Skyplane-planned dataset staging: which replica each consumer pulls a
shard from, and over which overlay route (paper technique -> input layer).
"""

from __future__ import annotations

import dataclasses

from repro.core.planner import Planner
from repro.core.spec import PlanSpec
from repro.core.topology import Topology


@dataclasses.dataclass
class ShardSource:
    shard: int
    source_region: str
    plan_tput_gbps: float
    plan_cost_per_gb: float
    relay_regions: list


def plan_shard_sources(
    top: Topology,
    shard_replicas: dict[int, list[str]],
    consumer_region: str,
    *,
    shard_gb: float = 1.0,
    tput_floor_gbps: float = 2.0,
    max_relays: int = 6,
) -> list[ShardSource]:
    """For each shard, pick the replica + overlay route minimizing $/GB
    subject to a bandwidth floor (Skyplane cost-min mode per source)."""
    planner = Planner(top, max_relays=max_relays)
    out = []
    plan_cache: dict[str, tuple] = {}
    for shard, replicas in sorted(shard_replicas.items()):
        best = None
        for src in replicas:
            if src == consumer_region:
                best = (0.0, src, 1e9, [])
                break
            if src not in plan_cache:
                goal = min(
                    tput_floor_gbps,
                    planner.plan(PlanSpec(
                        objective="max_throughput", src=src,
                        dst=consumer_region,
                    )) * 0.9,
                )
                if goal <= 0:
                    continue
                plan = planner.plan(PlanSpec(
                    objective="cost_min", src=src, dst=consumer_region,
                    tput_goal_gbps=goal, volume_gb=shard_gb,
                ))
                relays = sorted(
                    {r for path, _ in plan.paths() for r in path[1:-1]}
                )
                plan_cache[src] = (
                    plan.cost_per_gb, plan.throughput,
                    [top.keys()[r] for r in relays],
                )
            cost, tput, relays = plan_cache[src]
            if best is None or cost < best[0]:
                best = (cost, src, tput, relays)
        if best is None:
            raise ValueError(f"no reachable replica for shard {shard}")
        cost, src, tput, relays = best
        out.append(ShardSource(shard, src, tput, cost, relays))
    return out
