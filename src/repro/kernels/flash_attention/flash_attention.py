"""Blockwise online-softmax (Flash) attention Pallas kernel, GQA-aware,
causal and sliding-window, TPU-tiled.

Grid: (batch, q_heads, nq, nkv) — TPU iterates the minor-most axis fastest,
so for a fixed (b, h, iq) the kernel sees all kv blocks sequentially and
carries the online-softmax state (m, l, acc) in VMEM scratch, initialized at
the first visited kv block and flushed to the output on the last. Causal and
window masking are applied per-tile with iota; fully-masked tiles are
skipped with @pl.when (on TPU this saves the MXU work; block-level skipping
of out-of-window tiles is what makes SWA sub-quadratic here).

Block shapes default to (block_q, head_dim) x (block_k, head_dim) =
(128, Dh) tiles — MXU-aligned (multiples of 128 on the contracting dim for
Dh in {64, 112, 128, 192} pad to lanes) and sized so q/k/v/acc tiles fit
comfortably in ~16 MB VMEM.

Layouts (prepared by ops.py): q [B, H, S, Dh], k/v [B, Kv, S, Dh],
out [B, H, S, Dh].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, seq_len: int, causal: bool,
                  window: int | None, scale: float, n_kv_blocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    q_start = iq * block_q
    k_start = ik * block_k

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _compute():
        q_ids = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_ids = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        q = q_ref[0, 0].astype(jnp.float32)  # [block_q, d]
        k = k_ref[0, 0].astype(jnp.float32)  # [block_k, d]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # [block_q, block_k]
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, k_ids <= q_ids)
        if window is not None:
            mask = jnp.logical_and(mask, k_ids > q_ids - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]  # [block_q, 1]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new
        l_scr[...] = l_new

    # tile-level reachability: skip fully-masked tiles (this block-skip is
    # what makes sliding-window attention sub-quadratic on TPU)
    if causal or window is not None:
        reachable = k_start <= q_start + block_q - 1 if causal else (ik >= 0)
        if window is not None:
            reachable = jnp.logical_and(
                reachable, k_start + block_k - 1 > q_start - window
            )
        pl.when(reachable)(_compute)
    else:
        _compute()

    @pl.when(ik == n_kv_blocks - 1)
    def _flush():
        l = l_scr[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_scr[...] / safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k",
                     "q_per_kv", "interpret"),
)
def flash_attention_bhsd(
    q, k, v, *,
    q_per_kv: int,
    causal: bool = True,
    window: int | None = None,
    scale: float = 1.0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
):
    """q: [B,H,S,D], k/v: [B,Kv,S,D] -> out [B,H,S,D]."""
    b, h, s, d = q.shape
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    nq = s // block_q
    nkv = s // block_k
    kernel = functools.partial(
        _flash_kernel,
        block_q=block_q, block_k=block_k, seq_len=s, causal=causal,
        window=window, scale=scale, n_kv_blocks=nkv,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, iq, ik, qpk=q_per_kv: (b_, h_ // qpk, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, iq, ik, qpk=q_per_kv: (b_, h_ // qpk, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            _vmem((block_q, 1)),
            _vmem((block_q, 1)),
            _vmem((block_q, d)),
        ],
        interpret=interpret,
    )(q, k, v)


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)
