"""Public flash-attention wrapper in model layout [B,S,H,D]; handles GQA
head mapping, seq padding to block multiples, and interpret-mode fallback
off-TPU."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_bhsd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    scale: float | None = None, block_q: int = 128,
                    block_k: int = 128):
    """q: [B,S,H,D], k/v: [B,S,Kv,D] -> [B,S,H,D]."""
    b, s, h, d = q.shape
    kvh = k.shape[2]
    assert h % kvh == 0, (h, kvh)
    scale = d ** -0.5 if scale is None else scale
    bq = min(block_q, max(16, s))
    bk = min(block_k, max(16, s))
    pad = (-s) % max(bq, bk)
    qt = jnp.moveaxis(q, 2, 1)  # [B,H,S,D]
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pad:
        cfgpad = ((0, 0), (0, 0), (0, pad), (0, 0))
        qt = jnp.pad(qt, cfgpad)
        kt = jnp.pad(kt, cfgpad)
        vt = jnp.pad(vt, cfgpad)
    out = flash_attention_bhsd(
        qt, kt, vt, q_per_kv=h // kvh, causal=causal, window=window,
        scale=scale, block_q=bq, block_k=bk, interpret=_interpret(),
    )
    if pad:
        out = out[:, :, :s]
    return jnp.moveaxis(out, 1, 2)
