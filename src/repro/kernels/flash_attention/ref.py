"""Pure-jnp oracle for the flash attention kernel (GQA, causal, window)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_bhsd_ref(q, k, v, *, q_per_kv: int, causal: bool = True,
                       window: int | None = None, scale: float = 1.0):
    """q: [B,H,S,D], k/v: [B,Kv,S,D] -> [B,H,S,D], f32 softmax."""
    b, h, s, d = q.shape
    kvh = k.shape[1]
    qg = q.reshape(b, kvh, q_per_kv, s, d)
    scores = jnp.einsum(
        "bkgqd,bksd->bkgqs", qg, k, preferred_element_type=jnp.float32
    ) * scale
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", w.astype(v.dtype), v)
    return out.reshape(b, h, s, d)
