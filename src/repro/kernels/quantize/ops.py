"""jit'd public wrappers: arbitrary-shape tensors <-> blocked kernel layout.
On non-TPU backends the kernel runs in interpret mode (exact semantics)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .quantize import dequantize_int8_2d, quantize_int8_2d

_ROWS = 8


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def quantize_int8(x, *, block: int = 256):
    """x: any shape -> (q int8 [x.shape], scales f32 [n_blocks])."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad_elems = (-n) % block
    flat = jnp.pad(flat, (0, pad_elems))
    x2d = flat.reshape(-1, block)
    pad_rows = (-x2d.shape[0]) % _ROWS
    x2d = jnp.pad(x2d, ((0, pad_rows), (0, 0)))
    q2d, s2d = quantize_int8_2d(x2d, block=block, rows=_ROWS,
                                interpret=_interpret())
    n_blocks = (n + block - 1) // block
    q = q2d.reshape(-1)[:n].reshape(x.shape)
    return q, s2d[:n_blocks, 0]


def dequantize_int8(q, scales, *, block: int = 256):
    """Inverse of quantize_int8; returns f32 of q.shape."""
    flat = q.reshape(-1)
    n = flat.shape[0]
    pad_elems = (-n) % block
    flat = jnp.pad(flat, (0, pad_elems))
    q2d = flat.reshape(-1, block)
    s2d = scales.reshape(-1, 1)
    pad_rows = (-q2d.shape[0]) % _ROWS
    q2d = jnp.pad(q2d, ((0, pad_rows), (0, 0)))
    s2d = jnp.pad(s2d, ((0, q2d.shape[0] - s2d.shape[0]), (0, 0)),
                  constant_values=1.0)
    x2d = dequantize_int8_2d(q2d, s2d, block=block, rows=_ROWS,
                             interpret=_interpret())
    return x2d.reshape(-1)[:n].reshape(q.shape)
