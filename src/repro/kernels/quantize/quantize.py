"""Per-block symmetric int8 quantization Pallas kernel.

Hot path of the inter-pod gradient compressor (transfer.compression): each
VMEM tile of ``rows`` x ``block`` values is reduced (absmax), scaled and
rounded on-chip, so HBM sees one read of the f32 tensor and one write of
the int8 payload + scales. Tiles are (8, 256) by default — lane-aligned
(256 = 2*128) and sublane-aligned (8) for the VPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)  # [rows, block]
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)  # [rows, 1]
    scale = jnp.where(absmax > 0.0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...]


@functools.partial(jax.jit, static_argnames=("block", "rows", "interpret"))
def quantize_int8_2d(x2d, *, block: int = 256, rows: int = 8,
                     interpret: bool = False):
    """x2d: [n_blocks, block] f32 -> (q int8 [n_blocks, block],
    scales f32 [n_blocks, 1])."""
    n = x2d.shape[0]
    assert x2d.shape[1] == block and n % rows == 0, (x2d.shape, block, rows)
    grid = (n // rows,)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rows, block), lambda i: (i, 0)),
            pl.BlockSpec((rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, block), jnp.int8),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x2d)


@functools.partial(jax.jit, static_argnames=("block", "rows", "interpret"))
def dequantize_int8_2d(q2d, scales, *, block: int = 256, rows: int = 8,
                       interpret: bool = False):
    n = q2d.shape[0]
    grid = (n // rows,)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, block), lambda i: (i, 0)),
            pl.BlockSpec((rows, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, block), jnp.float32),
        interpret=interpret,
    )(q2d, scales)
