"""Pure-jnp oracle for the quantize kernel."""

from __future__ import annotations

import jax.numpy as jnp


def quantize_int8_2d_ref(x2d):
    x = x2d.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.where(absmax > 0.0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0).astype(jnp.int8)
    return q, scale


def dequantize_int8_2d_ref(q2d, scales):
    return q2d.astype(jnp.float32) * scales
