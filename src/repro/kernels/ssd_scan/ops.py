"""Public SSD wrapper in the model layout ([B,S,H,P]); interpret off-TPU."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .ssd_scan import ssd_scan_bhsp


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def ssd_scan(xh, dtv, a, bm, cm, *, chunk: int = 256):
    """Model layout: xh [B,S,H,P], dtv [B,S,H], a [H], bm/cm [B,S,N]
    -> (y [B,S,H,P], final_state [B,H,P,N]). Ragged tails padded with dt=0
    (identity for the recurrence), mirroring the jnp reference."""
    s_orig = xh.shape[1]
    chunk = min(chunk, s_orig)
    pad = (-s_orig) % chunk
    if pad:
        def zp(t, ax):
            return jnp.pad(t, [(0, pad) if i == ax else (0, 0)
                               for i in range(t.ndim)])
        xh, dtv = zp(xh, 1), zp(dtv, 1)
        bm, cm = zp(bm, 1), zp(cm, 1)
    x = jnp.moveaxis(xh, 2, 1)  # [B,H,S,P]
    dt = jnp.moveaxis(dtv, 2, 1)  # [B,H,S]
    y, state = ssd_scan_bhsp(
        x, dt, a, bm, cm, chunk=chunk, interpret=_interpret()
    )
    y = jnp.moveaxis(y, 1, 2)
    if pad:
        y = y[:, :s_orig]
    return y, state
