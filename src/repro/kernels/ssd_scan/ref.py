"""Self-contained pure-jnp oracle for the SSD scan kernel (mirrors
repro.models.ssm.ssd_chunked, in the kernel's [B,H,S,P] layout)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_bhsp_ref(x, dt, a, bm, cm, *, chunk: int):
    """x [B,H,S,P], dt [B,H,S], a [H], bm/cm [B,S,N]."""
    b, h, s, p = x.shape
    n = bm.shape[-1]
    nc = s // chunk
    xr = x.reshape(b, h, nc, chunk, p).astype(jnp.float32)
    dtr = dt.reshape(b, h, nc, chunk).astype(jnp.float32)
    br = bm.reshape(b, nc, chunk, n).astype(jnp.float32)
    cr = cm.reshape(b, nc, chunk, n).astype(jnp.float32)

    da = dtr * a[None, :, None, None]
    cum = jnp.cumsum(da, axis=-1)  # [b,h,nc,Q]
    diff = cum[..., :, None] - cum[..., None, :]
    ii = jnp.arange(chunk)
    mask = (ii[:, None] >= ii[None, :])[None, None, None]
    cb = jnp.einsum("bcin,bcjn->bcij", cr, br)
    scores = jnp.where(mask, cb[:, None] * jnp.exp(diff) * dtr[..., None, :], 0.0)
    y_intra = jnp.einsum("bhcij,bhcjp->bhcip", scores, xr)

    cum_last = cum[..., -1:]
    w_end = jnp.exp(cum_last - cum) * dtr
    s_chunk = jnp.einsum("bhcj,bhcjp,bcjn->bhcpn", w_end, xr, br)
    dec = jnp.exp(cum_last[..., 0])  # [b,h,nc]

    def step(carry, inp):
        sc, d = inp
        new = carry * d[..., None, None] + sc
        return new, carry

    s0 = jnp.zeros((b, h, p, n), jnp.float32)
    s_final, s_prevs = jax.lax.scan(
        step, s0, (s_chunk.transpose(2, 0, 1, 3, 4), dec.transpose(2, 0, 1))
    )
    s_prevs = s_prevs.transpose(1, 2, 0, 3, 4)  # [b,h,nc,p,n]
    y_inter = jnp.einsum("bcin,bhcpn->bhcip", cr, s_prevs) * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(b, h, s, p).astype(x.dtype)
    return y, s_final
