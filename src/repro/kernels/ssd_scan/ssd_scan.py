"""Mamba2 SSD chunked-scan Pallas kernel (state-space duality form).

Grid: (batch, heads, n_chunks) — chunks iterate minor-most, so the
inter-chunk SSD state [P, N] lives in VMEM scratch across the chunk loop
(initialized at chunk 0, emitted to the final-state output on the last
chunk). Per chunk the kernel computes, entirely on-chip:

  intra:  Y_intra = ((C B^T) . exp(cum_i - cum_j) . dt_j, masked i>=j) @ X
  inter:  Y_inter = (C @ S_prev^T) . exp(cum_i)
  state:  S_new   = S_prev * exp(cum_last) + X^T @ (B . dt . exp(cum_last - cum))

All decay exponents are <= 0 (A < 0, dt > 0), so every exp() is bounded by
1 — the f32 scratch state is numerically safe for arbitrarily long scans.

Tile sizes: chunk Q (default 256) x P (head dim, 64) x N (state, 64-128) —
the [Q, Q] intra-chunk score tile is the MXU workhorse.

Layouts (ops.py prepares): x [B,H,S,P], dt [B,H,S], a [H], Bm/Cm [B,S,N].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, state_scr,
                *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0].astype(jnp.float32)  # [Q, P]
    dt = dt_ref[0, 0].astype(jnp.float32)  # [Q]
    a = a_ref[0].astype(jnp.float32)  # scalar
    bm = b_ref[0].astype(jnp.float32)  # [Q, N]
    cm = c_ref[0].astype(jnp.float32)  # [Q, N]

    da = dt * a  # [Q], negative
    cum = jnp.cumsum(da)  # [Q]
    cum_last = cum[-1]

    # ---- intra-chunk quadratic term
    diff = cum[:, None] - cum[None, :]  # [Q, Q], <=0 on the causal triangle
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    mask = ii >= jj
    cb = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [Q, Q]
    scores = jnp.where(mask, cb * jnp.exp(diff) * dt[None, :], 0.0)
    y = jax.lax.dot_general(
        scores, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [Q, P]

    # ---- inter-chunk contribution from the carried state
    s_prev = state_scr[...]  # [P, N]
    y += jax.lax.dot_general(
        cm, s_prev, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * jnp.exp(cum)[:, None]

    # ---- state update
    w_end = jnp.exp(cum_last - cum) * dt  # [Q], <= dt
    xw = x * w_end[:, None]  # [Q, P]
    s_new = s_prev * jnp.exp(cum_last) + jax.lax.dot_general(
        xw, bm, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [P, N]
    state_scr[...] = s_new
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        st_ref[0, 0] = s_new.astype(st_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("chunk", "interpret")
)
def ssd_scan_bhsp(x, dt, a, bm, cm, *, chunk: int = 256,
                  interpret: bool = False):
    """x [B,H,S,P], dt [B,H,S], a [H], bm/cm [B,S,N] ->
    (y [B,H,S,P], final_state [B,H,P,N])."""
    b, h, s, p = x.shape
    n = bm.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc)
    return pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b_, h_, c_: (b_, h_, c_)),
            pl.BlockSpec((1,), lambda b_, h_, c_: (h_,)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, c_: (b_, c_, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, c_: (b_, c_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_, c_: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[_vmem((p, n))],
        interpret=interpret,
    )(x, dt, a, bm, cm)


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)
