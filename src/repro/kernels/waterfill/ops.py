"""Public water-filling wrapper: ragged conn sets -> padded kernel tiles.

Builds the one-hot scatter matrices, pads every axis to the f32 tile
grid (8 x 128), row-replicates the per-lane vectors, and flips the
kernel to interpret mode off-TPU. When link contention is disabled
(``ed_cap is None``) every connection is pinned to a single dummy edge
with a BIG budget — the edge term then can never bind (BIG / n_conns
still dwarfs any real VM share), which keeps the kernel free of
optional operands.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .waterfill import BIG, waterfill_8x


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad128(n: int) -> int:
    return max(128, -(-n // 128) * 128)


def waterfill_rates(caps, src, dst, eg_cap, in_cap, eid=None, ed_cap=None,
                    active=None, *, n_iters: int | None = None):
    """Max-min fair rates for connections (accelerator fast path).

    caps/src/dst [NC] with optional eid [NC] + ed_cap [NE] shared-edge
    budgets and an optional ``active`` lane mask; eg_cap/in_cap [NV].
    Returns f32 rates [NC], 0.0 on inactive lanes. f32-tolerance
    companion to ``ref.masked_maxmin_rates`` (the f64 parity oracle).
    """
    caps = np.asarray(caps, dtype=np.float32)
    src = np.asarray(src, dtype=np.int32)
    dst = np.asarray(dst, dtype=np.int32)
    nc = caps.shape[0]
    nv = int(eg_cap.shape[0])
    if active is None:
        active = np.ones(nc, dtype=bool)
    active = np.asarray(active, dtype=bool)
    if ed_cap is None:
        eid = np.zeros(nc, dtype=np.int32)
        ed_cap = np.full(1, BIG, dtype=np.float32)
    eid = np.asarray(eid, dtype=np.int32)
    ed_cap = np.asarray(ed_cap, dtype=np.float32)
    ne = ed_cap.shape[0]

    ncp, nvp, nep = _pad128(nc), _pad128(nv), _pad128(ne)
    actf = active.astype(np.float32)

    def onehot(idx, width):
        m = np.zeros((ncp, width), dtype=np.float32)
        m[np.arange(nc), idx] = actf
        return m

    def lane(vec, width, fill=0.0):
        row = np.full(width, fill, dtype=np.float32)
        row[: vec.shape[0]] = vec
        return np.broadcast_to(row, (8, width))

    s_src = onehot(src, nvp)
    s_dst = onehot(dst, nvp)
    s_ed = onehot(eid, nep)
    if n_iters is None:
        n_iters = 2 * nv + ne + 4
    rates8 = waterfill_8x(
        lane(caps, ncp), lane(actf, ncp),
        lane(np.asarray(eg_cap, dtype=np.float32), nvp, BIG),
        lane(np.asarray(in_cap, dtype=np.float32), nvp, BIG),
        lane(ed_cap, nep, BIG),
        jnp.asarray(s_src), jnp.asarray(s_src.T),
        jnp.asarray(s_dst), jnp.asarray(s_dst.T),
        jnp.asarray(s_ed), jnp.asarray(s_ed.T),
        n_iters=int(n_iters), interpret=_interpret(),
    )
    return np.asarray(rates8[0, :nc])
