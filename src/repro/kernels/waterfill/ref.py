"""Masked max-min water-filling — pure-jnp oracle for the Pallas kernel.

``masked_maxmin_rates`` is a full-array (masked) transliteration of
``flowsim._maxmin_rates_arr``: instead of compacting to the active
connections it runs the same iterative bottleneck-saturation rounds over
every padded lane, with inactive lanes pinned at rate 0 and excluded from
every count, share, threshold, and capacity subtraction. Under float64 it
is **bitwise identical** to the numpy oracle on the active lanes — every
round's arithmetic touches the same values in the same order (segment
sums add interspersed +0.0 weights, which cannot change an IEEE sum; the
masked threshold min pads with +inf, which never wins) — so
``flowsim_jax`` uses it as the parity-grade rate solver on CPU. The
Pallas kernel (``waterfill.py``) is the same algorithm in one-hot matmul
form for the accelerator, checked against this oracle in
``tests/test_kernels.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-12  # flowsim._EPS — saturation tolerance of the numpy oracle


def masked_maxmin_rates(caps, src, dst, eg_cap, in_cap, eid, ed_cap,
                        active, *, n_vms: int, n_edges: int,
                        n_edges_bound: int | None = None):
    """Max-min fair rates over the ``active`` lanes of a padded conn set.

    caps/src/dst/eid/active are per-connection lanes (padded); eg_cap and
    in_cap are per-VM egress/ingress budgets sized ``n_vms``; ed_cap is
    the shared per-edge budget sized ``n_edges`` or None when link
    contention is disabled. Returns per-lane rates, 0.0 on inactive
    lanes. Bitwise-equal to ``_maxmin_rates_arr`` on the active lanes
    under float64. ``n_edges_bound`` overrides the edge term of the
    round bound (callers that feed BIG edge budgets in place of "no
    contention" pass 0 so the trip count still matches the oracle's
    edge-free bound).
    """
    # The numpy oracle bounds its rounds by the *compacted* VM count; the
    # masked form recovers it from the active lanes so the trip count (and
    # therefore the clamp-to-zero history of the budgets) matches exactly.
    nv = jnp.max(jnp.where(active, jnp.maximum(src, dst), -1)) + 1
    if n_edges_bound is None:
        n_edges_bound = n_edges if ed_cap is not None else 0
    bound = 2 * nv + n_edges_bound + 4
    rate = jnp.zeros_like(caps)
    fixed = ~active

    def cond(c):
        k, rate, fixed, eg, inn, ed = c
        return (k < bound) & jnp.any(~fixed & active)

    def step(c):
        k, rate, fixed, eg, inn, ed = c
        un = active & ~fixed
        unf = un.astype(caps.dtype)
        cnt_out = jax.ops.segment_sum(unf, src, n_vms)
        cnt_in = jax.ops.segment_sum(unf, dst, n_vms)
        share_out = jnp.where(cnt_out > 0, eg / jnp.maximum(cnt_out, 1),
                              jnp.inf)
        share_in = jnp.where(cnt_in > 0, inn / jnp.maximum(cnt_in, 1),
                             jnp.inf)
        share = jnp.minimum(share_out[src], share_in[dst])
        if ed_cap is not None:
            cnt_ed = jax.ops.segment_sum(unf, eid, n_edges)
            share_ed = jnp.where(cnt_ed > 0, ed / jnp.maximum(cnt_ed, 1),
                                 jnp.inf)
            share = jnp.minimum(share, share_ed[eid])
        cap_hit = un & (caps <= share + _EPS)
        anyc = jnp.any(cap_hit)
        thresh = jnp.min(jnp.where(un, share, jnp.inf))
        newly = jnp.where(anyc, cap_hit, un & (share <= thresh + _EPS))
        rate = jnp.where(newly, jnp.where(anyc, caps, share), rate)
        w = jnp.where(newly, rate, 0.0)
        eg = jnp.maximum(eg - jax.ops.segment_sum(w, src, n_vms), 0.0)
        inn = jnp.maximum(inn - jax.ops.segment_sum(w, dst, n_vms), 0.0)
        if ed_cap is not None:
            ed = jnp.maximum(ed - jax.ops.segment_sum(w, eid, n_edges), 0.0)
        return (k + 1, rate, fixed | newly, eg, inn, ed)

    init = (jnp.int32(0), rate, fixed, eg_cap, in_cap, ed_cap)
    return jax.lax.while_loop(cond, step, init)[1]
