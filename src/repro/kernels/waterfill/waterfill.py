"""Max-min water-filling Pallas kernel (one-hot matmul form).

One round of the iterative bottleneck-link saturation step per grid
iteration (grid = (n_iters,), cribbing the scratch-across-grid pattern
from ``kernels/ssd_scan``): the per-VM / per-edge segment sums and the
per-connection gathers both become one-hot matmuls on the MXU —
``counts = un @ S`` and ``share_per_conn = share_per_vm @ S^T`` for a
one-hot scatter matrix ``S [NCp, NVp]``. All per-lane vectors ride in
``[8, X]`` row-replicated tiles (f32 min tile is 8 x 128); the running
rate / fixed / residual-budget state lives in VMEM scratch, initialized
on grid step 0 and emitted on the last step. Saturated rounds past
convergence are natural no-ops (no unfixed lanes -> zero counts -> no
newly-fixed lanes), so the static iteration bound just burns empty
steps.

``BIG`` stands in for +inf: infinities would turn the gather matmuls
into NaN (inf * 0), while BIG survives them (BIG * 0 == 0). The f32
saturation tolerance is correspondingly looser than the f64 oracle's
(1e-6 vs 1e-12) — this kernel is the accelerator fast path, checked
against ``ref.masked_maxmin_rates`` at f32 tolerance, not bitwise.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 1e30  # finite stand-in for +inf (survives `* 0.0` in matmuls)
_EPS32 = 1e-6  # f32 saturation tolerance (oracle uses 1e-12 in f64)


def _dot(a, b):
    return jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def _waterfill_kernel(caps_ref, act_ref, eg_ref, in_ref, ed_ref,
                      s_src_ref, s_src_t_ref, s_dst_ref, s_dst_t_ref,
                      s_ed_ref, s_ed_t_ref, rate_out_ref,
                      rate_s, fixed_s, eg_s, in_s, ed_s, *, n_iters: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        rate_s[...] = jnp.zeros_like(rate_s)
        fixed_s[...] = 1.0 - act_ref[...]
        eg_s[...] = eg_ref[...]
        in_s[...] = in_ref[...]
        ed_s[...] = ed_ref[...]

    caps = caps_ref[...]  # [8, NCp]
    un = act_ref[...] * (1.0 - fixed_s[...])  # [8, NCp], 0/1

    cnt_out = _dot(un, s_src_ref[...])  # [8, NVp]
    cnt_in = _dot(un, s_dst_ref[...])  # [8, NVp]
    cnt_ed = _dot(un, s_ed_ref[...])  # [8, NEp]
    share_out = jnp.where(cnt_out > 0, eg_s[...] / jnp.maximum(cnt_out, 1.0),
                          BIG)
    share_in = jnp.where(cnt_in > 0, in_s[...] / jnp.maximum(cnt_in, 1.0),
                         BIG)
    share_ed = jnp.where(cnt_ed > 0, ed_s[...] / jnp.maximum(cnt_ed, 1.0),
                         BIG)
    share = jnp.minimum(_dot(share_out, s_src_t_ref[...]),
                        _dot(share_in, s_dst_t_ref[...]))
    share = jnp.minimum(share, _dot(share_ed, s_ed_t_ref[...]))
    # gather-matmuls zero out padding lanes; restore their BIG sentinel so
    # the threshold min below never sees a spurious 0
    share = jnp.where(un > 0, share, BIG)

    cap_hit = jnp.where((un > 0) & (caps <= share + _EPS32), 1.0, 0.0)
    anyc = jnp.max(cap_hit)  # 1.0 when any lane saturated its own cap
    thresh = jnp.min(share)
    th_hit = jnp.where((un > 0) & (share <= thresh + _EPS32), 1.0, 0.0)
    newly = anyc * cap_hit + (1.0 - anyc) * th_hit
    chosen = anyc * caps + (1.0 - anyc) * share
    rate = jnp.where(newly > 0, chosen, rate_s[...])
    w = jnp.where(newly > 0, rate, 0.0)
    rate_s[...] = rate
    fixed_s[...] = jnp.minimum(fixed_s[...] + newly, 1.0)
    eg_s[...] = jnp.maximum(eg_s[...] - _dot(w, s_src_ref[...]), 0.0)
    in_s[...] = jnp.maximum(in_s[...] - _dot(w, s_dst_ref[...]), 0.0)
    ed_s[...] = jnp.maximum(ed_s[...] - _dot(w, s_ed_ref[...]), 0.0)

    @pl.when(i == n_iters - 1)
    def _emit():
        rate_out_ref[...] = rate_s[...]


@functools.partial(jax.jit, static_argnames=("n_iters", "interpret"))
def waterfill_8x(caps8, act8, eg8, in8, ed8, s_src, s_src_t, s_dst,
                 s_dst_t, s_ed, s_ed_t, *, n_iters: int,
                 interpret: bool = False):
    """Padded-tile water-filling: caps8/act8 [8, NCp], eg8/in8 [8, NVp],
    ed8 [8, NEp], one-hot scatter matrices s_* [NCp, NVp|NEp] (+ their
    transposes) -> rates [8, NCp] (rows identical)."""
    r, ncp = caps8.shape
    nvp = eg8.shape[1]
    nep = ed8.shape[1]
    def full(*shape):
        return pl.BlockSpec(shape, lambda i: (0,) * len(shape))

    kernel = functools.partial(_waterfill_kernel, n_iters=n_iters)
    return pl.pallas_call(
        kernel,
        grid=(n_iters,),
        in_specs=[
            full(r, ncp), full(r, ncp), full(r, nvp), full(r, nvp),
            full(r, nep), full(ncp, nvp), full(nvp, ncp), full(ncp, nvp),
            full(nvp, ncp), full(ncp, nep), full(nep, ncp),
        ],
        out_specs=full(r, ncp),
        out_shape=jax.ShapeDtypeStruct((r, ncp), jnp.float32),
        scratch_shapes=[
            _vmem((r, ncp)), _vmem((r, ncp)), _vmem((r, nvp)),
            _vmem((r, nvp)), _vmem((r, nep)),
        ],
        interpret=interpret,
    )(caps8, act8, eg8, in8, ed8, s_src, s_src_t, s_dst, s_dst_t, s_ed,
      s_ed_t)


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)
