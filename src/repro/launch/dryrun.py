import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
#   Placeholder host devices exist ONLY for this dry-run entrypoint; smoke
#   tests and benchmarks see the real single CPU device.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell AOT — ShapeDtypeStructs only, no allocation — and record
memory/cost/collective statistics for the roofline analysis.

Per runnable cell this produces:
  * full artifact  — the real step (scanned layer stacks) lowered and
    compiled on the production mesh. Proves sharding coherence; provides
    memory_analysis (bytes per device) and the collective schedule.
  * probe-delta roofline — two additional scanned lowerings with 2 and 3
    layer-groups. XLA cost analysis counts a while body once (measured:
    scan FLOPs ratio == 1/L), so per-group cost is S(3)-S(2) exactly, and
      total = S(2) + (G-2) * (S(3) - S(2))
    recovers trip-count-faithful FLOPs / bytes / collective bytes. Inner
    fixed-trip scans (chunked loss, SSD recurrence) are unrolled instead
    (cfg.inner_unroll) since their trip counts don't vary with G.

Artifacts: artifacts/dryrun/<arch>__<shape>__<mesh>.json  (resumable: cells
with an existing artifact are skipped unless --force).

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--force]
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, applicable, get_arch
from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec
from repro.launch import hlo_stats
from repro.launch.inputs import (
    decode_logical,
    decode_state_sds,
    decode_tokens_sds,
    param_sds,
    train_batch_logical,
    train_batch_sds,
)
from repro.launch.mesh import make_production_mesh
from repro.models.model import abstract_params, count_params
from repro.serve import make_serve_step
from repro.sharding.specs import (
    ShardingRules,
    make_param_shardings,
    set_mesh,
    shardings_for,
)
from repro.train import OptConfig, init_opt_state, make_train_step

DEFAULT_OUT = Path("artifacts/dryrun")

# §Perf hillclimb variants: cumulative config overrides, measured one at a
# time against the paper-faithful baseline (EXPERIMENTS.md §Perf logs the
# hypothesis -> before/after for each).
VARIANTS: dict[str, dict] = {
    "baseline": {},
    "v1_embed": dict(embed_dmodel_shard=True),
    "v2_cast": dict(embed_dmodel_shard=True, cast_params_once=True),
    "v3_moe": dict(embed_dmodel_shard=True, cast_params_once=True,
                   moe_shard_dispatch=True),
    "v4_bf16s": dict(embed_dmodel_shard=True, cast_params_once=True,
                     moe_shard_dispatch=True, attn_scores_bf16=True),
    "v5_dots": dict(embed_dmodel_shard=True, cast_params_once=True,
                    moe_shard_dispatch=True, attn_scores_bf16=True,
                    remat_policy="dots"),
    "opt": dict(embed_dmodel_shard=True, cast_params_once=True,
                moe_shard_dispatch=True, attn_scores_bf16=True,
                remat_policy="dots"),
    # best per-cell combination found by the §Perf loop: bf16 scores REFUTED
    # (manual softmax defused on the measured backend), everything else kept
    "v6_best": dict(embed_dmodel_shard=True, cast_params_once=True,
                    moe_shard_dispatch=True, remat_policy="dots"),
    # multi-pod only: explicit planner-ordered int8 ring for the pod-axis
    # gradient reduction (the paper's egress-volume lever on the DCN)
    "podring": dict(embed_dmodel_shard=True, cast_params_once=True,
                    moe_shard_dispatch=True, remat_policy="dots"),
    # SSD chunk-size hypothesis (SSM archs): intra-chunk decay/score bytes
    # scale with S*Q (nc*Q^2 = S*Q), so smaller Q should cut the SSD memory
    # term ~Q-proportionally at the cost of more (tiny) recurrence steps.
    "v7_ssdq64": dict(embed_dmodel_shard=True, cast_params_once=True,
                      moe_shard_dispatch=True, remat_policy="dots",
                      _ssd_chunk=64),
    "v7_ssdq128": dict(embed_dmodel_shard=True, cast_params_once=True,
                       moe_shard_dispatch=True, remat_policy="dots",
                       _ssd_chunk=128),
    # MoE combine via scatter-from-experts + psum (vs buffer all-gather)
    "v8_moecomb": dict(embed_dmodel_shard=True, cast_params_once=True,
                       moe_shard_dispatch=True, remat_policy="dots",
                       moe_psum_combine=True),
}


def _apply_overrides(cfg: ModelConfig, overrides: dict) -> ModelConfig:
    ov = dict(overrides)
    ssd_chunk = ov.pop("_ssd_chunk", None)
    cfg = dataclasses.replace(cfg, **ov)
    if ssd_chunk and cfg.ssm is not None:
        cfg = dataclasses.replace(
            cfg, ssm=dataclasses.replace(cfg.ssm, chunk=ssd_chunk)
        )
    return cfg


def rules_for(shape: ShapeSpec) -> ShardingRules:
    """Baseline sharding scheme per input shape (the §Perf starting point)."""
    if shape.name == "long_500k":
        # batch=1: context parallelism — shard the KV/SSM sequence dim over
        # the data axis instead of the (unshardable) batch dim.
        return ShardingRules(batch=None, fsdp="data", tp="model", seq="data")
    return ShardingRules(batch=("pod", "data"), fsdp="data", tp="model", seq=None)


def _probe_cfg(cfg: ModelConfig, k_groups: int) -> ModelConfig:
    """A k-group copy of cfg with UNROLLED scans. Scanned lowerings have
    identical HLO for every G (only the trip-count constant changes), so the
    probes must unroll to make S(3)-S(2) equal one group's true cost."""
    _, per = cfg.scan_groups()
    repl = {"num_layers": per * k_groups, "scan_unroll": True}
    if cfg.is_enc_dec:
        repl["encoder_layers"] = k_groups
    return dataclasses.replace(cfg, **repl)


def _lower_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, rules: ShardingRules,
                podring: bool = False):
    """Build the jitted step for this cell and lower it AOT."""
    set_mesh(mesh)
    abstract = abstract_params(cfg)
    if shape.kind == "train":
        pshard = make_param_shardings(mesh, rules, abstract)
        psds = param_sds(cfg)  # f32 master weights
        osds = jax.eval_shape(init_opt_state, psds)
        oshard = {"m": pshard, "v": pshard,
                  "step": jax.sharding.NamedSharding(
                      mesh, jax.sharding.PartitionSpec()
                  )}
        bsds = train_batch_sds(cfg, shape)
        bshard = shardings_for(mesh, rules, train_batch_logical(cfg), bsds)
        if podring and "pod" in mesh.axis_names:
            from repro.train.train_step import make_podring_train_step

            step = make_podring_train_step(cfg, rules, OptConfig(), mesh,
                                           compress_wire=True)
        else:
            step = make_train_step(cfg, rules, OptConfig())
        jitted = jax.jit(
            step,
            in_shardings=(pshard, oshard, bshard),
            donate_argnums=(0, 1),
        )
        with mesh:
            return jitted.lower(psds, osds, bsds)
    # serving cells run bf16 params
    serve_dtype = jnp.bfloat16
    cfg_serve = dataclasses.replace(cfg, param_dtype="bfloat16")
    abstract = abstract_params(cfg_serve)
    pshard = make_param_shardings(mesh, rules, abstract)
    psds = param_sds(cfg_serve, dtype=serve_dtype)
    if shape.kind == "prefill":
        from repro.serve import make_prefill_step

        bsds = train_batch_sds(cfg_serve, shape)
        bsds.pop("labels")
        blog = train_batch_logical(cfg_serve)
        blog.pop("labels")
        bshard = shardings_for(mesh, rules, blog, bsds)
        step = make_prefill_step(cfg_serve, rules, t_max=shape.seq_len)
        jitted = jax.jit(step, in_shardings=(pshard, bshard))
        with mesh:
            return jitted.lower(psds, bsds)
    # decode
    ssds = decode_state_sds(cfg_serve, shape)
    sshard = shardings_for(mesh, rules, decode_logical(cfg_serve), ssds)
    tsds = decode_tokens_sds(cfg_serve, shape)
    tshard = shardings_for(mesh, rules, ("batch", None), tsds)
    step = make_serve_step(cfg_serve, rules)
    jitted = jax.jit(step, in_shardings=(pshard, sshard, tshard),
                     donate_argnums=(1,))
    with mesh:
        return jitted.lower(psds, ssds, tsds)


def _stats_of(lowered) -> dict:
    compiled = lowered.compile()
    st = {}
    st.update(hlo_stats.cost_stats(compiled))
    st.update(hlo_stats.memory_stats(compiled))
    coll = hlo_stats.parse_collectives(compiled.as_text())
    st["collectives"] = coll.as_dict()
    st["wire_bytes_per_device"] = coll.wire_bytes
    return st


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             probes: bool = True, rules: ShardingRules | None = None,
             variant: str = "baseline") -> dict:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    art: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "variant": variant,
        "kind": shape.kind,
        "params": count_params(cfg),
        "params_active": count_params(cfg, active_only=True),
    }
    runs, why = applicable(cfg, shape)
    if not runs:
        art["status"] = "skipped"
        art["skip_reason"] = why
        return art

    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    art["mesh_shape"] = dict(zip(mesh.axis_names, mesh.devices.shape))
    rules = rules or rules_for(shape)
    overrides = VARIANTS.get(variant, {})
    art["overrides"] = overrides
    cfg_cell = _apply_overrides(
        dataclasses.replace(cfg, inner_unroll=True), overrides
    )

    podring = variant == "podring"
    t0 = time.time()
    lowered = _lower_cell(cfg_cell, shape, mesh, rules, podring=podring)
    full = _stats_of(lowered)
    art["full"] = full
    art["lower_compile_s"] = round(time.time() - t0, 2)

    if probes:
        groups, per = cfg.scan_groups()
        if groups <= 3:
            # few enough groups that the full artifact IS trip-faithful only
            # if groups==1; otherwise probe with what we have
            k_lo, k_hi = max(1, groups - 1), groups
        else:
            k_lo, k_hi = 2, 3
        s_lo = _stats_of(_lower_cell(
            _probe_cfg(cfg_cell, k_lo), shape, mesh, rules, podring=podring))
        s_hi = _stats_of(_lower_cell(
            _probe_cfg(cfg_cell, k_hi), shape, mesh, rules, podring=podring))

        def extrap(key):
            d = s_hi[key] - s_lo[key]
            return s_hi[key] + (groups - k_hi) * d / max(k_hi - k_lo, 1)

        flops = extrap("flops_per_device")
        bytes_ = extrap("bytes_per_device")
        wire = extrap("wire_bytes_per_device")
        terms = hlo_stats.roofline_terms(flops, bytes_, wire)
        n_dev = mesh.devices.size
        model_flops = 6.0 * art["params_active"] * shape.global_batch * shape.seq_len
        if shape.kind != "train":
            # forward-only; decode touches 1 token
            tokens = shape.global_batch * (
                1 if shape.kind == "decode" else shape.seq_len
            )
            model_flops = 2.0 * art["params_active"] * tokens
        art["roofline"] = {
            "flops_per_device": flops,
            "bytes_per_device": bytes_,
            "wire_bytes_per_device": wire,
            **terms,
            "dominant": hlo_stats.dominant_term(terms),
            "model_flops_total": model_flops,
            "hlo_flops_total": flops * n_dev,
            "useful_flops_ratio": model_flops / max(flops * n_dev, 1.0),
            "probe_groups": [k_lo, k_hi],
            "groups": groups,
        }
    return art


def cell_path(out: Path, arch: str, shape: str, mesh: str,
              variant: str = "baseline") -> Path:
    suffix = "" if variant == "baseline" else f"__{variant}"
    return out / f"{arch}__{shape}__{mesh}{suffix}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--variant", default="baseline", choices=sorted(VARIANTS))
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    args = ap.parse_args()

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    archs = sorted(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = sorted(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                path = cell_path(out, arch, shape, mesh_kind, args.variant)
                if path.exists() and not args.force:
                    print(f"skip (exists): {path.name}")
                    continue
                t0 = time.time()
                try:
                    # probes only add information on the single-pod roofline
                    probes = (not args.no_probes) and mesh_kind == "single"
                    art = run_cell(arch, shape, mesh_kind, probes=probes,
                                   variant=args.variant)
                    art["status"] = art.get("status", "ok")
                except Exception as ex:  # noqa: BLE001 - record and continue
                    art = {
                        "arch": arch, "shape": shape, "mesh": mesh_kind,
                        "status": "error", "error": str(ex)[:2000],
                        "traceback": traceback.format_exc()[-4000:],
                    }
                    failures += 1
                art["wall_s"] = round(time.time() - t0, 2)
                path.write_text(json.dumps(art, indent=2))
                print(f"{path.name}: {art['status']} ({art['wall_s']}s)")
    print(f"done; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
