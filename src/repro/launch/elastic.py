"""Elastic rescaling: pod join/leave -> new mesh + Skyplane-planned reshard.

When the pod count changes, parameters/optimizer state must move between
pods. The movement matrix (bytes from pod i's region to pod j's region) is
exactly a set of bulk transfers — so the reshard schedule comes from the
Skyplane planner, and at fleet scale would execute on the same gateway data
plane as checkpoint replication. On this host the state movement itself is
a device_put onto the new mesh's shardings (logical correctness), while the
planner output prices/schedules the inter-region movement.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core.planner import Planner
from repro.core.spec import PlanSpec
from repro.core.topology import Topology
from repro.models.model import abstract_params
from repro.sharding.specs import ShardingRules, make_param_shardings
from .mesh import make_mesh_for


@dataclasses.dataclass
class ReshardPlan:
    old_pods: int
    new_pods: int
    moves: list  # (src_region, dst_region, gb, tput_gbps, cost)
    total_gb: float
    total_cost: float
    est_time_s: float


def plan_reshard(
    cfg,
    top: Topology,
    pod_regions_old: list[str],
    pod_regions_new: list[str],
    *,
    bytes_per_param: int = 12,  # f32 master + Adam m/v is 12 B/param
    tput_floor_gbps: float = 5.0,
) -> ReshardPlan:
    """Price & schedule the state movement for old->new pod sets.

    With pure-DP over pods each pod holds a full replica, so a joining pod
    bootstraps from the cheapest-reachable existing pod; a leaving pod only
    requires quorum bookkeeping. (With fsdp_pod sharding the volume scales
    by old/new shard ratios instead — the planner call is identical.)"""
    n_params = cfg.param_count()
    replica_gb = n_params * bytes_per_param / 1e9
    joining = [r for r in pod_regions_new if r not in pod_regions_old]
    planner = Planner(top)
    moves = []
    total_cost = 0.0
    worst_time = 0.0
    for dst in joining:
        best = None
        for src in pod_regions_old:
            goal = min(tput_floor_gbps, planner.plan(PlanSpec(
                objective="max_throughput", src=src, dst=dst,
            )) * 0.9)
            if goal <= 0:
                continue
            plan = planner.plan(PlanSpec(
                objective="cost_min", src=src, dst=dst,
                tput_goal_gbps=goal, volume_gb=replica_gb,
            ))
            if best is None or plan.total_cost < best[0]:
                best = (plan.total_cost, src, plan)
        if best is None:
            raise ValueError(f"no source pod can reach joining pod {dst}")
        cost, src, plan = best
        moves.append((src, dst, replica_gb, plan.throughput, cost))
        total_cost += cost
        worst_time = max(worst_time, plan.transfer_time_s)
    return ReshardPlan(
        old_pods=len(pod_regions_old),
        new_pods=len(pod_regions_new),
        moves=moves,
        total_gb=replica_gb * len(joining),
        total_cost=total_cost,
        est_time_s=worst_time,
    )


def reshard_state(cfg, state_tree, *, new_pods: int, data: int = 16,
                  model: int = 16, rules: ShardingRules | None = None):
    """Re-mesh: place an existing (params/opt) tree onto the new mesh's
    shardings. Returns (new_mesh, resharded_tree)."""
    mesh = make_mesh_for(new_pods, data, model)
    rules = rules or ShardingRules()
    abstract = abstract_params(cfg)
    pshard = make_param_shardings(mesh, rules, abstract)

    def put(leaf, shd):
        return jax.device_put(np.asarray(jax.device_get(leaf)), shd)

    new_params = jax.tree.map(put, state_tree["params"], pshard)
    new_opt = {
        "m": jax.tree.map(put, state_tree["opt"]["m"], pshard),
        "v": jax.tree.map(put, state_tree["opt"]["v"], pshard),
        "step": state_tree["opt"]["step"],
    }
    return mesh, {"params": new_params, "opt": new_opt}
