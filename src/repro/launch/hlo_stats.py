"""Extract roofline terms from AOT-compiled artifacts.

  * FLOPs / bytes: ``compiled.cost_analysis()`` (per-device, post-SPMD).
  * collective bytes: parsed from ``compiled.as_text()`` — the result-shape
    bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute op, with ring-algorithm wire-byte estimates.

CAVEAT (measured, see DESIGN.md): XLA cost analysis and a flat text parse
count a while-loop body ONCE, but a scanned layer stack executes it
``num_groups`` times. ``launch.dryrun`` therefore lowers two scanned probes
(2 and 3 layer-groups) and extrapolates: total = S(2) + (G-2) * (S(3)-S(2)).
Everything in this module reports raw single-pass numbers; the probe-delta
arithmetic lives in dryrun.py.
"""

from __future__ import annotations

import dataclasses
import re


# v5e-class hardware constants (per brief)
PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    """'f32[16,128]' or '(f32[2], s32[4])' -> total bytes."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict  # op kind -> count
    result_bytes: dict  # op kind -> total result bytes (per device)
    wire_bytes: float  # estimated bytes moved on the interconnect per device

    def as_dict(self):
        return {
            "counts": self.counts,
            "result_bytes": self.result_bytes,
            "wire_bytes": self.wire_bytes,
        }


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Collect per-device collective traffic from the compiled HLO text.

    Ring-algorithm wire estimates (bytes leaving/entering one device):
      all-gather:          result * (g-1)/g     (receives all other shards)
      reduce-scatter:      input  * (g-1)/g  == result * (g-1)
      all-reduce:          2 * shard * (g-1)/g  ~= 2 * result * (g-1)/g
      all-to-all:          result * (g-1)/g
      collective-permute:  result               (send + receive one buffer)
    """
    counts: dict = {}
    result_bytes: dict = {}
    wire = 0.0
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _COLL_RE.search(line)
        if not m or " = " not in line:
            continue
        kind = m.group(1)
        # result type sits between '=' and the op name:
        #   %all-gather.1 = f32[96,576]{0,1} all-gather(%x), replica_groups=...
        rhs = line.split(" = ", 1)[1]
        type_seg = rhs.split(kind, 1)[0]
        rb = _shape_bytes(type_seg)
        if rb == 0:
            continue
        gm = _GROUPS_RE.search(line)
        if gm:
            gsize = int(gm.group(2))
        else:
            gm2 = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
            gsize = len(gm2.group(1).split(",")) if gm2 else 2
        counts[kind] = counts.get(kind, 0) + 1
        result_bytes[kind] = result_bytes.get(kind, 0) + rb
        if gsize <= 1:
            continue
        frac = (gsize - 1) / gsize
        if kind == "all-gather":
            wire += rb * frac
        elif kind == "reduce-scatter":
            wire += rb * (gsize - 1)
        elif kind == "all-reduce":
            wire += 2 * rb * frac
        elif kind == "all-to-all":
            wire += rb * frac
        else:  # collective-permute
            wire += rb
    return CollectiveStats(counts, result_bytes, wire)


def cost_stats(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {
        "flops_per_device": float(ca.get("flops", 0.0)),
        "bytes_per_device": float(ca.get("bytes accessed", 0.0)),
    }


def memory_stats(compiled) -> dict:
    ma = compiled.memory_analysis()
    return {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "code_bytes": int(ma.generated_code_size_in_bytes),
    }


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   wire_bytes_per_dev: float) -> dict:
    """The three roofline terms in seconds (per-device program, so chips
    cancel out of the brief's formulas)."""
    return {
        "compute_s": flops_per_dev / PEAK_FLOPS,
        "memory_s": bytes_per_dev / HBM_BW,
        "collective_s": wire_bytes_per_dev / ICI_BW,
    }


def dominant_term(terms: dict) -> str:
    key = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: terms.get(k, 0.0)
    )
    return {"compute_s": "compute", "memory_s": "memory",
            "collective_s": "collective"}[key]
