"""ShapeDtypeStruct stand-ins for every model input (no device allocation),
plus their logical sharding trees — the ``input_specs()`` of the brief.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec
from repro.models import init_decode_state, param_shape_dtypes
from repro.models.model import decode_state_logical


def train_batch_sds(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    sds = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    if cfg.is_vlm:
        sds["vision"] = jax.ShapeDtypeStruct(
            (b, cfg.num_vision_tokens, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    if cfg.is_enc_dec:
        sds["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.num_frames, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    return sds


def train_batch_logical(cfg: ModelConfig) -> dict:
    spec = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
    if cfg.is_vlm:
        spec["vision"] = ("batch", None, None)
    if cfg.is_enc_dec:
        spec["frames"] = ("batch", None, None)
    return spec


def decode_state_sds(cfg: ModelConfig, shape: ShapeSpec):
    """Decode state stand-in with a KV/SSM context of shape.seq_len."""
    return jax.eval_shape(
        lambda: init_decode_state(cfg, shape.global_batch, shape.seq_len)
    )


def decode_tokens_sds(cfg: ModelConfig, shape: ShapeSpec):
    return jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)


def decode_logical(cfg: ModelConfig) -> dict:
    return decode_state_logical(cfg)


def param_sds(cfg: ModelConfig, dtype=None):
    sds = param_shape_dtypes(cfg)
    if dtype is None:
        return sds
    dt = jnp.dtype(dtype)
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dt), sds)
