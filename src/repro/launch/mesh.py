"""Production meshes. Functions, not module constants — importing this module
never touches jax device state."""

from __future__ import annotations

import jax


def _mesh(shape, axes):
    """jax.make_mesh across jax versions: ``axis_types`` (and the AxisType
    enum) only exist from jax 0.5; the pinned 0.4.37 uses the default."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) data x model single pod (256 chips); (2, 16, 16) pod x data x
    model for the 2-pod = 512-chip multi-pod dry-run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh_for(n_pods: int, data: int = 16, model: int = 16):
    """Elastic variant: any pod count (1000+ node fleets pick n_pods here)."""
    if n_pods == 1:
        return _mesh((data, model), ("data", "model"))
    return _mesh((n_pods, data, model), ("pod", "data", "model"))
