"""Skyplane-style planner CLI.

    PYTHONPATH=src python -m repro.launch.plan \
        --src azure:canadacentral --dst gcp:asia-northeast1 \
        --volume-gb 50 [--cost-ceiling-x 1.25 | --tput-floor 20] [--simulate]
"""

from __future__ import annotations

import argparse
import json

from repro.core import Planner, PlanSpec, default_topology, direct_plan


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--src", required=True, help="e.g. aws:us-east-1")
    ap.add_argument("--dst", required=True)
    ap.add_argument("--volume-gb", type=float, default=50.0)
    ap.add_argument("--cost-ceiling-x", type=float, default=None,
                    help="price ceiling as a multiple of the direct path")
    ap.add_argument("--tput-floor", type=float, default=None,
                    help="Gbit/s floor for cost-min mode")
    ap.add_argument("--max-relays", type=int, default=10)
    ap.add_argument("--simulate", action="store_true",
                    help="execute on the fluid data-plane simulator")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args(argv)

    top = default_topology()
    planner = Planner(top, max_relays=args.max_relays)
    dp = direct_plan(top, args.src, args.dst, args.volume_gb)

    if args.tput_floor is not None:
        plan = planner.plan(PlanSpec(
            objective="cost_min", src=args.src, dst=args.dst,
            tput_goal_gbps=args.tput_floor, volume_gb=args.volume_gb,
        ))
    else:
        mult = args.cost_ceiling_x or 1.25
        plan = planner.plan(PlanSpec(
            objective="tput_max", src=args.src, dst=args.dst,
            cost_ceiling_per_gb=dp.cost_per_gb * mult,
            volume_gb=args.volume_gb,
        ))

    info = {
        "direct_gbps": round(dp.throughput, 2),
        "direct_cost_per_gb": round(dp.cost_per_gb, 4),
        "plan_gbps": round(plan.throughput, 2),
        "plan_cost_per_gb": round(plan.cost_per_gb, 4),
        "speedup": round(plan.throughput / max(dp.throughput, 1e-9), 2),
        "cost_x": round(plan.cost_per_gb / max(dp.cost_per_gb, 1e-9), 2),
        "vms": int(plan.num_vms),
        "paths": [
            {"route": [top.keys()[r] for r in path], "gbps": round(f, 2)}
            for path, f in plan.paths()
        ],
        "violations": plan.validate(),
    }
    if args.simulate:
        from repro.transfer import execute_plan

        rep = execute_plan(plan, chunk_mb=16, seed=0)
        info["simulated_gbps"] = round(rep.sim.tput_gbps, 2)
        info["simulated_cost"] = round(rep.sim.total_cost, 2)
    if args.json:
        print(json.dumps(info, indent=2))
    else:
        print(plan.describe())
        for k, v in info.items():
            if k != "paths":
                print(f"  {k}: {v}")
    return 0 if not info["violations"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
