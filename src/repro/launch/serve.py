"""Batched serving driver: prefill a batch of prompts, then greedy-decode.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --batch 4 --prompt-len 64 --decode 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.models import init_params, prefill
from repro.serve import make_serve_step
from repro.sharding.specs import ShardingRules


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--decode", type=int, default=32)
    ap.add_argument("--full", action="store_true",
                    help="serve the full config instead of the reduced one")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch) if args.full else reduced(get_arch(args.arch))
    rules = ShardingRules(batch=None, fsdp=None, tp=None)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)

    batch = {
        "tokens": jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab_size
        )
    }
    if cfg.is_vlm:
        batch["vision"] = jax.random.normal(
            key, (args.batch, cfg.num_vision_tokens, cfg.d_model)
        )
    if cfg.is_enc_dec:
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.num_frames, cfg.d_model)
        )

    t_max = args.prompt_len + args.decode
    t0 = time.time()
    state, last_logits = jax.jit(
        lambda p, b: prefill(cfg, rules, p, b, t_max=t_max)
    )(params, batch)
    t_prefill = time.time() - t0

    serve_step = jax.jit(make_serve_step(cfg, rules), donate_argnums=(1,))
    tok = jnp.argmax(last_logits, -1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    for _ in range(args.decode - 1):
        tok, state = serve_step(params, state, tok)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    seq = jnp.concatenate(out_tokens, axis=1)
    tput = args.batch * (args.decode - 1) / max(t_decode, 1e-9)
    print(f"arch={cfg.name} prefill {args.batch}x{args.prompt_len} in "
          f"{t_prefill:.2f}s; decode {args.decode-1} steps @ {tput:.1f} tok/s")
    print("sample token ids:", seq[0, :16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
