"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 200 --batch 8 --seq 256 [--scale 0.25] [--resume]

On this CPU host the full architectures are exercised via the dry-run; the
driver trains real weights on reduced (or --scale'd) configs with the whole
substrate engaged: pipeline -> jit train step -> async checkpoints ->
fault-tolerant restart -> optional Skyplane checkpoint replication.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
from pathlib import Path

from repro.configs import get_arch, reduced
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def scaled_config(arch: str, scale: float):
    cfg = get_arch(arch)
    if scale >= 1.0:
        return cfg
    groups, per = cfg.scan_groups()
    d = max(64, int(cfg.d_model * scale) // 16 * 16)
    heads = max(1, int(cfg.num_heads * scale))
    while cfg.num_heads % heads or heads > cfg.num_heads:
        heads -= 1
    kv = max(1, min(cfg.num_kv_heads, heads))
    while heads % kv:
        kv -= 1
    return reduced(
        cfg,
        num_layers=per * max(2, int(groups * scale)),
        d_model=d,
        num_heads=heads,
        num_kv_heads=kv,
        d_ff=max(128, int(cfg.d_ff * scale) // 16 * 16 or 128),
        vocab_size=min(cfg.vocab_size, 8192),
        head_dim=max(16, int((cfg.resolved_head_dim) * scale) // 8 * 8),
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--scale", type=float, default=0.25,
                    help="model scale fraction; 1.0 trains the full config")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--ckpt-dir", default="artifacts/train_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--out", default="artifacts/train_metrics.json")
    args = ap.parse_args(argv)

    cfg = scaled_config(args.arch, args.scale)
    cfg = dataclasses.replace(cfg, loss_chunk=min(cfg.loss_chunk, args.seq))
    if not args.resume:
        import shutil

        shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    trainer = Trainer(
        cfg,
        TrainerConfig(
            steps=args.steps,
            global_batch=args.batch,
            seq_len=args.seq,
            ckpt_every=args.ckpt_every,
            ckpt_dir=args.ckpt_dir,
            microbatches=args.microbatches,
            log_every=1,
        ),
        opt_cfg=OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                          total_steps=args.steps),
    )
    result = trainer.run()
    losses = result["losses"]
    k = max(len(losses) // 4, 1)
    first, last = sum(losses[:k]) / k, sum(losses[-k:]) / k
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"steps={result['final_step']} loss {first:.3f} -> {last:.3f}")
    Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    Path(args.out).write_text(json.dumps(result, indent=2))
    if args.steps >= 25:
        assert last < first, "loss did not decrease"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
