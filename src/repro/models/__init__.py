from .model import (  # noqa: F401
    abstract_params,
    count_params,
    init_params,
    param_logical,
    param_shape_dtypes,
    forward,
    loss_fn,
    init_decode_state,
    decode_step,
    prefill,
)
