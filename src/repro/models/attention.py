"""Attention: GQA/MHA, causal + sliding-window masks, cross-attention,
functional KV caches for decode. Reference einsum path everywhere; the
Pallas flash kernel (repro.kernels.flash_attention) is switched in for
training/prefill when cfg.use_pallas is set.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.specs import ShardingRules, shard_constraint
from .layers import rope
from .params import ParamDef

NEG_INF = -1e30


# ----------------------------------------------------------------- param defs
def attn_defs(
    cfg: ModelConfig, lead: tuple[int, ...] = (), cross: bool = False
) -> dict:
    d = cfg.d_model
    h, kv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ll = tuple(["layers"] * len(lead))
    defs = {
        "wq": ParamDef(lead + (d, h, dh), ll + ("fsdp", "tp", None), fan_in=d),
        "wk": ParamDef(lead + (d, kv, dh), ll + ("fsdp", "tp", None), fan_in=d),
        "wv": ParamDef(lead + (d, kv, dh), ll + ("fsdp", "tp", None), fan_in=d),
        "wo": ParamDef(lead + (h, dh, d), ll + ("tp", None, "fsdp"), fan_in=h * dh),
    }
    if cfg.qkv_bias and not cross:
        defs["bq"] = ParamDef(lead + (h, dh), ll + ("tp", None), init="zeros")
        defs["bk"] = ParamDef(lead + (kv, dh), ll + ("tp", None), init="zeros")
        defs["bv"] = ParamDef(lead + (kv, dh), ll + ("tp", None), init="zeros")
    return defs


# ------------------------------------------------------------------ core math
def _scores_constraint(scores, rules: ShardingRules):
    """Shard the [B,H,Sq,Sk] score/weight buffer: prefer heads over the TP
    axis; when the head count doesn't divide it (qwen2: 28H, smollm: 9H),
    shard the query-sequence dim instead so the O(S^2) buffer never
    replicates."""
    from repro.sharding.specs import current_mesh

    mesh = current_mesh()
    if mesh is None:
        return scores
    tp = rules.filter_for_mesh(mesh).tp
    if tp is None:
        return scores
    names = dict(zip(mesh.axis_names, mesh.devices.shape))
    size = names.get(tp if isinstance(tp, str) else tp[0], 1)
    b, h, sq, sk = scores.shape
    if h % size == 0:
        return shard_constraint(scores, rules, "batch", "tp", None, None)
    if sq % size == 0:
        return shard_constraint(scores, rules, "batch", None, "tp", None)
    return scores


def _gqa_scores(q, k, q_per_kv, acc_dtype=jnp.float32):
    """q: [B,Sq,H,Dh], k: [B,Sk,Kv,Dh] -> [B,H,Sq,Sk] (flat heads)."""
    b, sq, h, dh = q.shape
    kvh = k.shape[2]
    q = q.reshape(b, sq, kvh, q_per_kv, dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, k, preferred_element_type=acc_dtype)
    return s.reshape(b, h, sq, k.shape[1])


def _gqa_combine(w, v, q_per_kv):
    """w: [B,H,Sq,Sk] f32, v: [B,Sk,Kv,Dh] -> [B,Sq,H,Dh]."""
    b, h, sq, sk = w.shape
    kvh = v.shape[2]
    w = w.reshape(b, kvh, q_per_kv, sq, sk)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w.astype(v.dtype), v)
    return out.reshape(b, sq, h, v.shape[-1])


def attend(q, k, v, *, q_per_kv: int, mask=None, scale: float,
           rules: ShardingRules | None = None, scores_bf16: bool = False):
    """Masked GQA attention. mask: broadcastable [B|1,H|1,Sq,Sk] with True =
    attend. scores_bf16 (§Perf): keep the O(S^2) score/weight buffers in
    bf16 (row max in f32, sums in f32) — halves attention HBM bytes."""
    if scores_bf16:
        # every O(S^2) buffer stays bf16; row max/sum reductions are f32
        scores = _gqa_scores(q, k, q_per_kv, acc_dtype=jnp.bfloat16)
        scores = scores * jnp.bfloat16(scale)
        if rules is not None:
            scores = _scores_constraint(scores, rules)
        if mask is not None:
            scores = jnp.where(mask, scores, jnp.bfloat16(-3e38))
        m = jnp.max(scores.astype(jnp.float32), axis=-1, keepdims=True)
        p = jnp.exp(scores - m.astype(jnp.bfloat16))  # bf16 [.., Sq, Sk]
        if rules is not None:
            p = _scores_constraint(p, rules)
        denom = jnp.sum(p.astype(jnp.float32), axis=-1, keepdims=True)
        w = p / jnp.maximum(denom, 1e-20).astype(jnp.bfloat16)
        return _gqa_combine(w, v, q_per_kv)
    scores = _gqa_scores(q, k, q_per_kv) * scale
    if rules is not None:
        scores = _scores_constraint(scores, rules)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    if rules is not None:
        w = _scores_constraint(w, rules)
    return _gqa_combine(w, v, q_per_kv)


def causal_mask(sq: int, sk: int, *, window: int | None, q_offset=0):
    """[1,1,Sq,Sk] boolean; window = sliding-window width if any."""
    qi = jnp.arange(sq)[:, None] + q_offset
    ki = jnp.arange(sk)[None, :]
    m = ki <= qi
    if window is not None:
        m &= ki > qi - window
    return m[None, None]


# ----------------------------------------------------------------- full layer
def _pad_seq(x, t_max: int):
    """[B,S,...] -> [B,t_max,...] zero-padded."""
    s = x.shape[1]
    if s == t_max:
        return x
    pad = [(0, 0)] * x.ndim
    pad[1] = (0, t_max - s)
    return jnp.pad(x, pad)


def self_attention(
    cfg: ModelConfig,
    rules: ShardingRules,
    p: dict,
    x,
    positions,
    *,
    cache: dict | None = None,
    cache_len=None,  # decode: slot to write (wrapped for SWA ring buffers)
    seen_len=None,  # decode: total tokens seen (mask horizon); default slot
    emit_kv: int | None = None,  # prefill: emit {'k','v'} padded to this len
    is_causal: bool = True,
):
    """x: [B,S,D]. Training/prefill when cache is None; single-step decode
    when cache={'k','v'} ([B,T,Kv,Dh]) and cache_len = write slot."""
    dt = x.dtype
    dh = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = shard_constraint(q, rules, "batch", "seq", "tp", None)
    k = shard_constraint(k, rules, "batch", "seq", "tp", None)
    scale = dh ** -0.5

    if cache is None:
        if cfg.use_pallas and is_causal:
            from repro.kernels.flash_attention.ops import flash_attention

            out = flash_attention(
                q, k, v, causal=True, window=cfg.sliding_window, scale=scale
            )
        else:
            mask = (
                causal_mask(q.shape[1], k.shape[1], window=cfg.sliding_window)
                if is_causal
                else None
            )
            out = attend(q, k, v, q_per_kv=cfg.q_per_kv, mask=mask, scale=scale,
                         rules=rules, scores_bf16=cfg.attn_scores_bf16)
        new_cache = None
        if emit_kv is not None:
            new_cache = {"k": _pad_seq(k, emit_kv), "v": _pad_seq(v, emit_kv)}
    else:
        # decode: write k/v at slot cache_len, attend over everything seen.
        # For SWA the buffer IS the window (a ring), so once full every slot
        # is valid; attention is permutation-invariant over keys and RoPE was
        # applied at write time, so ring order is immaterial.
        T = cache["k"].shape[1]
        seen = cache_len if seen_len is None else seen_len
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, cache_len, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, cache_len, axis=1)
        ck = shard_constraint(ck, rules, "batch", "seq", "tp", None)
        cv = shard_constraint(cv, rules, "batch", "seq", "tp", None)
        ki = jnp.arange(T)[None, :]
        valid = ki <= jnp.minimum(seen, T - 1)
        mask = valid[None, None]  # [1,1,1(Sq),T]
        out = attend(q, ck, cv, q_per_kv=cfg.q_per_kv, mask=mask, scale=scale,
                     rules=rules, scores_bf16=cfg.attn_scores_bf16)
        new_cache = {"k": ck, "v": cv}

    out = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(dt))
    out = shard_constraint(out, rules, "batch", "seq", None)
    return out, new_cache


def cross_attention(cfg: ModelConfig, rules: ShardingRules, p: dict, x, kv_src):
    """Cross-attention from x [B,S,D] onto kv_src [B,Skv,D] (no RoPE, no mask;
    VLM image tokens / enc-dec memory)."""
    dt = x.dtype
    dh = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhe->bshe", kv_src, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhe->bshe", kv_src, p["wv"].astype(dt))
    out = attend(q, k, v, q_per_kv=cfg.q_per_kv, mask=None, scale=dh ** -0.5,
                 rules=rules, scores_bf16=cfg.attn_scores_bf16)
    out = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(dt))
    return shard_constraint(out, rules, "batch", "seq", None)


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers: int, dtype):
    """Stacked KV cache [n_layers, B, T, Kv, Dh] (scan-compatible)."""
    kv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    shape = (n_layers, batch, max_len, kv, dh)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def kv_cache_logical() -> dict:
    return {"k": ("layers", "batch", "seq", "tp", None),
            "v": ("layers", "batch", "seq", "tp", None)}
