"""Shared layers: RMSNorm, MLP variants, rotary embeddings, embedding/unembed."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.specs import ShardingRules, shard_constraint
from .params import ParamDef


# ------------------------------------------------------------------- rmsnorm
def rmsnorm_def(d: int) -> ParamDef:
    return ParamDef((d,), (None,), init="ones")


def rmsnorm(x, scale, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


# ----------------------------------------------------------------------- mlp
def mlp_defs(cfg: ModelConfig, lead: tuple[int, ...] = ()) -> dict:
    """Gated (SiLU/GELU) or squared-ReLU MLP parameter defs."""
    d, f = cfg.d_model, cfg.d_ff
    ll = tuple(["layers"] * len(lead))
    defs = {
        "wi": ParamDef(lead + (d, f), ll + ("fsdp", "tp"), fan_in=d),
        "wo": ParamDef(lead + (f, d), ll + ("tp", "fsdp"), fan_in=f),
    }
    if cfg.activation != "relu2":  # gated variants carry a second in-proj
        defs["wg"] = ParamDef(lead + (d, f), ll + ("fsdp", "tp"), fan_in=d)
    return defs


def mlp(cfg: ModelConfig, rules: ShardingRules, p: dict, x):
    """x: [B, S, D] -> [B, S, D]."""
    dt = x.dtype
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(dt))
    h = shard_constraint(h, rules, "batch", None, "tp")
    if cfg.activation == "relu2":  # Nemotron-4 squared ReLU
        h = jnp.square(jax.nn.relu(h))
    else:
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(dt))
        act = jax.nn.silu if cfg.activation == "silu" else jax.nn.gelu
        h = act(g) * h
    out = jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(dt))
    return shard_constraint(out, rules, "batch", None, None)


# ---------------------------------------------------------------------- rope
def rope(x, positions, theta: float):
    """Rotary position embedding. x: [..., S, H, Dh], positions: [..., S]."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freq  # [..., S, half]
    angles = angles[..., :, None, :]  # broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ----------------------------------------------------------------- embedding
def embed_defs(cfg: ModelConfig) -> dict:
    # baseline: vocab over TP (paper-faithful FSDP+TP table). The gather from
    # a vocab-sharded table makes XLA SPMD replicate the [B,S,D] lookup
    # ("involuntary full rematerialization") — the embed_dmodel_shard variant
    # shards d_model instead, so the indexed dim is whole and the lookup is
    # comm-free (§Perf iteration 1).
    tok_logical = (None, "tp") if cfg.embed_dmodel_shard else ("tp", "fsdp")
    d = {"tok": ParamDef((cfg.vocab_size, cfg.d_model), tok_logical, init="embed")}
    if not cfg.tie_embeddings:
        d["unembed"] = ParamDef(
            (cfg.d_model, cfg.vocab_size), ("fsdp", "tp"), fan_in=cfg.d_model
        )
    return d


def embed(cfg: ModelConfig, rules: ShardingRules, p: dict, tokens, dtype):
    x = jnp.take(p["tok"], tokens, axis=0).astype(dtype)
    return shard_constraint(x, rules, "batch", "seq", None)


def unembed_matrix(cfg: ModelConfig, p: dict, dtype):
    if cfg.tie_embeddings:
        return p["tok"].T.astype(dtype)
    return p["unembed"].astype(dtype)
