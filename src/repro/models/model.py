"""Top-level model API: params, forward, chunked loss, prefill, decode.

``batch`` dict convention:
  tokens  [B, S] int32      — decoder token ids (always present)
  labels  [B, S] int32      — next-token targets (train)
  vision  [B, Sv, D] f      — precomputed patch embeddings (VLM stub frontend)
  frames  [B, Sf, D] f      — precomputed audio frame embeddings (audio stub)

Decode state convention (functional, threaded through serve_step):
  {"pos": int32 scalar, "kv": {...}, "ssm": {...}, "memory"/"vision": [...]}
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.sharding.specs import ShardingRules, shard_constraint
from . import params as P
from .layers import embed, embed_defs, rmsnorm, rmsnorm_def, unembed_matrix
from .transformer import Aux, encoder_defs, encoder_stack, run_stack, stack_defs


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ------------------------------------------------------------------ params
def abstract_params(cfg: ModelConfig) -> dict:
    tree = {
        "embed": embed_defs(cfg),
        "decoder": stack_defs(cfg),
        "final_norm": rmsnorm_def(cfg.d_model),
    }
    if cfg.is_enc_dec:
        tree["encoder"] = encoder_defs(cfg)
    return tree


def init_params(cfg: ModelConfig, key) -> dict:
    return P.materialize(abstract_params(cfg), key)


def param_logical(cfg: ModelConfig) -> dict:
    return P.logical_specs(abstract_params(cfg))


def param_shape_dtypes(cfg: ModelConfig) -> dict:
    return P.shape_dtypes(abstract_params(cfg))


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    tree = abstract_params(cfg)
    total = P.count(tree)
    if not (active_only and cfg.moe):
        return total
    flat = jax.tree.leaves(tree, is_leaf=P.is_def)
    expert = sum(
        int(np.prod(pd.shape)) for pd in flat if "expert" in pd.logical
    )
    active = expert * cfg.moe.top_k / cfg.moe.num_experts
    return int(total - expert + active)


# ----------------------------------------------------------------- forward
def _positions(tokens):
    s = tokens.shape[1]
    pos = jnp.arange(s, dtype=jnp.int32)[None, :]
    return jnp.broadcast_to(pos, tokens.shape)


def _aux(cfg: ModelConfig, rules: ShardingRules, params, batch) -> Aux:
    memory = None
    vision = None
    if cfg.is_enc_dec:
        memory = encoder_stack(
            cfg, rules, params["encoder"], batch["frames"].astype(_dtype(cfg))
        )
    if cfg.is_vlm:
        vision = batch["vision"].astype(_dtype(cfg))
    return Aux(memory=memory, vision=vision)


def forward(cfg: ModelConfig, rules: ShardingRules, params, batch):
    """Train-mode forward to the final norm. Returns hidden [B, S, D]."""
    dt = _dtype(cfg)
    x = embed(cfg, rules, params["embed"], batch["tokens"], dt)
    aux = _aux(cfg, rules, params, batch)
    h, _ = run_stack(cfg, rules, params["decoder"], x, _positions(batch["tokens"]),
                     aux, mode="train")
    return rmsnorm(h, params["final_norm"], cfg.norm_eps)


def loss_fn(cfg: ModelConfig, rules: ShardingRules, params, batch):
    """Sequence-chunked cross entropy (keeps the [*, V] logits buffer small).

    Returns (loss, metrics)."""
    h = forward(cfg, rules, params, batch)
    labels = batch["labels"]
    b, s, d = h.shape
    c = min(cfg.loss_chunk, s)
    assert s % c == 0, (s, c)
    w = unembed_matrix(cfg, params["embed"], h.dtype)

    hc = h.reshape(b, s // c, c, d).transpose(1, 0, 2, 3)  # [nc, B, c, D]
    yc = labels.reshape(b, s // c, c).transpose(1, 0, 2)

    def chunk(carry, xs):
        hx, yx = xs
        logits = jnp.einsum(
            "bcd,dv->bcv", hx, w, preferred_element_type=jnp.float32
        )
        logits = shard_constraint(logits, rules, "batch", None, "tp")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yx[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(chunk, jnp.zeros((), jnp.float32), (hc, yc),
                        unroll=cfg.scan_unroll or cfg.inner_unroll)
    loss = total / (b * s)
    return loss, {"loss": loss, "tokens": jnp.array(b * s, jnp.float32)}


# ------------------------------------------------------------------ serving
def _attn_cache_layers(cfg: ModelConfig) -> tuple[int, ...]:
    """Leading stack dims of the KV cache for this family."""
    groups, per = cfg.scan_groups()
    if cfg.is_hybrid:
        return (groups,)
    if cfg.is_ssm:
        return ()
    if cfg.is_vlm:
        return (groups, per - 1)
    return (cfg.num_layers,)


def init_decode_state(cfg: ModelConfig, batch: int, seq_len: int, *,
                      dtype=None, batch_extras: dict | None = None) -> dict:
    """Zero caches sized for a context of ``seq_len`` tokens."""
    dt = dtype or _dtype(cfg)
    state: dict = {"pos": jnp.zeros((), jnp.int32)}
    lead = _attn_cache_layers(cfg)
    if lead:
        kv_len = seq_len
        if cfg.sliding_window is not None:
            kv_len = min(seq_len, cfg.sliding_window)  # SWA ring buffer
        kv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
        shape = lead + (batch, kv_len, kv, dh)
        state["kv"] = {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
    if cfg.ssm is not None:
        groups, per = cfg.scan_groups()
        n_ssm = groups * per if cfg.is_hybrid else cfg.num_layers
        s = cfg.ssm
        d_inner = s.expand * cfg.d_model
        heads = d_inner // s.head_dim
        conv_dim = d_inner + 2 * s.d_state
        ssm_lead = (groups, per) if cfg.is_hybrid else (cfg.num_layers,)
        state["ssm"] = {
            "conv": jnp.zeros(ssm_lead + (batch, s.d_conv - 1, conv_dim), dt),
            "state": jnp.zeros(ssm_lead + (batch, heads, s.head_dim, s.d_state), dt),
        }
    if cfg.is_enc_dec:
        extras = batch_extras or {}
        frames = extras.get("frames")
        state["memory"] = (
            frames if frames is not None
            else jnp.zeros((batch, cfg.num_frames, cfg.d_model), dt)
        )
    if cfg.is_vlm:
        extras = batch_extras or {}
        vision = extras.get("vision")
        state["vision"] = (
            vision if vision is not None
            else jnp.zeros((batch, cfg.num_vision_tokens, cfg.d_model), dt)
        )
    return state


def decode_state_logical(cfg: ModelConfig) -> dict:
    """Logical sharding axes mirroring init_decode_state's structure."""
    spec: dict = {"pos": ()}
    lead = _attn_cache_layers(cfg)
    if lead:
        ax = tuple(["layers"] * len(lead)) + ("batch", "seq", "tp", None)
        spec["kv"] = {"k": ax, "v": ax}
    if cfg.ssm is not None:
        nl = 2 if cfg.is_hybrid else 1
        ll = tuple(["layers"] * nl)
        spec["ssm"] = {
            "conv": ll + ("batch", None, "tp"),
            "state": ll + ("batch", "tp", None, None),
        }
    if cfg.is_enc_dec:
        spec["memory"] = ("batch", None, None)
    if cfg.is_vlm:
        spec["vision"] = ("batch", None, None)
    return spec


def prefill(cfg: ModelConfig, rules: ShardingRules, params, batch, *,
            t_max: int | None = None):
    """Run the full prompt, build decode caches. Returns (state, last_logits)."""
    dt = _dtype(cfg)
    tokens = batch["tokens"]
    s = tokens.shape[1]
    t_max = t_max or s
    x = embed(cfg, rules, params["embed"], tokens, dt)
    aux = _aux(cfg, rules, params, batch)
    h, caches = run_stack(cfg, rules, params["decoder"], x, _positions(tokens),
                          aux, mode="prefill", t_max=t_max)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    w = unembed_matrix(cfg, params["embed"], dt)
    last_logits = jnp.einsum(
        "bd,dv->bv", h[:, -1], w, preferred_element_type=jnp.float32
    )
    state: dict = {"pos": jnp.array(s, jnp.int32)}
    state.update(caches or {})
    if cfg.is_enc_dec:
        state["memory"] = aux.memory
    if cfg.is_vlm:
        state["vision"] = aux.vision
    return state, last_logits


def decode_step(cfg: ModelConfig, rules: ShardingRules, params, state, tokens):
    """One decode step. tokens: [B, 1] -> (logits [B, V], new state)."""
    dt = _dtype(cfg)
    pos = state["pos"]
    x = embed(cfg, rules, params["embed"], tokens, dt)
    positions = jnp.broadcast_to(pos[None, None], tokens.shape).astype(jnp.int32)
    aux = Aux(memory=state.get("memory"), vision=state.get("vision"))
    cache = {k: state[k] for k in ("kv", "ssm") if k in state}
    kv_pos = pos
    if cfg.sliding_window is not None and "kv" in state:
        kv_len = jax.tree.leaves(state["kv"])[0].shape[-3]
        kv_pos = jnp.where(kv_len < cfg.sliding_window, pos,
                           pos % jnp.int32(kv_len))  # SWA ring buffer
    h, new_caches = run_stack(cfg, rules, params["decoder"], x, positions, aux,
                              mode="decode", state=cache, cache_len=kv_pos,
                              seen_len=pos)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    w = unembed_matrix(cfg, params["embed"], dt)
    logits = jnp.einsum(
        "bd,dv->bv", h[:, 0], w, preferred_element_type=jnp.float32
    )
    new_state = dict(state)
    new_state.update(new_caches or {})
    new_state["pos"] = pos + 1
    return logits, new_state
