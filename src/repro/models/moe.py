"""Mixture-of-Experts FFN with top-k routing and capacity-bounded dispatch.

Dispatch is sort-based (argsort by expert id -> position-in-expert ->
scatter into an [E, C, D] buffer -> per-expert matmuls -> scatter-combine).
Gather/scatter moves bytes but adds no matmul FLOPs, so compiled-FLOP
roofline accounting reflects the *active* parameter count, matching the
6*N_active*D model. Experts are sharded over the "expert" logical axis
(== tensor-parallel mesh axis by default); the baseline relies on GSPMD to
place the dispatch collectives, which §Perf then iterates on.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.specs import ShardingRules, shard_constraint
from .params import ParamDef


def moe_defs(cfg: ModelConfig, lead: tuple[int, ...] = ()) -> dict:
    assert cfg.moe is not None
    d, e, f = cfg.d_model, cfg.moe.num_experts, cfg.moe.d_ff_expert
    ll = tuple(["layers"] * len(lead))
    if cfg.moe_shard_dispatch:
        # EP over the expert dim; when the expert count doesn't divide the TP
        # axis (mixtral: 8 vs 16) the shape-aware resolver drops "expert" and
        # the trailing "tp" kicks in -> per-expert tensor parallelism on d_ff.
        wi_l = ll + ("expert", "fsdp", "tp")
        wo_l = ll + ("expert", "tp", "fsdp")
    else:
        wi_l = ll + ("expert", "fsdp", None)
        wo_l = ll + ("expert", None, "fsdp")
    defs = {
        "router": ParamDef(lead + (d, e), ll + ("fsdp", None), fan_in=d),
        "wi": ParamDef(lead + (e, d, f), wi_l, fan_in=d),
        "wo": ParamDef(lead + (e, f, d), wo_l, fan_in=f),
    }
    if cfg.activation != "relu2":
        defs["wg"] = ParamDef(lead + (e, d, f), wi_l, fan_in=d)
    return defs


def capacity(cfg: ModelConfig, tokens: int) -> int:
    m = cfg.moe
    c = int(tokens * m.top_k * m.capacity_factor / m.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 for tiling friendliness


def _batch_shards(rules: ShardingRules) -> int:
    """Number of shards along the logical batch axis on the current mesh."""
    from repro.sharding.specs import current_mesh

    mesh = current_mesh()
    if mesh is None:
        return 1
    rules = rules.filter_for_mesh(mesh)
    ax = rules.batch
    if ax is None:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    flat = ax if isinstance(ax, tuple) else (ax,)
    n = 1
    for a in flat:
        n *= sizes.get(a, 1)
    return max(n, 1)


def moe_mlp_sharded(cfg: ModelConfig, rules: ShardingRules, p: dict, x):
    """Shard-local dispatch (§Perf): every data shard routes, sorts and
    position-computes its own tokens (batched ops — no global argsort, so no
    cross-shard collectives in dispatch), scattering into a dispatch buffer
    whose leading dim is aligned with the data axis. Capacity is enforced
    per shard; expert weights follow moe_defs' EP/TP layout. The only
    cross-device traffic left is the expert-dim reduction at combine."""
    m = cfg.moe
    dt = x.dtype
    b, s, d = x.shape
    t = b * s
    k = m.top_k
    e = m.num_experts
    n_sh = _batch_shards(rules)
    if t % n_sh or (t // n_sh) < 1:
        n_sh = 1
    t_loc = t // n_sh
    cap = capacity(cfg, t_loc)

    xt = x.reshape(n_sh, t_loc, d)
    xt = shard_constraint(xt, rules, "batch", None, None)
    logits = jnp.einsum(
        "gtd,de->gte", xt, p["router"].astype(dt),
        preferred_element_type=jnp.float32,
    )
    gates, eidx = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), k)
    gates = (gates / jnp.sum(gates, axis=-1, keepdims=True)).astype(dt)

    fe = eidx.reshape(n_sh, t_loc * k)
    fg = gates.reshape(n_sh, t_loc * k)
    ftok = jnp.tile(jnp.repeat(jnp.arange(t_loc), k)[None], (n_sh, 1))
    order = jnp.argsort(fe, axis=-1, stable=True)  # per-shard (batched) sort
    se = jnp.take_along_axis(fe, order, axis=-1)
    stok = jnp.take_along_axis(ftok, order, axis=-1)
    sg = jnp.take_along_axis(fg, order, axis=-1)
    starts = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(e)))(se)
    pos = jnp.arange(t_loc * k)[None] - jnp.take_along_axis(starts, se, axis=-1)
    keep = pos < cap
    posc = jnp.minimum(pos, cap - 1)

    # All gathers/scatters are vmapped over the shard dim: the explicit
    # batch dim lets XLA's SPMD partitioner keep them shard-local (a fancy
    # 3-D indexed scatter with a computed shard index replicates instead —
    # measured: ~69 GB all-reduces of [n_sh, t_loc*k, d] per layer).
    src = jax.vmap(lambda xr, ir: xr[ir])(xt, stok) * keep[..., None].astype(dt)
    buf = jax.vmap(
        lambda se_r, po_r, v_r: jnp.zeros((e, cap, d), dt).at[se_r, po_r].add(v_r)
    )(se, posc, src)
    buf = shard_constraint(buf, rules, "batch", "expert", None, None)

    h = jnp.einsum("gecd,edf->gecf", buf, p["wi"].astype(dt))
    h = shard_constraint(h, rules, "batch", "expert", None, "tp")
    if cfg.activation == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        g = jnp.einsum("gecd,edf->gecf", buf, p["wg"].astype(dt))
        act = jax.nn.silu if cfg.activation == "silu" else jax.nn.gelu
        h = act(g) * h
    outb = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(dt))
    outb = shard_constraint(outb, rules, "batch", "expert", None, None)

    if cfg.moe_psum_combine:
        # §Perf iteration: combine by scattering FROM the expert-sharded
        # buffer instead of gathering from it. Each TP rank scatters its
        # experts' slot outputs into a per-token partial sum; XLA reduces
        # the partials over the expert axis (one [t_loc, d] psum per shard
        # vs all-gathering the whole [E, cap, d] buffer — ~10x fewer bytes
        # for qwen3's 128 experts).
        slot_tok = jax.vmap(
            lambda se_r, po_r, st_r: jnp.full((e, cap), t_loc, jnp.int32)
            .at[se_r, po_r].set(st_r.astype(jnp.int32))
        )(se, posc, jnp.where(keep, stok, t_loc))
        slot_gate = jax.vmap(
            lambda se_r, po_r, g_r: jnp.zeros((e, cap), dt)
            .at[se_r, po_r].set(g_r)
        )(se, posc, sg * keep.astype(dt))
        contrib = outb * slot_gate[..., None]  # [n_sh, E, cap, d]
        y = jax.vmap(
            lambda tok_r, c_r: jnp.zeros((t_loc + 1, d), dt)
            .at[tok_r.reshape(-1)].add(c_r.reshape(-1, d))[: t_loc]
        )(slot_tok, contrib)
    else:
        vals = jax.vmap(lambda ob_r, se_r, po_r: ob_r[se_r, po_r])(outb, se, posc)
        vals = vals * (sg * keep.astype(dt))[..., None]
        y = jax.vmap(
            lambda st_r, v_r: jnp.zeros((t_loc, d), dt).at[st_r].add(v_r)
        )(stok, vals)
    y = shard_constraint(y, rules, "batch", None, None)
    return y.reshape(b, s, d)


def moe_mlp(cfg: ModelConfig, rules: ShardingRules, p: dict, x):
    """x: [B, S, D] -> [B, S, D]."""
    if cfg.moe_shard_dispatch:
        return moe_mlp_sharded(cfg, rules, p, x)
    m = cfg.moe
    dt = x.dtype
    b, s, d = x.shape
    t = b * s
    k = m.top_k
    e = m.num_experts
    cap = capacity(cfg, t)

    xt = x.reshape(t, d)
    logits = jnp.einsum(
        "td,de->te", xt, p["router"].astype(dt), preferred_element_type=jnp.float32
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)  # [T, k]
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    # flatten (token, slot) assignments and sort by expert
    fe = eidx.reshape(-1)  # [T*k] expert of each assignment
    fg = gates.reshape(-1).astype(dt)
    ftok = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(fe, stable=True)
    se, stok, sg = fe[order], ftok[order], fg[order]
    starts = jnp.searchsorted(se, jnp.arange(e), side="left")
    pos = jnp.arange(t * k) - starts[se]  # position within the expert
    keep = pos < cap  # capacity overflow dropped (standard top-k MoE)
    posc = jnp.minimum(pos, cap - 1)

    # dispatch: [E, C, D] buffer
    src = jnp.take(xt, stok, axis=0) * keep[:, None].astype(dt)
    buf = jnp.zeros((e, cap, d), dt).at[se, posc].add(src)
    buf = shard_constraint(buf, rules, "expert", None, None)

    # expert FFN
    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(dt))
    if cfg.activation == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        g = jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(dt))
        act = jax.nn.silu if cfg.activation == "silu" else jax.nn.gelu
        h = act(g) * h
    outb = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dt))
    outb = shard_constraint(outb, rules, "expert", None, None)

    # combine: weighted scatter back to token order
    vals = outb[se, posc] * (sg * keep.astype(dt))[:, None]
    y = jnp.zeros((t, d), dt).at[stok].add(vals)
    y = y.reshape(b, s, d)
    return shard_constraint(y, rules, "batch", None, None)
