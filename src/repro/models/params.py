"""Single-source-of-truth parameter definitions.

Model code declares parameters as ``ParamDef`` pytrees (shape + logical
sharding axes + init rule). From one abstract tree we derive:
  * real initialized parameters (small configs, smoke tests / examples)
  * ShapeDtypeStructs (dry-run lowering of the full-size configs)
  * logical -> PartitionSpec shardings (repro.sharding.specs)
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    logical: tuple[Any, ...]  # logical axis per dim (None | "fsdp" | "tp" | ...)
    init: str = "normal"  # "normal" | "zeros" | "ones" | "embed"
    fan_in: int | None = None  # stddev = 1/sqrt(fan_in) when set
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def tree_map_defs(fn, tree):
    return jax.tree.map(fn, tree, is_leaf=is_def)


def materialize(tree, key: jax.Array):
    """ParamDef tree -> initialized parameter tree (deterministic per path)."""
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_def)[0]
    n = len(leaves_with_paths)
    keys = jax.random.split(key, max(n, 1))

    def init_one(pd: ParamDef, k):
        if pd.init == "zeros":
            return jnp.zeros(pd.shape, pd.dtype)
        if pd.init == "ones":
            return jnp.ones(pd.shape, pd.dtype)
        if pd.init == "embed":
            return jax.random.normal(k, pd.shape, pd.dtype) * 0.02
        fan = (
            pd.fan_in
            if pd.fan_in
            else (pd.shape[-2] if len(pd.shape) >= 2 else pd.shape[-1])
        )
        std = 1.0 / np.sqrt(max(fan, 1))
        return jax.random.normal(k, pd.shape, pd.dtype) * std

    flat = [init_one(pd, keys[i]) for i, (_, pd) in enumerate(leaves_with_paths)]
    treedef = jax.tree_util.tree_structure(tree, is_leaf=is_def)
    return jax.tree_util.tree_unflatten(treedef, flat)


def shape_dtypes(tree):
    """ParamDef tree -> ShapeDtypeStruct tree (for AOT lowering)."""
    return tree_map_defs(lambda pd: jax.ShapeDtypeStruct(pd.shape, pd.dtype), tree)


def logical_specs(tree):
    """ParamDef tree -> logical-axis-tuple tree (for sharding rules)."""
    return tree_map_defs(lambda pd: tuple(pd.logical), tree)


def count(tree) -> int:
    flat = jax.tree.leaves(tree, is_leaf=is_def)
    return int(sum(int(np.prod(pd.shape)) for pd in flat))
