"""Mamba2 mixer: the SSD (state-space duality) form, arXiv:2405.21060.

Training/prefill uses the chunked SSD algorithm (intra-chunk quadratic term +
inter-chunk state recurrence via lax.scan); decode uses the O(1)-per-token
recurrent update with a carried (conv window, SSD state) cache. The pure-jnp
path here doubles as the oracle for the Pallas ``ssd_scan`` kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.specs import ShardingRules, shard_constraint
from .layers import rmsnorm
from .params import ParamDef


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.d_state  # x, B, C share the causal conv (G=1)
    return d_inner, heads, conv_dim


def ssm_defs(cfg: ModelConfig, lead: tuple[int, ...] = ()) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_inner, heads, conv_dim = _dims(cfg)
    proj = 2 * d_inner + 2 * s.d_state + heads  # z, x, B, C, dt
    ll = tuple(["layers"] * len(lead))
    return {
        "in_proj": ParamDef(lead + (d, proj), ll + ("fsdp", "tp"), fan_in=d),
        "conv_w": ParamDef(lead + (s.d_conv, conv_dim), ll + (None, "tp")),
        "conv_b": ParamDef(lead + (conv_dim,), ll + ("tp",), init="zeros"),
        "a_log": ParamDef(lead + (heads,), ll + ("tp",), init="ones"),
        "d_skip": ParamDef(lead + (heads,), ll + ("tp",), init="ones"),
        "dt_bias": ParamDef(lead + (heads,), ll + ("tp",), init="zeros"),
        "norm": ParamDef(lead + (d_inner,), ll + ("tp",), init="ones"),
        "out_proj": ParamDef(lead + (d_inner, d), ll + ("tp", "fsdp"), fan_in=d_inner),
    }


def _split_proj(cfg: ModelConfig, zxbcdt):
    s = cfg.ssm
    d_inner, heads, _ = _dims(cfg)
    z, xs, Bc, Cc, dt = jnp.split(
        zxbcdt,
        [d_inner, 2 * d_inner, 2 * d_inner + s.d_state, 2 * d_inner + 2 * s.d_state],
        axis=-1,
    )
    return z, xs, Bc, Cc, dt


def _causal_conv(seq, w, b):
    """Depthwise causal conv. seq: [B,S,C], w: [K,C] -> [B,S,C]."""
    k = w.shape[0]
    pad = jnp.pad(seq, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(seq)
    for i in range(k):  # k is tiny (4); unrolled taps
        out = out + pad[:, i : i + seq.shape[1], :] * w[i]
    return jax.nn.silu(out + b)


def ssd_chunked(x, dt, a, B, C, chunk: int, *, rules=None, unroll=False):
    """SSD scan. x:[b,S,H,P] dt:[b,S,H] a:[H](neg) B,C:[b,S,N].
    Returns y:[b,S,H,P] and final state [b,H,P,N].

    Ragged tails (prompt lengths off the chunk grid) are padded with dt=0 —
    zero step size leaves the recurrence invariant, so the final state is
    exact and the padded y rows are sliced off."""
    b, S, H, P = x.shape
    N = B.shape[-1]
    S_orig = S
    pad = (-S) % chunk
    if pad:
        def zpad(t):
            return jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        x, dt, B, C = zpad(x), zpad(dt), zpad(B), zpad(C)
        S = S + pad
    nc = S // chunk
    xr = x.reshape(b, nc, chunk, H, P)
    dtr = dt.reshape(b, nc, chunk, H)
    Br = B.reshape(b, nc, chunk, N)
    Cr = C.reshape(b, nc, chunk, N)

    dA = dtr * a  # [b,nc,Q,H], negative
    cum = jnp.cumsum(dA, axis=2)  # within-chunk cumulative

    # ---- intra-chunk (quadratic within the chunk)
    # decay(i,j) = exp(cum_i - cum_j) for i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [b,nc,i,j,H]
    ii = jnp.arange(chunk)
    mask = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    decay = jnp.where(mask, jnp.exp(diff), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", Cr, Br, preferred_element_type=jnp.float32)
    scores = cb[..., None] * decay * dtr[:, :, None, :, :]  # [b,nc,i,j,H]
    if rules is not None:
        scores = shard_constraint(scores, rules, "batch", None, None, None, "tp")
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores.astype(x.dtype), xr)

    # ---- inter-chunk state recurrence
    seg_end = cum[:, :, -1:, :]  # [b,nc,1,H]
    w_end = jnp.exp(seg_end - cum) * dtr  # decay from j to chunk end
    # inter-chunk state recurrence runs in f32 (long products of decays)
    s_chunk = jnp.einsum(
        "bcjh,bcjhp,bcjn->bchpn", w_end, xr.astype(jnp.float32),
        Br.astype(jnp.float32), preferred_element_type=jnp.float32,
    )
    chunk_decay = jnp.exp(seg_end[:, :, 0, :]).astype(jnp.float32)  # [b,nc,H]

    def step(carry, inp):
        s_prev = carry  # [b,H,P,N] f32
        s_c, dec = inp  # [b,H,P,N], [b,H]
        s_new = s_prev * dec[:, :, None, None] + s_c
        return s_new, s_prev

    s0 = jnp.zeros((b, H, P, N), jnp.float32)
    s_final, s_prevs = jax.lax.scan(
        step,
        s0,
        (s_chunk.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
        unroll=unroll,
    )
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)  # [b,nc,H,P,N]
    y_inter = jnp.einsum(
        "bcin,bchpn,bcih->bcihp", Cr.astype(jnp.float32), s_prevs,
        jnp.exp(cum), preferred_element_type=jnp.float32,
    ).astype(x.dtype)
    y = (y_intra + y_inter).reshape(b, S, H, P)
    if pad:
        y = y[:, :S_orig]
    return y, s_final.astype(x.dtype)


def ssm_prefill_mixer(cfg: ModelConfig, rules: ShardingRules, p: dict, x):
    """Prefill: chunked SSD forward that also emits the decode cache
    ({'conv': [B,K-1,Cd], 'state': [B,H,P,N]})."""
    s = cfg.ssm
    dt_ = x.dtype
    d_inner, heads, conv_dim = _dims(cfg)
    zxbcdt = jnp.einsum("bsd,dp->bsp", x, p["in_proj"].astype(dt_))
    z, xs, Bc, Cc, dt = _split_proj(cfg, zxbcdt)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    conv_in = jnp.concatenate([xs, Bc, Cc], axis=-1)
    conv_cache = conv_in[:, -(s.d_conv - 1):, :]
    conv_out = _causal_conv(conv_in, p["conv_w"].astype(dt_), p["conv_b"].astype(dt_))
    xs, Bc, Cc = jnp.split(conv_out, [d_inner, d_inner + s.d_state], axis=-1)
    xh = xs.reshape(*xs.shape[:2], heads, s.head_dim)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    y, state = ssd_chunked(xh, dtv, a, Bc, Cc, chunk=s.chunk, rules=rules,
                           unroll=cfg.inner_unroll)
    y = y + p["d_skip"].astype(dt_)[None, None, :, None] * xh
    y = y.reshape(*y.shape[:2], d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(dt_))
    out = shard_constraint(out, rules, "batch", "seq", None)
    return out, {"conv": conv_cache, "state": state}


def ssm_mixer(cfg: ModelConfig, rules: ShardingRules, p: dict, x, *, cache=None):
    """Mamba2 block mixer. x: [B,S,D]. cache (decode): {'conv': [B,K-1,Cd],
    'state': [B,H,P,N]} -> returns (y, new_cache)."""
    s = cfg.ssm
    dt_ = x.dtype
    d_inner, heads, conv_dim = _dims(cfg)
    zxbcdt = jnp.einsum("bsd,dp->bsp", x, p["in_proj"].astype(dt_))
    zxbcdt = shard_constraint(zxbcdt, rules, "batch", "seq", "tp")
    z, xs, Bc, Cc, dt = _split_proj(cfg, zxbcdt)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # [H]

    if cache is None:
        conv_in = jnp.concatenate([xs, Bc, Cc], axis=-1)
        conv_out = _causal_conv(
            conv_in, p["conv_w"].astype(dt_), p["conv_b"].astype(dt_)
        )
        xs, Bc, Cc = jnp.split(conv_out, [d_inner, d_inner + s.d_state], axis=-1)
        xh = xs.reshape(*xs.shape[:2], heads, s.head_dim)
        dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
        if cfg.use_pallas:
            from repro.kernels.ssd_scan.ops import ssd_scan

            y, _ = ssd_scan(xh, dtv, a, Bc, Cc, chunk=s.chunk)
        else:
            y, _ = ssd_chunked(xh, dtv, a, Bc, Cc, chunk=s.chunk, rules=rules,
                               unroll=cfg.inner_unroll)
        y = y + p["d_skip"].astype(dt_)[None, None, :, None] * xh
        new_cache = None
    else:
        # single-token recurrent update (S == 1)
        conv_in = jnp.concatenate([xs, Bc, Cc], axis=-1)  # [B,1,Cd]
        window = jnp.concatenate([cache["conv"], conv_in], axis=1)  # [B,K,Cd]
        w = p["conv_w"].astype(dt_)
        conv_out = jax.nn.silu(
            jnp.einsum("bkc,kc->bc", window, w) + p["conv_b"].astype(dt_)
        )[:, None, :]
        xs, Bc, Cc = jnp.split(conv_out, [d_inner, d_inner + s.d_state], axis=-1)
        xh = xs.reshape(xs.shape[0], heads, s.head_dim)  # [B,H,P]
        dtv = jax.nn.softplus(
            dt[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
        )  # [B,H]
        dA = jnp.exp(dtv * a)  # [B,H]
        state = cache["state"].astype(jnp.float32)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dtv, xh.astype(jnp.float32),
                         Bc[:, 0].astype(jnp.float32))
        state = state * dA[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", Cc[:, 0].astype(jnp.float32), state)
        y = y.astype(dt_) + p["d_skip"].astype(dt_)[None, :, None] * xh
        y = y[:, None]  # [B,1,H,P]
        new_cache = {"conv": window[:, 1:], "state": state.astype(cache["state"].dtype)}

    y = y.reshape(*y.shape[:2], d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(dt_))
    return shard_constraint(out, rules, "batch", "seq", None), new_cache


def init_ssm_cache(cfg: ModelConfig, batch: int, n_layers: int, dtype):
    s = cfg.ssm
    d_inner, heads, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((n_layers, batch, s.d_conv - 1, conv_dim), dtype),
        "state": jnp.zeros((n_layers, batch, heads, s.head_dim, s.d_state), dtype),
    }


def ssm_cache_logical() -> dict:
    return {
        "conv": ("layers", "batch", None, "tp"),
        "state": ("layers", "batch", "tp", None, None),
    }
