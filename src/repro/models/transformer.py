"""Block composition: dense / MoE / SSM / hybrid / VLM / enc-dec stacks.

All stacks are ``lax.scan`` over super-blocks (parameters stacked with a
leading group axis) so HLO size stays O(1) in depth — required to compile
96-layer x 18k-wide configs AOT. Heterogeneous families (Zamba2 hybrid,
VLM cross-attn interleave) scan over *groups* and unroll the tiny inner
pattern inside the scanned body.

Three execution modes share the block math:
  train    — no caches, optional per-block remat
  prefill  — same math, additionally emits KV/SSM caches (scan ys)
  decode   — single token, caches threaded through the scan (xs -> ys)
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.specs import ShardingRules
from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import mlp, mlp_defs, rmsnorm, rmsnorm_def
from .params import ParamDef


class Aux(NamedTuple):
    """Side inputs: encoder memory (enc-dec) or vision tokens (VLM)."""

    memory: Any = None  # [B, Skv, D]
    vision: Any = None  # [B, Sv, D]


# ------------------------------------------------------------ param defs
def dense_block_defs(cfg: ModelConfig, lead=()) -> dict:
    ll = tuple(["layers"] * len(lead))
    d = {
        "ln1": ParamDef(lead + (cfg.d_model,), ll + (None,), init="ones"),
        "attn": attn.attn_defs(cfg, lead),
        "ln2": ParamDef(lead + (cfg.d_model,), ll + (None,), init="ones"),
    }
    if cfg.moe is not None:
        d["ffn"] = moe_mod.moe_defs(cfg, lead)
    else:
        d["ffn"] = mlp_defs(cfg, lead)
    return d


def ssm_block_defs(cfg: ModelConfig, lead=()) -> dict:
    ll = tuple(["layers"] * len(lead))
    return {
        "ln1": ParamDef(lead + (cfg.d_model,), ll + (None,), init="ones"),
        "ssm": ssm_mod.ssm_defs(cfg, lead),
    }


def xattn_block_defs(cfg: ModelConfig, lead=()) -> dict:
    ll = tuple(["layers"] * len(lead))
    return {
        "ln1": ParamDef(lead + (cfg.d_model,), ll + (None,), init="ones"),
        "attn": attn.attn_defs(cfg, lead, cross=True),
        "ln2": ParamDef(lead + (cfg.d_model,), ll + (None,), init="ones"),
        "ffn": mlp_defs(cfg, lead),
    }


def stack_defs(cfg: ModelConfig) -> dict:
    """Parameter defs for the decoder stack of ``cfg``."""
    groups, per = cfg.scan_groups()
    if cfg.is_hybrid:
        return {
            "ssm_blocks": ssm_block_defs(cfg, lead=(groups, per)),
            "shared": dense_block_defs(cfg),  # ONE shared block (Zamba2)
        }
    if cfg.is_ssm:
        return {"ssm_blocks": ssm_block_defs(cfg, lead=(cfg.num_layers,))}
    if cfg.is_vlm:
        return {
            "self_blocks": dense_block_defs(cfg, lead=(groups, per - 1)),
            "cross_blocks": xattn_block_defs(cfg, lead=(groups,)),
        }
    if cfg.is_enc_dec:
        L = cfg.num_layers
        ll = ("layers",)
        return {
            "dec_blocks": {
                "ln1": ParamDef((L, cfg.d_model), ll + (None,), init="ones"),
                "attn": attn.attn_defs(cfg, (L,)),
                "lnx": ParamDef((L, cfg.d_model), ll + (None,), init="ones"),
                "xattn": attn.attn_defs(cfg, (L,), cross=True),
                "ln2": ParamDef((L, cfg.d_model), ll + (None,), init="ones"),
                "ffn": mlp_defs(cfg, (L,)),
            }
        }
    return {"blocks": dense_block_defs(cfg, lead=(cfg.num_layers,))}


def encoder_defs(cfg: ModelConfig) -> dict:
    return {
        "blocks": dense_block_defs(cfg, lead=(cfg.encoder_layers,)),
        "norm": rmsnorm_def(cfg.d_model),
    }


# ------------------------------------------------------------ block bodies
def dense_block(cfg, rules, p, x, positions, *, cache=None, cache_len=None,
                seen_len=None, emit_kv=None):
    h, new_cache = attn.self_attention(
        cfg, rules, p["attn"], rmsnorm(x, p["ln1"], cfg.norm_eps), positions,
        cache=cache, cache_len=cache_len, seen_len=seen_len, emit_kv=emit_kv,
    )
    x = x + h
    h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        x = x + moe_mod.moe_mlp(cfg, rules, p["ffn"], h2)
    else:
        x = x + mlp(cfg, rules, p["ffn"], h2)
    return x, new_cache


def ssm_block(cfg, rules, p, x, *, cache=None):
    h, new_cache = ssm_mod.ssm_mixer(
        cfg, rules, p["ssm"], rmsnorm(x, p["ln1"], cfg.norm_eps), cache=cache
    )
    return x + h, new_cache


def ssm_block_prefill(cfg, rules, p, x):
    h, cache = ssm_mod.ssm_prefill_mixer(
        cfg, rules, p["ssm"], rmsnorm(x, p["ln1"], cfg.norm_eps)
    )
    return x + h, cache


def xattn_block(cfg, rules, p, x, aux_kv):
    h = attn.cross_attention(
        cfg, rules, p["attn"], rmsnorm(x, p["ln1"], cfg.norm_eps), aux_kv
    )
    x = x + h
    x = x + mlp(cfg, rules, p["ffn"], rmsnorm(x, p["ln2"], cfg.norm_eps))
    return x


# -------------------------------------------------------------- the stacks
def _maybe_remat(cfg: ModelConfig, fn, train: bool):
    if not (train and cfg.remat) or cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        # save matmul outputs, recompute only elementwise ops in the backward
        # pass (-~25% recompute FLOPs and bytes vs full remat; §Perf)
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


def run_stack(
    cfg: ModelConfig,
    rules: ShardingRules,
    params: dict,
    x,
    positions,
    aux: Aux = Aux(),
    *,
    mode: str = "train",  # "train" | "prefill" | "decode"
    state: dict | None = None,  # decode caches (stacked)
    t_max: int | None = None,  # KV buffer length for prefill caches
    cache_len=None,  # decode: KV write slot (traced scalar)
    seen_len=None,  # decode: total tokens seen (mask horizon)
):
    """Returns (hidden, caches). ``caches`` is None in train mode; in prefill
    mode a freshly built stacked cache; in decode mode the updated one."""
    assert mode in ("train", "prefill", "decode")
    args = (cfg, rules, params, x, positions, aux, mode, state, t_max,
            cache_len, seen_len)
    if cfg.is_hybrid:
        return _hybrid_stack(*args)
    if cfg.is_ssm:
        return _ssm_stack(*args)
    if cfg.is_vlm:
        return _vlm_stack(*args)
    if cfg.is_enc_dec:
        return _encdec_stack(*args)
    return _dense_stack(*args)


def _dense_stack(cfg, rules, params, x, positions, aux, mode, state, t_max,
                 cache_len, seen_len):
    def body(carry, xs):
        if mode == "decode":
            p, c = xs
            return dense_block(cfg, rules, p, carry, positions, cache=c,
                               cache_len=cache_len, seen_len=seen_len)
        p = xs
        h, kv = dense_block(cfg, rules, p, carry, positions,
                            emit_kv=t_max if mode == "prefill" else None)
        return h, kv

    body = _maybe_remat(cfg, body, mode == "train")
    if mode == "decode":
        x, caches = jax.lax.scan(
            body, x, (params["blocks"], state["kv"]), unroll=cfg.scan_unroll
        )
        return x, {"kv": caches}
    x, ys = jax.lax.scan(body, x, params["blocks"], unroll=cfg.scan_unroll)
    return x, ({"kv": ys} if mode == "prefill" else None)


def _ssm_stack(cfg, rules, params, x, positions, aux, mode, state, t_max,
               cache_len, seen_len):
    def body(carry, xs):
        if mode == "decode":
            p, c = xs
            return ssm_block(cfg, rules, p, carry, cache=c)
        if mode == "prefill":
            return ssm_block_prefill(cfg, rules, xs, carry)
        h, _ = ssm_block(cfg, rules, xs, carry)
        return h, None

    body = _maybe_remat(cfg, body, mode == "train")
    if mode == "decode":
        x, caches = jax.lax.scan(
            body, x, (params["ssm_blocks"], state["ssm"]), unroll=cfg.scan_unroll
        )
        return x, {"ssm": caches}
    x, ys = jax.lax.scan(body, x, params["ssm_blocks"], unroll=cfg.scan_unroll)
    return x, ({"ssm": ys} if mode == "prefill" else None)


def _hybrid_stack(cfg, rules, params, x, positions, aux, mode, state, t_max,
                  cache_len, seen_len):
    groups, per = cfg.scan_groups()
    shared = params["shared"]

    def body(carry, xs):
        if mode == "decode":
            pg, ssm_c, kv_c = xs
            new_ssm = []
            for i in range(per):
                pi = jax.tree.map(lambda t: t[i], pg)
                ci = jax.tree.map(lambda t: t[i], ssm_c)
                carry, c2 = ssm_block(cfg, rules, pi, carry, cache=ci)
                new_ssm.append(c2)
            carry, kv2 = dense_block(cfg, rules, shared, carry, positions,
                                     cache=kv_c, cache_len=cache_len,
                                     seen_len=seen_len)
            stacked = jax.tree.map(lambda *ts: jnp.stack(ts), *new_ssm)
            return carry, (stacked, kv2)
        pg = xs
        ssm_caches = []
        for i in range(per):
            pi = jax.tree.map(lambda t: t[i], pg)
            if mode == "prefill":
                carry, c = ssm_block_prefill(cfg, rules, pi, carry)
                ssm_caches.append(c)
            else:
                carry, _ = ssm_block(cfg, rules, pi, carry)
        carry, kv = dense_block(cfg, rules, shared, carry, positions,
                                emit_kv=t_max if mode == "prefill" else None)
        if mode == "prefill":
            stacked = jax.tree.map(lambda *ts: jnp.stack(ts), *ssm_caches)
            return carry, (stacked, kv)
        return carry, None

    body = _maybe_remat(cfg, body, mode == "train")
    if mode == "decode":
        x, (ssm_c, kv_c) = jax.lax.scan(
            body, x, (params["ssm_blocks"], state["ssm"], state["kv"]),
            unroll=cfg.scan_unroll,
        )
        return x, {"ssm": ssm_c, "kv": kv_c}
    x, ys = jax.lax.scan(body, x, params["ssm_blocks"], unroll=cfg.scan_unroll)
    if mode == "prefill":
        ssm_c, kv_c = ys
        return x, {"ssm": ssm_c, "kv": kv_c}
    return x, None


def _vlm_stack(cfg, rules, params, x, positions, aux, mode, state, t_max,
               cache_len, seen_len):
    groups, per = cfg.scan_groups()
    vision = aux.vision

    def body(carry, xs):
        if mode == "decode":
            pg, pc, kv_c = xs  # kv_c: [per-1, B, T, Kv, Dh] pytree
            new_kv = []
            for i in range(per - 1):
                pi = jax.tree.map(lambda t: t[i], pg)
                ci = jax.tree.map(lambda t: t[i], kv_c)
                carry, c2 = dense_block(cfg, rules, pi, carry, positions,
                                        cache=ci, cache_len=cache_len,
                                        seen_len=seen_len)
                new_kv.append(c2)
            carry = xattn_block(cfg, rules, pc, carry, vision)
            stacked = jax.tree.map(lambda *ts: jnp.stack(ts), *new_kv)
            return carry, stacked
        pg, pc = xs
        kvs = []
        for i in range(per - 1):
            pi = jax.tree.map(lambda t: t[i], pg)
            carry, kv = dense_block(cfg, rules, pi, carry, positions,
                                    emit_kv=t_max if mode == "prefill" else None)
            if mode == "prefill":
                kvs.append(kv)
        carry = xattn_block(cfg, rules, pc, carry, vision)
        if mode == "prefill":
            return carry, jax.tree.map(lambda *ts: jnp.stack(ts), *kvs)
        return carry, None

    body = _maybe_remat(cfg, body, mode == "train")
    if mode == "decode":
        x, kv = jax.lax.scan(
            body, x, (params["self_blocks"], params["cross_blocks"], state["kv"]),
            unroll=cfg.scan_unroll,
        )
        return x, {"kv": kv}
    x, ys = jax.lax.scan(
        body, x, (params["self_blocks"], params["cross_blocks"]), unroll=cfg.scan_unroll
    )
    return x, ({"kv": ys} if mode == "prefill" else None)


def _encdec_stack(cfg, rules, params, x, positions, aux, mode, state, t_max,
                  cache_len, seen_len):
    memory = aux.memory

    def body(carry, xs):
        if mode == "decode":
            p, kv_c = xs
            xn = rmsnorm(carry, p["ln1"], cfg.norm_eps)
            h, kv2 = attn.self_attention(cfg, rules, p["attn"], xn, positions,
                                         cache=kv_c, cache_len=cache_len,
                                         seen_len=seen_len)
        else:
            p = xs
            xn = rmsnorm(carry, p["ln1"], cfg.norm_eps)
            h, kv2 = attn.self_attention(
                cfg, rules, p["attn"], xn, positions,
                emit_kv=t_max if mode == "prefill" else None)
        carry = carry + h
        carry = carry + attn.cross_attention(
            cfg, rules, p["xattn"], rmsnorm(carry, p["lnx"], cfg.norm_eps),
            memory)
        carry = carry + mlp(cfg, rules, p["ffn"],
                            rmsnorm(carry, p["ln2"], cfg.norm_eps))
        return carry, kv2

    body = _maybe_remat(cfg, body, mode == "train")
    blocks = params["dec_blocks"]
    if mode == "decode":
        x, kv = jax.lax.scan(body, x, (blocks, state["kv"]), unroll=cfg.scan_unroll)
        return x, {"kv": kv}
    x, ys = jax.lax.scan(body, x, blocks, unroll=cfg.scan_unroll)
    return x, ({"kv": ys} if mode == "prefill" else None)


def encoder_stack(cfg: ModelConfig, rules, params, frames):
    """Bidirectional encoder over precomputed frame embeddings [B, Sf, D]."""
    positions = jnp.arange(frames.shape[1])[None, :].astype(jnp.int32)
    positions = jnp.broadcast_to(positions, frames.shape[:2])

    def enc_block(carry, p):
        xn = rmsnorm(carry, p["ln1"], cfg.norm_eps)
        h, _ = attn.self_attention(cfg, rules, p["attn"], xn, positions,
                                   is_causal=False)
        carry = carry + h
        carry = carry + mlp(cfg, rules, p["ffn"],
                            rmsnorm(carry, p["ln2"], cfg.norm_eps))
        return carry, None

    x, _ = jax.lax.scan(enc_block, frames, params["blocks"], unroll=cfg.scan_unroll)
    return rmsnorm(x, params["norm"], cfg.norm_eps)
