"""Skytrace: the deterministic observability plane (ISSUE 9).

Two small, dependency-free primitives the rest of the repo instruments
itself with:

  * ``metrics`` — a process-local :class:`MetricsRegistry` of named
    counters / gauges / histograms. Ad-hoc module globals
    (``milp.N_STRUCT_BUILDS``) and report-only tallies
    (``GatewayReport.workers_leaked``) register here; reports expose a
    filtered snapshot through their ``to_dict()`` ``metrics`` section.
  * ``trace`` — a :class:`Tracer` recording spans, instant events and
    counter samples into a bounded ring buffer. Sim events carry
    sim-time; planner / gateway events carry ``perf_counter`` wall time
    re-based to the tracer's start. Disabled (the default) it is a
    shared no-op singleton and instrumented hot paths skip event
    construction entirely behind ``if tr.enabled:``.

``export`` renders a tracer's buffer as Chrome-trace / Perfetto JSON or
a plain-text timeline; ``python -m repro.obs`` runs a seeded chaos
scenario and exports its (byte-deterministic) sim trace.
"""

from __future__ import annotations

from .export import text_timeline, to_chrome_trace, trace_json, write_trace
from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    REGISTRY,
    get_registry,
)
from .trace import Tracer, disable, enable, get_tracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "REGISTRY",
    "Tracer",
    "disable",
    "enable",
    "get_registry",
    "get_tracer",
    "text_timeline",
    "to_chrome_trace",
    "trace_json",
    "write_trace",
]
