"""Trace a seeded chaos scenario and export it.

    PYTHONPATH=src python -m repro.obs [--seed N] [--out trace.json] [--text]

Plans two jobs on the default topology, compiles a seeded
``ChaosScenario`` against their routes, runs ``transfer.sim.simulate``
(the reference oracle with ``--sim ref``) with the tracer enabled, and writes
the Chrome-trace JSON — load it at https://ui.perfetto.dev or
``chrome://tracing``. The tracer is enabled AFTER planning, so the
exported trace contains only sim-time events and the same ``--seed``
produces byte-identical output across processes (pinned by
tests/test_obs.py).
"""

from __future__ import annotations

import argparse
import sys

from .export import text_timeline, trace_json
from .trace import disable, enable

SRC, DST = "aws:us-west-2", "aws:eu-central-1"
SRC2 = "gcp:us-central1"


def trace_chaos_scenario(
    seed: int = 0,
    *,
    volume_gb: float = 2.0,
    horizon_s: float = 12.0,
    capacity: int = 1 << 16,
    reference: bool = False,
) -> list:
    """Run the seeded chaos scenario under tracing; returns the events."""
    from repro.core import Planner, PlanSpec, default_topology
    from repro.transfer import ChaosScenario, TransferJob, simulate

    top = default_topology()
    planner = Planner(top, max_relays=6)
    s, d, s2 = top.index(SRC), top.index(DST), top.index(SRC2)
    jobs = [
        TransferJob(
            plan=planner.plan(PlanSpec(
                objective="cost_min", src=SRC, dst=DST,
                tput_goal_gbps=2.0, volume_gb=volume_gb,
            )),
            name="bulk-a", chunk_mb=64.0,
        ),
        TransferJob(
            plan=planner.plan(PlanSpec(
                objective="cost_min", src=SRC2, dst=DST,
                tput_goal_gbps=2.0, volume_gb=volume_gb,
            )),
            name="bulk-b", arrival_s=1.0, chunk_mb=64.0,
        ),
    ]
    sc = ChaosScenario(
        top, seed=seed, horizon_s=horizon_s * 0.5,
        n_brownouts=1, n_gray=1, n_flapping=1,
        links=[(s, d), (s2, d)],
    )
    engine = "ref" if reference else "soa"
    tr = enable(capacity=capacity)
    try:
        simulate(jobs, sc.events(len(jobs)), seed=seed,
                 horizon_s=horizon_s, drain=True, engine=engine)
        return tr.events()
    finally:
        disable()


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs", description=__doc__.splitlines()[0]
    )
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write Chrome-trace JSON here (default: stdout)")
    ap.add_argument("--text", action="store_true",
                    help="print a text timeline instead of JSON")
    ap.add_argument("--volume-gb", type=float, default=2.0)
    ap.add_argument("--horizon-s", type=float, default=12.0)
    ap.add_argument("--capacity", type=int, default=1 << 16,
                    help="trace ring-buffer capacity (events)")
    ap.add_argument("--sim", choices=("fast", "ref"), default="fast",
                    help="simulator: vectorized flowsim or the reference")
    args = ap.parse_args(argv)

    events = trace_chaos_scenario(
        args.seed, volume_gb=args.volume_gb, horizon_s=args.horizon_s,
        capacity=args.capacity, reference=args.sim == "ref",
    )
    if args.text:
        print(text_timeline(events))
        return 0
    payload = trace_json(events)
    if args.out:
        with open(args.out, "w", encoding="utf-8") as fh:
            fh.write(payload)
            fh.write("\n")
        print(f"# {len(events)} events -> {args.out}", file=sys.stderr)
    else:
        print(payload)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
