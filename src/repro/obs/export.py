"""Render a tracer's ring buffer: Chrome-trace JSON or a text timeline.

Chrome-trace output loads directly in Perfetto (ui.perfetto.dev) or
``chrome://tracing``. Timestamps quantize to integer microseconds at
export — the sims' float clocks can diverge at ulp level between the
vectorized and reference loops (different summation orders), and the
quantization is what makes their traces byte-identical.

``trace_json`` serializes with sorted keys and no whitespace so that the
same event stream always produces the same bytes (the cross-process
determinism pin in tests/test_obs.py).
"""

from __future__ import annotations

import json


def _us(ts_s: float) -> int:
    return int(round(ts_s * 1e6))


def to_chrome_trace(events) -> dict:
    """Chrome-trace (trace-event format) dict for a list of event tuples.

    Tracks become tids in order of first appearance, each announced with
    a ``thread_name`` metadata record so Perfetto labels the lanes."""
    tids: dict[str, int] = {}
    rows = []
    for ph, name, ts_s, dur_s, track, args in events:
        tid = tids.setdefault(track, len(tids) + 1)
        row = {"name": name, "ph": ph, "ts": _us(ts_s), "pid": 1, "tid": tid}
        if ph == "X":
            row["dur"] = max(_us(dur_s), 1)
        if args:
            row["args"] = args
        rows.append(row)
    meta = [
        {
            "name": "thread_name", "ph": "M", "pid": 1, "tid": tid,
            "args": {"name": track},
        }
        for track, tid in tids.items()
    ]
    return {"traceEvents": meta + rows, "displayTimeUnit": "ms"}


def trace_json(events) -> str:
    """Canonical (byte-stable) JSON serialization of a trace."""
    return json.dumps(
        to_chrome_trace(events), sort_keys=True, separators=(",", ":")
    )


def write_trace(events, path: str) -> None:
    with open(path, "w", encoding="utf-8") as fh:
        fh.write(trace_json(events))
        fh.write("\n")


def _fmt_args(args: dict) -> str:
    return " ".join(f"{k}={v}" for k, v in args.items())


def text_timeline(events, limit: int | None = None) -> str:
    """Human-readable one-line-per-event rendering, time-ordered as
    recorded. ``limit`` keeps only the last N events."""
    evs = list(events)
    if limit is not None:
        evs = evs[-limit:]
    lines = []
    for ph, name, ts_s, dur_s, track, args in evs:
        stamp = f"{ts_s * 1e3:12.3f}ms"
        tail = f" {_fmt_args(args)}" if args else ""
        if ph == "X":
            lines.append(
                f"{stamp} [{track}] {name} +{dur_s * 1e3:.3f}ms{tail}"
            )
        else:
            lines.append(f"{stamp} [{track}] {name}{tail}")
    return "\n".join(lines)
