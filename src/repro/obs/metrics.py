"""Process-local metrics registry.

One named instrument per fact the repo used to track ad hoc: the LP
structure-build counter that every zero-re-assembly test pins, leaked
gateway workers, probe spend, dedup hits, breaker trips, epoch rolls.
Instruments are get-or-create by name, so instrumentation sites can hold
a module-level reference (``_trips = REGISTRY.counter("breaker.trips")``)
and tests can read the same instrument back by name.

Names are dotted, ``<plane>.<fact>`` (``gateway.workers_leaked``,
``planner.struct_builds``, ``calibrate.probe_usd``); report classes pick
their ``metrics`` section out of the registry by plane prefix.

``reset()`` zeroes every instrument IN PLACE — cached references stay
valid — which is what the test-suite conftest fixture calls between
tests.
"""

from __future__ import annotations

import threading


class Counter:
    """Monotonically increasing value (int or float increments)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0
        self._lock = threading.Lock()

    @property
    def value(self):
        return self._value

    def inc(self, n=1) -> None:
        with self._lock:
            self._value += n

    def reset(self) -> None:
        with self._lock:
            self._value = 0

    def _snapshot(self):
        return self._value if self._value else None


class Gauge:
    """Last-written value; absent from snapshots until first ``set``."""

    __slots__ = ("name", "_value", "_set", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._set = False
        self._lock = threading.Lock()

    @property
    def value(self):
        return self._value

    def set(self, v) -> None:
        with self._lock:
            self._value = v
            self._set = True

    def reset(self) -> None:
        with self._lock:
            self._value = 0.0
            self._set = False

    def _snapshot(self):
        return self._value if self._set else None


class Histogram:
    """Count / total / min / max summary of observed values."""

    __slots__ = ("name", "count", "total", "min", "max", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._zero()

    def _zero(self) -> None:
        self.count = 0
        self.total = 0.0
        self.min = None
        self.max = None

    def observe(self, v) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.total += v
            self.min = v if self.min is None else min(self.min, v)
            self.max = v if self.max is None else max(self.max, v)

    def reset(self) -> None:
        with self._lock:
            self._zero()

    def _snapshot(self):
        if not self.count:
            return None
        return {
            "count": self.count,
            "total": self.total,
            "min": self.min,
            "max": self.max,
        }


class MetricsRegistry:
    """Get-or-create home for every named instrument in the process."""

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: dict = {}

    def _get(self, name: str, cls):
        with self._lock:
            m = self._instruments.get(name)
            if m is None:
                m = cls(name)
                self._instruments[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}"
                )
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._instruments)

    def snapshot(self, prefixes: tuple = ()) -> dict:
        """Name -> value for every non-empty instrument, sorted by name.

        ``prefixes`` filters to the given dotted-name prefixes (a report's
        plane selection); empty means everything."""
        with self._lock:
            items = sorted(self._instruments.items())
        out: dict = {}
        for name, m in items:
            if prefixes and not any(name.startswith(p) for p in prefixes):
                continue
            v = m._snapshot()
            if v is not None:
                out[name] = v
        return out

    def reset(self) -> None:
        """Zero every instrument in place (cached references stay live)."""
        with self._lock:
            items = list(self._instruments.values())
        for m in items:
            m.reset()


# The process-local default registry every instrumentation site uses.
REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return REGISTRY
