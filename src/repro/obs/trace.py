"""Bounded, deterministic event tracer.

A :class:`Tracer` records events into a ``deque(maxlen=capacity)`` ring
buffer — appends are GIL-atomic, so gateway worker threads emit without
a lock, and an unbounded run can never exhaust memory (old events fall
off the front).

Event timebases, by track:

  * ``sim`` — sim-time seconds from the simulators' own clocks. Two runs
    with the same seed produce byte-identical traces, and ``flowsim`` /
    ``flowsim_ref`` emit identical sim-event streams (pinned by
    tests/test_obs.py).
  * ``planner`` / ``gateway`` / ``service`` wall spans —
    ``time.perf_counter()`` re-based to the tracer's start
    (``now_wall``); legal under SKY001, nondeterministic by nature.

The default tracer is a shared no-op singleton with ``enabled = False``.
Instrumented hot paths capture ``tr = get_tracer()`` once and guard
every emission with ``if tr.enabled:`` so disabled-mode overhead is one
attribute read (unmeasurable on ``flowsim_bench`` — gated by
``BENCH_obs.json``).
"""

from __future__ import annotations

import time
from collections import deque

DEFAULT_CAPACITY = 1 << 16

# Event tuples: (phase, name, ts_s, dur_s, track, args-or-None) with
# Chrome-trace phases — "X" complete span, "i" instant, "C" counter.


class Tracer:
    enabled = True

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        self.capacity = int(capacity)
        self._buf: deque = deque(maxlen=self.capacity)
        self._wall0 = time.perf_counter()

    def now_wall(self) -> float:
        """Wall seconds since this tracer was created (perf_counter)."""
        return time.perf_counter() - self._wall0

    def instant(self, name: str, ts_s: float, track: str = "sim", **args):
        self._buf.append(("i", name, float(ts_s), 0.0, track, args or None))

    def span(self, name: str, ts_s: float, dur_s: float,
             track: str = "sim", **args):
        self._buf.append(
            ("X", name, float(ts_s), float(dur_s), track, args or None)
        )

    def sample(self, name: str, ts_s: float, value, track: str = "sim"):
        self._buf.append(
            ("C", name, float(ts_s), 0.0, track, {"value": value})
        )

    def events(self) -> list:
        return list(self._buf)

    def clear(self) -> None:
        self._buf.clear()

    def __len__(self) -> int:
        return len(self._buf)


class _NullTracer(Tracer):
    """The disabled tracer: every emission is a no-op."""

    enabled = False

    def __init__(self):
        super().__init__(capacity=0)

    def instant(self, name, ts_s, track="sim", **args):
        pass

    def span(self, name, ts_s, dur_s, track="sim", **args):
        pass

    def sample(self, name, ts_s, value, track="sim"):
        pass


_NULL = _NullTracer()
_CURRENT: list[Tracer] = [_NULL]  # one-slot box: swap, never rebind


def get_tracer() -> Tracer:
    """The process-current tracer (the no-op singleton when disabled)."""
    return _CURRENT[0]


def enable(capacity: int = DEFAULT_CAPACITY) -> Tracer:
    """Install (and return) a fresh recording tracer."""
    tr = Tracer(capacity=capacity)
    _CURRENT[0] = tr
    return tr


def disable() -> None:
    """Restore the shared no-op tracer."""
    _CURRENT[0] = _NULL
