"""Serving steps: prefill (prompt -> caches) and serve_step (one new token
against a KV/SSM state of ``seq_len``) — the functions the decode-shape
dry-run cells lower.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import decode_step, prefill
from repro.sharding.specs import ShardingRules


def make_prefill_step(cfg: ModelConfig, rules: ShardingRules, *, t_max: int):
    def prefill_step(params, batch):
        state, last_logits = prefill(cfg, rules, params, batch, t_max=t_max)
        return state, last_logits

    return prefill_step


def make_serve_step(cfg: ModelConfig, rules: ShardingRules, *, greedy: bool = True):
    """serve_step(params, state, tokens[B,1]) -> (next_tokens[B,1], state)."""

    def serve_step(params, state, tokens):
        logits, state = decode_step(cfg, rules, params, state, tokens)
        if greedy:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        else:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return nxt, state

    return serve_step
