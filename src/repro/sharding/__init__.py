from .specs import (  # noqa: F401
    ShardingRules,
    current_mesh,
    logical_to_physical,
    make_param_shardings,
    set_mesh,
    shard_constraint,
    shardings_for,
)
