"""Logical-axis sharding rules (MaxText-style) for the whole framework.

Model code annotates tensors with *logical* axis names ("batch", "heads",
"ff", ...). A ``ShardingRules`` instance maps each logical name to zero or
more *mesh* axes. Changing the parallelism scheme (the §Perf hillclimb knob)
means swapping rules, never touching model code.

Default scheme:
  batch   -> ("pod", "data")   pure DP over pods, batch-DP within a pod
  fsdp    -> "data"            parameters fully sharded over the data axis
  tp      -> "model"           tensor parallelism (heads / ff / vocab / experts)
  seq     -> None              (context parallelism only for long-decode rules)

Mesh plumbing: the launcher calls ``set_mesh(mesh)``; ``shard_constraint``
then attaches ``NamedSharding`` constraints inside jit-traced code. With no
mesh set (CPU unit tests), constraints are no-ops.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def set_mesh(mesh: Mesh | None) -> None:
    _state.mesh = mesh


def current_mesh() -> Mesh | None:
    return getattr(_state, "mesh", None)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Maps logical axis names to mesh axes (str, tuple of str, or None)."""

    batch: Any = ("pod", "data")
    fsdp: Any = "data"  # parameter sharding (ZeRO-3 style)
    tp: Any = "model"  # tensor parallel
    seq: Any = None  # sequence/context parallel
    expert: Any = "model"  # expert parallel
    # set fsdp_pod to also shard params/optimizer over the pod axis (ZeRO-3
    # across pods; trades parameter all-gather traffic on DCN for memory).
    fsdp_pod: bool = False

    def resolve(self, logical: str | None):
        if logical is None or logical == "layers":
            return None  # the stacked-layer axis is never sharded
        axes = {
            "batch": self.batch,
            "fsdp": self._fsdp_axes(),
            "tp": self.tp,
            "seq": self.seq,
            "expert": self.expert,
        }[logical]
        return axes

    def _fsdp_axes(self):
        if self.fsdp is None:
            return None
        if self.fsdp_pod:
            base = self.fsdp if isinstance(self.fsdp, tuple) else (self.fsdp,)
            return ("pod",) + base
        return self.fsdp

    def filter_for_mesh(self, mesh: Mesh | None) -> "ShardingRules":
        """Drop references to mesh axes that don't exist (e.g. 'pod' on the
        single-pod mesh)."""
        if mesh is None:
            return self
        names = set(mesh.axis_names)

        def keep(v):
            if v is None:
                return None
            if isinstance(v, tuple):
                kept = tuple(a for a in v if a in names)
                return kept if kept else None
            return v if v in names else None

        return dataclasses.replace(
            self,
            batch=keep(self.batch),
            fsdp=keep(self.fsdp),
            tp=keep(self.tp),
            seq=keep(self.seq),
            expert=keep(self.expert),
            fsdp_pod=self.fsdp_pod and "pod" in names,
        )


def logical_to_physical(
    rules: ShardingRules,
    logical: Sequence[str | None],
    shape: Sequence[int] | None = None,
    mesh: Mesh | None = None,
) -> P:
    """Resolve logical axes to a PartitionSpec.

    Shape-aware: a mesh axis (product) that does not evenly divide the dim is
    dropped (the dim stays replicated). jit's in_shardings rejects uneven
    shardings, and several pool archs have head counts that don't divide the
    16-wide model axis (e.g. qwen2's 28 heads / 8 kv heads) — those dims fall
    back to replication; §Perf revisits them (head-dim sharding etc.).
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh is not None else {}
    axes = []
    used: set[str] = set()
    for d, name in enumerate(logical):
        ax = rules.resolve(name)
        if ax is None:
            axes.append(None)
            continue
        flat = ax if isinstance(ax, tuple) else (ax,)
        flat = tuple(a for a in flat if a not in used)
        if shape is not None and sizes:
            prod = 1
            for a in flat:
                prod *= sizes.get(a, 1)
            if prod == 0 or (prod and shape[d] % prod != 0):
                # try dropping trailing axes until it divides
                while flat:
                    prod = 1
                    for a in flat:
                        prod *= sizes.get(a, 1)
                    if prod and shape[d] % prod == 0:
                        break
                    flat = flat[:-1]
                if not flat:
                    axes.append(None)
                    continue
                prod = 1
                for a in flat:
                    prod *= sizes.get(a, 1)
                if shape[d] % prod != 0:
                    axes.append(None)
                    continue
        used.update(flat)
        axes.append(flat if len(flat) > 1 else (flat[0] if flat else None))
    return P(*axes)


def shard_constraint(x, rules: ShardingRules, *logical: str | None):
    """with_sharding_constraint on a logical spec; no-op without a mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = logical_to_physical(
        rules.filter_for_mesh(mesh), logical, shape=x.shape, mesh=mesh
    )
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _is_logical(s) -> bool:
    return isinstance(s, tuple) and all(e is None or isinstance(e, str) for e in s)


def make_param_shardings(mesh: Mesh, rules: ShardingRules, abstract_tree):
    """pytree of ParamDef -> pytree of NamedSharding (shape-aware)."""
    from repro.models.params import is_def

    rules = rules.filter_for_mesh(mesh)
    return jax.tree.map(
        lambda pd: NamedSharding(
            mesh, logical_to_physical(rules, pd.logical, pd.shape, mesh)
        ),
        abstract_tree,
        is_leaf=is_def,
    )


def shardings_for(mesh: Mesh, rules: ShardingRules, logical_tree, sds_tree):
    """(logical tuples tree, ShapeDtypeStruct tree) -> NamedSharding tree."""
    rules = rules.filter_for_mesh(mesh)
    return jax.tree.map(
        lambda spec, sds: NamedSharding(
            mesh, logical_to_physical(rules, spec, sds.shape, mesh)
        ),
        logical_tree,
        sds_tree,
        is_leaf=lambda s: _is_logical(s),
    )
