from .optimizer import (  # noqa: F401
    OptConfig,
    adamw_update,
    init_opt_state,
    opt_state_logical,
)
from .train_step import make_train_step  # noqa: F401
