from .optimizer import OptConfig, adamw_update, init_opt_state, opt_state_logical  # noqa: F401
from .train_step import make_train_step  # noqa: F401
