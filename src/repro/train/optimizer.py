"""AdamW with warmup+cosine schedule and global-norm clipping.

Optimizer state (m, v) inherits the parameter sharding (FSDP), so per-chip
optimizer memory is params/chips * 8 bytes on top of the f32 master weights.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    clip_norm: float = 1.0


def init_opt_state(params) -> dict:
    def zeros(p):
        return jnp.zeros_like(p)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_logical(param_logical) -> dict:
    return {"m": param_logical, "v": param_logical, "step": ()}


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def global_norm(tree):
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(grads, params, state, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m2 / bc1
        vhat = v2 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p
        return p - lr * delta, m2, v2

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(
        lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple)
    )
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return (
        new_params,
        {"m": new_m, "v": new_v, "step": step},
        {"grad_norm": gnorm, "lr": lr},
    )
