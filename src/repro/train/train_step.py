"""The jitted training step: loss -> grads -> AdamW, with optional
microbatched gradient accumulation and a pluggable pod-axis gradient
reduction (the Skyplane-planned / compressed path from repro.transfer).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import loss_fn
from repro.sharding.specs import ShardingRules
from .optimizer import OptConfig, adamw_update


def make_train_step(
    cfg: ModelConfig,
    rules: ShardingRules,
    opt_cfg: OptConfig,
    *,
    microbatches: int = 1,
    grad_transform: Callable | None = None,
):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    grad_transform: optional hook applied to the f32 grad pytree before the
    optimizer (e.g. transfer.collective.compressed_pod_allreduce).
    """

    def compute_grads(params, batch):
        def lw(p, b):
            if cfg.cast_params_once:
                # cast the whole tree to the compute dtype up front: FSDP
                # all-gathers then move bf16 (half the f32 bytes); the cast
                # is linear so grads flow back to the f32 masters unchanged.
                dt = jnp.dtype(cfg.dtype)
                p = jax.tree.map(
                    lambda t: t.astype(dt) if t.dtype == jnp.float32 else t, p
                )
            loss, metrics = loss_fn(cfg, rules, p, b)
            return loss, metrics

        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(lw, has_aux=True)(
                params, batch
            )
            return grads, loss, metrics

        def split(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])

        mbs = jax.tree.map(split, batch)

        def acc(carry, mb):
            g_acc, l_acc = carry
            (loss, _), grads = jax.value_and_grad(lw, has_aux=True)(params, mb)
            g_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), g_acc, grads
            )
            return (g_acc, l_acc + loss), None

        g0 = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        (grads, loss_sum), _ = jax.lax.scan(acc, (g0, jnp.zeros((), jnp.float32)), mbs)
        inv = 1.0 / microbatches
        grads = jax.tree.map(lambda g: g * inv, grads)
        loss = loss_sum * inv
        return grads, loss, {"loss": loss}

    def train_step(params, opt_state, batch):
        grads, loss, metrics = compute_grads(params, batch)
        if grad_transform is not None:
            grads = grad_transform(grads)
        params, opt_state, opt_metrics = adamw_update(
            grads, params, opt_state, opt_cfg
        )
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_podring_train_step(
    cfg: ModelConfig,
    rules: ShardingRules,
    opt_cfg: OptConfig,
    mesh,
    *,
    compress_wire: bool = True,
    pod_tput=None,
):
    """Inter-pod DP with an explicit, planner-ordered, optionally int8-
    compressed ring all-reduce (the paper's egress-volume lever applied to
    gradients on the DCN) instead of GSPMD's automatic pod all-reduce.

    Structure: shard_map manual over 'pod' (auto over data/model). Each pod
    computes grads on its batch shard with FSDP/TP handled by GSPMD inside;
    the ring then averages grads across pods — moving int8+scales on the
    DCN wire when compress_wire is set (4x fewer inter-pod bytes)."""
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.transfer.collective import choose_ring_order, ring_allreduce_tree

    assert "pod" in mesh.axis_names
    n_pods = dict(zip(mesh.axis_names, mesh.devices.shape))["pod"]
    order = choose_ring_order(
        pod_tput if pod_tput is not None else np.ones((n_pods, n_pods))
    )
    # inside the pod-manual region, batch parallelism only spans 'data'
    import dataclasses as _dc

    inner_rules = _dc.replace(rules, batch="data")

    def body(params, opt_state, batch_local):
        def lw(p, b):
            if cfg.cast_params_once:
                dt = jnp.dtype(cfg.dtype)
                p = jax.tree.map(
                    lambda t: t.astype(dt) if t.dtype == jnp.float32 else t, p
                )
            loss, metrics = loss_fn(cfg, inner_rules, p, b)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(lw, has_aux=True)(
            params, batch_local
        )
        grads = ring_allreduce_tree(
            grads, "pod", order, compress_wire=compress_wire, mean=True
        )
        params2, opt2, opt_metrics = adamw_update(
            grads, params, opt_state, opt_cfg
        )
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = jax.lax.pmean(loss, "pod")
        return params2, opt2, metrics

    def step(params, opt_state, batch):
        return jax.shard_map(
            body,
            mesh=mesh,
            in_specs=(P(), P(), P("pod")),
            out_specs=(P(), P(), P()),
            axis_names=frozenset({"pod"}),
            check_vma=False,
        )(params, opt_state, batch)

    return step
