"""Fault-tolerant training loop.

Production behaviors implemented (and tested with injected failures):
  * periodic async checkpoints (params + optimizer + data-pipeline state);
  * crash/restart: on failure the loop restores the newest committed
    checkpoint — including the exact pipeline position — and continues;
  * step-time watchdog: steps slower than ``straggler_factor`` x the running
    median are logged as straggler events (at fleet scale these feed the
    scheduler; here they feed metrics);
  * optional cross-region checkpoint replication through the Skyplane
    planner (repro.ckpt.replicate) on a cadence;
  * optional planner-scheduled compressed pod-axis gradient reduction.
"""

from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Callable

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.ckpt.checkpoint import CheckpointManager
from repro.data.pipeline import ShardedTokenPipeline
from repro.models import init_params
from repro.sharding.specs import ShardingRules
from .optimizer import OptConfig, init_opt_state
from .train_step import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 128
    ckpt_every: int = 25
    ckpt_dir: str = "artifacts/ckpt"
    keep_ckpts: int = 3
    seed: int = 0
    microbatches: int = 1
    straggler_factor: float = 3.0
    log_every: int = 10


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        tcfg: TrainerConfig,
        *,
        rules: ShardingRules | None = None,
        opt_cfg: OptConfig | None = None,
        grad_transform: Callable | None = None,
        failure_injector: Callable[[int], bool] | None = None,
        on_checkpoint: Callable[[Path, int], None] | None = None,
    ):
        self.cfg = cfg
        self.tcfg = tcfg
        self.rules = rules or ShardingRules(batch=None, fsdp=None, tp=None)
        self.opt_cfg = opt_cfg or OptConfig(total_steps=tcfg.steps)
        self.failure_injector = failure_injector
        self.on_checkpoint = on_checkpoint
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep_ckpts)
        self.pipeline = ShardedTokenPipeline(
            cfg, global_batch=tcfg.global_batch, seq_len=tcfg.seq_len,
            seed=tcfg.seed,
        )
        step_fn = make_train_step(
            cfg, self.rules, self.opt_cfg,
            microbatches=tcfg.microbatches, grad_transform=grad_transform,
        )
        self._jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
        self.metrics_log: list[dict] = []
        self.restarts = 0
        self.straggler_events = 0

    # ------------------------------------------------------------- lifecycle
    def _fresh_state(self):
        params = init_params(self.cfg, jax.random.PRNGKey(self.tcfg.seed))
        return params, init_opt_state(params)

    def _restore_or_init(self):
        params, opt_state = self._fresh_state()
        tree = {"params": params, "opt": opt_state}
        restored, step, extra = self.ckpt.restore(tree)
        if restored is None:
            return params, opt_state, 0
        if "pipeline" in extra:
            self.pipeline.load_state_dict(extra["pipeline"])
        return restored["params"], restored["opt"], step

    # ------------------------------------------------------------------ loop
    def run(self) -> dict:
        params, opt_state, start = self._restore_or_init()
        step = start
        times: list[float] = []
        while step < self.tcfg.steps:
            try:
                batch = next(self.pipeline)
                if self.failure_injector and self.failure_injector(step):
                    raise RuntimeError(f"injected node failure at step {step}")
                t0 = time.time()
                params, opt_state, metrics = self._jit_step(params, opt_state, batch)
                metrics = {k: float(v) for k, v in metrics.items()}
                dt = time.time() - t0
                times.append(dt)
                med = float(np.median(times[-50:]))
                if len(times) > 5 and dt > self.tcfg.straggler_factor * med:
                    self.straggler_events += 1
                    metrics["straggler"] = dt / med
                step += 1
                metrics["step"] = step
                metrics["step_time_s"] = dt
                if step % self.tcfg.log_every == 0 or step == self.tcfg.steps:
                    self.metrics_log.append(metrics)
                if step % self.tcfg.ckpt_every == 0 or step == self.tcfg.steps:
                    self.ckpt.save_async(
                        step,
                        {"params": params, "opt": opt_state},
                        extra={"pipeline": self.pipeline.state_dict()},
                    )
                    if self.on_checkpoint:
                        self.ckpt.wait()
                        path = self.ckpt.latest()
                        if path is not None:
                            self.on_checkpoint(path, step)
            except RuntimeError as ex:
                if "injected node failure" not in str(ex):
                    raise
                # ---- restart path: restore last committed state
                self.restarts += 1
                self.ckpt.wait()
                params, opt_state, step = self._restore_or_init()
        self.ckpt.wait()
        return {
            "final_step": step,
            "restarts": self.restarts,
            "straggler_events": self.straggler_events,
            "losses": [m["loss"] for m in self.metrics_log],
            "metrics": self.metrics_log,
        }
