from .chunk import Chunk, chunk_object, checksum  # noqa: F401
from .flowsim import SimResult, simulate_transfer  # noqa: F401
from .flowsim_ref import simulate_transfer_reference  # noqa: F401
from .executor import execute_plan, execute_service_model  # noqa: F401
