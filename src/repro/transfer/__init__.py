from .chunk import Chunk, chunk_manifest, chunk_object, checksum  # noqa: F401
from .flowsim import SimResult, simulate_multi, simulate_transfer  # noqa: F401
from .flowsim_ref import (  # noqa: F401
    simulate_multi_reference,
    simulate_transfer_reference,
)
from .events import (  # noqa: F401
    GrayFailure,
    JobSimResult,
    LinkDegrade,
    LinkRestore,
    MultiSimResult,
    TransferJob,
    VMFailure,
)
from .breaker import (  # noqa: F401
    BreakerConfig,
    BreakerTransition,
    LinkBreaker,
)
from .chaos import (  # noqa: F401
    ChaosScenario,
    FlappingLink,
    GrayLink,
    ProviderBrownout,
    RegionOutage,
    compile_archetypes,
)
from .executor import (  # noqa: F401
    BackoffLadder,
    DegradationLadder,
    ExecutionReport,
    JobReport,
    ReplanRecord,
    ServiceReport,
    TransferRequest,
    TransferService,
    execute_plan,
    execute_service_model,
)
from .gateway import (  # noqa: F401
    BlobStore,
    DirStore,
    FaultInjector,
    GatewayReport,
    MulticastGatewayReport,
    ObjectStore,
    transfer_objects,
    transfer_objects_multicast,
)
