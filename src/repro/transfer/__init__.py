from .chunk import Chunk, chunk_manifest, chunk_object, checksum  # noqa: F401
from .simconfig import SimConfig  # noqa: F401
from .sim import simulate  # noqa: F401
from .flowsim import SimResult, simulate_multi, simulate_transfer  # noqa: F401
from .flowsim_ref import (  # noqa: F401
    simulate_multi_reference,
    simulate_transfer_reference,
)
from .events import (  # noqa: F401
    GrayFailure,
    JobSimResult,
    LinkDegrade,
    LinkRestore,
    MultiSimResult,
    TransferJob,
    VMFailure,
)
from .breaker import (  # noqa: F401
    BreakerConfig,
    BreakerTransition,
    LinkBreaker,
)
from .chaos import (  # noqa: F401
    ChaosScenario,
    FlappingLink,
    GrayLink,
    ProviderBrownout,
    RegionOutage,
    compile_archetypes,
)
from .reports import Report  # noqa: F401
from .executor import (  # noqa: F401
    BackoffLadder,
    DegradationLadder,
    ExecutionReport,
    JobReport,
    ReplanRecord,
    ServiceReport,
    TransferRequest,
    TransferService,
    execute_plan,
    execute_service_model,
)
from .gateway import (  # noqa: F401
    BlobStore,
    DirStore,
    FaultInjector,
    GatewayReport,
    MulticastGatewayReport,
    ObjectStore,
    transfer_objects,
    transfer_objects_multicast,
)

# The fleet controller subclasses the calibration plane's service, which
# itself imports this package's executor — importing it lazily (PEP 562)
# keeps `import repro.calibrate` from hitting a half-initialized module.
_FLEET_NAMES = ("FleetController", "FleetReport", "TenantReport",
                "TenantSpec")

__all__ = [
    "BackoffLadder",
    "BlobStore",
    "BreakerConfig",
    "BreakerTransition",
    "ChaosScenario",
    "Chunk",
    "DegradationLadder",
    "DirStore",
    "ExecutionReport",
    "FaultInjector",
    "FlappingLink",
    "FleetController",
    "FleetReport",
    "GatewayReport",
    "GrayFailure",
    "GrayLink",
    "JobReport",
    "JobSimResult",
    "LinkBreaker",
    "LinkDegrade",
    "LinkRestore",
    "MultiSimResult",
    "MulticastGatewayReport",
    "ObjectStore",
    "ProviderBrownout",
    "RegionOutage",
    "ReplanRecord",
    "Report",
    "ServiceReport",
    "SimConfig",
    "SimResult",
    "TenantReport",
    "TenantSpec",
    "TransferJob",
    "TransferRequest",
    "TransferService",
    "VMFailure",
    "checksum",
    "chunk_manifest",
    "chunk_object",
    "compile_archetypes",
    "execute_plan",
    "execute_service_model",
    "simulate",
    "simulate_multi",
    "simulate_multi_reference",
    "simulate_transfer",
    "simulate_transfer_reference",
    "transfer_objects",
    "transfer_objects_multicast",
]


def __getattr__(name):
    if name in _FLEET_NAMES:
        from . import fleet

        return getattr(fleet, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
