"""Link circuit breaker: quarantine flaky links instead of retrying them.

A link that fails or flaps repeatedly inside a short window is not worth
re-planning onto — every re-plan that trusts its restored capacity walks
the next transfer into the next flap. The breaker gives the service a
three-state policy per directed link:

  * **closed**    — healthy; failures are counted in a sliding window;
  * **open**      — ``k`` failures-or-flaps landed within ``window_s``:
    the link is quarantined (the service pins its degraded-view factor to
    0.0, which the planner turns into ``extra_ub = 0`` rows on the CACHED
    LP structures — zero re-assembly) and no plan may use it;
  * **half-open** — ``cooldown_s`` after opening, one probe is allowed
    through: healthy (``>= heal_ratio`` of the epoch grid) closes the
    breaker and lifts the quarantine, unhealthy re-opens it for another
    cooldown.

The breaker itself is pure bookkeeping — it never touches a plan or a
belief. The TransferService owns the quarantine view; the calibrated
service additionally routes the half-open probe through its Calibrator
and feeds the measurement to ``BeliefGrid.reset_link`` so the belief
treats quarantine entry/exit as a regime change, not more noise.
"""

from __future__ import annotations

import dataclasses
from collections import deque

from repro.obs.metrics import REGISTRY
from repro.obs.trace import get_tracer

Link = tuple[int, int]

_trips = REGISTRY.counter("breaker.trips")


@dataclasses.dataclass(frozen=True)
class BreakerConfig:
    k: int = 3  # failures-or-flaps within window_s that open the breaker
    window_s: float = 30.0
    cooldown_s: float = 20.0  # open -> half-open delay
    heal_ratio: float = 0.5  # half-open probe must measure this fraction
    # of the epoch-grid rate for the breaker to close


@dataclasses.dataclass
class _LinkState:
    failures: deque = dataclasses.field(default_factory=deque)  # times
    state: str = "closed"  # "closed" | "open" | "half_open"
    opened_at: float = 0.0
    restore_seen: bool = False  # a LinkRestore arrived since opening
    trips: int = 0


@dataclasses.dataclass(frozen=True)
class BreakerTransition:
    """One audit-trail entry: the breaker changed state on a link."""

    t_s: float
    link: Link
    state: str  # the state entered: "open" | "half_open" | "closed"
    failures_in_window: int = 0


class LinkBreaker:
    """Per-link failure counting and open/half-open/closed transitions.

    All methods take the scenario clock ``t_s`` explicitly — the breaker
    holds no wall-clock state, so simulated services drive it with
    simulated time and tests are deterministic.
    """

    def __init__(self, config: BreakerConfig | None = None, **kw):
        self.config = config if config is not None else BreakerConfig(**kw)
        if self.config.k < 1:
            raise ValueError("breaker needs k >= 1")
        self._links: dict[Link, _LinkState] = {}
        self.transitions: list[BreakerTransition] = []

    def _state(self, link: Link) -> _LinkState:
        return self._links.setdefault(link, _LinkState())

    # ------------------------------------------------------------- signals
    def record_failure(self, link: Link, t_s: float) -> bool:
        """Count one failure-or-flap on ``link`` at ``t_s``. Returns True
        when this failure just OPENED the breaker (the caller quarantines
        the link); failures on an already-open link only refresh the
        window."""
        st = self._state(link)
        st.failures.append(float(t_s))
        lo = float(t_s) - self.config.window_s
        while st.failures and st.failures[0] < lo:
            st.failures.popleft()
        if st.state != "closed":
            return False
        if len(st.failures) >= self.config.k:
            st.state = "open"
            st.opened_at = float(t_s)
            st.restore_seen = False
            st.trips += 1
            _trips.inc()
            self.transitions.append(BreakerTransition(
                t_s=float(t_s), link=link, state="open",
                failures_in_window=len(st.failures),
            ))
            tr = get_tracer()
            if tr.enabled:
                tr.instant("breaker.open", float(t_s), track="breaker",
                           link=f"{link[0]}->{link[1]}",
                           failures=len(st.failures))
            return True
        return False

    def note_restore(self, link: Link, t_s: float) -> None:
        """A visible LinkRestore arrived — on an open link this is the
        base service's stand-in health signal for the half-open check
        (the calibrated service probes instead)."""
        st = self._links.get(link)
        if st is not None and st.state in ("open", "half_open"):
            st.restore_seen = True

    # -------------------------------------------------------- transitions
    def is_quarantined(self, link: Link) -> bool:
        """True while no plan may use the link (open OR half-open: the
        probe goes through, tenant traffic does not)."""
        st = self._links.get(link)
        return st is not None and st.state != "closed"

    def due_half_open(self, t_s: float) -> list[Link]:
        """Open links whose cooldown has elapsed — each transitions to
        half-open and is returned for the caller to probe."""
        due = []
        for link, st in sorted(self._links.items()):
            if (
                st.state == "open"
                and float(t_s) >= st.opened_at + self.config.cooldown_s
            ):
                st.state = "half_open"
                self.transitions.append(BreakerTransition(
                    t_s=float(t_s), link=link, state="half_open",
                ))
                tr = get_tracer()
                if tr.enabled:
                    tr.instant("breaker.half_open", float(t_s),
                               track="breaker",
                               link=f"{link[0]}->{link[1]}")
                due.append(link)
        return due

    def half_open_result(self, link: Link, t_s: float, healthy: bool) -> None:
        """Resolve a half-open probe: close (and forget the failure
        history — the next regime starts clean) or re-open for another
        cooldown."""
        st = self._state(link)
        if healthy:
            st.state = "closed"
            st.failures.clear()
            st.restore_seen = False
            self.transitions.append(BreakerTransition(
                t_s=float(t_s), link=link, state="closed",
            ))
        else:
            st.state = "open"
            st.opened_at = float(t_s)
            st.restore_seen = False
            self.transitions.append(BreakerTransition(
                t_s=float(t_s), link=link, state="open",
            ))
        tr = get_tracer()
        if tr.enabled:
            tr.instant("breaker.close" if healthy else "breaker.reopen",
                       float(t_s), track="breaker",
                       link=f"{link[0]}->{link[1]}")

    def restore_seen(self, link: Link) -> bool:
        st = self._links.get(link)
        return st is not None and st.restore_seen

    # ----------------------------------------------------------- reporting
    def open_links(self) -> list[Link]:
        return sorted(
            link for link, st in self._links.items() if st.state != "closed"
        )

    @property
    def trips(self) -> int:
        return sum(st.trips for st in self._links.values())
