"""Chaos plane: seeded correlated-fault scenario generation.

Real incidents are correlated, not the single scripted ``LinkDegrade`` /
``VMFailure`` the fault tests throw: a region outage takes down every VM
*and* every link touching the region at once; a provider brownout saps an
entire provider's interconnect; a gray failure silently delivers a
fraction of the believed rate with no failure signal; a flapping link
cycles down/up faster than any static re-plan can follow.

This module composes those archetypes into the primitive event stream both
simulators execute (``events.LinkDegrade`` / ``GrayFailure`` /
``LinkRestore`` / ``VMFailure``), so the chunk-for-chunk parity between
``flowsim.simulate_multi`` and the ``flowsim_ref`` oracle extends to every
chaos scenario for free — archetypes are pure compile-time sugar, the
event loops never learn new physics.

Like ``calibrate.drift.DriftModel``, a :class:`ChaosScenario` draws every
random choice ONCE at construction from ``numpy.random.default_rng(seed)``
— the archetype list and the compiled event stream are pure functions of
the constructor arguments, bitwise reproducible across processes.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .events import GrayFailure, LinkDegrade, LinkRestore, VMFailure

# Degrade factors stay strictly positive so every down-edge has an exact
# multiplicative inverse for its restore (factor * 1/factor compounds back
# to the pre-event rate up to float rounding — identically in both sims).
SEVERITY_FLOOR = 0.02

# More VMs than any plan provisions in one region: a RegionOutage kill
# with this count takes out every gateway the job has there.
_ALL_VMS = 1_000_000


# ------------------------------------------------------------- archetypes
@dataclasses.dataclass(frozen=True)
class RegionOutage:
    """At ``t_s`` the region goes dark for ``duration_s``: every job loses
    all its VMs there (permanently — instances do not resurrect, replacing
    quota is not modelled) and every link touching the region collapses to
    ``severity`` of its current capacity until the outage lifts."""

    t_s: float
    region: int  # region index
    duration_s: float
    severity: float = SEVERITY_FLOOR


@dataclasses.dataclass(frozen=True)
class ProviderBrownout:
    """Provider-wide capacity brownout: every link whose endpoint region
    belongs to ``provider`` (the ``"aws"`` of ``"aws:us-east-1"``) runs at
    ``severity`` of its current capacity for ``duration_s``."""

    t_s: float
    provider: str
    duration_s: float
    severity: float = 0.4


@dataclasses.dataclass(frozen=True)
class GrayLink:
    """Silent partial failure: the link delivers ``delivered_fraction`` of
    its believed throughput for ``duration_s`` with NO failure signal —
    compiled to ``GrayFailure`` events (down and silent recovery), which
    the TransferService deliberately never folds into its degraded view."""

    t_s: float
    src: int  # region index
    dst: int
    duration_s: float
    delivered_fraction: float = 0.3


@dataclasses.dataclass(frozen=True)
class FlappingLink:
    """The link cycles down/up ``n_flaps`` times: down to ``down_factor``
    at the start of each ``period_s`` window, restored after ``duty`` of
    the period. Each flap is a visible degrade/restore pair — exactly the
    failures-or-flaps signature a link circuit breaker counts."""

    t_s: float
    src: int  # region index
    dst: int
    n_flaps: int = 3
    period_s: float = 2.0
    down_factor: float = 0.05
    duty: float = 0.5


ARCHETYPES = (RegionOutage, ProviderBrownout, GrayLink, FlappingLink)


# --------------------------------------------------------------- compiler
def _links_touching(top, region: int) -> list[tuple[int, int]]:
    tput = np.asarray(top.tput)
    out = []
    for x in range(top.num_regions):
        if x == region:
            continue
        if tput[region, x] > 0:
            out.append((region, x))
        if tput[x, region] > 0:
            out.append((x, region))
    return out


def _provider_links(top, provider: str) -> list[tuple[int, int]]:
    tput = np.asarray(top.tput)
    keys = top.keys()
    mine = [i for i, k in enumerate(keys) if k.split(":")[0] == provider]
    mset = set(mine)
    return [
        (a, b)
        for a, b in np.argwhere(tput > 0).tolist()
        if a in mset or b in mset
    ]


def compile_archetypes(archetypes, top, n_jobs: int) -> list:
    """Materialize archetypes into the primitive events both sims execute.

    ``n_jobs`` scopes the VM kills of a RegionOutage (``VMFailure`` is
    per job — the outage hits every tenant's gateways in the region).
    Events come back sorted by time; the down/up pair of every window uses
    exactly inverse factors, so the capacity view compounds back to its
    pre-incident value once an incident lifts."""
    events: list = []
    for arch in archetypes:
        if isinstance(arch, RegionOutage):
            f = max(float(arch.severity), SEVERITY_FLOOR)
            for j in range(n_jobs):
                events.append(VMFailure(
                    t_s=arch.t_s, job=j, region=arch.region, count=_ALL_VMS,
                ))
            for a, b in _links_touching(top, arch.region):
                events.append(LinkDegrade(t_s=arch.t_s, src=a, dst=b, factor=f))
                events.append(LinkRestore(
                    t_s=arch.t_s + arch.duration_s, src=a, dst=b,
                    factor=1.0 / f,
                ))
        elif isinstance(arch, ProviderBrownout):
            f = max(float(arch.severity), SEVERITY_FLOOR)
            for a, b in _provider_links(top, arch.provider):
                events.append(LinkDegrade(t_s=arch.t_s, src=a, dst=b, factor=f))
                events.append(LinkRestore(
                    t_s=arch.t_s + arch.duration_s, src=a, dst=b,
                    factor=1.0 / f,
                ))
        elif isinstance(arch, GrayLink):
            f = min(max(float(arch.delivered_fraction), SEVERITY_FLOOR), 1.0)
            events.append(GrayFailure(
                t_s=arch.t_s, src=arch.src, dst=arch.dst, factor=f,
            ))
            events.append(GrayFailure(  # the recovery is just as silent
                t_s=arch.t_s + arch.duration_s, src=arch.src, dst=arch.dst,
                factor=1.0 / f,
            ))
        elif isinstance(arch, FlappingLink):
            f = max(float(arch.down_factor), SEVERITY_FLOOR)
            up = min(max(float(arch.duty), 0.05), 0.95) * arch.period_s
            for i in range(int(arch.n_flaps)):
                t0 = arch.t_s + i * arch.period_s
                events.append(LinkDegrade(
                    t_s=t0, src=arch.src, dst=arch.dst, factor=f,
                ))
                events.append(LinkRestore(
                    t_s=t0 + up, src=arch.src, dst=arch.dst, factor=1.0 / f,
                ))
        else:
            raise TypeError(f"unknown chaos archetype {arch!r}")
    events.sort(key=lambda e: e.t_s)
    return events


# ---------------------------------------------------------------- scenario
class ChaosScenario:
    """A seeded mix of correlated fault archetypes over ``horizon_s``.

    Every random draw happens once, here, from ``default_rng(seed)`` in a
    fixed order — after construction, ``archetypes`` is frozen data and
    ``events(n_jobs)`` is a pure compilation of it. ``links`` restricts
    link-scoped archetypes (gray / flapping) to the given directed pairs —
    point the chaos at the trunks a scenario's plans actually ride, or
    leave None to draw from every positive-throughput link.
    """

    def __init__(
        self,
        top,
        *,
        seed: int = 0,
        horizon_s: float = 30.0,
        n_region_outages: int = 0,
        n_brownouts: int = 0,
        n_gray: int = 1,
        n_flapping: int = 1,
        outage_duration_s: tuple[float, float] = (4.0, 10.0),
        outage_severity: float = SEVERITY_FLOOR,
        brownout_severity: tuple[float, float] = (0.3, 0.6),
        brownout_duration_s: tuple[float, float] = (5.0, 15.0),
        gray_fraction: tuple[float, float] = (0.15, 0.5),
        gray_duration_s: tuple[float, float] = (5.0, 15.0),
        flap_down_factor: float = 0.05,
        flap_period_s: tuple[float, float] = (1.0, 3.0),
        flap_count: tuple[int, int] = (2, 5),
        links: list[tuple[int, int]] | None = None,
    ):
        self.top = top
        self.seed = int(seed)
        self.horizon_s = float(horizon_s)
        rng = np.random.default_rng(self.seed)
        tput = np.asarray(top.tput)
        if links is None:
            links = [tuple(x) for x in np.argwhere(tput > 0).tolist()]
        if not links:
            raise ValueError("no candidate links for chaos")
        providers = sorted({k.split(":")[0] for k in top.keys()})

        arch: list = []
        for _ in range(int(n_region_outages)):
            arch.append(RegionOutage(
                t_s=float(rng.uniform(0.0, horizon_s)),
                region=int(rng.integers(top.num_regions)),
                duration_s=float(rng.uniform(*outage_duration_s)),
                severity=float(outage_severity),
            ))
        for _ in range(int(n_brownouts)):
            arch.append(ProviderBrownout(
                t_s=float(rng.uniform(0.0, horizon_s)),
                provider=providers[int(rng.integers(len(providers)))],
                duration_s=float(rng.uniform(*brownout_duration_s)),
                severity=float(rng.uniform(*brownout_severity)),
            ))
        for _ in range(int(n_gray)):
            a, b = links[int(rng.integers(len(links)))]
            arch.append(GrayLink(
                t_s=float(rng.uniform(0.0, horizon_s)),
                src=int(a), dst=int(b),
                duration_s=float(rng.uniform(*gray_duration_s)),
                delivered_fraction=float(rng.uniform(*gray_fraction)),
            ))
        for _ in range(int(n_flapping)):
            a, b = links[int(rng.integers(len(links)))]
            arch.append(FlappingLink(
                t_s=float(rng.uniform(0.0, horizon_s)),
                src=int(a), dst=int(b),
                n_flaps=int(rng.integers(flap_count[0], flap_count[1] + 1)),
                period_s=float(rng.uniform(*flap_period_s)),
                down_factor=float(flap_down_factor),
            ))
        arch.sort(key=lambda a: a.t_s)
        self.archetypes = arch

    def events(self, n_jobs: int) -> list:
        """The primitive event stream for an ``n_jobs``-job scenario."""
        return compile_archetypes(self.archetypes, self.top, n_jobs)
