"""Chunking + integrity (paper §6: objects are split into ~equal small chunks
so many read/write ops can run in parallel against the object stores)."""

from __future__ import annotations

import dataclasses
import hashlib
import zlib


@dataclasses.dataclass(frozen=True)
class Chunk:
    object_key: str
    index: int
    offset: int
    length: int

    @property
    def id(self) -> str:
        return f"{self.object_key}#{self.index}"


def chunk_object(object_key: str, size_bytes: int, chunk_bytes: int) -> list[Chunk]:
    chunks = []
    off = 0
    i = 0
    while off < size_bytes:
        ln = min(chunk_bytes, size_bytes - off)
        chunks.append(Chunk(object_key, i, off, ln))
        off += ln
        i += 1
    return chunks


def checksum(data: bytes, *, strong: bool = False) -> str:
    if strong:
        return hashlib.sha256(data).hexdigest()
    return f"{zlib.crc32(data):08x}"
