"""Chunking + integrity (paper §6: objects are split into ~equal small chunks
so many read/write ops can run in parallel against the object stores)."""

from __future__ import annotations

import dataclasses
import hashlib
import zlib


@dataclasses.dataclass(frozen=True)
class Chunk:
    object_key: str
    index: int
    offset: int
    length: int

    @property
    def id(self) -> str:
        return f"{self.object_key}#{self.index}"


def chunk_object(object_key: str, size_bytes: int, chunk_bytes: int) -> list[Chunk]:
    chunks = []
    off = 0
    i = 0
    while off < size_bytes:
        ln = min(chunk_bytes, size_bytes - off)
        chunks.append(Chunk(object_key, i, off, ln))
        off += ln
        i += 1
    return chunks


def checksum(data: bytes, *, strong: bool = False) -> str:
    if strong:
        return hashlib.sha256(data).hexdigest()
    return f"{zlib.crc32(data):08x}"


def chunk_manifest(
    store, keys: list[str], chunk_bytes: int, *, with_sums: bool = True
) -> tuple[list[Chunk], dict[str, str], dict[str, str]]:
    """Chunk every object and checksum each chunk and whole object.

    The per-chunk sums are what make resume cheap: a destination can verify
    and commit chunks independently, re-requesting only the ones that failed
    — never re-reading bytes it already verified. Each object is read once:
    the object checksum is the CRC stream of the same chunk buffers.

    Returns (chunks, chunk_sums by Chunk.id, object_sums by key); the sum
    dicts are empty when ``with_sums`` is false.
    """
    chunks: list[Chunk] = []
    chunk_sums: dict[str, str] = {}
    object_sums: dict[str, str] = {}
    for key in keys:
        parts = chunk_object(key, store.size(key), chunk_bytes)
        chunks.extend(parts)
        if with_sums:
            running = 0
            for ch in parts:
                data = store.get_range(key, ch.offset, ch.length)
                chunk_sums[ch.id] = checksum(data)
                running = zlib.crc32(data, running)
            object_sums[key] = f"{running:08x}"
    return chunks, chunk_sums, object_sums
