"""Planner-scheduled inter-pod collectives (the paper's technique on-mesh).

The pod axis of the production mesh is DCN-connected: slow, heterogeneous
and (across regions/clouds) *billed per byte* — exactly the setting of
Skyplane's planner. This module implements the data-parallel gradient
reduction over the pod axis as an explicit ring built from
``jax.lax.ppermute`` inside a ``shard_map`` that is *manual* over "pod" and
*auto* (GSPMD) over data/model:

  * the ring order comes from a Skyplane-style bottleneck-max heuristic over
    the pod-level throughput grid (choose_ring_order);
  * segments are chunked so reduce-scatter and all-gather phases pipeline;
  * optional int8 on-wire compression (transfer.compression) cuts DCN bytes
    4x — the egress-volume lever of paper §2 applied to gradients.

Baseline training relies on GSPMD's automatic pod all-reduce; §Perf swaps
this in and measures the collective-term delta.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P



def choose_ring_order(pod_tput: np.ndarray) -> list[int]:
    """Order pods to maximize the minimum link throughput along the ring
    (greedy nearest-neighbor on the bottleneck metric — the RON-style
    heuristic specialized to a Hamiltonian cycle)."""
    n = pod_tput.shape[0]
    if n <= 2:
        return list(range(n))
    order = [0]
    left = set(range(1, n))
    while left:
        cur = order[-1]
        nxt = max(left, key=lambda j: min(pod_tput[cur, j], pod_tput[j, cur]))
        order.append(nxt)
        left.remove(nxt)
    return order


def _send(seg, axis_name, ring, compress_wire: bool, block: int):
    """Move one ring segment to the next rank. With compression the WIRE
    carries int8 + per-block scales (4x fewer DCN bytes); the receiver
    dequantizes. Without it, the raw floats move."""
    if not compress_wire:
        return jax.lax.ppermute(seg, axis_name, perm=ring)
    from .compression import dequantize_int8_blockwise, quantize_int8_blockwise

    q, scales = quantize_int8_blockwise(seg, block)
    q_r = jax.lax.ppermute(q, axis_name, perm=ring)
    s_r = jax.lax.ppermute(scales, axis_name, perm=ring)
    return dequantize_int8_blockwise(q_r, s_r, block)[: seg.size].reshape(
        seg.shape
    ).astype(seg.dtype)


def _quant_lastaxis(x, block: int):
    """Sharding-preserving int8 quantization: blocks along the LAST axis
    only, so leading (possibly GSPMD-sharded) dims are untouched. A global
    reshape(-1) of a sharded tensor makes SPMD all-gather it — measured as a
    24x wire regression in the first podring attempt (EXPERIMENTS §Perf)."""
    last = x.shape[-1]
    pad = (-last) % block
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)]) if pad else x
    blocks = xp.reshape(*xp.shape[:-1], -1, block).astype(jnp.float32)
    absmax = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[..., 0], pad


def _dequant_lastaxis(q, scale, pad: int, out_shape):
    x = q.astype(jnp.float32) * scale[..., None]
    x = x.reshape(*x.shape[:-2], -1)
    if pad:
        x = x[..., :-pad]
    return x.reshape(out_shape)


def _exchange_reduce_pair(x, axis_name: str, *, compress_wire: bool,
                          block: int):
    """2-pod all-reduce: one ppermute of the (still-sharded) tensor each
    way, optionally int8 on the wire. No reshapes, so GSPMD keeps every
    auto-axis sharding intact."""
    perm = [(0, 1), (1, 0)]
    if not compress_wire:
        return x + jax.lax.ppermute(x, axis_name, perm=perm)
    q, scale, pad = _quant_lastaxis(x, block)
    q_r = jax.lax.ppermute(q, axis_name, perm=perm)
    s_r = jax.lax.ppermute(scale, axis_name, perm=perm)
    other = _dequant_lastaxis(q_r, s_r, pad, x.shape).astype(x.dtype)
    # symmetric lossy view: quantize our own contribution identically so
    # both pods hold bit-identical parameters afterwards
    own = _dequant_lastaxis(q, scale, pad, x.shape).astype(x.dtype)
    return own + other


def _ring_allreduce(x, axis_name: str, order: list[int], *,
                    compress_wire: bool = False, block: int = 256):
    """Ring all-reduce over ``axis_name`` inside shard_map (manual axis).

    reduce-scatter + all-gather, ``n-1`` steps each, over the planner's ring
    order. With compression, each hop quantizes its outgoing segment.
    The 2-pod case short-circuits to a sharding-preserving pairwise
    exchange (see _exchange_reduce_pair)."""
    n = len(order)
    if n <= 1:
        return x
    if n == 2:
        return _exchange_reduce_pair(
            x, axis_name, compress_wire=compress_wire, block=block
        )
    ring = [(order[i], order[(i + 1) % n]) for i in range(n)]

    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % n
    flat = jnp.pad(flat, (0, pad))
    segs = flat.reshape(n, -1)

    my = jax.lax.axis_index(axis_name)
    pos = jnp.zeros((), jnp.int32)
    for i, p_ in enumerate(order):
        pos = jnp.where(my == p_, i, pos)

    def seg_at(k):
        # segment index this rank accumulates at step k of reduce-scatter
        return (pos - k) % n

    acc = segs
    # ---- reduce-scatter: after n-1 steps, rank at ring position i owns the
    # fully-reduced segment (i+1) % n
    for k in range(n - 1):
        send_ix = (pos - k) % n
        send = jnp.take(acc, send_ix[None], axis=0)[0]
        recv = _send(send, axis_name, ring, compress_wire, block)
        recv_ix = (pos - k - 1) % n
        upd = jnp.take(acc, recv_ix[None], axis=0)[0] + recv
        acc = jax.lax.dynamic_update_index_in_dim(acc, upd, recv_ix, axis=0)
    # ---- all-gather: rank at position i owns segment (i+1); at step k it
    # sends segment (i+1-k) (own first, then forward what it received) and
    # receives segment (i-k) from its predecessor.
    for k in range(n - 1):
        send_ix = (pos + 1 - k) % n
        send = jnp.take(acc, send_ix[None], axis=0)[0]
        recv = _send(send, axis_name, ring, compress_wire, block)
        recv_ix = (pos - k) % n
        acc = jax.lax.dynamic_update_index_in_dim(acc, recv, recv_ix, axis=0)
    out = acc.reshape(-1)
    if pad:
        out = out[:-pad]
    return out.reshape(x.shape)


def ring_allreduce_tree(grads, axis_name: str, order: list[int], *,
                        compress_wire: bool = False, mean: bool = True):
    """All-reduce a pytree over a manual mesh axis with the planner's ring.
    Must be called INSIDE a shard_map that is manual over ``axis_name``."""
    n = len(order)

    def one(g):
        r = _ring_allreduce(g, axis_name, order, compress_wire=compress_wire)
        return r / n if mean else r

    return jax.tree.map(one, grads)


def make_pod_gradient_reducer(mesh, *, pod_tput: np.ndarray | None = None,
                              compress_wire: bool = False, mean: bool = True):
    """Returns reduce(tree) -> tree over the 'pod' axis with an explicit
    planner-ordered ring. The input tree holds per-pod partial values that
    are replicated over the other mesh axes; call sites inside an existing
    pod-manual shard_map should use ring_allreduce_tree directly.
    No-op (None) on single-pod meshes."""
    if "pod" not in mesh.axis_names:
        return None
    n_pods = dict(zip(mesh.axis_names, mesh.devices.shape))["pod"]
    if pod_tput is None:
        pod_tput = np.ones((n_pods, n_pods))
    order = choose_ring_order(pod_tput)

    def reduce_tree(grads):
        def body(g_tree):
            return ring_allreduce_tree(
                g_tree, "pod", order, compress_wire=compress_wire, mean=mean
            )

        return jax.shard_map(
            body,
            mesh=mesh,
            in_specs=P(),
            out_specs=P(),
            axis_names=frozenset({"pod"}),
            check_vma=False,
        )(grads)

    return reduce_tree
