"""Gradient compression for inter-pod (DCN) reduction.

Skyplane's cost lever is *egress volume* (§2: transfers are billed per GB).
On a TPU fleet the pod-to-pod links are the expensive, slow resource, so the
same lever applies: per-block symmetric int8 quantization cuts wire bytes
4x. Error feedback (Seide et al.; Karimireddy et al. 2019) keeps SGD/Adam
convergence: the quantization residual is carried and re-added next step.

Pure-jnp reference here; the Pallas quantize kernel (repro.kernels.quantize)
is the TPU hot path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8_blockwise(x, block: int = 256, *, use_pallas: bool = False):
    """x: float array -> (q int8 [same shape], scales f32 [n_blocks])."""
    if use_pallas:
        from repro.kernels.quantize.ops import quantize_int8 as _kq

        return _kq(x, block=block)
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block).astype(jnp.float32)
    absmax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q.reshape(-1)[: x.size].reshape(x.shape), scale[:, 0]


def dequantize_int8_blockwise(q, scales, block: int = 256):
    flat = q.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block) * scales[:, None]
    return blocks.reshape(-1)[:n]


def compress(x, block: int = 256, *, use_pallas: bool = False):
    """Lossy round-trip (the on-wire transform)."""
    q, s = quantize_int8_blockwise(x, block, use_pallas=use_pallas)
    return dequantize_int8_blockwise(q, s, block).reshape(x.shape).astype(x.dtype)


def init_error_feedback(params) -> dict:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_error_feedback(grads, ef_state, block: int = 256,
                                 *, use_pallas: bool = False):
    """Returns (compressed_grads, new_ef_state)."""

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        sent = compress(corrected, block, use_pallas=use_pallas)
        return sent.astype(g.dtype), corrected - sent

    out = jax.tree.map(one, grads, ef_state)
    comp = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return comp, new_ef
