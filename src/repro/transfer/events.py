"""Multi-job transfer scenarios: jobs, scripted faults, shared materialization.

The fault-tolerant data plane (ISSUE 2) runs several concurrent
``TransferPlan``s against a scripted schedule of mid-transfer events:

  * ``TransferJob``   — one plan plus its arrival time and chunk size;
  * ``LinkDegrade``   — a region-pair link loses a fraction of its capacity
    (compounding: ``factor`` multiplies the *current* rates);
  * ``VMFailure``     — gateway VMs of one job die; their in-flight chunks
    are lost and re-dispatched to the surviving workers of the same stage
    (chunk-level retry, zero data loss while any worker survives);
  * ``GrayFailure``   — the chaos plane's silent partial failure: the same
    rate multiplication as ``LinkDegrade``, but no failure signal — the
    TransferService never folds it into its degraded view, only telemetry
    (or a circuit breaker fed by it) can catch the slowdown;
  * ``LinkRestore``   — visible recovery: the inverse multiplication of an
    earlier degrade; the service heals its degraded view (capped at full
    capacity) and circuit breakers read it as the up-edge of a flap.

All three rate events (``RATE_EVENTS``) are executed identically by both
simulators — a compounding multiply on the affected connections' rates and
the shared link cap — so the chaos suite's chunk-for-chunk parity holds
for every archetype ``transfer.chaos`` compiles down to them.

Both the vectorized simulator (``flowsim.simulate_multi``) and the
object-per-connection oracle (``flowsim_ref.simulate_multi_reference``)
consume the same ``materialize_jobs`` scenario — identical per-job RNG
streams, VM/connection materialization and chunk->path assignment — so the
equivalence tests can pin them together chunk-for-chunk. The two event
loops themselves are implemented independently.

Jobs contend for the wide-area links: each directed region pair is modelled
as a shared fluid resource with capacity ``link_capacity_scale`` times the
single-VM-pair grid rate, divided max-min fairly across every tenant's
connections (OneDataShare-style multi-job scheduling pressure).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.plan import MulticastPlan, TransferPlan
from repro.core.topology import GBIT_PER_GB

from .flowsim import conn_efficiency

# One tolerance for every time comparison of the multi-job event loops
# (schedule due-ness, horizon cuts, final horizon classification). Both
# simulators — vectorized and reference — must use THIS constant: a
# boundary event classified differently on the two sides breaks the
# chunk-for-chunk equivalence the tests pin.
T_EPS = 1e-9


@dataclasses.dataclass
class TransferJob:
    """One tenant job of the multi-job data plane.

    ``plan`` is either a point-to-point ``TransferPlan`` or a one-to-many
    ``MulticastPlan`` — a multicast job uploads each chunk once, fans out
    at the relays of its distribution trees, and completes when every
    destination holds every chunk."""

    plan: TransferPlan | MulticastPlan
    name: str = ""
    arrival_s: float = 0.0
    chunk_mb: float = 16.0


@dataclasses.dataclass(frozen=True)
class LinkDegrade:
    """At ``t_s``, the (src, dst) region-pair link drops to ``factor`` of its
    current capacity (per-connection rates and the shared link cap)."""

    t_s: float
    src: int  # region index
    dst: int
    factor: float


@dataclasses.dataclass(frozen=True)
class GrayFailure:
    """At ``t_s``, the (src, dst) link silently delivers ``factor`` of its
    current rate. Data-plane effect identical to ``LinkDegrade``; control-
    plane effect deliberately absent — there is NO failure signal, so the
    orchestrator keeps planning on the healthy view until telemetry or a
    breaker notices the shortfall. A silent recovery is another
    ``GrayFailure`` carrying the inverse factor."""

    t_s: float
    src: int  # region index
    dst: int
    factor: float


@dataclasses.dataclass(frozen=True)
class LinkRestore:
    """At ``t_s``, the (src, dst) link recovers: rates multiply by
    ``factor`` (the inverse of an earlier degrade, > 1). Visible to the
    service — the degraded-topology view heals (capped at full capacity)
    and circuit breakers read it as the up-edge of a flap."""

    t_s: float
    src: int  # region index
    dst: int
    factor: float


# Every event that is a pure rate multiplication on one directed link.
# BOTH event loops must dispatch on this tuple (not on LinkDegrade alone):
# a rate event handled by one simulator and not the other breaks the
# chunk-for-chunk parity the chaos tests pin.
RATE_EVENTS = (LinkDegrade, GrayFailure, LinkRestore)


@dataclasses.dataclass(frozen=True)
class VMFailure:
    """At ``t_s``, ``count`` gateway VMs of job ``job`` in ``region`` die.

    Connections touching a dead VM are gone for good; chunks they carried
    return to their stage's ready queue and retry on surviving workers."""

    t_s: float
    job: int  # index into the job list
    region: int  # region index
    count: int = 1


@dataclasses.dataclass
class JobSimResult:
    """Per-job outcome of a multi-job simulation."""

    job: int
    name: str
    time_s: float  # arrival -> completion (or horizon / stall point)
    tput_gbps: float
    chunks_delivered: int  # multicast: chunks EVERY destination holds
    n_chunks: int
    retried_chunks: int
    egress_cost: float
    vm_cost: float
    total_cost: float
    status: str  # "done" | "running" | "stalled" | "pending"
    per_edge_gb: dict
    # multicast only: destination region -> chunks delivered there
    per_dst_delivered: dict | None = None
    # passive-telemetry support (vectorized sim only): "a->b" -> seconds the
    # job had at least one active connection on that edge, and the GB moved
    # within that window. Both stop where a drain begins (the observation
    # window is the horizon interval; the straggler tail would dilute the
    # rate). Observed-GB over active-seconds is the link rate the
    # calibration plane feeds back into its belief — bytes/duration would
    # under-read links that idled while the job waited on other hops.
    per_edge_active_s: dict | None = None
    per_edge_obs_gb: dict | None = None
    # connections still carrying a partially-transferred chunk when the sim
    # ended (0 for completed jobs). A horizon cut restarts these chunks from
    # scratch in the next segment — the service counts them against the
    # job's retry budget, same as a gateway re-dispatching a chunk whose
    # worker died mid-copy.
    chunks_in_flight: int = 0

    @property
    def done(self) -> bool:
        return self.status == "done"

    @property
    def remaining_chunks(self) -> int:
        return self.n_chunks - self.chunks_delivered


@dataclasses.dataclass
class MultiSimResult:
    jobs: list[JobSimResult]
    time_s: float
    events: int  # event-loop iterations (perf accounting)

    @property
    def all_done(self) -> bool:
        return all(j.done for j in self.jobs)

    @property
    def total_cost(self) -> float:
        return sum(j.total_cost for j in self.jobs)


@dataclasses.dataclass
class MultiSetup:
    """Everything both event loops need, materialized once per scenario.

    Connections are globally indexed in ascending (job, path/tree, hop/edge,
    conn) order; stages in ascending (job, path/tree, hop/edge) order — the
    dispatch order both simulators iterate in, which is what makes them
    comparable.

    A unicast job's stages form a chain (each stage has at most one child);
    a multicast job's stages are the edges of its distribution trees — a
    stage can have several children (fan-out at a relay) and can both
    deliver (its head region is a destination) and forward on. Completion
    is tracked per (job, destination) "slot": a unicast job has one slot,
    a multicast job one per destination its trees serve."""

    top: object  # Topology of jobs[0] (shared link grid / prices)
    arrivals: np.ndarray  # [J]
    # job indices sorted by (arrival_s, job id). Padded-array engines lay
    # jobs out in THIS order, so it must be deterministic under tied
    # arrivals: a bare ``np.argsort(arrivals)`` (introsort) may permute
    # equal keys differently across runs/platforms, silently reshuffling
    # the padded layout between engines. The job id in the sort key pins
    # the tie-break.
    arrival_order: np.ndarray  # [J]
    n_chunks: np.ndarray  # [J] chunks per job
    chunk_gbit: np.ndarray  # [J] chunk size per job (Gbit)
    chunk_path: list[np.ndarray]  # per job: chunk id -> path/tree id
    vm_eg_cap: np.ndarray  # [NV] per-VM egress cap
    vm_in_cap: np.ndarray
    vm_region: np.ndarray  # [NV]
    vm_job: np.ndarray  # [NV]
    n_stages: int
    stage_job: np.ndarray  # [NS]
    stage_hop: np.ndarray  # [NS] 0 at source-egress stages
    stage_children: list[list[int]]  # [NS] downstream stage ids (fan-out)
    stage_deliver: np.ndarray  # [NS] completion slot fed here, -1 if none
    first_stage: list[list[list[int]]]  # per job: path/tree -> root stages
    slot_job: np.ndarray  # [NSLOT]
    slot_dst: np.ndarray  # [NSLOT] destination region (unicast: plan.dst)
    job_slots: list[list[int]]  # per job: its slot ids
    conn_job: np.ndarray  # [NC] all ascending (job, path, hop, conn)
    conn_sid: np.ndarray
    conn_src: np.ndarray  # global VM ids
    conn_dst: np.ndarray
    conn_rate: np.ndarray  # nominal * straggler multiplier
    conn_edge: np.ndarray  # [NC] index into edges_used
    edges_used: list[tuple[int, int]]
    max_hops: int


def materialize_jobs(
    jobs: list[TransferJob],
    *,
    seed: int = 0,
    straggler_prob: float = 0.05,
    straggler_speed: tuple[float, float] = (0.15, 0.5),
    exec_top=None,
) -> MultiSetup:
    """Materialize VMs, connections and chunk streams for every job.

    Per-job state is drawn from an independent RNG stream seeded by
    (seed, job index) in the same draw order as the single-job simulator:
    one multiplier per connection in connection order, then the chunk->path
    assignment.

    ``exec_top`` executes the jobs against a different throughput grid
    than the one they were planned on (same regions; built with
    ``Topology.with_tput``): connection rates and shared link capacities
    come from ``exec_top``, while each plan's F/N/M allocations stand.
    This is the calibration plane's split view — plans are made on the
    BELIEVED grid, the data plane delivers the TRUE one, and the gap is
    what passive telemetry observes. RNG draws are identical either way,
    so a believed-vs-true pair of runs differs only in rates."""
    if not jobs:
        raise ValueError("no jobs")
    top0 = jobs[0].plan.top
    for job in jobs:
        top = job.plan.top
        if top is not top0 and not (
            top.num_regions == top0.num_regions
            and np.array_equal(top.tput, top0.tput)
            and np.array_equal(top.price_egress, top0.price_egress)
        ):
            raise ValueError(
                "all jobs must share one topology (shared link caps and "
                "egress prices come from the first job's grid)"
            )
    if exec_top is not None:
        if exec_top.num_regions != top0.num_regions:
            raise ValueError(
                "exec_top must cover the same regions as the job plans"
            )
        if exec_top.limit_conn != top0.limit_conn:
            raise ValueError("exec_top must keep the planned limit_conn")

    arrivals = np.array([float(j.arrival_s) for j in jobs])
    n_chunks = np.zeros(len(jobs), dtype=np.int64)
    chunk_gbit = np.zeros(len(jobs))
    chunk_path: list[np.ndarray] = []

    vm_eg_cap: list[float] = []
    vm_in_cap: list[float] = []
    vm_region: list[int] = []
    vm_job: list[int] = []

    stage_job: list[int] = []
    stage_hop: list[int] = []
    stage_children: list[list[int]] = []
    stage_deliver: list[int] = []
    first_stage: list[list[list[int]]] = []
    slot_job: list[int] = []
    slot_dst: list[int] = []
    job_slots: list[list[int]] = []

    conn_job: list[int] = []
    conn_sid: list[int] = []
    conn_src: list[int] = []
    conn_dst: list[int] = []
    conn_rate: list[float] = []
    conn_edge_pairs: list[tuple[int, int]] = []
    max_hops = 1

    def add_conns(j, top, rng, sid, a, b, n_conn, vms_a, vms_b):
        per_pair = max(n_conn / (len(vms_a) * len(vms_b)), 1e-9)
        eff = conn_efficiency(per_pair * len(vms_b), top.limit_conn)
        nominal = top.tput[a, b] * eff / n_conn * len(vms_a)
        for c in range(n_conn):
            if rng.uniform() < straggler_prob:
                mult = float(rng.uniform(*straggler_speed))
            else:
                mult = float(np.exp(rng.normal(0.0, 0.05)))
            conn_job.append(j)
            conn_sid.append(sid)
            conn_src.append(vms_a[c % len(vms_a)])
            conn_dst.append(vms_b[c % len(vms_b)])
            conn_rate.append(nominal * mult)
            conn_edge_pairs.append((a, b))

    for j, job in enumerate(jobs):
        plan = job.plan
        top = plan.top
        # connection rates come from the EXECUTION grid (true topology when
        # the calibration plane splits the view); allocations from the plan
        gtop = exec_top if exec_top is not None else top
        rng = np.random.default_rng([seed, j])
        multicast = isinstance(plan, MulticastPlan)

        volume_gbit = plan.volume_gb * GBIT_PER_GB
        cg = job.chunk_mb * 8.0 / 1024.0
        chunk_gbit[j] = cg
        n_chunks[j] = max(1, int(np.ceil(volume_gbit / cg)))

        # ---- VMs (global ids, appended in job then region order)
        vm_of: dict[int, list[int]] = {}
        for r in range(top.num_regions):
            ids = []
            for _ in range(int(round(plan.N[r]))):
                ids.append(len(vm_eg_cap))
                vm_eg_cap.append(top.limit_egress[r])
                vm_in_cap.append(top.limit_ingress[r])
                vm_region.append(r)
                vm_job.append(j)
            vm_of[r] = ids

        if not multicast:
            paths = plan.paths()
            if not paths:
                raise ValueError(f"job {j} ({job.name!r}) carries no flow")
            slot0 = len(slot_job)
            slot_job.append(j)
            slot_dst.append(plan.dst)
            job_slots.append([slot0])

            # ---- stages: one per (path, hop), chained
            stage_of: dict[tuple[int, int], int] = {}
            path_len = {pid: len(p) - 1 for pid, (p, _) in enumerate(paths)}
            max_hops = max(max_hops, max(path_len.values()))
            for pid, (path, _) in enumerate(paths):
                for hop in range(path_len[pid]):
                    stage_of[(pid, hop)] = len(stage_job)
                    stage_job.append(j)
                    stage_hop.append(hop)
                    stage_children.append([])
                    stage_deliver.append(-1)
            for (pid, hop), sid in stage_of.items():
                if hop + 1 < path_len[pid]:
                    stage_children[sid] = [stage_of[(pid, hop + 1)]]
                else:
                    stage_deliver[sid] = slot0
            first_stage.append(
                [[stage_of[(pid, 0)]] for pid in range(len(paths))]
            )

            # ---- connections: same nominal-rate formula as the 1-job sim
            edge_flow_total: dict[tuple[int, int], float] = {}
            for path, flow in paths:
                for a, b in zip(path[:-1], path[1:]):
                    edge_flow_total[(a, b)] = (
                        edge_flow_total.get((a, b), 0.0) + flow
                    )
            for pid, (path, flow) in enumerate(paths):
                for hop, (a, b) in enumerate(zip(path[:-1], path[1:])):
                    m_edge = int(round(plan.M[a, b]))
                    share = flow / edge_flow_total[(a, b)]
                    n_conn = max(1, int(round(m_edge * share)))
                    vms_a = vm_of.get(a) or []
                    vms_b = vm_of.get(b) or []
                    if not vms_a or not vms_b:
                        raise ValueError(
                            f"job {j} has flow on edge {a}->{b} but no VMs"
                        )
                    add_conns(j, gtop, rng, stage_of[(pid, hop)], a, b,
                              n_conn, vms_a, vms_b)

            flows = np.array([f for _, f in paths])
            chunk_path.append(
                rng.choice(len(paths), size=int(n_chunks[j]),
                           p=flows / flows.sum())
            )
            continue

        # -------------------------------------------------- multicast job
        trees = plan.trees()
        if not trees:
            raise ValueError(f"job {j} ({job.name!r}) carries no flow")
        served = sorted({d for t in trees for d in t.paths})
        slot_of = {}
        slots_j = []
        for d in served:
            slot_of[d] = len(slot_job)
            slots_j.append(len(slot_job))
            slot_job.append(j)
            slot_dst.append(d)
        job_slots.append(slots_j)

        # ---- stages: one per (tree, edge), children = tree fan-out
        stage_of_edge: list[dict[tuple[int, int], int]] = []
        firsts_j: list[list[int]] = []
        for t in trees:
            edges = t.edges()
            max_hops = max(max_hops, len(edges))
            hop_of: dict[tuple[int, int], int] = {}
            for p in t.paths.values():
                for i, e in enumerate(zip(p[:-1], p[1:])):
                    hop_of[e] = min(hop_of.get(e, i), i)
            s_of: dict[tuple[int, int], int] = {}
            for e in edges:
                s_of[e] = len(stage_job)
                stage_job.append(j)
                stage_hop.append(hop_of[e])
                stage_children.append([])
                stage_deliver.append(-1)
            children = t.children()
            delivers = t.delivers()
            for e in edges:
                stage_children[s_of[e]] = [s_of[c] for c in children[e]]
            for e, d in delivers.items():
                stage_deliver[s_of[e]] = slot_of[d]
            stage_of_edge.append(s_of)
            firsts_j.append([s_of[e] for e in t.roots()])
        first_stage.append(firsts_j)

        # ---- connections: the envelope usage of an edge is shared by the
        # trees riding it, so each tree gets its rate share of M_e
        edge_rate_total: dict[tuple[int, int], float] = {}
        for t in trees:
            for e in t.edges():
                edge_rate_total[e] = edge_rate_total.get(e, 0.0) + t.rate
        for tid, t in enumerate(trees):
            for e in t.edges():
                a, b = e
                m_edge = int(round(plan.M[a, b]))
                share = t.rate / edge_rate_total[e]
                n_conn = max(1, int(round(m_edge * share)))
                vms_a = vm_of.get(a) or []
                vms_b = vm_of.get(b) or []
                if not vms_a or not vms_b:
                    raise ValueError(
                        f"job {j} has flow on edge {a}->{b} but no VMs"
                    )
                add_conns(j, gtop, rng, stage_of_edge[tid][e], a, b,
                          n_conn, vms_a, vms_b)

        rates = np.array([t.rate for t in trees])
        chunk_path.append(
            rng.choice(len(trees), size=int(n_chunks[j]),
                       p=rates / rates.sum())
        )

    edges_used = sorted(set(conn_edge_pairs))
    edge_index = {e: i for i, e in enumerate(edges_used)}
    return MultiSetup(
        top=exec_top if exec_top is not None else top0,
        arrivals=arrivals,
        arrival_order=np.asarray(
            sorted(range(len(jobs)), key=lambda j: (float(arrivals[j]), j)),
            dtype=np.int64,
        ),
        n_chunks=n_chunks,
        chunk_gbit=chunk_gbit,
        chunk_path=chunk_path,
        vm_eg_cap=np.asarray(vm_eg_cap, dtype=float),
        vm_in_cap=np.asarray(vm_in_cap, dtype=float),
        vm_region=np.asarray(vm_region, dtype=np.int64),
        vm_job=np.asarray(vm_job, dtype=np.int64),
        n_stages=len(stage_job),
        stage_job=np.asarray(stage_job, dtype=np.int64),
        stage_hop=np.asarray(stage_hop, dtype=np.int64),
        stage_children=stage_children,
        stage_deliver=np.asarray(stage_deliver, dtype=np.int64),
        first_stage=first_stage,
        slot_job=np.asarray(slot_job, dtype=np.int64),
        slot_dst=np.asarray(slot_dst, dtype=np.int64),
        job_slots=job_slots,
        conn_job=np.asarray(conn_job, dtype=np.int64),
        conn_sid=np.asarray(conn_sid, dtype=np.int64),
        conn_src=np.asarray(conn_src, dtype=np.int64),
        conn_dst=np.asarray(conn_dst, dtype=np.int64),
        conn_rate=np.asarray(conn_rate, dtype=float),
        conn_edge=np.asarray(
            [edge_index[e] for e in conn_edge_pairs], dtype=np.int64
        ),
        edges_used=edges_used,
        max_hops=max_hops,
    )


def sorted_schedule(
    jobs: list[TransferJob], faults
) -> list[tuple[float, int, object]]:
    """Arrivals + faults merged into one (time, seq, payload) list. Payloads:
    an int job index for arrivals, or the fault event itself."""
    sched: list[tuple[float, int, object]] = []
    for j, job in enumerate(jobs):
        sched.append((float(job.arrival_s), len(sched), j))
    for f in faults:
        sched.append((float(f.t_s), len(sched), f))
    sched.sort(key=lambda e: (e[0], e[1]))
    return sched
