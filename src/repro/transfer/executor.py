"""Plan execution front-ends.

``execute_plan`` runs one TransferPlan on the fluid simulator and reconciles
realized cost/throughput against the planner's predictions (plus the
managed-service models for the Fig. 6 comparison).

``TransferService`` (ISSUE 2) is the multi-tenant orchestrator on top: it
admits a queue of jobs, plans them with the batched ``backend="jax"``
solver, runs them concurrently on the multi-job simulator, and — when a
scripted fault degrades the topology mid-transfer — re-plans each affected
job's *remaining* volume. Re-planning rides entirely on the planner's
memoized pruned subgraphs and cached ``LPStructure``s: the degraded links
and unhealthy regions become extra constraint rows (``Planner._degrade_
cuts``), so no constraint matrix is ever re-assembled; tests pin
``milp.N_STRUCT_BUILDS`` across a re-plan to assert it.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import milp
from repro.core.baselines import CloudServiceModel
from repro.core.plan import MulticastPlan, TransferPlan
from repro.core.planner import Planner
from repro.core.spec import PlanSpec
from repro.core.topology import GBIT_PER_GB, Topology
from repro.obs.metrics import REGISTRY
from repro.obs.trace import get_tracer

from .breaker import LinkBreaker
from .events import (
    T_EPS,
    GrayFailure,
    LinkDegrade,
    LinkRestore,
    TransferJob,
    VMFailure,
)
from .flowsim import SimResult, simulate_transfer
from .reports import Report


@dataclasses.dataclass
class ExecutionReport(Report):
    sim: SimResult
    planned_tput_gbps: float
    planned_cost: float
    tput_ratio: float  # achieved / planned
    cost_ratio: float  # realized / planned

    @property
    def time_s(self) -> float:
        return self.sim.time_s

    kind = "execution"
    _summary_keys = ("time_s", "realized_tput_gbps", "tput_ratio",
                     "cost_ratio")

    def _payload(self) -> dict:
        return {
            "time_s": self.time_s,
            "planned_tput_gbps": self.planned_tput_gbps,
            "realized_tput_gbps": self.sim.tput_gbps,
            "planned_cost": self.planned_cost,
            "realized_cost": self.sim.total_cost,
            "tput_ratio": self.tput_ratio,
            "cost_ratio": self.cost_ratio,
        }


def execute_plan(plan: TransferPlan, **sim_kwargs) -> ExecutionReport:
    sim = simulate_transfer(plan, **sim_kwargs)
    return ExecutionReport(
        sim=sim,
        planned_tput_gbps=plan.throughput,
        planned_cost=plan.total_cost,
        tput_ratio=sim.tput_gbps / max(plan.throughput, 1e-9),
        cost_ratio=sim.total_cost / max(plan.total_cost, 1e-9),
    )


def execute_service_model(
    model: CloudServiceModel, top: Topology, src: str, dst: str, volume_gb: float
) -> dict:
    t = model.transfer_time_s(top, src, dst, volume_gb)
    return {
        "service": model.name,
        "time_s": t,
        "tput_gbps": volume_gb * 8.0 / t,
        "cost": model.cost(top, src, dst, volume_gb),
    }


# ------------------------------------------------------------------- service
@dataclasses.dataclass
class TransferRequest:
    """One tenant job submitted to the TransferService.

    ``dsts`` switches the job to one-to-many replication: the service plans
    a single multicast transfer to every listed destination (``dst`` is
    ignored) with ``tput_goal_gbps`` as the per-destination floor.

    ``deadline_s`` (relative to ``arrival_s``) declares a completion SLO:
    the service sheds work down the :class:`DegradationLadder` under
    deadline pressure and, at the deadline itself, cuts the job to an
    explicit partial delivery instead of running late. ``retry_budget``
    caps chunk retries — exhaustion also ends in a ``"partial"`` report
    with the delivered byte count intact, never silent loss. Both default
    to None: no deadline, unlimited retries — exactly today's semantics."""

    name: str
    src: str
    dst: str
    volume_gb: float
    tput_goal_gbps: float
    arrival_s: float = 0.0
    chunk_mb: float = 16.0
    dsts: list[str] | None = None
    deadline_s: float | None = None
    retry_budget: int | None = None

    @property
    def multicast(self) -> bool:
        return self.dsts is not None


@dataclasses.dataclass(frozen=True)
class BackoffLadder:
    """Named goal-backoff schedule for constrained re-plans.

    When a re-plan at the capacity-capped goal is infeasible, the service
    walks ``factors`` (each a multiplier on that base goal) until a rung
    solves. The default reproduces the halving ladder the service always
    had, but as data: benchmarks can pin an aggressive single-rung ladder,
    tests can enumerate the exact sequence, and ``ReplanRecord.ladder``
    names which schedule produced each record."""

    name: str = "halving"
    factors: tuple[float, ...] = (1.0, 0.5, 0.25)

    def goals(self, base_goal: float) -> list[float]:
        return [base_goal * f for f in self.factors]


@dataclasses.dataclass(frozen=True)
class DegradationLadder:
    """What a deadline-pressured job sheds, in order, before giving up.

    At each segment boundary the service compares the job's ETA (at the
    more pessimistic of planned and realized throughput) against the time
    left; when ``eta * pressure`` exceeds it, the job climbs one rung:

      * ``"shed_robustness"`` — re-plan at z=0: stop paying the belief's
        lower-confidence-bound safety margin for headroom;
      * ``"shed_trickle"``    — re-plan and drop paths below
        ``trickle_frac`` of plan throughput: a slow path's in-flight
        chunks gate every boundary drain, a latency tax a deadline job
        cannot afford;
      * ``"partial"``         — stop: report partial delivery now rather
        than miss the deadline by more.

    Rungs are sticky — every later re-plan of the job keeps the shed."""

    steps: tuple[str, ...] = ("shed_robustness", "shed_trickle", "partial")
    pressure: float = 1.0  # >1 escalates earlier (safety margin on the ETA)
    trickle_frac: float = 0.25


def _drop_trickle_paths(plan, frac: float = 0.05):
    """Drop decomposed paths below ``frac`` of plan throughput and
    rebuild F. A trickle path over a collapsed link is rational to the
    LP (the re-plan goal sits at 95% of robust capacity, so the solver
    scrapes every capped drop) but poisonous to the segmented data
    plane: its in-flight chunks crawl, and every boundary drain waits
    for them — a latency tax far above the capacity the path adds."""
    if isinstance(plan, MulticastPlan):
        return plan
    paths = plan.paths()
    total = sum(f for _, f in paths)
    keep = [(p, f) for p, f in paths if f >= frac * total]
    if not keep or len(keep) == len(paths):
        return plan
    F = np.zeros_like(plan.F)
    for p, f in keep:
        for a, b in zip(p[:-1], p[1:]):
            F[a, b] += f
    plan.F = F
    plan.tput_goal = min(plan.tput_goal, float(F[plan.src, :].sum()))
    return plan


@dataclasses.dataclass
class ReplanRecord:
    job: str
    at_s: float
    remaining_gb: float
    latency_s: float
    structure_builds: int  # LPStructure assemblies during the re-plan
    plan: TransferPlan
    goal_gbps: float = 0.0  # throughput goal the accepted re-plan ran at
    backoffs: int = 0  # times the goal was backed off before success
    ladder: str = "halving"  # BackoffLadder.name that produced the goals
    reason: str = "fault"  # "fault" | "deadline" | "quarantine"

    @property
    def reused_structure(self) -> bool:
        """True when the re-plan was a pure cache hit (no LP re-assembly)."""
        return self.structure_builds == 0

    @property
    def degraded_slo(self) -> bool:
        """True when the re-plan only succeeded at a backed-off goal."""
        return self.backoffs > 0


@dataclasses.dataclass
class JobReport(Report):
    request: TransferRequest
    plan: TransferPlan  # the job's current (possibly re-planned) allocation
    status: str  # "done" | "stalled" | "failed" | "running" | "partial"
    planned_tput_gbps: float
    planned_cost: float
    realized_tput_gbps: float
    realized_cost: float
    delivered_gb: float
    retried_chunks: int
    contended: bool  # realized tput fell below the contention threshold
    replans: list[ReplanRecord]
    deadline_met: bool | None = None  # None when no deadline was requested
    budget_exhausted: bool = False  # retry budget spent -> partial delivery
    degrade_level: int = 0  # DegradationLadder rungs climbed
    n_chunks: int = 0  # total chunks the request chunked into
    delivered_chunks: int = 0  # chunks landed (== n_chunks iff done)

    @property
    def tput_ratio(self) -> float:
        return self.realized_tput_gbps / max(self.planned_tput_gbps, 1e-9)

    @property
    def cost_ratio(self) -> float:
        return self.realized_cost / max(self.planned_cost, 1e-9)

    @property
    def lost_chunks(self) -> int:
        """Chunks neither delivered nor accounted by an explicit partial/
        failed/stalled/running status. Nonzero means silent loss — the
        integrity invariant every chaos scenario must keep at zero."""
        if self.status != "done":
            return 0  # undelivered remainder is explicit, not lost
        return self.n_chunks - self.delivered_chunks

    kind = "job"
    _summary_keys = ("name", "status", "delivered_gb", "realized_tput_gbps",
                     "replans", "deadline_met")

    def _payload(self) -> dict:
        return {
            "name": self.request.name,
            "status": self.status,
            "planned_tput_gbps": self.planned_tput_gbps,
            "realized_tput_gbps": self.realized_tput_gbps,
            "planned_cost": self.planned_cost,
            "realized_cost": self.realized_cost,
            "delivered_gb": self.delivered_gb,
            "n_chunks": self.n_chunks,
            "delivered_chunks": self.delivered_chunks,
            "retried_chunks": self.retried_chunks,
            "lost_chunks": self.lost_chunks,
            "contended": self.contended,
            "replans": len(self.replans),
            "replan_struct_builds": sum(
                r.structure_builds for r in self.replans
            ),
            "deadline_met": self.deadline_met,
            "budget_exhausted": self.budget_exhausted,
            "degrade_level": self.degrade_level,
        }


@dataclasses.dataclass
class ServiceReport(Report):
    jobs: list[JobReport]
    time_s: float
    segments: int
    sim_events: int
    # breaker audit trail: every open/half-open/close transition
    quarantines: list = dataclasses.field(default_factory=list)

    @property
    def replans(self) -> list[ReplanRecord]:
        return [r for j in self.jobs for r in j.replans]

    @property
    def all_done(self) -> bool:
        return all(j.status == "done" for j in self.jobs)

    @property
    def partial_jobs(self) -> list[JobReport]:
        return [j for j in self.jobs if j.status == "partial"]

    @property
    def slo_violations(self) -> int:
        """Jobs that requested a deadline and missed it (late or partial)."""
        return sum(1 for j in self.jobs if j.deadline_met is False)

    @property
    def slo_violation_rate(self) -> float:
        with_slo = [j for j in self.jobs if j.request.deadline_s is not None]
        if not with_slo:
            return 0.0
        return sum(1 for j in with_slo if j.deadline_met is False) / len(with_slo)

    kind = "service"
    _summary_keys = ("jobs", "time_s", "delivered_gb", "segments",
                     "slo_violations")
    _metrics_prefixes = ("planner.", "service.", "breaker.")

    def _payload(self) -> dict:
        return {
            "jobs": len(self.jobs),
            "time_s": self.time_s,
            "segments": self.segments,
            "sim_events": self.sim_events,
            "delivered_gb": sum(j.delivered_gb for j in self.jobs),
            "all_done": self.all_done,
            "slo_violations": self.slo_violations,
            "slo_violation_rate": self.slo_violation_rate,
            "replans": len(self.replans),
            "replan_struct_builds": sum(
                r.structure_builds for r in self.replans
            ),
            "quarantines": len(self.quarantines),
            "per_job": [j.to_dict() for j in self.jobs],
        }


@dataclasses.dataclass
class _JobState:
    req: TransferRequest
    plan: TransferPlan  # or MulticastPlan for one-to-many jobs
    chunk_gbit: float
    remaining_chunks: int  # multicast: chunks the slowest branch still needs
    n_chunks: int
    planned_tput0: float = 0.0  # the admission-time plan's predictions
    planned_cost0: float = 0.0
    delivered_chunks: int = 0
    realized_cost: float = 0.0
    retried_chunks: int = 0
    finished_at: float | None = None
    status: str = "queued"
    degrade_level: int = 0  # DegradationLadder rungs climbed so far
    budget_exhausted: bool = False
    replans: list = dataclasses.field(default_factory=list)
    # multicast: cumulative chunks per destination region (capped at
    # n_chunks) — a full destination drops out of the next re-plan's goals,
    # so only the surviving branches are re-planned
    delivered_by_dst: dict = dataclasses.field(default_factory=dict)

    @property
    def remaining_gb(self) -> float:
        # half-chunk shave so re-chunking the remainder reproduces the
        # integer chunk count exactly (ceil is not float-robust at the edge)
        return max(self.remaining_chunks - 0.5, 0.5) * self.chunk_gbit / GBIT_PER_GB

    def dst_done(self, d: int) -> bool:
        return self.delivered_by_dst.get(d, 0) >= self.n_chunks


class TransferService:
    """Fault-tolerant multi-job transfer orchestrator.

    Usage::

        svc = TransferService(top, backend="jax")
        svc.submit(TransferRequest("job-a", src, dst, 8.0, 4.0))
        report = svc.run(faults=[LinkDegrade(t_s=5.0, src=s, dst=t, factor=0.3)])

    ``run`` simulates all admitted jobs concurrently on the multi-job fluid
    data plane, segmenting the timeline at each scripted fault: the fault is
    folded into the service's degraded-topology view, every affected
    unfinished job has its remaining volume re-planned under the degraded
    constraints (cached-structure refit), and the data plane resumes with
    the new allocations. Accumulated link degradations also throttle the
    simulator itself, so un-replanned jobs feel them too.

    Re-planning is chunk-granular: chunks in flight at a segment boundary
    restart under the new allocation (their partial bytes were already
    billed — the same semantics as the gateway re-dispatching a chunk whose
    worker died). A fault landing within one chunk-ETA of the previous one
    can therefore show zero delivered chunks for the short segment.

    Multicast jobs (``TransferRequest(dsts=[...])``) are admitted as ONE
    one-to-many plan; on a fault, only the surviving branches are
    re-planned — destinations that already hold every chunk get a zero
    goal on the same cached structure and drop out of the trees.
    """

    def __init__(
        self,
        top: Topology,
        *,
        backend: str = "jax",
        max_relays: int = 10,
        contention_ratio: float = 0.5,
        backoff_ladder: BackoffLadder | None = None,
        degradation: DegradationLadder | None = None,
        breaker: LinkBreaker | None = None,
        vm_budget: float | None = None,
    ):
        self.top = top
        self.backend = backend
        self.planner = Planner(top, max_relays=max_relays)
        self.contention_ratio = contention_ratio
        # the deployment's VM instance quota: no single plan of this
        # service may provision more VMs than the subscription allows.
        # None = uncapped. Enforced by goal backoff on every admission
        # and re-plan solve (_fit_vm_budget).
        self.vm_budget = vm_budget if vm_budget is None else float(vm_budget)
        self._vm_clamped: set[str] = set()
        self.backoff_ladder = (
            backoff_ladder if backoff_ladder is not None else BackoffLadder()
        )
        self.degradation = degradation
        self.breaker = breaker
        self._queue: list[TransferRequest] = []
        # degraded-topology view, accumulated across faults. Link health is
        # physical and shared by every tenant; VM loss is per job (job 0's
        # dead gateways say nothing about job 1's quota in that region).
        self.degraded_links: dict[tuple[int, int], float] = {}
        self.vm_caps_by_job: dict[int, dict[int, float]] = {}
        # gray view: rate multipliers the service does NOT know about —
        # GrayFailures fold here so the simulator keeps feeling them across
        # segment boundaries while plans stay blissfully on the healthy view
        self._gray: dict[tuple[int, int], float] = {}
        # link health stashed while the breaker quarantines it (the view
        # pins at 0.0; degrades/restores keep compounding on the shadow)
        self._pre_quarantine: dict[tuple[int, int], float] = {}
        # deadline-shedding state, set around re-plans of degraded jobs
        self._replan_z: float | None = None
        self._replan_trickle: float | None = None

    def submit(self, req: TransferRequest) -> TransferRequest:
        self._queue.append(req)
        return req

    # ------------------------------------------------------------------ run
    def _plan_scale(self) -> np.ndarray | None:
        """Full-grid [V,V] throughput scale every solve should plan under,
        or None. The base service trusts its grid; the calibration plane
        overrides this with the belief's lower-confidence-bound scale so
        every admission and re-plan is uncertainty-aware — the scale rides
        the cached LP structures as extra rows (zero re-assembly)."""
        return None

    def _spec_extras(self) -> dict:
        """Extra ``PlanSpec`` fields every solve of this service carries.

        The base service has none; the fleet controller injects its
        per-tenant ``agg_scale`` fair-share caps here so admission and
        re-plans alike respect the tenant's link shares."""
        return {}

    def _plan_spec(self, req: TransferRequest, goal, volume_gb: float,
                   *, vm_caps=None, constrained: bool) -> PlanSpec:
        """The ``PlanSpec`` for one admission/re-plan solve of ``req``."""
        common = dict(
            objective="cost_min",
            src=req.src,
            volume_gb=volume_gb,
            degraded_links=(dict(self.degraded_links)
                            if constrained and self.degraded_links else None),
            vm_caps=(dict(vm_caps)
                     if constrained and vm_caps else None),
            tput_scale=self._plan_scale(),
            **self._spec_extras(),
        )
        if req.multicast:
            goals = goal if np.ndim(goal) else float(goal)
            return PlanSpec(dsts=tuple(req.dsts), tput_goal_gbps=goals,
                            **common)
        return PlanSpec(
            dst=req.dst, tput_goal_gbps=float(goal),
            backend="numpy" if constrained else self.backend, **common,
        )

    def _plan_for(self, req: TransferRequest, goal: float, volume_gb: float,
                  *, vm_caps=None, constrained: bool) -> TransferPlan:
        """One admission/re-plan solve for either job flavor. A multicast
        re-plan only carries goals for the destinations still missing
        chunks, so faulted branches are re-planned and finished ones
        dropped — on the SAME cached structure (goals are pure RHS)."""
        plan = self.planner.plan(self._plan_spec(
            req, goal, volume_gb, vm_caps=vm_caps, constrained=constrained,
        ))
        if (
            not req.multicast
            and self._replan_trickle is not None
            and plan.solver_status == "optimal"
        ):
            # deadline shedding: a pressured job refuses slow paths
            plan = _drop_trickle_paths(plan, self._replan_trickle)
        return plan

    def _capacity(self, req: TransferRequest, *, vm_caps=None) -> float:
        common = dict(
            objective="max_throughput",
            src=req.src,
            degraded_links=dict(self.degraded_links) or None,
            vm_caps=dict(vm_caps) if vm_caps else None,
            tput_scale=self._plan_scale(),
            **self._spec_extras(),
        )
        if req.multicast:
            return self.planner.plan(PlanSpec(dsts=tuple(req.dsts), **common))
        return self.planner.plan(PlanSpec(dst=req.dst, **common))

    def _vm_budget_for(self, req: TransferRequest) -> float | None:
        """VM ceiling for one plan of ``req`` — the deployment's instance
        quota. The base service applies its flat ``vm_budget`` (the
        subscription limit an isolated tenant cannot exceed); the fleet
        controller overrides this with per-tenant quotas plus idle-pool
        borrowing."""
        return self.vm_budget

    def _fit_vm_budget(self, req: TransferRequest, plan, goal,
                       volume_gb: float, *, vm_caps=None, constrained):
        """Goal backoff until the plan fits the VM ceiling.

        VM counts are ceil-of-flow OUTPUTS of the LP, not constraint
        rows, so a quota cannot ride the cached structures as a cut —
        backing the throughput goal off (pure RHS, zero re-assembly) is
        how the budget is honored without a structure rebuild. If a
        backed-off solve goes infeasible the last optimal (over-budget)
        plan is kept: a quota violation the operator can see beats a
        failed job."""
        budget = self._vm_budget_for(req)
        if budget is None or plan.solver_status != "optimal":
            return plan
        g = goal
        for _ in range(4):
            if plan.num_vms <= budget + 1e-9:
                return plan
            shrink = max(min(budget / max(plan.num_vms, 1e-9), 0.75), 0.1)
            g = ([float(x) * shrink for x in g] if np.ndim(g)
                 else float(g) * shrink)
            self._vm_clamped.add(req.name)
            nxt = self._plan_for(req, g, volume_gb,
                                 vm_caps=vm_caps, constrained=constrained)
            if nxt.solver_status != "optimal":
                break
            plan = nxt
        return plan

    def _admit(self, req: TransferRequest) -> _JobState:
        if self.degraded_links or self._plan_scale() is not None:
            # the service already carries degraded links from earlier runs:
            # new tenants must be planned (and their predictions priced)
            # against that view, or they are flagged contended forever and
            # nothing ever re-routes them (constrained solves run on the
            # sequential backend; still a cached-structure refit)
            cap = self._capacity(req)
            goal = min(req.tput_goal_gbps, max(cap, 1e-9) * 0.95)
            plan = self._plan_for(req, goal, req.volume_gb, constrained=True)
            plan = self._fit_vm_budget(req, plan, goal, req.volume_gb,
                                       constrained=True)
        else:
            plan = self._plan_for(req, req.tput_goal_gbps, req.volume_gb,
                                  constrained=False)
            plan = self._fit_vm_budget(req, plan, req.tput_goal_gbps,
                                       req.volume_gb, constrained=False)
        return self._state_for(req, plan)

    def _state_for(self, req: TransferRequest, plan) -> _JobState:
        """Chunk the request and wrap its plan as a fresh job state."""
        cg = req.chunk_mb * 8.0 / 1024.0
        n_chunks = max(1, int(np.ceil(req.volume_gb * GBIT_PER_GB / cg)))
        st = _JobState(req=req, plan=plan, chunk_gbit=cg,
                       remaining_chunks=n_chunks, n_chunks=n_chunks,
                       planned_tput0=plan.throughput,
                       planned_cost0=plan.total_cost)
        st.status = "planned" if plan.solver_status == "optimal" else "failed"
        return st

    def _admit_queue(self) -> list[_JobState]:
        """Admission hook: turn the queued requests into job states, in
        submission order (fault scripts address jobs by that index). The
        base service admits everything with one planner call per job; the
        fleet controller overrides this with admission control, weighted
        fair shares, and one batched cohort solve."""
        states = [self._admit(r) for r in self._queue]
        self._queue = []
        return states

    def _replan(
        self, st: _JobState, job_ix: int, at_s: float, reason: str = "fault"
    ) -> None:
        req = st.req
        vm_caps = self.vm_caps_by_job.get(job_ix, {})
        t0 = time.perf_counter()
        builds0 = milp.N_STRUCT_BUILDS
        # deadline-pressure shedding is sticky: every re-plan of a degraded
        # job keeps the rungs it has climbed (z=0 / trickle-free plans)
        climbed: tuple[str, ...] = ()
        if self.degradation is not None and st.degrade_level > 0:
            climbed = self.degradation.steps[: st.degrade_level]
        self._replan_z = 0.0 if "shed_robustness" in climbed else None
        self._replan_trickle = (
            self.degradation.trickle_frac
            if "shed_trickle" in climbed else None
        )
        try:
            cap = self._capacity(req, vm_caps=vm_caps)
            if cap <= 1e-9:
                st.status = "failed"
                return
            base_goal = min(req.tput_goal_gbps, cap * 0.95)
            # A non-optimal constrained solve does not mean the job is
            # dead: a lower throughput goal may still be feasible on the
            # degraded topology. Walk the backoff ladder before declaring
            # failure; the record keeps the degraded SLO visible.
            goal, plan, backoffs = base_goal, None, 0
            fit_goal = base_goal
            for backoff, g in enumerate(self.backoff_ladder.goals(base_goal)):
                # the record reports the LAST goal actually attempted,
                # whether or not it was accepted
                goal, backoffs = g, backoff
                if req.multicast:
                    goals = [
                        0.0 if st.dst_done(self.top.index(d)) else g
                        for d in req.dsts
                    ]
                    if not any(goals):
                        return  # every branch already delivered in full
                    g_try = goals
                else:
                    g_try = g
                plan = self._plan_for(req, g_try, st.remaining_gb,
                                      vm_caps=vm_caps, constrained=True)
                fit_goal = g_try
                if plan.solver_status == "optimal":
                    break
            if plan is not None and plan.solver_status == "optimal":
                plan = self._fit_vm_budget(req, plan, fit_goal,
                                           st.remaining_gb,
                                           vm_caps=vm_caps, constrained=True)
        finally:
            self._replan_z = None
            self._replan_trickle = None
        rec = ReplanRecord(
            job=req.name,
            at_s=at_s,
            remaining_gb=st.remaining_gb,
            latency_s=time.perf_counter() - t0,
            structure_builds=milp.N_STRUCT_BUILDS - builds0,
            plan=plan,
            goal_gbps=goal,
            backoffs=backoffs,
            ladder=self.backoff_ladder.name,
            reason=reason,
        )
        st.replans.append(rec)
        REGISTRY.counter("service.replans").inc()
        tr = get_tracer()
        if tr.enabled:
            tr.instant("service.replan", float(at_s), track="service",
                       job=req.name, reason=reason,
                       struct_builds=rec.structure_builds)
        if plan.solver_status == "optimal":
            st.plan = plan
        else:
            st.status = "failed"

    def _post_replan(self, st: _JobState) -> None:
        """Hook for subclasses to refresh per-plan caches after a re-plan
        issued outside their own run loop (deadline/quarantine paths)."""

    # ------------------------------------------------------- chaos policies
    def _quarantine(self, key: tuple[int, int]) -> None:
        """Open-breaker quarantine: pin the link's degraded-view factor to
        0.0 — the planner turns that into ``extra_ub = 0`` rows on the
        CACHED structures, so no plan can route a byte over it and no
        constraint matrix is re-assembled. The link's real health keeps
        compounding on a shadow entry for when the breaker closes."""
        self._pre_quarantine[key] = self.degraded_links.get(key, 1.0)
        self.degraded_links[key] = 0.0
        REGISTRY.counter("service.quarantines").inc()

    def _unquarantine(self, key: tuple[int, int]) -> None:
        phi = self._pre_quarantine.pop(key, 1.0)
        if phi >= 1.0 - 1e-9:
            self.degraded_links.pop(key, None)
        else:
            self.degraded_links[key] = phi

    def _deadline_checks(self, states: list[_JobState], now: float) -> None:
        """At a segment boundary, escalate deadline-pressured jobs one rung
        down the degradation ladder — or cut them to partial delivery at
        the deadline itself. Jobs without a deadline are never touched."""
        for i, st in enumerate(states):
            if st.req.deadline_s is None:
                continue
            if st.status not in ("planned", "running") or not st.remaining_chunks:
                continue
            time_left = st.req.arrival_s + st.req.deadline_s - now
            if time_left <= T_EPS:
                # the deadline has passed with chunks outstanding: an
                # explicit partial delivery beats an unbounded overrun
                st.status = "partial"
                continue
            if self.degradation is None:
                continue
            rate = max(float(st.plan.throughput), 1e-9)
            elapsed = now - st.req.arrival_s
            if st.delivered_chunks > 0 and elapsed > T_EPS:
                realized = st.delivered_chunks * st.chunk_gbit / elapsed
                rate = min(rate, max(realized, 1e-9))
            eta = st.remaining_chunks * st.chunk_gbit / rate
            if eta * self.degradation.pressure <= time_left:
                continue
            if st.degrade_level >= len(self.degradation.steps):
                continue
            st.degrade_level += 1
            step = self.degradation.steps[st.degrade_level - 1]
            if step == "partial":
                st.status = "partial"
            else:
                self._replan(st, i, at_s=now, reason="deadline")
                self._post_replan(st)

    def _sim_faults(self) -> list:
        """The degraded + gray views as t=0 events for the simulator. The
        gray entries re-inject the silent slowdowns the service does not
        know about — both fold to the same rate multiply in the sim, the
        split only matters to the control plane."""
        evs: list = [
            LinkDegrade(t_s=0.0, src=a, dst=b, factor=phi)
            for (a, b), phi in self.degraded_links.items()
        ]
        evs += [
            GrayFailure(t_s=0.0, src=a, dst=b, factor=g)
            for (a, b), g in self._gray.items()
        ]
        return evs

    def _fold_segment(
        self, active: list[_JobState], res, now: float, *,
        restart: bool = False,
    ) -> None:
        """Fold one simulated segment's per-job results into job state
        (delivered/remaining chunks, realized cost, retries, status).

        ``restart=True`` marks a segment cut at a fault boundary: chunks
        in flight at the cut restart from scratch under the next plan, so
        they count against the job's retry budget — the fluid analogue of
        the gateway re-dispatching chunks whose worker died mid-copy."""
        for st, jr in zip(active, res.jobs):
            st.delivered_chunks += jr.chunks_delivered
            st.remaining_chunks -= jr.chunks_delivered
            st.realized_cost += jr.total_cost
            st.retried_chunks += jr.retried_chunks
            if restart and jr.status == "running":
                st.retried_chunks += jr.chunks_in_flight
            if jr.per_dst_delivered:
                for d, cnt in jr.per_dst_delivered.items():
                    st.delivered_by_dst[d] = min(
                        st.n_chunks,
                        st.delivered_by_dst.get(d, 0) + cnt,
                    )
            if jr.status == "done":
                st.status = "done"
                st.finished_at = (
                    now + max(st.req.arrival_s - now, 0.0) + jr.time_s
                )
            elif jr.status == "stalled":
                st.status = "stalled"
            elif jr.status == "running":
                st.status = "running"
            if (
                st.req.retry_budget is not None
                and st.retried_chunks > st.req.retry_budget
                and st.remaining_chunks > 0
            ):
                # budget exhausted with chunks outstanding: explicit
                # partial delivery, delivered count intact — never silent
                st.status = "partial"
                st.budget_exhausted = True

    def _job_reports(self, states: list[_JobState], now: float) -> list[JobReport]:
        """Final per-job reports from terminal (or horizon-cut) job state."""
        reports = []
        for st in states:
            delivered_gb = st.delivered_chunks * st.chunk_gbit / GBIT_PER_GB
            end = st.finished_at if st.finished_at is not None else now
            dur = max(end - st.req.arrival_s, 1e-9)
            realized_tput = st.delivered_chunks * st.chunk_gbit / dur
            status = st.status
            if status == "planned":  # never simulated (no active segment)
                status = "queued"
            if st.req.deadline_s is None:
                deadline_met = None
            else:
                deadline_met = (
                    status == "done"
                    and st.finished_at is not None
                    and st.finished_at - st.req.arrival_s
                    <= st.req.deadline_s + 1e-9
                )
            reports.append(JobReport(
                request=st.req,
                plan=st.plan,
                status=status,
                planned_tput_gbps=st.planned_tput0,
                planned_cost=st.planned_cost0,
                realized_tput_gbps=realized_tput,
                realized_cost=st.realized_cost,
                delivered_gb=delivered_gb,
                retried_chunks=st.retried_chunks,
                contended=(
                    status == "done"
                    and realized_tput
                    < self.contention_ratio * st.planned_tput0
                ),
                replans=st.replans,
                deadline_met=deadline_met,
                budget_exhausted=st.budget_exhausted,
                degrade_level=st.degrade_level,
                n_chunks=st.n_chunks,
                delivered_chunks=st.delivered_chunks,
            ))
        return reports

    def run(
        self,
        faults=(),
        *,
        seed: int = 0,
        link_capacity_scale: float | None = 2.0,
        sim=None,
        **sim_kwargs,
    ) -> ServiceReport:
        """Plan, execute and (on faults) re-plan every submitted job.

        ``faults`` are service-level events (events.LinkDegrade /
        events.LinkRestore / events.GrayFailure / events.VMFailure with
        absolute times); ``sim`` overrides the simulator entry point
        (defaults to transfer.sim.simulate — the reference oracle drops
        in for cross-checks).

        Visible events segment the timeline and fold into the degraded
        view (re-planning affected jobs); GrayFailures are SILENT — they
        reach the simulator so the data plane feels them, but never the
        planner's view, never a segment boundary, never a re-plan. That
        asymmetry is the whole gray-failure story: only telemetry (or a
        breaker fed by it) can catch what the control plane cannot see."""
        from .sim import simulate

        sim = sim or simulate
        states = self._admit_queue()
        visible = [f for f in faults if not isinstance(f, GrayFailure)]
        silent = sorted(
            (f for f in faults if isinstance(f, GrayFailure)),
            key=lambda f: f.t_s,
        )
        boundaries = sorted({float(f.t_s) for f in visible})
        by_time: dict[float, list] = {}
        for f in visible:
            by_time.setdefault(float(f.t_s), []).append(f)

        now = 0.0
        sim_events = 0
        segments = 0
        seg_end = 0.0
        for seg, boundary in enumerate(boundaries + [None]):
            # gray events already behind us compound into the gray view
            # (re-injected at t=0 each segment); upcoming ones within this
            # segment ride along at sim-relative times
            while silent and silent[0].t_s < now - T_EPS:
                f = silent.pop(0)
                key = (f.src, f.dst)
                g = self._gray.get(key, 1.0) * f.factor
                if abs(g - 1.0) <= 1e-9:
                    self._gray.pop(key, None)  # silent recovery healed it
                else:
                    self._gray[key] = g
            seg_silent = [
                dataclasses.replace(f, t_s=max(f.t_s - now, 0.0))
                for f in silent
                if boundary is None or f.t_s < boundary - T_EPS
            ]
            active = [
                st for st in states
                if st.status in ("planned", "running") and st.remaining_chunks
            ]
            if active:
                segments += 1
                sim_jobs = [
                    TransferJob(
                        plan=st.plan.with_volume(st.remaining_gb),
                        name=st.req.name,
                        arrival_s=max(st.req.arrival_s - now, 0.0),
                        chunk_mb=st.req.chunk_mb,
                    )
                    for st in active
                ]
                res = sim(
                    sim_jobs, self._sim_faults() + seg_silent,
                    horizon_s=None if boundary is None else boundary - now,
                    seed=seed + 101 * seg,
                    link_capacity_scale=link_capacity_scale,
                    **sim_kwargs,
                )
                sim_events += res.events
                self._fold_segment(active, res, now,
                                   restart=boundary is not None)
                seg_end = now + res.time_s
                tr = get_tracer()
                if tr.enabled:
                    tr.span("service.segment", now, res.time_s,
                            track="service", seg=seg, jobs=len(active),
                            sim_events=res.events)
            else:
                seg_end = now

            if boundary is None:
                now = seg_end
                break
            if not any(
                st.status in ("planned", "running") and st.remaining_chunks
                for st in states
            ):
                # everything terminal before the next fault: later faults
                # change nothing, and the makespan is the real sim end, not
                # the last scripted fault time
                now = seg_end
                break
            now = boundary

            # ---- breaker: cooldowns that elapsed by this boundary get
            # their half-open health check (the base service's stand-in
            # probe: did a visible restore arrive since the open?)
            if self.breaker is not None:
                for key in self.breaker.due_half_open(now):
                    healthy = self.breaker.restore_seen(key)
                    self.breaker.half_open_result(key, now, healthy)
                    if healthy:
                        self._unquarantine(key)
                        for i, st in enumerate(states):
                            if (
                                st.status in ("planned", "running")
                                and st.remaining_chunks
                            ):
                                self._replan(st, i, at_s=now,
                                             reason="quarantine")
                                self._post_replan(st)

            # ---- fold the fault(s) into the degraded-topology view
            affected: set[int] = set()

            def _mark_users(src: int, dst: int) -> None:
                for i, st in enumerate(states):
                    # a multicast job rides the link iff its envelope
                    # does (the bytes actually on the wire)
                    used = (
                        st.plan.G[src, dst]
                        if isinstance(st.plan, MulticastPlan)
                        else st.plan.F[src, dst]
                    )
                    if used > 1e-9:
                        affected.add(i)

            for f in by_time[boundary]:
                if isinstance(f, LinkDegrade):
                    key = (f.src, f.dst)
                    quarantined = (
                        self.breaker is not None
                        and self.breaker.is_quarantined(key)
                    )
                    if quarantined:
                        # the view stays pinned at 0.0; health compounds
                        # on the shadow for when the breaker closes
                        self._pre_quarantine[key] = (
                            self._pre_quarantine.get(key, 1.0) * f.factor
                        )
                    else:
                        self.degraded_links[key] = (
                            self.degraded_links.get(key, 1.0) * f.factor
                        )
                        _mark_users(f.src, f.dst)
                    if self.breaker is not None:
                        if self.breaker.record_failure(key, now) and (
                            not quarantined
                        ):
                            self._quarantine(key)
                            tr = get_tracer()
                            if tr.enabled:
                                tr.instant(
                                    "service.quarantine", now,
                                    track="service",
                                    link=f"{key[0]}->{key[1]}",
                                )
                            _mark_users(f.src, f.dst)
                elif isinstance(f, LinkRestore):
                    key = (f.src, f.dst)
                    if (
                        self.breaker is not None
                        and self.breaker.is_quarantined(key)
                    ):
                        self._pre_quarantine[key] = min(
                            self._pre_quarantine.get(key, 1.0) * f.factor,
                            1.0,
                        )
                        self.breaker.note_restore(key, now)
                    else:
                        phi = min(
                            self.degraded_links.get(key, 1.0) * f.factor, 1.0
                        )
                        if phi >= 1.0 - 1e-9:
                            self.degraded_links.pop(key, None)
                        else:
                            self.degraded_links[key] = phi
                        # restored capacity is worth re-optimizing for —
                        # every active job may want the healed link back
                        # (the no-breaker baseline's trap under flapping)
                        for i, st in enumerate(states):
                            affected.add(i)
                elif isinstance(f, VMFailure):
                    caps = self.vm_caps_by_job.setdefault(f.job, {})
                    lost = caps.get(f.region, float(self.top.limit_vm)) - f.count
                    caps[f.region] = max(lost, 0.0)
                    if 0 <= f.job < len(states):
                        affected.add(f.job)
                else:
                    raise TypeError(f"unknown fault {f!r}")
            for i in sorted(affected):
                st = states[i]
                if st.status in ("planned", "running") and st.remaining_chunks:
                    self._replan(st, i, at_s=boundary)
                    self._post_replan(st)

            # ---- deadline SLOs: escalate pressured jobs down the ladder
            self._deadline_checks(states, now)

        return ServiceReport(
            jobs=self._job_reports(states, now), time_s=now,
            segments=segments, sim_events=sim_events,
            quarantines=(
                list(self.breaker.transitions)
                if self.breaker is not None else []
            ),
        )
