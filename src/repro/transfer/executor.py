"""Plan execution front-end: run a TransferPlan on the fluid simulator and
reconcile realized cost/throughput against the planner's predictions, plus
the managed-service models for the Fig. 6 comparison."""

from __future__ import annotations

import dataclasses

from repro.core.baselines import CloudServiceModel
from repro.core.plan import TransferPlan
from repro.core.topology import Topology
from .flowsim import SimResult, simulate_transfer


@dataclasses.dataclass
class ExecutionReport:
    sim: SimResult
    planned_tput_gbps: float
    planned_cost: float
    tput_ratio: float  # achieved / planned
    cost_ratio: float  # realized / planned

    @property
    def time_s(self) -> float:
        return self.sim.time_s


def execute_plan(plan: TransferPlan, **sim_kwargs) -> ExecutionReport:
    sim = simulate_transfer(plan, **sim_kwargs)
    return ExecutionReport(
        sim=sim,
        planned_tput_gbps=plan.throughput,
        planned_cost=plan.total_cost,
        tput_ratio=sim.tput_gbps / max(plan.throughput, 1e-9),
        cost_ratio=sim.total_cost / max(plan.total_cost, 1e-9),
    )


def execute_service_model(
    model: CloudServiceModel, top: Topology, src: str, dst: str, volume_gb: float
) -> dict:
    t = model.transfer_time_s(top, src, dst, volume_gb)
    return {
        "service": model.name,
        "time_s": t,
        "tput_gbps": volume_gb * 8.0 / t,
        "cost": model.cost(top, src, dst, volume_gb),
    }
