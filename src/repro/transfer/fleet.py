"""Fleet control plane: multi-tenant transfer-as-a-service on ONE belief.

``FleetController`` runs many tenants' transfer jobs through a single
:class:`~repro.calibrate.service.CalibratedTransferService` loop instead
of one service instance per tenant. What the fleet shares, and what it
isolates:

  * **One belief, one calibrator.** Every tenant's probes and passive
    telemetry fold into the same :class:`BeliefGrid`; the shared
    :class:`Calibrator` runs with a probe dedup window, so a link any
    tenant measured recently is skipped in the next tenant's broad VoI
    sweep — probe dollars amortize across the fleet instead of N
    services re-measuring the same grid. Readers that need a stable view
    take epoch-versioned ``BeliefGrid.snapshot()``s.

  * **Admission control.** Queued requests are admitted in waves against
    per-route capacity (``max_throughput`` on the CACHED structures):
    deadline-class jobs are admitted first at their requested goal;
    bulk jobs take what fits under ``admission_margin`` of the route's
    remaining capacity, and a bulk job that would be squeezed below
    ``min_admit_frac`` of its request is *deferred* — its arrival is
    pushed past the estimated drain time of the jobs ahead of it, so it
    plans at full goal for a later wave instead of trickling now.

  * **Weighted max-min link shares.** Contended links (where the summed
    admitted demand exceeds the shared-link capacity) get per-tenant
    fair shares: deadline demand is carved out first, bulk tenants
    water-fill the residual in proportion to their weights. The shares
    ride every RE-plan as per-link aggregate ``agg_scale`` cuts — extra
    rows on the cached LP structures, zero re-assembly — so one tenant's
    re-routed remainder cannot squeeze another tenant off a link the
    fleet already arbitrated.

  * **One batched cohort solve.** The admitted wave's unicast cost-min
    specs are planned by ``Planner.plan_cohort`` — grouped by route and
    solved as ONE stacked ``solve_milp_batched`` sweep, not a Python
    loop of per-job planner calls.

Execution, drift detection, deadline ladders, breakers and epoch rolls
are all inherited unchanged — the fleet is a policy layer over the
calibrated loop, not a new data plane.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.calibrate.calibrator import Calibrator
from repro.obs.metrics import REGISTRY
from repro.obs.trace import get_tracer
from repro.calibrate.service import (
    CalibratedServiceReport,
    CalibratedTransferService,
)
from repro.core.topology import GBIT_PER_GB

from .executor import TransferRequest, _JobState
from .reports import Report

__all__ = [
    "FleetController",
    "FleetReport",
    "TenantReport",
    "TenantSpec",
]

_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant of the fleet.

    ``weight`` scales the tenant's bulk share in the weighted max-min
    water-fill; ``slo_class`` is ``"bulk"`` or ``"deadline"`` (deadline
    tenants are admitted and allocated before any bulk tenant);
    ``vm_quota`` caps the total VMs any single plan of this tenant may
    provision (enforced by goal backoff at admission); re-plans may
    additionally borrow idle quota from tenants that have drained — the
    pooled-subscription dividend of running as a fleet."""

    name: str
    weight: float = 1.0
    slo_class: str = "bulk"
    vm_quota: float | None = None

    def __post_init__(self):
        if self.slo_class not in ("bulk", "deadline"):
            raise ValueError(
                f"slo_class must be 'bulk' or 'deadline', "
                f"got {self.slo_class!r}"
            )
        if self.weight <= 0:
            raise ValueError("tenant weight must be positive")


@dataclasses.dataclass
class TenantReport(Report):
    """Per-tenant rollup of the fleet run."""

    name: str
    weight: float
    slo_class: str
    jobs: int
    requested_gb: float
    delivered_gb: float
    realized_cost: float
    deferred: int  # jobs pushed to a later admission wave
    quota_clamps: int  # jobs goal-backed-off to fit the VM quota
    deadline_misses: int
    probe_cost_share_usd: float  # shared calibrator cost / n_tenants
    quota_borrows: int = 0  # re-plans that ran on borrowed idle VM quota

    kind = "tenant"
    _summary_keys = ("name", "slo_class", "jobs", "delivered_gb",
                     "deferred", "deadline_misses")

    def _payload(self) -> dict:
        return {
            "name": self.name,
            "weight": self.weight,
            "slo_class": self.slo_class,
            "jobs": self.jobs,
            "requested_gb": self.requested_gb,
            "delivered_gb": self.delivered_gb,
            "realized_cost": self.realized_cost,
            "deferred": self.deferred,
            "quota_clamps": self.quota_clamps,
            "deadline_misses": self.deadline_misses,
            "probe_cost_share_usd": self.probe_cost_share_usd,
            "quota_borrows": self.quota_borrows,
        }


@dataclasses.dataclass
class FleetReport(CalibratedServiceReport):
    """The calibrated-service report plus the per-tenant rollups."""

    tenants: list[TenantReport] = dataclasses.field(default_factory=list)
    deferred_jobs: int = 0

    kind = "fleet"
    _summary_keys = ("jobs", "tenants_n", "time_s", "delivered_gb",
                     "probe_cost_usd", "deferred_jobs")
    _metrics_prefixes = ("planner.", "service.", "breaker.", "calibrate.",
                         "fleet.")

    def _payload(self) -> dict:
        d = super()._payload()
        d.update({
            "tenants_n": len(self.tenants),
            "deferred_jobs": self.deferred_jobs,
            "tenants": [t.to_dict() for t in self.tenants],
        })
        return d


def weighted_max_min(
    weights: list[float], demands: list[float], capacity: float
) -> list[float]:
    """Weighted max-min fair allocation of ``capacity`` across demands.

    Classic water-fill: repeatedly offer each unsatisfied demand its
    weight-proportional share of the remaining capacity; demands smaller
    than their share are fully satisfied and leave, donating the excess
    to the next round."""
    alloc = [0.0] * len(demands)
    active = [i for i, d in enumerate(demands) if d > _EPS]
    remaining = float(capacity)
    while active and remaining > _EPS:
        wsum = sum(weights[i] for i in active)
        fair = {i: remaining * weights[i] / wsum for i in active}
        satisfied = [i for i in active if demands[i] - alloc[i]
                     <= fair[i] + _EPS]
        if not satisfied:
            for i in active:
                alloc[i] += fair[i]
            remaining = 0.0
            break
        for i in satisfied:
            take = demands[i] - alloc[i]
            alloc[i] = demands[i]
            remaining -= take
        active = [i for i in active if i not in satisfied]
    return alloc


class FleetController(CalibratedTransferService):
    """Multi-tenant transfer-as-a-service over one calibrated loop.

    Usage::

        fleet = FleetController(drift, tenants=[
            TenantSpec("analytics", weight=1.0),
            TenantSpec("ml-sync", weight=2.0, slo_class="deadline"),
        ])
        fleet.submit(TransferRequest(...), tenant="analytics")
        report = fleet.run()
    """

    def __init__(
        self,
        drift,
        *,
        tenants: list[TenantSpec],
        probe_dedup_window_s: float = 8.0,
        admission_margin: float = 0.9,
        min_admit_frac: float = 0.35,
        min_link_share: float = 0.05,
        headroom_boost: float = 1.5,
        **kw,
    ):
        if not tenants:
            raise ValueError("a fleet needs at least one TenantSpec")
        self.tenants = {t.name: t for t in tenants}
        if len(self.tenants) != len(tenants):
            raise ValueError("duplicate tenant names")
        self.admission_margin = float(admission_margin)
        self.min_admit_frac = float(min_admit_frac)
        self.min_link_share = float(min_link_share)
        self.headroom_boost = float(headroom_boost)
        super().__init__(drift, **kw)
        # ONE calibrator for the whole fleet, probe dedup on: a broad VoI
        # sweep skips links any tenant measured inside the window, so the
        # fleet runs ONE default-sized round per boundary where N isolated
        # services would each run their own. Coverage of the union of
        # tenant subgraphs comes from the targeted confirmation probes the
        # calibrated loop fires at contention-masked links (the shared
        # data plane makes masking common in a fleet), not from scaling
        # the sweep budget by N.
        if self.calibrate and kw.get("calibrator") is None:
            self.calibrator = Calibrator(
                self.belief, dedup_window_s=float(probe_dedup_window_s),
            )
        # req.name -> tenant name (requests stay tenant-agnostic)
        self._tenant_of: dict[str, str] = {}
        # tenant name -> full-grid [V,V] agg share (np.inf = uncapped),
        # rebuilt at every admission wave; rides re-plans as agg_scale
        self._tenant_shares: dict[str, np.ndarray] = {}
        self._deferred: dict[str, float] = {}  # req.name -> deferred-to t
        # jobs goal-backed-off to fit a VM quota (the executor's shared
        # clamp set — the fleet reads it for per-tenant reporting)
        self._quota_clamped = self._vm_clamped
        # tenant -> re-plans that ran on a borrowed (pooled) VM budget
        self._quota_borrows: dict[str, int] = {}
        self._live_states: list[_JobState] = []
        self._active_tenant: str | None = None
        self._admitting = False
        self._probe_turn = 0  # rotating per-tenant sweep focus

    # ------------------------------------------------------------- submission
    def submit(self, req: TransferRequest,
               tenant: str | None = None) -> TransferRequest:
        if tenant is None:
            if len(self.tenants) != 1:
                raise ValueError("multi-tenant fleet: submit(..., tenant=)")
            tenant = next(iter(self.tenants))
        if tenant not in self.tenants:
            raise KeyError(f"unknown tenant {tenant!r}")
        if req.name in self._tenant_of:
            raise ValueError(f"duplicate job name {req.name!r}")
        self._tenant_of[req.name] = tenant
        return super().submit(req)

    # ------------------------------------------------------- per-tenant cuts
    def _spec_extras(self) -> dict:
        """Inject the active tenant's fair-share ``agg_scale`` into every
        RE-plan solve. Admission-wave solves stay cut-free (the wave's
        sharing is done on the goal side, so the cohort batches)."""
        if self._admitting or self._active_tenant is None:
            return {}
        share = self._tenant_shares.get(self._active_tenant)
        if share is None or not np.isfinite(share).any():
            return {}
        return {"agg_scale": share}

    def _plan_spec(self, req, goal, volume_gb, *, vm_caps=None, constrained):
        self._active_tenant = self._tenant_of.get(req.name)
        return super()._plan_spec(req, goal, volume_gb, vm_caps=vm_caps,
                                  constrained=constrained)

    def _capacity(self, req, *, vm_caps=None) -> float:
        self._active_tenant = self._tenant_of.get(req.name)
        return super()._capacity(req, vm_caps=vm_caps)

    # --------------------------------------------------------------- admission
    def _route_edges(self, req) -> list[tuple[int, int]]:
        """Full-grid candidate edges of the request's pruned subgraph —
        the links its plans could ever ride (same notion the calibrator
        uses for probe candidates)."""
        if req.multicast:
            sub, s, ds, keep = self.planner._prune_mc(req.src, list(req.dsts))
            edges = sub.edge_list(s, None)
        else:
            sub, s, t, keep = self.planner._prune(req.src, req.dst)
            edges = sub.edge_list(s, t)
        return [(keep[a], keep[b]) for a, b in edges]

    def _route_key(self, req):
        return (req.src, tuple(req.dsts)) if req.multicast \
            else (req.src, req.dst)

    def _admission(self, reqs: list[TransferRequest]) -> dict[str, float]:
        """Admission control: the goal each request is admitted at.

        Deadline-class jobs first, at their requested goal. Bulk jobs in
        submission order take what fits under ``admission_margin`` of
        their route's remaining capacity; a job squeezed below
        ``min_admit_frac`` of its request is deferred instead — arrival
        pushed past the estimated drain of the wave ahead of it, full
        goal restored.

        Admission is then work-conserving: capacity the wave leaves
        unclaimed under the margin is granted back to the admitted jobs
        pro-rata by tenant weight, up to ``headroom_boost`` x each
        request. This is the consolidation dividend an isolated
        per-tenant service cannot take — it must treat the request as a
        cap because it cannot see the other tenants' demand on the
        shared links, while the fleet knows the residual is genuinely
        idle this wave."""
        cap_cache: dict = {}
        committed: dict = {}  # route -> Gbps already admitted
        queued_gb: dict = {}  # route -> volume ahead of a deferred job

        def route_cap(req) -> float:
            key = self._route_key(req)
            if key not in cap_cache:
                cap_cache[key] = float(np.sum(self._capacity(req)))
            return cap_cache[key]

        def klass(req) -> str:
            if req.deadline_s is not None:
                return "deadline"
            return self.tenants[self._tenant_of[req.name]].slo_class

        goals: dict[str, float] = {}
        ordered = [r for r in reqs if klass(r) == "deadline"] + \
                  [r for r in reqs if klass(r) != "deadline"]
        for req in ordered:
            key = self._route_key(req)
            cap = route_cap(req)
            room = self.admission_margin * cap - committed.get(key, 0.0)
            want = float(np.sum(np.asarray(req.tput_goal_gbps, dtype=float)))
            if klass(req) == "deadline":
                goal = min(want, max(room, self.min_admit_frac * want))
            elif room >= self.min_admit_frac * want:
                goal = min(want, room)
            else:
                # defer: plan at full goal for the wave after the queue
                # ahead of it drains (capacity estimate, not a promise —
                # the data plane arbitrates the truth)
                ahead_gb = queued_gb.get(key, 0.0)
                drain_s = ahead_gb * GBIT_PER_GB / max(cap, _EPS)
                req.arrival_s = max(req.arrival_s, drain_s)
                self._deferred[req.name] = req.arrival_s
                goal = want
                REGISTRY.counter("fleet.deferrals").inc()
                tr = get_tracer()
                if tr.enabled:
                    tr.instant("fleet.deferral", float(req.arrival_s),
                               track="fleet", job=req.name)
            goals[req.name] = goal
            committed[key] = committed.get(key, 0.0) + (
                goal if req.name not in self._deferred else 0.0
            )
            queued_gb[key] = queued_gb.get(key, 0.0) + req.volume_gb
        # ---- work conservation: hand the wave's unclaimed margin back
        if self.headroom_boost > 1.0:
            by_route: dict = {}
            for req in reqs:
                if req.name not in self._deferred:
                    by_route.setdefault(self._route_key(req), []).append(req)
            for key, members in by_route.items():
                leftover = (self.admission_margin * cap_cache[key]
                            - committed.get(key, 0.0))
                if leftover <= _EPS:
                    continue
                wants = [
                    float(np.sum(np.asarray(r.tput_goal_gbps, dtype=float)))
                    for r in members
                ]
                extra = [max(self.headroom_boost * w - goals[r.name], 0.0)
                         for r, w in zip(members, wants)]
                weights = [
                    self.tenants[self._tenant_of[r.name]].weight
                    for r in members
                ]
                for r, grant in zip(
                    members, weighted_max_min(weights, extra, leftover)
                ):
                    goals[r.name] += grant
                    committed[key] = committed.get(key, 0.0) + grant
        return goals

    def _fair_shares(
        self, reqs: list[TransferRequest], goals: dict[str, float]
    ) -> dict[str, np.ndarray]:
        """Per-tenant full-grid aggregate link shares (np.inf = uncapped).

        Per contended link — summed admitted demand above the shared-link
        capacity — deadline demand is carved out first (submission
        order), then bulk jobs water-fill the residual with weights
        ``tenant.weight / n_tenant_jobs`` (so a tenant's total share is
        weight-proportional however it splits its jobs). Uncontended
        links stay uncapped: agg rows are emitted only where the fleet
        actually arbitrated."""
        V = len(self.top.keys())
        tput = np.asarray(self.top.tput, dtype=float)
        lcs = float(self.link_capacity_scale or 1.0)
        shares = {t: np.full((V, V), np.inf) for t in self.tenants}
        by_req = {r.name: r for r in reqs}
        # link -> list of (job name, demand fraction of link capacity)
        users: dict[tuple[int, int], list[str]] = {}
        n_jobs = {t: 0 for t in self.tenants}
        for req in reqs:
            n_jobs[self._tenant_of[req.name]] += 1
            for e in self._route_edges(req):
                users.setdefault(e, []).append(req.name)
        for (a, b), names in users.items():
            cap = lcs * tput[a, b]
            if cap <= _EPS or len(names) < 2:
                continue
            demand = {n: min(goals[n] / cap, 1.0) for n in names}
            if sum(demand.values()) <= 1.0 + _EPS:
                continue  # uncontended: no cut
            dl = [n for n in names if by_req[n].deadline_s is not None
                  or self.tenants[self._tenant_of[n]].slo_class
                  == "deadline"]
            bulk = [n for n in names if n not in dl]
            alloc: dict[str, float] = {}
            residual = 1.0
            for n in dl:  # deadline demand carved out first
                alloc[n] = min(demand[n], residual)
                residual -= alloc[n]
            if bulk:
                w = [self.tenants[self._tenant_of[n]].weight
                     / max(n_jobs[self._tenant_of[n]], 1) for n in bulk]
                d = [demand[n] for n in bulk]
                for n, a_frac in zip(bulk, weighted_max_min(w, d, residual)):
                    alloc[n] = a_frac
            per_tenant: dict[str, float] = {}
            for n, frac in alloc.items():
                t = self._tenant_of[n]
                per_tenant[t] = per_tenant.get(t, 0.0) + frac
            for t, frac in per_tenant.items():
                shares[t][a, b] = max(frac, self.min_link_share)
        return shares

    def _admit_queue(self) -> list[_JobState]:
        """The fleet's admission wave, replacing one-planner-call-per-job:

        1. admission control clamps/defers goals against route capacity;
        2. weighted max-min link shares are fixed for the wave (they ride
           every later re-plan as ``agg_scale`` cuts);
        3. the whole cohort is planned in ONE ``plan_cohort`` sweep
           (batched where the specs are batchable), cut-free — the
           wave's arbitration already happened on the goal side.

        States come back in submission order (fault scripts and reports
        address jobs by that index)."""
        reqs, self._queue = self._queue, []
        for r in reqs:
            if r.name not in self._tenant_of:
                raise ValueError(
                    f"job {r.name!r} was queued without a tenant"
                )
        goals = self._admission(reqs)
        REGISTRY.counter("fleet.admission_waves").inc()
        tr = get_tracer()
        if tr.enabled:
            tr.instant("fleet.admission_wave", 0.0, track="fleet",
                       jobs=len(reqs), deferred=len(self._deferred))
        self._tenant_shares = self._fair_shares(reqs, goals)
        self._admitting = True
        try:
            specs = [
                self._plan_spec(
                    r,
                    goals[r.name] / (len(r.dsts) if r.multicast else 1),
                    r.volume_gb, constrained=False,
                )
                for r in reqs
            ]
            plans = self.planner.plan_cohort(specs)
            states = []
            for req, plan in zip(reqs, plans):
                plan = self._enforce_quota(req, plan, goals[req.name])
                # the admitted goal IS the job's goal from here on: every
                # re-plan targets what admission granted (boost included),
                # not the original request
                req.tput_goal_gbps = (
                    goals[req.name] / len(req.dsts) if req.multicast
                    else goals[req.name]
                )
                states.append(self._state_for(req, plan))
        finally:
            self._admitting = False
        # the run loop owns the states; the fleet keeps a reference so
        # quota borrowing can see which jobs still hold VMs at re-plan time
        self._live_states = states
        return states

    def _enforce_quota(self, req, plan, goal: float):
        """Goal backoff until the plan fits the tenant's VM quota — the
        admission-wave entry point of the executor's ``_fit_vm_budget``."""
        return self._fit_vm_budget(req, plan, goal, req.volume_gb,
                                   constrained=False)

    def _vm_budget_for(self, req):
        """Per-tenant VM quota, with idle-pool borrowing on re-plans.

        At admission every tenant is held to its OWN subscription quota —
        the wave is full, there is nothing idle to lend. A RE-plan may
        instead provision up to the pooled fleet quota minus what other
        still-active quota'd jobs hold: a tenant whose recovery plan
        needs more VMs than its subscription allows borrows the idle
        quota of tenants that already drained. This is the consolidation
        dividend an isolated service structurally cannot take — its
        subscription limit is a wall, not a pool."""
        spec = self.tenants.get(self._tenant_of.get(req.name, ""))
        if spec is None or spec.vm_quota is None:
            return self.vm_budget
        if self._admitting or not self._live_states:
            return float(spec.vm_quota)
        pool = sum(float(t.vm_quota) for t in self.tenants.values()
                   if t.vm_quota is not None)
        # a tenant with ANY live job keeps its whole subscription reserved
        # (its plans may scale back up); only drained tenants lend quota
        busy = {
            self._tenant_of[st.req.name] for st in self._live_states
            if st.status in ("planned", "running") and st.remaining_chunks
        }
        reserved = sum(
            float(t.vm_quota) for name, t in self.tenants.items()
            if t.vm_quota is not None
            and name != self._tenant_of.get(req.name)
            and name in busy
        )
        eff = max(float(spec.vm_quota), pool - reserved)
        if eff > float(spec.vm_quota) + _EPS:
            t = self._tenant_of[req.name]
            self._quota_borrows[t] = self._quota_borrows.get(t, 0) + 1
            REGISTRY.counter("fleet.quota_borrows").inc()
        return eff

    def _probe_focus(self, states, act):
        """Rotating per-tenant sweep focus.

        One default-sized probe round per boundary, concentrated on a
        single tenant's candidate subgraph — the same per-round attention
        an isolated service gives its own links, time-multiplexed across
        the fleet instead of multiplied by it. Ranking the UNION of every
        tenant's candidates under one round's budget dilutes each
        tenant's plan links below the probe cut; focusing restores the
        isolated service's detection latency at a third of its spend.
        A hit on a shared link still rescues every tenant riding it: the
        probe's sample feeds every active job's drift check through the
        shared belief."""
        order = sorted({self._tenant_of[states[i].req.name] for i in act})
        if not order:
            return super()._probe_focus(states, act)
        focus = order[self._probe_turn % len(order)]
        self._probe_turn += 1
        sel = [i for i in act
               if self._tenant_of[states[i].req.name] == focus]
        ctxs = [
            (states[i].req.src, states[i].req.dsts)
            if states[i].req.multicast
            else (states[i].req.src, states[i].req.dst)
            for i in sel
        ]
        return ctxs, [states[i].plan for i in sel]

    def _deadline_checks(self, states, now: float) -> None:
        """Boundary hook: the inherited deadline ladder first, then quota
        upgrades — a VM-clamped job re-plans on the pooled budget once
        enough idle quota has appeared to matter (≥ 1 whole VM beyond its
        current plan). The re-plan rides the cached structures like every
        other re-plan (zero re-assembly); its record carries
        ``reason="quota-borrow"``."""
        super()._deadline_checks(states, now)
        for i, st in enumerate(states):
            if st.req.name not in self._quota_clamped:
                continue
            if st.status not in ("planned", "running") \
                    or not st.remaining_chunks:
                continue
            want = float(np.sum(np.asarray(
                st.req.tput_goal_gbps, dtype=float)))
            if float(st.plan.throughput) >= 0.95 * want:
                continue  # the clamp is not what is holding it back
            budget = self._vm_budget_for(st.req)
            if budget is None or budget < float(st.plan.num_vms) + 1.0:
                continue
            self._quota_clamped.discard(st.req.name)
            self._replan(st, i, at_s=now, reason="quota-borrow")
            self._post_replan(st)

    # ------------------------------------------------------------------ report
    def run(self, *args, **kwargs) -> FleetReport:
        base = super().run(*args, **kwargs)
        fields = {
            f.name: getattr(base, f.name)
            for f in dataclasses.fields(CalibratedServiceReport)
        }
        return FleetReport(
            **fields,
            tenants=self._tenant_reports(base),
            deferred_jobs=len(self._deferred),
        )

    def _tenant_reports(self, base) -> list[TenantReport]:
        probe_share = base.probe_cost_usd / max(len(self.tenants), 1)
        out = []
        for name, spec in self.tenants.items():
            jrs = [j for j in base.jobs
                   if self._tenant_of.get(j.request.name) == name]
            out.append(TenantReport(
                name=name, weight=spec.weight, slo_class=spec.slo_class,
                jobs=len(jrs),
                requested_gb=sum(j.request.volume_gb for j in jrs),
                delivered_gb=sum(j.delivered_gb for j in jrs),
                realized_cost=sum(j.realized_cost for j in jrs),
                deferred=sum(
                    1 for j in jrs if j.request.name in self._deferred
                ),
                quota_clamps=sum(
                    1 for j in jrs if j.request.name in self._quota_clamped
                ),
                deadline_misses=sum(
                    1 for j in jrs if j.deadline_met is False
                ),
                probe_cost_share_usd=probe_share,
                quota_borrows=self._quota_borrows.get(name, 0),
            ))
        return out
