"""Fluid (max-min fair) simulator of Skyplane's data plane.

Executes a ``TransferPlan`` at chunk granularity over the planned gateway
VMs and TCP connections:

  * per-connection nominal rate from the throughput grid at 64 connections,
    with the paper's sub-linear connection-scaling curve (Fig. 9a);
  * per-VM egress/ingress caps shared max-min fairly (water-filling) among
    the connections using that VM;
  * straggler connections (random slow multipliers) — mitigated by dynamic
    chunk dispatch (paper §6) vs. exposed by GridFTP-style static
    round-robin assignment;
  * hop-by-hop flow control: a relay whose chunk buffer is full stalls its
    incoming connections (paper §6);
  * store-and-forward per chunk at relays, pipelined across chunks.

Outputs transfer time, achieved throughput, realized egress/VM cost and
per-resource utilization for the bottleneck analysis (Fig. 8).

The event loop is vectorized (structure-of-arrays connection state, deque
chunk queues, bincount byte accounting, and max-min rates recomputed only
when the set of active connections changes), running ~an order of magnitude
more events/s than the object-per-connection reference preserved in
``flowsim_ref.py`` — enough to push Fig. 6/7/8 workloads to 10x the chunk
counts. Semantics match the reference (same RNG stream, same dispatch and
speculation rules); tests pin delivered-chunk counts to it at fixed seed.
"""

from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.core.plan import MulticastPlan, TransferPlan
from repro.core.topology import GBIT_PER_GB
from repro.obs.trace import get_tracer

from .simconfig import SimConfig
from .simconfig import resolve as resolve_sim_config
from .simconfig import warn_deprecated_entry as _warn_deprecated_entry

_EPS = 1e-12


def conn_efficiency(n: int, limit: int = 64) -> float:
    """Aggregate throughput fraction of the grid value achieved with n
    connections per VM pair (paper Fig. 9a: sub-linear, ~plateau at 64)."""
    if n <= 0:
        return 0.0
    return min(1.0, (n / limit) ** 0.9)


@dataclasses.dataclass
class SimResult:
    time_s: float
    tput_gbps: float
    egress_cost: float
    vm_cost: float
    total_cost: float
    chunks_delivered: int
    per_edge_gb: dict
    utilization: dict  # resource name -> fraction of capacity used
    bottlenecks: list  # resources with utilization >= threshold
    volume_gb: float = 0.0
    events: int = 0  # simulator event-loop iterations (perf accounting)

    @property
    def cost_per_gb(self) -> float:
        return self.total_cost / max(self.volume_gb, 1e-9)


def _maxmin_rates_arr(caps, src, dst, vm_eg_cap, vm_in_cap,
                      eid=None, edge_cap=None):
    """Water-filling max-min fair allocation over the active connections.

    caps/src/dst are aligned arrays for the active set; returns the rate
    array in the same order. Resources: each connection's own cap, each VM's
    egress cap over its outgoing conns, each VM's ingress cap over incoming,
    and — when ``eid``/``edge_cap`` are given (multi-job mode) — each shared
    wide-area link's capacity over every tenant's connections on it.
    """
    n = caps.shape[0]
    nv = max(int(src.max()), int(dst.max())) + 1
    eg_rem = vm_eg_cap[:nv].copy()
    in_rem = vm_in_cap[:nv].copy()
    ne = 0
    if eid is not None:
        ne = edge_cap.shape[0]
        ed_rem = edge_cap.copy()

    rate = np.zeros(n)
    fixed = np.zeros(n, dtype=bool)
    for _ in range(2 * nv + ne + 4):
        un = ~fixed
        if not un.any():
            break
        cnt_out = np.bincount(src[un], minlength=nv).astype(float)
        cnt_in = np.bincount(dst[un], minlength=nv).astype(float)
        with np.errstate(divide="ignore", invalid="ignore"):
            share_out = np.where(cnt_out > 0, eg_rem / np.maximum(cnt_out, 1), np.inf)
            share_in = np.where(cnt_in > 0, in_rem / np.maximum(cnt_in, 1), np.inf)
        share = np.minimum(share_out[src], share_in[dst])
        if ne:
            cnt_ed = np.bincount(eid[un], minlength=ne).astype(float)
            with np.errstate(divide="ignore", invalid="ignore"):
                share_ed = np.where(
                    cnt_ed > 0, ed_rem / np.maximum(cnt_ed, 1), np.inf
                )
            share = np.minimum(share, share_ed[eid])
        newly = un & (caps <= share + _EPS)
        if newly.any():
            rate[newly] = caps[newly]
        else:
            thresh = share[un].min()
            newly = un & (share <= thresh + _EPS)
            rate[newly] = share[newly]
        eg_rem -= np.bincount(src[newly], weights=rate[newly], minlength=nv)
        in_rem -= np.bincount(dst[newly], weights=rate[newly], minlength=nv)
        np.maximum(eg_rem, 0.0, out=eg_rem)
        np.maximum(in_rem, 0.0, out=in_rem)
        if ne:
            ed_rem -= np.bincount(eid[newly], weights=rate[newly], minlength=ne)
            np.maximum(ed_rem, 0.0, out=ed_rem)
        fixed |= newly
    return rate


def simulate_transfer(
    plan: TransferPlan,
    *,
    chunk_mb: float = 16.0,
    dispatch: str = "dynamic",  # "dynamic" (Skyplane) | "static" (GridFTP)
    straggler_prob: float = 0.05,
    straggler_speed: tuple[float, float] = (0.15, 0.5),
    relay_buffer_chunks: int = 64,
    seed: int = 0,
    util_threshold: float = 0.99,
    speculative: bool | None = None,  # re-dispatch straggling chunks (tail
    # kill). Defaults to True for dynamic dispatch — the natural extension of
    # paper §6's ready-connection dispatch; duplicate bytes are billed.
) -> SimResult:
    if speculative is None:
        speculative = dispatch == "dynamic"
    top = plan.top
    rng = np.random.default_rng(seed)
    paths = plan.paths()
    if not paths:
        raise ValueError("plan carries no flow")

    volume_gbit = plan.volume_gb * GBIT_PER_GB
    chunk_gbit = chunk_mb * 8.0 / 1024.0
    n_chunks = max(1, int(np.ceil(volume_gbit / chunk_gbit)))

    # ---- materialize VMs
    vm_of_region: dict[int, list[int]] = {}
    vm_eg_cap: list[float] = []
    vm_in_cap: list[float] = []
    vm_region: list[int] = []
    for r in range(top.num_regions):
        cnt = int(round(plan.N[r]))
        ids = []
        for _ in range(cnt):
            ids.append(len(vm_eg_cap))
            vm_eg_cap.append(top.limit_egress[r])
            vm_in_cap.append(top.limit_ingress[r])
            vm_region.append(r)
        vm_of_region[r] = ids

    # ---- materialize connections (SoA), same RNG stream as the reference
    path_len = {pid: len(path) - 1 for pid, (path, _) in enumerate(paths)}
    edge_flow_total: dict[tuple[int, int], float] = {}
    for path, flow in paths:
        for a, b in zip(path[:-1], path[1:]):
            edge_flow_total[(a, b)] = edge_flow_total.get((a, b), 0.0) + flow

    # stages: one per (path, hop), ids assigned in path/hop order
    stage_of: dict[tuple[int, int], int] = {}
    for pid, (path, _) in enumerate(paths):
        for hop in range(path_len[pid]):
            stage_of[(pid, hop)] = len(stage_of)
    n_stages = len(stage_of)

    c_edge: list[tuple[int, int]] = []
    c_sid: list[int] = []
    c_rate: list[float] = []
    c_src: list[int] = []
    c_dst: list[int] = []
    for pid, (path, flow) in enumerate(paths):
        for hop, (a, b) in enumerate(zip(path[:-1], path[1:])):
            m_edge = int(round(plan.M[a, b]))
            share = flow / edge_flow_total[(a, b)]
            n_conn = max(1, int(round(m_edge * share)))
            vms_a = vm_of_region.get(a) or []
            vms_b = vm_of_region.get(b) or []
            if not vms_a or not vms_b:
                raise ValueError(f"plan has flow on edge {a}->{b} but no VMs")
            per_pair = max(n_conn / (len(vms_a) * len(vms_b)), 1e-9)
            eff = conn_efficiency(per_pair * len(vms_b), top.limit_conn)
            nominal = top.tput[a, b] * eff / n_conn * len(vms_a)
            sid = stage_of[(pid, hop)]
            for c in range(n_conn):
                if rng.uniform() < straggler_prob:
                    mult = float(rng.uniform(*straggler_speed))
                else:
                    mult = float(np.exp(rng.normal(0.0, 0.05)))
                c_edge.append((a, b))
                c_sid.append(sid)
                c_rate.append(nominal * mult)
                c_src.append(vms_a[c % len(vms_a)])
                c_dst.append(vms_b[c % len(vms_b)])

    nc = len(c_sid)
    sid_arr = np.asarray(c_sid, dtype=np.int64)
    rate_eff = np.asarray(c_rate)
    src_vm = np.asarray(c_src, dtype=np.int64)
    dst_vm = np.asarray(c_dst, dtype=np.int64)
    edges_used = sorted(set(c_edge))
    edge_index = {e: i for i, e in enumerate(edges_used)}
    edge_id = np.asarray([edge_index[e] for e in c_edge], dtype=np.int64)
    vm_eg = np.asarray(vm_eg_cap, dtype=float)
    vm_in = np.asarray(vm_in_cap, dtype=float)

    # per-stage metadata
    stage_pid = np.zeros(n_stages, dtype=np.int64)
    stage_hop = np.zeros(n_stages, dtype=np.int64)
    stage_next = np.full(n_stages, -1, dtype=np.int64)  # downstream stage id
    for (pid, hop), sid in stage_of.items():
        stage_pid[sid] = pid
        stage_hop[sid] = hop
        if hop + 1 < path_len[pid]:
            stage_next[sid] = stage_of[(pid, hop + 1)]
    next_sid = stage_next[sid_arr]  # -1 when this hop is the last

    chunk_arr = np.full(nc, -1, dtype=np.int64)
    remaining = np.zeros(nc)

    flows = np.array([f for _, f in paths])
    flow_frac = flows / flows.sum()

    # chunk -> path assignment: proportional to planned flow (both modes)
    chunk_path = rng.choice(len(paths), size=n_chunks, p=flow_frac)
    ready: list[deque] = [deque() for _ in range(n_stages)]
    for ch in range(n_chunks):
        ready[stage_of[(int(chunk_path[ch]), 0)]].append(ch)
    # static (GridFTP) mode: pre-assign chunks round-robin to connections
    static_assign: dict[int, deque] = {}
    if dispatch == "static":
        by_first_hop: dict[int, list[int]] = {}
        for ci in range(nc):
            if stage_hop[sid_arr[ci]] == 0:
                by_first_hop.setdefault(int(stage_pid[sid_arr[ci]]), []).append(ci)
        rrobin: dict[int, int] = {}
        for ch in range(n_chunks):
            pid = int(chunk_path[ch])
            lst = by_first_hop[pid]
            k = rrobin.get(pid, 0)
            static_assign.setdefault(lst[k % len(lst)], deque()).append(ch)
            rrobin[pid] = k + 1
    # every first-hop connection is statically routed in static mode — even
    # ones that received no chunks (they must NOT fall through to the shared
    # ready queue, mirroring the reference semantics)
    is_static_first = np.zeros(nc, dtype=bool)
    if dispatch == "static":
        is_static_first = stage_hop[sid_arr] == 0

    relay_occ = np.zeros(n_stages, dtype=np.int64)  # buffered chunks per stage
    done_hops: set[tuple[int, int]] = set()  # (sid, chunk)
    replicas: dict[tuple[int, int], int] = {}  # (sid, chunk) -> replica count
    delivered = 0
    now = 0.0
    edge_gbit_vec = np.zeros(len(edges_used))
    vm_busy_out = np.zeros(len(vm_eg_cap))
    vm_busy_in = np.zeros(len(vm_eg_cap))

    # per-cascade-pass cache: sid -> (eta, chunk) of the worst eligible
    # in-flight chunk, or None; invalidated when the stage's state changes
    spec_cache: dict[int, tuple[float, int] | None] = {}

    def _stage_worst(sid: int):
        cand = np.flatnonzero((sid_arr == sid) & (chunk_arr >= 0))
        if cand.size == 0:
            return None
        etas = remaining[cand] / np.maximum(rate_eff[cand], _EPS)
        for j in np.argsort(-etas):
            ch = int(chunk_arr[cand[j]])
            if replicas.get((sid, ch), 1) < 2:
                return float(etas[j]), ch
        return None

    def try_speculate(ci: int) -> bool:
        """Idle conn + empty queue: duplicate the worst-ETA in-flight chunk
        on this stage; first finisher wins, loser's bytes are billed."""
        sid = int(sid_arr[ci])
        if sid in spec_cache:
            worst = spec_cache[sid]
        else:
            worst = _stage_worst(sid)
            spec_cache[sid] = worst
        if worst is None:
            return False
        eta, ch = worst
        if eta < 2.0 * (chunk_gbit / max(rate_eff[ci], _EPS)):
            return False
        replicas[(sid, ch)] = replicas.get((sid, ch), 1) + 1
        chunk_arr[ci] = ch
        remaining[ci] = chunk_gbit
        spec_cache.pop(sid, None)
        return True

    def try_refill(ci: int) -> bool:
        sid = sid_arr[ci]
        nsid = next_sid[ci]
        # flow control: downstream relay buffer full -> stall
        if nsid >= 0 and relay_occ[nsid] >= relay_buffer_chunks:
            return False
        if is_static_first[ci]:
            q = static_assign.get(ci)
            if not q:
                return False
        else:
            q = ready[sid]
            if not q:
                if speculative and not (dispatch == "static" and stage_hop[sid] == 0):
                    return try_speculate(ci)
                return False
        ch = q.popleft()
        chunk_arr[ci] = ch
        remaining[ci] = chunk_gbit
        if stage_hop[sid] > 0:
            relay_occ[sid] -= 1
        spec_cache.pop(int(sid), None)  # stage gained an in-flight chunk
        return True

    max_events = n_chunks * 6 * max(path_len.values()) + 10000
    events = 0
    last_active = None
    rates = None
    for _ in range(max_events):
        # cascade refills (buffer drains unlock upstream); candidate filter
        # keeps each pass O(conns with plausibly available work)
        while True:
            progressed = False
            spec_cache.clear()
            idle = chunk_arr < 0
            if not idle.any():
                break
            queue_work = np.fromiter(
                (len(q) > 0 for q in ready), dtype=bool, count=n_stages
            )[sid_arr]
            cand_mask = idle & queue_work
            if dispatch == "static":
                static_work = np.zeros(nc, dtype=bool)
                for ci, q in static_assign.items():
                    if q:
                        static_work[ci] = True
                cand_mask = (idle & static_work) | (cand_mask & ~is_static_first)
            if speculative:
                inflight = np.bincount(
                    sid_arr[chunk_arr >= 0], minlength=n_stages
                ) > 0
                spec_mask = idle & inflight[sid_arr] & ~queue_work
                if dispatch == "static":
                    spec_mask &= ~is_static_first
                cand_mask |= spec_mask
            for ci in np.flatnonzero(cand_mask):
                if chunk_arr[ci] < 0 and try_refill(ci):
                    progressed = True
            if not progressed:
                break
        active_ix = np.flatnonzero(chunk_arr >= 0)
        if active_ix.size == 0:
            break
        events += 1
        # max-min rates depend only on the active membership: reuse if same
        if last_active is None or not np.array_equal(active_ix, last_active):
            rates = _maxmin_rates_arr(
                rate_eff[active_ix], src_vm[active_ix], dst_vm[active_ix],
                vm_eg, vm_in,
            )
            last_active = active_ix
        safe_rates = np.maximum(rates, _EPS)
        dt = max(float((remaining[active_ix] / safe_rates).min()), 1e-9)
        now += dt
        moved = rates * dt
        remaining[active_ix] -= moved
        edge_gbit_vec += np.bincount(
            edge_id[active_ix], weights=moved, minlength=len(edges_used)
        )
        vm_busy_out += np.bincount(
            src_vm[active_ix], weights=moved, minlength=vm_busy_out.shape[0]
        )
        vm_busy_in += np.bincount(
            dst_vm[active_ix], weights=moved, minlength=vm_busy_in.shape[0]
        )
        completed = active_ix[remaining[active_ix] <= 1e-9]
        for ci in completed:
            ch = int(chunk_arr[ci])
            if ch < 0:
                continue  # cancelled earlier in this event by a replica win
            sid = int(sid_arr[ci])
            chunk_arr[ci] = -1
            remaining[ci] = 0.0
            key = (sid, ch)
            if key in done_hops:
                continue  # a replica already finished this hop
            done_hops.add(key)
            if replicas.get(key, 1) > 1:
                losers = np.flatnonzero((sid_arr == sid) & (chunk_arr == ch))
                chunk_arr[losers] = -1
                remaining[losers] = 0.0
            nsid = int(stage_next[sid])
            if nsid >= 0:
                ready[nsid].append(ch)
                relay_occ[nsid] += 1
            else:
                delivered += 1
        if delivered >= n_chunks:
            break

    time_s = max(now, 1e-9)
    tput = delivered * chunk_gbit / time_s
    per_edge_gb = {e: edge_gbit_vec[i] / GBIT_PER_GB
                   for e, i in edge_index.items() if edge_gbit_vec[i] > 0}
    egress_cost = sum(
        gb * top.price_egress[e] for e, gb in per_edge_gb.items()
    )
    vm_cost = float(plan.N @ top.price_vm) * time_s

    # ---- utilization / bottleneck attribution (Fig. 8)
    src_r, dst_r = plan.src, plan.dst
    util: dict[str, float] = {}
    for v in range(len(vm_eg_cap)):
        r = vm_region[v]
        loc = ("source_vm" if r == src_r else
               "dest_vm" if r == dst_r else "overlay_vm")
        used = max(vm_busy_out[v], vm_busy_in[v])
        cap = (vm_eg_cap[v] if vm_busy_out[v] >= vm_busy_in[v] else vm_in_cap[v])
        u = used / max(cap * time_s, _EPS)
        util[loc] = max(util.get(loc, 0.0), u)
    for (a, b), gb in per_edge_gb.items():
        loc = "source_link" if a == src_r else "overlay_link"
        cap = top.tput[a, b] * max(plan.N[a], 1)
        u = gb * GBIT_PER_GB / max(cap * time_s, _EPS)
        util[loc] = max(util.get(loc, 0.0), u)
    bottlenecks = [k for k, v in util.items() if v >= util_threshold]

    res = SimResult(
        time_s=time_s,
        tput_gbps=tput,
        egress_cost=float(egress_cost),
        vm_cost=float(vm_cost),
        total_cost=float(egress_cost + vm_cost),
        chunks_delivered=delivered,
        per_edge_gb={f"{e[0]}->{e[1]}": gb for e, gb in per_edge_gb.items()},
        utilization=util,
        bottlenecks=bottlenecks,
        volume_gb=plan.volume_gb,
        events=events,
    )
    return res


# --------------------------------------------------------------------- multi
def simulate_multi(
    jobs,
    faults=(),
    *,
    config: SimConfig | None = None,
    link_capacity_scale: float | None = 2.0,
    straggler_prob: float = 0.05,
    straggler_speed: tuple[float, float] = (0.15, 0.5),
    relay_buffer_chunks: int = 64,
    seed: int = 0,
    horizon_s: float | None = None,
    exec_top=None,
    drain: bool = False,
):
    """Deprecated alias for ``transfer.sim.simulate(engine="soa")``.

    Kept (signature-pinned, bitwise-equal) for backward compatibility;
    new code goes through the dispatcher, which is the one place the
    ``engine`` knob is honored. SKY010 bans fresh first-party calls."""
    _warn_deprecated_entry("flowsim.simulate_multi")
    return _simulate_multi_impl(
        jobs, faults, config=config,
        link_capacity_scale=link_capacity_scale,
        straggler_prob=straggler_prob, straggler_speed=straggler_speed,
        relay_buffer_chunks=relay_buffer_chunks, seed=seed,
        horizon_s=horizon_s, exec_top=exec_top, drain=drain,
    )


def _simulate_multi_impl(
    jobs,
    faults=(),
    *,
    config: SimConfig | None = None,
    link_capacity_scale: float | None = 2.0,
    straggler_prob: float = 0.05,
    straggler_speed: tuple[float, float] = (0.15, 0.5),
    relay_buffer_chunks: int = 64,
    seed: int = 0,
    horizon_s: float | None = None,
    exec_top=None,
    drain: bool = False,
):
    """Vectorized multi-job simulator with scripted faults (ISSUE 2/3).

    Runs every ``TransferJob`` concurrently on one fluid data plane:

      * jobs arrive at ``job.arrival_s``; chunks enter their first-hop
        queues on arrival;
      * connections of all tenants share VM caps per job AND the wide-area
        links — each directed region pair is a fluid resource of capacity
        ``link_capacity_scale * top.tput[a, b]`` divided max-min fairly
        (``link_capacity_scale=None`` disables link contention);
      * a job whose plan is a ``MulticastPlan`` uploads each chunk once and
        fans out at relays: a completed hop feeds EVERY child stage of its
        distribution tree (deduplicated — shared segments carry a chunk
        once), deliveries are tracked per destination, and the job is done
        when every destination holds every chunk;
      * ``events.LinkDegrade`` multiplies the affected connections' rates
        and the shared link cap mid-transfer;
      * ``events.VMFailure`` kills gateway VMs: their connections die and
        any chunk they carried re-enters its stage queue and retries on a
        surviving connection of the same branch (counted in
        ``retried_chunks``; a stage whose every connection died stalls the
        job);
      * ``horizon_s`` cuts the run (jobs report status "running"). All
        time comparisons share one tolerance (``events.T_EPS``) so a
        boundary event cannot be classified inconsistently.
        ``drain=True`` makes the cut graceful: past the horizon no new
        chunk is picked up and no further scripted event applies, but
        chunks already on the wire run to completion (``time_s`` may
        exceed the horizon). Periodic re-segmentation (the calibration
        plane's probe cadence) NEEDS this — a hard cut discards every
        in-flight chunk, so a link whose per-chunk ETA exceeds the
        segment length would never complete anything across restarts;
      * ``exec_top`` executes against a different throughput grid than the
        jobs were planned on (the calibration plane's believed/true split
        — see ``events.materialize_jobs``); per-job results then carry
        ``per_edge_active_s`` so observed link rates (GB over busy
        seconds) can feed the belief as passive telemetry.

    Dispatch is the dynamic (paper §6) mode; speculation is off so retry
    accounting stays exact. Returns ``events.MultiSimResult``; the oracle
    is ``flowsim_ref.simulate_multi_reference`` (same per-job chunk counts
    at fixed seed — pinned by tests/test_multijob.py + test_multicast.py).
    """
    from .events import T_EPS, JobSimResult, MultiSimResult
    from .events import materialize_jobs, sorted_schedule

    cfg = resolve_sim_config(
        config, link_capacity_scale=link_capacity_scale,
        straggler_prob=straggler_prob, straggler_speed=straggler_speed,
        relay_buffer_chunks=relay_buffer_chunks, seed=seed,
        horizon_s=horizon_s, exec_top=exec_top, drain=drain,
    )
    link_capacity_scale = cfg.link_capacity_scale
    relay_buffer_chunks = cfg.relay_buffer_chunks
    horizon_s, drain = cfg.horizon_s, cfg.drain
    su = materialize_jobs(
        jobs, seed=cfg.seed, straggler_prob=cfg.straggler_prob,
        straggler_speed=cfg.straggler_speed, exec_top=cfg.exec_top,
    )
    top = su.top
    J = len(jobs)
    nc = su.conn_job.shape[0]
    ne = len(su.edges_used)
    rate_eff = su.conn_rate.copy()
    sid_arr = su.conn_sid
    children = su.stage_children
    edge_cap = None
    if link_capacity_scale is not None:
        edge_cap = np.array(
            [top.tput[a, b] * link_capacity_scale for a, b in su.edges_used]
        )

    conn_alive = np.ones(nc, dtype=bool)
    vm_alive = np.ones(su.vm_eg_cap.shape[0], dtype=bool)
    arrived = np.zeros(J, dtype=bool)
    chunk_arr = np.full(nc, -1, dtype=np.int64)
    remaining = np.zeros(nc)
    chunk_size = su.chunk_gbit[su.conn_job]  # per-conn chunk size (Gbit)
    ready: list[deque] = [deque() for _ in range(su.n_stages)]
    relay_occ = np.zeros(su.n_stages, dtype=np.int64)
    done_hops: set[tuple[int, int]] = set()
    enqueued: set[tuple[int, int]] = set()  # fan-in dedup on propagation
    n_slots = su.slot_job.shape[0]
    delivered = np.zeros(n_slots, dtype=np.int64)
    retried = np.zeros(J, dtype=np.int64)
    finish: list[float | None] = [None] * J
    job_edge_gbit = np.zeros(J * ne)
    # telemetry observation window: bytes and busy-seconds accumulated only
    # BEFORE the drain starts. The drain tail (a handful of straggler
    # connections finishing their last chunk) would otherwise dilute
    # bytes-over-busy-time far below the rate the link actually sustained,
    # and the calibration plane would read healthy links as drifted.
    job_edge_obs_gbit = np.zeros(J * ne)
    job_edge_busy = np.zeros(J * ne)  # obs-window seconds with active conns

    sched = sorted_schedule(jobs, faults)
    ptr = 0
    now = 0.0
    last_active = None
    rates = None
    tr = get_tracer()
    if tr.enabled:
        tr.instant("sim.start", 0.0, jobs=J, scheduled=len(sched))

    def apply_due():
        nonlocal ptr, last_active
        from .events import RATE_EVENTS, VMFailure

        applied_t = None
        rate_n = 0
        while ptr < len(sched) and sched[ptr][0] <= now + T_EPS:
            t_ev = sched[ptr][0]
            ev = sched[ptr][2]
            ptr += 1
            last_active = None  # any event can change rates/membership
            applied_t = t_ev
            if isinstance(ev, int):  # job arrival
                arrived[ev] = True
                firsts = su.first_stage[ev]
                for ch in range(int(su.n_chunks[ev])):
                    for s0 in firsts[int(su.chunk_path[ev][ch])]:
                        ready[s0].append(ch)
                if tr.enabled:
                    tr.instant("sim.arrival", t_ev, job=int(ev),
                               chunks=int(su.n_chunks[ev]))
            elif isinstance(ev, RATE_EVENTS):
                # LinkDegrade / GrayFailure / LinkRestore: one compounding
                # multiply on the link's connection rates and shared cap —
                # gray-vs-visible is a control-plane distinction, the data
                # plane feels them all the same way
                on_edge = np.array(
                    [e == (ev.src, ev.dst) for e in su.edges_used], dtype=bool
                )
                rate_eff[on_edge[su.conn_edge]] *= ev.factor
                if edge_cap is not None:
                    edge_cap[on_edge] *= ev.factor
                # rate events arrive in bursts (gray/flap trains expand to
                # thousands); coalesced per batch below so tracing stays
                # inside the obs/tracing_overhead_ratio gate
                rate_n += 1
            elif isinstance(ev, VMFailure):
                kill = [
                    v for v in np.flatnonzero(
                        (su.vm_job == ev.job) & (su.vm_region == ev.region)
                    )
                    if vm_alive[v]
                ][: ev.count]
                requeued = 0
                if kill:
                    vm_alive[kill] = False
                    hit = conn_alive & (
                        np.isin(su.conn_src, kill)
                        | np.isin(su.conn_dst, kill)
                    )
                    for ci in np.flatnonzero(hit):
                        if chunk_arr[ci] >= 0:
                            sid = int(sid_arr[ci])
                            ready[sid].append(int(chunk_arr[ci]))
                            if su.stage_hop[sid] > 0:
                                relay_occ[sid] += 1
                            retried[su.conn_job[ci]] += 1
                            chunk_arr[ci] = -1
                            remaining[ci] = 0.0
                            requeued += 1
                    conn_alive[hit] = False
                if tr.enabled:
                    tr.instant("sim.vm_failure", t_ev, job=int(ev.job),
                               region=int(ev.region), killed=len(kill),
                               requeued=requeued)
            else:
                raise TypeError(f"unknown event {ev!r}")
        if applied_t is not None and tr.enabled:
            if rate_n:
                tr.instant("sim.rate_events", applied_t, n=rate_n)
            # per-link active-connection sample after every applied batch;
            # ts comes from the schedule (exact), not the float clock
            counts = np.bincount(
                su.conn_edge[chunk_arr >= 0], minlength=ne
            )
            for i, (a, b) in enumerate(su.edges_used):
                if counts[i]:
                    tr.sample(f"link {a}->{b}", applied_t, int(counts[i]))

    def try_refill(ci: int) -> bool:
        sid = int(sid_arr[ci])
        # flow control: ANY full downstream buffer stalls the stage — with
        # fan-out, the slowest branch backpressures the shared segment
        for nsid in children[sid]:
            if relay_occ[nsid] >= relay_buffer_chunks:
                return False
        q = ready[sid]
        if not q:
            return False
        chunk_arr[ci] = q.popleft()
        remaining[ci] = chunk_size[ci]
        if su.stage_hop[sid] > 0:
            relay_occ[sid] -= 1
        return True

    max_events = (
        int((su.n_chunks * 6).sum()) * su.max_hops + 10000 + 8 * len(sched)
    )
    events = 0
    draining = False
    for _ in range(max_events):
        if not draining:
            apply_due()
        if horizon_s is not None and now >= horizon_s - T_EPS:
            if not drain:
                break
            draining = True
        # cascade refills (buffer drains unlock upstream); a draining run
        # picks up nothing new
        while not draining:
            progressed = False
            idle = (chunk_arr < 0) & conn_alive & arrived[su.conn_job]
            if not idle.any():
                break
            queue_work = np.fromiter(
                (len(q) > 0 for q in ready), dtype=bool, count=su.n_stages
            )[sid_arr]
            for ci in np.flatnonzero(idle & queue_work):
                if chunk_arr[ci] < 0 and try_refill(ci):
                    progressed = True
            if not progressed:
                break
        active_ix = np.flatnonzero(chunk_arr >= 0)
        t_next = (
            sched[ptr][0] if ptr < len(sched) and not draining else None
        )
        if active_ix.size == 0:
            if t_next is not None and (
                horizon_s is None or t_next < horizon_s - T_EPS
            ):
                now = t_next
                continue
            break
        events += 1
        if last_active is None or not np.array_equal(active_ix, last_active):
            rates = _maxmin_rates_arr(
                rate_eff[active_ix], su.conn_src[active_ix],
                su.conn_dst[active_ix], su.vm_eg_cap, su.vm_in_cap,
                eid=None if edge_cap is None else su.conn_edge[active_ix],
                edge_cap=edge_cap,
            )
            last_active = active_ix
        if float(rates.max(initial=0.0)) <= 1e-9 and t_next is None:
            break  # all remaining links dead: no progress possible, stall
        safe_rates = np.maximum(rates, _EPS)
        dt = max(float((remaining[active_ix] / safe_rates).min()), 1e-9)
        if t_next is not None and now + dt > t_next:
            dt = t_next - now
        horizon_hit = False
        obs_live = not draining  # telemetry window ends where the drain starts
        if horizon_s is not None and now + dt >= horizon_s - T_EPS:
            if drain:
                draining = True  # past the boundary: in-flight only
            else:
                dt = horizon_s - now
                horizon_hit = True
        now += dt
        moved = rates * dt
        remaining[active_ix] -= moved
        je = su.conn_job[active_ix] * ne + su.conn_edge[active_ix]
        job_edge_gbit += np.bincount(je, weights=moved, minlength=J * ne)
        if obs_live:
            job_edge_obs_gbit += np.bincount(
                je, weights=moved, minlength=J * ne
            )
            job_edge_busy[np.unique(je)] += dt
        completed = active_ix[remaining[active_ix] <= 1e-9]
        for ci in completed:
            ch = int(chunk_arr[ci])
            sid = int(sid_arr[ci])
            chunk_arr[ci] = -1
            remaining[ci] = 0.0
            key = (sid, ch)
            if key in done_hops:
                continue
            done_hops.add(key)
            slot = int(su.stage_deliver[sid])
            if slot >= 0:
                delivered[slot] += 1
                j = int(su.slot_job[slot])
                if delivered[slot] >= su.n_chunks[j] and all(
                    delivered[s] >= su.n_chunks[j] for s in su.job_slots[j]
                ):
                    finish[j] = now
                    if tr.enabled:
                        tr.instant("sim.job_done", now, job=j)
            for nsid in children[sid]:
                if (nsid, ch) in enqueued:
                    continue  # another in-edge already fed this stage
                enqueued.add((nsid, ch))
                ready[nsid].append(ch)
                relay_occ[nsid] += 1
        if horizon_hit:
            break
        if all(f is not None for f in finish):
            break

    horizon_cut = horizon_s is not None and now >= horizon_s - T_EPS
    out = []
    for j, job in enumerate(jobs):
        end = finish[j] if finish[j] is not None else now
        dur = max(end - float(su.arrivals[j]), 1e-9)
        eg = job_edge_gbit[j * ne : (j + 1) * ne]
        ego = job_edge_obs_gbit[j * ne : (j + 1) * ne]
        busy = job_edge_busy[j * ne : (j + 1) * ne]
        per_edge_gb = {
            f"{a}->{b}": eg[i] / GBIT_PER_GB
            for i, (a, b) in enumerate(su.edges_used) if eg[i] > 0
        }
        per_edge_obs_gb = {
            f"{a}->{b}": ego[i] / GBIT_PER_GB
            for i, (a, b) in enumerate(su.edges_used) if busy[i] > 0
        }
        per_edge_active_s = {
            f"{a}->{b}": float(busy[i])
            for i, (a, b) in enumerate(su.edges_used) if busy[i] > 0
        }
        eg_cost = sum(
            eg[i] / GBIT_PER_GB * top.price_egress[a, b]
            for i, (a, b) in enumerate(su.edges_used)
        )
        if finish[j] is not None:
            status = "done"
        elif not arrived[j]:
            status, dur = "pending", 0.0
        elif horizon_cut:
            status = "running"
        else:
            status = "stalled"
        slots = su.job_slots[j]
        full_copies = int(min(delivered[s] for s in slots))
        per_dst = (
            {int(su.slot_dst[s]): int(delivered[s]) for s in slots}
            if isinstance(job.plan, MulticastPlan) else None
        )
        vm_cost = float(job.plan.N @ job.plan.top.price_vm) * dur
        out.append(JobSimResult(
            job=j,
            name=job.name,
            time_s=dur,
            tput_gbps=float(full_copies * su.chunk_gbit[j]) / max(dur, 1e-9),
            chunks_delivered=full_copies,
            n_chunks=int(su.n_chunks[j]),
            retried_chunks=int(retried[j]),
            egress_cost=float(eg_cost),
            vm_cost=vm_cost,
            total_cost=float(eg_cost + vm_cost),
            status=status,
            per_edge_gb=per_edge_gb,
            per_dst_delivered=per_dst,
            per_edge_active_s=per_edge_active_s,
            per_edge_obs_gb=per_edge_obs_gb,
            chunks_in_flight=int(np.count_nonzero(
                (su.conn_job == j) & (chunk_arr >= 0)
            )),
        ))
    if tr.enabled:
        tr.instant("sim.end", now,
                   delivered=sum(int(r.chunks_delivered) for r in out))
    return MultiSimResult(jobs=out, time_s=now, events=events)
