"""Accelerator-resident multi-job flow simulator (fixed-shape jax).

Third engine of the ``transfer.sim`` dispatcher ("jax"), bitwise-pinned
against the numpy SoA loop (``flowsim.simulate_multi``) and therefore
against the object-per-connection oracle. The event loop runs entirely
on-device under ``lax.while_loop`` over padded structure-of-arrays state
with validity masks; the host keeps only the scripted schedule — each
segment of the loop runs until the next due event, the host applies it
(numpy, the exact reference logic, emitting the same Skytrace stream)
and re-enters. The max-min water-filling step is the masked pure-jnp
transliteration (``kernels.waterfill.ref.masked_maxmin_rates``, bitwise
vs the numpy oracle under f64) on CPU, or the Pallas one-hot-matmul
kernel (``kernels.waterfill``) on TPU backends.

Exact-semantics notes (each is load-bearing for chunk-for-chunk parity):

  * ``None`` horizons / exhausted schedules are encoded as +inf — every
    comparison the SoA loop makes (``now >= horizon - T_EPS``,
    ``t_next < horizon``, ``now + dt > t_next``, the stall check's
    ``t_next is None``) evaluates identically under IEEE inf;
  * cascade refills run a single batched pass when no relay buffer is at
    capacity (blocked-ness is per stage and ``relay_occ`` only decreases
    during a cascade, so eligibility is static and the SoA pass order
    equals rank-in-stage order); with any buffer full it falls back to an
    exact sequential sweep replicating the reference pass structure;
  * ``moved = rates * dt`` feeds both the remaining-update and the
    telemetry segment-sums — the multiple use (plus living inside
    ``lax.while_loop``) keeps LLVM from contracting the multiply-subtract
    into an FMA, which would break last-ulp parity with numpy;
  * segment-sums over masked lanes add interspersed ``+0.0`` terms to the
    reference bincounts, which cannot change an IEEE sum; masked minima
    pad with ``+inf``, which never wins.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64
from jax.ops import segment_sum

from repro.core.plan import MulticastPlan
from repro.core.topology import GBIT_PER_GB
from repro.obs.trace import get_tracer

from .simconfig import SimConfig
from .simconfig import resolve as resolve_sim_config

_EPS = 1e-12  # flowsim._EPS
_INF = float("inf")


class _Sc(NamedTuple):
    """Static (hashable) shape/config key — jit retraces per value."""

    ncp: int  # conns padded to a multiple of 8
    ns: int  # stages (buffers carry one extra dump row)
    j: int  # jobs
    nslot: int  # completion slots
    ne: int  # shared edges
    qcap: int  # ready-queue ring capacity (>= max chunks per job)
    maxch: int  # max children per stage
    nv: int  # VMs
    ne_bound: int  # edge count in the water-filling round bound (0 when
    # link contention is off — the oracle's bound excludes edges then)
    solver: str  # "masked" (f64 parity) | "pallas" (f32 TPU kernel)
    n_iters: int  # pallas kernel grid length


class _Cn(NamedTuple):
    """Per-scenario constants (traced, but never mutated)."""

    conn_job: jnp.ndarray
    conn_sid: jnp.ndarray
    conn_src: jnp.ndarray
    conn_dst: jnp.ndarray
    conn_edge: jnp.ndarray
    conn_valid: jnp.ndarray
    chunk_size: jnp.ndarray
    conn_first: jnp.ndarray  # first conn index of this conn's stage
    stage_hop: jnp.ndarray  # [NS + 1]
    stage_deliver: jnp.ndarray  # [NS + 1]
    children: jnp.ndarray  # [NS + 1, MAXCH], -1 padded
    slot_job: jnp.ndarray
    slot_need: jnp.ndarray  # n_chunks of the slot's job
    vm_eg: jnp.ndarray
    vm_in: jnp.ndarray
    horizon: jnp.ndarray  # f64 scalar, +inf when None
    drain: jnp.ndarray  # bool scalar
    relay_cap: jnp.ndarray  # i64 scalar
    max_events: jnp.ndarray  # i64 scalar
    t_eps: jnp.ndarray  # f64 scalar (events.T_EPS)
    one: jnp.ndarray  # f64 1.0, runtime-traced — FMA defeat (see _step)
    # pallas solver operands (1-element dummies under "masked")
    p_ssrc: jnp.ndarray
    p_ssrc_t: jnp.ndarray
    p_sdst: jnp.ndarray
    p_sdst_t: jnp.ndarray
    p_sed: jnp.ndarray
    p_sed_t: jnp.ndarray
    p_eg8: jnp.ndarray
    p_in8: jnp.ndarray


class _St(NamedTuple):
    """Mutable simulation state (the while-loop carry)."""

    now: jnp.ndarray
    it: jnp.ndarray  # loop iterations (the reference's for-range budget)
    events: jnp.ndarray  # iterations that reached the rate step
    draining: jnp.ndarray
    stop: jnp.ndarray  # terminal break reached
    t_sched: jnp.ndarray  # next unapplied scripted event time (+inf)
    chunk_arr: jnp.ndarray  # [NCp] chunk id in flight, -1 idle
    remaining: jnp.ndarray  # [NCp] Gbit left of the in-flight chunk
    rate_eff: jnp.ndarray  # [NCp] per-conn cap (host scales on events)
    conn_alive: jnp.ndarray
    arrived: jnp.ndarray  # [J]
    ready_buf: jnp.ndarray  # [NS + 1, QCAP] ring buffers (+ dump row)
    q_head: jnp.ndarray  # [NS + 1] monotonic pop counter
    q_tail: jnp.ndarray  # [NS + 1] monotonic push counter
    relay_occ: jnp.ndarray  # [NS + 1]
    done_bm: jnp.ndarray  # [NS + 1, QCAP] hop-completion dedup
    enq_bm: jnp.ndarray  # [NS + 1, QCAP] fan-in enqueue dedup
    delivered: jnp.ndarray  # [NSLOT]
    finished: jnp.ndarray  # [J]
    finish: jnp.ndarray  # [J] f64, +inf until finished
    jeg: jnp.ndarray  # [J * NE] per-(job, edge) Gbit moved
    jeo: jnp.ndarray  # [J * NE] observation-window Gbit
    jeb: jnp.ndarray  # [J * NE] observation-window busy seconds
    edge_cap: jnp.ndarray  # [NE] shared caps (BIG-like when disabled)
    rates: jnp.ndarray  # [NCp] cached water-filling solution
    last_active: jnp.ndarray  # [NCp] membership the cache was solved for
    rates_valid: jnp.ndarray
    td_time: jnp.ndarray  # [J + 1] buffered sim.job_done instants
    td_job: jnp.ndarray
    td_n: jnp.ndarray


def _compute_rates(st: _St, cn: _Cn, sc: _Sc, active):
    if sc.solver == "pallas":
        from repro.kernels.waterfill.ops import _interpret
        from repro.kernels.waterfill.waterfill import waterfill_8x

        nc128 = cn.p_ssrc.shape[0]

        def lane(v, width):
            row = jnp.zeros(width, dtype=jnp.float32)
            row = row.at[: v.shape[0]].set(v.astype(jnp.float32))
            return jnp.broadcast_to(row[None, :], (8, width))

        nep = cn.p_sed.shape[1]
        r8 = waterfill_8x(
            lane(st.rate_eff, nc128), lane(active.astype(jnp.float64), nc128),
            cn.p_eg8, cn.p_in8, lane(st.edge_cap, nep),
            cn.p_ssrc, cn.p_ssrc_t, cn.p_sdst, cn.p_sdst_t,
            cn.p_sed, cn.p_sed_t, n_iters=sc.n_iters,
            interpret=_interpret(),
        )
        return r8[0, : sc.ncp].astype(st.rates.dtype)
    from repro.kernels.waterfill.ref import masked_maxmin_rates

    return masked_maxmin_rates(
        st.rate_eff, cn.conn_src, cn.conn_dst, cn.vm_eg, cn.vm_in,
        cn.conn_edge, st.edge_cap, active, n_vms=sc.nv, n_edges=sc.ne,
        n_edges_bound=sc.ne_bound,
    )


def _cascade_batch(st: _St, cn: _Cn, sc: _Sc, run) -> _St:
    """Single-pass batched refill — exact while no relay buffer is full.

    ``run`` predicates the whole pass (False turns every take off): the
    hot loop calls this unconditionally instead of under ``lax.cond``,
    because a cond whose branches carry the state would make XLA copy the
    O(chunks) ring buffers/bitmaps every iteration (see ``_step``)."""
    i64 = st.q_head.dtype
    idle = (
        run & (st.chunk_arr < 0) & st.conn_alive
        & st.arrived[cn.conn_job] & cn.conn_valid
    )
    qlen = st.q_tail - st.q_head
    elig = idle & (qlen[cn.conn_sid] > 0)
    ef = elig.astype(i64)
    excl = jnp.cumsum(ef) - ef
    rank = excl - excl[cn.conn_first]
    take = elig & (rank < qlen[cn.conn_sid])
    row = jnp.where(take, cn.conn_sid, sc.ns)
    pos = (st.q_head[row] + rank) % sc.qcap
    ch = st.ready_buf[row, jnp.where(take, pos, 0)]
    cnt = segment_sum(take.astype(i64), row, num_segments=sc.ns + 1)
    return st._replace(
        chunk_arr=jnp.where(take, ch, st.chunk_arr),
        remaining=jnp.where(take, cn.chunk_size, st.remaining),
        q_head=st.q_head + cnt,
        relay_occ=st.relay_occ - jnp.where(cn.stage_hop > 0, cnt, 0),
    )


def _cascade_seq(small, st: _St, cn: _Cn, sc: _Sc):
    """Exact sequential replication of the reference cascade passes.

    Carries only the four arrays the cascade writes (``small`` =
    (chunk_arr, remaining, q_head, relay_occ)); everything else — the
    ready ring buffers in particular — is read through ``st`` as a
    read-only closure capture, so the enclosing ``lax.cond`` never has
    the big buffers among its outputs (no per-iteration copies)."""
    i64 = st.q_head.dtype

    def pass_body(carry):
        (chunk_arr, remaining, q_head, relay_occ), _ = carry
        idle = (
            (chunk_arr < 0) & st.conn_alive
            & st.arrived[cn.conn_job] & cn.conn_valid
        )
        any_idle = jnp.any(idle)
        cand = idle & ((st.q_tail - q_head)[cn.conn_sid] > 0)

        def per_conn(i, inner):
            (chunk_arr, remaining, q_head, relay_occ), prog = inner
            sid = cn.conn_sid[i]
            want = cand[i] & (chunk_arr[i] < 0)
            kids = cn.children[sid]
            blocked = jnp.any(
                (kids >= 0)
                & (relay_occ[jnp.maximum(kids, 0)] >= cn.relay_cap)
            )
            take = want & ~blocked & (st.q_tail[sid] > q_head[sid])
            ch = st.ready_buf[sid, q_head[sid] % sc.qcap]
            one = jnp.where(take, jnp.asarray(1, i64), jnp.asarray(0, i64))
            dec = jnp.where(cn.stage_hop[sid] > 0, one, jnp.asarray(0, i64))
            out = (
                chunk_arr.at[i].set(jnp.where(take, ch, chunk_arr[i])),
                remaining.at[i].set(
                    jnp.where(take, cn.chunk_size[i], remaining[i])
                ),
                q_head.at[sid].add(one),
                relay_occ.at[sid].add(-dec),
            )
            return out, prog | take

        def do_pass(t):
            return jax.lax.fori_loop(
                0, sc.ncp, per_conn, (t, jnp.bool_(False))
            )

        t, prog = jax.lax.cond(
            any_idle, do_pass, lambda t: (t, jnp.bool_(False)),
            (chunk_arr, remaining, q_head, relay_occ),
        )
        return t, prog

    small, _ = jax.lax.while_loop(
        lambda c: c[1], pass_body, (small, jnp.bool_(True))
    )
    return small


def _step(st: _St, cn: _Cn, sc: _Sc) -> _St:
    """Rate solve + stall check + fluid step + event-less jump, merged.

    The reference picks work vs jump vs stall with branches; here every
    effect is PREDICATED (``jnp.where`` on small arrays, no-op dump-row
    scatters on the big ones) instead of routed through ``lax.cond`` on
    the whole state. XLA resolves conditional aliasing by inserting
    copies, so a state-carrying cond duplicates the O(chunks) ring
    buffers and dedup bitmaps on EVERY loop iteration — measured ~14 MB
    per event at 1e5 chunks, which is what made the device loop lose to
    the numpy engine. Only ``_compute_rates`` (padded-lane output) and
    the rare full-relay sequential cascade stay behind conds, and neither
    carries a chunk-sized output."""
    i64 = st.q_head.dtype
    active = st.chunk_arr >= 0
    has_active = jnp.any(active)
    work = ~st.stop & has_active
    jump = ~st.stop & ~has_active
    events = st.events + work.astype(i64)

    changed = work & (~st.rates_valid | jnp.any(active != st.last_active))
    rates = jax.lax.cond(
        changed,
        lambda: _compute_rates(st, cn, sc, active),
        lambda: st.rates,
    )
    last_active = jnp.where(work, active, st.last_active)
    rates_valid = st.rates_valid | work
    t_next = jnp.where(st.draining, _INF, st.t_sched)
    stalled = work & (jnp.max(rates) <= 1e-9) & ~jnp.isfinite(t_next)
    adv = work & ~stalled
    jok = jnp.isfinite(t_next) & (t_next < cn.horizon - cn.t_eps)

    # ---- fluid step: the formulas are the reference's verbatim; every
    # consumer masks on ``adv`` (the garbage they produce when adv is
    # False never lands anywhere)
    safe = jnp.maximum(rates, _EPS)
    ratio = jnp.where(active, st.remaining / safe, _INF)
    dt = jnp.maximum(jnp.min(ratio), 1e-9)
    dt = jnp.where(
        jnp.isfinite(t_next) & (st.now + dt > t_next), t_next - st.now, dt
    )
    obs_live = ~st.draining  # telemetry window ends where the drain starts
    cross = adv & (st.now + dt >= cn.horizon - cn.t_eps)
    horizon_hit = cross & ~cn.drain
    draining = st.draining | (cross & cn.drain)
    dt = jnp.where(horizon_hit, cn.horizon - st.now, dt)
    now = jnp.where(adv, st.now + dt, jnp.where(jump & jok, t_next, st.now))

    # The trailing * cn.one (a runtime-traced 1.0) is an FMA defeat: LLVM
    # contracts `rem - rates * dt` (and the segment-sum adds of it) into
    # fused multiply-adds, a 1-ulp drift vs the numpy loop. XLA fusions
    # clone cheap ops, so multi-use alone does not protect the multiply,
    # and bitcast round-trips fold away below XLA. With the extra multiply
    # the contractible producer is `x * one`, and fma(x, 1.0, r) IS the
    # correctly-rounded r + x (the * 1.0 is exact) — contraction becomes
    # harmless instead of prevented.
    moved = rates * dt * cn.one
    act_adv = active & adv
    remaining = jnp.where(act_adv, st.remaining - moved, st.remaining)
    w = jnp.where(act_adv, moved, 0.0)
    je = cn.conn_job * sc.ne + cn.conn_edge
    seg = segment_sum(w, je, num_segments=sc.j * sc.ne)
    jeg = jnp.where(adv, st.jeg + seg, st.jeg)
    je_on = segment_sum(
        act_adv.astype(w.dtype), je, num_segments=sc.j * sc.ne
    ) > 0
    jeo = jnp.where(adv & obs_live, st.jeo + seg, st.jeo)
    jeb = jnp.where(adv & obs_live & je_on, st.jeb + dt, st.jeb)

    # ---- batched hop completions (ascending-conn order is preserved:
    # one parent per child stage, contiguous conns per stage)
    completed = act_adv & (remaining <= 1e-9)
    ch = jnp.maximum(st.chunk_arr, 0)
    sid = cn.conn_sid
    newdone = completed & ~st.done_bm[sid, ch]
    done_bm = st.done_bm.at[sid, ch].max(newdone)
    slot = cn.stage_deliver[sid]
    sval = newdone & (slot >= 0)
    delivered = st.delivered + segment_sum(
        sval.astype(i64), jnp.maximum(slot, 0), num_segments=sc.nslot
    )
    ok_slot = delivered >= cn.slot_need
    bad = segment_sum(
        (~ok_slot).astype(i64), cn.slot_job, num_segments=sc.j
    )
    job_ok = adv & (bad == 0)
    newly = job_ok & ~st.finished
    finished = st.finished | job_ok
    finish = jnp.where(newly, now, st.finish)
    nf = newly.astype(i64)
    idx = jnp.where(newly, st.td_n + jnp.cumsum(nf) - nf, sc.j)
    td_time = st.td_time.at[idx].set(now)
    td_job = st.td_job.at[idx].set(jnp.arange(sc.j, dtype=i64))
    td_n = st.td_n + jnp.sum(nf)

    ready_buf, q_tail, relay_occ, enq_bm = (
        st.ready_buf, st.q_tail, st.relay_occ, st.enq_bm
    )
    for k in range(sc.maxch):
        nsid = cn.children[sid, k]
        has = newdone & (nsid >= 0)
        nsid_cl = jnp.where(has, nsid, sc.ns)
        val = has & ~enq_bm[nsid_cl, ch]
        vf = val.astype(i64)
        excl = jnp.cumsum(vf) - vf
        rank = excl - excl[cn.conn_first]
        row = jnp.where(val, nsid_cl, sc.ns)
        pos = jnp.where(val, (q_tail[row] + rank) % sc.qcap, 0)
        ready_buf = ready_buf.at[row, pos].set(
            jnp.where(val, ch, ready_buf[row, pos])
        )
        cnt = segment_sum(vf, row, num_segments=sc.ns + 1)
        q_tail = q_tail + cnt
        relay_occ = relay_occ + cnt
        enq_bm = enq_bm.at[row, ch].max(val)

    stop = jnp.where(
        adv, horizon_hit | jnp.all(finished),
        jnp.where(jump, ~jok,
                  jnp.where(stalled, jnp.bool_(True), st.stop)),
    )
    return st._replace(
        now=now, draining=draining, stop=stop, events=events,
        rates=rates, last_active=last_active, rates_valid=rates_valid,
        chunk_arr=jnp.where(completed, -1, st.chunk_arr),
        remaining=jnp.where(completed, 0.0, remaining),
        ready_buf=ready_buf, q_tail=q_tail, relay_occ=relay_occ,
        done_bm=done_bm, enq_bm=enq_bm, delivered=delivered,
        finished=finished, finish=finish, jeg=jeg, jeo=jeo, jeb=jeb,
        td_time=td_time, td_job=td_job, td_n=td_n,
    )


@functools.partial(jax.jit, static_argnames=("sc",))
def _segment(st: _St, cn: _Cn, sc: _Sc) -> _St:
    """Run event-loop iterations until a scripted event is due (the host
    applies it and re-enters), a terminal break is reached, or the
    iteration budget is spent."""

    def cond(st):
        would = ~st.draining & (st.t_sched <= st.now + cn.t_eps)
        return ~st.stop & (st.it < cn.max_events) & ~would

    def body(st):
        # Straight-line, predicated body. lax.cond branches that output the
        # O(chunks) buffers force XLA copy-insertion of those buffers every
        # iteration (14MB/iter at 1e5 chunks); every effect below is instead
        # masked with jnp.where / no-op dump-row scatters so the big arrays
        # are donated through the loop carry untouched.
        st = st._replace(it=st.it + 1)
        cross = st.now >= cn.horizon - cn.t_eps
        st = st._replace(
            stop=cross & ~cn.drain, draining=st.draining | (cross & cn.drain)
        )
        run = ~st.stop & ~st.draining
        use_seq = jnp.any(st.relay_occ[: sc.ns] >= cn.relay_cap)
        st = _cascade_batch(st, cn, sc, run & ~use_seq)
        # The per-chunk sequential cascade (relay caps binding) is rare and
        # inherently serial; it stays behind a cond, but only the four small
        # arrays it writes are carried — the big buffers are closure-read.
        small = (st.chunk_arr, st.remaining, st.q_head, st.relay_occ)
        small = jax.lax.cond(
            run & use_seq,
            lambda t: _cascade_seq(t, st, cn, sc),
            lambda t: t,
            small,
        )
        st = st._replace(
            chunk_arr=small[0], remaining=small[1],
            q_head=small[2], relay_occ=small[3],
        )
        return _step(st, cn, sc)

    return jax.lax.while_loop(cond, body, st)


# ------------------------------------------------------------------ host side
def _pad128(n: int) -> int:
    return max(128, -(-n // 128) * 128)


def _build(su, cfg, sched, solver: str):
    """Materialized scenario -> (static key, constants, initial state)."""
    from repro.kernels.waterfill.waterfill import BIG

    nc = int(su.conn_job.shape[0])
    ncp = max(8, -(-nc // 8) * 8)
    ns = int(su.n_stages)
    j = int(su.arrivals.shape[0])
    nslot = int(su.slot_job.shape[0])
    ne = len(su.edges_used)
    nv = int(su.vm_eg_cap.shape[0])
    qcap = max(1, int(su.n_chunks.max()))
    # maxch == 0 (no stage has children anywhere in the batch) statically
    # removes the hop fan-out block from _step — for direct-plan-only
    # workloads its dump-row scatters were pure overhead (~40% of the
    # per-event wall at 1e5 chunks)
    maxch = max((len(c) for c in su.stage_children), default=0)

    def padc(a, fill):
        out = np.full(ncp, fill, dtype=np.asarray(a).dtype)
        out[:nc] = a
        return out

    def pads(a, fill):
        out = np.full(ns + 1, fill, dtype=np.asarray(a).dtype)
        out[:ns] = a
        return out

    children = np.full((ns + 1, maxch), -1, dtype=np.int64)
    for s, kids in enumerate(su.stage_children):
        children[s, : len(kids)] = kids
    first_ci = np.searchsorted(su.conn_sid, np.arange(ns))
    conn_first = padc(first_ci[su.conn_sid], 0)

    use_edge = cfg.link_capacity_scale is not None
    if use_edge:
        edge_cap = np.array([
            su.top.tput[a, b] * cfg.link_capacity_scale
            for a, b in su.edges_used
        ])
    else:
        edge_cap = np.full(ne, BIG)

    n_iters = 2 * nv + ne + 4
    if solver == "pallas":
        nc128, nv128, ne128 = _pad128(ncp), _pad128(nv), _pad128(ne)

        def onehot(idx, width):
            m = np.zeros((nc128, width), dtype=np.float32)
            m[np.arange(nc), np.asarray(idx)] = 1.0
            return m

        def lane8(vec, width):
            row = np.full(width, BIG, dtype=np.float32)
            row[: vec.shape[0]] = vec
            return np.broadcast_to(row, (8, width)).copy()

        s_src = onehot(su.conn_src, nv128)
        s_dst = onehot(su.conn_dst, nv128)
        s_ed = onehot(su.conn_edge, ne128)
        pall = (
            s_src, s_src.T.copy(), s_dst, s_dst.T.copy(),
            s_ed, s_ed.T.copy(),
            lane8(su.vm_eg_cap, nv128), lane8(su.vm_in_cap, nv128),
        )
    else:
        z = np.zeros((1, 1), dtype=np.float32)
        pall = (z, z, z, z, z, z, z, z)

    from .events import T_EPS

    sc = _Sc(
        ncp=ncp, ns=ns, j=j, nslot=nslot, ne=ne, qcap=qcap, maxch=maxch,
        nv=nv, ne_bound=ne if use_edge else 0, solver=solver,
        n_iters=n_iters,
    )
    max_events = (
        int((su.n_chunks * 6).sum()) * su.max_hops + 10000 + 8 * len(sched)
    )
    cn = _Cn(
        conn_job=jnp.asarray(padc(su.conn_job, 0)),
        conn_sid=jnp.asarray(padc(su.conn_sid, ns)),
        conn_src=jnp.asarray(padc(su.conn_src, 0)),
        conn_dst=jnp.asarray(padc(su.conn_dst, 0)),
        conn_edge=jnp.asarray(padc(su.conn_edge, 0)),
        conn_valid=jnp.asarray(np.arange(ncp) < nc),
        chunk_size=jnp.asarray(padc(su.chunk_gbit[su.conn_job], 0.0)),
        conn_first=jnp.asarray(conn_first),
        stage_hop=jnp.asarray(pads(su.stage_hop, 0)),
        stage_deliver=jnp.asarray(pads(su.stage_deliver, -1)),
        children=jnp.asarray(children),
        slot_job=jnp.asarray(su.slot_job),
        slot_need=jnp.asarray(su.n_chunks[su.slot_job]),
        vm_eg=jnp.asarray(su.vm_eg_cap),
        vm_in=jnp.asarray(su.vm_in_cap),
        horizon=jnp.float64(
            _INF if cfg.horizon_s is None else cfg.horizon_s
        ),
        drain=jnp.bool_(cfg.drain),
        relay_cap=jnp.int64(cfg.relay_buffer_chunks),
        max_events=jnp.int64(max_events),
        t_eps=jnp.float64(T_EPS),
        one=jnp.float64(1.0),
        p_ssrc=jnp.asarray(pall[0]), p_ssrc_t=jnp.asarray(pall[1]),
        p_sdst=jnp.asarray(pall[2]), p_sdst_t=jnp.asarray(pall[3]),
        p_sed=jnp.asarray(pall[4]), p_sed_t=jnp.asarray(pall[5]),
        p_eg8=jnp.asarray(pall[6]), p_in8=jnp.asarray(pall[7]),
    )
    st = _St(
        now=jnp.float64(0.0), it=jnp.int64(0), events=jnp.int64(0),
        draining=jnp.bool_(False), stop=jnp.bool_(False),
        t_sched=jnp.float64(sched[0][0] if sched else _INF),
        chunk_arr=jnp.full(ncp, -1, dtype=jnp.int64),
        remaining=jnp.zeros(ncp),
        rate_eff=jnp.asarray(padc(su.conn_rate, 0.0)),
        conn_alive=jnp.asarray(np.arange(ncp) < nc),
        arrived=jnp.zeros(j, dtype=bool),
        ready_buf=jnp.zeros((ns + 1, qcap), dtype=jnp.int64),
        q_head=jnp.zeros(ns + 1, dtype=jnp.int64),
        q_tail=jnp.zeros(ns + 1, dtype=jnp.int64),
        relay_occ=jnp.zeros(ns + 1, dtype=jnp.int64),
        done_bm=jnp.zeros((ns + 1, qcap), dtype=bool),
        enq_bm=jnp.zeros((ns + 1, qcap), dtype=bool),
        delivered=jnp.zeros(nslot, dtype=jnp.int64),
        finished=jnp.zeros(j, dtype=bool),
        finish=jnp.full(j, _INF),
        jeg=jnp.zeros(j * ne), jeo=jnp.zeros(j * ne),
        jeb=jnp.zeros(j * ne),
        edge_cap=jnp.asarray(edge_cap),
        rates=jnp.zeros(ncp),
        last_active=jnp.zeros(ncp, dtype=bool),
        rates_valid=jnp.bool_(False),
        td_time=jnp.zeros(j + 1), td_job=jnp.zeros(j + 1, dtype=jnp.int64),
        td_n=jnp.int64(0),
    )
    return sc, cn, st


def _host_apply_due(st: _St, su, sched, ptr, vm_alive, retried, use_edge,
                    qcap, tr):
    """Apply every due scripted event — numpy, the exact reference logic
    (including its Skytrace instants). Returns (new state, new ptr)."""
    from .events import RATE_EVENTS, T_EPS, VMFailure

    now = float(st.now)
    # np.array (copy): np.asarray of a jax array can be a read-only view
    h = {
        "chunk_arr": np.array(st.chunk_arr), "remaining":
        np.array(st.remaining), "rate_eff": np.array(st.rate_eff),
        "conn_alive": np.array(st.conn_alive), "arrived":
        np.array(st.arrived), "ready_buf": np.array(st.ready_buf),
        "q_tail": np.array(st.q_tail), "relay_occ":
        np.array(st.relay_occ), "edge_cap": np.array(st.edge_cap),
    }
    nc = su.conn_job.shape[0]

    def push(sid, ch):
        h["ready_buf"][sid, h["q_tail"][sid] % qcap] = ch
        h["q_tail"][sid] += 1

    applied_t = None
    rate_n = 0
    while ptr < len(sched) and sched[ptr][0] <= now + T_EPS:
        t_ev = sched[ptr][0]
        ev = sched[ptr][2]
        ptr += 1
        applied_t = t_ev
        if isinstance(ev, int):  # job arrival
            h["arrived"][ev] = True
            firsts = su.first_stage[ev]
            for ch in range(int(su.n_chunks[ev])):
                for s0 in firsts[int(su.chunk_path[ev][ch])]:
                    push(s0, ch)
            if tr.enabled:
                tr.instant("sim.arrival", t_ev, job=int(ev),
                           chunks=int(su.n_chunks[ev]))
        elif isinstance(ev, RATE_EVENTS):
            on_edge = np.array(
                [e == (ev.src, ev.dst) for e in su.edges_used], dtype=bool
            )
            hit = on_edge[su.conn_edge]
            h["rate_eff"][:nc][hit] *= ev.factor
            if use_edge:
                h["edge_cap"][on_edge] *= ev.factor
            rate_n += 1
        elif isinstance(ev, VMFailure):
            kill = [
                v for v in np.flatnonzero(
                    (su.vm_job == ev.job) & (su.vm_region == ev.region)
                )
                if vm_alive[v]
            ][: ev.count]
            requeued = 0
            if kill:
                vm_alive[kill] = False
                hit = h["conn_alive"][:nc] & (
                    np.isin(su.conn_src, kill)
                    | np.isin(su.conn_dst, kill)
                )
                for ci in np.flatnonzero(hit):
                    if h["chunk_arr"][ci] >= 0:
                        sid = int(su.conn_sid[ci])
                        push(sid, int(h["chunk_arr"][ci]))
                        if su.stage_hop[sid] > 0:
                            h["relay_occ"][sid] += 1
                        retried[su.conn_job[ci]] += 1
                        h["chunk_arr"][ci] = -1
                        h["remaining"][ci] = 0.0
                        requeued += 1
                ca = h["conn_alive"][:nc]
                ca[hit] = False
            if tr.enabled:
                tr.instant("sim.vm_failure", t_ev, job=int(ev.job),
                           region=int(ev.region), killed=len(kill),
                           requeued=requeued)
        else:
            raise TypeError(f"unknown event {ev!r}")
    if applied_t is not None and tr.enabled:
        if rate_n:
            tr.instant("sim.rate_events", applied_t, n=rate_n)
        counts = np.bincount(
            su.conn_edge[h["chunk_arr"][:nc] >= 0],
            minlength=len(su.edges_used),
        )
        for i, (a, b) in enumerate(su.edges_used):
            if counts[i]:
                tr.sample(f"link {a}->{b}", applied_t, int(counts[i]))
    if applied_t is not None:
        st = st._replace(
            chunk_arr=jnp.asarray(h["chunk_arr"]),
            remaining=jnp.asarray(h["remaining"]),
            rate_eff=jnp.asarray(h["rate_eff"]),
            conn_alive=jnp.asarray(h["conn_alive"]),
            arrived=jnp.asarray(h["arrived"]),
            ready_buf=jnp.asarray(h["ready_buf"]),
            q_tail=jnp.asarray(h["q_tail"]),
            relay_occ=jnp.asarray(h["relay_occ"]),
            edge_cap=jnp.asarray(h["edge_cap"]),
            rates_valid=jnp.bool_(False),  # events invalidate the cache
        )
    st = st._replace(
        t_sched=jnp.float64(sched[ptr][0] if ptr < len(sched) else _INF)
    )
    return st, ptr


def _finalize(st: _St, su, jobs, cfg, retried, tr):
    """Pull the final device state and build MultiSimResult — the exact
    accounting of the reference tail."""
    from .events import T_EPS, JobSimResult, MultiSimResult

    top = su.top
    ne = len(su.edges_used)
    now = float(st.now)
    nc = su.conn_job.shape[0]
    chunk_arr = np.asarray(st.chunk_arr)[:nc]
    arrived = np.asarray(st.arrived)
    finished = np.asarray(st.finished)
    finish_t = np.asarray(st.finish)
    delivered = np.asarray(st.delivered)
    job_edge_gbit = np.asarray(st.jeg)
    job_edge_obs_gbit = np.asarray(st.jeo)
    job_edge_busy = np.asarray(st.jeb)
    horizon_s = cfg.horizon_s

    horizon_cut = horizon_s is not None and now >= horizon_s - T_EPS
    out = []
    for j, job in enumerate(jobs):
        end = float(finish_t[j]) if finished[j] else now
        dur = max(end - float(su.arrivals[j]), 1e-9)
        eg = job_edge_gbit[j * ne : (j + 1) * ne]
        ego = job_edge_obs_gbit[j * ne : (j + 1) * ne]
        busy = job_edge_busy[j * ne : (j + 1) * ne]
        per_edge_gb = {
            f"{a}->{b}": eg[i] / GBIT_PER_GB
            for i, (a, b) in enumerate(su.edges_used) if eg[i] > 0
        }
        per_edge_obs_gb = {
            f"{a}->{b}": ego[i] / GBIT_PER_GB
            for i, (a, b) in enumerate(su.edges_used) if busy[i] > 0
        }
        per_edge_active_s = {
            f"{a}->{b}": float(busy[i])
            for i, (a, b) in enumerate(su.edges_used) if busy[i] > 0
        }
        eg_cost = sum(
            eg[i] / GBIT_PER_GB * top.price_egress[a, b]
            for i, (a, b) in enumerate(su.edges_used)
        )
        if finished[j]:
            status = "done"
        elif not arrived[j]:
            status, dur = "pending", 0.0
        elif horizon_cut:
            status = "running"
        else:
            status = "stalled"
        slots = su.job_slots[j]
        full_copies = int(min(delivered[s] for s in slots))
        per_dst = (
            {int(su.slot_dst[s]): int(delivered[s]) for s in slots}
            if isinstance(job.plan, MulticastPlan) else None
        )
        vm_cost = float(job.plan.N @ job.plan.top.price_vm) * dur
        out.append(JobSimResult(
            job=j,
            name=job.name,
            time_s=dur,
            tput_gbps=float(full_copies * su.chunk_gbit[j]) / max(dur, 1e-9),
            chunks_delivered=full_copies,
            n_chunks=int(su.n_chunks[j]),
            retried_chunks=int(retried[j]),
            egress_cost=float(eg_cost),
            vm_cost=vm_cost,
            total_cost=float(eg_cost + vm_cost),
            status=status,
            per_edge_gb=per_edge_gb,
            per_dst_delivered=per_dst,
            per_edge_active_s=per_edge_active_s,
            per_edge_obs_gb=per_edge_obs_gb,
            chunks_in_flight=int(np.count_nonzero(
                (su.conn_job == j) & (chunk_arr >= 0)
            )),
        ))
    if tr.enabled:
        tr.instant("sim.end", now,
                   delivered=sum(int(r.chunks_delivered) for r in out))
    return MultiSimResult(jobs=out, time_s=now, events=int(st.events))


def simulate_multi_jax(
    jobs,
    faults=(),
    *,
    config: SimConfig | None = None,
    link_capacity_scale: float | None = 2.0,
    straggler_prob: float = 0.05,
    straggler_speed: tuple[float, float] = (0.15, 0.5),
    relay_buffer_chunks: int = 64,
    seed: int = 0,
    horizon_s: float | None = None,
    exec_top=None,
    drain: bool = False,
    _rate_solver: str = "auto",  # "masked" (f64 parity) | "pallas" | auto:
    # pallas on TPU backends, masked everywhere else
):
    """Accelerator-resident multi-job simulation (``SimConfig`` knobs and
    ``events`` scenarios identical to the other engines; results pinned
    chunk-for-chunk against them). Prefer ``transfer.sim.simulate`` with
    ``engine="jax"`` over calling this directly."""
    from .events import T_EPS, materialize_jobs, sorted_schedule

    cfg = resolve_sim_config(
        config, link_capacity_scale=link_capacity_scale,
        straggler_prob=straggler_prob, straggler_speed=straggler_speed,
        relay_buffer_chunks=relay_buffer_chunks, seed=seed,
        horizon_s=horizon_s, exec_top=exec_top, drain=drain,
    )
    solver = _rate_solver
    if solver == "auto":
        solver = "pallas" if jax.default_backend() == "tpu" else "masked"
    if solver not in ("masked", "pallas"):
        raise ValueError(f"unknown rate solver {_rate_solver!r}")
    su = materialize_jobs(
        jobs, seed=cfg.seed, straggler_prob=cfg.straggler_prob,
        straggler_speed=cfg.straggler_speed, exec_top=cfg.exec_top,
    )
    sched = sorted_schedule(jobs, faults)
    tr = get_tracer()
    if tr.enabled:
        tr.instant("sim.start", 0.0, jobs=len(jobs), scheduled=len(sched))
    retried = np.zeros(len(jobs), dtype=np.int64)
    vm_alive = np.ones(su.vm_eg_cap.shape[0], dtype=bool)
    with enable_x64():
        sc, cn, st = _build(su, cfg, sched, solver)
        ptr = 0
        max_events = int(cn.max_events)
        while True:
            if not bool(st.draining):
                st, ptr = _host_apply_due(
                    st, su, sched, ptr, vm_alive, retried,
                    cfg.link_capacity_scale is not None, sc.qcap, tr,
                )
            st = _segment(st, cn, sc)
            n_td = int(st.td_n)
            if n_td and tr.enabled:
                td_time = np.asarray(st.td_time)
                td_job = np.asarray(st.td_job)
                for i in range(n_td):
                    tr.instant("sim.job_done", float(td_time[i]),
                               job=int(td_job[i]))
            if n_td:
                st = st._replace(td_n=jnp.int64(0))
            if bool(st.stop) or int(st.it) >= max_events:
                break
            due = not bool(st.draining) and ptr < len(sched) and (
                sched[ptr][0] <= float(st.now) + T_EPS
            )
            if not due:
                break
        return _finalize(st, su, jobs, cfg, retried, tr)
