"""Reference (pre-vectorization) fluid simulator — oracle for flowsim.py.

This is the original object-per-connection event loop: a Python ``_Conn``
dataclass per TCP connection, dict-based max-min rate allocation, and
``list.pop(0)`` chunk queues. ``flowsim.simulate_transfer`` replays the same
semantics on numpy arrays at ~an order of magnitude more events/s; the
equivalence tests in tests/test_flowsim.py pin the two together (identical
delivered-chunk counts at fixed seed), and benchmarks/flowsim_bench.py uses
this module as the speedup baseline.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.plan import TransferPlan
from repro.core.topology import GBIT_PER_GB
from repro.obs.trace import get_tracer

from .flowsim import SimResult, conn_efficiency
from .simconfig import SimConfig
from .simconfig import resolve as resolve_sim_config
from .simconfig import warn_deprecated_entry as _warn_deprecated_entry

_EPS = 1e-12


@dataclasses.dataclass
class _Conn:
    edge: tuple[int, int]
    path_id: int
    hop: int  # hop index within the path
    rate_nominal: float  # Gbit/s when unconstrained
    src_vm: int  # global vm index
    dst_vm: int
    mult: float = 1.0  # straggler multiplier
    chunk: int = -1  # active chunk id (-1 idle)
    remaining: float = 0.0  # Gbit left on the active chunk


def _maxmin_rates(conns, active_ix, vm_eg_cap, vm_in_cap):
    """Water-filling max-min fair allocation (vectorized).

    Resources: each active connection's own cap, each VM's egress cap over
    its outgoing conns, each VM's ingress cap over incoming conns.
    """
    n = len(active_ix)
    if n == 0:
        return {}
    caps = np.array([conns[i].rate_nominal * conns[i].mult for i in active_ix])
    src = np.array([conns[i].src_vm for i in active_ix], dtype=np.int64)
    dst = np.array([conns[i].dst_vm for i in active_ix], dtype=np.int64)
    nv = max(int(src.max()), int(dst.max())) + 1
    eg_rem = np.asarray(vm_eg_cap, dtype=float)[:nv].copy()
    in_rem = np.asarray(vm_in_cap, dtype=float)[:nv].copy()

    rate = np.zeros(n)
    fixed = np.zeros(n, dtype=bool)
    for _ in range(2 * nv + 4):
        un = ~fixed
        if not un.any():
            break
        cnt_out = np.bincount(src[un], minlength=nv).astype(float)
        cnt_in = np.bincount(dst[un], minlength=nv).astype(float)
        with np.errstate(divide="ignore", invalid="ignore"):
            share_out = np.where(cnt_out > 0, eg_rem / np.maximum(cnt_out, 1), np.inf)
            share_in = np.where(cnt_in > 0, in_rem / np.maximum(cnt_in, 1), np.inf)
        share = np.minimum(share_out[src], share_in[dst])
        newly = un & (caps <= share + _EPS)
        if newly.any():
            rate[newly] = caps[newly]
        else:
            thresh = share[un].min()
            newly = un & (share <= thresh + _EPS)
            rate[newly] = share[newly]
        eg_rem -= np.bincount(src[newly], weights=rate[newly], minlength=nv)
        in_rem -= np.bincount(dst[newly], weights=rate[newly], minlength=nv)
        np.maximum(eg_rem, 0.0, out=eg_rem)
        np.maximum(in_rem, 0.0, out=in_rem)
        fixed |= newly
    return {active_ix[i]: float(rate[i]) for i in range(n)}


def simulate_transfer_reference(
    plan: TransferPlan,
    *,
    chunk_mb: float = 16.0,
    dispatch: str = "dynamic",  # "dynamic" (Skyplane) | "static" (GridFTP)
    straggler_prob: float = 0.05,
    straggler_speed: tuple[float, float] = (0.15, 0.5),
    relay_buffer_chunks: int = 64,
    seed: int = 0,
    util_threshold: float = 0.99,
    speculative: bool | None = None,  # re-dispatch straggling chunks (tail
    # kill). Defaults to True for dynamic dispatch — the natural extension of
    # paper §6's ready-connection dispatch; duplicate bytes are billed.
) -> SimResult:
    if speculative is None:
        speculative = dispatch == "dynamic"
    top = plan.top
    rng = np.random.default_rng(seed)
    paths = plan.paths()
    if not paths:
        raise ValueError("plan carries no flow")

    volume_gbit = plan.volume_gb * GBIT_PER_GB
    chunk_gbit = chunk_mb * 8.0 / 1024.0
    n_chunks = max(1, int(np.ceil(volume_gbit / chunk_gbit)))

    # ---- materialize VMs
    vm_of_region: dict[int, list[int]] = {}
    vm_eg_cap: list[float] = []
    vm_in_cap: list[float] = []
    vm_region: list[int] = []
    for r in range(top.num_regions):
        cnt = int(round(plan.N[r]))
        ids = []
        for _ in range(cnt):
            ids.append(len(vm_eg_cap))
            vm_eg_cap.append(top.limit_egress[r])
            vm_in_cap.append(top.limit_ingress[r])
            vm_region.append(r)
        vm_of_region[r] = ids

    # ---- materialize connections per path hop, proportional to flow share
    conns: list[_Conn] = []
    edge_flow_total: dict[tuple[int, int], float] = {}
    for path, flow in paths:
        for a, b in zip(path[:-1], path[1:]):
            edge_flow_total[(a, b)] = edge_flow_total.get((a, b), 0.0) + flow
    for pid, (path, flow) in enumerate(paths):
        for hop, (a, b) in enumerate(zip(path[:-1], path[1:])):
            m_edge = int(round(plan.M[a, b]))
            share = flow / edge_flow_total[(a, b)]
            n_conn = max(1, int(round(m_edge * share)))
            vms_a = vm_of_region.get(a) or []
            vms_b = vm_of_region.get(b) or []
            if not vms_a or not vms_b:
                raise ValueError(f"plan has flow on edge {a}->{b} but no VMs")
            per_pair = max(n_conn / (len(vms_a) * len(vms_b)), 1e-9)
            eff = conn_efficiency(per_pair * len(vms_b), top.limit_conn)
            nominal = top.tput[a, b] * eff / n_conn * len(vms_a)
            for c in range(n_conn):
                mult = 1.0
                if rng.uniform() < straggler_prob:
                    mult = float(rng.uniform(*straggler_speed))
                else:
                    mult = float(np.exp(rng.normal(0.0, 0.05)))
                conns.append(
                    _Conn(
                        edge=(a, b), path_id=pid, hop=hop,
                        rate_nominal=nominal,
                        src_vm=vms_a[c % len(vms_a)],
                        dst_vm=vms_b[c % len(vms_b)],
                        mult=mult,
                    )
                )

    path_len = {pid: len(path) - 1 for pid, (path, _) in enumerate(paths)}
    flows = np.array([f for _, f in paths])
    flow_frac = flows / flows.sum()

    # chunk -> path assignment: proportional to planned flow (both modes)
    chunk_path = rng.choice(len(paths), size=n_chunks, p=flow_frac)
    # per-hop queues per path: chunks ready to be sent on hop h
    ready: dict[tuple[int, int], list[int]] = {}
    for ch in range(n_chunks):
        ready.setdefault((int(chunk_path[ch]), 0), []).append(ch)
    # static (GridFTP) mode: pre-assign chunks round-robin to connections
    static_assign: dict[int, list[int]] = {}
    if dispatch == "static":
        by_first_hop: dict[int, list[int]] = {}
        for ci, c in enumerate(conns):
            if c.hop == 0:
                by_first_hop.setdefault(c.path_id, []).append(ci)
        rrobin: dict[int, int] = {}
        for ch in range(n_chunks):
            pid = int(chunk_path[ch])
            lst = by_first_hop[pid]
            k = rrobin.get(pid, 0)
            static_assign.setdefault(lst[k % len(lst)], []).append(ch)
            rrobin[pid] = k + 1

    relay_occupancy: dict[tuple[int, int], int] = {}  # (path, hop) buffered
    done_hops: set[tuple[int, int, int]] = set()
    delivered = 0
    now = 0.0
    edge_gbit: dict[tuple[int, int], float] = {}
    vm_busy_out = np.zeros(len(vm_eg_cap))
    vm_busy_in = np.zeros(len(vm_eg_cap))

    # speculation bookkeeping: (path,hop,chunk) -> replica count
    replicas: dict[tuple[int, int, int], int] = {}

    def refill(ci: int) -> bool:
        c = conns[ci]
        if c.chunk >= 0:
            return False
        # flow control: downstream relay buffer full -> stall
        key_down = (c.path_id, c.hop + 1)
        if c.hop + 1 < path_len[c.path_id]:
            if relay_occupancy.get(key_down, 0) >= relay_buffer_chunks:
                return False
        if dispatch == "static" and c.hop == 0:
            lst = static_assign.get(ci, [])
            if not lst:
                return False
            ch = lst.pop(0)
        else:
            q = ready.get((c.path_id, c.hop), [])
            if not q:
                if speculative:
                    return _speculate(ci)
                return False
            ch = q.pop(0)
        c.chunk = ch
        c.remaining = chunk_gbit
        if c.hop > 0:
            relay_occupancy[(c.path_id, c.hop)] = (
                relay_occupancy.get((c.path_id, c.hop), 0) - 1
            )
        return True

    def _speculate(ci: int) -> bool:
        """Idle conn + empty queue: duplicate the worst-ETA in-flight chunk
        on this (path, hop); first finisher wins, loser's bytes are wasted
        egress (billed)."""
        c = conns[ci]
        worst = None
        worst_eta = 0.0
        for cj in active_set:
            o = conns[cj]
            if cj == ci or o.chunk < 0:
                continue
            if (o.path_id, o.hop) != (c.path_id, c.hop):
                continue
            if replicas.get((o.path_id, o.hop, o.chunk), 1) >= 2:
                continue
            eta = o.remaining / max(o.rate_nominal * o.mult, _EPS)
            if eta > worst_eta:
                worst_eta, worst = eta, o.chunk
        own_eta = chunk_gbit / max(c.rate_nominal * c.mult, _EPS)
        if worst is None or worst_eta < 2.0 * own_eta:
            return False
        key = (c.path_id, c.hop, worst)
        replicas[key] = replicas.get(key, 1) + 1
        c.chunk = worst
        c.remaining = chunk_gbit
        return True

    max_events = n_chunks * 6 * max(path_len.values()) + 10000
    idle_set = set(range(len(conns)))
    active_set: set[int] = set()
    events = 0
    for _ in range(max_events):
        progressed = True
        while progressed:  # cascade refills (buffer drains unlock upstream)
            progressed = False
            for ci in list(idle_set):
                if refill(ci):
                    idle_set.discard(ci)
                    active_set.add(ci)
                    progressed = True
        active = [ci for ci in active_set if conns[ci].chunk >= 0]
        # speculation losers were cancelled in place; resync the sets
        for ci in list(active_set):
            if conns[ci].chunk < 0:
                active_set.discard(ci)
                idle_set.add(ci)
        if not active:
            break
        events += 1
        rates = _maxmin_rates(conns, active, vm_eg_cap, vm_in_cap)
        # next completion
        dt = min(
            conns[ci].remaining / max(rates[ci], _EPS) for ci in active
        )
        dt = max(dt, 1e-9)
        now += dt
        for ci in active:
            c = conns[ci]
            moved = rates[ci] * dt
            c.remaining -= moved
            edge_gbit[c.edge] = edge_gbit.get(c.edge, 0.0) + moved
            vm_busy_out[c.src_vm] += moved
            vm_busy_in[c.dst_vm] += moved
            if c.remaining <= 1e-9:
                ch = c.chunk
                c.chunk = -1
                c.remaining = 0.0
                key = (c.path_id, c.hop, ch)
                if key in done_hops:
                    continue  # a replica already finished this hop
                done_hops.add(key)
                if replicas.get(key, 1) > 1:
                    for o in conns:  # cancel the losing replica
                        if o.chunk == ch and (o.path_id, o.hop) == (c.path_id, c.hop):
                            o.chunk = -1
                            o.remaining = 0.0
                if c.hop + 1 < path_len[c.path_id]:
                    ready.setdefault((c.path_id, c.hop + 1), []).append(ch)
                    relay_occupancy[(c.path_id, c.hop + 1)] = (
                        relay_occupancy.get((c.path_id, c.hop + 1), 0) + 1
                    )
                else:
                    delivered += 1
        for ci in active:
            if conns[ci].chunk < 0:
                active_set.discard(ci)
                idle_set.add(ci)
        if delivered >= n_chunks:
            break

    time_s = max(now, 1e-9)
    tput = delivered * chunk_gbit / time_s
    per_edge_gb = {e: g / GBIT_PER_GB for e, g in edge_gbit.items()}
    egress_cost = sum(
        gb * top.price_egress[e] for e, gb in per_edge_gb.items()
    )
    vm_cost = float(plan.N @ top.price_vm) * time_s

    # ---- utilization / bottleneck attribution (Fig. 8)
    src_r, dst_r = plan.src, plan.dst
    util: dict[str, float] = {}
    for v in range(len(vm_eg_cap)):
        r = vm_region[v]
        loc = ("source_vm" if r == src_r else
               "dest_vm" if r == dst_r else "overlay_vm")
        used = max(vm_busy_out[v], vm_busy_in[v])
        cap = (vm_eg_cap[v] if vm_busy_out[v] >= vm_busy_in[v] else vm_in_cap[v])
        u = used / max(cap * time_s, _EPS)
        util[loc] = max(util.get(loc, 0.0), u)
    for (a, b), gbit in edge_gbit.items():
        loc = "source_link" if a == src_r else "overlay_link"
        cap = top.tput[a, b] * max(plan.N[a], 1)
        u = gbit / max(cap * time_s, _EPS)
        util[loc] = max(util.get(loc, 0.0), u)
    bottlenecks = [k for k, v in util.items() if v >= util_threshold]

    res = SimResult(
        time_s=time_s,
        tput_gbps=tput,
        egress_cost=float(egress_cost),
        vm_cost=float(vm_cost),
        total_cost=float(egress_cost + vm_cost),
        chunks_delivered=delivered,
        per_edge_gb={f"{e[0]}->{e[1]}": gb for e, gb in per_edge_gb.items()},
        utilization=util,
        bottlenecks=bottlenecks,
        volume_gb=plan.volume_gb,
        events=events,
    )
    return res


# --------------------------------------------------------------------- multi
@dataclasses.dataclass
class _MConn:
    """Object-per-connection state of the multi-job reference loop."""

    job: int
    sid: int  # stage id
    edge_ix: int  # index into the scenario's edge list
    src_vm: int
    dst_vm: int
    rate: float  # effective (nominal * straggler mult * degrades)
    alive: bool = True
    chunk: int = -1
    remaining: float = 0.0


def _maxmin_rates_multi(conns, active_ix, vm_eg_cap, vm_in_cap, edge_rem0):
    """Water-filling over the active multi-job set: per-connection caps,
    per-VM egress/ingress caps, and the shared wide-area link caps."""
    n = len(active_ix)
    if n == 0:
        return {}
    caps = np.array([conns[i].rate for i in active_ix])
    src = np.array([conns[i].src_vm for i in active_ix], dtype=np.int64)
    dst = np.array([conns[i].dst_vm for i in active_ix], dtype=np.int64)
    nv = max(int(src.max()), int(dst.max())) + 1
    eg_rem = np.asarray(vm_eg_cap, dtype=float)[:nv].copy()
    in_rem = np.asarray(vm_in_cap, dtype=float)[:nv].copy()
    ne = 0
    if edge_rem0 is not None:
        eid = np.array([conns[i].edge_ix for i in active_ix], dtype=np.int64)
        ed_rem = edge_rem0.copy()
        ne = ed_rem.shape[0]

    rate = np.zeros(n)
    fixed = np.zeros(n, dtype=bool)
    for _ in range(2 * nv + ne + 4):
        un = ~fixed
        if not un.any():
            break
        cnt_out = np.bincount(src[un], minlength=nv).astype(float)
        cnt_in = np.bincount(dst[un], minlength=nv).astype(float)
        with np.errstate(divide="ignore", invalid="ignore"):
            share_out = np.where(cnt_out > 0, eg_rem / np.maximum(cnt_out, 1), np.inf)
            share_in = np.where(cnt_in > 0, in_rem / np.maximum(cnt_in, 1), np.inf)
        share = np.minimum(share_out[src], share_in[dst])
        if ne:
            cnt_ed = np.bincount(eid[un], minlength=ne).astype(float)
            with np.errstate(divide="ignore", invalid="ignore"):
                share_ed = np.where(
                    cnt_ed > 0, ed_rem / np.maximum(cnt_ed, 1), np.inf
                )
            share = np.minimum(share, share_ed[eid])
        newly = un & (caps <= share + _EPS)
        if newly.any():
            rate[newly] = caps[newly]
        else:
            thresh = share[un].min()
            newly = un & (share <= thresh + _EPS)
            rate[newly] = share[newly]
        eg_rem -= np.bincount(src[newly], weights=rate[newly], minlength=nv)
        in_rem -= np.bincount(dst[newly], weights=rate[newly], minlength=nv)
        np.maximum(eg_rem, 0.0, out=eg_rem)
        np.maximum(in_rem, 0.0, out=in_rem)
        if ne:
            ed_rem -= np.bincount(eid[newly], weights=rate[newly], minlength=ne)
            np.maximum(ed_rem, 0.0, out=ed_rem)
        fixed |= newly
    return {int(active_ix[i]): float(rate[i]) for i in range(n)}


def simulate_multi_reference(
    jobs,
    faults=(),
    *,
    config: SimConfig | None = None,
    link_capacity_scale: float | None = 2.0,
    straggler_prob: float = 0.05,
    straggler_speed: tuple[float, float] = (0.15, 0.5),
    relay_buffer_chunks: int = 64,
    seed: int = 0,
    horizon_s: float | None = None,
    exec_top=None,
    drain: bool = False,
):
    """Deprecated alias for ``transfer.sim.simulate(engine="ref")``.

    Kept (signature-pinned, bitwise-equal) for backward compatibility;
    new code goes through the dispatcher. SKY010 bans fresh first-party
    calls."""
    _warn_deprecated_entry("flowsim_ref.simulate_multi_reference")
    return _simulate_multi_reference_impl(
        jobs, faults, config=config,
        link_capacity_scale=link_capacity_scale,
        straggler_prob=straggler_prob, straggler_speed=straggler_speed,
        relay_buffer_chunks=relay_buffer_chunks, seed=seed,
        horizon_s=horizon_s, exec_top=exec_top, drain=drain,
    )


def _simulate_multi_reference_impl(
    jobs,
    faults=(),
    *,
    config: SimConfig | None = None,
    link_capacity_scale: float | None = 2.0,
    straggler_prob: float = 0.05,
    straggler_speed: tuple[float, float] = (0.15, 0.5),
    relay_buffer_chunks: int = 64,
    seed: int = 0,
    horizon_s: float | None = None,
    exec_top=None,
    drain: bool = False,
):
    """Object-per-connection oracle for ``flowsim.simulate_multi``.

    Consumes the same materialized scenario (events.materialize_jobs, so the
    RNG streams and dispatch order match by construction) but runs the event
    loop on per-connection objects with dict/list bookkeeping — including
    multicast jobs (tree fan-out, per-destination delivery slots). The
    vectorized loop must reproduce its per-job delivered-chunk counts
    exactly (``exec_top`` included: the believed/true grid split changes
    rates, not materialization order)."""
    from .events import RATE_EVENTS, T_EPS, JobSimResult, MultiSimResult
    from .events import VMFailure, materialize_jobs, sorted_schedule
    from repro.core.plan import MulticastPlan

    cfg = resolve_sim_config(
        config, link_capacity_scale=link_capacity_scale,
        straggler_prob=straggler_prob, straggler_speed=straggler_speed,
        relay_buffer_chunks=relay_buffer_chunks, seed=seed,
        horizon_s=horizon_s, exec_top=exec_top, drain=drain,
    )
    link_capacity_scale = cfg.link_capacity_scale
    relay_buffer_chunks = cfg.relay_buffer_chunks
    horizon_s, drain = cfg.horizon_s, cfg.drain
    su = materialize_jobs(
        jobs, seed=cfg.seed, straggler_prob=cfg.straggler_prob,
        straggler_speed=cfg.straggler_speed, exec_top=cfg.exec_top,
    )
    top = su.top
    J = len(jobs)
    nc = su.conn_job.shape[0]
    conns = [
        _MConn(
            job=int(su.conn_job[i]), sid=int(su.conn_sid[i]),
            edge_ix=int(su.conn_edge[i]), src_vm=int(su.conn_src[i]),
            dst_vm=int(su.conn_dst[i]), rate=float(su.conn_rate[i]),
        )
        for i in range(nc)
    ]
    edge_cap = None
    if link_capacity_scale is not None:
        edge_cap = np.array(
            [top.tput[a, b] * link_capacity_scale for a, b in su.edges_used]
        )

    vm_alive = [True] * su.vm_eg_cap.shape[0]
    arrived = [False] * J
    ready: dict[int, list[int]] = {s: [] for s in range(su.n_stages)}
    relay_occ: dict[int, int] = {}
    done_hops: set[tuple[int, int]] = set()
    enqueued: set[tuple[int, int]] = set()  # fan-in dedup on propagation
    delivered = [0] * su.slot_job.shape[0]
    retried = [0] * J
    finish: list[float | None] = [None] * J
    job_edge_gbit: dict[tuple[int, int], float] = {}

    sched = sorted_schedule(jobs, faults)
    ptr = 0
    now = 0.0
    tr = get_tracer()
    if tr.enabled:
        tr.instant("sim.start", 0.0, jobs=J, scheduled=len(sched))

    def apply_due():
        nonlocal ptr
        applied_t = None
        rate_n = 0
        while ptr < len(sched) and sched[ptr][0] <= now + T_EPS:
            t_ev = sched[ptr][0]
            ev = sched[ptr][2]
            ptr += 1
            applied_t = t_ev
            if isinstance(ev, int):  # job arrival
                arrived[ev] = True
                firsts = su.first_stage[ev]
                for ch in range(int(su.n_chunks[ev])):
                    for s0 in firsts[int(su.chunk_path[ev][ch])]:
                        ready[s0].append(ch)
                if tr.enabled:
                    tr.instant("sim.arrival", t_ev, job=int(ev),
                               chunks=int(su.n_chunks[ev]))
            elif isinstance(ev, RATE_EVENTS):
                # same compounding multiply as the vectorized loop — gray
                # or visible, the data plane cannot tell them apart
                want = (
                    su.edges_used.index((ev.src, ev.dst))
                    if (ev.src, ev.dst) in su.edges_used
                    else -1
                )
                for c in conns:
                    if c.edge_ix == want:
                        c.rate *= ev.factor
                if edge_cap is not None and want >= 0:
                    edge_cap[want] *= ev.factor
                # coalesced per batch below, exactly like the vectorized
                # loop — per-event instants would dominate gray/flap trains
                rate_n += 1
            elif isinstance(ev, VMFailure):
                kill = [
                    v for v in range(len(vm_alive))
                    if vm_alive[v] and su.vm_job[v] == ev.job
                    and su.vm_region[v] == ev.region
                ][: ev.count]
                requeued = 0
                if kill:
                    for v in kill:
                        vm_alive[v] = False
                    killset = set(kill)
                    for ci, c in enumerate(conns):
                        if not c.alive:
                            continue
                        if c.src_vm in killset or c.dst_vm in killset:
                            if c.chunk >= 0:
                                ready[c.sid].append(c.chunk)
                                if su.stage_hop[c.sid] > 0:
                                    relay_occ[c.sid] = (
                                        relay_occ.get(c.sid, 0) + 1
                                    )
                                retried[c.job] += 1
                                c.chunk = -1
                                c.remaining = 0.0
                                requeued += 1
                            c.alive = False
                if tr.enabled:
                    tr.instant("sim.vm_failure", t_ev, job=int(ev.job),
                               region=int(ev.region), killed=len(kill),
                               requeued=requeued)
            else:
                raise TypeError(f"unknown event {ev!r}")
        if applied_t is not None and tr.enabled:
            if rate_n:
                tr.instant("sim.rate_events", applied_t, n=rate_n)
            # mirrors the vectorized loop's post-batch link sample exactly
            counts = [0] * len(su.edges_used)
            for c in conns:
                if c.chunk >= 0:
                    counts[c.edge_ix] += 1
            for i, (a, b) in enumerate(su.edges_used):
                if counts[i]:
                    tr.sample(f"link {a}->{b}", applied_t, counts[i])

    def refill(ci: int) -> bool:
        c = conns[ci]
        if c.chunk >= 0 or not c.alive or not arrived[c.job]:
            return False
        for nsid in su.stage_children[c.sid]:
            if relay_occ.get(nsid, 0) >= relay_buffer_chunks:
                return False
        q = ready[c.sid]
        if not q:
            return False
        c.chunk = q.pop(0)
        c.remaining = float(su.chunk_gbit[c.job])
        if su.stage_hop[c.sid] > 0:
            relay_occ[c.sid] = relay_occ.get(c.sid, 0) - 1
        return True

    max_events = (
        int((su.n_chunks * 6).sum()) * su.max_hops + 10000 + 8 * len(sched)
    )
    events = 0
    draining = False
    for _ in range(max_events):
        if not draining:
            apply_due()
        if horizon_s is not None and now >= horizon_s - T_EPS:
            if not drain:
                break
            draining = True
        progressed = not draining
        while progressed:  # cascade refills (none while draining)
            progressed = False
            for ci in range(nc):
                if conns[ci].chunk < 0 and refill(ci):
                    progressed = True
        active = [ci for ci in range(nc) if conns[ci].chunk >= 0]
        t_next = (
            sched[ptr][0] if ptr < len(sched) and not draining else None
        )
        if not active:
            if t_next is not None and (
                horizon_s is None or t_next < horizon_s - T_EPS
            ):
                now = t_next
                continue
            break
        events += 1
        rates = _maxmin_rates_multi(
            conns, active, su.vm_eg_cap, su.vm_in_cap, edge_cap
        )
        if max(rates.values(), default=0.0) <= 1e-9 and t_next is None:
            break  # all remaining links dead: no progress possible, stall
        dt = min(
            conns[ci].remaining / max(rates[ci], _EPS) for ci in active
        )
        dt = max(dt, 1e-9)
        if t_next is not None and now + dt > t_next:
            dt = t_next - now
        horizon_hit = False
        if horizon_s is not None and now + dt >= horizon_s - T_EPS:
            if drain:
                draining = True  # past the boundary: in-flight only
            else:
                dt = horizon_s - now
                horizon_hit = True
        now += dt
        for ci in active:
            c = conns[ci]
            moved = rates[ci] * dt
            c.remaining -= moved
            jkey = (c.job, c.edge_ix)
            job_edge_gbit[jkey] = job_edge_gbit.get(jkey, 0.0) + moved
            if c.remaining <= 1e-9:
                ch = c.chunk
                c.chunk = -1
                c.remaining = 0.0
                key = (c.sid, ch)
                if key in done_hops:
                    continue
                done_hops.add(key)
                slot = int(su.stage_deliver[c.sid])
                if slot >= 0:
                    delivered[slot] += 1
                    jj = int(su.slot_job[slot])
                    if delivered[slot] >= su.n_chunks[jj] and all(
                        delivered[s] >= su.n_chunks[jj]
                        for s in su.job_slots[jj]
                    ):
                        finish[jj] = now
                        if tr.enabled:
                            tr.instant("sim.job_done", now, job=jj)
                for nsid in su.stage_children[c.sid]:
                    if (nsid, ch) in enqueued:
                        continue  # another in-edge already fed this stage
                    enqueued.add((nsid, ch))
                    ready[nsid].append(ch)
                    relay_occ[nsid] = relay_occ.get(nsid, 0) + 1
        if horizon_hit:
            break
        if all(f is not None for f in finish):
            break

    horizon_cut = horizon_s is not None and now >= horizon_s - T_EPS
    out = []
    for j, job in enumerate(jobs):
        end = finish[j] if finish[j] is not None else now
        dur = max(end - float(su.arrivals[j]), 1e-9)
        eg_cost = 0.0
        per_edge_gb = {}
        for i, (a, b) in enumerate(su.edges_used):
            gbit = job_edge_gbit.get((j, i), 0.0)
            eg_cost += gbit / GBIT_PER_GB * top.price_egress[a, b]
            if gbit > 0:
                per_edge_gb[f"{a}->{b}"] = gbit / GBIT_PER_GB
        if finish[j] is not None:
            status = "done"
        elif not arrived[j]:
            status, dur = "pending", 0.0
        elif horizon_cut:
            status = "running"
        else:
            status = "stalled"
        slots = su.job_slots[j]
        full_copies = int(min(delivered[s] for s in slots))
        per_dst = (
            {int(su.slot_dst[s]): int(delivered[s]) for s in slots}
            if isinstance(job.plan, MulticastPlan) else None
        )
        vm_cost = float(job.plan.N @ job.plan.top.price_vm) * dur
        out.append(JobSimResult(
            job=j,
            name=job.name,
            time_s=dur,
            tput_gbps=float(full_copies * su.chunk_gbit[j]) / max(dur, 1e-9),
            chunks_delivered=full_copies,
            n_chunks=int(su.n_chunks[j]),
            retried_chunks=int(retried[j]),
            egress_cost=float(eg_cost),
            vm_cost=vm_cost,
            total_cost=float(eg_cost + vm_cost),
            status=status,
            per_edge_gb=per_edge_gb,
            per_dst_delivered=per_dst,
            chunks_in_flight=sum(
                1 for c in conns if c.job == j and c.chunk >= 0
            ),
        ))
    if tr.enabled:
        tr.instant("sim.end", now,
                   delivered=sum(int(r.chunks_delivered) for r in out))
    return MultiSimResult(jobs=out, time_s=now, events=events)
