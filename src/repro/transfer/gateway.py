"""In-process gateway data plane moving REAL bytes (paper §3.3/§6).

The fluid simulator (flowsim) models timing; this module implements the
actual mechanics on real data — chunking, bounded relay queues (hop-by-hop
flow control), parallel workers per hop, dynamic chunk dispatch, per-chunk
checksum verification at the destination — and is what checkpoint
replication (repro.ckpt.replicate) runs on. Object stores are pluggable
(in-memory dict or a directory), mirroring S3/Blob/GCS semantics: immutable
puts, no rename.

Fault tolerance (ISSUE 2): every chunk carries a source-side checksum, the
destination verifies and commits chunks independently, and failed chunks —
a killed hop worker, a corrupted payload, a chunk stranded in a dead
path's queues — are re-dispatched to surviving workers. Verified bytes are
never re-sent (chunk-level checksummed resume), duplicate deliveries are
discarded, and a ``FaultInjector`` scripts the same failure scenarios the
fluid simulator runs (events.VMFailure / LinkDegrade analogues) against
the real-bytes path.

Multicast (ISSUE 3): ``transfer_objects_multicast`` executes a
``MulticastPlan``'s distribution trees — relay workers fan each chunk out
to multiple downstream chains (shared segments carry it once), every
destination verifies independently, and a chunk lost on one branch is
re-dispatched only toward the destinations still missing it. For
multicast stages the FaultInjector key is (tree id, global stage id)
instead of (path id, hop).
"""

from __future__ import annotations

import dataclasses
import heapq
import queue
import random
import threading
import time
from pathlib import Path

from repro.core.plan import MulticastPlan, TransferPlan
from repro.obs.metrics import REGISTRY
from repro.obs.trace import get_tracer

from .chunk import Chunk, checksum, chunk_manifest, chunk_object
from .reports import Report, per_edge_dict

# registered gateway counters — leaked workers used to be a RuntimeWarning;
# a counter survives in long-lived processes where warnings are one-shot
_workers_leaked = REGISTRY.counter("gateway.workers_leaked")
_retries = REGISTRY.counter("gateway.retries")
_checksum_failures = REGISTRY.counter("gateway.checksum_failures")
_stall_rounds = REGISTRY.counter("gateway.stall_rounds")


def _retry_delay(attempt: int, base_s: float, cap_s: float,
                 rng: random.Random) -> float:
    """Exponential backoff with seeded jitter for chunk re-dispatch.

    ``base_s * 2**(attempt-1)`` capped at ``cap_s``, scaled by a uniform
    jitter in [0.5, 1.5) so simultaneous failures (a killed worker drops
    its whole queue) do not re-dispatch as one synchronized thundering
    herd onto the next path. Deterministic given the rng's seed; attempt
    0 (first dispatch) never waits."""
    if attempt <= 0 or base_s <= 0.0:
        return 0.0
    delay = min(base_s * (2.0 ** (attempt - 1)), cap_s)
    return delay * (0.5 + rng.random())


class ObjectStore:
    """Interface of an object store (S3/Blob/GCS-like semantics)."""

    def put(self, key: str, data: bytes) -> None:
        raise NotImplementedError

    def get(self, key: str) -> bytes:
        raise NotImplementedError

    def get_range(self, key: str, offset: int, length: int) -> bytes:
        raise NotImplementedError

    def exists(self, key: str) -> bool:
        raise NotImplementedError

    def keys(self) -> list[str]:
        raise NotImplementedError

    def size(self, key: str) -> int:
        raise NotImplementedError


class BlobStore(ObjectStore):
    """In-memory object store."""

    def __init__(self):
        self._data: dict[str, bytes] = {}
        self._lock = threading.Lock()

    def put(self, key: str, data: bytes) -> None:
        with self._lock:
            self._data[key] = bytes(data)

    def get(self, key: str) -> bytes:
        with self._lock:
            return self._data[key]

    def get_range(self, key: str, offset: int, length: int) -> bytes:
        with self._lock:
            return self._data[key][offset : offset + length]

    def exists(self, key: str) -> bool:
        with self._lock:
            return key in self._data

    def keys(self) -> list[str]:
        with self._lock:
            return sorted(self._data)

    def size(self, key: str) -> int:
        with self._lock:
            return len(self._data[key])


class DirStore(ObjectStore):
    """Directory-backed store (used by the checkpoint replicator).

    The directory is authoritative: every read is served from disk and no
    in-memory copy of object payloads is kept, so replicating a large
    checkpoint costs one resident copy, not two."""

    def __init__(self, root: str | Path):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    def _path(self, key: str) -> Path:
        return self.root / key.replace("/", "__")

    def put(self, key: str, data: bytes) -> None:
        p = self._path(key)
        tmp = p.with_name(p.name + ".tmp")
        tmp.write_bytes(data)
        tmp.rename(p)  # atomic within the fs

    def get(self, key: str) -> bytes:
        return self._path(key).read_bytes()

    def get_range(self, key: str, offset: int, length: int) -> bytes:
        with open(self._path(key), "rb") as f:
            f.seek(offset)
            return f.read(length)

    def exists(self, key: str) -> bool:
        return self._path(key).exists()

    def keys(self) -> list[str]:
        return sorted(p.name.replace("__", "/") for p in self.root.iterdir()
                      if not p.name.endswith(".tmp"))

    def size(self, key: str) -> int:
        return self._path(key).stat().st_size


class FaultInjector:
    """Scripted faults for the real-bytes path.

    * ``kill_worker_after={(path_id, hop): n}`` — one worker on that hop
      dies when it picks up its (n+1)-th chunk; the chunk it carried is
      lost and re-dispatched (the gateway-kill scenario of
      ``events.VMFailure``). With ``workers_per_hop >= 2`` the hop
      survives on its remaining workers.
    * ``corrupt_chunks={chunk_id, ...}`` — the payload is corrupted once in
      flight; the destination's per-chunk checksum catches it and the
      chunk retries (a flaky link, ``events.LinkDegrade``'s ugly cousin).

    ``faults_injected`` counts every fault actually fired.
    """

    def __init__(self, *, kill_worker_after=None, corrupt_chunks=None):
        self.kill_worker_after: dict[tuple[int, int], int] = dict(
            kill_worker_after or {}
        )
        self.corrupt_chunks: set[str] = set(corrupt_chunks or ())
        self.faults_injected = 0
        self._lock = threading.Lock()
        self._pickups: dict[tuple[int, int], int] = {}
        self._killed: set[tuple[int, int]] = set()

    def on_pickup(self, path_id: int, hop: int, ch: Chunk, data: bytes,
                  attempt: int) -> tuple[str, bytes | None]:
        """Called by a hop worker for every chunk it picks up.

        Returns ("ok", data), ("kill", None) — the worker must requeue the
        chunk and die — or ("corrupt", mangled_payload)."""
        with self._lock:
            key = (path_id, hop)
            if key in self.kill_worker_after and key not in self._killed:
                n = self._pickups.get(key, 0)
                self._pickups[key] = n + 1
                if n >= self.kill_worker_after[key]:
                    self._killed.add(key)
                    self.faults_injected += 1
                    return "kill", None
            if data is not None and ch.id in self.corrupt_chunks:
                self.corrupt_chunks.discard(ch.id)
                self.faults_injected += 1
                return "corrupt", bytes([data[0] ^ 0xFF]) + data[1:]
        return "ok", data


@dataclasses.dataclass
class GatewayReport(Report):
    objects: int
    chunks: int
    bytes_moved: int
    checksum_failures: int  # objects whose final assembly failed to verify
    per_path_chunks: dict
    retried_chunks: int = 0  # chunk re-dispatches (kills, corruption, stalls)
    duplicate_chunks: int = 0  # deliveries discarded as already-verified
    faults_injected: int = 0
    objects_skipped: int = 0  # already present + verified at the destination
    chunks_missing: int = 0  # gave up after max_attempts (0 == zero loss)
    workers_leaked: int = 0  # threads still alive after the shutdown join
    # passive telemetry for the calibration plane: per region-pair edge,
    # bytes that crossed the hop and the wall-clock window they crossed in
    per_edge_bytes: dict | None = None  # (a, b) -> bytes
    per_edge_seconds: dict | None = None  # (a, b) -> active seconds

    def link_gbps(self) -> dict:
        """Observed per-edge delivered rate (Gbit/s) — the gateway-side
        feed for ``calibrate.BeliefGrid`` passive updates."""
        out = {}
        for e, nbytes in (self.per_edge_bytes or {}).items():
            secs = (self.per_edge_seconds or {}).get(e, 0.0)
            if secs > 1e-9:
                out[e] = nbytes * 8.0 / 1e9 / secs
        return out

    kind = "gateway"
    _summary_keys = ("objects", "chunks", "delivered_gb", "retried_chunks",
                     "chunks_missing")
    _metrics_prefixes = ("gateway.",)

    def _payload(self) -> dict:
        return {
            "objects": self.objects,
            "chunks": self.chunks,
            "delivered_gb": self.bytes_moved / 1e9,
            "checksum_failures": self.checksum_failures,
            "retried_chunks": self.retried_chunks,
            "duplicate_chunks": self.duplicate_chunks,
            "chunks_missing": self.chunks_missing,
            "objects_skipped": self.objects_skipped,
            "faults_injected": self.faults_injected,
            "workers_leaked": self.workers_leaked,
            "per_edge": per_edge_dict(self.per_edge_bytes,
                                      self.per_edge_seconds),
        }


def _same_object(src_store: ObjectStore, dst_store: ObjectStore, key: str,
                 window: int) -> bool:
    """Streamed equality check for the resume pre-pass: size short-circuit,
    then windowed get_range comparison — no whole-object materialization,
    early exit on the first differing window."""
    size = src_store.size(key)
    if dst_store.size(key) != size:
        return False
    off = 0
    while off < size:
        n = min(window, size - off)
        if src_store.get_range(key, off, n) != dst_store.get_range(key, off, n):
            return False
        off += n
    return True


def transfer_objects(
    plan: TransferPlan,
    src_store: ObjectStore,
    dst_store: ObjectStore,
    object_keys: list[str],
    *,
    chunk_bytes: int = 4 << 20,
    workers_per_hop: int = 4,
    relay_buffer_chunks: int = 32,
    verify: bool = True,
    fault_injector: FaultInjector | None = None,
    max_attempts: int = 5,
    stall_timeout_s: float = 1.0,
    resume: bool = True,
    retry_backoff_s: float = 0.01,
    retry_backoff_cap_s: float = 0.25,
    seed: int = 0,
) -> GatewayReport:
    """Move objects src->dst along the plan's decomposed paths.

    Every path becomes a chain of bounded queues with ``workers_per_hop``
    threads per hop — a faithful miniature of the gateway chain: bounded
    queues ARE the hop-by-hop flow control; idle workers pulling from the
    shared source queue ARE dynamic dispatch. The destination verifies and
    commits chunks independently; anything lost in flight is re-dispatched
    to a surviving path (``max_attempts`` per chunk), so a mid-transfer
    gateway kill completes with zero data loss and no verified byte is
    ever sent twice. ``resume=True`` additionally skips whole objects the
    destination already holds with a matching checksum.

    A ``MulticastPlan`` delegates to ``transfer_objects_multicast`` —
    ``dst_store`` must then be a dict mapping destination region keys to
    stores.
    """
    if isinstance(plan, MulticastPlan):
        return transfer_objects_multicast(
            plan, src_store, dst_store, object_keys,
            chunk_bytes=chunk_bytes, workers_per_hop=workers_per_hop,
            relay_buffer_chunks=relay_buffer_chunks, verify=verify,
            fault_injector=fault_injector, max_attempts=max_attempts,
            stall_timeout_s=stall_timeout_s, resume=resume,
            retry_backoff_s=retry_backoff_s,
            retry_backoff_cap_s=retry_backoff_cap_s, seed=seed,
        )
    paths = plan.paths()
    if not paths:
        raise ValueError("plan has no flow")
    tr = get_tracer()
    w0 = tr.now_wall() if tr.enabled else 0.0

    skipped = 0
    keys_to_move = []
    for key in object_keys:
        if (
            resume and verify and dst_store.exists(key)
            and _same_object(src_store, dst_store, key, chunk_bytes)
        ):
            skipped += 1
            continue
        keys_to_move.append(key)

    all_chunks, chunk_sums, object_sums = chunk_manifest(
        src_store, keys_to_move, chunk_bytes, with_sums=verify
    )
    # zero-byte objects produce no chunks: commit them directly so they are
    # not silently dropped by the chunk-delivery loop
    chunked = {ch.object_key for ch in all_chunks}
    for key in keys_to_move:
        if key not in chunked:
            dst_store.put(key, b"")
    keys_to_move = [k for k in keys_to_move if k in chunked]

    # weighted round-robin pre-binning of chunks to paths
    weights = [f for _, f in paths]
    total_w = sum(weights)
    bins: list[list[Chunk]] = [[] for _ in paths]
    cum = [w / total_w for w in weights]
    acc = [0.0] * len(paths)
    for ch in all_chunks:
        i = max(range(len(paths)), key=lambda j: cum[j] - acc[j])
        bins[i].append(ch)
        acc[i] += 1.0 / max(len(all_chunks), 1)
    per_path_count = {i: len(b) for i, b in enumerate(bins)}

    done_event = threading.Event()
    done_q: "queue.Queue" = queue.Queue()
    retry_q: "queue.Queue" = queue.Queue()
    lock = threading.Lock()
    bytes_moved = [0]
    retried = [0]
    live = {(pid, h): workers_per_hop
            for pid, (path, _) in enumerate(paths)
            for h in range(len(path) - 1)}
    # per region-pair telemetry: bytes across the hop + first/last activity
    edge_of_hop = {(pid, h): (path[h], path[h + 1])
                   for pid, (path, _) in enumerate(paths)
                   for h in range(len(path) - 1)}
    edge_bytes: dict[tuple[int, int], int] = {}
    edge_t0: dict[tuple[int, int], float] = {}
    edge_t1: dict[tuple[int, int], float] = {}

    def _put(q: queue.Queue, item) -> None:
        while not done_event.is_set():
            try:
                q.put(item, timeout=0.05)
                return
            except queue.Full:
                continue

    first_qs: list[queue.Queue] = []
    threads: list[threading.Thread] = []
    for pid, (path, _flow) in enumerate(paths):
        hops = len(path) - 1
        qs: list[queue.Queue] = [queue.Queue()]
        for _ in range(hops - 1):
            qs.append(queue.Queue(maxsize=relay_buffer_chunks))  # flow ctrl
        qs.append(done_q)
        first_qs.append(qs[0])
        for ch in bins[pid]:
            qs[0].put((ch, 0))

        def hop_worker(pid: int, h: int, q_in: queue.Queue,
                       q_out: queue.Queue, first: bool):
            while not done_event.is_set():
                try:
                    item = q_in.get(timeout=0.05)
                except queue.Empty:
                    continue
                t0w = tr.now_wall() if tr.enabled else 0.0
                # the telemetry window opens when the FIRST transfer on the
                # edge begins — stamping at first completion would shave one
                # chunk's time off the window and overstate the link rate
                with lock:
                    edge_t0.setdefault(edge_of_hop[(pid, h)],
                                       time.monotonic())
                if first:
                    ch, attempt = item
                    data = src_store.get_range(ch.object_key, ch.offset,
                                               ch.length)
                else:
                    ch, data, attempt = item
                if fault_injector is not None:
                    action, data = fault_injector.on_pickup(
                        pid, h, ch, data, attempt
                    )
                    if action == "kill":
                        with lock:
                            live[(pid, h)] -= 1
                        retry_q.put((ch, attempt + 1))
                        if tr.enabled:
                            tr.instant("gateway.worker_killed",
                                       tr.now_wall(), track="gateway",
                                       path=pid, hop=h)
                        return  # the worker thread dies with its chunk
                with lock:
                    bytes_moved[0] += len(data)
                    e = edge_of_hop[(pid, h)]
                    edge_bytes[e] = edge_bytes.get(e, 0) + len(data)
                    edge_t1[e] = time.monotonic()
                _put(q_out, (ch, data, attempt))
                if tr.enabled:
                    tr.span("gateway.hop", t0w, tr.now_wall() - t0w,
                            track="gateway", path=pid, hop=h, chunk=ch.id)

        for h in range(hops):
            for _ in range(workers_per_hop):
                t = threading.Thread(
                    target=hop_worker, args=(pid, h, qs[h], qs[h + 1], h == 0),
                    daemon=True,
                )
                threads.append(t)
                t.start()

    # retry feeder: re-dispatch lost chunks onto any path whose every hop
    # still has a live worker (dynamic dispatch across surviving gateways)
    attempts: dict[str, int] = {}
    dead: set[str] = set()
    verified: set[str] = set()
    rr = [0]

    def alive_paths() -> list[int]:
        with lock:
            return [
                pid for pid, (path, _) in enumerate(paths)
                if all(live[(pid, h)] > 0 for h in range(len(path) - 1))
            ]

    def dispatch(ch: Chunk, attempt: int) -> None:
        if ch.id in verified:
            return  # a duplicate copy already landed: nothing to do
        if attempt > max_attempts:
            dead.add(ch.id)
            return
        targets = alive_paths()
        if not targets:
            dead.add(ch.id)
            return
        with lock:
            retried[0] += 1
        _retries.inc()
        pid = targets[rr[0] % len(targets)]
        rr[0] += 1
        attempts[ch.id] = max(attempts.get(ch.id, 0), attempt)
        if tr.enabled:
            tr.instant("gateway.retry", tr.now_wall(), track="gateway",
                       chunk=ch.id, attempt=attempt, path=pid)
        first_qs[pid].put((ch, attempt))

    def feeder():
        # exponential backoff with seeded jitter: re-dispatches wait on a
        # due-time heap instead of sleeping inline, so one backed-off chunk
        # never delays another's (shorter) retry
        rng = random.Random(seed)
        pending: list = []  # (due monotonic time, tiebreak, chunk, attempt)
        tick = 0
        while not done_event.is_set():
            timeout = 0.05
            if pending:
                timeout = max(min(timeout, pending[0][0] - time.monotonic()),
                              0.0)
            try:
                ch, attempt = retry_q.get(timeout=timeout)
                delay = _retry_delay(attempt, retry_backoff_s,
                                     retry_backoff_cap_s, rng)
                tick += 1
                heapq.heappush(
                    pending, (time.monotonic() + delay, tick, ch, attempt)
                )
            except queue.Empty:
                pass
            while pending and pending[0][0] <= time.monotonic():
                _, _, ch, attempt = heapq.heappop(pending)
                dispatch(ch, attempt)

    feeder_t = threading.Thread(target=feeder, daemon=True)
    feeder_t.start()

    # destination: verify + commit chunks independently, reassemble objects
    buffers: dict[str, dict[int, bytes]] = {k: {} for k in keys_to_move}
    expect = {
        k: len(chunk_object(k, src_store.size(k), chunk_bytes))
        for k in keys_to_move
    }
    duplicates = 0
    failures = 0
    stall_rounds = 0
    # adaptive stall detection: a pipeline is only declared stuck once the
    # quiet period exceeds both the configured window and twice the worst
    # inter-delivery gap seen so far, so a slow-but-healthy transfer (cold
    # disk, big chunks) is not flooded with wholesale re-dispatches
    max_gap = stall_timeout_s
    last_delivery = time.monotonic()
    while len(verified) + len(dead - verified) < len(all_chunks):
        try:
            ch, data, attempt = done_q.get(timeout=stall_timeout_s)
        except queue.Empty:
            quiet = time.monotonic() - last_delivery
            if quiet < max(stall_timeout_s, 2.0 * max_gap):
                continue  # plausibly just slow: keep waiting
            # Stuck: every in-flight copy died or sits behind a dead hop.
            # Re-dispatch the missing chunks — the checksummed-resume path:
            # verified chunks are never re-sent. Stall re-sends are bounded
            # by their own round counter (reset on progress), NOT by
            # per-chunk attempts, so timeouts alone never fail a transfer.
            stall_rounds += 1
            _stall_rounds.inc()
            missing = [c for c in all_chunks
                       if c.id not in verified and c.id not in dead]
            if not missing or stall_rounds > max_attempts:
                break
            if tr.enabled:
                tr.instant("gateway.stall", tr.now_wall(), track="gateway",
                           missing=len(missing), round=stall_rounds)
            for c in missing:
                retry_q.put((c, attempts.get(c.id, 0)))
            last_delivery = time.monotonic()  # re-arm for the next round
            continue
        now_t = time.monotonic()
        max_gap = max(max_gap, now_t - last_delivery)
        last_delivery = now_t
        stall_rounds = 0
        if ch.id in verified:
            duplicates += 1
            continue
        if verify and checksum(data) != chunk_sums[ch.id]:
            _checksum_failures.inc()
            if tr.enabled:
                tr.instant("gateway.checksum_fail", tr.now_wall(),
                           track="gateway", chunk=ch.id, attempt=attempt)
            retry_q.put((ch, attempt + 1))
            continue
        verified.add(ch.id)
        dead.discard(ch.id)
        buffers[ch.object_key][ch.index] = data
        if len(buffers[ch.object_key]) == expect[ch.object_key]:
            parts = buffers[ch.object_key]
            blob = b"".join(parts[i] for i in range(len(parts)))
            if verify and checksum(blob) != object_sums[ch.object_key]:
                failures += 1
            dst_store.put(ch.object_key, blob)
            if tr.enabled:
                tr.instant("gateway.commit", tr.now_wall(),
                           track="gateway", key=ch.object_key)

    done_event.set()
    feeder_t.join(timeout=2.0)
    for t in threads:
        t.join(timeout=2.0)
    # a worker blocked inside a store call (hung disk/network read) survives
    # the bounded join: it is a real leak until its syscall returns. Count
    # and surface it — silent thread leaks poison long-lived processes.
    leaked = sum(1 for t in threads if t.is_alive()) + (
        1 if feeder_t.is_alive() else 0
    )
    if leaked:
        _workers_leaked.inc(leaked)
        if tr.enabled:
            tr.instant("gateway.workers_leaked", tr.now_wall(),
                       track="gateway", leaked=leaked)

    missing = len(all_chunks) - len(verified)
    if tr.enabled:
        tr.span("gateway.transfer", w0, tr.now_wall() - w0,
                track="gateway", chunks=len(all_chunks),
                retried=retried[0], leaked=leaked)
    return GatewayReport(
        objects=len(object_keys),
        chunks=len(all_chunks),
        bytes_moved=bytes_moved[0],
        checksum_failures=failures,
        per_path_chunks=per_path_count,
        retried_chunks=retried[0],
        duplicate_chunks=duplicates,
        faults_injected=0 if fault_injector is None
        else fault_injector.faults_injected,
        objects_skipped=skipped,
        chunks_missing=missing,
        workers_leaked=leaked,
        per_edge_bytes=dict(edge_bytes),
        per_edge_seconds={
            e: max(edge_t1[e] - edge_t0[e], 1e-9) for e in edge_bytes
        },
    )


# ------------------------------------------------------------------ multicast
@dataclasses.dataclass
class MulticastGatewayReport(Report):
    """Aggregate + per-destination outcome of a one-to-many transfer."""

    per_dest: dict  # destination region key -> GatewayReport
    chunks: int  # distinct source chunks
    bytes_moved: int  # bytes that crossed ANY hop (envelope accounting)
    retried_chunks: int
    faults_injected: int
    per_tree_chunks: dict  # tree id -> chunks initially binned to it
    workers_leaked: int = 0  # threads still alive after the shutdown join
    # passive telemetry, same shape as the unicast report: per tree-edge
    # region pair, envelope bytes that crossed it (each chunk once, however
    # many destinations it serves downstream) and the active window
    per_edge_bytes: dict | None = None  # (a, b) -> bytes
    per_edge_seconds: dict | None = None  # (a, b) -> active seconds

    def link_gbps(self) -> dict:
        """Observed per-edge delivered rate (Gbit/s) — the fan-out path's
        feed for ``calibrate.BeliefGrid.observe_link_rates``, mirroring
        ``GatewayReport.link_gbps``."""
        out = {}
        for e, nbytes in (self.per_edge_bytes or {}).items():
            secs = (self.per_edge_seconds or {}).get(e, 0.0)
            if secs > 1e-9:
                out[e] = nbytes * 8.0 / 1e9 / secs
        return out

    @property
    def checksum_failures(self) -> int:
        return sum(r.checksum_failures for r in self.per_dest.values())

    @property
    def chunks_missing(self) -> int:
        return sum(r.chunks_missing for r in self.per_dest.values())

    @property
    def duplicate_chunks(self) -> int:
        return sum(r.duplicate_chunks for r in self.per_dest.values())

    kind = "multicast_gateway"
    _summary_keys = ("chunks", "delivered_gb", "retried_chunks",
                     "chunks_missing")
    _metrics_prefixes = ("gateway.",)

    def _payload(self) -> dict:
        return {
            "chunks": self.chunks,
            "delivered_gb": self.bytes_moved / 1e9,
            "checksum_failures": self.checksum_failures,
            "retried_chunks": self.retried_chunks,
            "duplicate_chunks": self.duplicate_chunks,
            "chunks_missing": self.chunks_missing,
            "faults_injected": self.faults_injected,
            "workers_leaked": self.workers_leaked,
            "per_dst": {
                dst: rep.to_dict() for dst, rep in self.per_dest.items()
            },
            "per_edge": per_edge_dict(self.per_edge_bytes,
                                      self.per_edge_seconds),
        }


def transfer_objects_multicast(
    plan: MulticastPlan,
    src_store: ObjectStore,
    dst_stores: dict,
    object_keys: list[str],
    *,
    chunk_bytes: int = 4 << 20,
    workers_per_hop: int = 4,
    relay_buffer_chunks: int = 32,
    verify: bool = True,
    fault_injector: FaultInjector | None = None,
    max_attempts: int = 5,
    stall_timeout_s: float = 1.0,
    resume: bool = True,
    retry_backoff_s: float = 0.01,
    retry_backoff_cap_s: float = 0.25,
    seed: int = 0,
) -> MulticastGatewayReport:
    """Replicate objects to every destination of a multicast plan.

    The plan's distribution trees become a forwarding mesh of bounded
    queues: each tree edge is a stage with ``workers_per_hop`` threads, and
    a worker finishing a chunk fans it out to EVERY downstream stage of the
    tree (deduplicated, so a segment shared by several destinations carries
    each chunk exactly once — the data-plane realization of envelope
    billing) and, where the edge terminates at a destination, hands it to
    that destination's verifier. Each destination verifies and commits
    chunks independently against the source-side checksums; a chunk lost on
    one branch (killed worker, corruption) is re-dispatched for the
    destinations that still miss it, along a surviving tree path to each —
    chunk-level retry per branch, without re-sending to destinations that
    already verified it. ``dst_stores`` maps destination region keys (or
    region indices) to stores; zero-byte objects are committed everywhere.
    """
    keys_of_top = plan.top.keys()
    stores: dict[int, ObjectStore] = {}
    for d in plan.active_dsts:
        store = dst_stores.get(keys_of_top[d], dst_stores.get(d))
        if store is None:
            raise ValueError(f"no destination store for {keys_of_top[d]}")
        stores[d] = store
    trees = plan.trees()
    if not trees or not stores:
        raise ValueError("plan has no flow")
    dests = sorted(stores)
    tr = get_tracer()
    w0 = tr.now_wall() if tr.enabled else 0.0

    # ---- per-destination resume pre-pass
    skipped = {d: 0 for d in dests}
    keys_by_dest: dict[int, set] = {}
    for d in dests:
        need = set()
        for key in object_keys:
            if (
                resume and verify and stores[d].exists(key)
                and _same_object(src_store, stores[d], key, chunk_bytes)
            ):
                skipped[d] += 1
                continue
            need.add(key)
        keys_by_dest[d] = need
    keys_to_move = sorted(set().union(*keys_by_dest.values()))

    all_chunks, chunk_sums, object_sums = chunk_manifest(
        src_store, keys_to_move, chunk_bytes, with_sums=verify
    )
    chunked = {ch.object_key for ch in all_chunks}
    for d in dests:  # zero-byte objects commit directly, everywhere needed
        for key in keys_by_dest[d]:
            if key not in chunked:
                stores[d].put(key, b"")
        keys_by_dest[d] &= chunked
    keys_to_move = [k for k in keys_to_move if k in chunked]
    chunk_by_id = {ch.id: ch for ch in all_chunks}

    # ---- stages: one per (tree, edge)
    class _Stage:
        __slots__ = ("sid", "tid", "edge", "hop", "q", "children",
                     "serves", "deliver")

    stages: list[_Stage] = []
    stage_of: list[dict] = []  # per tree: edge -> stage
    path_stages: dict[tuple[int, int], list[int]] = {}  # (tree, dest) -> sids
    for tid, t in enumerate(trees):
        s_of = {}
        kids = t.children()
        serves = t.dests_of_edge()
        delivers = t.delivers()
        for e in t.edges():
            st = _Stage()
            st.sid = len(stages)
            st.tid = tid
            st.edge = e
            st.hop = 0 if e[0] == plan.src else 1
            st.q = (
                queue.Queue()
                if st.hop == 0
                else queue.Queue(maxsize=relay_buffer_chunks)
            )
            st.serves = serves[e] & set(dests)
            st.deliver = delivers.get(e)
            if st.deliver is not None and st.deliver not in stores:
                st.deliver = None
            s_of[e] = st
            stages.append(st)
        for e in t.edges():
            s_of[e].children = [s_of[c].sid for c in kids[e]]
        stage_of.append(s_of)
        for d, p in t.paths.items():
            if d in stores:
                path_stages[(tid, d)] = [
                    s_of[e].sid for e in zip(p[:-1], p[1:])
                ]

    # ---- chunk -> tree pre-binning by rate share
    weights = [t.rate for t in trees]
    total_w = sum(weights)
    bins: list[list[Chunk]] = [[] for _ in trees]
    cum = [w / total_w for w in weights]
    acc = [0.0] * len(trees)
    for ch in all_chunks:
        i = max(range(len(trees)), key=lambda j: cum[j] - acc[j])
        bins[i].append(ch)
        acc[i] += 1.0 / max(len(all_chunks), 1)
    per_tree_count = {i: len(b) for i, b in enumerate(bins)}

    done_event = threading.Event()
    done_q: "queue.Queue" = queue.Queue()
    retry_q: "queue.Queue" = queue.Queue()  # (chunk, attempt, target dest)
    lock = threading.Lock()
    bytes_moved = [0]
    retried = [0]
    live = {st.sid: workers_per_hop for st in stages}
    # per region-pair telemetry (several stages may share one region pair
    # across trees — the counters aggregate the pair): envelope bytes that
    # crossed the hop and first-pickup/last-completion stamps
    edge_bytes: dict[tuple[int, int], int] = {}
    edge_t0: dict[tuple[int, int], float] = {}
    edge_t1: dict[tuple[int, int], float] = {}
    forwarded: set[tuple[int, str]] = set()  # (sid, chunk id) fan-in dedup
    verified: set[tuple[int, str]] = set()  # (dest, chunk id)
    # every (dest, chunk) pair the transfer owes — fixed up front so retry
    # targeting (and the exit predicate it feeds) ignores destinations that
    # resume-skipped the object
    needed = {
        (d, ch.id) for d in dests for ch in all_chunks
        if ch.object_key in keys_by_dest[d]
    }

    def _put(q: queue.Queue, item) -> None:
        while not done_event.is_set():
            try:
                q.put(item, timeout=0.05)
                return
            except queue.Full:
                continue

    def _fan_out(st: _Stage, ch: Chunk, data: bytes, attempt: int, target):
        """Deliver + forward a chunk that finished traversing ``st``."""
        if st.deliver is not None and (target is None or target == st.deliver):
            done_q.put((st.deliver, ch, data, attempt))
        for csid in st.children:
            child = stages[csid]
            if target is None:
                with lock:
                    if (csid, ch.id) in forwarded:
                        continue
                    forwarded.add((csid, ch.id))
                _put(child.q, (ch, data, attempt, None))
            elif target in child.serves:
                _put(child.q, (ch, data, attempt, target))

    def hop_worker(st: _Stage):
        while not done_event.is_set():
            try:
                item = st.q.get(timeout=0.05)
            except queue.Empty:
                continue
            ch, data, attempt, target = item
            t0w = tr.now_wall() if tr.enabled else 0.0
            # open the edge's telemetry window at FIRST pickup — stamping at
            # first completion would shave one chunk's time off the window
            # and overstate the link rate (same discipline as the unicast
            # path)
            with lock:
                edge_t0.setdefault(st.edge, time.monotonic())
            if data is None:  # root stage: read from the source store once
                data = src_store.get_range(ch.object_key, ch.offset, ch.length)
            if fault_injector is not None:
                action, data = fault_injector.on_pickup(
                    st.tid, st.sid, ch, data, attempt
                )
                if action == "kill":
                    with lock:
                        live[st.sid] -= 1
                    # the chunk retries per branch: one targeted re-dispatch
                    # for every destination downstream of this edge that
                    # still misses it
                    wants = st.serves if target is None else {target}
                    for d in sorted(wants):
                        retry_q.put((ch, attempt + 1, d))
                    if tr.enabled:
                        tr.instant("gateway.worker_killed", tr.now_wall(),
                                   track="gateway", tree=st.tid,
                                   stage=st.sid)
                    return  # the worker dies with its chunk
            with lock:
                bytes_moved[0] += len(data)
                edge_bytes[st.edge] = edge_bytes.get(st.edge, 0) + len(data)
                edge_t1[st.edge] = time.monotonic()
            _fan_out(st, ch, data, attempt, target)
            if tr.enabled:
                tr.span("gateway.hop", t0w, tr.now_wall() - t0w,
                        track="gateway", tree=st.tid, stage=st.sid,
                        chunk=ch.id)

    threads: list[threading.Thread] = []
    for st in stages:
        for _ in range(workers_per_hop):
            t = threading.Thread(target=hop_worker, args=(st,), daemon=True)
            threads.append(t)
            t.start()
    for tid, t in enumerate(trees):
        roots = [stage_of[tid][e] for e in t.roots()]
        for ch in bins[tid]:
            for st in roots:
                st.q.put((ch, None, 0, None))

    # ---- retry feeder: targeted re-dispatch down a surviving branch
    attempts: dict[tuple[int, str], int] = {}
    dead: set[tuple[int, str]] = set()

    def alive_routes(d: int) -> list[tuple[int, int]]:
        with lock:
            return [
                (tid, d) for tid in range(len(trees))
                if (tid, d) in path_stages
                and all(live[s] > 0 for s in path_stages[(tid, d)])
            ]

    rr = [0]

    def dispatch(ch: Chunk, attempt: int, d: int) -> None:
        if (d, ch.id) not in needed or (d, ch.id) in verified:
            return  # not owed / already landed: nothing to do
        if attempt > max_attempts:
            dead.add((d, ch.id))
            return
        routes = alive_routes(d)
        if not routes:
            dead.add((d, ch.id))
            return
        with lock:
            retried[0] += 1
        _retries.inc()
        tid, _ = routes[rr[0] % len(routes)]
        rr[0] += 1
        attempts[(d, ch.id)] = max(attempts.get((d, ch.id), 0), attempt)
        if tr.enabled:
            tr.instant("gateway.retry", tr.now_wall(), track="gateway",
                       chunk=ch.id, attempt=attempt, dest=d, tree=tid)
        stages[path_stages[(tid, d)][0]].q.put((ch, None, attempt, d))

    def feeder():
        # same heap-scheduled exponential backoff as the unicast feeder —
        # per-(dest, chunk) re-dispatches jittered off a shared seeded rng
        rng = random.Random(seed)
        pending: list = []  # (due time, tiebreak, chunk, attempt, dest)
        tick = 0
        while not done_event.is_set():
            timeout = 0.05
            if pending:
                timeout = max(min(timeout, pending[0][0] - time.monotonic()),
                              0.0)
            try:
                ch, attempt, d = retry_q.get(timeout=timeout)
                delay = _retry_delay(attempt, retry_backoff_s,
                                     retry_backoff_cap_s, rng)
                tick += 1
                heapq.heappush(
                    pending, (time.monotonic() + delay, tick, ch, attempt, d)
                )
            except queue.Empty:
                pass
            while pending and pending[0][0] <= time.monotonic():
                _, _, ch, attempt, d = heapq.heappop(pending)
                dispatch(ch, attempt, d)

    feeder_t = threading.Thread(target=feeder, daemon=True)
    feeder_t.start()

    # ---- destinations: verify + commit per (dest, chunk), reassemble
    buffers = {d: {k: {} for k in keys_by_dest[d]} for d in dests}
    expect = {
        k: len(chunk_object(k, src_store.size(k), chunk_bytes))
        for k in keys_to_move
    }
    duplicates = {d: 0 for d in dests}
    failures = {d: 0 for d in dests}
    stall_rounds = 0
    max_gap = stall_timeout_s
    last_delivery = time.monotonic()
    while len(verified) + len(dead - verified) < len(needed):
        try:
            d, ch, data, attempt = done_q.get(timeout=stall_timeout_s)
        except queue.Empty:
            quiet = time.monotonic() - last_delivery
            if quiet < max(stall_timeout_s, 2.0 * max_gap):
                continue  # plausibly just slow: keep waiting
            stall_rounds += 1
            _stall_rounds.inc()
            missing = [p for p in needed if p not in verified and p not in dead]
            if not missing or stall_rounds > max_attempts:
                break
            if tr.enabled:
                tr.instant("gateway.stall", tr.now_wall(), track="gateway",
                           missing=len(missing), round=stall_rounds)
            for dm, cid in missing:
                retry_q.put((chunk_by_id[cid], attempts.get((dm, cid), 0), dm))
            last_delivery = time.monotonic()
            continue
        now_t = time.monotonic()
        max_gap = max(max_gap, now_t - last_delivery)
        last_delivery = now_t
        stall_rounds = 0
        if (d, ch.id) not in needed or (d, ch.id) in verified:
            duplicates[d] = duplicates.get(d, 0) + 1
            continue
        if verify and checksum(data) != chunk_sums[ch.id]:
            _checksum_failures.inc()
            if tr.enabled:
                tr.instant("gateway.checksum_fail", tr.now_wall(),
                           track="gateway", chunk=ch.id, attempt=attempt,
                           dest=d)
            retry_q.put((ch, attempt + 1, d))
            continue
        verified.add((d, ch.id))
        dead.discard((d, ch.id))
        parts = buffers[d][ch.object_key]
        parts[ch.index] = data
        if len(parts) == expect[ch.object_key]:
            blob = b"".join(parts[i] for i in range(len(parts)))
            if verify and checksum(blob) != object_sums[ch.object_key]:
                failures[d] += 1
            stores[d].put(ch.object_key, blob)
            if tr.enabled:
                tr.instant("gateway.commit", tr.now_wall(),
                           track="gateway", key=ch.object_key, dest=d)

    done_event.set()
    feeder_t.join(timeout=2.0)
    for t in threads:
        t.join(timeout=2.0)
    leaked = sum(1 for t in threads if t.is_alive()) + (
        1 if feeder_t.is_alive() else 0
    )
    if leaked:
        _workers_leaked.inc(leaked)
        if tr.enabled:
            tr.instant("gateway.workers_leaked", tr.now_wall(),
                       track="gateway", leaked=leaked)

    per_dest = {}
    for d in dests:
        need_d = {cid for (dd, cid) in needed if dd == d}
        got_d = {cid for (dd, cid) in verified if dd == d}
        per_dest[keys_of_top[d]] = GatewayReport(
            objects=len(object_keys),
            chunks=len(need_d),
            bytes_moved=0,  # envelope bytes are aggregate, see the report
            checksum_failures=failures[d],
            per_path_chunks={},
            duplicate_chunks=duplicates[d],
            objects_skipped=skipped[d],
            chunks_missing=len(need_d - got_d),
        )
    if tr.enabled:
        tr.span("gateway.transfer_multicast", w0, tr.now_wall() - w0,
                track="gateway", chunks=len(all_chunks),
                retried=retried[0], leaked=leaked)
    return MulticastGatewayReport(
        per_dest=per_dest,
        chunks=len(all_chunks),
        bytes_moved=bytes_moved[0],
        retried_chunks=retried[0],
        faults_injected=0 if fault_injector is None
        else fault_injector.faults_injected,
        per_tree_chunks=per_tree_count,
        workers_leaked=leaked,
        per_edge_bytes=dict(edge_bytes),
        per_edge_seconds={
            e: max(edge_t1[e] - edge_t0[e], 1e-9) for e in edge_bytes
        },
    )
