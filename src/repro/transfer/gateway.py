"""In-process gateway data plane moving REAL bytes (paper §3.3/§6).

The fluid simulator (flowsim) models timing; this module implements the
actual mechanics on real data — chunking, bounded relay queues (hop-by-hop
flow control), parallel workers per hop, dynamic chunk dispatch, checksum
verification at the destination — and is what checkpoint replication
(repro.ckpt.replicate) runs on. Object stores are pluggable (in-memory dict
or a directory), mirroring S3/Blob/GCS semantics: immutable puts, no rename.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from pathlib import Path

from repro.core.plan import TransferPlan
from .chunk import Chunk, checksum, chunk_object


class BlobStore:
    """In-memory object store with S3-like semantics."""

    def __init__(self):
        self._data: dict[str, bytes] = {}
        self._lock = threading.Lock()

    def put(self, key: str, data: bytes) -> None:
        with self._lock:
            self._data[key] = bytes(data)

    def get(self, key: str) -> bytes:
        with self._lock:
            return self._data[key]

    def get_range(self, key: str, offset: int, length: int) -> bytes:
        with self._lock:
            return self._data[key][offset : offset + length]

    def exists(self, key: str) -> bool:
        with self._lock:
            return key in self._data

    def keys(self) -> list[str]:
        with self._lock:
            return sorted(self._data)

    def size(self, key: str) -> int:
        with self._lock:
            return len(self._data[key])


class DirStore(BlobStore):
    """Directory-backed store (used by the checkpoint replicator)."""

    def __init__(self, root: str | Path):
        super().__init__()
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    def _path(self, key: str) -> Path:
        p = self.root / key.replace("/", "__")
        return p

    def put(self, key: str, data: bytes) -> None:
        tmp = self._path(key).with_suffix(".tmp")
        tmp.write_bytes(data)
        tmp.rename(self._path(key))  # atomic within the fs

    def get(self, key: str) -> bytes:
        return self._path(key).read_bytes()

    def get_range(self, key: str, offset: int, length: int) -> bytes:
        with open(self._path(key), "rb") as f:
            f.seek(offset)
            return f.read(length)

    def exists(self, key: str) -> bool:
        return self._path(key).exists()

    def keys(self) -> list[str]:
        return sorted(p.name.replace("__", "/") for p in self.root.iterdir()
                      if not p.name.endswith(".tmp"))

    def size(self, key: str) -> int:
        return self._path(key).stat().st_size


@dataclasses.dataclass
class GatewayReport:
    objects: int
    chunks: int
    bytes_moved: int
    checksum_failures: int
    per_path_chunks: dict


_STOP = object()


def transfer_objects(
    plan: TransferPlan,
    src_store: BlobStore,
    dst_store: BlobStore,
    object_keys: list[str],
    *,
    chunk_bytes: int = 4 << 20,
    workers_per_hop: int = 4,
    relay_buffer_chunks: int = 32,
    verify: bool = True,
) -> GatewayReport:
    """Move objects src->dst along the plan's decomposed paths.

    Every path becomes a chain of bounded queues with ``workers_per_hop``
    threads per hop — a faithful miniature of the gateway chain: bounded
    queues ARE the hop-by-hop flow control; idle workers pulling from the
    shared source queue ARE dynamic dispatch."""
    paths = plan.paths()
    if not paths:
        raise ValueError("plan has no flow")

    # chunk all objects; single shared dispatch queue (dynamic assignment)
    all_chunks: list[Chunk] = []
    sums: dict[str, str] = {}
    for key in object_keys:
        size = src_store.size(key)
        all_chunks.extend(chunk_object(key, size, chunk_bytes))
        if verify:
            sums[key] = checksum(src_store.get(key))

    source_q: "queue.Queue" = queue.Queue()
    weights = [f for _, f in paths]
    total_w = sum(weights)
    # weighted round-robin pre-binning of chunks to paths
    import itertools

    bins: list[list[Chunk]] = [[] for _ in paths]
    cum = [w / total_w for w in weights]
    acc = [0.0] * len(paths)
    for ch in all_chunks:
        i = max(range(len(paths)), key=lambda j: cum[j] - acc[j])
        bins[i].append(ch)
        acc[i] += 1.0 / len(all_chunks)

    done_q: "queue.Queue" = queue.Queue()
    per_path_count = {i: len(b) for i, b in enumerate(bins)}
    failures = [0]
    bytes_moved = [0]
    lock = threading.Lock()

    threads: list[threading.Thread] = []
    for pid, (path, _flow) in enumerate(paths):
        hops = len(path) - 1
        qs: list[queue.Queue] = [queue.Queue()]
        for _ in range(hops - 1):
            qs.append(queue.Queue(maxsize=relay_buffer_chunks))  # flow ctrl
        qs.append(done_q)
        for ch in bins[pid]:
            qs[0].put(ch)
        for _ in range(workers_per_hop):
            qs[0].put(_STOP)

        def hop_worker(h: int, q_in: queue.Queue, q_out: queue.Queue,
                       first: bool):
            while True:
                item = q_in.get()
                if item is _STOP:
                    q_out.put(_STOP)
                    return
                if first:
                    ch: Chunk = item
                    data = src_store.get_range(ch.object_key, ch.offset, ch.length)
                    payload = (ch, data)
                else:
                    payload = item
                with lock:
                    bytes_moved[0] += len(payload[1])
                q_out.put(payload)

        for h in range(hops):
            for _ in range(workers_per_hop):
                t = threading.Thread(
                    target=hop_worker, args=(h, qs[h], qs[h + 1], h == 0),
                    daemon=True,
                )
                threads.append(t)
                t.start()

    # destination writer: reassemble objects
    buffers: dict[str, dict[int, bytes]] = {}
    expect: dict[str, int] = {}
    for key in object_keys:
        size = src_store.size(key)
        expect[key] = len(chunk_object(key, size, chunk_bytes))
        buffers[key] = {}

    stops_expected = sum(workers_per_hop for _ in paths)
    stops = 0
    delivered = 0
    while delivered < len(all_chunks) and stops < stops_expected * 2:
        item = done_q.get()
        if item is _STOP:
            stops += 1
            continue
        ch, data = item
        buffers[ch.object_key][ch.index] = data
        delivered += 1
        if len(buffers[ch.object_key]) == expect[ch.object_key]:
            parts = buffers[ch.object_key]
            blob = b"".join(parts[i] for i in range(len(parts)))
            if verify and checksum(blob) != sums[ch.object_key]:
                failures[0] += 1
            dst_store.put(ch.object_key, blob)

    for t in threads:
        t.join(timeout=5.0)

    return GatewayReport(
        objects=len(object_keys),
        chunks=len(all_chunks),
        bytes_moved=bytes_moved[0],
        checksum_failures=failures[0],
        per_path_chunks=per_path_count,
    )
